"""Reproduce the paper's core comparison end-to-end: ChemGCN with the
batched (Fig. 7) vs non-batched (Fig. 6) graph-convolution execution —
identical losses, different wall time.

    PYTHONPATH=src python examples/chemgcn_batched_vs_nonbatched.py
"""
import dataclasses
import time

import jax

from repro.core.formats import BatchedCOO
from repro.core.gcn import GCNConfig, gcn_loss, init_gcn
from repro.data.graphs import GraphDatasetSpec, batches, generate
from repro.optim import AdamConfig, adam_init, adam_update


def train(cfg, spec, data, epochs=2):
    params = init_gcn(jax.random.key(0), cfg)
    opt = AdamConfig(lr=3e-3)
    state = adam_init(params)

    @jax.jit
    def step(params, state, adj_arrays, x, n_nodes, labels):
        adj = [BatchedCOO(*a) for a in adj_arrays]
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gcn_loss(p, cfg, adj, x, n_nodes, labels),
            has_aux=True)(params)
        params, state = adam_update(opt, params, grads, state)
        return params, state, loss, acc

    t0, losses = time.perf_counter(), []
    for epoch in range(epochs):
        for b in batches(data, spec, 50, seed=epoch):
            adj_arrays = [(a.row_ids, a.col_ids, a.values, a.nnz, a.n_rows)
                          for a in b["adj"]]
            params, state, loss, acc = step(
                params, state, adj_arrays, b["x"], b["n_nodes"], b["labels"])
        losses.append(float(loss))
    jax.block_until_ready(loss)
    return time.perf_counter() - t0, losses


def infer_times(cfg, spec, data):
    """Batched single-op inference vs TF-style per-sample dispatch."""
    from repro.core.gcn import apply_gcn

    params = init_gcn(jax.random.key(0), cfg)
    b = next(batches(data, spec, 50))
    adj_arrays = [(a.row_ids, a.col_ids, a.values, a.nnz, a.n_rows)
                  for a in b["adj"]]

    @jax.jit
    def fwd(params, adj_arrays, x, n_nodes):
        adj = [BatchedCOO(*a) for a in adj_arrays]
        return apply_gcn(params, cfg, adj, x, n_nodes)

    jax.block_until_ready(fwd(params, adj_arrays, b["x"], b["n_nodes"]))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(fwd(params, adj_arrays, b["x"], b["n_nodes"]))
    t_batched = (time.perf_counter() - t0) / 5

    def slice_sample(i):
        return ([tuple(x[i:i + 1] for x in a) for a in adj_arrays],
                b["x"][i:i + 1], b["n_nodes"][i:i + 1])

    jax.block_until_ready(fwd(params, *slice_sample(0)))
    t0 = time.perf_counter()
    for _ in range(2):
        for i in range(50):     # one dispatch per sample (TF-style)
            out = fwd(params, *slice_sample(i))
        jax.block_until_ready(out)
    t_dispatch = (time.perf_counter() - t0) / 2
    return t_batched, t_dispatch


def main():
    spec = GraphDatasetSpec.tox21_like(n_samples=300)
    data = generate(spec)
    base = GCNConfig.tox21(impl="ref")
    t_b, l_b = train(base, spec, data)
    t_n, l_n = train(dataclasses.replace(base, batched=False), spec, data)
    print(f"batched    (Fig.7): {t_b:6.2f}s  losses={[round(x,4) for x in l_b]}")
    print(f"nonbatched (Fig.6): {t_n:6.2f}s  losses={[round(x,4) for x in l_n]}")
    print(f"train speedup vs in-graph sequential: {t_n / t_b:.2f}x")
    print("(XLA whole-program compilation already amortizes launches that "
          "TF dispatched per-op; the TF-style baseline is per-sample "
          "dispatch:)")
    ti_b, ti_d = infer_times(base, spec, data)
    print(f"inference batched one-op:      {ti_b*1e3:8.1f} ms/minibatch")
    print(f"inference per-sample dispatch: {ti_d*1e3:8.1f} ms/minibatch")
    print(f"speedup: {ti_d / ti_b:.2f}x  (paper: 1.37x infer end-to-end, "
          "~10x SpMM-only, on P100)")
    assert all(abs(a - b) < 1e-2 for a, b in zip(l_b, l_n)), \
        "batched execution changed numerics!"


if __name__ == "__main__":
    main()
