"""End-to-end driver: pretrain a ~100M-param LM for a few hundred steps with
the fault-tolerant trainer (checkpoints, resume, metrics log).

    PYTHONPATH=src python examples/lm_pretrain.py --steps 300

Uses a llama3-family config scaled to ~100M params; the data stream has
bigram structure, so the loss drop is meaningful (≈ ln(vocab) → much lower).
"""
import argparse
import dataclasses

from repro import configs
from repro.launch.mesh import make_mesh
from repro.launch.train import synthetic_data
from repro.optim import AdamConfig
from repro.training import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    # ~100M params: 12L, d=768, llama3 family (GQA, RoPE, SwiGLU)
    cfg = dataclasses.replace(
        configs.get("llama3-8b"), n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab=8192, head_dim=64, dtype="float32")
    print(f"params ≈ {cfg.param_count()/1e6:.0f}M")
    mesh = make_mesh((1, 1), ("data", "model"))
    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                         log_every=20, checkpoint_dir=args.checkpoint_dir)
    trainer = Trainer(cfg, mesh, AdamConfig(lr=3e-4, grad_clip=1.0), tcfg)
    data = synthetic_data(cfg, batch=8, seq=256)
    trainer.fit(data, on_metrics=lambda s, rec: print(
        f"step {s}: loss {rec['loss']:.4f}", flush=True))


if __name__ == "__main__":
    main()
