"""Giant-graph tier end-to-end (DESIGN.md §14): train a node-classification
GCN on a synthetic 100k-node "reddit-like" powerlaw graph with CSC
neighbor-sampled minibatches, a hot-node feature cache, and the block-aware
``impl="auto"`` kernel dispatch.

    PYTHONPATH=src python examples/node_classification.py --nodes 100000

Prints per-epoch train metrics, the held-out validation accuracy (computed
through the same sampled-block forward), the cache hit rate and the number
of distinct compiled step programs (bounded by the bucket ladder, not the
epoch length).
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.core.gcn import GCNConfig, apply_gcn_blocks
from repro.data.graphs import reddit_like
from repro.optim import AdamConfig
from repro.sampling import (
    FeatureStore,
    HotNodeCache,
    SampledNodeLoader,
    static_hot_ids,
)
from repro.training.trainer import GCNTrainer, TrainerConfig


def evaluate(params, cfg, loader, *, epochs_seed: int = 10_000):
    """Validation accuracy through the sampled forward: one pass over the
    loader's seed set (an out-of-range 'epoch' keeps the eval sample
    independent of any training epoch's randomness)."""
    hits = total = 0
    for batch in loader.epoch(epochs_seed):
        logits = apply_gcn_blocks(
            params, cfg, [b.adj for b in batch.blocks], batch.x,
            m_pads=tuple(b.m_pad for b in batch.blocks))
        pred = np.asarray(jax.numpy.argmax(logits[:len(batch.labels)], -1))
        hits += int((pred == batch.labels).sum())
        total += len(batch.labels)
    return hits / max(total, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=100_000)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--features", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=512)
    ap.add_argument("--fanouts", type=int, nargs="+", default=[10, 5])
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--cache-nodes", type=int, default=4096)
    args = ap.parse_args()

    print(f"generating reddit-like graph: {args.nodes} nodes ...")
    data = reddit_like(args.nodes, n_classes=args.classes,
                       n_features=args.features)
    print(f"  {data.csc.n_edges} edges, "
          f"max in-degree {int(data.csc.in_degrees().max())}")

    store = FeatureStore(data.features)
    cache = HotNodeCache(
        store, args.cache_nodes, policy="static",
        hot_ids=static_hot_ids(data.csc.in_degrees(), args.cache_nodes))
    loader = SampledNodeLoader(
        data.csc, data.features, data.labels, data.train_ids,
        fanouts=args.fanouts, batch_size=args.batch_size, cache=cache)
    val_loader = SampledNodeLoader(
        data.csc, data.features, data.labels, data.val_ids,
        fanouts=args.fanouts, batch_size=args.batch_size, cache=cache)

    cfg = GCNConfig(n_features=args.features, channels=1,
                    conv_widths=(64,) * len(args.fanouts),
                    n_tasks=args.classes, task="multiclass", k_pad=None)
    with tempfile.TemporaryDirectory() as ckpt:
        trainer = GCNTrainer(
            cfg, AdamConfig(lr=5e-3),
            TrainerConfig(checkpoint_dir=ckpt, checkpoint_every=10_000,
                          log_every=20))
        params, _, metrics = trainer.fit_sampled(
            loader, epochs=args.epochs,
            on_metrics=lambda e, r: print(
                f"  epoch {e}: loss {r['loss']:.4f} acc {r['acc']:.3f} "
                f"programs {r['programs']}"))

    val_acc = evaluate(params, cfg, val_loader)
    print(f"val accuracy: {val_acc:.3f} "
          f"(chance {1.0 / args.classes:.3f})")
    print(f"cache hit rate: {cache.hit_rate():.3f} over "
          f"{len(cache)} cached rows")
    print(f"compiled programs: {metrics['programs']}")


if __name__ == "__main__":
    main()
