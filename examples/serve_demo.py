"""Batched serving demo: one compiled decode step serves a queue of requests
in slot-masked waves (the Batched-SpMM idea applied to inference).

    PYTHONPATH=src python examples/serve_demo.py
"""
import jax

from repro import configs
from repro.models import lm
from repro.serving import ServeEngine
from repro.serving.engine import Request


def main():
    cfg = configs.get("mixtral-8x22b").reduced()   # tiny MoE with SWA
    params = lm.init_params(jax.random.key(0), cfg)
    engine = ServeEngine(params, cfg, batch=4, max_len=64, temperature=0.8)
    reqs = [Request(prompt=[1 + i, 7, 42], max_new_tokens=8 + 2 * i)
            for i in range(6)]
    engine.run(reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt={r.prompt} -> out={r.out}")
    assert all(r.done for r in reqs)
    assert all(len(r.out) == r.max_new_tokens for r in reqs)
    print("all requests served (2 waves of 4 slots).")


if __name__ == "__main__":
    main()
