"""Quickstart: train a small ChemGCN with Batched SpMM in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.formats import BatchedCOO
from repro.core.gcn import GCNConfig, gcn_loss, init_gcn
from repro.data.graphs import GraphDatasetSpec, batches, generate
from repro.optim import AdamConfig, adam_init, adam_update


def main():
    spec = GraphDatasetSpec.tox21_like(n_samples=256)
    data = generate(spec)
    cfg = GCNConfig.tox21(impl="auto")         # adaptive dispatch (DESIGN.md
                                               # §5); pin e.g. impl="pallas_ell"
                                               # to override
    params = init_gcn(jax.random.key(0), cfg)
    opt, state = AdamConfig(lr=3e-3), None
    state = adam_init(params)

    @jax.jit
    def step(params, state, adj_arrays, x, n_nodes, labels):
        adj = [BatchedCOO(*a) for a in adj_arrays]
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gcn_loss(p, cfg, adj, x, n_nodes, labels),
            has_aux=True)(params)
        params, state = adam_update(opt, params, grads, state)
        return params, state, loss, acc

    for epoch in range(5):
        for b in batches(data, spec, 32, seed=epoch):
            adj_arrays = [(a.row_ids, a.col_ids, a.values, a.nnz, a.n_rows)
                          for a in b["adj"]]
            params, state, loss, acc = step(
                params, state, adj_arrays, b["x"], b["n_nodes"], b["labels"])
        print(f"epoch {epoch}: loss {float(loss):.4f} acc {float(acc):.3f}")


if __name__ == "__main__":
    main()
