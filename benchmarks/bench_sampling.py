"""Sampled-tier benchmark (DESIGN.md §14): fanout × graph-size sweep over
the CSC neighbor-sampling pipeline, plus the two A-B comparisons the CI
gate pins:

* ``sampling/<geo>/sampled_vs_full`` — forward+backward step time of the
  fanout-sampled minibatch vs the full-batch step over the whole graph at
  the LARGEST geometry (``ratio=`` full/sampled, gated ≥ 1.0: minibatching
  a giant graph must beat stepping it whole, or the tier is pointless).
* ``sampling/cache_{on,off}/fetch`` — feature bytes fetched from the
  backing store over one epoch with and without the hot-node cache
  (``bytes=`` gated: cache-on ≤ cache-off) plus the measured hit rate.

    PYTHONPATH=src python -m benchmarks.bench_sampling
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from benchmarks.common import row, time_fn
from repro.core.csc import make_block
from repro.core.formats import BatchedCOO
from repro.core.gcn import GCNConfig, gcn_node_loss, init_gcn
from repro.data.graphs import reddit_like
from repro.observability.metrics import MetricsRegistry
from repro.sampling import (
    FeatureStore,
    HotNodeCache,
    SampledNodeLoader,
    neighbor_sample,
    static_hot_ids,
)


@functools.partial(jax.jit, static_argnames=("cfg", "m_pads", "impls"))
def _loss_step(params, adj_arrays, x, labels, *, cfg, m_pads, impls):
    adjs = [BatchedCOO(*a) for a in adj_arrays]
    (loss, _), grads = jax.value_and_grad(
        lambda p: gcn_node_loss(p, cfg, adjs, x, labels,
                                m_pads=m_pads, impls=impls),
        has_aux=True)(params)
    return loss, grads


def _step_args(blocks, features, labels_all, seeds, n_features):
    m_pads = tuple(b.m_pad for b in blocks)
    adj_arrays = tuple(
        (b.adj.row_ids, b.adj.col_ids, b.adj.values, b.adj.nnz, b.adj.n_rows)
        for b in blocks)
    x = np.zeros((blocks[0].m_pad, n_features), np.float32)
    x[:blocks[0].n_src] = features[blocks[0].src_ids]
    return adj_arrays, x, labels_all[seeds], m_pads


def _full_blocks(data, n_layers):
    """The whole graph as one square 'block' per layer — the full-batch
    baseline the sampled step is gated against."""
    n = data.csc.n_nodes
    dst = np.repeat(np.arange(n, dtype=np.int64), data.csc.in_degrees())
    b = make_block(dst.astype(np.int32), data.csc.indices.astype(np.int32),
                   np.arange(n, dtype=np.int64), n, normalize="mean")
    return [b] * n_layers


def geometry(n_nodes: int, fanouts: list[int], batch_size: int,
             *, full_baseline: bool) -> None:
    tag = f"n{n_nodes}_f{'x'.join(map(str, fanouts))}"
    data = reddit_like(n_nodes, n_classes=8, n_features=64)
    cfg = GCNConfig(n_features=64, channels=1,
                    conv_widths=(64,) * len(fanouts),
                    n_tasks=8, task="multiclass", impl="ref", k_pad=None)
    params = init_gcn(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    seeds = rng.choice(n_nodes, batch_size, replace=False)

    t_sample = time_fn(
        lambda i: neighbor_sample(data.csc, seeds, fanouts, seed=(0, int(i))),
        3, warmup=1, iters=5)
    blocks = neighbor_sample(data.csc, seeds, fanouts, seed=(0, 0))
    row(f"sampling/{tag}/sample", t_sample * 1e6,
        f"nnz={sum(b.nnz for b in blocks)},"
        f"src={blocks[0].n_src},max_deg={max(b.max_deg for b in blocks)}")

    adj, x, y, m_pads = _step_args(blocks, data.features, data.labels,
                                   seeds, 64)
    t_samp_step = time_fn(
        lambda: _loss_step(params, adj, x, y, cfg=cfg, m_pads=m_pads,
                           impls=None))
    row(f"sampling/{tag}/step", t_samp_step * 1e6,
        f"batch={batch_size},m_pads={'x'.join(map(str, m_pads))}")

    if full_baseline:
        fb = _full_blocks(data, len(fanouts))
        fadj, fx, fy, fm = _step_args(fb, data.features, data.labels,
                                      np.arange(n_nodes), 64)
        t_full = time_fn(
            lambda: _loss_step(params, fadj, fx, fy, cfg=cfg, m_pads=fm,
                               impls=None),
            warmup=1, iters=3)
        row(f"sampling/{tag}/full_batch_step", t_full * 1e6,
            f"nodes={n_nodes},nnz={fb[0].nnz}")
        row(f"sampling/{tag}/sampled_vs_full", t_samp_step * 1e6,
            f"ratio={t_full / t_samp_step:.2f}")


def cache_sweep(n_nodes: int, fanouts: list[int], batch_size: int) -> None:
    """One epoch's backing-store traffic, cache on vs off (fresh registry
    per arm so the counters don't mix)."""
    data = reddit_like(n_nodes, n_classes=8, n_features=64)
    results = {}
    for arm in ("off", "on"):
        reg = MetricsRegistry()
        store = FeatureStore(data.features, registry=reg)
        cache = None
        if arm == "on":
            cap = max(256, n_nodes // 16)
            cache = HotNodeCache(
                store, cap, policy="static",
                hot_ids=static_hot_ids(data.csc.in_degrees(), cap),
                registry=reg)
        loader = SampledNodeLoader(
            data.csc, data.features, data.labels, data.train_ids,
            fanouts=fanouts, batch_size=batch_size,
            cache=cache, store=store, registry=reg)
        for _ in loader.epoch(0):
            pass
        nbytes = store._fetch_bytes.total()
        hit = cache.hit_rate() if cache else 0.0
        results[arm] = nbytes
        row(f"sampling/cache_{arm}/fetch", 0.0,
            f"bytes={int(nbytes)},hit_rate={hit:.3f}")
    saved = 1.0 - results["on"] / max(results["off"], 1.0)
    row("sampling/cache/summary", 0.0, f"traffic_saved={saved:.3f}")


def main(smoke: bool = False) -> None:
    if smoke:
        geos = [(2000, [5, 3], 128), (6000, [10, 5], 256)]
        cache_geo = (6000, [10, 5], 256)
    else:
        geos = [(20000, [10, 5], 512), (50000, [10, 5], 512),
                (50000, [15, 10], 512)]
        cache_geo = (50000, [10, 5], 512)
    for i, (n, fanouts, bs) in enumerate(geos):
        geometry(n, fanouts, bs, full_baseline=(i == len(geos) - 1))
    cache_sweep(*cache_geo)


if __name__ == "__main__":
    main()
