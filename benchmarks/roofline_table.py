"""Render the §Roofline table from the dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh pod16x16] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(mesh: str, include_variants: bool = False) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ROOT, mesh, "*.json"))):
        name = os.path.basename(path)[:-5]
        if not include_variants and name.count("__") != 1:
            continue   # §Perf variant records carry a __<suffix>
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt(rec: dict, md: bool) -> str:
    if rec["status"] == "skipped":
        cells = [rec["arch"], rec["cell"], "N/A", "", "", "", "skipped", "", ""]
    elif rec["status"] == "error":
        cells = [rec["arch"], rec["cell"], "ERROR",
                 rec.get("error", "")[:60], "", "", "", "", ""]
    else:
        cells = [
            rec["arch"], rec["cell"],
            f"{rec['t_compute']:.3f}",
            f"{rec['t_memory']:.3f}",
            f"{rec['t_collective']:.3f}",
            rec["bottleneck"],
            f"{rec['model_flops']:.2e}",
            f"{rec['useful_flops_ratio']:.3f}",
            f"{rec['peak_fraction']:.4f}",
        ]
    sep = " | " if md else ","
    line = sep.join(str(c) for c in cells)
    return f"| {line} |" if md else line


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    cols = ["arch", "cell", "t_compute(s)", "t_memory(s)", "t_collective(s)",
            "bottleneck", "MODEL_FLOPS", "useful_ratio", "peak_frac"]
    if args.md:
        print("| " + " | ".join(cols) + " |")
        print("|" + "---|" * len(cols))
    else:
        print(",".join(cols))
    for rec in load(args.mesh):
        print(fmt(rec, args.md))


if __name__ == "__main__":
    main()
