"""Format-conversion overhead (paper §IV honesty check): COO→ELL / COO→dense
conversion cost relative to one SpMM — the paper's argument for staying with
simple formats is that exotic-format conversion costs ≳ several SpMMs."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import coo_to_dense, coo_to_ell, random_batch
from repro.core.spmm import batched_spmm


def main(batch=100, dim=50, nnz=2, n_b=128):
    rng = np.random.default_rng(4)
    coo, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)
    b = jnp.asarray(rng.normal(size=(batch, m_pad, n_b)), jnp.float32)

    t_spmm = time_fn(jax.jit(functools.partial(batched_spmm, impl="ref")),
                     coo, b)
    t_ell = time_fn(jax.jit(functools.partial(coo_to_ell, m_pad=m_pad,
                                              k_pad=nnz + 3)), coo)
    t_dense = time_fn(jax.jit(functools.partial(coo_to_dense, m_pad=m_pad)),
                      coo)
    row("conversion/spmm_ref", t_spmm * 1e6, "1.00xSpMM")
    row("conversion/coo_to_ell", t_ell * 1e6, f"{t_ell / t_spmm:.2f}xSpMM")
    row("conversion/coo_to_dense", t_dense * 1e6, f"{t_dense / t_spmm:.2f}xSpMM")


if __name__ == "__main__":
    main()
