"""Paper Fig. 9: parameter sweeps — batch size (50/100/200), matrix dim
(32/64/128), nnz/row (1/5) — for the batched approaches."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import random_batch
from repro.core.spmm import batched_spmm, resolve_impl


def one(batch, dim, nnz, n_b=128):
    rng = np.random.default_rng(1)
    coo, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)
    b = jnp.asarray(rng.normal(size=(batch, m_pad, n_b)), jnp.float32)
    total_nnz = float(jnp.sum(coo.nnz))
    for impl in ("ref", "dense", "loop", "auto"):
        fn = jax.jit(functools.partial(batched_spmm, impl=impl,
                                       k_pad=nnz + 2))
        t = time_fn(fn, coo, b)
        gflops = 2 * total_nnz * n_b / t / 1e9
        derived = f"{gflops:.2f}GFLOPS"
        if impl == "auto":
            d = resolve_impl(coo, b, k_pad=nnz + 2)
            derived += f"->{d.impl}(case{d.case})"
        row(f"fig9/b{batch}_dim{dim}_nnz{nnz}/{impl}", t * 1e6, derived)


def main():
    for batch in (50, 100, 200):            # Fig 9-(b)/(d): batch scaling
        one(batch, 64, 2)
    for dim in (32, 64, 128):               # Fig 9-(a)/(b)/(c): dim scaling
        one(100, dim, 2)
    for nnz in (1, 5):                      # Fig 9-(e)/(f): density scaling
        one(100, 64, nnz)


if __name__ == "__main__":
    main()
