"""Device-count scaling of the mesh-sharded Batched SpMM (DESIGN.md §6).

Each device count runs in its own subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (XLA locks the host
device count at first init, so a sweep cannot share a process). The worker
jits one forward sharded_batched_spmm call on a fixed global workload and
reports median wall time; the parent prints a markdown table ready for
EXPERIMENTS.md §Sharding.

CPU caveat (benchmarks/common.py): forced host devices are threads on one
CPU, so absolute speedups understate a real multi-chip mesh — what the sweep
demonstrates is the *structure*: per-shard work drops as batch/N, the
forward path all-gathers nothing, and the per-shard ``impl="auto"``
decision re-resolves against the local workload.

    PYTHONPATH=src python -m benchmarks.bench_sharded [--smoke]
        [--devices 1,2,4,8] [--batch 64] [--dim 56] [--nnz-per-row 4]
        [--n-feat 64] [--impl auto]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

_WORKER = r"""
import sys
import jax, jax.numpy as jnp, numpy as np
from benchmarks.common import time_fn
from repro.core.formats import random_batch
from repro.distributed.spmm import resolve_sharded_impl, sharded_batched_spmm
from repro.kernels.ops import batched_spmm, resolve_impl

batch, dim, nnz_per_row, n_feat = (int(x) for x in sys.argv[1:5])
impl = sys.argv[5]
n_dev = len(jax.devices())
rng = np.random.default_rng(0)
a, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz_per_row)
b = jnp.asarray(rng.standard_normal((batch, m_pad, n_feat)), jnp.float32)

if n_dev == 1:
    fn = jax.jit(lambda v, bb: batched_spmm(a.with_values(v), bb, impl=impl))
    chosen = resolve_impl(a, b, impl=impl).impl
else:
    mesh = jax.make_mesh((n_dev,), ("data",))
    fn = jax.jit(lambda v, bb: sharded_batched_spmm(
        a.with_values(v), bb, mesh=mesh, impl=impl))
    chosen = resolve_sharded_impl(a, b, mesh, impl=impl).impl
t = time_fn(fn, a.values, b, warmup=2, iters=5)
print(f"ROW,{n_dev},{-(-batch // n_dev)},{chosen},{t * 1e3:.3f}")
"""


def sweep(devices: list[int], *, batch: int, dim: int, nnz_per_row: int,
          n_feat: int, impl: str) -> list[tuple[int, int, str, float]]:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    rows = []
    for n in devices:
        env = {**os.environ,
               "XLA_FLAGS": f"--xla_force_host_platform_device_count={n}",
               "JAX_PLATFORMS": "cpu",
               "PYTHONPATH": os.pathsep.join(
                   [src, os.path.join(src, "..")]
                   + [p for p in os.environ.get(
                       "PYTHONPATH", "").split(os.pathsep) if p])}
        r = subprocess.run(
            [sys.executable, "-c", _WORKER, str(batch), str(dim),
             str(nnz_per_row), str(n_feat), impl],
            capture_output=True, text=True, env=env, timeout=900)
        line = [ln for ln in r.stdout.splitlines() if ln.startswith("ROW,")]
        if not line:
            print(f"device_count={n} FAILED:\n{r.stdout}\n{r.stderr}",
                  file=sys.stderr)
            continue
        _, n_dev, local_b, chosen, ms = line[0].split(",")
        rows.append((int(n_dev), int(local_b), chosen, float(ms)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + {1,2,8} devices (CI mode)")
    ap.add_argument("--devices", default="1,2,4,8")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--dim", type=int, default=56)
    ap.add_argument("--nnz-per-row", type=int, default=4)
    ap.add_argument("--n-feat", type=int, default=64)
    ap.add_argument("--impl", default="auto")
    args = ap.parse_args()

    devices = [int(x) for x in args.devices.split(",")]
    if args.smoke:
        devices = [1, 2, 8]
        args.batch, args.dim, args.n_feat = 32, 24, 32

    rows = sweep(devices, batch=args.batch, dim=args.dim,
                 nnz_per_row=args.nnz_per_row, n_feat=args.n_feat,
                 impl=args.impl)
    if not rows:
        raise SystemExit("no sweep rows produced")
    # normalize against the first SURVIVING row and label it honestly (a
    # failed n=1 worker must not masquerade as the 1-device baseline)
    base_dev, _, _, base = rows[0]
    print(f"\nglobal workload: batch={args.batch} dim={args.dim} "
          f"nnz/row={args.nnz_per_row} n_b={args.n_feat} "
          f"impl={args.impl} (CPU, forced host devices)")
    print(f"| devices | batch/shard | resolved impl | ms/call "
          f"| vs {base_dev} dev |")
    print("|---|---|---|---|---|")
    for n_dev, local_b, chosen, ms in rows:
        print(f"| {n_dev} | {local_b} | {chosen} | {ms:.2f} "
              f"| {base / ms:.2f}× |")


if __name__ == "__main__":
    main()
