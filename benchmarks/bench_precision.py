"""Reduced-precision sweep (DESIGN.md §10): bf16/i8 kernel variants vs their
f32 base impls on the paper's Fig. 8–10 geometries.

Two measurements per geometry, persisted to ``BENCH_precision.json``:

- **speedup** — each XLA-lowered reduced variant timed against ITS OWN f32
  base impl on identical inputs (``ell`` vs ``ell_bf16``, ``csr`` vs
  ``csr_bf16``) — the same-class comparison ``impl="auto"`` ranks when a
  layer opts into a dtype policy. Pallas variants are interpret-mode Python
  on CPU (correctness paths, never timed here — the cost model prices their
  TPU bytes);
- **max-abs-error** — EVERY variant's forward output against the f32 ref
  oracle, the measured counterpart of the tolerance table in
  tests/oracle.py. Rows publish ``dtype=…`` and ``maxerr=…`` markers that
  ``benchmarks/check_bench_json.py`` gates per-dtype in CI.

The ``precision/summary/auto`` row records what ``impl="auto"`` actually
selects under a bf16 policy on each geometry and the best measured
same-class speedup among geometries where it picked a reduced variant —
``reduced_selected=1`` + ``best_speedup>=1.0`` is the ISSUE 6 acceptance
gate (also enforced by check_bench_json.py).
"""
from __future__ import annotations

import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_formats import GEOMETRIES, SMOKE
from benchmarks.common import row, time_fn
from repro.autotune import PRECISION_IMPLS, Workload, precision_of, select_impl
from repro.core import max_row_degree, random_batch
from repro.core.spmm import batched_spmm

# XLA-lowered (wall-clockable on CPU) variant → base pairs; the Pallas
# variants appear in the accuracy rows only.
TIMED_VARIANTS = ("ell_bf16", "csr_bf16")


def _inputs(name: str, batch, dim, nnz, n_b):
    rng = np.random.default_rng(zlib.crc32(name.encode()))
    coo, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)
    b = jnp.asarray(rng.normal(size=(batch, m_pad, n_b)), jnp.float32)
    k_pad = int(np.asarray(max_row_degree(coo, m_pad)).max())
    return coo, m_pad, b, k_pad


def _max_abs_error(coo, b, k_pad, impl) -> float:
    want = np.asarray(batched_spmm(coo, b, impl="ref"), np.float32)
    got = np.asarray(batched_spmm(coo, b, impl=impl, k_pad=k_pad),
                     np.float32)
    return float(np.max(np.abs(got - want))) if want.size else 0.0


def sweep_geometry(name: str, batch, dim, nnz, n_b, *, iters: int = 10):
    """Per-geometry: time each timed variant vs its f32 base, record the
    auto decision under a bf16 policy. Returns (selected impl, measured
    same-class speedup of the selection — 0.0 when auto stayed f32)."""
    coo, m_pad, b, k_pad = _inputs(name, batch, dim, nnz, n_b)
    speedups: dict[str, float] = {}
    for variant in TIMED_VARIANTS:
        base = precision_of(variant)[0]
        t_base = time_fn(
            jax.jit(functools.partial(batched_spmm, impl=base, k_pad=k_pad)),
            coo, b, warmup=2, iters=iters)
        t_var = time_fn(
            jax.jit(functools.partial(batched_spmm, impl=variant,
                                      k_pad=k_pad)),
            coo, b, warmup=2, iters=iters)
        speedups[variant] = t_base / t_var
        err = _max_abs_error(coo, b, k_pad, variant)
        row(f"precision/{name}/{variant}", t_var * 1e6,
            f"dtype={precision_of(variant)[1]} base={base} "
            f"speedup={speedups[variant]:.2f} maxerr={err:.4f}")

    w = Workload(batch=coo.batch, m_pad=m_pad, nnz_pad=coo.nnz_pad,
                 k_pad=k_pad, n_b=n_b, itemsize=4, dtype="bf16")
    selected = select_impl(w, allow_pallas=False).impl
    speedup = speedups.get(selected, 0.0)
    row(f"precision/{name}/auto", 0.0,
        f"impl={selected} speedup={speedup:.2f}")
    return selected, speedup


def accuracy_rows(smoke: bool = False):
    """Forward max-abs-error of EVERY registered variant (Pallas ones run
    interpret-mode) on the skew geometry — the measured face of the oracle
    tolerance table."""
    geo = (SMOKE if smoke else GEOMETRIES)["fig10"]
    coo, m_pad, b, k_pad = _inputs("fig10", *geo)
    for variant in PRECISION_IMPLS:
        if precision_of(variant)[0] == "fused":
            continue            # layer-class: exercised in bench_fused
        err = _max_abs_error(coo, b, k_pad, variant)
        row(f"precision/accuracy/{variant}", 0.0,
            f"dtype={precision_of(variant)[1]} maxerr={err:.4f}")


def main(smoke: bool = False):
    geos = SMOKE if smoke else GEOMETRIES
    best_impl, best = "", 0.0
    for name, (batch, dim, nnz, n_b) in geos.items():
        selected, speedup = sweep_geometry(name, batch, dim, nnz, n_b,
                                           iters=5 if smoke else 10)
        if speedup > best:
            best_impl, best = selected, speedup
    accuracy_rows(smoke=smoke)
    reduced = int(best_impl in PRECISION_IMPLS and best > 0.0)
    # the ISSUE 6 acceptance row: auto (under a bf16 policy) picked a
    # reduced variant that measured >= 1.0x against its own f32 base on at
    # least one Fig. 8-10 geometry. check_bench_json.py gates this.
    row("precision/summary/auto", 0.0,
        f"impl={best_impl or 'none'} reduced_selected={reduced} "
        f"best_speedup={best:.2f}")
    return {"impl": best_impl, "best_speedup": best}


if __name__ == "__main__":
    import sys

    from benchmarks.common import header

    header()
    main(smoke="--smoke" in sys.argv)
