"""Paper Fig. 8: SpMM throughput, batched vs non-batched vs dense GEMM,
sweeping the dense-operand width n_B. GFLOPS = 2·nnz·n_B / time (the paper's
metric — the dense baseline is charged the same useful FLOPs).

Baselines, mapped from the paper's GPU setting to this runtime:
- ``dispatch``: one jitted SpMM call per sample, Python loop — the honest
  analogue of TF's per-(sample)-kernel-launch execution (dispatch overhead +
  no batching), the thing Batched SpMM eliminates;
- ``scan``: per-sample sequential inside ONE compiled program (an XLA-fused
  sequential lower bound the paper's TF baseline cannot reach);
- batched: ``ref`` (scatter-add), ``ell`` (gather+contraction), ``dense``
  (gemmBatched analogue) — one device op for the whole batch; we report
  best-of like the paper reports best-of csrmm/csrmm2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import random_batch
from repro.core.spmm import batched_spmm, resolve_impl
from repro.kernels.ref import spmm_coo_single

# "auto" rides along so every figure also reports the adaptive dispatcher's
# choice (DESIGN.md §5) next to the hand-picked impls it replaces.
BATCHED = ("ref", "ell", "dense", "auto")


def _dispatch_baseline(coo, b, m_pad):
    """One jitted per-sample SpMM, dispatched sample by sample."""
    single = jax.jit(functools.partial(spmm_coo_single, m_out=m_pad))

    def run(coo, b):
        outs = [single(coo.row_ids[i], coo.col_ids[i], coo.values[i], b[i])
                for i in range(b.shape[0])]
        return jax.block_until_ready(outs[-1])

    return run


def run(batch=100, dim=50, nnz=2, n_bs=(16, 64, 128, 512),
        include_pallas=False):
    rng = np.random.default_rng(0)
    coo, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)
    total_nnz = float(jnp.sum(coo.nnz))
    results = {}
    for n_b in n_bs:
        b = jnp.asarray(rng.normal(size=(batch, m_pad, n_b)), jnp.float32)
        disp = _dispatch_baseline(coo, b, m_pad)
        t = time_fn(disp, coo, b, warmup=1, iters=5)
        results[("dispatch", n_b)] = t
        row(f"fig8/dim{dim}/nB{n_b}/dispatch", t * 1e6,
            f"{2 * total_nnz * n_b / t / 1e9:.2f}GFLOPS")
        impls = BATCHED + (("loop",) if n_b <= 128 else ("loop",))
        impls = impls + (("pallas_coo", "pallas_ell") if include_pallas
                         else ())
        for impl in impls:
            fn = jax.jit(functools.partial(
                batched_spmm, impl=impl, k_pad=max(nnz + 2, 4)))
            t = time_fn(fn, coo, b)
            name = "scan" if impl == "loop" else impl
            results[(name, n_b)] = t
            derived = f"{2 * total_nnz * n_b / t / 1e9:.2f}GFLOPS"
            if impl == "auto":
                d = resolve_impl(coo, b, k_pad=max(nnz + 2, 4))
                derived += f"->{d.impl}(case{d.case})"
            row(f"fig8/dim{dim}/nB{n_b}/{name}", t * 1e6, derived)
    for n_b in n_bs:
        best = min(results[(i, n_b)] for i in BATCHED)
        sp = results[("dispatch", n_b)] / best
        row(f"fig8/dim{dim}/nB{n_b}/speedup_batched_vs_dispatch", 0.0,
            f"{sp:.2f}x")
        best_sparse = min(results[(i, n_b)] for i in ("ref", "ell"))
        row(f"fig8/dim{dim}/nB{n_b}/batchedspmm_vs_batchedgemm", 0.0,
            f"{results[('dense', n_b)] / best_sparse:.2f}x")
    return results


def main():
    run(dim=50, nnz=2)                    # Fig 8-(a) regime (GCN graphs)
    run(dim=256, nnz=5, n_bs=(64, 512))   # Fig 8-(b) larger matrices


if __name__ == "__main__":
    main()
