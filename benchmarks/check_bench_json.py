"""CI sanity gate for the persisted ``BENCH_*.json`` artifacts.

Two classes of failure, both cheap to hit when a harness regresses silently:

1. **Schema** — a persisted file missing its required top-level keys
   (``suite``/``backend``/``rows``) or rows missing ``name``/``us_per_call``
   /``derived`` would break the cross-PR perf-trajectory tooling downstream.
2. **Regression guard** — rows that publish an explicit ``ratio=<float>``
   field in ``derived`` (e.g. ``bench_formats``'s ``best=csr,ratio=1.31``
   rows, defined so the ratio is ≥ 1.0 by construction) must never report
   below ``MIN_RATIO``: a value that low means the measured comparison
   inverted — the harness or the kernel it guards broke, not timing noise.
   Free-form ``...x`` annotations (like the fused bench's CPU wall ratios)
   are NOT guarded; only the explicit ``ratio=`` marker opts a row in.
3. **Precision gates** (``BENCH_precision.json`` only, suite="precision"):
   every row publishing ``maxerr=`` must stay within the per-dtype error
   ceiling (the measured counterpart of the tests/oracle.py tolerance
   table), and the ``precision/summary/auto`` row must report
   ``reduced_selected=1`` with ``best_speedup>=1.0`` — the ISSUE 6
   acceptance: ``impl="auto"`` under a bf16 policy picks a reduced variant
   that measured at least parity against its own f32 base on one of the
   Fig. 8–10 geometries. Note the precision rows use a ``speedup=`` marker,
   not ``ratio=`` — a same-class dtype comparison on a paper geometry is
   real measurement (gated at 1.0 on the summary's best), not the
   ≥-1.0-by-construction ``best=`` rows the loose MIN_RATIO floor guards.

4. **Formats gates** (``BENCH_formats.json`` only, suite="formats"): every
   geometry must carry a measured ``hybrid`` row (the degree-binned dispatch
   stays in the sweep), the degree-skewed ``powerlaw`` geometry must be
   present, and its ``formats/powerlaw/best_tpu_model`` row must name
   ``best=pallas_hybrid`` with ``ratio>=1.0`` — the ISSUE 8 acceptance pin
   that the cost model keeps picking the hybrid path over the prior best
   sparse class on its target regime.

5. **g-SpMM gates** (``BENCH_gspmm.json`` only, suite="gspmm"): every
   ``maxerr=`` row must stay within the f32 ceiling (all g-SpMM impls are
   full precision), and all 9 ``gspmm/<op>_<reduce>/best`` rows plus the
   ``gspmm/gat_vector/best`` vector-edge row must be present — the sweep
   covering the full message-passing matrix is itself part of the ISSUE 7
   acceptance.

6. **Sampling gates** (``BENCH_sampling.json`` only, suite="sampling"): the
   cache on/off rows must both publish ``bytes=`` with cache-on fetching no
   more than cache-off, and every ``sampled_vs_full`` row must report
   ``ratio >= 1.0`` — the ISSUE 10 acceptance that fanout-sampled minibatch
   steps beat the full-batch step at the largest benchmarked geometry.

Exit code 1 with one line per problem; silent 0 otherwise.

    PYTHONPATH=src python -m benchmarks.check_bench_json [paths...]
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

from benchmarks.common import REPO_ROOT

REQUIRED_TOP = ("suite", "backend", "rows")
REQUIRED_ROW = ("name", "us_per_call", "derived")
RATIO_RE = re.compile(r"(?:^|[ ,;])ratio=([-+0-9.eE]+)")
MIN_RATIO = 0.5

# --- precision-suite gates (class 3 above) -------------------------------
MAXERR_RE = re.compile(r"(?:^|[ ,;])maxerr=([-+0-9.eE]+)")
DTYPE_RE = re.compile(r"(?:^|[ ,;])dtype=(\w+)")
# per-dtype forward max-abs-error ceilings vs the f32 ref oracle, sized
# ~2x above the tests/oracle.py tolerances (bench geometries are larger
# than the oracle cases, so storage rounding accumulates more slack)
MAX_ERR = {"f32": 1e-4, "bf16": 0.15, "i8": 0.5}
SUMMARY_ROW = "precision/summary/auto"
SUMMARY_RE = re.compile(
    r"reduced_selected=([01]).*best_speedup=([-+0-9.eE]+)")
MIN_BEST_SPEEDUP = 1.0

# --- formats-suite gates (BENCH_formats.json, suite="formats") ------------
HYBRID_MODEL_ROW = "formats/powerlaw/best_tpu_model"
BEST_RE = re.compile(r"(?:^|[ ,;])best=(\w+)")
MIN_HYBRID_RATIO = 1.0


def _check_formats_rows(path, rows) -> list[str]:
    errors: list[str] = []
    names = {r.get("name") for r in rows}
    geos = sorted({n.split("/")[1] for n in names
                   if isinstance(n, str) and n.startswith("formats/")})
    for g in geos:
        if f"formats/{g}/hybrid" not in names:
            errors.append(
                f"{path.name}: geometry {g!r} has no measured hybrid row — "
                "the degree-binned dispatch fell out of the sweep")
    if not any(g.startswith("powerlaw") for g in geos):
        errors.append(
            f"{path.name}: no powerlaw geometry — the degree-skewed family "
            "the hybrid path targets is no longer benchmarked")
        return errors
    brow = next((r for r in rows if r.get("name") == HYBRID_MODEL_ROW), None)
    if brow is None:
        errors.append(
            f"{path.name}: missing required row {HYBRID_MODEL_ROW!r}")
        return errors
    derived = str(brow.get("derived", ""))
    bm = BEST_RE.search(derived)
    rm = RATIO_RE.search(derived)
    if bm is None or bm.group(1) != "pallas_hybrid":
        errors.append(
            f"{path.name}: {HYBRID_MODEL_ROW} best="
            f"{bm.group(1) if bm else '<missing>'} != pallas_hybrid — the "
            "cost model stopped picking the hybrid path on the skewed "
            "regime (ISSUE 8 gate)")
    if rm is None or float(rm.group(1)) < MIN_HYBRID_RATIO:
        errors.append(
            f"{path.name}: {HYBRID_MODEL_ROW} ratio="
            f"{rm.group(1) if rm else '<missing>'} < {MIN_HYBRID_RATIO} — "
            "hybrid no longer beats the prior best sparse impl "
            "(ISSUE 8 gate)")
    return errors


# --- gspmm-suite gates (BENCH_gspmm.json, suite="gspmm") ------------------
# every g-SpMM impl is f32, so its maxerr= rows are held to the f32 ceiling
# by the shared maxerr machinery; additionally the sweep must cover the
# FULL (op × reduce) message-passing matrix — a corner silently dropped
# from bench_gspmm.py would otherwise read as "covered" downstream.
GSPMM_CORNERS = tuple(
    f"gspmm/{op}_{red}/best"
    for op in ("mul", "add", "copy_lhs")
    for red in ("sum", "max", "mean")) + ("gspmm/gat_vector/best",)


def _check_gspmm_rows(path, rows) -> list[str]:
    errors: list[str] = []
    names = {r.get("name") for r in rows}
    for corner in GSPMM_CORNERS:
        if corner not in names:
            errors.append(
                f"{path.name}: missing required row {corner!r} — the "
                "(op × reduce) sweep no longer covers the full matrix")
    for i, r in enumerate(rows):
        derived = str(r.get("derived", ""))
        m = MAXERR_RE.search(derived)
        if m and float(m.group(1)) > MAX_ERR["f32"]:
            errors.append(
                f"{path.name}: rows[{i}] ({r.get('name')}) maxerr="
                f"{float(m.group(1))} > {MAX_ERR['f32']} — g-SpMM impls "
                "are f32, this is an oracle-parity regression")
    return errors


# --- sampling-suite gates (BENCH_sampling.json, suite="sampling") ---------
# the two A-B comparisons the sampled tier exists for (ISSUE 10): the
# hot-node cache must never INCREASE backing-store traffic, and a sampled
# minibatch step must beat the full-batch step at the largest geometry
# (its sampled_vs_full row publishes ratio= full/sampled, so the shared
# RATIO_RE machinery already floors it at MIN_RATIO; the suite gate holds
# it to >= 1.0).
BYTES_RE = re.compile(r"(?:^|[ ,;])bytes=([0-9]+)")
MIN_SAMPLED_RATIO = 1.0


def _check_sampling_rows(path, rows) -> list[str]:
    errors: list[str] = []
    by_name = {r.get("name"): r for r in rows}
    fetch = {}
    for arm in ("on", "off"):
        name = f"sampling/cache_{arm}/fetch"
        r = by_name.get(name)
        m = BYTES_RE.search(str(r.get("derived", ""))) if r else None
        if m is None:
            errors.append(f"{path.name}: missing required row {name!r} "
                          "(with bytes=) — the cache A-B fell out of the "
                          "sweep")
        else:
            fetch[arm] = int(m.group(1))
    if len(fetch) == 2 and fetch["on"] > fetch["off"]:
        errors.append(
            f"{path.name}: cache-on fetched {fetch['on']} bytes > cache-off "
            f"{fetch['off']} — the hot-node cache is amplifying traffic "
            "(ISSUE 10 gate)")
    vs = [(n, r) for n, r in by_name.items()
          if isinstance(n, str) and n.endswith("/sampled_vs_full")]
    if not vs:
        errors.append(
            f"{path.name}: no sampled_vs_full row — the full-batch baseline "
            "comparison is no longer benchmarked")
    for n, r in vs:
        m = RATIO_RE.search(str(r.get("derived", "")))
        if m is None or float(m.group(1)) < MIN_SAMPLED_RATIO:
            errors.append(
                f"{path.name}: {n} ratio="
                f"{m.group(1) if m else '<missing>'} < {MIN_SAMPLED_RATIO} "
                "— the sampled step no longer beats full-batch at the "
                "largest geometry (ISSUE 10 gate)")
    return errors


def _check_precision_rows(path, rows) -> list[str]:
    errors: list[str] = []
    summary = None
    for i, r in enumerate(rows):
        derived = str(r.get("derived", ""))
        m = MAXERR_RE.search(derived)
        if m:
            dt = DTYPE_RE.search(derived)
            bound = MAX_ERR.get(dt.group(1)) if dt else None
            if bound is None:
                errors.append(
                    f"{path.name}: rows[{i}] ({r.get('name')}) has maxerr= "
                    f"but no recognised dtype= in derived={derived!r}")
            elif float(m.group(1)) > bound:
                errors.append(
                    f"{path.name}: rows[{i}] ({r.get('name')}) maxerr="
                    f"{float(m.group(1))} > {bound} for dtype="
                    f"{dt.group(1)} — precision regression")
        if r.get("name") == SUMMARY_ROW:
            summary = (i, derived)
    if summary is None:
        errors.append(f"{path.name}: missing required row {SUMMARY_ROW!r}")
        return errors
    i, derived = summary
    m = SUMMARY_RE.search(derived)
    if not m:
        errors.append(
            f"{path.name}: rows[{i}] ({SUMMARY_ROW}) unparseable summary "
            f"derived={derived!r}")
        return errors
    if m.group(1) != "1":
        errors.append(
            f"{path.name}: {SUMMARY_ROW} reduced_selected=0 — impl=\"auto\""
            " never picked a reduced-precision variant (ISSUE 6 gate)")
    if float(m.group(2)) < MIN_BEST_SPEEDUP:
        errors.append(
            f"{path.name}: {SUMMARY_ROW} best_speedup={float(m.group(2))}"
            f" < {MIN_BEST_SPEEDUP} — reduced variant lost to its f32 base"
            " on every Fig. 8-10 geometry (ISSUE 6 gate)")
    return errors


def _reject_non_finite(token: str):
    # json.loads only calls parse_constant for NaN/Infinity/-Infinity —
    # Python-only extensions that strict JSON parsers reject; a bench file
    # carrying one is unreadable to non-Python tooling downstream
    raise ValueError(f"non-finite JSON literal {token!r} "
                     "(write_bench_json must serialize these as null)")


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    try:
        doc = json.loads(path.read_text(),
                         parse_constant=_reject_non_finite)
    except (OSError, ValueError) as e:
        return [f"{path.name}: unreadable ({e})"]
    for key in REQUIRED_TOP:
        if key not in doc:
            errors.append(f"{path.name}: missing required key {key!r}")
    for i, r in enumerate(doc.get("rows", [])):
        for key in REQUIRED_ROW:
            if key not in r:
                errors.append(f"{path.name}: rows[{i}] missing {key!r}")
                continue
        m = RATIO_RE.search(str(r.get("derived", "")))
        if m:
            try:
                ratio = float(m.group(1))
            except ValueError:
                errors.append(
                    f"{path.name}: rows[{i}] ({r.get('name')}) unparseable "
                    f"ratio in derived={r.get('derived')!r}")
                continue
            if ratio < MIN_RATIO:
                errors.append(
                    f"{path.name}: rows[{i}] ({r.get('name')}) reports "
                    f"ratio={ratio} < {MIN_RATIO} — regression guard")
    if doc.get("suite") == "precision":
        errors.extend(_check_precision_rows(path, doc.get("rows", [])))
    if doc.get("suite") == "formats":
        errors.extend(_check_formats_rows(path, doc.get("rows", [])))
    if doc.get("suite") == "gspmm":
        errors.extend(_check_gspmm_rows(path, doc.get("rows", [])))
    if doc.get("suite") == "sampling":
        errors.extend(_check_sampling_rows(path, doc.get("rows", [])))
    return errors


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = [pathlib.Path(p) for p in argv] or \
        sorted(REPO_ROOT.glob("BENCH_*.json"))
    if not paths:
        print("check_bench_json: no BENCH_*.json files found", file=sys.stderr)
        return 1
    errors: list[str] = []
    for p in paths:
        errors.extend(check_file(p))
    for e in errors:
        print(f"check_bench_json: {e}", file=sys.stderr)
    if not errors:
        print(f"check_bench_json: {len(paths)} file(s) OK "
              f"({', '.join(p.name for p in paths)})")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
