"""CI sanity gate for the persisted ``BENCH_*.json`` artifacts.

Two classes of failure, both cheap to hit when a harness regresses silently:

1. **Schema** — a persisted file missing its required top-level keys
   (``suite``/``backend``/``rows``) or rows missing ``name``/``us_per_call``
   /``derived`` would break the cross-PR perf-trajectory tooling downstream.
2. **Regression guard** — rows that publish an explicit ``ratio=<float>``
   field in ``derived`` (e.g. ``bench_formats``'s ``best=csr,ratio=1.31``
   rows, defined so the ratio is ≥ 1.0 by construction) must never report
   below ``MIN_RATIO``: a value that low means the measured comparison
   inverted — the harness or the kernel it guards broke, not timing noise.
   Free-form ``...x`` annotations (like the fused bench's CPU wall ratios)
   are NOT guarded; only the explicit ``ratio=`` marker opts a row in.

Exit code 1 with one line per problem; silent 0 otherwise.

    PYTHONPATH=src python -m benchmarks.check_bench_json [paths...]
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

from benchmarks.common import REPO_ROOT

REQUIRED_TOP = ("suite", "backend", "rows")
REQUIRED_ROW = ("name", "us_per_call", "derived")
RATIO_RE = re.compile(r"(?:^|[ ,;])ratio=([-+0-9.eE]+)")
MIN_RATIO = 0.5


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unreadable ({e})"]
    for key in REQUIRED_TOP:
        if key not in doc:
            errors.append(f"{path.name}: missing required key {key!r}")
    for i, r in enumerate(doc.get("rows", [])):
        for key in REQUIRED_ROW:
            if key not in r:
                errors.append(f"{path.name}: rows[{i}] missing {key!r}")
                continue
        m = RATIO_RE.search(str(r.get("derived", "")))
        if m:
            try:
                ratio = float(m.group(1))
            except ValueError:
                errors.append(
                    f"{path.name}: rows[{i}] ({r.get('name')}) unparseable "
                    f"ratio in derived={r.get('derived')!r}")
                continue
            if ratio < MIN_RATIO:
                errors.append(
                    f"{path.name}: rows[{i}] ({r.get('name')}) reports "
                    f"ratio={ratio} < {MIN_RATIO} — regression guard")
    return errors


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = [pathlib.Path(p) for p in argv] or \
        sorted(REPO_ROOT.glob("BENCH_*.json"))
    if not paths:
        print("check_bench_json: no BENCH_*.json files found", file=sys.stderr)
        return 1
    errors: list[str] = []
    for p in paths:
        errors.extend(check_file(p))
    for e in errors:
        print(f"check_bench_json: {e}", file=sys.stderr)
    if not errors:
        print(f"check_bench_json: {len(paths)} file(s) OK "
              f"({', '.join(p.name for p in paths)})")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
