"""Benchmark utilities.

CPU-timing caveat (applies to every harness here): this container runs XLA's
CPU backend, so absolute numbers are NOT TPU numbers. What transfers is the
*structural* comparison the paper makes — batched-one-op vs sequential
per-sample ops — because the dispatch/launch overhead being amortized exists
on both runtimes. Pallas kernels run in interpret mode (Python), so they are
validated for correctness here and their TPU performance is modeled in the
roofline (EXPERIMENTS.md §Roofline), not wall-clocked.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

# Every row() lands here too, so drivers can persist a suite's rows as a
# machine-readable BENCH_*.json (repo root) — the cross-PR perf trajectory.
RESULTS: list[dict] = []
REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in seconds (blocks on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    RESULTS.append({"name": name, "us_per_call": us_per_call,
                    "derived": derived})


def header() -> None:
    print("name,us_per_call,derived", flush=True)


def results_snapshot() -> int:
    """Marker into RESULTS; pair with ``write_bench_json(..., start=...)``."""
    return len(RESULTS)


def write_bench_json(suite: str, *, start: int = 0,
                     extra: dict | None = None,
                     path: pathlib.Path | None = None) -> pathlib.Path:
    """Persist rows[start:] as ``BENCH_<suite>.json`` at the repo root —
    machine-readable across PRs (name/us_per_call/derived per row, plus any
    ``extra`` structured payload a harness wants to attach).

    STRICT JSON: Python's default ``json.dumps`` emits bare ``NaN``/
    ``Infinity`` literals (not JSON — strict parsers and most non-Python
    tooling reject the file), so non-finite floats are serialized as
    ``null`` and ``allow_nan=False`` guarantees none slip through."""
    from repro.observability import sanitize_json

    path = path or REPO_ROOT / f"BENCH_{suite}.json"
    payload = {
        "suite": suite,
        "backend": jax.default_backend(),
        "rows": RESULTS[start:],
    }
    if extra:
        payload.update(extra)
    path.write_text(json.dumps(sanitize_json(payload), indent=2,
                               sort_keys=True, allow_nan=False) + "\n")
    return path
