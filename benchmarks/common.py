"""Benchmark utilities.

CPU-timing caveat (applies to every harness here): this container runs XLA's
CPU backend, so absolute numbers are NOT TPU numbers. What transfers is the
*structural* comparison the paper makes — batched-one-op vs sequential
per-sample ops — because the dispatch/launch overhead being amortized exists
on both runtimes. Pallas kernels run in interpret mode (Python), so they are
validated for correctness here and their TPU performance is modeled in the
roofline (EXPERIMENTS.md §Roofline), not wall-clocked.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in seconds (blocks on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def header() -> None:
    print("name,us_per_call,derived", flush=True)
