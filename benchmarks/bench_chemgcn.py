"""Paper Tables II/III: ChemGCN end-to-end training & inference time,
batched (Fig. 7) vs non-batched (Fig. 6), on Tox21-like and Reaction100-like
synthetic datasets. Same numerics, different op structure — the speedup is
the paper's headline claim (1.59× train / 1.37× infer on P100)."""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import row
from repro.core.formats import BatchedCOO
from repro.core.gcn import GCNConfig, apply_gcn, gcn_loss, init_gcn
from repro.data.graphs import GraphDatasetSpec, batches, generate
from repro.optim import AdamConfig, adam_init, adam_update


def _steps(cfg, spec, data, batch, epochs, mode):
    params = init_gcn(jax.random.key(0), cfg)
    opt = AdamConfig(lr=1e-3)
    state = adam_init(params)

    @jax.jit
    def train_step(params, state, adj_arrays, x, n_nodes, labels):
        adj = [BatchedCOO(*a) for a in adj_arrays]
        (loss, _), grads = jax.value_and_grad(
            lambda p: gcn_loss(p, cfg, adj, x, n_nodes, labels),
            has_aux=True)(params)
        params, state = adam_update(opt, params, grads, state)
        return params, state, loss

    @jax.jit
    def infer_step(params, adj_arrays, x, n_nodes):
        adj = [BatchedCOO(*a) for a in adj_arrays]
        return apply_gcn(params, cfg, adj, x, n_nodes)

    # warmup/compile on the first batch
    first = next(batches(data, spec, batch))
    adj_arrays = [(a.row_ids, a.col_ids, a.values, a.nnz, a.n_rows)
                  for a in first["adj"]]
    if mode == "train":
        jax.block_until_ready(train_step(params, state, adj_arrays,
                                         first["x"], first["n_nodes"],
                                         first["labels"])[2])
    else:
        jax.block_until_ready(infer_step(params, adj_arrays, first["x"],
                                         first["n_nodes"]))
    t0 = time.perf_counter()
    for epoch in range(epochs):
        for b in batches(data, spec, batch, seed=epoch):
            adj_arrays = [(a.row_ids, a.col_ids, a.values, a.nnz, a.n_rows)
                          for a in b["adj"]]
            if mode == "train":
                params, state, loss = train_step(
                    params, state, adj_arrays, b["x"], b["n_nodes"],
                    b["labels"])
            else:
                out = infer_step(params, adj_arrays, b["x"], b["n_nodes"])
        jax.block_until_ready(params if mode == "train" else out)
    return time.perf_counter() - t0


def run(name, spec, cfg, *, batch, infer_batch, epochs=1):
    data = generate(spec)
    times = {}
    for mode, bsz in (("train", batch), ("infer", infer_batch)):
        for batched in (False, True):
            c = dataclasses.replace(cfg, batched=batched)
            t = _steps(c, spec, data, bsz, epochs, mode)
            times[(mode, batched)] = t
            label = "batched" if batched else "nonbatched"
            row(f"chemgcn/{name}/{mode}/{label}", t * 1e6, f"{t:.3f}s")
        sp = times[(mode, False)] / times[(mode, True)]
        row(f"chemgcn/{name}/{mode}/speedup", 0.0, f"{sp:.2f}x")


def main(small: bool = False):
    n = 160 if small else 640
    run("tox21", GraphDatasetSpec.tox21_like(n_samples=n),
        GCNConfig.tox21(impl="ref"), batch=50, infer_batch=min(200, n // 2))
    n2 = 96 if small else 320
    run("reaction100", GraphDatasetSpec.reaction100_like(n_samples=n2),
        # paper: 3 conv layers, width 512
        GCNConfig.reaction100(impl="ref"),
        batch=min(100, n2 // 2), infer_batch=min(200, n2 // 2))


if __name__ == "__main__":
    main()
