"""Benchmark driver: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke]

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py for
the CPU-timing caveat). ``--full`` uses paper-scale dataset sizes; the
default keeps the whole suite under a few minutes; ``--smoke`` is the CI
mode — tiny shapes, SpMM figures, the adaptive-dispatch decisions and the
serving-scheduler sweep, a couple of minutes on a CPU runner.

Suites named in ``PERSISTED`` additionally write their rows to
``BENCH_<suite>.json`` at the repo root (machine-readable perf trajectory
across PRs; CI uploads them as build artifacts).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import header, results_snapshot, write_bench_json

# suites whose rows are persisted as BENCH_<name>.json at the repo root so
# the perf trajectory stays machine-readable across PRs
PERSISTED = {"fused", "serve", "formats", "gspmm", "sampling"}
# persisted only on full runs: the precision speedup gate (check_bench_json
# enforces best_speedup >= 1.0 on the summary row) needs paper-scale
# geometries to amortize the cast overhead — smoke shapes would overwrite
# the committed artifact with sub-1.0 noise. Smoke still RUNS the suite so
# a broken variant fails CI; it just doesn't persist.
FULL_ONLY_PERSISTED = {"precision"}


def _smoke_suites():
    from benchmarks import (
        bench_fig8,
        bench_fig9,
        bench_fig10,
        bench_formats,
        bench_fused,
        bench_gspmm,
        bench_precision,
        bench_sampling,
    )

    def decisions():
        """Print the impl="auto" decision for the acceptance regimes."""
        from benchmarks.common import row
        from repro.autotune import Workload, select_impl

        probes = {
            "small_dense": Workload(batch=20, m_pad=56, nnz_pad=512,
                                    k_pad=16, n_b=64),
            "large_m": Workload(batch=2, m_pad=9000, nnz_pad=36000,
                                k_pad=4, n_b=64),
            "col_paneled": Workload(batch=20, m_pad=2048, nnz_pad=8192,
                                    k_pad=4, n_b=512),
        }
        for name, w in probes.items():
            d = select_impl(w, allow_pallas=False)
            row(f"auto/{name}", 0.0, f"{d.impl}(case{d.case},{d.source})")

    from benchmarks import bench_serve

    return [
        ("fig8", lambda: bench_fig8.run(batch=20, dim=20, nnz=2,
                                        n_bs=(16, 64))),
        ("fig9", lambda: bench_fig9.one(20, 32, 2, n_b=64)),
        ("fig10", lambda: bench_fig10.main(batch=20, n_bs=(64,))),
        ("fused", lambda: bench_fused.main(smoke=True)),
        ("formats", lambda: bench_formats.main(smoke=True)),
        ("auto", decisions),
        ("serve", lambda: bench_serve.graph_sweep(smoke=True)),
        ("precision", lambda: bench_precision.main(smoke=True)),
        ("gspmm", lambda: bench_gspmm.main(smoke=True)),
        ("sampling", lambda: bench_sampling.main(smoke=True)),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny shapes, SpMM suites only")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="enable telemetry (REPRO_TELEMETRY) for the run and "
                         "export the span buffer as Chrome-trace JSON — "
                         "loads in Perfetto / chrome://tracing")
    args = ap.parse_args()

    if args.trace:
        from repro.observability import set_enabled

        set_enabled(True)

    header()
    if args.smoke:
        suites = _smoke_suites()
    else:
        from benchmarks import (
            bench_chemgcn,
            bench_conversion,
            bench_fig8,
            bench_fig9,
            bench_fig10,
            bench_formats,
            bench_fused,
            bench_gspmm,
            bench_kernel_breakdown,
            bench_moe,
            bench_precision,
            bench_sampling,
            bench_serve,
        )

        suites = [
            ("fig8", lambda: bench_fig8.main()),
            ("fig9", lambda: bench_fig9.main()),
            ("fig10", lambda: bench_fig10.main()),
            ("fused", lambda: bench_fused.main()),
            ("table4", lambda: bench_kernel_breakdown.main()),
            ("conversion", lambda: bench_conversion.main()),
            ("formats", lambda: bench_formats.main()),
            ("chemgcn", lambda: bench_chemgcn.main(small=not args.full)),
            ("moe", lambda: bench_moe.main()),
            ("serve", lambda: bench_serve.main(persist=False)),
            ("precision", lambda: bench_precision.main()),
            ("gspmm", lambda: bench_gspmm.main(smoke=not args.full)),
            ("sampling", lambda: bench_sampling.main(smoke=not args.full)),
        ]
    failed = []
    for name, fn in suites:
        start = results_snapshot()
        extra = None
        try:
            if args.trace:
                from repro.observability import TRACER

                with TRACER.span(f"suite/{name}", cat="bench"):
                    out = fn()
            else:
                out = fn()
            if name == "serve" and isinstance(out, dict):
                extra = {"graph_sweep": out}
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            continue
        persist = name in PERSISTED or (
            name in FULL_ONLY_PERSISTED and not args.smoke)
        if persist:
            path = write_bench_json(name, start=start, extra=extra)
            print(f"wrote {path}", file=sys.stderr)
    if args.trace:
        from repro.observability import default_auditor, export_chrome_trace

        print(f"wrote {export_chrome_trace(args.trace)}", file=sys.stderr)
        print(default_auditor().format_report(), file=sys.stderr)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
