"""Benchmark driver: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py for
the CPU-timing caveat). ``--full`` uses paper-scale dataset sizes; the
default keeps the whole suite under a few minutes.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import header


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from benchmarks import (
        bench_chemgcn,
        bench_fig8,
        bench_fig9,
        bench_fig10,
        bench_format,
        bench_kernel_breakdown,
        bench_moe,
        bench_serve,
    )

    header()
    suites = [
        ("fig8", lambda: bench_fig8.main()),
        ("fig9", lambda: bench_fig9.main()),
        ("fig10", lambda: bench_fig10.main()),
        ("table4", lambda: bench_kernel_breakdown.main()),
        ("format", lambda: bench_format.main()),
        ("chemgcn", lambda: bench_chemgcn.main(small=not args.full)),
        ("moe", lambda: bench_moe.main()),
        ("serve", lambda: bench_serve.main()),
    ]
    failed = []
    for name, fn in suites:
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
