"""CI sanity gate for exported Chrome-trace JSON (``--trace`` artifacts).

A trace that fails here won't load in Perfetto / chrome://tracing — the
whole point of exporting one. Checks, per file:

1. **Strict JSON** — bare ``NaN``/``Infinity`` literals (Python extensions)
   are rejected; the exporter sanitizes args to null, so one appearing
   means a new emitter bypassed ``sanitize_json``.
2. **Schema** — a top-level ``traceEvents`` list, NON-empty (an empty trace
   from a telemetry-enabled run means the instrumentation silently
   detached); every event carries ``name``/``ph``/``ts``/``pid``/``tid``, a
   known phase (``X``/``i``/``C``), numeric finite ``ts``, and — for
   complete spans — a numeric non-negative ``dur``.

Exit code 1 with one line per problem; silent 0 otherwise.

    PYTHONPATH=src python -m benchmarks.check_trace_json trace.json [...]
"""
from __future__ import annotations

import json
import math
import pathlib
import sys

REQUIRED_EVENT = ("name", "ph", "ts", "pid", "tid")
KNOWN_PHASES = {"X", "i", "C"}


def _reject_non_finite(token: str):
    raise ValueError(f"non-finite JSON literal {token!r} "
                     "(the trace exporter must sanitize args to null)")


def check_file(path: pathlib.Path) -> list[str]:
    try:
        doc = json.loads(path.read_text(),
                         parse_constant=_reject_non_finite)
    except (OSError, ValueError) as e:
        return [f"{path.name}: unreadable ({e})"]
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return [f"{path.name}: no top-level traceEvents key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return [f"{path.name}: traceEvents is not a list"]
    if not events:
        return [f"{path.name}: traceEvents is EMPTY — telemetry was on but "
                "nothing recorded a span"]
    errors: list[str] = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"{path.name}: traceEvents[{i}] is not an object")
            continue
        missing = [k for k in REQUIRED_EVENT if k not in ev]
        if missing:
            errors.append(
                f"{path.name}: traceEvents[{i}] ({ev.get('name')!r}) "
                f"missing {missing}")
            continue
        if ev["ph"] not in KNOWN_PHASES:
            errors.append(
                f"{path.name}: traceEvents[{i}] ({ev['name']!r}) unknown "
                f"phase {ev['ph']!r} (expected one of {sorted(KNOWN_PHASES)})")
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or not math.isfinite(ts):
            errors.append(
                f"{path.name}: traceEvents[{i}] ({ev['name']!r}) "
                f"non-finite ts={ts!r}")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if (not isinstance(dur, (int, float)) or not math.isfinite(dur)
                    or dur < 0):
                errors.append(
                    f"{path.name}: traceEvents[{i}] ({ev['name']!r}) "
                    f"complete span with bad dur={dur!r}")
    return errors


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("check_trace_json: no trace files given", file=sys.stderr)
        return 1
    errors: list[str] = []
    for p in argv:
        errors.extend(check_file(pathlib.Path(p)))
    for e in errors:
        print(f"check_trace_json: {e}", file=sys.stderr)
    if not errors:
        print(f"check_trace_json: {len(argv)} file(s) OK "
              f"({', '.join(pathlib.Path(p).name for p in argv)})")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
