"""Decode throughput (reduced configs, CPU): one compiled decode step serving
a full slot batch — the serving-side analogue of the paper's batched-vs-
per-launch comparison (batch 8 vs batch 1 per step)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro import configs
from repro.launch import specs
from repro.models import lm


def one(arch: str, batch: int = 8, cache_len: int = 64):
    cfg = configs.get(arch).reduced()
    params = lm.init_params(jax.random.key(0), cfg)
    tokens, caches, pos = specs.make_decode_inputs(cfg, batch, cache_len,
                                                   concrete=True)
    step = jax.jit(lambda p, t, c, q: lm.decode_step(p, cfg, t, c, q))

    def run(p, t, c, q):
        logits, c2 = step(p, t, c, q)
        return logits

    t = time_fn(run, params, tokens, caches, pos, warmup=2, iters=8)
    row(f"serve/{arch}/batch{batch}", t * 1e6,
        f"{batch / t:.0f}tok_per_s")
    # batch-1 steps for the same token count (per-request dispatch analogue)
    tokens1, caches1, pos1 = specs.make_decode_inputs(cfg, 1, cache_len,
                                                      concrete=True)
    t1 = time_fn(run, params, tokens1, caches1, pos1, warmup=2, iters=8)
    row(f"serve/{arch}/batch1x{batch}", batch * t1 * 1e6,
        f"{1 / t1:.0f}tok_per_s")
    row(f"serve/{arch}/batched_speedup", 0.0, f"{batch * t1 / t:.2f}x")


def main():
    for arch in ("llama3-8b", "mixtral-8x22b", "rwkv6-1.6b", "zamba2-7b"):
        one(arch)


if __name__ == "__main__":
    main()
