"""Serving benchmarks.

Part 1 (LM): decode throughput — one compiled decode step serving a full slot
batch vs per-request dispatch (the paper's batched-vs-per-launch comparison).

Part 2 (graphs): the continuous-batching sweep. A mixed-size synthetic
molecule stream (tox21_like geometry statistics) arrives under a Poisson or
bursty process; the SAME stream is served by

- ``fixed``    — the pre-scheduler baseline: one worst-case geometry, waves
  launch only when all 32 slots fill (Scheduler.fixed_wave — identical
  policy to the old ``_serve_in_waves`` loop, measured by the same clock);
- ``bucketed`` — the continuous-batching scheduler: geometry-tier buckets,
  fill-vs-wait dispatch with a ``flush_after`` straggler guard.

Both run on a VirtualClock: waiting jumps to the next event and every wave
advances time by its measured service wall time, so latency percentiles are
deterministic functions of the arrival seed and the measured wave costs.
Reported per (process × policy): throughput, p50/p99 latency, padding-waste
ratios, wave count, fill rate and compile count — the compile count must
equal the number of geometry tiers (program-cache invariant, DESIGN.md §8).

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke | --graphs-only]

writes BENCH_serve.json at the repo root.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import results_snapshot, row, time_fn, write_bench_json
from repro import configs
from repro.launch import specs
from repro.models import lm


def one(arch: str, batch: int = 8, cache_len: int = 64):
    cfg = configs.get(arch).reduced()
    params = lm.init_params(jax.random.key(0), cfg)
    tokens, caches, pos = specs.make_decode_inputs(cfg, batch, cache_len,
                                                   concrete=True)
    step = jax.jit(lambda p, t, c, q: lm.decode_step(p, cfg, t, c, q))

    def run(p, t, c, q):
        logits, c2 = step(p, t, c, q)
        return logits

    t = time_fn(run, params, tokens, caches, pos, warmup=2, iters=8)
    row(f"serve/{arch}/batch{batch}", t * 1e6,
        f"{batch / t:.0f}tok_per_s")
    # batch-1 steps for the same token count (per-request dispatch analogue)
    tokens1, caches1, pos1 = specs.make_decode_inputs(cfg, 1, cache_len,
                                                      concrete=True)
    t1 = time_fn(run, params, tokens1, caches1, pos1, warmup=2, iters=8)
    row(f"serve/{arch}/batch1x{batch}", batch * t1 * 1e6,
        f"{1 / t1:.0f}tok_per_s")
    row(f"serve/{arch}/batched_speedup", 0.0, f"{batch * t1 / t:.2f}x")


# ---------------------------------------------------------------------------
# Graph continuous-batching sweep
# ---------------------------------------------------------------------------

def _arrival_times(process: str, n: int, mean_gap: float,
                   seed: int = 0) -> np.ndarray:
    """Cumulative arrival times: ``poisson`` (exponential gaps) or ``bursty``
    (groups of 8 arriving together, bursts spaced 8×mean_gap)."""
    rng = np.random.default_rng(seed)
    if process == "poisson":
        gaps = rng.exponential(mean_gap, n)
    elif process == "bursty":
        burst = 8
        gaps = np.zeros(n)
        gaps[::burst] = rng.exponential(mean_gap * burst, -(-n // burst))
    else:
        raise ValueError(f"unknown arrival process {process!r}")
    return np.cumsum(gaps)


def _requests(data):
    from repro.serving import GraphRequest

    return [GraphRequest(rows=s.rows, cols=s.cols, features=s.features,
                         n_nodes=s.n_nodes) for s in data]


def graph_sweep(*, smoke: bool = False, seed: int = 0) -> dict:
    """Fixed-wave vs bucketed continuous batching under arrival processes.

    Returns {process: {policy: metrics-summary}} (persisted by the driver
    into BENCH_serve.json)."""
    from repro.core.gcn import GCNConfig, init_gcn
    from repro.data.graphs import GraphDatasetSpec, generate
    from repro.scheduler import (
        Scheduler,
        SchedulerConfig,
        TierPolicy,
        VirtualClock,
    )

    n_samples = 50 if smoke else 200
    batch = 8 if smoke else 32
    # skewed sizes (paper Table I: Avg dim well below Max dim) — the traffic
    # profile whose worst-case padding the bucketing policy exists to avoid
    spec = GraphDatasetSpec.tox21_like(n_samples=n_samples, seed=seed,
                                       size_dist="skewed")
    data = generate(spec)
    cfg = GCNConfig(n_features=spec.n_features, channels=spec.channels,
                    conv_widths=(16, 16) if smoke else (64, 64),
                    n_tasks=spec.n_tasks)
    params = init_gcn(jax.random.key(0), cfg)

    # data-driven tier ladder: m rungs halve from the observed max; each
    # rung's nnz_pad covers every molecule that fits its node count
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=3, batch=batch)
    top = policy.tiers[-1]

    # ONE engine per geometry, shared by the calibration scheduler and every
    # policy variant below — each tier program compiles exactly once for the
    # whole sweep instead of once per scheduler
    import dataclasses

    from repro.serving import GraphServeEngine

    cfg_serve = dataclasses.replace(cfg, bn_mode="sample")
    engines: dict = {}

    def shared_engines(tier):
        key = (tier.m_pad, tier.nnz_pad, tier.batch)
        if key not in engines:
            engines[key] = GraphServeEngine(
                params, cfg_serve, batch=tier.batch, m_pad=tier.m_pad,
                nnz_pad=tier.nnz_pad)
        return engines[key]

    # calibrate the arrival timescale against one measured warm wave at the
    # top tier, so the offered load is comparable across machines
    cal = Scheduler(params, cfg, tiers=policy, clock=VirtualClock(),
                    config=SchedulerConfig(batch=batch),
                    engine_factory=shared_engines)
    # ONE measured scale — a warm FULL wave at the top tier — drives every
    # timescale below (service model, arrival gaps, flush guard). Per-tier
    # service is modeled as half fixed dispatch overhead + half work
    # proportional to the tier's node geometry (a padded wave's compute
    # scales with its array shapes, not its fill). Because every time
    # constant is proportional to the same wave_s, the reported
    # fixed-vs-bucketed RATIOS are deterministic functions of the arrival
    # seed alone — CPU frequency drift between runs rescales everything
    # equally instead of flipping outcomes.
    fits = [s for s in data
            if top.fits(s.n_nodes, max(len(r) for r in s.rows))]
    full_wave = [_requests([fits[i % len(fits)]])[0] for i in range(batch)]
    prog = cal.programs.get(top)
    wave_s = time_fn(lambda: prog.engine.run_wave(full_wave),
                     warmup=1, iters=5)

    def service_model(tier, n_served):
        return wave_s * (0.5 + 0.5 * tier.m_pad / top.m_pad)
    mean_gap = 3.0 * wave_s / batch     # fixed-wave fill wait ≈ 3 wave times
    flush_after = 1.0 * batch * mean_gap  # straggler guard ≈ that fill wait

    results: dict = {"calibration": {"wave_s": wave_s, "mean_gap": mean_gap,
                                     "flush_after": flush_after}}
    for process in ("poisson", "bursty"):
        arrivals = _arrival_times(process, n_samples, mean_gap, seed=seed)
        results[process] = {}
        for name in ("fixed", "bucketed"):
            if name == "fixed":
                sched = Scheduler.fixed_wave(
                    params, cfg, batch=batch, m_pad=top.m_pad,
                    nnz_pad=top.nnz_pad, clock=VirtualClock(),
                    service_model=service_model,
                    engine_factory=shared_engines)
            else:
                sched = Scheduler(
                    params, cfg, tiers=policy, clock=VirtualClock(),
                    service_model=service_model,
                    engine_factory=shared_engines,
                    config=SchedulerConfig(batch=batch,
                                           flush_after=flush_after))
            reqs = _requests(data)
            sched.warmup(reqs)          # compiles stay out of the timed run
            sched.serve(reqs, arrivals=list(arrivals))
            assert all(r.done for r in reqs), f"{name}/{process}: unserved"
            s = sched.metrics.summary()
            results[process][name] = s
            row(f"serve/graph/{process}/{name}/p50", s["latency_p50_s"] * 1e6,
                f"{s['throughput_rps']:.1f}req_per_s")
            row(f"serve/graph/{process}/{name}/p99", s["latency_p99_s"] * 1e6,
                f"fill={s['fill_rate']:.2f}")
            row(f"serve/graph/{process}/{name}/waste", 0.0,
                f"nodes={s['padding_waste_nodes']:.3f},"
                f"nnz={s['padding_waste_nnz']:.3f}")
            row(f"serve/graph/{process}/{name}/compiles", 0.0,
                f"{s['compile_count']}programs,{s['waves']}waves")
        fx, bk = results[process]["fixed"], results[process]["bucketed"]
        p99_imp = fx["latency_p99_s"] / max(bk["latency_p99_s"], 1e-12)
        # ratio= opts this row into the CI bench-JSON regression gate
        # (check_bench_json, MIN_RATIO=0.5). The p99 improvement is
        # DETERMINISTIC (virtual clock, seeded arrivals, per-tier service
        # constants all scale from one measured wave time), so a value
        # under the gate means the bucketed scheduler genuinely became 2x
        # worse than the fixed-wave baseline — never timing noise.
        row(f"serve/graph/{process}/improvement", 0.0,
            f"p99={p99_imp:.2f}x,"
            f"waste={fx['padding_waste_nodes'] / max(bk['padding_waste_nodes'], 1e-12):.2f}x,"
            f"ratio={p99_imp:.2f}")
    return results


def main(*, smoke: bool = False, graphs_only: bool = False,
         persist: bool = True):
    """``persist=False`` when driven by benchmarks/run.py, which owns the
    BENCH_serve.json write for its suites — exactly one writer per artifact."""
    start = results_snapshot()
    if not graphs_only and not smoke:
        for arch in ("llama3-8b", "mixtral-8x22b", "rwkv6-1.6b", "zamba2-7b"):
            one(arch)
    sweep = graph_sweep(smoke=smoke)
    if persist:
        write_bench_json("serve", start=start, extra={"graph_sweep": sweep})
    return sweep


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + graph sweep only (CI)")
    ap.add_argument("--graphs-only", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke, graphs_only=args.graphs_only)
