"""MoE dispatch strategies (reduced mixtral, CPU wall-clock) + the grouped
ragged-matmul kernel vs its oracle — the §Perf Pair-1 iterations as a
runnable benchmark."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro import configs, tuning
from repro.models.layers import init_moe, moe_apply


def dispatch_modes(b=8, t=128):
    cfg = dataclasses.replace(configs.get("mixtral-8x22b").reduced(),
                              dtype="float32")
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, t, cfg.d_model))
    for mode in ("scatter", "grouped"):
        def f(p, x, mode=mode):
            with tuning.use_flags(moe_dispatch=mode):
                return moe_apply(p, cfg, x)[0]

        tt = time_fn(jax.jit(f), p, x)
        row(f"moe/dispatch_{mode}", tt * 1e6, "")


def grouped_kernel(m=512, k=64, n=128, e=8):
    from repro.kernels.grouped_matmul import grouped_matmul
    from repro.kernels.ref import grouped_matmul_ref

    rng = np.random.default_rng(0)
    sizes = np.full((e,), m // e, np.int32)
    eids = jnp.asarray(np.repeat(np.arange(e), sizes), jnp.int32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(e, k, n)), jnp.float32)
    t_ref = time_fn(jax.jit(lambda x, w: grouped_matmul_ref(x, eids, w)),
                    x, w)
    row("moe/grouped_ref_einsum", t_ref * 1e6, "")
    t_k = time_fn(lambda x, w: grouped_matmul(
        x, w, jnp.asarray(sizes), max_groups_per_tile=2), x, w)
    row("moe/grouped_pallas_interpret", t_k * 1e6,
        "interpret-mode (correctness path)")


def main():
    dispatch_modes()
    grouped_kernel()


if __name__ == "__main__":
    main()
