"""Paper Fig. 10: mixed batch — dim ∈ [32,256], nnz/row ∈ [1,5] in ONE batch
(dense gemmBatched excluded, as in the paper: it cannot mix shapes; our padded
dense path can, so we report it as a beyond-paper extra)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import random_batch
from repro.core.spmm import batched_spmm, resolve_impl


def main(batch=100, n_bs=(64, 256, 1024)):
    rng = np.random.default_rng(2)
    coo, m_pad = random_batch(rng, batch=batch, dim=(32, 256),
                              nnz_per_row=(1, 5))
    total_nnz = float(jnp.sum(coo.nnz))
    for n_b in n_bs:
        b = jnp.asarray(rng.normal(size=(batch, m_pad, n_b)), jnp.float32)
        ts = {}
        for impl in ("loop", "ref", "dense", "auto"):
            fn = jax.jit(functools.partial(batched_spmm, impl=impl, k_pad=8))
            t = time_fn(fn, coo, b)
            ts[impl] = t
            gflops = 2 * total_nnz * n_b / t / 1e9
            derived = f"{gflops:.2f}GFLOPS"
            if impl == "auto":
                d = resolve_impl(coo, b, k_pad=8)
                derived += f"->{d.impl}(case{d.case})"
            row(f"fig10/mixed_nB{n_b}/{impl}", t * 1e6, derived)
        row(f"fig10/mixed_nB{n_b}/speedup_batched_vs_nonbatched", 0.0,
            f"{ts['loop'] / ts['ref']:.2f}x")


if __name__ == "__main__":
    main()
