"""g-SpMM message-passing sweep (DESIGN.md §11): the (op × reduce) matrix
timed across the XLA-lowered g-SpMM impls, persisted to ``BENCH_gspmm.json``.

Per corner, three kinds of rows:

- ``gspmm/<op>_<reduce>/<impl>`` — wall time of each XLA-lowered impl
  (Pallas impls are interpret-mode Python on CPU: correctness paths, never
  timed here) plus its forward ``maxerr=`` against the pure-jnp oracle
  (``dtype=f32`` — every g-SpMM impl is full precision, so
  ``check_bench_json.py`` holds these to the f32 ceiling);
- ``gspmm/<op>_<reduce>/best`` — the fastest impl for the corner with its
  ``ratio=`` speedup over the ``ref`` scatter baseline (≥ 1.0 by
  construction — ref is in the candidate set);
- ``gspmm/gat_vector/…`` — the GAT aggregation shape (vector edge features,
  ``(mul, sum)``), the one corner the scalar matrix does not cover.

``check_bench_json.py`` additionally requires all 9 (op × reduce) ``best``
rows to be present — a corner silently dropped from the sweep fails CI.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import max_row_degree, random_batch
from repro.core.spmm import GSPMM_OPS, GSPMM_REDUCES, batched_gspmm
from repro.kernels import ref

# XLA-lowered (wall-clockable on CPU) g-SpMM impls; the Pallas members of
# autotune.GSPMM_IMPLS are accuracy-checked by tests/oracle.py instead.
TIMED_IMPLS = ("ref", "loop", "csr", "ell")


def _inputs(batch, dim, nnz, n_b, *, d_e=None, seed=17):
    rng = np.random.default_rng(seed)
    coo, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)
    if d_e is not None:
        valid = (np.arange(coo.nnz_pad)[None, :]
                 < np.asarray(coo.nnz)[:, None])
        vv = rng.normal(size=(batch, coo.nnz_pad, d_e)).astype(np.float32)
        coo = dataclasses.replace(
            coo, values=jnp.asarray(np.where(valid[..., None], vv, 0.0)))
    b = jnp.asarray(rng.normal(size=(batch, m_pad, n_b)), jnp.float32)
    k_pad = max(1, int(np.asarray(max_row_degree(coo, m_pad)).max()))
    return coo, m_pad, b, k_pad


def _max_abs_error(coo, b, m_pad, k_pad, impl, op, reduce) -> float:
    want = np.asarray(
        ref.batched_gspmm_ref(coo, b, m_pad, op=op, reduce=reduce),
        np.float32)
    got = np.asarray(batched_gspmm(coo, b, op=op, reduce=reduce, impl=impl,
                                   k_pad=k_pad), np.float32)
    return float(np.max(np.abs(got - want))) if want.size else 0.0


def sweep_corner(op: str, reduce: str, coo, m_pad, b, k_pad, *, iters: int):
    times: dict[str, float] = {}
    for impl in TIMED_IMPLS:
        fn = jax.jit(functools.partial(batched_gspmm, op=op, reduce=reduce,
                                       impl=impl, k_pad=k_pad))
        times[impl] = time_fn(fn, coo, b, warmup=2, iters=iters)
        err = _max_abs_error(coo, b, m_pad, k_pad, impl, op, reduce)
        row(f"gspmm/{op}_{reduce}/{impl}", times[impl] * 1e6,
            f"dtype=f32 maxerr={err:.6f}")
    best = min(times, key=times.get)
    row(f"gspmm/{op}_{reduce}/best", times[best] * 1e6,
        f"best={best} ratio={times['ref'] / times[best]:.2f}")


def gat_vector_rows(*, batch, dim, nnz, n_b, iters: int):
    """The GAT aggregation shape: (mul, sum) with d_e == n_b vector edge
    features — exercises the vector-edge kernel path the scalar matrix
    cannot reach."""
    coo, m_pad, b, k_pad = _inputs(batch, dim, nnz, n_b, d_e=n_b)
    times: dict[str, float] = {}
    for impl in TIMED_IMPLS:
        fn = jax.jit(functools.partial(batched_gspmm, op="mul", reduce="sum",
                                       impl=impl, k_pad=k_pad))
        times[impl] = time_fn(fn, coo, b, warmup=2, iters=iters)
        err = _max_abs_error(coo, b, m_pad, k_pad, impl, "mul", "sum")
        row(f"gspmm/gat_vector/{impl}", times[impl] * 1e6,
            f"dtype=f32 maxerr={err:.6f}")
    best = min(times, key=times.get)
    row("gspmm/gat_vector/best", times[best] * 1e6,
        f"best={best} ratio={times['ref'] / times[best]:.2f}")


def main(smoke: bool = False):
    batch, dim, nnz, n_b = (8, 24, 3, 32) if smoke else (64, 50, 4, 128)
    iters = 3 if smoke else 10
    coo, m_pad, b, k_pad = _inputs(batch, dim, nnz, n_b)
    for op in GSPMM_OPS:
        for reduce in GSPMM_REDUCES:
            sweep_corner(op, reduce, coo, m_pad, b, k_pad, iters=iters)
    gat_vector_rows(batch=batch, dim=dim, nnz=nnz,
                    n_b=min(n_b, 32), iters=iters)


if __name__ == "__main__":
    main()
