"""Paper Table IV / Fig. 11: per-op execution time inside one graph-conv
layer for one mini-batch — MatMul, Add, SpMM — non-batched (one op per
sample × channel) vs batched (one op per channel)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import random_batch
from repro.core.spmm import batched_spmm
from repro.kernels.ref import spmm_coo_single


def main(batch=50, dim=50, n_in=64, n_out=64):
    rng = np.random.default_rng(3)
    coo, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=2)
    x = jnp.asarray(rng.normal(size=(batch, m_pad, n_in)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n_in, n_out)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(n_out,)), jnp.float32)

    # --- non-batched: one op per sample (scan reproduces sequential launches)
    def mm_loop(x, w):
        return jax.lax.scan(lambda _, xb: (None, xb @ w), None, x)[1]

    def add_loop(u, bias):
        return jax.lax.scan(lambda _, ub: (None, ub + bias), None, u)[1]

    def spmm_loop(rid, cid, val, u):
        return jax.lax.scan(
            lambda _, a: (None, spmm_coo_single(*a, m_pad)), None,
            (rid, cid, val, u))[1]

    # --- batched: one op for the whole mini-batch (Fig. 7)
    def mm_batched(x, w):
        return jnp.einsum("bmn,nf->bmf", x, w)

    def add_batched(u, bias):
        return u + bias

    def spmm_batched(coo, u):
        return batched_spmm(coo, u, impl="ref")

    u = mm_batched(x, w)
    t = {}
    t["MatMul", "nonbatched"] = time_fn(jax.jit(mm_loop), x, w)
    t["MatMul", "batched"] = time_fn(jax.jit(mm_batched), x, w)
    t["Add", "nonbatched"] = time_fn(jax.jit(add_loop), u, bias)
    t["Add", "batched"] = time_fn(jax.jit(add_batched), u, bias)
    t["SpMM", "nonbatched"] = time_fn(
        jax.jit(spmm_loop), coo.row_ids, coo.col_ids, coo.values, u)
    t["SpMM", "batched"] = time_fn(jax.jit(spmm_batched), coo, u)

    for op in ("MatMul", "Add", "SpMM"):
        for kind in ("nonbatched", "batched"):
            row(f"table4/{op}/{kind}", t[op, kind] * 1e6, "")
        row(f"table4/{op}/speedup", 0.0,
            f"{t[op, 'nonbatched'] / t[op, 'batched']:.2f}x")


if __name__ == "__main__":
    main()
