"""Fused graph-conv megakernel vs the unfused layer (DESIGN.md §7).

Three executions of the SAME Fig. 7 layer ``Y = Σ_ch A_ch·(X·W_ch + b_ch)``:

- ``unfused``  the pre-fusion structure: per channel one MatMul, one Add, one
  Batched SpMM, one channel-sum — 4·channels device ops, every intermediate
  ``(batch, m_pad, n_out)`` round-tripping through HBM;
- ``stacked``  the fallback path of ``graph_conv_batched``: one
  (channels·batch) einsum + ONE stacked Batched SpMM + one sum — 3 ops;
- ``fused``    the megakernel: ONE ``pallas_call`` (skew-aware nnz packing,
  no HBM intermediates). On this CPU container it runs in interpret mode
  (Python emulation — correctness path, like bench_moe); its TPU cost is the
  analytic `estimate_layer` also reported.

Reported per shape: wall time, device ops per layer (4·channels → 3 → 1),
the per-sample skew-aware chunk counts (``BatchPlan.sample_chunks``) next to
the skew-oblivious batch-max bound, and the cost model's per-impl estimate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.autotune import Workload, estimate_layer
from repro.core import random_batch
from repro.core.batching import CHUNK, plan_fused_graph_conv
from repro.core.graph_conv import graph_conv_batched, init_graph_conv
from repro.core.spmm import batched_spmm


def _unfused_layer(params, adj, x, *, impl):
    """The pre-fusion Fig. 7 loop: 4 device ops per channel."""
    y = None
    for ch, a_ch in enumerate(adj):
        u = jnp.einsum("bmn,nf->bmf", x, params["w"][ch])      # MATMUL
        u = u + params["b"][ch]                                 # ADD
        c = batched_spmm(a_ch, u, impl=impl)                    # BATCHEDSPMM
        y = c if y is None else y + c                           # SUM
    return y


def one(batch, dim, nnz, channels, n_in, n_out, *, label, time_fused=True):
    rng = np.random.default_rng(0)
    adj, m_pads = [], []
    for _ in range(channels):
        coo, mp = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)
        adj.append(coo)
        m_pads.append(mp)
    m_pad = max(m_pads)
    x = jnp.asarray(rng.normal(size=(batch, m_pad, n_in)), jnp.float32)
    params = init_graph_conv(jax.random.key(0), n_in, n_out, channels)

    # skew-aware packing decision, from host-side nnz metadata
    nnz_host = np.stack([np.asarray(a.nnz) for a in adj], 1)   # (batch, ch)
    nnz_pad = max(a.nnz_pad for a in adj)
    plan = plan_fused_graph_conv(
        batch=batch, m_pad=m_pad, n_in=n_in, n_out=n_out, channels=channels,
        nnz_pad=nnz_pad, nnz_per_sample=nnz_host)   # (batch, ch): exact ceils
    oblivious = channels * max(1, -(-nnz_pad // CHUNK))
    row(f"fused/{label}/chunks", 0.0,
        f"per-sample={list(plan.sample_chunks)} "
        f"skew-oblivious={oblivious}/sample "
        f"saved={1 - sum(plan.sample_chunks) / (batch * oblivious):.0%}")

    w = Workload(batch=batch, m_pad=m_pad, nnz_pad=nnz_pad, k_pad=None,
                 n_b=n_out, channels=channels, n_in=n_in,
                 nnz_avg=int(nnz_host.mean()))
    variants = {
        "unfused": (4 * channels,
                    jax.jit(functools.partial(_unfused_layer, impl="ref")),
                    estimate_layer(w, "ref") + 3 * channels * 2e-6),
        "stacked": (3,
                    jax.jit(functools.partial(graph_conv_batched, impl="ref")),
                    estimate_layer(w, "ref")),
        "fused": (1,
                  jax.jit(functools.partial(graph_conv_batched, impl="fused")),
                  estimate_layer(w, "fused")),
    }
    times = {}
    for name, (n_ops, fn, est) in variants.items():
        if name == "fused" and not time_fused:
            row(f"fused/{label}/fused", 0.0,
                f"ops/layer=1 model_est={est * 1e6:.1f}us (not timed: "
                "interpret mode at this size)")
            continue
        t = time_fn(fn, params, adj, x, warmup=1, iters=3)
        times[name] = t
        note = " interpret-mode (correctness path)" if name == "fused" else ""
        row(f"fused/{label}/{name}", t * 1e6,
            f"ops/layer={n_ops} model_est={est * 1e6:.1f}us{note}")
    if "stacked" in times and "unfused" in times:
        row(f"fused/{label}/stacked_vs_unfused", 0.0,
            f"{times['unfused'] / times['stacked']:.2f}x CPU wall ratio "
            "(the 4ch->3 launch cut targets accelerator dispatch; "
            "structure transfers, absolute CPU ratios do not)")
    row(f"fused/{label}/ops_per_layer", 0.0,
        f"{4 * channels}(unfused) -> 3(stacked) -> 1(fused)")
    return times


def main(smoke: bool = False):
    if smoke:
        one(8, (6, 40), (1, 4), 4, 16, 32, label="smoke")
        return
    one(32, (10, 50), (1, 4), 4, 62, 64, label="tox21")
    one(16, (20, 50), (2, 5), 4, 512, 512, label="reaction100",
        time_fused=False)
    one(32, (4, 50), (1, 8), 4, 62, 64, label="skewed")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke)
