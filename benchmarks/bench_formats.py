"""Format sweep (DESIGN.md §9): COO vs ELL vs CSR vs dense on the paper's
Fig. 8–10 geometries.

The paper's central variable is the sparse *format* the batched kernel runs
over; this harness makes that a measured, per-geometry decision. Each
geometry times every XLA-lowered format class on identical inputs (the
Pallas variants are interpret-mode Python on CPU — correctness paths, never
timed here; their TPU costs are modeled in `autotune/cost_model.py`):

- ``ref``    COO scatter-add (SparseTensor class);
- ``ell``    row-split over the padded ELL slots, k_pad = the batch's TRUE
             max row degree (sized via `repro.core.formats.max_row_degree`,
             so no silent nnz drops);
- ``csr``    CSR segment-sum over the flat nnz arrays;
- ``dense``  densify + batched GEMM (the gemmBatched baseline);
- ``hybrid`` degree-binned dense/sparse split (DESIGN.md §12): hub rows as
             a small dense GEMM slab, tail rows through the CSR path.

Geometries mirror the figures: fig8 (small fixed-size molecules, feature
width sweep axis), fig9 (larger uniform matrices), fig10 (mixed sizes — the
regime the paper CALLS skewed, though its row degrees stay near-uniform)
plus ``powerlaw`` (genuinely degree-skewed rows with hubs, the hybrid
path's target; it also persists a ``best_tpu_model`` row pinning the cost
model's TPU-posture ranking). Rows persist to ``BENCH_formats.json``; each
geometry also emits
a ``best=`` row whose ``ratio=`` (t_ref / t_best, ≥ 1.0 by construction
since ref is a candidate) opts into the CI bench-JSON gate
(`benchmarks/check_bench_json.py`) as a harness-integrity check, and an
informational ``batched_vs_loop`` row (batched scatter vs sequential
per-sample dispatch). The non-tautological gated ratio is bench_serve's
deterministic p99-improvement row.
"""
from __future__ import annotations

import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import max_row_degree, random_batch
from repro.core.formats import random_powerlaw_batch
from repro.core.spmm import batched_spmm

FORMATS = ("ref", "ell", "csr", "dense", "hybrid")

GEOMETRIES = {
    # name: (batch, dim, nnz_per_row, n_b)
    "fig8": (100, 20, 2, 64),         # many small molecules (Table I scale)
    "fig9": (40, 64, 2, 64),          # larger uniform matrices
    "fig10": (40, (8, 64), (1, 8), 64),  # mixed sizes: the skewed regime
    # DEGREE-skewed (power-law rows, hubs ≈ m_pad): fig10 mixes matrix
    # SIZES but draws near-uniform degrees — this family is the
    # load-imbalance regime the hybrid dispatch targets (DESIGN.md §12).
    # For powerlaw geometries the third field is the power-law MEAN degree.
    "powerlaw": (40, 256, 8, 64),
}

# smoke keeps dims/features small but the BATCH big enough that the
# batched-vs-loop guard ratio has real margin over the 0.5 CI gate (the
# sequential loop's per-sample dispatch has to dominate). powerlaw is the
# one full-size smoke geometry: the hybrid split only amortizes once
# dmin = m_pad/4 sits well below the hub degree AND the saved serialization
# clears the prep overhead, which needs the full (batch, m_pad) scale —
# smoke only drops the iteration count.
SMOKE = {
    "fig8": (64, 16, 2, 32),
    "fig9": (32, 32, 2, 32),
    "fig10": (32, (8, 32), (1, 6), 32),
    "powerlaw": (40, 256, 8, 64),
}


def sweep_geometry(name: str, batch, dim, nnz, n_b, *, iters: int = 10):
    """Time every format on one geometry; returns {impl: seconds}."""
    # crc32, not hash(): PYTHONHASHSEED randomizes hash() per process, and
    # these rows are a cross-PR perf trajectory — inputs must be identical
    # run to run for the persisted ratios to mean anything
    rng = np.random.default_rng(zlib.crc32(name.encode()))
    if name.startswith("powerlaw"):
        # degree-skewed family: `nnz` is the power-law MEAN degree
        coo, m_pad = random_powerlaw_batch(rng, batch=batch, dim=dim,
                                           avg_deg=nnz)
    else:
        coo, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)
    b = jnp.asarray(rng.normal(size=(batch, m_pad, n_b)), jnp.float32)
    # lossless ELL sizing: the batch's true max row degree, never a guess
    k_pad = int(np.asarray(max_row_degree(coo, m_pad)).max())

    times: dict[str, float] = {}
    for impl in FORMATS:
        fn = jax.jit(functools.partial(batched_spmm, impl=impl, k_pad=k_pad))
        times[impl] = time_fn(fn, coo, b, warmup=2, iters=iters)
    t_ref = times["ref"]
    for impl in FORMATS:
        row(f"formats/{name}/{impl}", times[impl] * 1e6,
            f"{t_ref / times[impl]:.2f}xref k{k_pad}")
    best = min(times, key=times.get)
    # ratio= opts the row into the CI gate; ref is itself a candidate, so
    # this one is >= 1.0 by construction — it guards harness integrity
    # (schema/parse/inversion), not perf. The NON-tautological gated ratio
    # lives in bench_serve's deterministic p99-improvement row; the
    # batched-vs-loop comparison below is informational only, because on
    # CPU the loop/ref margin (~1.1-1.5x) is within an XLA version bump
    # of the 0.5 gate.
    row(f"formats/{name}/best", times[best] * 1e6,
        f"best={best},ratio={t_ref / times[best]:.2f}")
    t_loop = time_fn(
        jax.jit(functools.partial(batched_spmm, impl="loop", k_pad=k_pad)),
        coo, b, warmup=2, iters=iters)
    row(f"formats/{name}/batched_vs_loop", t_loop * 1e6,
        f"loop_vs_ref={t_loop / t_ref:.2f}x")
    if name.startswith("powerlaw"):
        modeled_tpu_row(name, batch=coo.row_ids.shape[0], m_pad=m_pad,
                        nnz_pad=coo.nnz_pad, n_b=n_b, max_deg=k_pad)
    return times


def modeled_tpu_row(name, *, batch, m_pad, nnz_pad, n_b, max_deg):
    """Persist the cost model's TPU ranking for a skewed geometry.

    The hybrid split exists for the TPU serialization bound, which CPU
    wall-clock cannot witness (Pallas runs interpret-mode here). This row
    pins the MODELED decision instead: with the measured max row degree on
    the Workload, pallas_hybrid must out-rank every prior sparse class
    (ratio = prior_best_sparse / hybrid, gated >= 1.0 in
    check_bench_json.py). A cost-model regression that stops picking the
    hybrid path on its target regime fails the bench gate, not just a
    unit test.
    """
    from repro.autotune.cost_model import Workload, rank
    from repro.autotune.selector import KINDS

    w = Workload(batch=batch, m_pad=m_pad, nnz_pad=nnz_pad, k_pad=None,
                 n_b=n_b, max_deg=max_deg)
    scores = rank(w, allow_pallas=True)
    sparse = [(impl, t) for impl, t in scores
              if KINDS[impl] in ("scatter", "ell", "csr", "coo", "hybrid")]
    best, t_best = sparse[0]
    t_prior = next(t for impl, t in sparse if KINDS[impl] != "hybrid")
    row(f"formats/{name}/best_tpu_model", t_best * 1e6,
        f"best={best},ratio={t_prior / t_best:.2f},modeled md{max_deg}")


def main(smoke: bool = False):
    geos = SMOKE if smoke else GEOMETRIES
    out = {}
    for name, (batch, dim, nnz, n_b) in geos.items():
        out[name] = sweep_geometry(name, batch, dim, nnz, n_b,
                                   iters=5 if smoke else 10)
    return out


if __name__ == "__main__":
    import sys

    from benchmarks.common import header

    header()
    main(smoke="--smoke" in sys.argv)
