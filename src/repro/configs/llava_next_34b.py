"""LLaVA-NeXT 34B [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] —
Yi-34B backbone + anyres vision tiling. The vision tower is a STUB: the input
pipeline provides precomputed per-tile patch embeddings which are scattered
into the prompt prefix (frontend_len positions); the ragged tile batch routes
through the paper's batching planner (DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    rope_theta=5e6,
    frontend="vision_tiles",
    frontend_len=576,     # one 24x24 tile of patch embeddings in the prefix
)
