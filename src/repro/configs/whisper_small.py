"""Whisper small [arXiv:2212.04356; unverified] — encoder-decoder; the conv
audio frontend is a STUB (input pipeline provides precomputed frame
embeddings). Decoder cells (decode_32k) run; long_500k skipped (full attn)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,           # decoder layers
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    head_dim=64,
    frontend="audio_frames",
    tie_embeddings=True,
)
