"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 backbone with ONE shared
attention block applied every `attn_every` layers (weight-shared). SSM state
=> long_500k runs."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    block_pattern=("mamba",),
    ssm_state=64,
    ssm_heads=56,         # mamba2 heads: 2*d_model / head_dim(128)
    attn_every=6,
)
