"""Mixtral 8x22B [arXiv:2401.04088; hf] — 8-expert top-2 MoE, GQA,
sliding-window attention (window-bounded KV => long_500k runnable)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    block_pattern=("attn_moe",),
    n_experts=8,
    top_k=2,
    window=4096,
    rope_theta=1e6,
)
