"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
128-expert top-1 MoE interleaved with dense layers (every other layer MoE),
GQA kv=8, 202k vocab. Early-fusion multimodality is out of scope for the LM
backbone cells (text path only)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    block_pattern=("attn_dense", "attn_moe"),   # interleaved MoE
    n_experts=128,
    top_k=1,
    shared_expert=True,
    rope_theta=5e5,
)
