"""Config registry: repro.configs.get("<arch-id>") → ModelConfig."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPE_CELLS, ModelConfig, ShapeCell  # noqa: F401

ARCHS = (
    "mixtral-8x22b",
    "llama4-maverick-400b-a17b",
    "stablelm-12b",
    "qwen3-14b",
    "llama3-8b",
    "yi-34b",
    "rwkv6-1.6b",
    "llava-next-34b",
    "zamba2-7b",
    "whisper-small",
)


def get(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module("repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod.CONFIG
