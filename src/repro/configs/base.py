"""Architecture & shape-cell config system.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``;
``repro.configs.get(arch_id)`` loads it, ``reduced()`` derives the CPU smoke
config of the same family. Shape cells (train_4k / prefill_32k / decode_32k /
long_500k) are ``ShapeCell`` entries shared by the dry-run, roofline and
launcher.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 → d_model // n_heads
    # --- block pattern ------------------------------------------------
    # sequence of sublayer kinds scanned as one homogeneous block, e.g.
    # ("attn_dense",), ("attn_moe",), ("attn_dense","attn_moe"), ("mamba",)
    block_pattern: tuple[str, ...] = ("attn_dense",)
    # --- MoE ------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False     # llama4-style always-on expert
    # --- attention flavor ------------------------------------------------
    window: int = 0                 # sliding-window size; 0 = full attention
    qk_norm: bool = False
    rope_theta: float = 1e4
    # --- SSM / hybrid -----------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0
    attn_every: int = 0             # zamba2: shared attn block period
    # --- enc-dec / frontend ------------------------------------------------
    encoder_layers: int = 0         # whisper
    frontend: str = "none"          # none | audio_frames | vision_tiles
    frontend_len: int = 0           # positions carrying stub embeddings
    # --- numerics ---------------------------------------------------------
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.block_pattern) == 0 or self.attn_every, (
            self.name, self.n_layers, self.block_pattern)

    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM state, hybrid, or
        sliding-window KV — see DESIGN.md §4.)"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window > 0

    def param_count(self) -> int:
        """Total parameters (used for MODEL_FLOPS = 6·N·D roofline term)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        per_kind = {}
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        if self.shared_expert:
            moe_ffn += 3 * d * self.d_ff
        mamba = 6 * d * d + 4 * d * (self.ssm_state or 64)
        rwkv = 6 * d * d + 2 * d * self.d_ff
        per_kind["attn_dense"] = attn + dense_ffn
        per_kind["attn_moe"] = attn + moe_ffn
        per_kind["moe"] = moe_ffn
        per_kind["mamba"] = mamba
        per_kind["rwkv"] = rwkv
        if self.attn_every:  # zamba2: n_layers mamba + ONE shared attn block
            n += self.n_layers * mamba + (attn + dense_ffn)
        else:
            for i in range(self.n_layers):
                kind = self.block_pattern[i % len(self.block_pattern)]
                n += per_kind[kind]
        if self.encoder_layers:
            n += self.encoder_layers * (attn + dense_ffn) \
                + self.n_layers * (attn // 2)  # cross-attn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        full_moe = self.n_experts * 3 * self.d_model * self.d_ff
        active_moe = (self.top_k + int(self.shared_expert)) \
            * 3 * self.d_model * self.d_ff
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if self.block_pattern[i % len(self.block_pattern)] == "attn_moe")
        return self.param_count() - n_moe_layers * (full_moe - active_moe)

    def reduced(self) -> "ModelConfig":
        """Same-family smoke config: tiny widths, few layers/experts."""
        return dataclasses.replace(
            self,
            n_layers=max(len(self.block_pattern),
                         2 * len(self.block_pattern)) if not self.attn_every
                     else 4,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads
                       else 4,
            d_ff=128,
            vocab=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 2) if self.ssm_heads else 0,
            attn_every=2 if self.attn_every else 0,
            window=min(self.window, 16) if self.window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_len=min(self.frontend_len, 8) if self.frontend_len else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode
    microbatches: int = 1           # train: gradient-accumulation steps


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train", microbatches=16),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
