"""Performance-tuning flags (the knobs the §Perf hillclimb turns).

Flags are a trace-time context: the dry-run / trainer sets them around
``.lower()``, model code reads them. Every flag set is recorded in the
dry-run JSON so every §Perf data point is reproducible.

``constrain(x, *spec)`` applies a sharding constraint IF a mesh hint is
active and every named axis divides the corresponding dim — model code stays
mesh-agnostic and single-device tests are unaffected.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class TuneFlags:
    # remat policy for the per-layer checkpoint in scan-over-blocks:
    #   "full" — recompute everything (paper-era default, lowest memory)
    #   "dots" — save matmul outputs, recompute elementwise (less recompute)
    #   "none" — no remat (XLA saves all residuals)
    remat_policy: str = "full"
    # chunked-attention block sizes (VMEM working-set knobs).
    # DEFAULTS are the §Perf-optimized configuration; the paper-faithful
    # baselines are reproducible with --tune (see EXPERIMENTS.md §Perf).
    q_block: int = 1024
    kv_block: int = 1024
    # MoE dispatch: "grouped" (per-sequence local dispatch, vmap-batched
    # scatter — optimized default) | "scatter" (global at[].add baseline) |
    # "sharded_scatter" (refuted §Perf iteration, kept for reproduction)
    moe_dispatch: str = "grouped"
    # decode: sequence-parallel KV attention constraints (§Perf: 1800× less
    # decode collective traffic)
    constrain_decode: bool = True
    # attention implementation: "xla_packed" (triangle-packed blocked
    # attention — optimized default) | "xla_chunked" (plain blocked scan) |
    # "pallas" (flash kernel; interpret=True on CPU — tests/benches only)
    attention_impl: str = "xla_packed"
    # MoE capacity factor
    capacity_factor: float = 1.25
    # FSDP/ZeRO-3: additionally shard PARAMS over the data axis (all-gather
    # at use); required to fit ≥100B-param models on 256 chips
    fsdp: bool = False
    # Mamba2 SSD: blocked (chunked) evaluation of the selective scan —
    # intra-chunk MXU matmuls + inter-chunk state carry; 0 = sequential scan
    mamba_chunk: int = 0


_FLAGS: contextvars.ContextVar[TuneFlags] = contextvars.ContextVar(
    "tune_flags", default=TuneFlags())
_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "mesh_hint", default=None)


def flags() -> TuneFlags:
    return _FLAGS.get()


@contextlib.contextmanager
def use_flags(**kw):
    tok = _FLAGS.set(dataclasses.replace(_FLAGS.get(), **kw))
    try:
        yield _FLAGS.get()
    finally:
        _FLAGS.reset(tok)


@contextlib.contextmanager
def use_mesh_hint(mesh):
    tok = _MESH.set(mesh)
    try:
        yield
    finally:
        _MESH.reset(tok)


def axis_size(name: str):
    """Size of a hinted mesh axis, or None outside a mesh-hint context."""
    mesh = _MESH.get()
    if mesh is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name)


def constrain(x: jax.Array, *spec):
    """Best-effort sharding constraint: no mesh hint or non-divisible dims →
    identity. spec entries: None | axis-name | tuple of axis-names."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = list(spec) + [None] * (x.ndim - len(spec))
    clean = []
    for dim, part in zip(x.shape, parts):
        if part is None:
            clean.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        axes = tuple(a for a in axes if a in sizes)
        k = 1
        for a in axes:
            k *= sizes[a]
        if axes and dim % k == 0:
            clean.append(axes if len(axes) > 1 else axes[0])
        else:
            clean.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*clean)))


def parse_tune_args(pairs: list[str]) -> dict:
    """--tune key=value CLI helper."""
    out = {}
    fields = {f.name: f.type for f in dataclasses.fields(TuneFlags)}
    for pair in pairs or []:
        k, v = pair.split("=", 1)
        if k not in fields:
            raise KeyError(f"unknown tune flag {k}; known: {list(fields)}")
        t = fields[k]
        if t in ("int", int):
            out[k] = int(v)
        elif t in ("float", float):
            out[k] = float(v)
        elif t in ("bool", bool):
            out[k] = v.lower() in ("1", "true", "yes")
        else:
            out[k] = v
    return out
