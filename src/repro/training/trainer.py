"""Fault-tolerant trainers: the LM ``Trainer`` and the paper-side
``GCNTrainer`` (ChemGCN over Batched SpMM, §IV-D/§V-B).

``GCNTrainer`` routes every graph-convolution through
``batched_spmm(impl=cfg.impl)`` — ``"auto"`` by default, so the adaptive
dispatcher (DESIGN.md §5) picks the kernel per workload shape instead of the
trainer hard-coding one.

LM ``Trainer`` responsibilities:
- builds the pjit train step from ``distributed.steps`` against any mesh
  (elastic: restart on a different mesh shape re-lowers automatically);
- checkpoint/restart: atomic periodic checkpoints + resume-from-latest; a
  SIGTERM triggers one final checkpoint before exit (preemption-safe);
- straggler posture: the input pipeline is pull-based (any iterator), steps
  are dispatched asynchronously (JAX async dispatch) and the loss is only
  synced every ``log_every`` steps, so a slow host does not serialize the
  whole fleet on every step; checkpoint writes happen off the critical path
  (device→host copy only at save steps).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import signal
import time
from typing import Callable, Iterator

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core.formats import BatchedCOO, validate_ell_k_pad
from repro.core.gcn import GCNConfig, gcn_loss, gcn_node_loss, init_gcn
from repro.distributed.compression import ef_init
from repro.distributed.steps import build_train_step
from repro.models import lm
from repro.optim import AdamConfig, adam_init, adam_update


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    microbatches: int = 1
    remat: bool = False
    compress_grads: bool = False
    zero1: bool = True
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, opt: AdamConfig,
                 tcfg: TrainerConfig):
        self.cfg, self.mesh, self.opt, self.tcfg = cfg, mesh, opt, tcfg
        self.manager = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep)
        self._jit_builder, self.p_specs, self.o_specs = build_train_step(
            cfg, mesh, opt, microbatches=tcfg.microbatches, remat=tcfg.remat,
            compress_grads=tcfg.compress_grads, zero1=tcfg.zero1,
            donate=True)
        self._step_fn = None
        self._interrupted = False

    # -- state ---------------------------------------------------------

    def init_state(self):
        params = lm.init_params(jax.random.key(self.tcfg.seed), self.cfg)
        opt_state = adam_init(params)
        if self.tcfg.compress_grads:
            opt_state["ef_err"] = ef_init(params)
        return params, opt_state

    def restore_or_init(self):
        params, opt_state = self.init_state()
        latest = self.manager.latest_step()
        if latest is not None:
            params, opt_state = self.manager.restore(
                latest, (params, opt_state))
            return params, opt_state, latest
        return params, opt_state, 0

    # -- loop ----------------------------------------------------------

    def _on_sigterm(self, *_):
        self._interrupted = True

    def fit(self, data_iter: Iterator[dict],
            on_metrics: Callable[[int, dict], None] | None = None):
        tcfg = self.tcfg
        params, opt_state, start = self.restore_or_init()
        old_handler = signal.signal(signal.SIGTERM, self._on_sigterm)
        log_path = os.path.join(tcfg.checkpoint_dir, "metrics.jsonl")
        step = start
        try:
            with self.mesh:
                for step in range(start, tcfg.total_steps):
                    batch = next(data_iter)
                    if self._step_fn is None:
                        shapes = jax.tree.map(
                            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            batch)
                        self._step_fn = self._jit_builder(shapes)
                    params, opt_state, metrics = self._step_fn(
                        params, opt_state, batch)
                    if (step + 1) % tcfg.log_every == 0 or \
                            step + 1 == tcfg.total_steps:
                        loss = float(metrics["loss"])   # sync point
                        rec = {"step": step + 1, "loss": loss,
                               "time": time.time()}
                        with open(log_path, "a") as f:
                            f.write(json.dumps(rec) + "\n")
                        if on_metrics:
                            on_metrics(step + 1, rec)
                    if (step + 1) % tcfg.checkpoint_every == 0:
                        self.manager.save(step + 1, (params, opt_state))
                    if self._interrupted:
                        break
        finally:
            signal.signal(signal.SIGTERM, old_handler)
        if self._interrupted:
            # preemption: final durable checkpoint before exiting
            self.manager.save(step + 1, (params, opt_state))
        return params, opt_state


class GCNTrainer:
    """Trainer for the paper's target application: ChemGCN over Batched SpMM.

    One jitted step per batch shape; adjacency pytrees are flattened to plain
    arrays at the jit boundary (the quickstart/test idiom) so retracing is
    shape-keyed only. The SpMM implementation comes from ``cfg.impl`` —
    ``"auto"`` by default, resolved per workload by ``repro.autotune``.

    ``mesh=`` turns the step data-parallel (DESIGN.md §6): every graph
    convolution's Batched SpMM runs mesh-sharded over the ``"data"`` axis
    (per-shard ``impl="auto"`` resolution), batch leaves are placed
    batch-sharded on the mesh, params/optimizer state stay replicated, and
    the gradient all-reduce over the mesh is inserted by GSPMD from exactly
    that sharded-batch/replicated-params layout.

    Telemetry (DESIGN.md §13): every step records a ``train/step`` span and
    a wall-time histogram sample on ``registry`` (the process default unless
    one is passed); loss/accuracy/grad-norm gauges and graphs-throughput
    sync on the ``tcfg.log_every`` cadence — the per-step path never forces
    a device sync (JAX async dispatch stays pipelined). ``telemetry=False``
    opts the instance out entirely.
    """

    def __init__(self, cfg: GCNConfig, opt: AdamConfig | None = None,
                 tcfg: TrainerConfig | None = None, *, mesh=None,
                 registry=None, telemetry: bool = True):
        from repro.observability import default_registry

        self.cfg = cfg
        self.opt = opt or AdamConfig(lr=3e-3)
        self.tcfg = tcfg or TrainerConfig()
        self.mesh = mesh
        self.manager = CheckpointManager(self.tcfg.checkpoint_dir,
                                         keep=self.tcfg.keep)
        self.telemetry = telemetry
        self.registry = registry if registry is not None else \
            default_registry()
        self._m_step_s = self.registry.histogram(
            "train_step_seconds", "per-step wall time (dispatch-paced)")
        self._m_steps = self.registry.counter(
            "train_steps_total", "training steps executed")
        self._m_loss = self.registry.gauge("train_loss", "last synced loss")
        self._m_acc = self.registry.gauge(
            "train_accuracy", "last synced accuracy")
        self._m_gnorm = self.registry.gauge(
            "train_grad_norm", "last synced global gradient L2 norm")
        self._m_tput = self.registry.gauge(
            "train_graphs_per_s", "graphs/s over the last log window")

        @jax.jit
        def step(params, state, adj_arrays, x, n_nodes, labels):
            adj = [BatchedCOO(*a) for a in adj_arrays]
            (loss, acc), grads = jax.value_and_grad(
                lambda p: gcn_loss(p, self.cfg, adj, x, n_nodes, labels,
                                   mesh=mesh),
                has_aux=True)(params)
            gnorm = jax.numpy.sqrt(sum(
                jax.numpy.vdot(g, g).real
                for g in jax.tree.leaves(grads)))
            params, state = adam_update(self.opt, params, grads, state)
            return params, state, loss, acc, gnorm

        self._step = step

        @functools.partial(jax.jit, static_argnames=("m_pads", "impls"))
        def sampled_step(params, state, adj_arrays, x, labels, *, m_pads,
                         impls):
            adjs = [BatchedCOO(*a) for a in adj_arrays]
            (loss, acc), grads = jax.value_and_grad(
                lambda p: gcn_node_loss(p, self.cfg, adjs, x, labels,
                                        m_pads=m_pads, impls=impls),
                has_aux=True)(params)
            gnorm = jax.numpy.sqrt(sum(
                jax.numpy.vdot(g, g).real
                for g in jax.tree.leaves(grads)))
            params, state = adam_update(self.opt, params, grads, state)
            return params, state, loss, acc, gnorm

        self._sampled_step = sampled_step
        self._block_impl_memo: dict[tuple, tuple] = {}

    def block_decisions(self, batch) -> tuple:
        """Per-layer autotune decisions for one sampled minibatch
        (``repro.autotune.Decision`` each) — the block-aware workload:
        ``block`` = the layer's padded dst-row count, ``max_deg`` = the
        sampled in-degree skew rounded up to a power of two (so the memo and
        tuning-cache keys stay bounded), ``k_pad=None`` (no global ELL bound
        exists for a sampled block). Memoized per (geometry, skew) key; the
        jitted step receives the resolved impl names as static args."""
        from repro import autotune
        from repro.kernels import resolve_interpret

        blocks = batch.blocks
        m_pads = tuple(b.m_pad for b in blocks)
        n_seed = len(batch.labels)
        # static per-layer dst-row bound: the next block's padded src count
        # (dst rows ARE its src prefix); the last layer's is the seed count
        dst_pads = tuple(
            min(m_pads[i], m_pads[i + 1]) if i + 1 < len(blocks)
            else min(m_pads[i], -(-n_seed // 8) * 8)
            for i in range(len(blocks)))
        max_degs = tuple(
            1 << max(b.max_deg, 1).bit_length() for b in blocks)
        key = (m_pads, tuple(b.nnz_pad for b in blocks), dst_pads, max_degs)
        if key not in self._block_impl_memo:
            interpret = resolve_interpret(self.cfg.interpret)
            decisions = []
            for i, b in enumerate(blocks):
                w = autotune.Workload(
                    batch=1, m_pad=b.m_pad, nnz_pad=b.nnz_pad, k_pad=None,
                    n_b=self.cfg.conv_widths[i],
                    itemsize=batch.x.dtype.itemsize,
                    max_deg=max_degs[i], block=dst_pads[i])
                if self.cfg.impl != "auto":
                    decisions.append(autotune.forced_decision(
                        w, self.cfg.impl))
                else:
                    decisions.append(autotune.select_impl(
                        w, allow_pallas=not interpret,
                        cache=autotune.default_cache()))
            self._block_impl_memo[key] = tuple(decisions)
        return self._block_impl_memo[key]

    def fit_sampled(self, loader, *, epochs: int = 1, prefetch: bool = True,
                    on_metrics: Callable[[int, dict], None] | None = None):
        """Giant-graph training over a sampled-minibatch stream
        (DESIGN.md §14): same step/checkpoint/telemetry machinery as ``fit``
        on ``repro.sampling.SampledNodeLoader`` batches.

        Per minibatch: the per-layer block decisions resolve host-side
        (:meth:`block_decisions` — block-aware ``Workload``, memoized per
        geometry) and the jitted node-classification step runs with the
        blocks' ``(m_pads, impls)`` as static args, so the compile count is
        bounded by the loader's bucket ladder, not the epoch length. The
        distinct program count is exported as the ``train_sampled_programs``
        gauge next to the usual loss/accuracy/step-time series.

        Resume follows ``fit``'s contract: restore-latest, then fast-forward
        ``start`` batches — the loader's ``(seed, epoch, batch)``-addressable
        sampling makes the replayed stream bitwise identical. ``prefetch``
        wraps each epoch in the one-deep double buffer so the next
        minibatch's sample+gather overlaps the current step."""
        if self.mesh is not None:
            raise ValueError("fit_sampled is single-host for now: sampled "
                             "blocks have batch=1, so there is no batch "
                             "axis to shard over a mesh")
        from repro.observability import TRACER

        params, state, start = self.restore_or_init()
        loss = acc = gnorm = float("nan")
        labels_kw = {"layer": self.cfg.layer, "impl": self.cfg.impl}
        log_every = max(self.tcfg.log_every, 1)
        win_t0, win_nodes = time.perf_counter(), 0
        m_programs = self.registry.gauge(
            "train_sampled_programs",
            "distinct compiled sampled-step programs (bucket-bounded)")
        programs: set[tuple] = set()
        step = seen = 0
        for epoch in range(epochs):
            batches = loader.epoch(epoch)
            if prefetch:
                from repro.sampling import Prefetcher

                batches = Prefetcher(batches, registry=self.registry)
            for b in batches:
                seen += 1
                if seen <= start:
                    continue    # already trained before the restart
                decisions = self.block_decisions(b)
                impls = tuple(d.impl for d in decisions)
                m_pads = tuple(bl.m_pad for bl in b.blocks)
                adj_arrays = [(bl.adj.row_ids, bl.adj.col_ids,
                               bl.adj.values, bl.adj.nnz, bl.adj.n_rows)
                              for bl in b.blocks]
                programs.add((m_pads,
                              tuple(bl.nnz_pad for bl in b.blocks), impls))
                if self.telemetry:
                    with TRACER.span("train/sampled_step", cat="train",
                                     args={"step": seen, **labels_kw}):
                        t0 = time.perf_counter()
                        params, state, loss, acc, gnorm = self._sampled_step(
                            params, state, adj_arrays, b.x, b.labels,
                            m_pads=m_pads, impls=impls)
                        self._m_step_s.observe(
                            time.perf_counter() - t0, **labels_kw)
                    self._m_steps.inc(**labels_kw)
                    m_programs.set(len(programs), **labels_kw)
                    win_nodes += len(b.labels)
                    if seen % log_every == 0:
                        # the ONLY per-window device sync (same posture
                        # as fit)
                        self._m_loss.set(float(loss), **labels_kw)
                        self._m_acc.set(float(acc), **labels_kw)
                        self._m_gnorm.set(float(gnorm), **labels_kw)
                        now = time.perf_counter()
                        if now > win_t0:
                            self._m_tput.set(win_nodes / (now - win_t0),
                                             **labels_kw)
                        win_t0, win_nodes = now, 0
                else:
                    params, state, loss, acc, gnorm = self._sampled_step(
                        params, state, adj_arrays, b.x, b.labels,
                        m_pads=m_pads, impls=impls)
                step = seen
                if step % max(self.tcfg.checkpoint_every, 1) == 0:
                    self.manager.save(step, (params, state))
            if step > start:
                rec = {"epoch": epoch + 1, "loss": float(loss),
                       "acc": float(acc), "grad_norm": float(gnorm),
                       "programs": len(programs), "time": time.time()}
                if self.telemetry:
                    self._m_loss.set(float(loss), **labels_kw)
                    self._m_acc.set(float(acc), **labels_kw)
                    self._m_gnorm.set(float(gnorm), **labels_kw)
                if on_metrics:
                    on_metrics(epoch + 1, rec)
        if step > start:
            self.manager.save(step, (params, state))
        return params, state, {"loss": float(loss), "acc": float(acc),
                               "grad_norm": float(gnorm),
                               "programs": len(programs)}

    def layer_decision(self, batch: dict):
        """The adaptive layer decision (``repro.autotune.Decision``) for one
        training batch's first conv layer — fused megakernel vs stacked SpMM
        (DESIGN.md §5/§7) — resolved exactly as the jitted step will resolve
        it (per-shard workload when the trainer is mesh-parallel). Audit /
        logging only; the step itself resolves at trace time."""
        from repro.core.graph_conv import resolve_graph_conv_impl

        if self.cfg.layer != "gcn":
            from repro.core.gcn import resolve_conv_impls

            adj, x = batch["adj"], batch["x"]
            return resolve_conv_impls(
                self.cfg, x.shape[0], x.shape[1], adj[0].row_ids.shape[1],
                mesh=self.mesh)[0]
        return resolve_graph_conv_impl(
            batch["adj"], batch["x"], self.cfg.conv_widths[0],
            impl=self.cfg.impl, k_pad=self.cfg.k_pad,
            interpret=self.cfg.interpret, mesh=self.mesh,
            precision=self.cfg.precision)

    def _replicate(self, tree):
        if self.mesh is None:
            return tree
        repl = jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec())
        return jax.device_put(tree, repl)

    def init_state(self):
        params = init_gcn(jax.random.key(self.tcfg.seed), self.cfg)
        state = adam_init(params)
        return self._replicate(params), self._replicate(state)

    def restore_or_init(self):
        """Resume-from-latest (the LM ``Trainer`` pattern): restore the
        newest checkpoint's (params, opt-state) and its step counter, or
        fresh-init at step 0 when the directory holds none. ``fit`` calls
        this — NOT ``init_state`` — so a restarted trainer continues where
        the killed one checkpointed instead of silently restarting at step
        0 and overwriting prior saves."""
        params, state = self.init_state()
        latest = self.manager.latest_step()
        if latest is not None:
            params, state = self.manager.restore(latest, (params, state))
            return self._replicate(params), self._replicate(state), latest
        return params, state, 0

    def _place_batch(self, tree):
        """Batch-shard every batch-leading leaf on the mesh's data axis (the
        computation then follows the data: SpMMs run per-shard, GSPMD
        all-reduces the grads)."""
        if self.mesh is None:
            return tree
        from repro.distributed import sharding as shrules

        def one(x):
            spec = shrules.batch_specs(x, self.mesh)
            return jax.device_put(
                x, jax.sharding.NamedSharding(self.mesh, spec))

        return jax.tree.map(one, tree)

    def fit(self, batch_iter: Iterator[dict] | Callable, *, epochs: int = 1,
            on_metrics: Callable[[int, dict], None] | None = None):
        """``batch_iter``: a callable returning one epoch's batch iterator
        (e.g. ``lambda e: data.batches(...)``), or an iterable. A one-shot
        iterator/generator is materialized once so every epoch sees the
        full data (a generator would silently exhaust after epoch 1).
        Checkpoints every ``checkpoint_every`` *steps* (the LM Trainer
        convention) plus a final save.

        Resume: the latest checkpoint in ``tcfg.checkpoint_dir`` is restored
        (``restore_or_init``) and the first ``start`` batches of the stream
        are fast-forwarded, so a save→kill→restart sequence continues the
        same deterministic trajectory instead of re-initializing at step 0
        and overwriting the saved state."""
        params, state, start = self.restore_or_init()
        if not callable(batch_iter):
            data = (batch_iter if isinstance(batch_iter, (list, tuple))
                    else list(batch_iter))
            batch_iter = lambda epoch: data  # noqa: E731
        loss = acc = float("nan")
        # The jitted step can never data-branch, so the ELL silent-drop
        # guard (ISSUE 5) lives HERE, at the last concrete boundary: when
        # any conv layer's impl resolves to an ELL path for this batch's
        # shapes, an undersized k_pad fails fast instead of silently
        # zeroing edges in coo_to_ell. The impl resolution is shape-keyed
        # and memoized; the DATA check (a bincount per sample) runs on
        # every batch — it is data-dependent, so no object/shape memo can
        # soundly skip it, and it is trivial next to a training step.
        # Class membership via precision_of so reduced-precision ELL
        # variants (ell_bf16, pallas_ell_i8, …) trip the guard too.
        from repro.autotune import precision_of
        from repro.core.spmm import IMPLS

        ell_candidates = tuple(
            i for i in IMPLS if precision_of(i)[0] in ("ell", "pallas_ell"))
        maybe_ell = (self.cfg.k_pad is not None
                     and self.cfg.impl in ("auto",) + ell_candidates)
        from repro.observability import TRACER

        ell_by_shape: dict[tuple, bool] = {}
        step = seen = 0
        gnorm = float("nan")
        labels = {"layer": self.cfg.layer, "impl": self.cfg.impl}
        log_every = max(self.tcfg.log_every, 1)
        win_t0, win_graphs = time.perf_counter(), 0
        for epoch in range(epochs):
            for b in batch_iter(epoch):
                seen += 1
                if seen <= start:
                    continue    # already trained before the restart
                if maybe_ell:
                    from repro.core.gcn import resolve_conv_impls

                    key = (b["x"].shape[0], b["x"].shape[1],
                           max(a.nnz_pad for a in b["adj"]))
                    if key not in ell_by_shape:
                        ell_by_shape[key] = (
                            self.cfg.impl in ell_candidates
                            or any(d.impl in ell_candidates
                                   for d in resolve_conv_impls(
                                       self.cfg, *key,
                                       itemsize=b["x"].dtype.itemsize,
                                       mesh=self.mesh)))
                    if ell_by_shape[key]:
                        for a in b["adj"]:
                            validate_ell_k_pad(a, b["x"].shape[1],
                                               self.cfg.k_pad)
                adj_arrays = [(a.row_ids, a.col_ids, a.values, a.nnz,
                               a.n_rows) for a in b["adj"]]
                adj_arrays, x, n_nodes, y = self._place_batch(
                    (adj_arrays, b["x"], b["n_nodes"], b["labels"]))
                if self.telemetry:
                    with TRACER.span("train/step", cat="train",
                                     args={"step": seen, **labels}):
                        t0 = time.perf_counter()
                        params, state, loss, acc, gnorm = self._step(
                            params, state, adj_arrays, x, n_nodes, y)
                        self._m_step_s.observe(
                            time.perf_counter() - t0, **labels)
                    self._m_steps.inc(**labels)
                    win_graphs += b["x"].shape[0]
                    if seen % log_every == 0:
                        # the ONLY per-window device sync (mirrors the LM
                        # Trainer's log_every posture)
                        self._m_loss.set(float(loss), **labels)
                        self._m_acc.set(float(acc), **labels)
                        self._m_gnorm.set(float(gnorm), **labels)
                        now = time.perf_counter()
                        if now > win_t0:
                            self._m_tput.set(win_graphs / (now - win_t0),
                                             **labels)
                        win_t0, win_graphs = now, 0
                else:
                    params, state, loss, acc, gnorm = self._step(
                        params, state, adj_arrays, x, n_nodes, y)
                step = seen
                if step % max(self.tcfg.checkpoint_every, 1) == 0:
                    self.manager.save(step, (params, state))
            if step > start:    # an epoch fully fast-forwarded on resume
                if self.telemetry:
                    self._m_loss.set(float(loss), **labels)
                    self._m_acc.set(float(acc), **labels)
                    self._m_gnorm.set(float(gnorm), **labels)
                rec = {"epoch": epoch + 1, "loss": float(loss),
                       "acc": float(acc), "grad_norm": float(gnorm),
                       "time": time.time()}
                if on_metrics:
                    on_metrics(epoch + 1, rec)
        if step > start:
            self.manager.save(step, (params, state))
        return params, state, {"loss": float(loss), "acc": float(acc),
                               "grad_norm": float(gnorm)}
