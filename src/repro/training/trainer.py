"""Fault-tolerant LM trainer.

Responsibilities:
- builds the pjit train step from ``distributed.steps`` against any mesh
  (elastic: restart on a different mesh shape re-lowers automatically);
- checkpoint/restart: atomic periodic checkpoints + resume-from-latest; a
  SIGTERM triggers one final checkpoint before exit (preemption-safe);
- straggler posture: the input pipeline is pull-based (any iterator), steps
  are dispatched asynchronously (JAX async dispatch) and the loss is only
  synced every ``log_every`` steps, so a slow host does not serialize the
  whole fleet on every step; checkpoint writes happen off the critical path
  (device→host copy only at save steps).
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Callable, Iterator

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.distributed.compression import ef_init
from repro.distributed.steps import build_train_step
from repro.models import lm
from repro.optim import AdamConfig, adam_init


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    microbatches: int = 1
    remat: bool = False
    compress_grads: bool = False
    zero1: bool = True
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, opt: AdamConfig,
                 tcfg: TrainerConfig):
        self.cfg, self.mesh, self.opt, self.tcfg = cfg, mesh, opt, tcfg
        self.manager = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep)
        self._jit_builder, self.p_specs, self.o_specs = build_train_step(
            cfg, mesh, opt, microbatches=tcfg.microbatches, remat=tcfg.remat,
            compress_grads=tcfg.compress_grads, zero1=tcfg.zero1,
            donate=True)
        self._step_fn = None
        self._interrupted = False

    # -- state ---------------------------------------------------------

    def init_state(self):
        params = lm.init_params(jax.random.key(self.tcfg.seed), self.cfg)
        opt_state = adam_init(params)
        if self.tcfg.compress_grads:
            opt_state["ef_err"] = ef_init(params)
        return params, opt_state

    def restore_or_init(self):
        params, opt_state = self.init_state()
        latest = self.manager.latest_step()
        if latest is not None:
            params, opt_state = self.manager.restore(
                latest, (params, opt_state))
            return params, opt_state, latest
        return params, opt_state, 0

    # -- loop ----------------------------------------------------------

    def _on_sigterm(self, *_):
        self._interrupted = True

    def fit(self, data_iter: Iterator[dict],
            on_metrics: Callable[[int, dict], None] | None = None):
        tcfg = self.tcfg
        params, opt_state, start = self.restore_or_init()
        old_handler = signal.signal(signal.SIGTERM, self._on_sigterm)
        log_path = os.path.join(tcfg.checkpoint_dir, "metrics.jsonl")
        step = start
        try:
            with self.mesh:
                for step in range(start, tcfg.total_steps):
                    batch = next(data_iter)
                    if self._step_fn is None:
                        shapes = jax.tree.map(
                            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            batch)
                        self._step_fn = self._jit_builder(shapes)
                    params, opt_state, metrics = self._step_fn(
                        params, opt_state, batch)
                    if (step + 1) % tcfg.log_every == 0 or \
                            step + 1 == tcfg.total_steps:
                        loss = float(metrics["loss"])   # sync point
                        rec = {"step": step + 1, "loss": loss,
                               "time": time.time()}
                        with open(log_path, "a") as f:
                            f.write(json.dumps(rec) + "\n")
                        if on_metrics:
                            on_metrics(step + 1, rec)
                    if (step + 1) % tcfg.checkpoint_every == 0:
                        self.manager.save(step + 1, (params, opt_state))
                    if self._interrupted:
                        break
        finally:
            signal.signal(signal.SIGTERM, old_handler)
        if self._interrupted:
            # preemption: final durable checkpoint before exiting
            self.manager.save(step + 1, (params, opt_state))
        return params, opt_state
