from repro.training.trainer import Trainer, TrainerConfig  # noqa: F401
