from repro.training.trainer import (  # noqa: F401
    GCNTrainer,
    Trainer,
    TrainerConfig,
)
