"""Synthetic molecular-graph datasets shaped like the paper's (Table I).

Tox21 and Reaction100 are not redistributable here, so we generate graphs with
the same statistics the paper reports — max dim 50 nodes, bond-degree ≤ 4,
multiple bond-type channels — and label them with a fixed hidden "teacher" GCN
so that training has real signal (loss decreases measurably; tests assert it).
The batching/padding path is exactly what a real featurizer would feed.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np
import jax.numpy as jnp

from repro.core.csc import CSCGraph, csc_from_edges
from repro.core.formats import BatchedCOO, coo_from_lists, powerlaw_degrees


@dataclasses.dataclass(frozen=True)
class GraphSample:
    rows: list[np.ndarray]      # per channel
    cols: list[np.ndarray]
    n_nodes: int
    features: np.ndarray        # (n_nodes, n_features)
    label: np.ndarray


@dataclasses.dataclass(frozen=True)
class GraphDatasetSpec:
    n_samples: int = 1024
    max_nodes: int = 50          # paper Table I: Max dim = 50
    min_nodes: int = 8
    max_degree: int = 4          # chemistry: ≤4 bonds
    channels: int = 4            # bond types
    n_features: int = 62
    n_tasks: int = 12
    task: str = "multitask_binary"
    size_dist: str = "uniform"   # node-count distribution: "uniform" over
                                 # [min_nodes, max_nodes], or "skewed" — a
                                 # clipped lognormal whose median sits well
                                 # below max_nodes, matching the paper's
                                 # Table I gap between Avg dim and Max dim
                                 # (most molecules are small; the serving
                                 # scheduler's bucketing exploits exactly
                                 # this skew)
    seed: int = 0

    @staticmethod
    def tox21_like(n_samples: int = 1024, **kw) -> "GraphDatasetSpec":
        return GraphDatasetSpec(n_samples=n_samples, n_tasks=12,
                                task="multitask_binary", **kw)

    @staticmethod
    def reaction100_like(n_samples: int = 1024, **kw) -> "GraphDatasetSpec":
        return GraphDatasetSpec(n_samples=n_samples, n_tasks=100,
                                task="multiclass", **kw)


def _random_molecule(rng: np.random.Generator, spec: GraphDatasetSpec):
    """Random connected graph with chemistry-like degree bound, bond types
    assigned per edge; channel 0 additionally carries the self-loops
    (a_uu = 1, paper §II-A)."""
    if spec.size_dist == "skewed":
        # median ≈ min + (max-min)/4, long right tail clipped at max_nodes
        med = spec.min_nodes + (spec.max_nodes - spec.min_nodes) / 4
        n = int(np.clip(round(rng.lognormal(np.log(med), 0.45)),
                        spec.min_nodes, spec.max_nodes))
    else:
        n = int(rng.integers(spec.min_nodes, spec.max_nodes + 1))
    deg = np.zeros(n, np.int32)
    edges = []
    for v in range(1, n):                       # random spanning tree
        u = int(rng.integers(0, v))
        if deg[u] < spec.max_degree and deg[v] < spec.max_degree:
            edges.append((u, v))
            deg[u] += 1
            deg[v] += 1
    extra = int(rng.integers(0, max(1, n // 4)))  # rings
    for _ in range(extra):
        u, v = rng.integers(0, n, 2)
        if u != v and deg[u] < spec.max_degree and deg[v] < spec.max_degree:
            edges.append((int(u), int(v)))
            deg[u] += 1
            deg[v] += 1
    bond = rng.integers(0, spec.channels, len(edges))
    rows = [[] for _ in range(spec.channels)]
    cols = [[] for _ in range(spec.channels)]
    for (u, v), ch in zip(edges, bond):
        rows[ch] += [u, v]
        cols[ch] += [v, u]
    for v in range(n):                          # self loops on channel 0
        rows[0].append(v)
        cols[0].append(v)
    atom_type = rng.integers(0, spec.n_features, n)
    feats = np.zeros((n, spec.n_features), np.float32)
    feats[np.arange(n), atom_type] = 1.0
    return (
        [np.asarray(r, np.int32) for r in rows],
        [np.asarray(c, np.int32) for c in cols],
        n,
        feats,
    )


def _teacher_logits(sample, spec: GraphDatasetSpec, w1, w2):
    """Fixed random 1-layer GCN teacher → learnable labels."""
    rows, cols, n, feats = sample
    a = np.zeros((n, n), np.float32)
    for r, c in zip(rows, cols):
        a[r, c] = 1.0
    h = np.maximum(a @ (feats @ w1), 0)
    return h.sum(0) @ w2


def generate(spec: GraphDatasetSpec) -> list[GraphSample]:
    rng = np.random.default_rng(spec.seed)
    w1 = rng.normal(size=(spec.n_features, 32)).astype(np.float32) * 0.3
    w2 = rng.normal(size=(32, spec.n_tasks)).astype(np.float32) * 0.3
    out = []
    for _ in range(spec.n_samples):
        rows, cols, n, feats = _random_molecule(rng, spec)
        logits = _teacher_logits((rows, cols, n, feats), spec, w1, w2)
        if spec.task == "multitask_binary":
            label = (logits > np.median(logits)).astype(np.float32)
        else:
            label = np.asarray(int(np.argmax(logits)) % spec.n_tasks)
        out.append(GraphSample(rows, cols, n, feats, label))
    return out


def batches(
    data: list[GraphSample],
    spec: GraphDatasetSpec,
    batch_size: int,
    *,
    m_pad: int | None = None,
    nnz_pad: int | None = None,
    drop_remainder: bool = True,
    seed: int = 0,
    epochs: int = 1,
    start_epoch: int = 0,
) -> Iterator[dict]:
    """Padding batch iterator: pads every sample to the dataset max (static
    shapes → one compiled step), yields per-channel BatchedCOO + features.

    Each epoch's shuffle is a pure function of ``(seed, epoch)`` — NOT one
    sequentially-consumed RNG — so a checkpoint-restored run can rebuild any
    epoch's exact batch order without replaying the epochs before it:
    ``batches(..., start_epoch=e)`` reproduces the tail of a longer stream
    bitwise (the resume contract ``GCNTrainer.fit`` fast-forwards on)."""
    m_pad = m_pad or -(-max(s.n_nodes for s in data) // 8) * 8
    # Pad nnz to the DATASET max by default so every batch has identical
    # static shapes (single XLA compilation across the epoch).
    if nnz_pad is None:
        nnz_pad = -(-max(
            max(len(s.rows[ch]) for ch in range(spec.channels))
            for s in data) // 8) * 8
    for epoch in range(start_epoch, start_epoch + epochs):
        idx = np.random.default_rng((seed, epoch)).permutation(len(data))
        n_full = len(idx) // batch_size
        for i in range(n_full if drop_remainder else n_full + 1):
            sel = idx[i * batch_size:(i + 1) * batch_size]
            if len(sel) == 0:
                continue
            samples = [data[j] for j in sel]
            adj = []
            for ch in range(spec.channels):
                triples = [
                    (s.rows[ch], s.cols[ch],
                     np.ones(len(s.rows[ch]), np.float32))
                    for s in samples
                ]
                adj.append(coo_from_lists(
                    triples, [s.n_nodes for s in samples], nnz_pad=nnz_pad))
            feats = np.zeros((len(samples), m_pad, spec.n_features), np.float32)
            for k, s in enumerate(samples):
                feats[k, :s.n_nodes] = s.features
            labels = np.stack([s.label for s in samples])
            yield {
                "adj": adj,
                "x": jnp.asarray(feats),
                "n_nodes": jnp.asarray([s.n_nodes for s in samples],
                                       jnp.int32),
                "labels": jnp.asarray(labels),
            }


# -- giant-graph tier (DESIGN.md §14) -----------------------------------


@dataclasses.dataclass(frozen=True)
class NodeClassData:
    """One giant node-classification graph for the sampled tier: the static
    CSC sampling structure, per-node features/labels, and a train/val seed
    split. Everything is host-side NumPy — features enter the device only
    through the sampled-minibatch gather."""

    csc: CSCGraph
    features: np.ndarray   # (n_nodes, n_features) float32
    labels: np.ndarray     # (n_nodes,) int32 class ids
    train_ids: np.ndarray  # (n_train,) int64
    val_ids: np.ndarray    # (n_val,) int64
    n_classes: int


def reddit_like(
    n_nodes: int = 100_000,
    *,
    n_classes: int = 8,
    n_features: int = 64,
    avg_deg: int = 12,
    alpha: float = 1.2,
    homophily: float = 0.7,
    noise: float = 1.0,
    val_frac: float = 0.1,
    seed: int = 0,
) -> NodeClassData:
    """Synthetic "reddit-like" powerlaw node-classification graph.

    The two properties the sampled tier exercises, built in O(E + N)
    vectorized passes (a 100k-node / ~1M-edge graph generates in ~a second):

    * **Zipf-hot hubs** — per-node in-degrees follow the same powerlaw as
      ``random_powerlaw_batch`` (shared :func:`powerlaw_degrees` helper), so
      a handful of hub nodes appear in most sampled neighborhoods: exactly
      the skew the hot-node feature cache and the autotuner's ``max_deg``
      pricing are built for.
    * **Learnable labels** — planted partition: each edge's source is drawn
      from the destination's own class with probability ``homophily`` (else
      uniformly), and features are a noisy class centroid, so neighbor
      aggregation genuinely helps and a sampled GCN's accuracy climbs well
      above ``1 / n_classes`` (the e2e test's signal).

    Self-loops are added on every node (paper §II-A's ``a_uu = 1``), so a
    destination's own features survive fanout sampling.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    # class-sorted node table: same-class sources are one fancy-index away
    order = np.argsort(labels, kind="stable")
    class_sizes = np.bincount(labels, minlength=n_classes)
    class_offsets = np.zeros(n_classes + 1, np.int64)
    np.cumsum(class_sizes, out=class_offsets[1:])
    # powerlaw IN-degrees: hubs are hot as destinations AND (by symmetry of
    # the uniform branch) as sampled sources
    deg = powerlaw_degrees(rng, n_nodes, avg_deg, alpha)
    dst = np.repeat(np.arange(n_nodes, dtype=np.int64), deg)
    e = len(dst)
    same = rng.random(e) < homophily
    dst_cls = labels[dst]
    within = rng.integers(0, np.maximum(class_sizes[dst_cls], 1))
    src = np.where(
        same,
        order[class_offsets[dst_cls] + within],   # same-class source
        rng.integers(0, n_nodes, e),              # long-range source
    )
    loops = np.arange(n_nodes, dtype=np.int64)
    src = np.concatenate([src, loops])
    dst = np.concatenate([dst, loops])
    csc = csc_from_edges(src, dst, n_nodes)
    centroids = rng.standard_normal((n_classes, n_features))
    features = (centroids[labels]
                + noise * rng.standard_normal((n_nodes, n_features))
                ).astype(np.float32)
    perm = rng.permutation(n_nodes).astype(np.int64)
    n_val = int(n_nodes * val_frac)
    return NodeClassData(csc=csc, features=features, labels=labels,
                         train_ids=perm[n_val:], val_ids=perm[:n_val],
                         n_classes=n_classes)
