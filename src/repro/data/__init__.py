"""Data pipelines: synthetic molecular graphs (ChemGCN) and token streams (LMs)."""
