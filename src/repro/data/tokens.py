"""Token data pipeline for the LM trainers.

Design goals that matter at fleet scale:
- **deterministic by (seed, step, shard)**: batch `i` is a pure function of
  the stream spec, so restart-after-preemption resumes the exact sequence
  with no data-loader state in the checkpoint, and every data-parallel shard
  draws a disjoint slice (`shard`, `num_shards`);
- **pull-based with prefetch**: a bounded background thread keeps `depth`
  batches ready so a slow step never stalls the input side (straggler
  posture: input is never the synchronization point);
- **synthetic but learnable**: a fixed random bigram table + noise gives a
  real loss floor (≪ ln(vocab)), so convergence tests and the 100M example
  measure actual learning.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamSpec:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    branch: int = 4          # bigram successors per token
    noise: float = 0.1
    shard: int = 0
    num_shards: int = 1


def make_batch(spec: TokenStreamSpec, step: int) -> np.ndarray:
    """Batch for `step` — pure function of (spec, step)."""
    table_rng = np.random.default_rng(spec.seed)
    table = table_rng.integers(0, spec.vocab,
                               size=(spec.vocab, spec.branch))
    rng = np.random.default_rng(
        (spec.seed, step, spec.shard, 0xA5A5))
    toks = np.empty((spec.batch, spec.seq_len), np.int32)
    toks[:, 0] = rng.integers(0, spec.vocab, spec.batch)
    for t in range(1, spec.seq_len):
        nxt = table[toks[:, t - 1], rng.integers(0, spec.branch, spec.batch)]
        mix = rng.random(spec.batch) < spec.noise
        nxt[mix] = rng.integers(0, spec.vocab, int(mix.sum()))
        toks[:, t] = nxt
    return toks


def token_stream(spec: TokenStreamSpec, start_step: int = 0,
                 prefetch: int = 2) -> Iterator[dict]:
    """Prefetching iterator of {"tokens": (batch, seq)} starting at
    `start_step` (exact resume)."""
    q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            arr = make_batch(spec, step)
            q.put(arr)
            step += 1

    th = threading.Thread(target=worker, daemon=True)
    th.start()
    try:
        while True:
            yield {"tokens": jnp.asarray(q.get())}
    finally:
        stop.set()
