"""Metrics registry: counters, gauges, fixed-bucket histograms with labeled
series, and a JSON-lines snapshot exporter (DESIGN.md §13).

One :class:`MetricsRegistry` holds named metrics; each metric holds one
*series* per label set (``impl="csr"``, ``tier="m56_z256"``, …), so the same
``spmm_dispatch_total`` counter fans out per implementation without
pre-declaring the label values. The registry is the shared substrate
``ServeMetrics``, the trainer hooks, and the kernel-dispatch spans all
report through — one ``snapshot()`` covers the whole process.

Histograms are **fixed-bucket** (cumulative-style ``le`` upper bounds like
Prometheus): ``observe()`` is O(#buckets) with no allocation, and the bucket
boundaries are part of the exporter schema (pinned by tests so downstream
dashboards can't drift silently). ``keep_samples=True`` additionally retains
raw samples (bounded) for EXACT percentiles — ``ServeMetrics`` uses this so
the serving p50/p99 stay sample-exact, not bucket-interpolated.

Snapshot rows are strict JSON (NaN → null via ``sanitize_json``).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
import threading

import numpy as np

from repro.observability.trace import sanitize_json

# default latency-ish buckets (seconds): 1µs … 100s, multiplicative ~x4.64
DEFAULT_TIME_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0)
# how many raw samples a keep_samples=True histogram retains before it stops
# appending (count/sum/min/max/buckets stay exact; percentiles degrade to
# the retained prefix — sized far above any serve/train run we record)
SAMPLE_LIMIT = 100_000


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Metric:
    kind = "abstract"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._series: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _get(self, labels: dict, make):
        key = _label_key(labels)
        s = self._series.get(key)
        if s is None:
            with self._lock:
                s = self._series.setdefault(key, make())
        return s

    def labelsets(self) -> list[dict]:
        return [dict(k) for k in self._series]


class Counter(_Metric):
    """Monotonically increasing count (per label set)."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name}: negative inc {value}")
        box = self._get(labels, lambda: [0.0])
        box[0] += value

    def value(self, **labels) -> float:
        box = self._series.get(_label_key(labels))
        return box[0] if box else 0.0

    def total(self) -> float:
        return sum(box[0] for box in self._series.values())

    def rows(self):
        for key, box in self._series.items():
            yield {"metric": self.name, "type": "counter",
                   "labels": dict(key), "value": box[0]}


class Gauge(_Metric):
    """Last-written value (per label set); ``nan`` until first set."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        box = self._get(labels, lambda: [float("nan")])
        box[0] = float(value)

    def add(self, value: float, **labels) -> None:
        box = self._get(labels, lambda: [float("nan")])
        box[0] = value if math.isnan(box[0]) else box[0] + value

    def value(self, **labels) -> float:
        box = self._series.get(_label_key(labels))
        return box[0] if box else float("nan")

    def rows(self):
        for key, box in self._series.items():
            yield {"metric": self.name, "type": "gauge",
                   "labels": dict(key), "value": box[0]}


@dataclasses.dataclass
class _HistSeries:
    counts: list          # per-bucket counts (+1 overflow bucket)
    n: int = 0
    total: float = 0.0
    vmin: float = float("inf")
    vmax: float = float("-inf")
    samples: list | None = None


class Histogram(_Metric):
    """Fixed-bucket histogram; ``le`` upper bounds + one +Inf overflow."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: tuple[float, ...] = DEFAULT_TIME_BUCKETS,
                 keep_samples: bool = False):
        super().__init__(name, help)
        if tuple(sorted(buckets)) != tuple(buckets) or not buckets:
            raise ValueError(
                f"histogram {name}: buckets must be non-empty and ascending, "
                f"got {buckets}")
        self.buckets = tuple(float(b) for b in buckets)
        self.keep_samples = keep_samples

    def _make(self):
        return _HistSeries(
            counts=[0] * (len(self.buckets) + 1),
            samples=[] if self.keep_samples else None)

    def observe(self, value: float, **labels) -> None:
        s: _HistSeries = self._get(labels, self._make)
        v = float(value)
        i = 0
        for i, b in enumerate(self.buckets):   # noqa: B007 — small, fixed
            if v <= b:
                break
        else:
            i = len(self.buckets)
        s.counts[i] += 1
        s.n += 1
        s.total += v
        s.vmin = min(s.vmin, v)
        s.vmax = max(s.vmax, v)
        if s.samples is not None and len(s.samples) < SAMPLE_LIMIT:
            s.samples.append(v)

    def count(self, **labels) -> int:
        s = self._series.get(_label_key(labels))
        return s.n if s else 0

    def sum(self, **labels) -> float:
        s = self._series.get(_label_key(labels))
        return s.total if s else 0.0

    def percentile(self, p: float, **labels) -> float:
        """Sample-exact when ``keep_samples`` (numpy percentile over the raw
        samples); bucket-upper-bound otherwise. NaN for an empty series."""
        s: _HistSeries | None = self._series.get(_label_key(labels))
        if s is None or s.n == 0:
            return float("nan")
        if s.samples:
            return float(np.percentile(np.asarray(s.samples), p))
        target = p / 100.0 * s.n
        acc = 0
        for i, c in enumerate(s.counts):
            acc += c
            if acc >= target:
                return (self.buckets[i] if i < len(self.buckets)
                        else s.vmax)
        return s.vmax

    def rows(self):
        for key, s in self._series.items():
            yield {
                "metric": self.name, "type": "histogram",
                "labels": dict(key), "count": s.n, "sum": s.total,
                "min": s.vmin if s.n else float("nan"),
                "max": s.vmax if s.n else float("nan"),
                "buckets": [
                    {"le": b, "count": c}
                    for b, c in zip(self.buckets + (float("inf"),), s.counts)
                ],
            }


class MetricsRegistry:
    """Named metrics with get-or-create semantics and one shared snapshot.

    Re-registering a name returns the SAME metric object (so independent
    layers share series) but a kind mismatch raises — a counter silently
    shadowing a histogram is exactly the drift the registry exists to stop.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _register(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_TIME_BUCKETS,
                  keep_samples: bool = False) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets,
                              keep_samples=keep_samples)

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> list[dict]:
        """Every labeled series as one flat row list, name-sorted."""
        rows: list[dict] = []
        for name in self.names():
            rows.extend(self._metrics[name].rows())
        return rows

    def export_jsonl(self, path: str | os.PathLike,
                     extra: dict | None = None) -> pathlib.Path:
        """One strict-JSON line per series (NaN → null); ``extra`` prepends a
        metadata line tagged ``"meta"`` so consumers can key the snapshot."""
        path = pathlib.Path(path)
        lines = []
        if extra is not None:
            lines.append(json.dumps(
                sanitize_json({"type": "meta", **extra}), allow_nan=False))
        for row in self.snapshot():
            lines.append(json.dumps(sanitize_json(row), allow_nan=False))
        path.write_text("\n".join(lines) + "\n")
        return path


# The process-default registry: trainer/scheduler/kernels report here unless
# handed an explicit registry (tests pass their own for isolation).
REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return REGISTRY
