"""Unified telemetry layer (DESIGN.md §13): span tracer + metrics registry +
autotune regret auditing.

Three pieces, one import surface:

- :mod:`repro.observability.trace` — nested spans into a process-local ring
  buffer with a Chrome-trace/Perfetto exporter and
  ``jax.profiler.TraceAnnotation``/``named_scope`` bridging. Hot-path spans
  (kernel dispatch) are gated by ``REPRO_TELEMETRY`` (default off);
  structural spans (train step, serve wave, scheduler lifecycle) record
  unconditionally unless the emitting object is built ``telemetry=False``.
- :mod:`repro.observability.metrics` — counters/gauges/fixed-bucket
  histograms with labeled series and a JSON-lines snapshot exporter;
  ``ServeMetrics`` and the trainer hooks sit on this registry.
- :mod:`repro.observability.regret` — the autotune decision audit:
  predicted-vs-measured per (impl, workload-key), flagged regret, and
  would-have-won alternatives.
"""
from repro.observability.metrics import (  # noqa: F401
    DEFAULT_TIME_BUCKETS,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
)
from repro.observability.trace import (  # noqa: F401
    ENV_VAR,
    TRACER,
    TraceEvent,
    Tracer,
    enabled,
    export_chrome_trace,
    sanitize_json,
    set_enabled,
    span,
    telemetry,
)

__all__ = [
    "AUDITOR", "Counter", "DEFAULT_TIME_BUCKETS", "ENV_VAR", "Gauge",
    "Histogram", "MetricsRegistry", "REGISTRY", "RegretAuditor",
    "RegretEntry", "TRACER", "TraceEvent", "Tracer", "default_auditor",
    "default_registry", "enabled", "export_chrome_trace", "sanitize_json",
    "set_enabled", "span", "telemetry",
]

# The regret auditor imports repro.autotune (cost model + selector); loading
# it lazily keeps `kernels/ops.py`'s import of this package out of the
# autotune import graph (repro.core's __init__ pulls ops.py in while
# cost_model may still be initializing — see the note in cost_model.py).
_REGRET_NAMES = ("AUDITOR", "RegretAuditor", "RegretEntry",
                 "default_auditor")


def __getattr__(name: str):
    if name in _REGRET_NAMES:
        from repro.observability import regret

        return getattr(regret, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
