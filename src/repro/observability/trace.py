"""Span tracer: nested spans, a ring buffer, Chrome-trace export (DESIGN.md
§13).

One process-local :class:`Tracer` (the module singleton :data:`TRACER`)
collects three kinds of events into a bounded ring buffer:

- **complete spans** (Chrome ``ph="X"``) — a named interval with wall-clock
  ``ts``/``dur`` and structured ``args``; nested spans nest in the viewer by
  timestamp containment on the same track;
- **instant events** (``ph="i"``) — a point marker (request arrival, admit);
- **counter samples** (``ph="C"``) — a named scalar over time (queue depth).

Two clock domains share the file: spans opened with :meth:`Tracer.span` are
stamped from ``time.perf_counter`` (the process wall clock); scheduler
lifecycle events carry the *scheduler's* clock (possibly a
``VirtualClock``) and live on their own ``tid`` track so the two timelines
never interleave confusingly.

**Hot-path gating**: the module-level :func:`span` checks :func:`enabled`
(the ``REPRO_TELEMETRY`` env var, default off) before doing ANY work and
returns a shared null context when disabled — that one predicate is the
entire disabled-mode cost, which the overhead-guard test bounds at < 5% of
a single XLA dispatch. Structural spans (trainer steps, serve waves,
scheduler waves) call :meth:`Tracer.span` directly: they are emitted
unconditionally because their cost is negligible next to the work they
measure, and the emitting object takes ``telemetry=False`` to opt out.

**XLA bridging**: every span also enters ``jax.profiler.TraceAnnotation``
(so a concurrent ``jax.profiler.trace`` capture shows our spans on the
host-thread track, aligned with XLA's own device timeline) and
``jax.named_scope`` (so ops traced inside the span carry the span's name in
the HLO metadata).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import os
import pathlib
import threading
import time

ENV_VAR = "REPRO_TELEMETRY"
_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

# default ring capacity: ~64k events ≈ a few MB — long runs wrap instead of
# growing without bound, and `dropped` records how many fell off the front
DEFAULT_CAPACITY = 65536


def _env_default() -> bool:
    env = os.environ.get(ENV_VAR)
    if env is None:
        return False
    v = env.strip().lower()
    if v in _TRUTHY:
        return True
    if v in _FALSY:
        return False
    raise ValueError(
        f"{ENV_VAR}={env!r}: expected one of {_TRUTHY + _FALSY}")


class _State:
    enabled: bool = _env_default()


_STATE = _State()
_NULL = contextlib.nullcontext()


def enabled() -> bool:
    """Whether hot-path (kernel-dispatch) telemetry is on. This is the ONE
    check `kernels/ops.py` pays per dispatch when telemetry is off."""
    return _STATE.enabled


def set_enabled(value: bool) -> None:
    """Programmatic override of the ``REPRO_TELEMETRY`` default."""
    _STATE.enabled = bool(value)


@contextlib.contextmanager
def telemetry(value: bool = True):
    """Scoped :func:`set_enabled` — ``with telemetry(): ...``."""
    prev = _STATE.enabled
    _STATE.enabled = bool(value)
    try:
        yield
    finally:
        _STATE.enabled = prev


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One Chrome-trace event (the exporter serializes these verbatim)."""

    name: str
    ph: str                     # "X" complete | "i" instant | "C" counter
    ts: float                   # microseconds
    dur: float = 0.0            # microseconds, ph == "X" only
    tid: int | str = 0
    cat: str = "repro"
    args: dict | None = None


class Tracer:
    """Bounded ring buffer of trace events + the span context manager."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = capacity
        self._events: collections.deque[TraceEvent] = collections.deque(
            maxlen=capacity)
        self._lock = threading.Lock()
        self.dropped = 0

    # -- recording ----------------------------------------------------------
    def _append(self, ev: TraceEvent) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "repro",
             args: dict | None = None, annotate: bool = True):
        """Record one complete span around the body. ``annotate=True`` also
        enters the jax profiler annotation + named_scope so the span lines
        up with XLA's own profile and names traced ops."""
        stack = contextlib.ExitStack()
        if annotate:
            import jax

            stack.enter_context(jax.profiler.TraceAnnotation(name))
            stack.enter_context(jax.named_scope(name))
        t0 = time.perf_counter()
        try:
            with stack:
                yield self
        finally:
            t1 = time.perf_counter()
            self._append(TraceEvent(
                name=name, ph="X", ts=t0 * 1e6, dur=(t1 - t0) * 1e6,
                tid=threading.get_ident() & 0xFFFF, cat=cat, args=args))

    def complete(self, name: str, *, ts: float, dur: float,
                 tid: int | str = "clock", cat: str = "repro",
                 args: dict | None = None) -> None:
        """Record a complete span with CALLER-owned timestamps (seconds) —
        the scheduler's virtual-clock lifecycle track."""
        self._append(TraceEvent(name=name, ph="X", ts=ts * 1e6,
                                dur=dur * 1e6, tid=tid, cat=cat, args=args))

    def instant(self, name: str, *, ts: float | None = None,
                tid: int | str = "clock", cat: str = "repro",
                args: dict | None = None) -> None:
        ts = time.perf_counter() if ts is None else ts
        self._append(TraceEvent(name=name, ph="i", ts=ts * 1e6,
                                tid=tid, cat=cat, args=args))

    def counter(self, name: str, value: float, *, ts: float | None = None,
                tid: int | str = "clock", cat: str = "repro") -> None:
        ts = time.perf_counter() if ts is None else ts
        self._append(TraceEvent(name=name, ph="C", ts=ts * 1e6, tid=tid,
                                cat=cat, args={"value": float(value)}))

    # -- introspection / export --------------------------------------------
    def events(self) -> list[TraceEvent]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def export_chrome(self, path: str | os.PathLike) -> pathlib.Path:
        """Write the buffer as STRICT Chrome-trace JSON (loads in Perfetto /
        chrome://tracing). ``allow_nan=False``: a NaN arg would render the
        file unparseable to strict readers, so args are sanitized first."""
        pid = os.getpid()
        out = []
        for ev in self.events():
            d = {"name": ev.name, "ph": ev.ph, "ts": ev.ts, "pid": pid,
                 "tid": ev.tid, "cat": ev.cat}
            if ev.ph == "X":
                d["dur"] = ev.dur
            if ev.ph == "i":
                d["s"] = "t"
            if ev.args is not None:
                d["args"] = sanitize_json(ev.args)
            out.append(d)
        doc = {"traceEvents": out, "displayTimeUnit": "ms",
               "otherData": {"dropped_events": self.dropped}}
        path = pathlib.Path(path)
        path.write_text(json.dumps(doc, allow_nan=False) + "\n")
        return path


def sanitize_json(obj):
    """Recursively map NaN/±Inf floats to None so a payload serializes under
    ``json.dumps(..., allow_nan=False)`` (strict JSON has no NaN literal).
    Shared by the trace exporter, the metrics snapshot, and
    ``benchmarks/common.write_bench_json``."""
    if isinstance(obj, dict):
        return {k: sanitize_json(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize_json(v) for v in obj]
    if isinstance(obj, float):
        return obj if obj == obj and abs(obj) != float("inf") else None
    return obj


TRACER = Tracer()


def span(name: str, *, cat: str = "repro", args: dict | None = None):
    """Gated hot-path span: a shared null context when telemetry is off —
    the kernels' per-dispatch cost is exactly this one predicate."""
    if not _STATE.enabled:
        return _NULL
    return TRACER.span(name, cat=cat, args=args)


def export_chrome_trace(path: str | os.PathLike) -> pathlib.Path:
    return TRACER.export_chrome(path)
