"""Autotune regret auditor: predicted-vs-measured per (impl, workload-key)
and would-have-won alternatives (DESIGN.md §13).

``impl="auto"`` trusts a two-layer oracle — the analytic cost model, overlaid
by the measured tuning cache. Neither is audited anywhere once a decision
ships: a stale cache entry or a mis-calibrated roofline constant silently
taxes every dispatch. This module closes that loop:

- :meth:`RegretAuditor.audit` takes one workload plus its measured per-impl
  times (from ``autotune.cache.measure_workload`` or a tuning-cache record),
  replays the decision ``select_impl`` makes for that workload, and records
  *regret*: ``measured[chosen] / measured[best]`` — 1.0 means the dispatcher
  picked the measured winner, 1.4 means every call pays 40% over the
  would-have-won alternative.
- Per-impl **misprediction ratios** ``measured / predicted`` accumulate
  across workloads; a geometric mean far from 1.0 localizes which roofline
  branch is mis-calibrated (the constants are relative knobs — ordering is
  what matters, so only *spread* between impls is actionable, not a common
  scale factor).
- :meth:`RegretAuditor.record` is the online feed: the kernel-dispatch spans
  (``kernels/ops.py``, telemetry on) report (key, impl, predicted, measured
  wall) per eager dispatch.

``report()`` rolls everything into one strict-JSON-able dict; entries whose
regret ratio exceeds ``flag_threshold`` land in ``flagged`` — the
deliberately mis-cached decision test asserts exactly that path.
"""
from __future__ import annotations

import dataclasses
import math

from repro.analysis.roofline import HW
from repro.autotune.cost_model import Workload, estimate
from repro.autotune.selector import select_impl

# a chosen impl measuring >20% over the measured best is a flagged decision:
# comfortably above timing jitter at the medians the cache stores, small
# enough to catch real cost-model inversions
FLAG_THRESHOLD = 1.2


@dataclasses.dataclass(frozen=True)
class RegretEntry:
    """One audited decision for one workload key."""

    key: str                    # Workload.key()
    chosen: str                 # what the dispatcher picked
    source: str                 # "model" | "cache" | "forced" | "span"
    best: str                   # measured winner among the candidates
    measured: dict              # impl -> measured seconds
    predicted: dict             # impl -> cost-model seconds
    regret_ratio: float         # measured[chosen] / measured[best]
    regret_s: float             # measured[chosen] - measured[best]

    @property
    def flagged(self) -> bool:
        return self.regret_ratio > FLAG_THRESHOLD

    def mispredictions(self) -> dict:
        """measured/predicted ratio per impl present in both maps."""
        out = {}
        for impl, m in self.measured.items():
            p = self.predicted.get(impl)
            if p and p > 0 and m > 0:
                out[impl] = m / p
        return out


class RegretAuditor:
    def __init__(self, *, hw: HW = HW(),
                 flag_threshold: float = FLAG_THRESHOLD):
        self.hw = hw
        self.flag_threshold = flag_threshold
        self.entries: list[RegretEntry] = []

    # -- feeds --------------------------------------------------------------
    def audit(self, w: Workload, times: dict, *,
              chosen: str | None = None, source: str | None = None,
              allow_pallas: bool = True, cache=None) -> RegretEntry:
        """Audit one workload against its measured per-impl ``times``.

        ``chosen=None`` replays the production decision — ``select_impl``
        with the SAME cache precedence the dispatcher uses, so a poisoned
        cache entry is audited as the decision it actually causes."""
        if not times:
            raise ValueError(f"workload {w.key()}: no measured times")
        if chosen is None:
            d = select_impl(w, allow_pallas=allow_pallas, cache=cache,
                            hw=self.hw)
            chosen, source = d.impl, d.source
        predicted = {}
        for impl in times:
            try:
                t = estimate(w, impl, self.hw)
            except ValueError:
                continue
            if t != float("inf"):
                predicted[impl] = t
        best = min(times, key=times.get)
        m_chosen = times.get(chosen)
        if m_chosen is None:
            # the chosen impl was never measured (e.g. case-3 forced ref on
            # a sweep that skipped it): regret vs best is unknowable — treat
            # the entry as maximally informative by flagging it
            m_chosen = float("inf")
        entry = RegretEntry(
            key=w.key(), chosen=chosen, source=source or "caller",
            best=best, measured=dict(times), predicted=predicted,
            regret_ratio=(m_chosen / times[best] if times[best] > 0
                          else float("inf")),
            regret_s=m_chosen - times[best])
        self.entries.append(entry)
        return entry

    def audit_cache(self, cache, workloads, *,
                    allow_pallas: bool = True) -> list[RegretEntry]:
        """Audit every ``workloads`` member that has a tuning-cache record:
        the cache's measured times vs the decision the cache+model produce.
        A record whose pinned ``best`` is NOT the measured argmin (stale or
        poisoned entry) comes out flagged."""
        out = []
        for w in workloads:
            times = cache.times(w.key())
            if not times:
                continue
            out.append(self.audit(w, times, allow_pallas=allow_pallas,
                                  cache=cache))
        return out

    def record(self, key: str, impl: str, *, predicted_s: float,
               measured_s: float) -> RegretEntry:
        """Online single-impl observation (the kernel-span feed): no
        alternatives were measured, so regret is definitionally 1.0 and the
        value is the measured/predicted calibration point."""
        entry = RegretEntry(
            key=key, chosen=impl, source="span", best=impl,
            measured={impl: measured_s}, predicted={impl: predicted_s},
            regret_ratio=1.0, regret_s=0.0)
        self.entries.append(entry)
        return entry

    # -- rollup -------------------------------------------------------------
    def per_impl_ratios(self) -> dict:
        """impl → {n, geomean} of measured/predicted across all entries."""
        logs: dict[str, list[float]] = {}
        for e in self.entries:
            for impl, r in e.mispredictions().items():
                logs.setdefault(impl, []).append(math.log(r))
        return {impl: {"n": len(ls),
                       "geomean_measured_over_predicted":
                           math.exp(sum(ls) / len(ls))}
                for impl, ls in sorted(logs.items())}

    def report(self, top: int = 10) -> dict:
        """The regret report (strict-JSON-able): flagged decisions, the top
        mispredictions, and per-impl calibration ratios."""
        flagged = [e for e in self.entries
                   if e.regret_ratio > self.flag_threshold]
        flagged.sort(key=lambda e: -e.regret_ratio)
        mis = []
        for e in self.entries:
            for impl, r in e.mispredictions().items():
                mis.append({"key": e.key, "impl": impl,
                            "measured_over_predicted": r})
        mis.sort(key=lambda d: -abs(math.log(
            d["measured_over_predicted"])))
        return {
            "n_entries": len(self.entries),
            "n_flagged": len(flagged),
            "flag_threshold": self.flag_threshold,
            "flagged": [{
                "key": e.key, "chosen": e.chosen, "source": e.source,
                "would_have_won": e.best,
                "regret_ratio": e.regret_ratio,
                "regret_s": e.regret_s,
            } for e in flagged[:top]],
            "top_mispredictions": mis[:top],
            "per_impl": self.per_impl_ratios(),
        }

    def format_report(self, top: int = 10) -> str:
        r = self.report(top)
        lines = [f"regret audit: {r['n_entries']} decision(s), "
                 f"{r['n_flagged']} flagged (> {r['flag_threshold']:.2f}x)"]
        for f in r["flagged"]:
            lines.append(
                f"  FLAG {f['key']}: chose {f['chosen']} ({f['source']}), "
                f"measured best {f['would_have_won']} — "
                f"{f['regret_ratio']:.2f}x / +{f['regret_s']:.2e}s per call")
        for impl, s in r["per_impl"].items():
            lines.append(
                f"  model {impl}: measured/predicted geomean "
                f"{s['geomean_measured_over_predicted']:.2f} "
                f"(n={s['n']})")
        return "\n".join(lines)


# Process-default auditor — the kernel-span feed reports here; benchmarks
# and tests construct their own for isolation.
AUDITOR = RegretAuditor()


def default_auditor() -> RegretAuditor:
    return AUDITOR
