"""Geometry-tier bucketing: stop 10-node molecules paying 50-node padding.

The paper's §IV-C pad-to-max policy makes every wave a single compiled
program — but ONE global (m_pad, nnz_pad) geometry means every small graph
pays the worst case. The bucketing policy quantizes request geometry onto a
small ladder of :class:`GeometryTier`s (derived through the same
``core/batching`` rounding the :class:`~repro.core.batching.BatchPlan`
constructors use), so each wave still hits exactly one compiled program —
now per TIER — while small molecules ride small-geometry waves.

A request is assigned the SMALLEST tier that fits both its node count and its
largest per-channel edge count; anything too big for the top rung has no
bucket (``tier_for`` returns None) and the scheduler rejects it cleanly
instead of killing a wave.
"""
from __future__ import annotations

import dataclasses

from repro.core.batching import SUBLANES, _round_up, tier_ladder
from repro.serving.engine import GraphRequest


@dataclasses.dataclass(frozen=True, order=True)
class GeometryTier:
    """One wave geometry: every wave of this tier runs the SAME jitted
    program (``batch`` slots × ``m_pad`` node rows × ``nnz_pad`` COO slots
    per channel), so the tier is also the program-cache key (DESIGN.md §8)."""

    m_pad: int
    nnz_pad: int
    batch: int

    def fits(self, n_nodes: int, max_nnz: int) -> bool:
        return n_nodes <= self.m_pad and max_nnz <= self.nnz_pad

    @property
    def key(self) -> str:
        return f"m{self.m_pad}_nnz{self.nnz_pad}_b{self.batch}"


class TierPolicy:
    """The tier ladder plus the assignment rule (smallest fitting rung).

    ``m_pads``/``nnz_pads`` are parallel ladders — rung i is
    ``(m_pads[i], nnz_pads[i])`` — normally produced by
    :func:`repro.core.batching.tier_ladder` from the dataset maxima.
    """

    def __init__(self, *, m_pads=(16, 32, 56), nnz_pads=(64, 128, 256),
                 batch: int = 32):
        if len(m_pads) != len(nnz_pads):
            raise ValueError(
                f"parallel ladders required: {len(m_pads)} m_pads vs "
                f"{len(nnz_pads)} nnz_pads")
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        rungs = sorted(
            {(_round_up(m, SUBLANES), _round_up(nz, 8))
             for m, nz in zip(m_pads, nnz_pads)})
        for (m0, z0), (m1, z1) in zip(rungs, rungs[1:]):
            if z1 < z0:
                # wave top-up assumes any smaller-tier request also fits a
                # bigger tier — that needs BOTH dims monotone up the ladder
                raise ValueError(
                    f"non-monotone ladder: rung ({m1}, {z1}) has smaller "
                    f"nnz_pad than rung ({m0}, {z0})")
        self.tiers: tuple[GeometryTier, ...] = tuple(
            GeometryTier(m_pad=m, nnz_pad=nz, batch=batch)
            for m, nz in rungs)

    @staticmethod
    def for_sizes(*, m_max: int, nnz_max: int, levels: int = 3,
                  batch: int = 32) -> "TierPolicy":
        """Ladder halving down from the dataset maxima (``tier_ladder``)."""
        rungs = tier_ladder(m_max=m_max, nnz_max=nnz_max, levels=levels)
        return TierPolicy(m_pads=[m for m, _ in rungs],
                          nnz_pads=[nz for _, nz in rungs], batch=batch)

    @staticmethod
    def from_requests(geometries, *, levels: int = 3,
                      batch: int = 32) -> "TierPolicy":
        """Data-driven ladder from observed ``(n_nodes, max_nnz)`` pairs
        (e.g. a calibration sample of the traffic): m rungs halve down from
        the observed max, and each rung's nnz_pad is the LARGEST nnz among
        requests that fit the rung's node count — so the nnz dimension never
        bounces a request to a bigger tier than its node count demands
        (node count and edge count are strongly correlated in molecular
        graphs; the paper's Table I degree bound makes nnz ≈ O(nodes))."""
        geoms = list(geometries)
        if not geoms:
            raise ValueError("need at least one (n_nodes, max_nnz) sample")
        m_max = max(n for n, _ in geoms)
        nnz_max = max(z for _, z in geoms)
        rungs = tier_ladder(m_max=m_max, nnz_max=nnz_max, levels=levels,
                            nnz_min=8)
        m_pads = [m for m, _ in rungs]
        nnz_pads = []
        for m in m_pads:
            fits = [z for n, z in geoms if n <= m]
            nnz_pads.append(_round_up(max(fits, default=8), 8))
        return TierPolicy(m_pads=m_pads, nnz_pads=nnz_pads, batch=batch)

    @staticmethod
    def single(*, m_pad: int, nnz_pad: int, batch: int = 32) -> "TierPolicy":
        """Degenerate one-rung policy: the fixed-wave baseline geometry."""
        return TierPolicy(m_pads=(m_pad,), nnz_pads=(nnz_pad,), batch=batch)

    def tier_for(self, n_nodes: int, max_nnz: int) -> GeometryTier | None:
        """Smallest tier fitting (n_nodes, max_nnz); None when even the top
        rung is too small (the scheduler rejects such requests cleanly)."""
        for t in self.tiers:
            if t.fits(n_nodes, max_nnz):
                return t
        return None

    def assign(self, request: GraphRequest) -> GeometryTier | None:
        return self.tier_for(request.n_nodes, request.max_nnz)
