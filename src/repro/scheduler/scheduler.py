"""The continuous-batching graph-serving scheduler (DESIGN.md §8).

Pipeline: ``submit()`` → :class:`~repro.scheduler.queue.AdmissionQueue` →
geometry buckets (:class:`~repro.scheduler.bucketing.TierPolicy`) →
:class:`~repro.scheduler.dispatcher.ContinuousDispatcher` picks the next
wave → the tier's cached :class:`~repro.serving.engine.GraphServeEngine`
program executes it → :class:`~repro.scheduler.metrics.ServeMetrics`
accounts for it. ``drain()`` is an event loop over a pluggable clock:

- :class:`RealClock` — wall time; waiting sleeps.
- :class:`VirtualClock` — simulated time; waiting jumps to the next event
  and each wave advances the clock by its (measured or modeled) service
  time. This is what makes arrival-process benchmarks and latency tests
  deterministic and fast.

Numerics: the scheduler serves with ``bn_mode="sample"`` by default —
per-graph batch-norm statistics — because under continuous batching the set
of co-batched requests is a scheduling accident, and a request's logits must
not depend on it. With sample-mode BN every request's output is bitwise
identical to scoring it alone through a ``GraphServeEngine`` of the same
tier geometry (tests/test_scheduler.py asserts exactly that).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Callable, Sequence

from repro.core.gcn import GCNConfig
from repro.scheduler.bucketing import GeometryTier, TierPolicy
from repro.scheduler.dispatcher import ContinuousDispatcher, Wait, WavePlan
from repro.scheduler.metrics import ServeMetrics
from repro.scheduler.programs import ProgramCache
from repro.scheduler.queue import AdmissionQueue, PendingRequest
from repro.serving.engine import GraphRequest, GraphServeEngine


class RealClock:
    """Wall time (monotonic); waiting really sleeps."""

    def now(self) -> float:
        return time.monotonic()

    def sleep_until(self, t: float) -> None:
        dt = t - time.monotonic()
        if dt > 0:
            time.sleep(dt)

    def on_service(self, dt: float) -> None:
        pass                        # wall time already advanced while serving


class VirtualClock:
    """Simulated time for deterministic scheduling runs: waiting jumps the
    clock forward, and each executed wave advances it by the wave's service
    time (measured wall time, or the caller's service model)."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep_until(self, t: float) -> None:
        self._t = max(self._t, t)

    def on_service(self, dt: float) -> None:
        self._t += dt


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Knobs of the continuous-batching policy."""

    batch: int | None = None        # wave slots per tier; None inherits the
                                    # TierPolicy's batch (default 32). Setting
                                    # both this and an explicit `tiers=` to
                                    # different values is a config error.
    flush_after: float = 0.05       # straggler guard / deadline margin (s)
    bn_mode: str = "sample"         # wave-composition-invariant numerics;
                                    # "batch" restores legacy wave statistics
    default_slo: float | None = None  # deadline = arrival + slo when the
                                      # caller gives none (None: best effort)


class Scheduler:
    """Continuous-batching front end over per-tier ``GraphServeEngine``s.

    Either one-shot::

        sched = Scheduler(params, cfg, tiers=TierPolicy.for_sizes(...))
        sched.serve(requests)                  # everything, now

    or streaming::

        sched.submit(r, arrival=t, deadline=t + 0.2)
        ...
        sched.drain()                          # event loop until empty

    ``mesh=`` flows to every tier engine, so each wave spans the device mesh
    exactly as ``GraphServeEngine(mesh=...)`` waves do (DESIGN.md §6).

    Telemetry (DESIGN.md §13): the request lifecycle —
    arrival → admit → dispatch → finish — lands in the span tracer as
    instants plus one complete span per request and per wave, stamped from
    the SCHEDULER's clock (virtual or wall) on the shared ``tid="clock"``
    track, with queue-depth counter samples at every admit/dispatch.
    ``telemetry=False`` silences the trace feed; ``registry=`` hands
    :class:`ServeMetrics` a shared metrics registry (plus ``instance``
    label) instead of its own.
    """

    def __init__(
        self,
        params,
        cfg: GCNConfig,
        *,
        tiers: TierPolicy | None = None,
        config: SchedulerConfig | None = None,
        mesh=None,
        clock=None,
        service_model: Callable[[GeometryTier, int], float] | None = None,
        engine_factory: Callable[[GeometryTier], GraphServeEngine]
        | None = None,
        telemetry: bool = True,
        registry=None,
        instance: str = "default",
    ):
        from repro.observability import TRACER
        self.config = config or SchedulerConfig()
        if self.config.bn_mode != cfg.bn_mode:
            cfg = dataclasses.replace(cfg, bn_mode=self.config.bn_mode)
        self.cfg = cfg
        self.policy = tiers or TierPolicy(batch=self.config.batch or 32)
        if self.config.batch is not None and any(
                t.batch != self.config.batch for t in self.policy.tiers):
            raise ValueError(
                f"SchedulerConfig.batch={self.config.batch} disagrees with "
                f"the tier policy's wave size(s) "
                f"{sorted({t.batch for t in self.policy.tiers})}; wave "
                "geometry comes from the TierPolicy — set batch there, or "
                "leave SchedulerConfig.batch=None to inherit it")
        self.clock = clock or RealClock()
        self.service_model = service_model
        self.dispatcher = ContinuousDispatcher(
            flush_after=self.config.flush_after)
        self.queue = AdmissionQueue()
        self.buckets: dict[GeometryTier, collections.deque[PendingRequest]]
        self.buckets = {}
        self.telemetry = telemetry
        self.tracer = TRACER
        self._calibrated: set[GeometryTier] = set()
        self.metrics = ServeMetrics(
            registry=registry,
            labels=None if registry is None else {"instance": instance})
        # engine_factory lets several schedulers share warm engines (one
        # compile per geometry across e.g. a benchmark's policy variants);
        # a custom factory owns the engines' cfg/numerics
        self.programs = ProgramCache(
            engine_factory or (lambda tier: GraphServeEngine(
                params, self.cfg, batch=tier.batch, m_pad=tier.m_pad,
                nnz_pad=tier.nnz_pad, mesh=mesh)))
        self.completed: list[PendingRequest] = []

    # -- intake -------------------------------------------------------------
    def submit(self, request: GraphRequest, *, arrival: float | None = None,
               deadline: float | None = None) -> PendingRequest:
        """Queue one request. ``arrival`` defaults to the clock's now (a
        future arrival is admitted when the clock reaches it); ``deadline``
        defaults to ``arrival + default_slo`` when an SLO is configured."""
        if arrival is None:
            arrival = self.clock.now()
        if deadline is None and self.config.default_slo is not None:
            deadline = arrival + self.config.default_slo
        if self.telemetry:
            self.tracer.instant(
                "request/arrival", ts=arrival, cat="sched",
                args={"n_nodes": request.n_nodes,
                      "max_nnz": request.max_nnz, "deadline": deadline})
        return self.queue.submit(request, arrival=arrival, deadline=deadline)

    def _queue_depth(self) -> int:
        return sum(len(b) for b in self.buckets.values())

    def _admit(self, now: float) -> None:
        admitted = False
        for p in self.queue.due(now):
            tier = self.policy.assign(p.request)
            if tier is None:
                r = p.request
                r.failed, r.done = True, False
                r.error = (
                    f"no geometry tier fits n_nodes={r.n_nodes}, "
                    f"max_nnz={r.max_nnz} (top tier: {self.policy.tiers[-1]})")
                self.metrics.record_rejection(arrival=p.arrival)
                self.completed.append(p)
                if self.telemetry:
                    self.tracer.instant("request/reject", ts=now, cat="sched",
                                        args={"reason": r.error})
                continue
            p.tier = tier
            self.buckets.setdefault(tier, collections.deque()).append(p)
            admitted = True
            if self.telemetry:
                self.tracer.instant("request/admit", ts=now, cat="sched",
                                    args={"tier": tier.key})
        if admitted and self.telemetry:
            self.tracer.counter("queue_depth", self._queue_depth(), ts=now,
                                cat="sched")

    # -- execution ----------------------------------------------------------
    def warmup(self, requests: Sequence[GraphRequest]) -> int:
        """Pre-compile the tier program of every geometry these requests
        would use; returns the number of programs now cached. Benchmarks
        call this so compile time stays out of the timed run."""
        tiers = {self.policy.assign(r) for r in requests} - {None}
        for tier in sorted(tiers):
            self.programs.get(tier).warm()
        self.metrics.compile_count = self.programs.compile_count
        return self.programs.compile_count

    def _execute(self, plan: WavePlan) -> None:
        wave: list[PendingRequest] = []
        for src, count in plan.takes:
            bucket = self.buckets[src]
            wave.extend(bucket.popleft() for _ in range(count))
        # the chosen tier's own requests first, then top-ups (already the
        # takes order) — slot order inside one wave is irrelevant to outputs
        # (bn_mode="sample": per-slot numerics), but keep it deterministic
        program = self.programs.get(plan.tier)
        dispatch = self.clock.now()
        if self.telemetry:
            # the wall-clock sched/wave span wraps the engine's serve/wave
            # span (which wraps any trace-time kernel spans): the nested
            # scheduler → wave → kernel structure the trace viewer shows
            span = self.tracer.span(
                "sched/wave", cat="sched",
                args={"tier": plan.tier.key, "n_requests":
                      sum(c for _, c in plan.takes)})
        else:
            span = contextlib.nullcontext()
        t0 = time.perf_counter()
        with span:
            report = program.engine.run_wave([p.request for p in wave])
        measured = time.perf_counter() - t0
        served = report.n_requests - report.n_failed
        service = (measured if self.service_model is None
                   else self.service_model(plan.tier, served))
        self.clock.on_service(service)
        finish = self.clock.now()
        self.metrics.record_wave(plan.tier.key, dispatch, service, report)
        if self.telemetry:
            self._feed_regret(plan.tier, program, measured)
        if self.telemetry:
            # clock-domain twin of the wall span: where the wave sits on the
            # scheduler's (possibly virtual) timeline
            self.tracer.complete(
                f"wave[{plan.tier.key}]", ts=dispatch, dur=service,
                cat="sched", args={"served": served,
                                   "n_failed": report.n_failed})
        for p in wave:
            p.served_tier = plan.tier
            p.dispatch, p.finish = dispatch, finish
            self.metrics.record_request(
                arrival=p.arrival, dispatch=dispatch, finish=finish,
                deadline=p.deadline, failed=p.request.failed)
            self.completed.append(p)
            if self.telemetry:
                self.tracer.complete(
                    "request", ts=p.arrival, dur=max(finish - p.arrival, 0.0),
                    tid="requests", cat="sched",
                    args={"tier": plan.tier.key,
                          "wait_s": dispatch - p.arrival,
                          "failed": bool(p.request.failed),
                          "deadline_missed": bool(
                              p.deadline is not None and finish > p.deadline)})
        if self.telemetry:
            self.tracer.counter("queue_depth", self._queue_depth(),
                                ts=finish, cat="sched")
        self.metrics.compile_count = self.programs.compile_count

    def _feed_regret(self, tier: GeometryTier, program, measured: float
                     ) -> None:
        """Wave-level calibration feed for the regret auditor: measured wave
        wall time vs the tier decision's predicted first-layer kernel time.
        Each tier's FIRST wave is skipped — it carries the compile, which
        would poison the measured/predicted ratio by orders of magnitude.
        The serve path's kernels only ever run inside the tier's jitted
        program (no eager dispatch), so this is where serve-side
        predicted-vs-measured provenance comes from (DESIGN.md §13)."""
        if tier not in self._calibrated:
            self._calibrated.add(tier)      # compile wave: record nothing
            return
        d = program.decision
        w = getattr(d, "workload", None)
        predicted = dict(getattr(d, "scores", ()) or ()).get(d.impl)
        if predicted is None or predicted <= 0 or predicted != predicted \
                or predicted == float("inf"):
            return
        from repro.observability import default_auditor

        default_auditor().record(
            w.key() if w is not None else tier.key, d.impl,
            predicted_s=predicted, measured_s=measured)

    def drain(self) -> list[PendingRequest]:
        """Event loop: admit arrivals, dispatch ready waves, wait (sleep or
        simulated jump) when batching longer is the better trade. Returns
        every request completed during this drain, completion order."""
        start = len(self.completed)
        while True:
            now = self.clock.now()
            self._admit(now)
            plan = self.dispatcher.next_wave(
                self.buckets, now, draining=len(self.queue) == 0)
            if isinstance(plan, WavePlan):
                self._execute(plan)
                continue
            nxt = self.queue.next_arrival()
            if isinstance(plan, Wait):
                target = plan.until if nxt is None else min(plan.until, nxt)
            elif nxt is not None:       # buckets empty, arrivals pending
                target = nxt
            else:                       # fully drained
                break
            self.clock.sleep_until(max(target, now))
        return self.completed[start:]

    def serve(self, requests: Sequence[GraphRequest], *,
              arrivals: Sequence[float] | None = None,
              deadlines: Sequence[float] | None = None,
              ) -> list[GraphRequest]:
        """Submit a whole stream (optionally with per-request arrival times
        and deadlines) and drain it. Returns the same request objects with
        ``logits``/``done`` (or ``failed``/``error``) filled in."""
        for i, r in enumerate(requests):
            self.submit(
                r,
                arrival=None if arrivals is None else arrivals[i],
                deadline=None if deadlines is None else deadlines[i])
        self.drain()
        return list(requests)

    # -- convenience constructors ------------------------------------------
    @classmethod
    def fixed_wave(cls, params, cfg: GCNConfig, *, batch: int = 32,
                   m_pad: int = 56, nnz_pad: int = 256,
                   **kw) -> "Scheduler":
        """The pre-scheduler baseline expressed in scheduler terms: ONE
        geometry tier at the worst-case padding, waves launch only when full
        (or at final drain) — exactly the old ``_serve_in_waves`` slicing,
        but measured by the same clock and metrics as the bucketed policy,
        so benchmark comparisons are apples-to-apples."""
        import math

        config = kw.pop("config", None) or SchedulerConfig(
            batch=batch, flush_after=math.inf)
        if not math.isinf(config.flush_after):
            config = dataclasses.replace(config, flush_after=math.inf)
        tiers = TierPolicy.single(m_pad=m_pad, nnz_pad=nnz_pad, batch=batch)
        return cls(params, cfg, tiers=tiers, config=config, **kw)
