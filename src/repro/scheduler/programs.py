"""Per-tier program cache: each wave geometry compiles exactly once.

Every :class:`~repro.scheduler.bucketing.GeometryTier` maps to one
:class:`~repro.serving.engine.GraphServeEngine` whose jitted apply is the
tier's compiled program — the continuous-batching analogue of the paper's
"one compiled step per epoch" static-shape discipline. The cache also
records the adaptive layer decision (``repro.autotune`` via
``engine.layer_decision()``) the tier's wave workload resolves to, so ops
can audit WHICH kernel each geometry runs without re-deriving it
(DESIGN.md §5/§8). ``compile_count`` is the invariant the metrics module
surfaces: number of programs == number of tiers actually used.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.scheduler.bucketing import GeometryTier
from repro.serving.engine import GraphServeEngine


@dataclasses.dataclass
class TierProgram:
    """One tier's executor + its audited autotune layer decision."""

    tier: GeometryTier
    engine: GraphServeEngine
    decision: object            # repro.autotune.Decision for the tier workload
    warmed: bool = False

    def warm(self) -> None:
        """Force the tier's one compilation now (empty wave: all-empty
        slots still trace and compile the full program). Idempotent —
        repeated warms don't re-execute."""
        if not self.warmed:
            self.engine.run_wave([])
            self.warmed = True


class ProgramCache:
    """Lazy tier → TierProgram map; ``factory(tier)`` builds the engine."""

    def __init__(self, factory: Callable[[GeometryTier], GraphServeEngine]):
        self._factory = factory
        self._programs: dict[GeometryTier, TierProgram] = {}

    def get(self, tier: GeometryTier) -> TierProgram:
        prog = self._programs.get(tier)
        if prog is None:
            engine = self._factory(tier)
            prog = TierProgram(tier=tier, engine=engine,
                               decision=engine.layer_decision())
            self._programs[tier] = prog
        return prog

    @property
    def compile_count(self) -> int:
        """Distinct compiled wave programs — equals the number of geometry
        tiers that have served (or been warmed)."""
        return len(self._programs)

    def tiers(self) -> tuple[GeometryTier, ...]:
        return tuple(sorted(self._programs))

    def decisions(self) -> dict[str, object]:
        """tier key → autotune Decision, for audit/metrics."""
        return {t.key: p.decision for t, p in self._programs.items()}

    def jit_cache_sizes(self) -> dict[str, int]:
        """tier key → entries in the tier engine's jit cache. The one-
        compilation-per-tier invariant holds iff every value is 1. Tiers
        whose runtime cannot report a count (no jit introspection) are
        omitted rather than guessed."""
        sizes = {t.key: p.engine.compiled_programs()
                 for t, p in self._programs.items()}
        return {k: v for k, v in sizes.items() if v is not None}
