"""Continuous-batching dispatch policy: fill-rate vs. oldest-request wait.

Given the per-tier buckets of pending requests, decide — deterministically —
which tier launches the next wave, or how long to wait for a better one
(DESIGN.md §8). A bucket becomes *ready* when any of:

- it can fill a full wave (``len(bucket) >= tier.batch``): maximal
  launch amortization, dispatch now;
- its oldest request has waited ``flush_after`` seconds: the straggler
  guard — a lone small molecule is never starved by an idle bucket;
- its tightest pending deadline's slack (anywhere in the bucket, not just
  the head) has shrunk to ``flush_after``: deadline-aware early flush, the
  request ships while it can still make its SLO;
- the scheduler is draining (no future arrivals remain): everything left
  must ship.

Among ready buckets the dispatcher launches the best ``fill + urgency``
score (fill = wave occupancy it would achieve, urgency = oldest wait or
deadline slack in units of ``flush_after``), tie-broken by oldest arrival
then tier key — no wall-clock or hash-order nondeterminism anywhere, which
is what makes scheduler runs replayable in tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Deque, Mapping

from repro.scheduler.bucketing import GeometryTier
from repro.scheduler.queue import PendingRequest


@dataclasses.dataclass(frozen=True)
class WavePlan:
    """Launch order for one wave of ``tier``'s geometry: pop the given
    number of oldest requests from each listed bucket (``takes`` is ordered
    ``(source_tier, count)``; sources other than ``tier`` are smaller-tier
    TOP-UPS — their requests fit the larger geometry, and riding a wave
    that is launching anyway beats waiting for their own bucket to fill)."""

    tier: GeometryTier
    takes: tuple[tuple[GeometryTier, int], ...]

    @property
    def count(self) -> int:
        return sum(c for _, c in self.takes)


@dataclasses.dataclass(frozen=True)
class Wait:
    """No bucket is ready; re-evaluate at ``until`` (earliest flush point)."""

    until: float


class ContinuousDispatcher:
    """Deterministic next-wave chooser over the geometry buckets.

    ``topup=True`` (default) fills a launching wave's spare slots with the
    globally-oldest requests from SMALLER tiers — they fit the larger
    geometry, so a wave that must launch anyway (flush, deadline, drain)
    leaves with maximal occupancy instead of empty slots. This is what lets
    bucketing win padding waste and tail latency simultaneously: small
    requests never wait on their own bucket when a larger wave is leaving.
    """

    def __init__(self, *, flush_after: float = 0.05, topup: bool = True):
        if flush_after <= 0:
            raise ValueError(f"flush_after must be > 0, got {flush_after}")
        self.flush_after = flush_after
        self.topup = topup

    def _urgency(self, oldest: PendingRequest, deadline: float | None,
                 now: float) -> float:
        """``deadline`` is the TIGHTEST deadline in the bucket, not the
        oldest request's — a younger request's SLO must pull the flush
        forward too."""
        wait = (now - oldest.arrival) / self.flush_after
        if deadline is not None and math.isfinite(self.flush_after):
            slack = deadline - now
            # slack <= flush_after ≡ urgency >= 1 (ready); overdue grows fast
            wait = max(wait, 2.0 - slack / self.flush_after)
        return wait

    def next_wave(
        self,
        buckets: Mapping[GeometryTier, Deque[PendingRequest]],
        now: float,
        *,
        draining: bool = False,
    ) -> WavePlan | Wait | None:
        """One scheduling decision. Returns a :class:`WavePlan` to launch, a
        :class:`Wait` when some bucket will become ready at a known future
        time, or None when every bucket is empty."""
        best = None         # (score, -arrival, -seq, tier) via explicit compare
        wait_until = math.inf
        tiers = sorted(buckets)                 # ascending geometry
        for i, tier in enumerate(tiers):
            q = buckets[tier]
            if not q:
                continue
            oldest = q[0]
            # achievable occupancy counts smaller-tier top-ups: the wave's
            # spare slots can carry any smaller-geometry request. Only a
            # tier with own pending requests is a launch candidate — no
            # request NEEDS a bigger geometry than its own tier.
            pool = len(q)
            if self.topup:
                pool += sum(len(buckets[t]) for t in tiers[:i])
            # the bucket's tightest deadline, wherever it sits in the queue
            # — a younger request's SLO pulls the flush forward too
            deadline = min((p.deadline for p in q if p.deadline is not None),
                           default=None)
            # readiness and the wait target MUST use the same arithmetic
            # (now >= flush_at), or float rounding can park the event loop
            # exactly on a flush point it never considers ready
            flush_at = oldest.arrival + self.flush_after
            if deadline is not None and math.isfinite(self.flush_after):
                flush_at = min(flush_at, deadline - self.flush_after)
            ready = pool >= tier.batch or now >= flush_at or draining
            if not ready:
                wait_until = min(wait_until, flush_at)
                continue
            urgency = self._urgency(oldest, deadline, now)
            fill = min(pool, tier.batch) / tier.batch
            score = fill + urgency
            cand = (score, -oldest.arrival, -oldest.seq, tier)
            if best is None or (cand[0], cand[1], cand[2]) > best[:3]:
                best = cand
        if best is not None:
            tier = best[3]
            return self._plan(buckets, tiers, tier)
        if math.isfinite(wait_until):
            return Wait(until=max(wait_until, now))
        return None

    def _plan(self, buckets, tiers, tier: GeometryTier) -> WavePlan:
        """Materialize the wave: the chosen tier's oldest requests first,
        spare slots topped up with the globally-oldest smaller-tier
        requests. The k oldest of arrival-sorted buckets are always bucket
        prefixes, so the plan is expressible as per-bucket pop counts."""
        own = min(len(buckets[tier]), tier.batch)
        takes = [(tier, own)]
        spare = tier.batch - own
        if spare > 0 and self.topup:
            smaller = [p for t in tiers if t < tier for p in buckets[t]]
            smaller.sort(key=lambda p: (p.arrival, p.seq))
            chosen = smaller[:spare]
            counts: dict[GeometryTier, int] = {}
            for p in chosen:
                counts[p.tier] = counts.get(p.tier, 0) + 1
            takes += [(t, counts[t]) for t in tiers if t in counts]
        return WavePlan(tier=tier, takes=tuple(takes))
