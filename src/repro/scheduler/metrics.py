"""Serving metrics: throughput, latency percentiles, padding waste, compiles.

One :class:`ServeMetrics` instance accumulates per-wave and per-request
records over a scheduler run and reduces them to the numbers the benchmarks
compare (DESIGN.md §8):

- **throughput** — served requests per second of clock time between the
  first arrival and the last wave completion;
- **p50/p99 latency** — request completion latency (finish − arrival), the
  continuous-batching headline number;
- **padding-waste ratio** — 1 − (real node rows) / (padded node-row capacity)
  over all executed waves (and the same for nnz slots): what the §IV-C
  pad-to-max policy costs, and what bucketing claws back;
- **compile count** — distinct wave programs built, which must equal the
  number of geometry tiers used (the program-cache invariant).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.engine import GraphWaveReport


@dataclasses.dataclass(frozen=True)
class WaveRecord:
    tier_key: str
    dispatch: float
    service_time: float
    report: GraphWaveReport


class ServeMetrics:
    def __init__(self) -> None:
        self.waves: list[WaveRecord] = []
        self.latencies: list[float] = []
        self.waits: list[float] = []
        self.first_arrival: float | None = None
        self.last_finish: float | None = None
        self.served = 0
        self.rejected = 0
        self.deadline_misses = 0
        self.compile_count = 0

    # -- recording ----------------------------------------------------------
    def record_wave(self, tier_key: str, dispatch: float,
                    service_time: float, report: GraphWaveReport) -> None:
        self.waves.append(WaveRecord(tier_key, dispatch, service_time,
                                     report))

    def record_request(self, *, arrival: float, dispatch: float,
                       finish: float, deadline: float | None = None,
                       failed: bool = False) -> None:
        if self.first_arrival is None or arrival < self.first_arrival:
            self.first_arrival = arrival
        if self.last_finish is None or finish > self.last_finish:
            self.last_finish = finish
        if failed:
            self.rejected += 1
            return
        self.served += 1
        self.latencies.append(finish - arrival)
        self.waits.append(dispatch - arrival)
        if deadline is not None and finish > deadline:
            self.deadline_misses += 1

    def record_rejection(self, *, arrival: float) -> None:
        if self.first_arrival is None or arrival < self.first_arrival:
            self.first_arrival = arrival
        self.rejected += 1

    # -- reductions ---------------------------------------------------------
    def latency_percentile(self, p: float) -> float:
        if not self.latencies:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies), p))

    @property
    def p50(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def throughput(self) -> float:
        if (self.first_arrival is None or self.last_finish is None
                or self.last_finish <= self.first_arrival):
            return float("nan")
        return self.served / (self.last_finish - self.first_arrival)

    @property
    def padding_waste_nodes(self) -> float:
        cap = sum(w.report.node_capacity for w in self.waves)
        real = sum(w.report.real_nodes for w in self.waves)
        return float("nan") if cap == 0 else 1.0 - real / cap

    @property
    def padding_waste_nnz(self) -> float:
        cap = sum(w.report.nnz_capacity for w in self.waves)
        real = sum(w.report.real_nnz for w in self.waves)
        return float("nan") if cap == 0 else 1.0 - real / cap

    @property
    def fill_rate(self) -> float:
        slots = sum(w.report.slots for w in self.waves)
        real = sum(w.report.n_requests - w.report.n_failed
                   for w in self.waves)
        return float("nan") if slots == 0 else real / slots

    def summary(self) -> dict:
        """Machine-readable rollup (what BENCH_serve.json persists)."""
        return {
            "served": self.served,
            "rejected": self.rejected,
            "deadline_misses": self.deadline_misses,
            "waves": len(self.waves),
            "compile_count": self.compile_count,
            "throughput_rps": self.throughput,
            "latency_p50_s": self.p50,
            "latency_p99_s": self.p99,
            "mean_wait_s": (float(np.mean(self.waits))
                            if self.waits else float("nan")),
            "padding_waste_nodes": self.padding_waste_nodes,
            "padding_waste_nnz": self.padding_waste_nnz,
            "fill_rate": self.fill_rate,
        }
