"""Serving metrics: throughput, latency percentiles, padding waste, compiles.

One :class:`ServeMetrics` instance accumulates per-wave and per-request
records over a scheduler run and reduces them to the numbers the benchmarks
compare (DESIGN.md §8):

- **throughput** — served requests per second of clock time between the
  first arrival and the last wave completion (a zero-width clock span —
  e.g. one request under a service model that never advances the clock —
  falls back to the summed wave service time instead of returning NaN);
- **p50/p99 latency** — request completion latency (finish − arrival), the
  continuous-batching headline number;
- **padding-waste ratio** — 1 − (real node rows) / (padded node-row capacity)
  over all executed waves (and the same for nnz slots): what the §IV-C
  pad-to-max policy costs, and what bucketing claws back;
- **compile count** — distinct wave programs built, which must equal the
  number of geometry tiers used (the program-cache invariant).

Storage sits on a :class:`repro.observability.MetricsRegistry` (DESIGN.md
§13) instead of private lists: counts are registry counters, latency/wait
distributions are ``keep_samples`` histograms (p50/p99 stay sample-exact),
and wave service times land in a per-tier labeled histogram — so one
``registry.snapshot()``/``export_jsonl()`` carries the whole serve run.
Each instance defaults to its OWN registry (concurrent schedulers in one
process must not sum each other's counters); pass a shared ``registry``
plus a distinguishing ``labels`` dict to aggregate deliberately.
"""
from __future__ import annotations

import dataclasses

from repro.observability.metrics import MetricsRegistry
from repro.serving.engine import GraphWaveReport


@dataclasses.dataclass(frozen=True)
class WaveRecord:
    tier_key: str
    dispatch: float
    service_time: float
    report: GraphWaveReport


class ServeMetrics:
    def __init__(self, *, registry: MetricsRegistry | None = None,
                 labels: dict | None = None) -> None:
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self.labels = dict(labels or {})
        self.waves: list[WaveRecord] = []
        self.first_arrival: float | None = None
        self.last_finish: float | None = None
        self._c_requests = self.registry.counter(
            "serve_requests_total", "requests by outcome (served/rejected)")
        self._c_misses = self.registry.counter(
            "serve_deadline_misses_total", "served past their deadline")
        self._c_waves = self.registry.counter(
            "serve_waves_total", "executed waves per geometry tier")
        self._h_latency = self.registry.histogram(
            "serve_latency_seconds", "finish - arrival per served request",
            keep_samples=True)
        self._h_wait = self.registry.histogram(
            "serve_wait_seconds", "dispatch - arrival per served request",
            keep_samples=True)
        self._h_service = self.registry.histogram(
            "serve_wave_service_seconds", "wave service time per tier")
        self._g_compiles = self.registry.gauge(
            "serve_compile_count", "distinct wave programs built")

    # -- registry-backed views ----------------------------------------------
    @property
    def served(self) -> int:
        return int(self._c_requests.value(outcome="served", **self.labels))

    @property
    def rejected(self) -> int:
        return int(self._c_requests.value(outcome="rejected", **self.labels))

    @property
    def deadline_misses(self) -> int:
        return int(self._c_misses.value(**self.labels))

    @property
    def compile_count(self) -> int:
        v = self._g_compiles.value(**self.labels)
        return 0 if v != v else int(v)      # gauge is NaN until first set

    @compile_count.setter
    def compile_count(self, value: int) -> None:
        self._g_compiles.set(value, **self.labels)

    # -- recording ----------------------------------------------------------
    def record_wave(self, tier_key: str, dispatch: float,
                    service_time: float, report: GraphWaveReport) -> None:
        self.waves.append(WaveRecord(tier_key, dispatch, service_time,
                                     report))
        self._c_waves.inc(tier=tier_key, **self.labels)
        self._h_service.observe(service_time, tier=tier_key, **self.labels)

    def record_request(self, *, arrival: float, dispatch: float,
                       finish: float, deadline: float | None = None,
                       failed: bool = False) -> None:
        if self.first_arrival is None or arrival < self.first_arrival:
            self.first_arrival = arrival
        if self.last_finish is None or finish > self.last_finish:
            self.last_finish = finish
        if failed:
            self._c_requests.inc(outcome="rejected", **self.labels)
            return
        self._c_requests.inc(outcome="served", **self.labels)
        self._h_latency.observe(finish - arrival, **self.labels)
        self._h_wait.observe(dispatch - arrival, **self.labels)
        if deadline is not None and finish > deadline:
            self._c_misses.inc(**self.labels)

    def record_rejection(self, *, arrival: float) -> None:
        if self.first_arrival is None or arrival < self.first_arrival:
            self.first_arrival = arrival
        self._c_requests.inc(outcome="rejected", **self.labels)

    # -- reductions ---------------------------------------------------------
    def latency_percentile(self, p: float) -> float:
        return self._h_latency.percentile(p, **self.labels)

    @property
    def p50(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def throughput(self) -> float:
        if (self.served == 0 or self.first_arrival is None
                or self.last_finish is None):
            return float("nan")
        span = self.last_finish - self.first_arrival
        if span <= 0:
            # zero-width clock span (e.g. ONE request whose finish stamps at
            # its arrival under a zero-cost service model): the wave service
            # time is the honest denominator, not NaN
            span = sum(w.service_time for w in self.waves)
        if span <= 0:
            return float("nan")
        return self.served / span

    @property
    def padding_waste_nodes(self) -> float:
        cap = sum(w.report.node_capacity for w in self.waves)
        real = sum(w.report.real_nodes for w in self.waves)
        return float("nan") if cap == 0 else 1.0 - real / cap

    @property
    def padding_waste_nnz(self) -> float:
        cap = sum(w.report.nnz_capacity for w in self.waves)
        real = sum(w.report.real_nnz for w in self.waves)
        return float("nan") if cap == 0 else 1.0 - real / cap

    @property
    def fill_rate(self) -> float:
        slots = sum(w.report.slots for w in self.waves)
        real = sum(w.report.n_requests - w.report.n_failed
                   for w in self.waves)
        return float("nan") if slots == 0 else real / slots

    def summary(self) -> dict:
        """Machine-readable rollup (what BENCH_serve.json persists)."""
        n_wait = self._h_wait.count(**self.labels)
        return {
            "served": self.served,
            "rejected": self.rejected,
            "deadline_misses": self.deadline_misses,
            "waves": len(self.waves),
            "compile_count": self.compile_count,
            "throughput_rps": self.throughput,
            "latency_p50_s": self.p50,
            "latency_p99_s": self.p99,
            "mean_wait_s": (self._h_wait.sum(**self.labels) / n_wait
                            if n_wait else float("nan")),
            "padding_waste_nodes": self.padding_waste_nodes,
            "padding_waste_nnz": self.padding_waste_nnz,
            "fill_rate": self.fill_rate,
        }
