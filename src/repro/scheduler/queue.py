"""Admission queue: arrival-stamped, deadline-carrying request intake.

The queue is the scheduler's front door (DESIGN.md §8): every
:class:`~repro.serving.engine.GraphRequest` is wrapped in a
:class:`PendingRequest` carrying its arrival timestamp, optional completion
deadline and (after admission) its geometry tier. Requests whose arrival lies
in the future — a simulated Poisson stream, or a real producer submitting
ahead — sit in an arrival-ordered heap until the scheduler's clock reaches
them; ``due(now)`` releases exactly the arrived prefix, in (arrival, submit
order) so FIFO ties break deterministically.
"""
from __future__ import annotations

import dataclasses
import heapq

from repro.serving.engine import GraphRequest


@dataclasses.dataclass
class PendingRequest:
    """One queued request plus its serving lifecycle timestamps."""

    seq: int                        # submission order (FIFO tiebreak)
    request: GraphRequest
    arrival: float                  # clock time the request entered the system
    deadline: float | None = None   # absolute completion deadline (None: best effort)
    tier: object | None = None      # GeometryTier once admitted to a bucket
    served_tier: object | None = None  # wave geometry it actually rode (may
                                       # be larger than `tier`: wave top-up)
    dispatch: float | None = None   # clock time its wave launched
    finish: float | None = None     # clock time its wave completed

    @property
    def latency(self) -> float | None:
        return None if self.finish is None else self.finish - self.arrival

    @property
    def wait(self) -> float | None:
        return None if self.dispatch is None else self.dispatch - self.arrival


class AdmissionQueue:
    """Arrival-ordered intake heap. ``submit`` is O(log n), ``due`` pops the
    arrived prefix; the scheduler drains it every event-loop tick."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, PendingRequest]] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def submit(self, request: GraphRequest, *, arrival: float,
               deadline: float | None = None) -> PendingRequest:
        p = PendingRequest(seq=self._seq, request=request, arrival=arrival,
                           deadline=deadline)
        self._seq += 1
        heapq.heappush(self._heap, (arrival, p.seq, p))
        return p

    def due(self, now: float) -> list[PendingRequest]:
        """Pop every request with ``arrival <= now`` (arrival, then FIFO)."""
        out = []
        while self._heap and self._heap[0][0] <= now:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def next_arrival(self) -> float | None:
        """Arrival time of the earliest still-future request, or None."""
        return self._heap[0][0] if self._heap else None
