"""Continuous-batching graph-serving scheduler (DESIGN.md §8).

Queue → geometry buckets → continuous-batching dispatcher → per-tier
compiled programs → metrics. ``Scheduler`` is the front end; the legacy
``GraphServeEngine`` remains the per-wave executor underneath it.
"""
from repro.scheduler.bucketing import GeometryTier, TierPolicy  # noqa: F401
from repro.scheduler.dispatcher import (  # noqa: F401
    ContinuousDispatcher,
    Wait,
    WavePlan,
)
from repro.scheduler.metrics import ServeMetrics, WaveRecord  # noqa: F401
from repro.scheduler.programs import ProgramCache, TierProgram  # noqa: F401
from repro.scheduler.queue import AdmissionQueue, PendingRequest  # noqa: F401
from repro.scheduler.scheduler import (  # noqa: F401
    RealClock,
    Scheduler,
    SchedulerConfig,
    VirtualClock,
)

__all__ = [
    "AdmissionQueue", "ContinuousDispatcher", "GeometryTier", "PendingRequest",
    "ProgramCache", "RealClock", "Scheduler", "SchedulerConfig",
    "ServeMetrics", "TierPolicy", "TierProgram", "VirtualClock", "Wait",
    "WavePlan", "WaveRecord",
]
