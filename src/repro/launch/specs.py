"""Input construction for every (architecture × shape cell).

``make_inputs(cfg, cell, concrete=False)`` returns the exact pytree the
train/prefill/decode step consumes — as ``jax.ShapeDtypeStruct`` stand-ins for
the dry-run (no allocation) or as zero arrays for smoke tests. This is the
single source of truth for cell applicability (``cell_supported``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import lm

WHISPER_DEC_RATIO = 8        # decoder tokens per encoder frame (train cells)
WHISPER_DEC_ENC_LEN = 4096   # encoder context used by decode cells


def cell_supported(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """Applicability per DESIGN.md §4."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention: 512k dense-KV decode is "
                       "out of scope for this config (no sub-quadratic "
                       "mechanism) — see DESIGN.md §4")
    if cell.name == "long_500k" and cfg.is_encoder_decoder:
        return False, "enc-dec audio model: 500k-token decode is meaningless"
    return True, ""


def _mk(shape, dtype, concrete):
    if concrete:
        return jnp.zeros(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype)


def make_train_batch(cfg: ModelConfig, batch: int, seq: int, concrete=False):
    out = {"tokens": _mk((batch, seq), jnp.int32, concrete)}
    if cfg.frontend == "vision_tiles":
        n_tiles = min(cfg.frontend_len, max(seq // 4, 8))
        out["patch_embeds"] = _mk((batch, n_tiles, lm.VISION_DIM),
                                  jnp.float32, concrete)
    if cfg.is_encoder_decoder:
        out["frames"] = _mk((batch, seq, lm.AUDIO_DIM), jnp.float32, concrete)
        out["tokens"] = _mk((batch, max(seq // WHISPER_DEC_RATIO, 8)),
                            jnp.int32, concrete)
    return out


def make_decode_inputs(cfg: ModelConfig, batch: int, cache_len: int,
                       concrete=False):
    """(tokens, caches, pos) for one decode step."""
    tokens = _mk((batch, 1), jnp.int32, concrete)
    enc_len = WHISPER_DEC_ENC_LEN if cfg.is_encoder_decoder else 0
    if concrete:
        caches = lm.init_decode_state(cfg, batch, cache_len, enc_len)
        pos = jnp.asarray(cache_len, jnp.int32)
    else:
        caches = jax.eval_shape(
            lambda: lm.init_decode_state(cfg, batch, cache_len, enc_len))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
    return tokens, caches, pos


def make_inputs(cfg: ModelConfig, cell: ShapeCell, concrete=False,
                dp_size: int = 1):
    """Returns (kind, inputs-pytree) for the cell. ``dp_size`` caps the
    gradient-accumulation depth so each microbatch still spans every
    data-parallel shard (per-microbatch batch ≥ dp_size)."""
    ok, why = cell_supported(cfg, cell)
    if not ok:
        raise ValueError(f"{cfg.name} × {cell.name}: {why}")
    if cell.kind == "train":
        microbatches = min(cell.microbatches,
                           max(1, cell.global_batch // max(dp_size, 1)))
        return "train", {
            "microbatches": microbatches,
            "batch": make_train_batch(
                cfg, cell.global_batch, cell.seq_len, concrete),
        }
    if cell.kind == "prefill":
        return "prefill", make_train_batch(cfg, cell.global_batch,
                                           cell.seq_len, concrete)
    tokens, caches, pos = make_decode_inputs(
        cfg, cell.global_batch, cell.seq_len, concrete)
    return "decode", {"tokens": tokens, "caches": caches, "pos": pos}
