"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
        --steps 200 --batch 8 --seq 128 --checkpoint-dir /tmp/ck

On this container the full configs only dry-run; ``--reduced`` trains the
same-family small config end-to-end on CPU. On a real fleet the same entry
point runs the full config against the production mesh.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch import specs
from repro.optim import AdamConfig
from repro.training import Trainer, TrainerConfig


def synthetic_data(cfg, batch, seq, seed=0, start_step=0):
    """Resumable synthetic next-token stream (repro.data.tokens): batch i is
    a pure function of (seed, i), so restart == exact resume."""
    from repro.data.tokens import TokenStreamSpec, token_stream

    spec = TokenStreamSpec(vocab=cfg.vocab, batch=batch, seq_len=seq,
                           seed=seed)
    extras = {k: v for k, v in specs.make_train_batch(
        cfg, batch, seq, concrete=True).items() if k != "tokens"}
    for b in token_stream(spec, start_step=start_step):
        yield {**extras, **b}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--mesh", default="1x1",
                    help='"DxM" data×model, or "production"/"multipod"')
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh == "production":
        mesh = make_production_mesh()
    elif args.mesh == "multipod":
        mesh = make_production_mesh(multi_pod=True)
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    tcfg = TrainerConfig(
        total_steps=args.steps, checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        microbatches=args.microbatches, remat=args.remat,
        compress_grads=args.compress_grads)
    trainer = Trainer(cfg, mesh, AdamConfig(lr=args.lr, grad_clip=1.0), tcfg)
    data = synthetic_data(cfg, args.batch, args.seq)
    trainer.fit(data, on_metrics=lambda s, rec: print(
        f"step {s}: loss {rec['loss']:.4f}", flush=True))


if __name__ == "__main__":
    main()
