"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
initialization — required because the dry-run overrides the platform device
count while tests/benchmarks must see one real device.
"""
from __future__ import annotations

import jax


import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod ("data", "model"); 2 pods stack a leading "pod"
    axis (hierarchical data parallelism — gradient reduce-scatter in-pod,
    all-reduce across pods). When more placeholder devices exist than the
    mesh needs (the dry-run forces 512), the first prod(shape) are used."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"production mesh needs {need} devices, have {len(devices)} — "
            "run via repro.launch.dryrun (it forces 512 host devices)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic entry point: trainer restart on a different device count simply
    re-lowers against a new mesh (sharding rules are mesh-parametric)."""
    return jax.make_mesh(shape, axes)
