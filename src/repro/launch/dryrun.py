import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape cell) on the
production meshes, with NO device allocation (jax.ShapeDtypeStruct inputs).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --cell train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # both meshes

Per cell it records: memory_analysis (fits-per-device proof), cost_analysis
FLOPs/bytes, the parsed collective schedule, and the three roofline terms —
JSON under experiments/dryrun/<mesh>/ consumed by EXPERIMENTS.md §Dry-run,
§Roofline and benchmarks/roofline_table.py.
"""  # noqa: E402

import argparse
import json
import time
import traceback

import jax

from repro import configs, tuning
from repro.analysis.roofline import analyze
from repro.configs.base import SHAPE_CELLS, ModelConfig, ShapeCell
from repro.distributed.steps import (
    build_decode_step,
    build_prefill,
    build_train_step,
    shaped_opt_state,
    shaped_params,
)
from repro.launch import specs
from repro.launch.mesh import make_production_mesh
from repro.optim import AdamConfig

OUT_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "experiments", "dryrun")


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS: 6·N_active·tokens (train), 2·N_active·tokens (fwd-only)."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch          # decode: one token per seq


def lower_cell(cfg: ModelConfig, cell: ShapeCell, mesh, *,
               remat: bool = True, compress_grads: bool = False,
               zero1: bool = True):
    """Returns (lowered, p_shape) for the cell's step function."""
    import numpy as np

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = int(np.prod([sizes.get(a, 1) for a in ("pod", "data")]))
    p_shape = shaped_params(cfg)
    kind, inputs = specs.make_inputs(cfg, cell, dp_size=dp)
    if kind == "train":
        builder, _, _ = build_train_step(
            cfg, mesh, AdamConfig(), microbatches=inputs["microbatches"],
            remat=remat, compress_grads=compress_grads, zero1=zero1)
        o_shape = shaped_opt_state(p_shape)
        if compress_grads:
            o_shape = dict(o_shape)
            o_shape["ef_err"] = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, "float32"), p_shape)
        batch = inputs["batch"]
        jitted = builder(batch)
        return jitted.lower(p_shape, o_shape, batch)
    if kind == "prefill":
        builder, _ = build_prefill(cfg, mesh)
        jitted = builder(inputs)
        return jitted.lower(p_shape, inputs)
    builder, _ = build_decode_step(cfg, mesh)
    jitted = builder(inputs["tokens"], inputs["caches"])
    return jitted.lower(p_shape, inputs["tokens"], inputs["caches"],
                        inputs["pos"])


def run_cell(arch: str, cell_name: str, multi_pod: bool, out_dir: str,
             tune: dict | None = None, **lower_kw) -> dict:
    cfg = configs.get(arch)
    cell = SHAPE_CELLS[cell_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = 512 if multi_pod else 256
    record: dict = {"arch": arch, "cell": cell_name, "mesh": mesh_name,
                    "tune": tune or {}}
    ok, why = specs.cell_supported(cfg, cell)
    if not ok:
        record.update(status="skipped", reason=why)
        return record
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with tuning.use_flags(**(tune or {})), tuning.use_mesh_hint(mesh):
            lowered = lower_cell(cfg, cell, mesh, **lower_kw)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        report = analyze(compiled, arch=arch, cell=cell_name,
                         mesh_name=mesh_name, chips=chips,
                         model_flops_total=model_flops(cfg, cell))
        record.update(status="ok", lower_s=round(t_lower, 1),
                      compile_s=round(t_compile, 1), **report.to_json())
    except Exception as e:  # noqa: BLE001 — a dry-run failure IS the result
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=configs.ARCHS)
    ap.add_argument("--cell", default=None, choices=list(SHAPE_CELLS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every arch × cell × both meshes")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_ROOT))
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have a JSON record")
    ap.add_argument("--tune", action="append", default=[],
                    help="key=value TuneFlags override (repeatable)")
    ap.add_argument("--suffix", default="",
                    help="output-file suffix for §Perf variant records")
    args = ap.parse_args()
    tune = tuning.parse_tune_args(args.tune)

    archs = [args.arch] if args.arch else list(configs.ARCHS)
    cells = [args.cell] if args.cell else list(SHAPE_CELLS)
    if args.all:
        meshes = [False, True]
    elif args.multi_pod and not args.single_pod:
        meshes = [True]
    elif args.single_pod and not args.multi_pod:
        meshes = [False]
    else:
        meshes = [False, True]

    n_err = 0
    for multi_pod in meshes:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        out_dir = os.path.join(args.out, mesh_name)
        os.makedirs(out_dir, exist_ok=True)
        for arch in archs:
            for cell in cells:
                sfx = f"__{args.suffix}" if args.suffix else ""
                path = os.path.join(out_dir, f"{arch}__{cell}{sfx}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {mesh_name} {arch} {cell}")
                    continue
                rec = run_cell(arch, cell, multi_pod, out_dir, tune=tune,
                               remat=not args.no_remat,
                               compress_grads=args.compress_grads)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f"compute={rec['t_compute']:.4f}s "
                             f"memory={rec['t_memory']:.4f}s "
                             f"coll={rec['t_collective']:.4f}s "
                             f"bottleneck={rec['bottleneck']} "
                             f"peak_frac={rec['peak_fraction']:.3f}")
                elif status == "error":
                    n_err += 1
                    extra = rec["error"][:160]
                else:
                    extra = rec["reason"][:80]
                print(f"[{status}] {mesh_name} {arch} {cell} {extra}",
                      flush=True)
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
