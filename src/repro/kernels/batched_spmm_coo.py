"""Batched COO/SparseTensor SpMM — the TPU adaptation of the paper's Batched
SWA-SpMM for SparseTensor (Fig. 3 + Fig. 5-(a)/(b)).

The GPU version splits work by *non-zero* and resolves output races with
``atomicAdd`` on shared memory. TPUs have no atomics; the adaptation
(DESIGN.md §2, "atomics → one-hot MXU scatter") is:

- non-zeros are processed in CHUNK-sized vector groups;
- the *gather* side (``B[cid]``) is a sublane-axis ``jnp.take``;
- the *scatter-add* side (``C[rid] += …``) becomes a one-hot matrix product
  ``P.T @ G`` where ``P[i, r] = (rid[i] == r)`` — a (chunk × m_pad)ᵀ ×
  (chunk × n_block) contraction that runs on the MXU. Races disappear because
  the reduction is a dot-product, not a read-modify-write.

Accumulation across chunks happens in a VMEM-resident f32 accumulator — the
shared-memory-resident output of Fig. 5-(a) — and the column-panel grid
dimension reproduces the cache blocking of Fig. 5-(b).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.batching import CHUNK, BatchPlan
from repro.kernels import resolve_interpret


def _kernel(rid_ref, cid_ref, val_ref, b_ref, c_ref, *, m_pad: int, chunks: int):
    bb = b_ref[0]                                    # (m_pad, n_block)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (CHUNK, m_pad), 1)

    def body(i, acc):
        sl = pl.dslice(i * CHUNK, CHUNK)
        # ids may be narrowed int16 storage (DESIGN.md §10); widen to int32
        # for the take / iota compare — Mosaic wants 32-bit indices
        rid = rid_ref[0, sl].astype(jnp.int32)       # (CHUNK,)
        cid = cid_ref[0, sl].astype(jnp.int32)
        val = val_ref[0, sl].astype(jnp.float32)
        g = jnp.take(bb, cid, axis=0).astype(jnp.float32) * val[:, None]
        p = (rid[:, None] == row_iota).astype(jnp.float32)   # (CHUNK, m_pad)
        # scatter-add as MXU contraction: acc[r] += Σ_i p[i, r] * g[i]
        return acc + jax.lax.dot_general(
            p, g, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    acc = jax.lax.fori_loop(
        0, chunks, body, jnp.zeros(c_ref.shape[1:], jnp.float32)
    )
    c_ref[0] = acc.astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def batched_spmm_coo(
    row_ids: jax.Array,   # (batch, nnz_pad) int32
    col_ids: jax.Array,   # (batch, nnz_pad) int32
    values: jax.Array,    # (batch, nnz_pad)
    b: jax.Array,         # (batch, m_pad, n_b)
    *,
    plan: BatchPlan,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    batch, nnz_pad = row_ids.shape
    m_pad, n_b = b.shape[1], b.shape[2]
    assert plan.batch == batch and plan.m_pad == m_pad and plan.n_b == n_b, plan
    if nnz_pad % CHUNK:
        pad = CHUNK - nnz_pad % CHUNK
        row_ids = jnp.pad(row_ids, ((0, 0), (0, pad)), constant_values=m_pad)
        col_ids = jnp.pad(col_ids, ((0, 0), (0, pad)))
        values = jnp.pad(values, ((0, 0), (0, pad)))
        nnz_pad += pad
    chunks = nnz_pad // CHUNK

    n_block, p = plan.n_block, plan.p
    if n_b % n_block:
        b = jnp.pad(b, ((0, 0), (0, 0), (0, p * n_block - n_b)))

    out = pl.pallas_call(
        functools.partial(_kernel, m_pad=m_pad, chunks=chunks),
        grid=(batch, p),
        in_specs=[
            pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, p * n_block), b.dtype),
        interpret=interpret,
    )(row_ids, col_ids, values, b)
    return out[..., :n_b]
