"""Batched COO/SparseTensor SpMM — the TPU adaptation of the paper's Batched
SWA-SpMM for SparseTensor (Fig. 3 + Fig. 5-(a)/(b)).

The GPU version splits work by *non-zero* and resolves output races with
``atomicAdd`` on shared memory. TPUs have no atomics; the adaptation
(DESIGN.md §2, "atomics → one-hot MXU scatter") is:

- non-zeros are processed in CHUNK-sized vector groups;
- the *gather* side (``B[cid]``) is a sublane-axis ``jnp.take``;
- the *scatter-add* side (``C[rid] += …``) becomes a one-hot matrix product
  ``P.T @ G`` where ``P[i, r] = (rid[i] == r)`` — a (chunk × m_pad)ᵀ ×
  (chunk × n_block) contraction that runs on the MXU. Races disappear because
  the reduction is a dot-product, not a read-modify-write.

Accumulation across chunks happens in a VMEM-resident f32 accumulator — the
shared-memory-resident output of Fig. 5-(a) — and the column-panel grid
dimension reproduces the cache blocking of Fig. 5-(b).

g-SpMM generalization (DESIGN.md §11): a static ``(op, reduce)`` pair turns
``C[rid] += val · B[cid]`` into ``C[rid] = reduce(op(B[cid], e))``. The
``(mul, sum)`` corner keeps the unmasked legacy path (padding values 0.0
are neutral); every other corner takes the per-matrix true non-zero count
``nnz`` (SMEM scalar) and masks slots ``i ≥ nnz`` explicitly. ``sum`` stays
a one-hot MXU contraction. ``max`` has no dot-product form — it runs a
one-hot *select*: each SUB-slot group of the chunk broadcasts its messages
against its one-hot row mask and folds with ``maximum`` (the (SUB, m_pad,
n_block) intermediate bounds the VMEM cost of a full-chunk broadcast).
``mean`` and the empty-row identity fix-up of ``max`` are applied by the
wrapper after the kernel (an XLA degree count — the kernel itself only
knows sum/max). Edge values may be scalars ``(batch, nnz_pad)`` or feature
vectors ``(batch, nnz_pad, d_e)`` with ``d_e == n_b``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.batching import CHUNK, BatchPlan
from repro.kernels import resolve_interpret

NEG_INF = -3.0e38   # finite stand-in for -inf (matches kernels/ref.py)
# one-hot-select group size for the max reduce: bounds the broadcast
# intermediate at (SUB, m_pad, n_block) f32 in VMEM per fold
_MAX_SUB = 8


def _kernel(*refs, m_pad: int, chunks: int, has_nnz: bool, op: str,
            reduce: str):
    refs = list(refs)
    nnz_ref = refs.pop(0) if has_nnz else None
    rid_ref, cid_ref, val_ref, b_ref, c_ref = refs
    bb = b_ref[0]                                    # (m_pad, n_block)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (CHUNK, m_pad), 1)

    def messages(i):
        sl = pl.dslice(i * CHUNK, CHUNK)
        # ids may be narrowed int16 storage (DESIGN.md §10); widen to int32
        # for the take / iota compare — Mosaic wants 32-bit indices
        rid = rid_ref[0, sl].astype(jnp.int32)       # (CHUNK,)
        cid = cid_ref[0, sl].astype(jnp.int32)
        g = jnp.take(bb, cid, axis=0).astype(jnp.float32)
        if op != "copy_lhs":
            val = val_ref[0, sl].astype(jnp.float32)  # (CHUNK[, n_block])
            if val.ndim == 1:
                val = val[:, None]
            g = g * val if op == "mul" else g + val
        valid = None
        if has_nnz:
            # explicit validity: the padding invariant (value 0.0 neutral)
            # only holds for (mul, sum)
            valid = (i * CHUNK + jax.lax.iota(jnp.int32, CHUNK)) < nnz_ref[0]
        return rid, g, valid

    def body_sum(i, acc):
        rid, g, valid = messages(i)
        if valid is not None:
            g = jnp.where(valid[:, None], g, 0.0)
        p = (rid[:, None] == row_iota).astype(jnp.float32)   # (CHUNK, m_pad)
        # scatter-add as MXU contraction: acc[r] += Σ_i p[i, r] * g[i]
        return acc + jax.lax.dot_general(
            p, g, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    def body_max(i, acc):
        rid, g, valid = messages(i)
        g = jnp.where(valid[:, None], g, NEG_INF)
        p = rid[:, None] == row_iota                         # (CHUNK, m_pad)
        # one-hot select: no dot-product form for max, so fold SUB slots at
        # a time — candidate[s, r, :] is message s where it targets row r
        for s in range(0, CHUNK, _MAX_SUB):
            cand = jnp.where(p[s:s + _MAX_SUB, :, None],
                             g[s:s + _MAX_SUB, None, :], NEG_INF)
            acc = jnp.maximum(acc, jnp.max(cand, axis=0))
        return acc

    init = NEG_INF if reduce == "max" else 0.0
    acc = jax.lax.fori_loop(
        0, chunks, body_max if reduce == "max" else body_sum,
        jnp.full(c_ref.shape[1:], init, jnp.float32)
    )
    c_ref[0] = acc.astype(c_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("plan", "interpret", "op", "reduce"))
def batched_spmm_coo(
    row_ids: jax.Array,   # (batch, nnz_pad) int32
    col_ids: jax.Array,   # (batch, nnz_pad) int32
    values: jax.Array,    # (batch, nnz_pad[, d_e])
    b: jax.Array,         # (batch, m_pad, n_b)
    *,
    plan: BatchPlan,
    nnz: jax.Array | None = None,     # (batch,) true nnz; g-SpMM masking
    op: str = "mul",
    reduce: str = "sum",
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    batch, nnz_pad = row_ids.shape
    m_pad, n_b = b.shape[1], b.shape[2]
    assert plan.batch == batch and plan.m_pad == m_pad and plan.n_b == n_b, plan
    if (op, reduce) != ("mul", "sum"):
        assert nnz is not None, \
            f"({op}, {reduce}) needs the per-matrix true nnz for masking"
    vec = values.ndim == 3
    if vec:
        assert values.shape[-1] == n_b, \
            f"vector edge features need d_e == n_b, got {values.shape[-1]}"
    if nnz_pad % CHUNK:
        pad = CHUNK - nnz_pad % CHUNK
        row_ids = jnp.pad(row_ids, ((0, 0), (0, pad)), constant_values=m_pad)
        col_ids = jnp.pad(col_ids, ((0, 0), (0, pad)))
        values = jnp.pad(values, ((0, 0), (0, pad)) + ((0, 0),) * vec)
        nnz_pad += pad
    chunks = nnz_pad // CHUNK

    n_block, p = plan.n_block, plan.p
    if n_b % n_block:
        pad = p * n_block - n_b
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)))
        if vec:
            values = jnp.pad(values, ((0, 0), (0, 0), (0, pad)))

    # the kernel reduces sum or max; mean = sum kernel + XLA degree scale,
    # and max needs the empty-row identity fix-up — both via the true
    # per-row degree, an XLA scatter-add over the (cheap) index arrays
    kernel_reduce = "sum" if reduce == "mean" else reduce
    val_spec = (
        pl.BlockSpec((1, nnz_pad, n_block), lambda i, j: (i, 0, j))
        if vec else pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)))
    in_specs = [
        pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)),
        pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)),
        val_spec,
        pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
    ]
    operands = [row_ids, col_ids, values, b]
    if nnz is not None:
        in_specs.insert(0, pl.BlockSpec((1,), lambda i, j: (i,),
                                        memory_space=pltpu.SMEM))
        operands.insert(0, nnz.astype(jnp.int32))

    out = pl.pallas_call(
        functools.partial(_kernel, m_pad=m_pad, chunks=chunks,
                          has_nnz=nnz is not None, op=op,
                          reduce=kernel_reduce),
        grid=(batch, p),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, p * n_block), b.dtype),
        interpret=interpret,
    )(*operands)
    out = out[..., :n_b]
    if reduce in ("mean", "max"):
        valid = (jnp.arange(nnz_pad)[None, :] < nnz[:, None]).astype(
            jnp.float32)
        deg = jax.vmap(
            lambda r, v: jnp.zeros((m_pad,), jnp.float32).at[
                jnp.clip(r.astype(jnp.int32), 0, m_pad - 1)].add(v)
        )(row_ids, valid)
        if reduce == "mean":
            out = out / jnp.maximum(deg, 1.0)[..., None].astype(out.dtype)
        else:
            out = jnp.where(deg[..., None] > 0, out,
                            jnp.zeros((), out.dtype))
    return out
