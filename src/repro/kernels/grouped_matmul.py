"""Ragged grouped matmul — the paper's Batched-SpMM idea applied to MoE
expert compute (DESIGN.md §4), as a Pallas TPU kernel.

Problem: ``out[i] = x[i] @ w[g[i]]`` for tokens sorted by group (expert),
with ragged group sizes — exactly the "batch of small matmuls with
different sizes" the paper batches into one kernel (its Fig. 10 mixed-size
case). The TPU formulation:

- tokens are pre-sorted by group; ``offsets[e]`` marks each group's start;
- grid = (m_tiles, n_tiles): one grid step computes a (tm × tn) output tile;
- each row tile belongs to ≥1 groups. For tile rows that straddle a group
  boundary we loop over the (few) groups intersecting the tile, select rows
  by a mask, and accumulate — the analogue of the paper's "redundant threads
  terminate immediately" padding policy, at tile granularity;
- weights stream through VMEM per (tile × group) via a dynamic gather on the
  stacked (E, K, N) weight array.

``ops-level`` helpers (`sort_by_group` / `unsort`) build the sorted layout
from top-k router output; `grouped_matmul` carries a custom VJP (transposed
ragged matmul for dx, one-hot-grouped einsum for dw), so it trains — the
R-GCN layer (`repro.models.gnn`) differentiates through it per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, gid_ref, out_ref, *, tm: int, max_groups_per_tile: int):
    it = pl.program_id(0)
    x = x_ref[...]                     # (tm, K)
    first = gid_ref[it, 0]             # first group intersecting this tile
    row_group = gid_ref[it, 1:1 + tm]  # (tm,) group of each row

    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for j in range(max_groups_per_tile):
        g = first + j
        w = jnp.take(w_ref[...], jnp.minimum(g, w_ref.shape[0] - 1), axis=0)
        part = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (tm, tn)
        mask = (row_group == g)[:, None]
        acc = jnp.where(mask, part, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


def _row_groups(group_sizes: jax.Array, m: int, e: int) -> jax.Array:
    """Per-row group id from ragged sizes (rows past the last boundary clamp
    to group ``e - 1``, matching the forward kernel)."""
    starts = jnp.cumsum(group_sizes)
    row_group = jnp.searchsorted(starts, jnp.arange(m), side="right")
    return jnp.minimum(row_group, e - 1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("tm", "tn", "max_groups_per_tile",
                                    "interpret"))
def _gmm(
    x: jax.Array,          # (M, K) rows sorted by group
    w: jax.Array,          # (E, K, N) stacked group weights
    group_sizes: jax.Array,  # (E,) int32, sum ≤ M (padding rows → group E-1+)
    *,
    tm: int = 128,
    tn: int = 128,
    max_groups_per_tile: int = 4,
    interpret: bool | None = None,
) -> jax.Array:
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    m, k = x.shape
    e, _, n = w.shape
    mp = -(-m // tm) * tm
    np_ = -(-n // tn) * tn
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, np_ - n)))
    # per-row group id from sizes (rows past the last boundary clamp to e-1)
    row_group = _row_groups(group_sizes, mp, e)
    n_tiles_m = mp // tm
    # per-tile metadata: [first_group, row groups…]
    tile_first = row_group.reshape(n_tiles_m, tm)[:, 0]
    meta = jnp.concatenate(
        [tile_first[:, None], row_group.reshape(n_tiles_m, tm)], axis=1)

    out = pl.pallas_call(
        functools.partial(_kernel, tm=tm,
                          max_groups_per_tile=max_groups_per_tile),
        grid=(n_tiles_m, np_ // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((e, k, tn), lambda i, j: (0, 0, j)),
            pl.BlockSpec((n_tiles_m, 1 + tm), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp, meta)
    return out[:m, :n]


def grouped_matmul(
    x: jax.Array,          # (M, K) rows sorted by group
    w: jax.Array,          # (E, K, N) stacked group weights
    group_sizes: jax.Array,  # (E,) int32, sum ≤ M (padding rows → group E-1+)
    *,
    tm: int = 128,
    tn: int = 128,
    max_groups_per_tile: int = 4,
    interpret: bool | None = None,
) -> jax.Array:
    """out[i] = x[i] @ w[group_of(i)] with rows pre-sorted by group.

    ``max_groups_per_tile`` bounds how many group boundaries may cross one
    row tile (static unroll); with capacity-style dispatch sizes it is ≤ 2.

    Differentiable in ``x`` and ``w`` via a custom VJP (``pallas_call`` has
    no autodiff rule): ``dx`` is the same ragged matmul against the
    transposed weights, and ``dw[g] = Σ_{i∈g} x[i]ᵀ · dout[i]`` is a
    one-hot-grouped einsum. Rows past ``sum(group_sizes)`` clamp to the last
    group in BOTH directions, matching the forward kernel exactly.
    """
    kw = dict(tm=tm, tn=tn, max_groups_per_tile=max_groups_per_tile,
              interpret=interpret)
    e = w.shape[0]

    # the custom_vjp is defined OUTSIDE any jit of our own (an inner jit
    # would leak closed-over tracers); group_sizes is closed over — it is
    # integer routing state, not a differentiable operand
    @jax.custom_vjp
    def f(x, w):
        return _gmm(x, w, group_sizes, **kw)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, dout):
        x, w = res
        dx = _gmm(dout, w.transpose(0, 2, 1), group_sizes, **kw)
        onehot = jax.nn.one_hot(_row_groups(group_sizes, x.shape[0], e), e,
                                dtype=jnp.float32)
        dw = jnp.einsum("me,mk,mn->ekn", onehot, x.astype(jnp.float32),
                        dout.astype(jnp.float32))
        return dx.astype(x.dtype), dw.astype(w.dtype)

    f.defvjp(fwd, bwd)
    return f(x, w)


def sort_by_group(eids: jax.Array, e: int):
    """Stable sort token-slots by expert. Returns (order, group_sizes)."""
    order = jnp.argsort(eids, stable=True)
    sizes = jnp.zeros((e,), jnp.int32).at[eids].add(1)
    return order, sizes
