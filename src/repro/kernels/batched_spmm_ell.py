"""Batched ELL SpMM — the TPU adaptation of the paper's Batched SWA-SpMM for
CSR (atomic-free row-split, Fig. 4 + Fig. 5-(c)/(d)).

Mapping (see DESIGN.md §2):
- one thread block per (matrix × column panel)  →  one grid step per
  (matrix × column panel): ``grid = (batch, p)``.
- subWarp threads striding over n_B columns     →  the 128-lane vector axis
  covers the column panel directly; rows sit on the sublane axis.
- shared-memory-resident output                 →  the output block lives in
  VMEM for the whole grid step; accumulation happens in registers/VMEM.
- CSR row loop ``for nzid in rpt[r]..rpt[r+1]`` →  dense ELL slot loop
  ``for k in range(k_pad)`` — the pad-to-max policy of §IV-C moved from
  "extra threads that terminate immediately" to "zero-valued slots".

The gather ``B[col_ids[:, k], :]`` is a sublane-axis dynamic gather
(``jnp.take``), which Mosaic supports; padded slots gather row 0 with weight
0.0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.batching import BatchPlan
from repro.kernels import resolve_interpret


def _kernel(*refs, k_pad: int, has_scale: bool):
    if has_scale:
        scale_ref, cid_ref, val_ref, b_ref, c_ref = refs
    else:
        cid_ref, val_ref, b_ref, c_ref = refs
        scale_ref = None
    # col ids may arrive as narrowed int16 storage (DESIGN.md §10); widen to
    # int32 before the gather — Mosaic requires 32-bit take indices
    cid = cid_ref[0].astype(jnp.int32)      # (m_pad, k_pad)
    val = val_ref[0]            # (m_pad, k_pad); f32/bf16 or int8 codes
    bb = b_ref[0]               # (m_pad, n_block)
    acc = jnp.zeros(c_ref.shape[1:], jnp.float32)
    for k in range(k_pad):      # static unroll; k_pad is small (nnz/row max)
        rows = jnp.take(bb, cid[:, k], axis=0)          # sublane gather
        acc = acc + val[:, k].astype(jnp.float32)[:, None] * rows.astype(
            jnp.float32
        )
    if has_scale:
        # int8 path: values are quantization codes; SpMM is linear in them,
        # so the per-matrix dequantization scale applies to the f32
        # accumulator exactly once, after the reduction.
        acc = acc * scale_ref[0]
    c_ref[0] = acc.astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def batched_spmm_ell(
    col_ids: jax.Array,   # (batch, m_pad, k_pad) int32 or int16
    values: jax.Array,    # (batch, m_pad, k_pad); int8 codes when scale given
    b: jax.Array,         # (batch, m_pad, n_b)
    *,
    plan: BatchPlan,
    scale: jax.Array | None = None,   # (batch,) f32 dequantization scale
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    batch, m_pad, k_pad = col_ids.shape
    n_b = b.shape[-1]
    assert plan.batch == batch and plan.m_pad == m_pad and plan.n_b == n_b, plan
    n_block, p = plan.n_block, plan.p
    if n_b % n_block:
        pad = p * n_block - n_b
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)))

    in_specs = [
        pl.BlockSpec((1, m_pad, k_pad), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, m_pad, k_pad), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
    ]
    operands = [col_ids, values, b]
    if scale is not None:
        in_specs.insert(0, pl.BlockSpec((1,), lambda i, j: (i,),
                                        memory_space=pltpu.SMEM))
        operands.insert(0, scale.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_kernel, k_pad=k_pad, has_scale=scale is not None),
        grid=(batch, p),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, p * n_block), b.dtype),
        interpret=interpret,
    )(*operands)
    return out[..., :n_b]
