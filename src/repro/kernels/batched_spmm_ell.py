"""Batched ELL SpMM — the TPU adaptation of the paper's Batched SWA-SpMM for
CSR (atomic-free row-split, Fig. 4 + Fig. 5-(c)/(d)).

Mapping (see DESIGN.md §2):
- one thread block per (matrix × column panel)  →  one grid step per
  (matrix × column panel): ``grid = (batch, p)``.
- subWarp threads striding over n_B columns     →  the 128-lane vector axis
  covers the column panel directly; rows sit on the sublane axis.
- shared-memory-resident output                 →  the output block lives in
  VMEM for the whole grid step; accumulation happens in registers/VMEM.
- CSR row loop ``for nzid in rpt[r]..rpt[r+1]`` →  dense ELL slot loop
  ``for k in range(k_pad)`` — the pad-to-max policy of §IV-C moved from
  "extra threads that terminate immediately" to "zero-valued slots".

The gather ``B[col_ids[:, k], :]`` is a sublane-axis dynamic gather
(``jnp.take``), which Mosaic supports; padded slots gather row 0 with weight
0.0.

g-SpMM generalization (DESIGN.md §11): a static ``(op, reduce)`` pair turns
the inner multiply-accumulate into ``reduce_k op(B[cid[:, k]], e_k)``. Any
corner other than ``(mul, sum)`` breaks the padding invariant (a zero-valued
slot is NOT neutral under ``add``/``copy_lhs``/``max``/``mean``), so those
paths take a per-row live-slot bound ``rlen`` and mask slot ``k`` with
``k < rlen`` — the same row-split masking the CSR kernel always does. Edge
values may be scalars ``(batch, m_pad, k_pad)`` or per-edge feature vectors
``(batch, m_pad, k_pad, d_e)`` with ``d_e == n_b`` (panel-blocked alongside
B). ``max`` accumulates from a finite -inf stand-in and rewrites empty rows
to the 0.0 identity; ``mean`` divides the sum by ``max(rlen, 1)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.batching import BatchPlan
from repro.kernels import resolve_interpret

NEG_INF = -3.0e38   # finite stand-in for -inf (matches kernels/ref.py)


def _kernel(*refs, k_pad: int, has_scale: bool, has_rlen: bool,
            op: str, reduce: str):
    refs = list(refs)
    scale_ref = refs.pop(0) if has_scale else None
    rlen_ref = refs.pop(0) if has_rlen else None
    cid_ref, val_ref, b_ref, c_ref = refs
    # col ids may arrive as narrowed int16 storage (DESIGN.md §10); widen to
    # int32 before the gather — Mosaic requires 32-bit take indices
    cid = cid_ref[0].astype(jnp.int32)      # (m_pad, k_pad)
    val = val_ref[0]    # (m_pad, k_pad[, n_block]); int8 codes when scaled
    bb = b_ref[0]               # (m_pad, n_block)
    rlen = rlen_ref[0] if has_rlen else None      # (m_pad,) int32 live bound
    init = NEG_INF if reduce == "max" else 0.0
    acc = jnp.full(c_ref.shape[1:], init, jnp.float32)
    for k in range(k_pad):      # static unroll; k_pad is small (nnz/row max)
        rows = jnp.take(bb, cid[:, k], axis=0).astype(jnp.float32)
        if op == "copy_lhs":
            msg = rows
        else:
            e = val[:, k].astype(jnp.float32)     # (m_pad,) or (m_pad, n_blk)
            if e.ndim == 1:
                e = e[:, None]
            msg = rows * e if op == "mul" else rows + e
        if not has_rlen:
            # (mul, sum) fast path: padded slots carry value 0.0 and are
            # already neutral — the legacy SpMM inner loop, unmasked
            acc = acc + msg
        else:
            live = (k < rlen)[:, None]
            if reduce == "max":
                acc = jnp.maximum(acc, jnp.where(live, msg, NEG_INF))
            else:
                acc = acc + jnp.where(live, msg, 0.0)
    if has_rlen:
        if reduce == "max":
            acc = jnp.where((rlen > 0)[:, None], acc, 0.0)
        elif reduce == "mean":
            acc = acc / jnp.maximum(rlen, 1).astype(jnp.float32)[:, None]
    if has_scale:
        # int8 path: values are quantization codes; SpMM is linear in them,
        # so the per-matrix dequantization scale applies to the f32
        # accumulator exactly once, after the reduction.
        acc = acc * scale_ref[0]
    c_ref[0] = acc.astype(c_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("plan", "interpret", "op", "reduce"))
def batched_spmm_ell(
    col_ids: jax.Array,   # (batch, m_pad, k_pad) int32 or int16
    values: jax.Array,    # (batch, m_pad, k_pad[, d_e]); int8 when scaled
    b: jax.Array,         # (batch, m_pad, n_b)
    *,
    plan: BatchPlan,
    scale: jax.Array | None = None,   # (batch,) f32 dequantization scale
    rlen: jax.Array | None = None,    # (batch, m_pad) int32 live-slot bound
    op: str = "mul",
    reduce: str = "sum",
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    batch, m_pad, k_pad = col_ids.shape
    n_b = b.shape[-1]
    assert plan.batch == batch and plan.m_pad == m_pad and plan.n_b == n_b, plan
    if (op, reduce) != ("mul", "sum"):
        assert rlen is not None, \
            f"({op}, {reduce}) needs the per-row live bound rlen"
        assert scale is None, "precision variants are (mul, sum)-only"
    vec = values.ndim == 4
    if vec:
        assert values.shape[-1] == n_b, \
            f"vector edge features need d_e == n_b, got {values.shape[-1]}"
    n_block, p = plan.n_block, plan.p
    if n_b % n_block:
        pad = p * n_block - n_b
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)))
        if vec:
            values = jnp.pad(values, ((0, 0), (0, 0), (0, 0), (0, pad)))

    val_spec = (
        pl.BlockSpec((1, m_pad, k_pad, n_block), lambda i, j: (i, 0, 0, j))
        if vec else
        pl.BlockSpec((1, m_pad, k_pad), lambda i, j: (i, 0, 0)))
    in_specs = [
        pl.BlockSpec((1, m_pad, k_pad), lambda i, j: (i, 0, 0)),
        val_spec,
        pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
    ]
    operands = [col_ids, values, b]
    if rlen is not None:
        in_specs.insert(0, pl.BlockSpec((1, m_pad), lambda i, j: (i, 0)))
        operands.insert(0, rlen.astype(jnp.int32))
    if scale is not None:
        in_specs.insert(0, pl.BlockSpec((1,), lambda i, j: (i,),
                                        memory_space=pltpu.SMEM))
        operands.insert(0, scale.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_kernel, k_pad=k_pad, has_scale=scale is not None,
                          has_rlen=rlen is not None, op=op, reduce=reduce),
        grid=(batch, p),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, p * n_block), b.dtype),
        interpret=interpret,
    )(*operands)
    return out[..., :n_b]
