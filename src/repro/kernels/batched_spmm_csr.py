"""Batched CSR SpMM — the paper's reference layout (Fig. 1/4, §IV-B) as a
TPU row-split kernel, in the GE-SpMM (arXiv:2007.03179) coalesced
row-segment style.

The ELL kernel (`batched_spmm_ell.py`) approximates the paper's SWA-CSR by
padding every row to the BATCH max degree ``k_pad`` — every matrix pays
``m_pad · k_pad`` slots of bandwidth and arithmetic even when only one row is
that long. This kernel keeps the CSR arrays flat and bounds the inner loop
per MATRIX:

- the slot loop runs ``max(rpt[r+1] - rpt[r])`` iterations for THIS matrix —
  a dynamic trip count read from SMEM (`jax.lax.fori_loop` with a traced
  bound, the same skew-aware idiom as ``fused_graph_conv.py``), so a batch
  mixing one dense matrix with many sparse ones stops early on the sparse
  ones;
- at slot ``k`` every row gathers its ``rpt[r] + k``-th non-zero from the
  flat ``col_ids``/``values`` arrays (a sublane-axis ``jnp.take``) and masks
  rows whose degree is ≤ k (``k < rpt[r+1] - rpt[r]``) — short rows "stop
  early" by contributing 0.0, the CSR row loop of Fig. 4 vectorized across
  the sublane axis;
- the gathered B rows multiply-accumulate into the VMEM-resident output
  panel, one grid step per (matrix × column panel) exactly like the §2
  kernels (`grid = (batch, p)`, blocking from the §3 planner).

Row-split means each output row is owned by one reduction — no atomics, no
races — and the flat nnz arrays mean HBM traffic scales with ``nnz_pad``
(the real non-zero count, padded to 8) instead of ``m_pad · k_pad``.

``rpt`` enters as host-precomputed ``start = rpt[:, :-1]`` / ``rlen =
diff(rpt)`` panels (cheap XLA slices) so the kernel never indexes the
unaligned ``(m_pad + 1,)`` pointer array.

g-SpMM generalization (DESIGN.md §11): a static ``(op, reduce)`` pair turns
the masked multiply-accumulate into ``reduce_k op(B[cid], e)``. The CSR row
loop already owns the per-row validity mask (``k < rlen``), so the matrix
extends for free: ``max`` accumulates from a finite -inf stand-in (masked
slots contribute the sentinel, empty rows are rewritten to the 0.0
identity), ``mean`` divides the masked sum by ``max(rlen, 1)``. Edge values
may be flat scalars ``(batch, nnz_pad)`` or per-edge feature vectors
``(batch, nnz_pad, d_e)`` with ``d_e == n_b``, panel-blocked like B.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.batching import BatchPlan
from repro.kernels import resolve_interpret


NEG_INF = -3.0e38   # finite stand-in for -inf (matches kernels/ref.py)


def _kernel(*refs, has_scale: bool, op: str, reduce: str):
    if has_scale:
        (scale_ref, rowmax_ref, start_ref, rlen_ref, cid_ref, val_ref, b_ref,
         c_ref) = refs
    else:
        rowmax_ref, start_ref, rlen_ref, cid_ref, val_ref, b_ref, c_ref = refs
        scale_ref = None
    start = start_ref[0]                     # (m_pad,) int32 = rpt[:-1]
    rlen = rlen_ref[0]                       # (m_pad,) int32 = diff(rpt)
    # col ids may be narrowed int16 storage (DESIGN.md §10); widen to int32
    # before the B gather — Mosaic requires 32-bit take indices
    cid = cid_ref[0]                         # (nnz_pad,) int32/int16, flat
    val = val_ref[0]                         # (nnz_pad[, n_block]), flat
    bb = b_ref[0]                            # (m_pad, n_block)
    nnz_pad = cid.shape[0]

    def body(k, acc):
        # row r's k-th non-zero sits at flat slot rpt[r] + k; rows shorter
        # than k are masked (the row-split validity the g-SpMM corners need)
        idx = jnp.minimum(start + k, nnz_pad - 1)
        live = (k < rlen)[:, None]                       # (m_pad, 1) bool
        c = jnp.take(cid, idx, axis=0).astype(jnp.int32)
        rows = jnp.take(bb, c, axis=0).astype(jnp.float32)  # sublane gather
        if op == "copy_lhs":
            msg = rows
        else:
            e = jnp.take(val, idx, axis=0).astype(jnp.float32)
            if e.ndim == 1:
                e = e[:, None]
            msg = rows * e if op == "mul" else rows + e
        if reduce == "max":
            return jnp.maximum(acc, jnp.where(live, msg, NEG_INF))
        return acc + jnp.where(live, msg, 0.0)

    # rpt-bounded dynamic trip count: THIS matrix's max row degree, from SMEM
    init = NEG_INF if reduce == "max" else 0.0
    acc = jax.lax.fori_loop(
        0, rowmax_ref[0], body, jnp.full(c_ref.shape[1:], init, jnp.float32)
    )
    if reduce == "max":
        acc = jnp.where((rlen > 0)[:, None], acc, 0.0)
    elif reduce == "mean":
        acc = acc / jnp.maximum(rlen, 1).astype(jnp.float32)[:, None]
    if has_scale:
        # int8 path: values are quantization codes; the reduction is linear
        # in them, so the per-matrix dequantization scale applies once to the
        # f32 accumulator.
        acc = acc * scale_ref[0]
    c_ref[0] = acc.astype(c_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("plan", "interpret", "op", "reduce"))
def batched_spmm_csr(
    rpt: jax.Array,       # (batch, m_pad + 1) int32
    col_ids: jax.Array,   # (batch, nnz_pad) int32/int16, row-sorted
    values: jax.Array,    # (batch, nnz_pad[, d_e]); int8 when scale given
    b: jax.Array,         # (batch, m_pad, n_b)
    *,
    plan: BatchPlan,
    scale: jax.Array | None = None,   # (batch,) f32 dequantization scale
    op: str = "mul",
    reduce: str = "sum",
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    batch, m_pad = rpt.shape[0], rpt.shape[1] - 1
    nnz_pad = col_ids.shape[1]
    n_b = b.shape[-1]
    assert plan.batch == batch and plan.m_pad == m_pad and plan.n_b == n_b, plan
    if (op, reduce) != ("mul", "sum"):
        assert scale is None, "precision variants are (mul, sum)-only"
    vec = values.ndim == 3
    if vec:
        assert values.shape[-1] == n_b, \
            f"vector edge features need d_e == n_b, got {values.shape[-1]}"

    start = rpt[:, :-1]
    rlen = rpt[:, 1:] - rpt[:, :-1]
    rowmax = jnp.max(rlen, axis=1).astype(jnp.int32)     # (batch,) loop bound

    n_block, p = plan.n_block, plan.p
    if n_b % n_block:
        pad = p * n_block - n_b
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad)))
        if vec:
            values = jnp.pad(values, ((0, 0), (0, 0), (0, pad)))

    val_spec = (
        pl.BlockSpec((1, nnz_pad, n_block), lambda i, j: (i, 0, j))
        if vec else pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)))
    in_specs = [
        pl.BlockSpec((1,), lambda i, j: (i,), memory_space=pltpu.SMEM),
        pl.BlockSpec((1, m_pad), lambda i, j: (i, 0)),
        pl.BlockSpec((1, m_pad), lambda i, j: (i, 0)),
        pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)),
        val_spec,
        pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
    ]
    operands = [rowmax, start, rlen, col_ids, values, b]
    if scale is not None:
        in_specs.insert(0, pl.BlockSpec((1,), lambda i, j: (i,),
                                        memory_space=pltpu.SMEM))
        operands.insert(0, scale.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_kernel, has_scale=scale is not None,
                          op=op, reduce=reduce),
        grid=(batch, p),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, p * n_block), b.dtype),
        interpret=interpret,
    )(*operands)
    return out[..., :n_b]
