"""Public, differentiable wrappers around the batched kernels.

The app-level contract mirrors the paper's TensorFlow integration (§IV-D):
adjacency matrices arrive as SparseTensor-style COO batches; one call executes
the whole batch. ``impl`` selects an entry from the registry table appended
below — the table is GENERATED from :data:`IMPLS` at import time so it can
never drift from the registry again (every registered impl must carry a
description, asserted by tests).

The VJP follows the paper's backward-pass batching: dB = batched-SpMM with Aᵀ
(index swap — free in COO), and dValues is a batched gather-dot. Both run as
single batched ops.

g-SpMM (DESIGN.md §11): :func:`batched_gspmm` generalizes the inner
``C[rid] += val · B[cid]`` into message passing ``C[r] = reduce(op(B[c], e))``
with a static ``(op, reduce)`` pair — ``op ∈`` :data:`GSPMM_OPS`, ``reduce ∈``
:data:`GSPMM_REDUCES` — and edge values that may be per-edge feature VECTORS
``(batch, nnz_pad, d_e)``. The ``(mul, sum)`` corner with scalar edges IS
plain batched SpMM and delegates to :func:`batched_spmm` (full registry,
precision variants included); every other corner runs the f32 g-SpMM-capable
subset (``autotune.GSPMM_IMPLS``) with explicit padding masks.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.autotune.cost_model import (
    GSPMM_IMPLS,
    PRECISION_IMPLS,
    precision_of,
    supports_gspmm,
)
from repro.observability import trace as obs_trace
from repro.core import batching
from repro.core.formats import (
    BatchedCOO,
    BatchedCSR,
    coo_to_csr,
    coo_to_dense,
    coo_to_ell,
    narrow_col_ids,
    quantize_values_i8,
    row_degrees,
    validate_ell_k_pad,
)
from repro.kernels import ref, resolve_interpret
from repro.kernels.batched_gemm import batched_gemm
from repro.kernels.batched_spmm_coo import batched_spmm_coo
from repro.kernels.batched_spmm_csr import batched_spmm_csr
from repro.kernels.batched_spmm_ell import batched_spmm_ell
from repro.kernels.batched_spmm_hybrid import (
    batched_spmm_hybrid,
    batched_spmm_hybrid_xla,
)

# "fused" is the graph-conv layer megakernel (kernels/fused_graph_conv.py):
# it is selectable wherever a layer-level workload is being resolved
# (graph_conv_batched / resolve_graph_conv_impl), but is NOT a plain SpMM —
# batched_spmm(impl="fused") raises with a pointer to the layer entry point.
# The reduced-precision variants (…_bf16 / …_i8, DESIGN.md §10) are distinct
# registry entries: each runs its base impl's execution structure with a
# cheaper storage policy and an f32 accumulator.
IMPLS = ("auto", "ref", "ell", "pallas_ell", "csr", "pallas_csr",
         "pallas_coo", "hybrid", "pallas_hybrid", "dense", "pallas_gemm",
         "loop", "fused", "fused_hybrid") + tuple(PRECISION_IMPLS)

# The static g-SpMM axes (DESIGN.md §11). ``copy_lhs`` ignores the edge
# value entirely (pure neighborhood aggregation, e.g. R-GCN's mean).
GSPMM_OPS = ("mul", "add", "copy_lhs")
GSPMM_REDUCES = ("sum", "max", "mean")

# One description per BASE impl; precision variants derive theirs from
# (base, policy) so adding a variant never needs a new entry here.
_IMPL_NOTES = {
    "auto": "shape-keyed adaptive dispatch: the paper's §IV-B/§IV-C "
            "resource-assignment policy extended into a which-kernel "
            "decision by repro.autotune (cost model + optional measured "
            "tuning cache, DESIGN.md §5); trace-time, jit-safe",
    "ref": "pure-jnp batched oracle (scatter-add), XLA-fused",
    "ell": "pure-XLA ELL row-split (gather + contraction): the batched "
           "single-op semantics without the Pallas kernel",
    "pallas_ell": "Batched SWA-CSR analogue (row-split ELL Pallas kernel)",
    "csr": "pure-XLA CSR segment-sum reference (same conversion, "
           "searchsorted row recovery + scatter-add)",
    "pallas_csr": "Batched CSR row-split (GE-SpMM style: flat nnz arrays, "
                  "rpt-bounded dynamic slot loop — DESIGN.md §9)",
    "pallas_coo": "Batched SWA-SparseTensor analogue (one-hot-scatter "
                  "kernel)",
    "hybrid": "pure-XLA degree-split hybrid: dense hub-row slab GEMM + "
              "ELL remainder bounded by the hub threshold (the "
              "HC-SpMM-style routing without the Pallas kernel)",
    "pallas_hybrid": "degree-binned hybrid row dispatch: MXU-dense hub "
                     "tiles + rpt-bounded CSR remainder over sorted work "
                     "bins, inverse row permutation fused into the "
                     "epilogue (DESIGN.md §12)",
    "dense": "densify + batched GEMM (the cuBLAS gemmBatched baseline)",
    "pallas_gemm": "densify + MXU Pallas batched GEMM",
    "loop": "the NON-batched baseline: one sequential SpMM per sample, "
            "reproducing the paper's per-sample-kernel-launch structure",
    "fused": "graph-conv LAYER megakernel (needs W and bias; raises here — "
             "use graph_conv_batched, DESIGN.md §7)",
    "fused_hybrid": "graph-conv LAYER megakernel with degree-binned hybrid "
                    "dispatch: per-channel dense hub slabs + compacted COO "
                    "scatter chunks (needs W and bias; raises here — use "
                    "graph_conv_batched, DESIGN.md §12)",
}
_POLICY_NOTES = {
    "bf16": "bfloat16 storage, f32 in-kernel accumulate (DESIGN.md §10)",
    "i8": "int8 value codes + per-matrix f32 dequantization scale "
          "(DESIGN.md §10)",
}


def _impl_table() -> str:
    """Render the registry table appended to this module's docstring —
    derived from :data:`IMPLS` so docs cannot drift from the registry."""
    lines = []
    for name in IMPLS:
        base, policy = precision_of(name)
        note = (_IMPL_NOTES[base] if policy == "f32"
                else f"{base!r} execution with {_POLICY_NOTES[policy]}")
        lines.append(f"- ``{name!r}``: {note}")
    return "Registered ``impl`` values:\n\n" + "\n".join(lines)


__doc__ = (__doc__ or "") + "\n" + _impl_table() + "\n"


def resolve_impl(
    a: BatchedCOO,
    b: jax.Array,
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    precision: str = "f32",
):
    """Resolve ``impl="auto"`` to the concrete impl for this call's shapes.

    Returns an ``repro.autotune.Decision`` (``.impl`` is the concrete
    string); a concrete ``impl`` passes through as a forced Decision so
    callers can introspect either path uniformly. ``precision`` is the
    caller's dtype policy (``"f32"``/``"bf16"``/``"i8"``): under
    ``impl="auto"`` it admits the matching reduced-precision variants to the
    ranking; a concrete impl carries its own policy and ignores it.
    """
    from repro import autotune

    interpret = resolve_interpret(interpret)
    batch, m_pad, n_b = b.shape
    if impl != "auto":
        w = autotune.Workload(batch=batch, m_pad=m_pad,
                              nnz_pad=a.row_ids.shape[1], k_pad=k_pad,
                              n_b=n_b, itemsize=b.dtype.itemsize,
                              dtype=precision_of(impl)[1])
        return autotune.forced_decision(w, impl)
    return autotune.resolve_auto(
        batch=batch, m_pad=m_pad, nnz_pad=a.row_ids.shape[1], k_pad=k_pad,
        n_b=n_b, itemsize=b.dtype.itemsize, interpret=interpret,
        dtype=precision)


def resolve_compute_dtype(a_dtype, b_dtype):
    """The deliberate mixed-dtype policy of the GEMM-class impls (DESIGN.md
    §10): compute in the PROMOTED dtype of the two operands so a
    full-precision operand is never silently downcast. Same lattice the
    precision variants use — bf16 meets f32 at f32."""
    return jnp.promote_types(a_dtype, b_dtype)


def _csr_forward(csr: BatchedCSR, b, *, impl, interpret, scale=None,
                 narrow=False):
    """Run a CSR-class impl on an already-converted :class:`BatchedCSR` —
    shared by the forward (COO→CSR) and the backward (``csr_transpose``).

    ``scale`` is the i8 policy's per-matrix dequantization factor (applied to
    the f32 accumulator — in-kernel on the Pallas path, post-hoc on the XLA
    fallbacks); ``narrow`` stores column ids as int16 on the Pallas wire."""
    if impl == "csr":
        out = ref.batched_spmm_csr_ref(csr, b)
        return out if scale is None else out * scale[:, None, None]
    plan = batching.plan_batched_spmm(
        batch=csr.batch, m_pad=csr.m_pad, n_b=b.shape[-1],
        slots=csr.nnz_pad, itemsize=b.dtype.itemsize)
    if plan.case == 3:
        # Paper case 3: matrices too large for the batched strategy — same
        # per-sample fallback as the COO/ELL kernels.
        out = ref.batched_spmm_csr_ref(csr, b)
        return out if scale is None else out * scale[:, None, None]
    cids = narrow_col_ids(csr.col_ids, csr.m_pad) if narrow else csr.col_ids
    return batched_spmm_csr(csr.rpt, cids, csr.values, b,
                            plan=plan, scale=scale, interpret=interpret)


def _forward(row_ids, col_ids, nnz, values, b, *, impl, k_pad, interpret,
             op="mul", reduce="sum"):
    """Dispatch one batched SpMM forward. A precision variant (DESIGN.md §10)
    decomposes into (base impl, storage policy): bf16 casts values and the
    dense operand to bfloat16 (f32 accumulate in-kernel, output cast back to
    the caller's dtype); i8 quantizes values to int8 codes with a per-matrix
    f32 scale applied once to the accumulator (exact, by linearity) while the
    dense operand stays full-precision. Both narrow the Pallas-side index
    storage to int16 behind :func:`repro.core.formats.narrow_col_ids`'s
    host-side overflow guard.

    A non-default ``(op, reduce)`` or 3D (vector-edge) ``values`` routes to
    the g-SpMM dispatch (:func:`_gspmm_forward`): f32-only, explicit padding
    masks, restricted to ``autotune.GSPMM_IMPLS``."""
    if (op, reduce) != ("mul", "sum") or values.ndim == 3:
        if not supports_gspmm(impl):
            raise ValueError(
                f"impl {impl!r} cannot run g-SpMM (op={op!r}, "
                f"reduce={reduce!r}, values.ndim={values.ndim}); the capable "
                f"set is {GSPMM_IMPLS} at f32")
        return _gspmm_forward(row_ids, col_ids, nnz, values, b,
                              impl=precision_of(impl)[0], k_pad=k_pad,
                              interpret=interpret, op=op, reduce=reduce)
    base, policy = precision_of(impl)
    out_dtype = b.dtype
    scale = None
    if policy == "bf16":
        values = values.astype(jnp.bfloat16)
        b = b.astype(jnp.bfloat16)
    elif policy == "i8":
        values, scale = quantize_values_i8(values)
    out = _forward_base(row_ids, col_ids, nnz, values, b, impl=impl,
                        base=base, k_pad=k_pad, interpret=interpret,
                        scale=scale, narrow=policy != "f32")
    # Reduced policies restore the caller's dtype; the f32 path returns the
    # branch's own result dtype (the GEMM class may deliberately PROMOTE on
    # mixed-dtype inputs — see resolve_compute_dtype).
    return out if policy == "f32" else out.astype(out_dtype)


def _forward_base(row_ids, col_ids, nnz, values, b, *, impl, base, k_pad,
                  interpret, scale, narrow):
    batch, m_pad, n_b = b.shape
    a = BatchedCOO(row_ids, col_ids, values, nnz, jnp.full((batch,), m_pad))

    def dequant(out):
        # XLA fallback for the i8 policy: the kernel-side accumulator scale,
        # applied after the (linear) unscaled SpMM of the codes
        return out if scale is None else out * scale[:, None, None]

    if base == "ref":
        return dequant(ref.batched_spmm_coo_ref(a, b, m_pad))
    if base == "loop":
        # Non-batched baseline: sequential per-sample SpMM (paper Fig. 2 / the
        # "TF" bars in Fig. 8). Structured as a scan so each sample is its own
        # sequential step, like one kernel launch per sample.
        def step(_, args):
            r, c, v, bb = args
            return None, ref.spmm_coo_single(r, c, v, bb, m_pad)

        _, out = jax.lax.scan(step, None, (row_ids, col_ids, values, b))
        return out
    if base in ("dense", "pallas_gemm"):
        a_dense = coo_to_dense(a, m_pad)
        # Deliberate mixed-dtype resolution (not a silent downcast to
        # b.dtype): both operands meet at the promoted dtype, so e.g. f32
        # adjacency values × bf16 features compute — and return — f32.
        compute = resolve_compute_dtype(a_dense.dtype, b.dtype)
        a_dense, bb = a_dense.astype(compute), b.astype(compute)
        if base == "dense":
            return ref.batched_gemm_ref(a_dense, bb)
        plan = batching.plan_batched_gemm(
            batch=batch, m=m_pad, n=n_b, k=m_pad, itemsize=bb.dtype.itemsize
        )
        return batched_gemm(a_dense, bb, plan=plan, interpret=interpret)
    if base in ("csr", "pallas_csr"):
        return _csr_forward(coo_to_csr(a, m_pad), b, impl=base,
                            interpret=interpret, scale=scale, narrow=narrow)
    if base in ("hybrid", "pallas_hybrid"):
        assert scale is None, "hybrid has no i8 variant"
        hplan = batching.plan_hybrid(
            batch=batch, m_pad=m_pad, n_b=n_b, nnz_pad=row_ids.shape[1],
            itemsize=b.dtype.itemsize)
        if base == "hybrid":
            return batched_spmm_hybrid_xla(a, b, m_pad, plan=hplan)
        if hplan.spmm.case == 3:
            # Paper case 3: same per-sample fallback as the other kernels.
            return ref.batched_spmm_coo_ref(a, b, m_pad)
        return batched_spmm_hybrid(row_ids, col_ids, values, nnz, b,
                                   plan=hplan, narrow=narrow,
                                   interpret=interpret)
    if base in ("pallas_ell", "ell"):
        if k_pad is None:
            raise ValueError(f"{impl} requires k_pad (max nnz/row)")
        # Silent-drop guard: coo_to_ell zeroes any nnz beyond k_pad in a row.
        # Eager (concrete) calls raise host-side here; traced calls cannot
        # branch on data and skip (callers own k_pad sizing under jit —
        # coo_to_ell(check=True) installs a runtime debug-assert instead).
        validate_ell_k_pad(a, m_pad, k_pad)
    plan = batching.plan_batched_spmm(
        batch=batch, m_pad=m_pad, n_b=n_b,
        slots=k_pad if base == "pallas_ell" else row_ids.shape[1],
        itemsize=b.dtype.itemsize,
    )
    if plan.case == 3:
        # Paper case 3: matrices too large for the batched shared-memory
        # strategy — take the per-sample path.
        return dequant(ref.batched_spmm_coo_ref(a, b, m_pad))
    if base in ("pallas_ell", "ell"):
        ell = coo_to_ell(a, m_pad, k_pad)
        if base == "ell":
            # pure-XLA batched row-split (gather + contraction): the batched
            # single-op semantics without the Pallas kernel
            return dequant(ref.batched_spmm_ell_ref(ell, b))
        cids = narrow_col_ids(ell.col_ids, m_pad) if narrow else ell.col_ids
        return batched_spmm_ell(cids, ell.values, b, plan=plan,
                                scale=scale, interpret=interpret)
    if base == "pallas_coo":
        rids, cids = row_ids, col_ids
        if narrow:
            rids = narrow_col_ids(rids, m_pad)
            cids = narrow_col_ids(cids, m_pad)
        return batched_spmm_coo(rids, cids, values, b, plan=plan,
                                interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")


def _gspmm_forward(row_ids, col_ids, nnz, values, b, *, impl, k_pad,
                   interpret, op, reduce):
    """Dispatch one batched g-SpMM forward over the capable impl subset.

    Every path masks padding EXPLICITLY from the true per-matrix ``nnz`` /
    per-row degree: the §IV-C padding invariant (value 0.0 is neutral) only
    holds for ``(mul, sum)``. The paper's case-3 guard falls back to the
    batched pure-jnp oracle, like the plain-SpMM branches."""
    batch, m_pad, n_b = b.shape
    a = BatchedCOO(row_ids, col_ids, values, nnz, jnp.full((batch,), m_pad))
    if impl == "ref":
        return ref.batched_gspmm_ref(a, b, m_pad, op=op, reduce=reduce)
    if impl == "loop":
        # Non-batched baseline: sequential per-sample g-SpMM (scan), the
        # per-sample-kernel-launch structure of the paper's "TF" bars.
        def step(_, args):
            r, c, v, n, bb = args
            return None, ref.gspmm_coo_single(r, c, v, bb, m_pad, n,
                                              op=op, reduce=reduce)

        _, out = jax.lax.scan(step, None, (row_ids, col_ids, values, nnz, b))
        return out

    def fallback():
        return ref.batched_gspmm_ref(a, b, m_pad, op=op, reduce=reduce)

    if impl in ("csr", "pallas_csr"):
        csr = coo_to_csr(a, m_pad)
        if impl == "csr":
            return ref.batched_gspmm_csr_ref(csr, b, op=op, reduce=reduce)
        plan = batching.plan_batched_spmm(
            batch=batch, m_pad=m_pad, n_b=n_b, slots=csr.nnz_pad,
            itemsize=b.dtype.itemsize)
        if plan.case == 3:
            return fallback()
        return batched_spmm_csr(csr.rpt, csr.col_ids, csr.values, b,
                                plan=plan, op=op, reduce=reduce,
                                interpret=interpret)
    if impl in ("ell", "pallas_ell"):
        if k_pad is None:
            raise ValueError(f"{impl} requires k_pad (max nnz/row)")
        validate_ell_k_pad(a, m_pad, k_pad)
        # the ELL layout cannot distinguish a real zero-valued edge from a
        # padded slot, so the per-row live bound travels beside it
        rlen = row_degrees(a, m_pad)
        ell = coo_to_ell(a, m_pad, k_pad)
        if impl == "ell":
            return ref.batched_gspmm_ell_ref(ell, rlen, b,
                                             op=op, reduce=reduce)
        plan = batching.plan_batched_spmm(
            batch=batch, m_pad=m_pad, n_b=n_b, slots=k_pad,
            itemsize=b.dtype.itemsize)
        if plan.case == 3:
            return fallback()
        return batched_spmm_ell(ell.col_ids, ell.values, b, plan=plan,
                                rlen=rlen, op=op, reduce=reduce,
                                interpret=interpret)
    if impl == "pallas_coo":
        plan = batching.plan_batched_spmm(
            batch=batch, m_pad=m_pad, n_b=n_b, slots=row_ids.shape[1],
            itemsize=b.dtype.itemsize)
        if plan.case == 3:
            return fallback()
        return batched_spmm_coo(row_ids, col_ids, values, b, plan=plan,
                                nnz=nnz, op=op, reduce=reduce,
                                interpret=interpret)
    raise ValueError(
        f"unknown g-SpMM impl {impl!r}; expected one of {GSPMM_IMPLS}")


def _traced_dispatch(f, values, b, *, impl, decision, workload):
    """Run one dispatch under a telemetry span (DESIGN.md §13).

    Only reached when ``observability.enabled()`` — the hot path pays a
    single predicate otherwise. The span carries the workload geometry, the
    auto-decision provenance, and the cost model's *predicted* seconds and
    minimum HBM bytes, so a trace viewer (and the regret auditor) can line
    predicted up against measured. Eager (non-traced) dispatches also feed
    the default regret auditor's online calibration stream; traced calls
    record the span (trace-time wall) but skip the auditor — a trace is not
    an execution.
    """
    from repro.autotune.cost_model import estimate
    from repro.observability.regret import default_auditor

    pred = dict(decision.scores).get(impl) if decision is not None else None
    if pred is None:
        try:
            pred = estimate(workload, impl)
        except ValueError:
            pred = None
        if pred == float("inf"):
            pred = None
    it = workload.itemsize
    # impl-independent floor: value+index slots once, B and C once each
    pred_bytes = (workload.batch * workload.nnz_pad * (it + 8)
                  + 2 * workload.batch * workload.m_pad * workload.n_b * it)
    args = {
        "impl": impl, "key": workload.key(), "batch": workload.batch,
        "m_pad": workload.m_pad, "nnz_pad": workload.nnz_pad,
        "k_pad": workload.k_pad, "n_b": workload.n_b,
        "dtype": workload.dtype, "op": workload.op,
        "reduce": workload.reduce, "predicted_s": pred,
        "predicted_bytes": pred_bytes,
    }
    if decision is not None:
        args["source"] = decision.source
        args["case"] = decision.case
    eager = not isinstance(values, jax.core.Tracer)
    t0 = time.perf_counter()
    with obs_trace.TRACER.span(f"spmm/{impl}", cat="kernel", args=args):
        out = f(values, b)
    if eager and pred is not None:
        default_auditor().record(workload.key(), impl, predicted_s=pred,
                                 measured_s=time.perf_counter() - t0)
    return out


_VARIANT_BWD = {
    # bf16 forwards keep a bf16-class backward (grads accumulate f32
    # in-kernel, cast on the way out); ELL-class forwards fall to the COO
    # class like their f32 bases. i8 forwards take a FULL-PRECISION
    # straight-through backward: the VJP residuals hold the original f32
    # values (quantization happens inside _forward), so dB is computed
    # against the unquantized operator — the class mapping of the f32 base.
    "ell_bf16": "ref",
    "csr_bf16": "csr_bf16",
    "pallas_ell_bf16": "pallas_coo_bf16",
    "pallas_csr_bf16": "pallas_csr_bf16",
    "pallas_coo_bf16": "pallas_coo_bf16",
    "pallas_ell_i8": "pallas_coo",
    "pallas_csr_i8": "pallas_csr",
    "fused_bf16": "pallas_coo_bf16",
    "pallas_hybrid_bf16": "pallas_csr_bf16",
}


def bwd_impl_for(impl: str) -> str:
    """The impl the backward pass (dB = Aᵀ @ dC) runs for a forward ``impl``.

    Aᵀ loses the per-row ELL bound, so ELL-class forwards fall back to the
    COO/scatter class; CSR-class forwards stay CSR — ``csr_transpose`` is an
    exact device-side Aᵀ with no per-row bound to lose. Shared by the local
    and the mesh-sharded VJP. The fused megakernel's dU = Aᵀ·dZ is itself a
    plain batched SpMM, so it takes the same COO-class backward. Precision
    variants map first (before the pallas catch-all) via ``_VARIANT_BWD``.

    The hybrid class maps to the CSR class (its sparse remainder IS the
    rpt-bounded CSR loop): the forward's inverse-permute epilogue sits
    inside the custom-VJP boundary, so cotangents arrive in ORIGINAL row
    order and the backward permutes nothing — it must not re-sort Aᵀ by
    *its* degrees, because dB = Aᵀ·dC is exact in any evaluation order and
    re-deriving a permutation for the transpose would pay the sort twice
    for no bound on Aᵀ's rows.
    """
    if impl in _VARIANT_BWD:
        return _VARIANT_BWD[impl]
    if impl in ("csr", "pallas_csr"):
        return impl
    if impl == "hybrid":
        return "csr"
    if impl == "pallas_hybrid":
        return "pallas_csr"
    if impl.startswith("pallas") or impl.startswith("fused"):
        return "pallas_coo"
    return impl if impl in ("ref", "loop", "dense") else "ref"


def backward_db(row_ids, col_ids, nnz, values, dc, *, impl, interpret):
    """dB = Aᵀ @ dC for a forward ``impl`` — batched SpMM with the transposed
    adjacency (paper §IV-D), shared by the local and the mesh-sharded VJP.

    Every class transposes by swapping the COO index arrays (free); for the
    CSR class ``_forward`` then row-sorts the swapped COO, which IS the
    device-side transposed CSR in one sort —
    ``csr_transpose(coo_to_csr(A))`` collapsed, since the VJP still holds
    the raw COO triples. :func:`repro.core.formats.csr_transpose` is the
    same Aᵀ for callers that hold only a ``BatchedCSR``.
    """
    return _forward(col_ids, row_ids, nnz, values, dc,
                    impl=bwd_impl_for(impl), k_pad=None, interpret=interpret)


def dvalues(row_ids, col_ids, dc, b):
    """dValues[i] = <dC[rid[i]], B[cid[i]]> — the batched gather-dot of the
    VJP (paper §IV-D), shared by the local and the mesh-sharded backward."""

    def one(rid, cid, dcc, bb):
        return jnp.sum(
            jnp.take(dcc, rid, axis=0) * jnp.take(bb, cid, axis=0), axis=-1)

    return jax.vmap(one)(row_ids, col_ids, dc, b)


def gspmm_backward(row_ids, col_ids, nnz, values, b, c, dc, *, op, reduce,
                   impl, interpret):
    """(dValues, dB) for one g-SpMM forward — shared by the local and the
    mesh-sharded VJP, like :func:`backward_db`/:func:`dvalues` for plain
    SpMM.

    ``mean`` pre-scales the cotangent by 1/deg (d mean = d sum / deg) and
    then reduces to the sum backward. The ``(mul, sum/mean)`` scalar-edge
    corner IS the plain-SpMM backward and keeps its in-class batched path
    (dB = Aᵀ @ dC via :func:`backward_db`, dValues a batched gather-dot —
    only the padding-slot gradient needs an explicit mask now). Every other
    corner runs a generic gather/scatter VJP:

    - ``max`` routes each row's cotangent to the winning edge(s) by an
      argmax mask ``msg == C[rid]`` — exact f32 equality is sound because
      the forward computes ``msg`` with the identical f32 expression; ties
      (e.g. duplicate edges under ``copy_lhs``) split the cotangent evenly,
      matching XLA's scatter-max autodiff convention;
    - dB scatters ``∂msg/∂B = e`` (mul) or ``1`` (add / copy_lhs) by column;
    - dValues is the feature-summed (scalar) or elementwise (vector) product
      with the gathered B rows for ``mul``, the bare cotangent for ``add``,
      and identically 0 for ``copy_lhs``.
    """
    batch, m_pad, _ = b.shape
    nnz_pad = row_ids.shape[1]
    valid = jnp.arange(nnz_pad)[None, :] < nnz[:, None]    # (batch, nnz_pad)
    dcf = dc.astype(jnp.float32)
    if reduce == "mean":
        a = BatchedCOO(row_ids, col_ids, values, nnz,
                       jnp.full((batch,), m_pad))
        deg = row_degrees(a, m_pad).astype(jnp.float32)    # (batch, m_pad)
        dcf = dcf / jnp.maximum(deg, 1.0)[..., None]
    scalar = values.ndim == 2
    if op == "mul" and reduce in ("sum", "mean") and scalar:
        # padded slots carry no semantics here (dB is linear in the values),
        # so zero them instead of trusting the padding-is-0.0 invariant
        vals_m = values * valid.astype(values.dtype)
        db = backward_db(row_ids, col_ids, nnz, vals_m, dcf,
                         impl=impl, interpret=interpret)
        dval = dvalues(row_ids, col_ids, dcf, b) * valid
        return dval.astype(values.dtype), db.astype(b.dtype)

    def one(rid, cid, val, n, bf, cf, dcc):
        vmask = (jnp.arange(nnz_pad) < n)[:, None]         # (nnz_pad, 1)
        rid_c = jnp.clip(rid.astype(jnp.int32), 0, m_pad - 1)
        cid_c = cid.astype(jnp.int32)
        u = jnp.take(bf, cid_c, axis=0).astype(jnp.float32)
        dmsg = jnp.take(dcc, rid_c, axis=0)
        if reduce == "max":
            msg = ref.gspmm_combine(u, val, op)
            win = ((msg == jnp.take(cf, rid_c, axis=0)) & vmask).astype(
                jnp.float32)
            # ties (e.g. duplicate edges under copy_lhs) split the cotangent
            # evenly — XLA's scatter-max autodiff convention
            nwin = jnp.zeros(cf.shape, jnp.float32).at[rid_c].add(win)
            dmsg = win * dmsg / jnp.maximum(
                jnp.take(nwin, rid_c, axis=0), 1.0)
        else:
            dmsg = jnp.where(vmask, dmsg, 0.0)
        if op == "mul":
            e = val.astype(jnp.float32)
            if scalar:
                e = e[:, None]
            db = jnp.zeros(bf.shape, jnp.float32).at[cid_c].add(dmsg * e)
            dval = jnp.sum(dmsg * u, axis=-1) if scalar else dmsg * u
        elif op == "add":
            db = jnp.zeros(bf.shape, jnp.float32).at[cid_c].add(dmsg)
            dval = jnp.sum(dmsg, axis=-1) if scalar else dmsg
        else:   # copy_lhs: the edge value never enters the forward
            db = jnp.zeros(bf.shape, jnp.float32).at[cid_c].add(dmsg)
            dval = jnp.zeros(val.shape, jnp.float32)
        return dval, db

    # only the max backward consults the forward output (argmax routing);
    # the linear reduces pass a placeholder so the residual can drop `c`
    cf = c.astype(jnp.float32) if reduce == "max" else jnp.zeros_like(dcf)
    dval, db = jax.vmap(one)(row_ids, col_ids, values, nnz, b, cf, dcf)
    return dval.astype(values.dtype), db.astype(b.dtype)


def resolve_gspmm_impl(
    a: BatchedCOO,
    b: jax.Array,
    *,
    op: str = "mul",
    reduce: str = "sum",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
):
    """Resolve ``impl="auto"`` for one g-SpMM call — the
    :func:`resolve_impl` analogue with the ``(op, reduce, d_e)`` workload
    axes set, so ``Workload.is_gspmm`` restricts the ranked ladder to the
    capable subset and the tuning-cache key never collides with the plain
    SpMM entry for the same shapes."""
    from repro import autotune

    interpret = resolve_interpret(interpret)
    batch, m_pad, n_b = b.shape
    d_e = a.values.shape[2] if a.values.ndim == 3 else None
    w = autotune.Workload(batch=batch, m_pad=m_pad,
                          nnz_pad=a.row_ids.shape[1], k_pad=k_pad, n_b=n_b,
                          itemsize=b.dtype.itemsize, d_e=d_e, reduce=reduce,
                          op=op)
    if impl != "auto":
        return autotune.forced_decision(w, impl)
    from repro.autotune.cache import default_cache
    return autotune.select_impl(w, allow_pallas=not interpret,
                                cache=default_cache())


def batched_gspmm(
    a: BatchedCOO,
    b: jax.Array,
    *,
    op: str = "mul",
    reduce: str = "sum",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
    mesh_axis: str = "data",
) -> jax.Array:
    """Generalized SpMM / message passing: per sample s,
    ``C[s][r] = reduce_{edges (r, c)} op(B[s][c], e)`` — the g-SpMM of
    DESIGN.md §11 (DGL's gspmm shape, arXiv:1909.01315).

    ``a.values`` holds the edge values ``e``: scalars ``(batch, nnz_pad)``
    or per-edge feature vectors ``(batch, nnz_pad, d_e)`` with ``d_e`` equal
    to B's feature width. Differentiable in ``a.values`` and ``b`` (custom
    VJP; ``max`` keeps its argmax routing, ``mean`` its degree scaling,
    zero-degree rows emit the 0.0 identity with 0 gradient).

    ``(op, reduce) == ("mul", "sum")`` with scalar edges IS plain batched
    SpMM and delegates to :func:`batched_spmm` — full registry, precision
    variants, identical numerics. Every other corner resolves over the
    f32 g-SpMM-capable subset (``autotune.GSPMM_IMPLS``).
    """
    if op not in GSPMM_OPS:
        raise ValueError(f"unknown g-SpMM op {op!r}; expected {GSPMM_OPS}")
    if reduce not in GSPMM_REDUCES:
        raise ValueError(
            f"unknown g-SpMM reduce {reduce!r}; expected {GSPMM_REDUCES}")
    if (op, reduce) == ("mul", "sum") and a.values.ndim == 2:
        return batched_spmm(a, b, impl=impl, k_pad=k_pad,
                            interpret=interpret, mesh=mesh,
                            mesh_axis=mesh_axis)
    interpret = resolve_interpret(interpret)
    if mesh is not None:
        from repro.distributed.spmm import sharded_batched_gspmm

        return sharded_batched_gspmm(a, b, op=op, reduce=reduce,
                                     mesh=mesh, axis=mesh_axis, impl=impl,
                                     k_pad=k_pad, interpret=interpret)
    tele = obs_trace.enabled()
    gdecision = None
    if impl == "auto" or tele:
        gdecision = resolve_gspmm_impl(a, b, op=op, reduce=reduce,
                                       impl=impl, k_pad=k_pad,
                                       interpret=interpret)
        impl = gdecision.impl
    if not supports_gspmm(impl):
        raise ValueError(
            f"impl {impl!r} cannot run g-SpMM (op={op!r}, reduce={reduce!r});"
            f" the capable set is {GSPMM_IMPLS} at f32")

    row_ids, col_ids, nnz = a.row_ids, a.col_ids, a.nnz

    @jax.custom_vjp
    def f(values, b):
        return _forward(row_ids, col_ids, nnz, values, b, impl=impl,
                        k_pad=k_pad, interpret=interpret, op=op,
                        reduce=reduce)

    def fwd(values, b):
        c = f(values, b)
        # the argmax routing of the max backward needs the forward output;
        # the linear reduces don't — drop it from their residual
        return c, (values, b, c if reduce == "max" else None)

    def bwd(res, dc):
        values, b, c = res
        dval, db = gspmm_backward(row_ids, col_ids, nnz, values, b, c, dc,
                                  op=op, reduce=reduce, impl=impl,
                                  interpret=interpret)
        return dval, db

    f.defvjp(fwd, bwd)
    if tele and gdecision is not None and gdecision.workload is not None:
        return _traced_dispatch(f, a.values, b, impl=impl,
                                decision=gdecision,
                                workload=gdecision.workload)
    return f(a.values, b)


def batched_spmm(
    a: BatchedCOO,
    b: jax.Array,
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
    mesh_axis: str = "data",
    precision: str = "f32",
) -> jax.Array:
    """C[s] = A[s] @ B[s] for every sample s in the batch, one device op.

    a: BatchedCOO over square (m_pad, m_pad) adjacencies; b: (batch, m_pad, n).
    Differentiable in ``a.values`` and ``b``. ``impl="auto"`` (default)
    resolves to a concrete implementation from the call's static shapes via
    ``repro.autotune`` before any tracing-dependent work happens.

    ``precision`` is the dtype policy for ``impl="auto"``: ``"bf16"``/
    ``"i8"`` let the ranking pick a reduced-precision variant (DESIGN.md
    §10). A concrete ``impl`` already encodes its policy (``"csr_bf16"``
    runs bf16 regardless of ``precision``).

    ``mesh=`` routes the call through the mesh-sharded path
    (:func:`repro.distributed.spmm.sharded_batched_spmm`): the batch axis is
    split over ``mesh_axis`` and the per-shard kernels run under shard_map,
    with ``impl="auto"`` resolved against the per-shard workload.
    """
    if precision_of(impl)[0].startswith("fused"):
        raise ValueError(
            f"impl={impl!r} is the graph-conv LAYER megakernel (it needs W "
            "and bias, not a bare dense operand) — call "
            "repro.core.graph_conv.graph_conv_batched(impl='fused') or "
            "repro.kernels.fused_graph_conv.fused_graph_conv directly")
    interpret = resolve_interpret(interpret)
    if mesh is not None:
        from repro.distributed.spmm import sharded_batched_spmm

        return sharded_batched_spmm(a, b, mesh=mesh, axis=mesh_axis,
                                    impl=impl, k_pad=k_pad,
                                    interpret=interpret, precision=precision)
    tele = obs_trace.enabled()
    decision = None
    if impl == "auto" or tele:
        # telemetry also resolves CONCRETE impls (a forced Decision) so the
        # span carries the same auditable plan/case/workload provenance
        decision = resolve_impl(a, b, impl=impl, k_pad=k_pad,
                                interpret=interpret, precision=precision)
        impl = decision.impl

    row_ids, col_ids, nnz = a.row_ids, a.col_ids, a.nnz

    @jax.custom_vjp
    def f(values, b):
        return _forward(row_ids, col_ids, nnz, values, b,
                        impl=impl, k_pad=k_pad, interpret=interpret)

    def fwd(values, b):
        return f(values, b), (values, b)

    def bwd(res, dc):
        values, b = res
        # dB = Aᵀ @ dC (paper §IV-D: "The Batched SpMM is also applied to
        # backward propagation") — COO index swap, or csr_transpose for the
        # CSR class.
        db = backward_db(row_ids, col_ids, nnz, values, dc,
                         impl=impl, interpret=interpret)
        dval = dvalues(row_ids, col_ids, dc, b).astype(values.dtype)
        return dval, db.astype(b.dtype)

    f.defvjp(fwd, bwd)
    if tele and decision is not None and decision.workload is not None:
        return _traced_dispatch(f, a.values, b, impl=impl,
                                decision=decision,
                                workload=decision.workload)
    return f(a.values, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_batched_matmul(a, b, *, interpret: bool | None = None):
    """Standalone MXU batched GEMM entry point (benchmark use)."""
    plan = batching.plan_batched_gemm(
        batch=a.shape[0], m=a.shape[1], n=b.shape[-1], k=a.shape[2],
        itemsize=b.dtype.itemsize,
    )
    return batched_gemm(a, b, plan=plan, interpret=interpret)
