"""Public, differentiable wrappers around the batched kernels.

The app-level contract mirrors the paper's TensorFlow integration (§IV-D):
adjacency matrices arrive as SparseTensor-style COO batches; one call executes
the whole batch. ``impl`` selects:

- ``"ref"``        pure-jnp batched oracle (scatter-add), XLA-fused;
- ``"pallas_ell"`` Batched SWA-CSR analogue (row-split ELL Pallas kernel);
- ``"pallas_csr"`` Batched CSR row-split (GE-SpMM style: flat nnz arrays,
                   rpt-bounded dynamic slot loop — DESIGN.md §9);
- ``"csr"``        pure-XLA CSR segment-sum reference (same conversion,
                   searchsorted row recovery + scatter-add);
- ``"pallas_coo"`` Batched SWA-SparseTensor analogue (one-hot-scatter kernel);
- ``"dense"``      densify + batched GEMM (the cuBLAS gemmBatched baseline);
- ``"pallas_gemm"`` densify + MXU Pallas batched GEMM;
- ``"loop"``       the NON-batched baseline: one sequential SpMM per sample,
                   reproducing the paper's per-sample-kernel-launch structure;
- ``"auto"``       (default) shape-keyed adaptive dispatch: the paper's
                   §IV-B/§IV-C resource-assignment policy extended into a
                   which-kernel decision by ``repro.autotune`` (cost model +
                   optional measured tuning cache — DESIGN.md §5). Resolution
                   happens at trace time from static shapes, so it is
                   jit-safe and free at run time.

The VJP follows the paper's backward-pass batching: dB = batched-SpMM with Aᵀ
(index swap — free in COO), and dValues is a batched gather-dot. Both run as
single batched ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.autotune.cost_model import PRECISION_IMPLS, precision_of
from repro.core import batching
from repro.core.formats import (
    BatchedCOO,
    BatchedCSR,
    coo_to_csr,
    coo_to_dense,
    coo_to_ell,
    narrow_col_ids,
    quantize_values_i8,
    validate_ell_k_pad,
)
from repro.kernels import ref, resolve_interpret
from repro.kernels.batched_gemm import batched_gemm
from repro.kernels.batched_spmm_coo import batched_spmm_coo
from repro.kernels.batched_spmm_csr import batched_spmm_csr
from repro.kernels.batched_spmm_ell import batched_spmm_ell

# "fused" is the graph-conv layer megakernel (kernels/fused_graph_conv.py):
# it is selectable wherever a layer-level workload is being resolved
# (graph_conv_batched / resolve_graph_conv_impl), but is NOT a plain SpMM —
# batched_spmm(impl="fused") raises with a pointer to the layer entry point.
# The reduced-precision variants (…_bf16 / …_i8, DESIGN.md §10) are distinct
# registry entries: each runs its base impl's execution structure with a
# cheaper storage policy and an f32 accumulator.
IMPLS = ("auto", "ref", "ell", "pallas_ell", "csr", "pallas_csr",
         "pallas_coo", "dense", "pallas_gemm", "loop",
         "fused") + tuple(PRECISION_IMPLS)


def resolve_impl(
    a: BatchedCOO,
    b: jax.Array,
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    precision: str = "f32",
):
    """Resolve ``impl="auto"`` to the concrete impl for this call's shapes.

    Returns an ``repro.autotune.Decision`` (``.impl`` is the concrete
    string); a concrete ``impl`` passes through as a forced Decision so
    callers can introspect either path uniformly. ``precision`` is the
    caller's dtype policy (``"f32"``/``"bf16"``/``"i8"``): under
    ``impl="auto"`` it admits the matching reduced-precision variants to the
    ranking; a concrete impl carries its own policy and ignores it.
    """
    from repro import autotune

    interpret = resolve_interpret(interpret)
    batch, m_pad, n_b = b.shape
    if impl != "auto":
        w = autotune.Workload(batch=batch, m_pad=m_pad,
                              nnz_pad=a.row_ids.shape[1], k_pad=k_pad,
                              n_b=n_b, itemsize=b.dtype.itemsize,
                              dtype=precision_of(impl)[1])
        return autotune.forced_decision(w, impl)
    return autotune.resolve_auto(
        batch=batch, m_pad=m_pad, nnz_pad=a.row_ids.shape[1], k_pad=k_pad,
        n_b=n_b, itemsize=b.dtype.itemsize, interpret=interpret,
        dtype=precision)


def resolve_compute_dtype(a_dtype, b_dtype):
    """The deliberate mixed-dtype policy of the GEMM-class impls (DESIGN.md
    §10): compute in the PROMOTED dtype of the two operands so a
    full-precision operand is never silently downcast. Same lattice the
    precision variants use — bf16 meets f32 at f32."""
    return jnp.promote_types(a_dtype, b_dtype)


def _csr_forward(csr: BatchedCSR, b, *, impl, interpret, scale=None,
                 narrow=False):
    """Run a CSR-class impl on an already-converted :class:`BatchedCSR` —
    shared by the forward (COO→CSR) and the backward (``csr_transpose``).

    ``scale`` is the i8 policy's per-matrix dequantization factor (applied to
    the f32 accumulator — in-kernel on the Pallas path, post-hoc on the XLA
    fallbacks); ``narrow`` stores column ids as int16 on the Pallas wire."""
    if impl == "csr":
        out = ref.batched_spmm_csr_ref(csr, b)
        return out if scale is None else out * scale[:, None, None]
    plan = batching.plan_batched_spmm(
        batch=csr.batch, m_pad=csr.m_pad, n_b=b.shape[-1],
        slots=csr.nnz_pad, itemsize=b.dtype.itemsize)
    if plan.case == 3:
        # Paper case 3: matrices too large for the batched strategy — same
        # per-sample fallback as the COO/ELL kernels.
        out = ref.batched_spmm_csr_ref(csr, b)
        return out if scale is None else out * scale[:, None, None]
    cids = narrow_col_ids(csr.col_ids, csr.m_pad) if narrow else csr.col_ids
    return batched_spmm_csr(csr.rpt, cids, csr.values, b,
                            plan=plan, scale=scale, interpret=interpret)


def _forward(row_ids, col_ids, nnz, values, b, *, impl, k_pad, interpret):
    """Dispatch one batched SpMM forward. A precision variant (DESIGN.md §10)
    decomposes into (base impl, storage policy): bf16 casts values and the
    dense operand to bfloat16 (f32 accumulate in-kernel, output cast back to
    the caller's dtype); i8 quantizes values to int8 codes with a per-matrix
    f32 scale applied once to the accumulator (exact, by linearity) while the
    dense operand stays full-precision. Both narrow the Pallas-side index
    storage to int16 behind :func:`repro.core.formats.narrow_col_ids`'s
    host-side overflow guard."""
    base, policy = precision_of(impl)
    out_dtype = b.dtype
    scale = None
    if policy == "bf16":
        values = values.astype(jnp.bfloat16)
        b = b.astype(jnp.bfloat16)
    elif policy == "i8":
        values, scale = quantize_values_i8(values)
    out = _forward_base(row_ids, col_ids, nnz, values, b, impl=impl,
                        base=base, k_pad=k_pad, interpret=interpret,
                        scale=scale, narrow=policy != "f32")
    # Reduced policies restore the caller's dtype; the f32 path returns the
    # branch's own result dtype (the GEMM class may deliberately PROMOTE on
    # mixed-dtype inputs — see resolve_compute_dtype).
    return out if policy == "f32" else out.astype(out_dtype)


def _forward_base(row_ids, col_ids, nnz, values, b, *, impl, base, k_pad,
                  interpret, scale, narrow):
    batch, m_pad, n_b = b.shape
    a = BatchedCOO(row_ids, col_ids, values, nnz, jnp.full((batch,), m_pad))

    def dequant(out):
        # XLA fallback for the i8 policy: the kernel-side accumulator scale,
        # applied after the (linear) unscaled SpMM of the codes
        return out if scale is None else out * scale[:, None, None]

    if base == "ref":
        return dequant(ref.batched_spmm_coo_ref(a, b, m_pad))
    if base == "loop":
        # Non-batched baseline: sequential per-sample SpMM (paper Fig. 2 / the
        # "TF" bars in Fig. 8). Structured as a scan so each sample is its own
        # sequential step, like one kernel launch per sample.
        def step(_, args):
            r, c, v, bb = args
            return None, ref.spmm_coo_single(r, c, v, bb, m_pad)

        _, out = jax.lax.scan(step, None, (row_ids, col_ids, values, b))
        return out
    if base in ("dense", "pallas_gemm"):
        a_dense = coo_to_dense(a, m_pad)
        # Deliberate mixed-dtype resolution (not a silent downcast to
        # b.dtype): both operands meet at the promoted dtype, so e.g. f32
        # adjacency values × bf16 features compute — and return — f32.
        compute = resolve_compute_dtype(a_dense.dtype, b.dtype)
        a_dense, bb = a_dense.astype(compute), b.astype(compute)
        if base == "dense":
            return ref.batched_gemm_ref(a_dense, bb)
        plan = batching.plan_batched_gemm(
            batch=batch, m=m_pad, n=n_b, k=m_pad, itemsize=bb.dtype.itemsize
        )
        return batched_gemm(a_dense, bb, plan=plan, interpret=interpret)
    if base in ("csr", "pallas_csr"):
        return _csr_forward(coo_to_csr(a, m_pad), b, impl=base,
                            interpret=interpret, scale=scale, narrow=narrow)
    if base in ("pallas_ell", "ell"):
        if k_pad is None:
            raise ValueError(f"{impl} requires k_pad (max nnz/row)")
        # Silent-drop guard: coo_to_ell zeroes any nnz beyond k_pad in a row.
        # Eager (concrete) calls raise host-side here; traced calls cannot
        # branch on data and skip (callers own k_pad sizing under jit —
        # coo_to_ell(check=True) installs a runtime debug-assert instead).
        validate_ell_k_pad(a, m_pad, k_pad)
    plan = batching.plan_batched_spmm(
        batch=batch, m_pad=m_pad, n_b=n_b,
        slots=k_pad if base == "pallas_ell" else row_ids.shape[1],
        itemsize=b.dtype.itemsize,
    )
    if plan.case == 3:
        # Paper case 3: matrices too large for the batched shared-memory
        # strategy — take the per-sample path.
        return dequant(ref.batched_spmm_coo_ref(a, b, m_pad))
    if base in ("pallas_ell", "ell"):
        ell = coo_to_ell(a, m_pad, k_pad)
        if base == "ell":
            # pure-XLA batched row-split (gather + contraction): the batched
            # single-op semantics without the Pallas kernel
            return dequant(ref.batched_spmm_ell_ref(ell, b))
        cids = narrow_col_ids(ell.col_ids, m_pad) if narrow else ell.col_ids
        return batched_spmm_ell(cids, ell.values, b, plan=plan,
                                scale=scale, interpret=interpret)
    if base == "pallas_coo":
        rids, cids = row_ids, col_ids
        if narrow:
            rids = narrow_col_ids(rids, m_pad)
            cids = narrow_col_ids(cids, m_pad)
        return batched_spmm_coo(rids, cids, values, b, plan=plan,
                                interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")


_VARIANT_BWD = {
    # bf16 forwards keep a bf16-class backward (grads accumulate f32
    # in-kernel, cast on the way out); ELL-class forwards fall to the COO
    # class like their f32 bases. i8 forwards take a FULL-PRECISION
    # straight-through backward: the VJP residuals hold the original f32
    # values (quantization happens inside _forward), so dB is computed
    # against the unquantized operator — the class mapping of the f32 base.
    "ell_bf16": "ref",
    "csr_bf16": "csr_bf16",
    "pallas_ell_bf16": "pallas_coo_bf16",
    "pallas_csr_bf16": "pallas_csr_bf16",
    "pallas_coo_bf16": "pallas_coo_bf16",
    "pallas_ell_i8": "pallas_coo",
    "pallas_csr_i8": "pallas_csr",
    "fused_bf16": "pallas_coo_bf16",
}


def bwd_impl_for(impl: str) -> str:
    """The impl the backward pass (dB = Aᵀ @ dC) runs for a forward ``impl``.

    Aᵀ loses the per-row ELL bound, so ELL-class forwards fall back to the
    COO/scatter class; CSR-class forwards stay CSR — ``csr_transpose`` is an
    exact device-side Aᵀ with no per-row bound to lose. Shared by the local
    and the mesh-sharded VJP. The fused megakernel's dU = Aᵀ·dZ is itself a
    plain batched SpMM, so it takes the same COO-class backward. Precision
    variants map first (before the pallas catch-all) via ``_VARIANT_BWD``.
    """
    if impl in _VARIANT_BWD:
        return _VARIANT_BWD[impl]
    if impl in ("csr", "pallas_csr"):
        return impl
    if impl.startswith("pallas") or impl == "fused":
        return "pallas_coo"
    return impl if impl in ("ref", "loop", "dense") else "ref"


def backward_db(row_ids, col_ids, nnz, values, dc, *, impl, interpret):
    """dB = Aᵀ @ dC for a forward ``impl`` — batched SpMM with the transposed
    adjacency (paper §IV-D), shared by the local and the mesh-sharded VJP.

    Every class transposes by swapping the COO index arrays (free); for the
    CSR class ``_forward`` then row-sorts the swapped COO, which IS the
    device-side transposed CSR in one sort —
    ``csr_transpose(coo_to_csr(A))`` collapsed, since the VJP still holds
    the raw COO triples. :func:`repro.core.formats.csr_transpose` is the
    same Aᵀ for callers that hold only a ``BatchedCSR``.
    """
    return _forward(col_ids, row_ids, nnz, values, dc,
                    impl=bwd_impl_for(impl), k_pad=None, interpret=interpret)


def dvalues(row_ids, col_ids, dc, b):
    """dValues[i] = <dC[rid[i]], B[cid[i]]> — the batched gather-dot of the
    VJP (paper §IV-D), shared by the local and the mesh-sharded backward."""

    def one(rid, cid, dcc, bb):
        return jnp.sum(
            jnp.take(dcc, rid, axis=0) * jnp.take(bb, cid, axis=0), axis=-1)

    return jax.vmap(one)(row_ids, col_ids, dc, b)


def batched_spmm(
    a: BatchedCOO,
    b: jax.Array,
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
    mesh_axis: str = "data",
    precision: str = "f32",
) -> jax.Array:
    """C[s] = A[s] @ B[s] for every sample s in the batch, one device op.

    a: BatchedCOO over square (m_pad, m_pad) adjacencies; b: (batch, m_pad, n).
    Differentiable in ``a.values`` and ``b``. ``impl="auto"`` (default)
    resolves to a concrete implementation from the call's static shapes via
    ``repro.autotune`` before any tracing-dependent work happens.

    ``precision`` is the dtype policy for ``impl="auto"``: ``"bf16"``/
    ``"i8"`` let the ranking pick a reduced-precision variant (DESIGN.md
    §10). A concrete ``impl`` already encodes its policy (``"csr_bf16"``
    runs bf16 regardless of ``precision``).

    ``mesh=`` routes the call through the mesh-sharded path
    (:func:`repro.distributed.spmm.sharded_batched_spmm`): the batch axis is
    split over ``mesh_axis`` and the per-shard kernels run under shard_map,
    with ``impl="auto"`` resolved against the per-shard workload.
    """
    if precision_of(impl)[0] == "fused":
        raise ValueError(
            f"impl={impl!r} is the graph-conv LAYER megakernel (it needs W "
            "and bias, not a bare dense operand) — call "
            "repro.core.graph_conv.graph_conv_batched(impl='fused') or "
            "repro.kernels.fused_graph_conv.fused_graph_conv directly")
    interpret = resolve_interpret(interpret)
    if mesh is not None:
        from repro.distributed.spmm import sharded_batched_spmm

        return sharded_batched_spmm(a, b, mesh=mesh, axis=mesh_axis,
                                    impl=impl, k_pad=k_pad,
                                    interpret=interpret, precision=precision)
    if impl == "auto":
        impl = resolve_impl(a, b, impl="auto", k_pad=k_pad,
                            interpret=interpret, precision=precision).impl

    row_ids, col_ids, nnz = a.row_ids, a.col_ids, a.nnz

    @jax.custom_vjp
    def f(values, b):
        return _forward(row_ids, col_ids, nnz, values, b,
                        impl=impl, k_pad=k_pad, interpret=interpret)

    def fwd(values, b):
        return f(values, b), (values, b)

    def bwd(res, dc):
        values, b = res
        # dB = Aᵀ @ dC (paper §IV-D: "The Batched SpMM is also applied to
        # backward propagation") — COO index swap, or csr_transpose for the
        # CSR class.
        db = backward_db(row_ids, col_ids, nnz, values, dc,
                         impl=impl, interpret=interpret)
        dval = dvalues(row_ids, col_ids, dc, b).astype(values.dtype)
        return dval, db.astype(b.dtype)

    f.defvjp(fwd, bwd)
    return f(a.values, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_batched_matmul(a, b, *, interpret: bool | None = None):
    """Standalone MXU batched GEMM entry point (benchmark use)."""
    plan = batching.plan_batched_gemm(
        batch=a.shape[0], m=a.shape[1], n=b.shape[-1], k=a.shape[2],
        itemsize=b.dtype.itemsize,
    )
    return batched_gemm(a, b, plan=plan, interpret=interpret)
