"""Public, differentiable wrappers around the batched kernels.

The app-level contract mirrors the paper's TensorFlow integration (§IV-D):
adjacency matrices arrive as SparseTensor-style COO batches; one call executes
the whole batch. ``impl`` selects:

- ``"ref"``        pure-jnp batched oracle (scatter-add), XLA-fused;
- ``"pallas_ell"`` Batched SWA-CSR analogue (row-split ELL Pallas kernel);
- ``"pallas_csr"`` Batched CSR row-split (GE-SpMM style: flat nnz arrays,
                   rpt-bounded dynamic slot loop — DESIGN.md §9);
- ``"csr"``        pure-XLA CSR segment-sum reference (same conversion,
                   searchsorted row recovery + scatter-add);
- ``"pallas_coo"`` Batched SWA-SparseTensor analogue (one-hot-scatter kernel);
- ``"dense"``      densify + batched GEMM (the cuBLAS gemmBatched baseline);
- ``"pallas_gemm"`` densify + MXU Pallas batched GEMM;
- ``"loop"``       the NON-batched baseline: one sequential SpMM per sample,
                   reproducing the paper's per-sample-kernel-launch structure;
- ``"auto"``       (default) shape-keyed adaptive dispatch: the paper's
                   §IV-B/§IV-C resource-assignment policy extended into a
                   which-kernel decision by ``repro.autotune`` (cost model +
                   optional measured tuning cache — DESIGN.md §5). Resolution
                   happens at trace time from static shapes, so it is
                   jit-safe and free at run time.

The VJP follows the paper's backward-pass batching: dB = batched-SpMM with Aᵀ
(index swap — free in COO), and dValues is a batched gather-dot. Both run as
single batched ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import batching
from repro.core.formats import (
    BatchedCOO,
    BatchedCSR,
    coo_to_csr,
    coo_to_dense,
    coo_to_ell,
    validate_ell_k_pad,
)
from repro.kernels import ref, resolve_interpret
from repro.kernels.batched_gemm import batched_gemm
from repro.kernels.batched_spmm_coo import batched_spmm_coo
from repro.kernels.batched_spmm_csr import batched_spmm_csr
from repro.kernels.batched_spmm_ell import batched_spmm_ell

# "fused" is the graph-conv layer megakernel (kernels/fused_graph_conv.py):
# it is selectable wherever a layer-level workload is being resolved
# (graph_conv_batched / resolve_graph_conv_impl), but is NOT a plain SpMM —
# batched_spmm(impl="fused") raises with a pointer to the layer entry point.
IMPLS = ("auto", "ref", "ell", "pallas_ell", "csr", "pallas_csr",
         "pallas_coo", "dense", "pallas_gemm", "loop", "fused")


def resolve_impl(
    a: BatchedCOO,
    b: jax.Array,
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
):
    """Resolve ``impl="auto"`` to the concrete impl for this call's shapes.

    Returns an ``repro.autotune.Decision`` (``.impl`` is the concrete
    string); a concrete ``impl`` passes through as a forced Decision so
    callers can introspect either path uniformly.
    """
    from repro import autotune

    interpret = resolve_interpret(interpret)
    batch, m_pad, n_b = b.shape
    if impl != "auto":
        w = autotune.Workload(batch=batch, m_pad=m_pad,
                              nnz_pad=a.row_ids.shape[1], k_pad=k_pad,
                              n_b=n_b, itemsize=b.dtype.itemsize)
        return autotune.forced_decision(w, impl)
    return autotune.resolve_auto(
        batch=batch, m_pad=m_pad, nnz_pad=a.row_ids.shape[1], k_pad=k_pad,
        n_b=n_b, itemsize=b.dtype.itemsize, interpret=interpret)


def _csr_forward(csr: BatchedCSR, b, *, impl, interpret):
    """Run a CSR-class impl on an already-converted :class:`BatchedCSR` —
    shared by the forward (COO→CSR) and the backward (``csr_transpose``)."""
    if impl == "csr":
        return ref.batched_spmm_csr_ref(csr, b)
    plan = batching.plan_batched_spmm(
        batch=csr.batch, m_pad=csr.m_pad, n_b=b.shape[-1],
        slots=csr.nnz_pad, itemsize=b.dtype.itemsize)
    if plan.case == 3:
        # Paper case 3: matrices too large for the batched strategy — same
        # per-sample fallback as the COO/ELL kernels.
        return ref.batched_spmm_csr_ref(csr, b)
    return batched_spmm_csr(csr.rpt, csr.col_ids, csr.values, b,
                            plan=plan, interpret=interpret)


def _forward(row_ids, col_ids, nnz, values, b, *, impl, k_pad, interpret):
    batch, m_pad, n_b = b.shape
    a = BatchedCOO(row_ids, col_ids, values, nnz, jnp.full((batch,), m_pad))
    if impl == "ref":
        return ref.batched_spmm_coo_ref(a, b, m_pad)
    if impl == "loop":
        # Non-batched baseline: sequential per-sample SpMM (paper Fig. 2 / the
        # "TF" bars in Fig. 8). Structured as a scan so each sample is its own
        # sequential step, like one kernel launch per sample.
        def step(_, args):
            r, c, v, bb = args
            return None, ref.spmm_coo_single(r, c, v, bb, m_pad)

        _, out = jax.lax.scan(step, None, (row_ids, col_ids, values, b))
        return out
    if impl in ("dense", "pallas_gemm"):
        a_dense = coo_to_dense(a, m_pad)
        if impl == "dense":
            return ref.batched_gemm_ref(a_dense, b)
        plan = batching.plan_batched_gemm(
            batch=batch, m=m_pad, n=n_b, k=m_pad, itemsize=b.dtype.itemsize
        )
        return batched_gemm(a_dense.astype(b.dtype), b, plan=plan,
                            interpret=interpret)
    if impl in ("csr", "pallas_csr"):
        return _csr_forward(coo_to_csr(a, m_pad), b, impl=impl,
                            interpret=interpret)
    if impl in ("pallas_ell", "ell"):
        if k_pad is None:
            raise ValueError(f"{impl} requires k_pad (max nnz/row)")
        # Silent-drop guard: coo_to_ell zeroes any nnz beyond k_pad in a row.
        # Eager (concrete) calls raise host-side here; traced calls cannot
        # branch on data and skip (callers own k_pad sizing under jit —
        # coo_to_ell(check=True) installs a runtime debug-assert instead).
        validate_ell_k_pad(a, m_pad, k_pad)
    plan = batching.plan_batched_spmm(
        batch=batch, m_pad=m_pad, n_b=n_b,
        slots=k_pad if impl == "pallas_ell" else row_ids.shape[1],
        itemsize=b.dtype.itemsize,
    )
    if plan.case == 3:
        # Paper case 3: matrices too large for the batched shared-memory
        # strategy — take the per-sample path.
        return ref.batched_spmm_coo_ref(a, b, m_pad)
    if impl in ("pallas_ell", "ell"):
        ell = coo_to_ell(a, m_pad, k_pad)
        if impl == "ell":
            # pure-XLA batched row-split (gather + contraction): the batched
            # single-op semantics without the Pallas kernel
            return ref.batched_spmm_ell_ref(ell, b)
        return batched_spmm_ell(ell.col_ids, ell.values, b, plan=plan,
                                interpret=interpret)
    if impl == "pallas_coo":
        return batched_spmm_coo(row_ids, col_ids, values, b, plan=plan,
                                interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")


def bwd_impl_for(impl: str) -> str:
    """The impl the backward pass (dB = Aᵀ @ dC) runs for a forward ``impl``.

    Aᵀ loses the per-row ELL bound, so ELL-class forwards fall back to the
    COO/scatter class; CSR-class forwards stay CSR — ``csr_transpose`` is an
    exact device-side Aᵀ with no per-row bound to lose. Shared by the local
    and the mesh-sharded VJP. The fused megakernel's dU = Aᵀ·dZ is itself a
    plain batched SpMM, so it takes the same COO-class backward.
    """
    if impl in ("csr", "pallas_csr"):
        return impl
    if impl.startswith("pallas") or impl == "fused":
        return "pallas_coo"
    return impl if impl in ("ref", "loop", "dense") else "ref"


def backward_db(row_ids, col_ids, nnz, values, dc, *, impl, interpret):
    """dB = Aᵀ @ dC for a forward ``impl`` — batched SpMM with the transposed
    adjacency (paper §IV-D), shared by the local and the mesh-sharded VJP.

    Every class transposes by swapping the COO index arrays (free); for the
    CSR class ``_forward`` then row-sorts the swapped COO, which IS the
    device-side transposed CSR in one sort —
    ``csr_transpose(coo_to_csr(A))`` collapsed, since the VJP still holds
    the raw COO triples. :func:`repro.core.formats.csr_transpose` is the
    same Aᵀ for callers that hold only a ``BatchedCSR``.
    """
    return _forward(col_ids, row_ids, nnz, values, dc,
                    impl=bwd_impl_for(impl), k_pad=None, interpret=interpret)


def dvalues(row_ids, col_ids, dc, b):
    """dValues[i] = <dC[rid[i]], B[cid[i]]> — the batched gather-dot of the
    VJP (paper §IV-D), shared by the local and the mesh-sharded backward."""

    def one(rid, cid, dcc, bb):
        return jnp.sum(
            jnp.take(dcc, rid, axis=0) * jnp.take(bb, cid, axis=0), axis=-1)

    return jax.vmap(one)(row_ids, col_ids, dc, b)


def batched_spmm(
    a: BatchedCOO,
    b: jax.Array,
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
    mesh_axis: str = "data",
) -> jax.Array:
    """C[s] = A[s] @ B[s] for every sample s in the batch, one device op.

    a: BatchedCOO over square (m_pad, m_pad) adjacencies; b: (batch, m_pad, n).
    Differentiable in ``a.values`` and ``b``. ``impl="auto"`` (default)
    resolves to a concrete implementation from the call's static shapes via
    ``repro.autotune`` before any tracing-dependent work happens.

    ``mesh=`` routes the call through the mesh-sharded path
    (:func:`repro.distributed.spmm.sharded_batched_spmm`): the batch axis is
    split over ``mesh_axis`` and the per-shard kernels run under shard_map,
    with ``impl="auto"`` resolved against the per-shard workload.
    """
    if impl == "fused":
        raise ValueError(
            "impl='fused' is the graph-conv LAYER megakernel (it needs W and "
            "bias, not a bare dense operand) — call "
            "repro.core.graph_conv.graph_conv_batched(impl='fused') or "
            "repro.kernels.fused_graph_conv.fused_graph_conv directly")
    interpret = resolve_interpret(interpret)
    if mesh is not None:
        from repro.distributed.spmm import sharded_batched_spmm

        return sharded_batched_spmm(a, b, mesh=mesh, axis=mesh_axis,
                                    impl=impl, k_pad=k_pad,
                                    interpret=interpret)
    if impl == "auto":
        impl = resolve_impl(a, b, impl="auto", k_pad=k_pad,
                            interpret=interpret).impl

    row_ids, col_ids, nnz = a.row_ids, a.col_ids, a.nnz

    @jax.custom_vjp
    def f(values, b):
        return _forward(row_ids, col_ids, nnz, values, b,
                        impl=impl, k_pad=k_pad, interpret=interpret)

    def fwd(values, b):
        return f(values, b), (values, b)

    def bwd(res, dc):
        values, b = res
        # dB = Aᵀ @ dC (paper §IV-D: "The Batched SpMM is also applied to
        # backward propagation") — COO index swap, or csr_transpose for the
        # CSR class.
        db = backward_db(row_ids, col_ids, nnz, values, dc,
                         impl=impl, interpret=interpret)
        dval = dvalues(row_ids, col_ids, dc, b).astype(values.dtype)
        return dval, db.astype(b.dtype)

    f.defvjp(fwd, bwd)
    return f(a.values, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_batched_matmul(a, b, *, interpret: bool | None = None):
    """Standalone MXU batched GEMM entry point (benchmark use)."""
    plan = batching.plan_batched_gemm(
        batch=a.shape[0], m=a.shape[1], n=b.shape[-1], k=a.shape[2],
        itemsize=b.dtype.itemsize,
    )
    return batched_gemm(a, b, plan=plan, interpret=interpret)
