"""Flash attention as a Pallas TPU kernel.

The §Roofline finding that motivates this kernel: the pure-XLA chunked
attention materializes every (q_block × kv_block) score panel to HBM ~6×
(dot → mask/exp fusion → dot), making every train/prefill cell memory-bound
(EXPERIMENTS.md §Perf, llama3-8b train_4k: memory term 138s vs compute 4s).
Keeping the panel in VMEM removes that traffic entirely — the classic flash
scheme, expressed TPU-natively:

- grid ``(batch, heads, q_blocks, kv_blocks)`` — the LAST axis is innermost
  and sequential on TPU, so the online-softmax state lives in VMEM scratch
  across kv steps (no atomics, no cross-core races: the paper's
  shared-memory-resident accumulator pattern at flash scale);
- GQA without materializing repeated K/V: the K/V BlockSpec index map sends
  query-head ``ih`` to kv-head ``ih // groups`` — the repeat happens in the
  address calculation, not in HBM;
- causal + sliding-window masking from block indices; fully-masked panels
  still run (grid is static) but contribute zeros.

Validated in interpret mode against the jnp oracle over shape/dtype sweeps
(tests/test_kernels.py); the roofline substitution it implies is quantified
in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, kv_blocks: int,
            q_block: int, kv_block: int, tq: int, tk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (qb, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (kvb, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 0)
    kpos = ik * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 1)
    mask = kpos < tk
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (qb,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == kv_blocks - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "interpret"))
def flash_attention(
    q: jax.Array,          # (B, Tq, H, hd)
    k: jax.Array,          # (B, Tk, KV, hd) — GQA handled by index map
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    from repro.kernels import resolve_interpret
    interpret = resolve_interpret(interpret)
    b, tq, h, hd = q.shape
    tk, kv = k.shape[1], k.shape[2]
    groups = h // kv
    scale = hd ** -0.5
    q_block = min(q_block, tq)
    kv_block = min(kv_block, tk)
    nq = -(-tq // q_block)
    nk = -(-tk // kv_block)
    qp = jnp.pad(q, ((0, 0), (0, nq * q_block - tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_block - tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_block - tk), (0, 0), (0, 0)))

    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            kv_blocks=nk, q_block=q_block, kv_block=kv_block, tq=tq, tk=tk),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, 1, hd),
                         lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            # GQA: kv head = query head // groups — no repeat in HBM
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda ib, ih, iq, ik, g=groups: (ib, ik, ih // g, 0)),
            pl.BlockSpec((1, kv_block, 1, hd),
                         lambda ib, ih, iq, ik, g=groups: (ib, ik, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, hd),
                               lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq * q_block, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, hd), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :tq]
