"""Per-row (segment) softmax over edge scores — the attention building block
(DESIGN.md §11).

``alpha[i] = exp(s[i] - max_row(s)) / Σ_{j: rid[j] = rid[i]} exp(s[j] - …)``
for every valid edge slot ``i``, where the segments are the destination rows
``row_ids`` of a :class:`~repro.core.formats.BatchedCOO` batch. This is the
GAT normalizer: scores live on edges, the softmax runs over each row's
incoming edges.

Numerics and identities:

- per-row max subtraction (scatter-max) keeps the exponentials in range; the
  shifted argument is masked to 0 before ``exp`` so no inf is ever
  materialized, even transiently;
- padded slots (``i ≥ nnz``) output exactly 0 and receive exactly 0
  gradient;
- zero-degree rows have no valid slots, so nothing is emitted for them —
  their (non-existent) weights are all-zero and the backward stays finite
  (the 0/0 is guarded by a denominator clamp, and the custom VJP is
  identically 0 there).

The custom VJP is the classic softmax Jacobian restricted to segments:
``ds[i] = alpha[i] · (g[i] - t[rid[i]])`` with ``t[r] = Σ_j alpha[j]·g[j]``
over row r — two scatter-adds, no materialized (nnz × nnz) Jacobian.

Scores may be ``(batch, nnz_pad)`` or multi-head ``(batch, nnz_pad, h)``;
the softmax is independent per head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -3.0e38   # finite stand-in for -inf (matches kernels/ref.py)


def _forward(scores, row_ids, nnz, m_pad):
    """Batched segment softmax on (batch, nnz_pad, h) scores; returns the
    weights in the scores' shape (all-f32 internally)."""

    def one(s, rid, n):
        nnz_pad, h = s.shape
        valid = (jnp.arange(nnz_pad) < n)[:, None]
        rid_c = jnp.clip(rid, 0, m_pad - 1)
        sf = s.astype(jnp.float32)
        smax = jnp.full((m_pad, h), NEG_INF, jnp.float32).at[rid_c].max(
            jnp.where(valid, sf, NEG_INF))
        # mask BEFORE exp: s - NEG_INF on an all-padding row would overflow
        shifted = jnp.where(valid, sf - smax[rid_c], 0.0)
        z = jnp.where(valid, jnp.exp(shifted), 0.0)
        denom = jnp.zeros((m_pad, h), jnp.float32).at[rid_c].add(z)
        return z / jnp.maximum(denom[rid_c], 1e-30)

    return jax.vmap(one)(scores, row_ids, nnz)


# NOT jitted at this level: the custom_vjp closes over row_ids/nnz, and a
# surrounding jit would capture them as leaked tracers in the VJP closure —
# same posture as ops.batched_spmm. Callers jit the enclosing layer/loss.
def segment_softmax(
    scores: jax.Array,    # (batch, nnz_pad) or (batch, nnz_pad, h)
    row_ids: jax.Array,   # (batch, nnz_pad) int32 — the segment ids
    *,
    nnz: jax.Array,       # (batch,) int32 — true edge count per sample
    m_pad: int,
) -> jax.Array:
    """Numerically stable softmax of ``scores`` over each destination row's
    incoming edges. Differentiable in ``scores`` (custom VJP)."""
    squeeze = scores.ndim == 2
    s3 = scores[..., None] if squeeze else scores

    @jax.custom_vjp
    def f(s):
        return _forward(s, row_ids, nnz, m_pad)

    def fwd(s):
        out = f(s)
        return out, out

    def bwd(out, g):
        gf = g.astype(jnp.float32)

        def one(o, gg, rid):
            rid_c = jnp.clip(rid, 0, m_pad - 1)
            # t[r] = Σ_{j in row r} alpha[j]·g[j]; invalid slots have o = 0
            t = jnp.zeros((m_pad, o.shape[-1]), jnp.float32).at[rid_c].add(
                o * gg)
            return o * (gg - t[rid_c])

        return (jax.vmap(one)(out, gf, row_ids),)

    f.defvjp(fwd, bwd)
    out = f(s3).astype(scores.dtype)
    return out[..., 0] if squeeze else out
