"""Degree-binned hybrid SpMM — MXU-dense hub tiles + rpt-bounded CSR
remainder, with the inverse row permutation fused into the epilogue
(DESIGN.md §12; HC-SpMM / Accel-GCN style routing, ISSUE 8).

The CSR row-split kernel (`batched_spmm_csr.py`) bounds its slot loop by
the per-matrix MAX row degree — one hub row serializes the whole matrix's
grid step. This kernel splits each matrix by degree instead:

- **sort**: rows are stably argsorted by descending degree, per matrix. The
  kernel computes in sorted row order; the *inverse* permutation (``rank``)
  is applied as the last epilogue op, so outputs leave in original row
  order and callers never see the reordering.
- **classify**: a row with ``deg >= plan.dmin`` (density ``tau`` of
  ``m_pad``, threshold inclusive) is a *hub*. Hub rows are scattered into a
  dense ``(d_pad, m_pad)`` slab outside the kernel and handled by ONE
  ``dot_general`` on the MXU — ``2·d_pad·m_pad·n_block`` FLOPs per row
  block instead of a ``max_deg``-trip scatter loop. ``d_pad`` is static and
  provably sufficient (``nnz_pad // dmin`` bounds the hub count); when it
  is 0 the kernel takes no slab operand at all, so degenerate inputs
  (all-empty batches, ``nnz_pad < dmin``) never emit an empty MXU tile
  group.
- **bin**: the sparse remainder runs the existing rpt-bounded CSR scatter
  loop, but over static SUBLANES-aligned bins of the sorted row axis, each
  with its own SMEM trip bound ``max(rlen)`` *within the bin*. Because rows
  are degree-sorted, bins are similar-work groups: the fori_loop over a
  light bin stops after its own (small) max degree instead of the matrix
  max — and every sparse row's degree is ``< dmin`` by classification, so
  the worst bin is bounded by ``dmin - 1`` regardless of skew.

The flat ``col_ids``/``values`` arrays stay in CSR (original-row) order;
only the per-row ``start``/``rlen`` pointers are permuted, so no nnz-sized
re-sort is paid. Hub rows keep ``rlen_sparse = 0`` — their non-zeros live
only in the slab, sparse rows only in the CSR arrays (no double counting).

Gradients: the inverse-permute epilogue lives INSIDE the generic
``batched_spmm`` custom-VJP boundary, so cotangents arrive in original row
order and the backward needs no re-sort — it reuses the CSR-class backward
(``bwd_impl_for``), pricing ``dB = Aᵀ·dC`` with Aᵀ's own (unsorted)
structure. The forward's permutation is a pure reordering of the same f32
sums, not a different linearization.

``batched_spmm_hybrid_xla`` is the pure-XLA sibling (registry name
``"hybrid"``): the identical classify/split, expressed as a hub-slab
``einsum`` plus an ELL remainder whose static width is ``dmin - 1`` — the
same dense/sparse routing without a Pallas launch, timeable on CPU.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.batching import HybridPlan
from repro.core.formats import (
    BatchedCOO,
    coo_to_csr,
    coo_to_ell,
    narrow_col_ids,
    row_degrees,
)
from repro.kernels import ref, resolve_interpret


def hybrid_operands(row_ids, col_ids, values, nnz, m_pad, plan: HybridPlan):
    """Trace-safe prep: sort, classify and bin one batch for the kernel.

    Returns ``(rank, start_s, rlen_sparse, rowmax_bins, cid_flat, val_flat,
    slab)`` where

    - ``rank[b, r]`` is row r's position in matrix b's descending-degree
      order (the inverse permutation the epilogue applies);
    - ``start_s``/``rlen_sparse`` are the CSR row pointers *permuted into
      sorted order* (the flat nnz arrays are NOT re-sorted — the kernel
      gathers at arbitrary offsets), with hub rows zeroed out of the
      sparse path;
    - ``rowmax_bins[b, j]`` is bin j's own trip bound (``max rlen`` within
      the bin) — the load-balancing decision, as SMEM scalars;
    - ``slab`` is the ``(batch, d_pad, m_pad)`` dense hub operand, or
      ``None`` when ``plan.d_pad == 0`` (degenerate guard: no hub can
      exist, no MXU tile group is built).
    """
    a = BatchedCOO(row_ids=row_ids, col_ids=col_ids, values=values,
                   nnz=nnz, n_rows=jnp.full((row_ids.shape[0],), m_pad,
                                            jnp.int32))
    deg = row_degrees(a, m_pad)                          # (batch, m_pad)
    perm = jnp.argsort(-deg, axis=1, stable=True)        # sorted -> original
    rank = jnp.argsort(perm, axis=1).astype(jnp.int32)   # original -> sorted
    csr = coo_to_csr(a, m_pad)
    start = csr.rpt[:, :-1]
    rlen = csr.rpt[:, 1:] - csr.rpt[:, :-1]
    start_s = jnp.take_along_axis(start, perm, axis=1)
    rlen_s = jnp.take_along_axis(rlen, perm, axis=1)
    # descending stable sort ⟹ sorted positions [0, n_dense) are EXACTLY the
    # rows with deg >= dmin (ties at the threshold classify dense)
    n_dense = jnp.minimum(jnp.sum(deg >= plan.dmin, axis=1),
                          plan.d_pad).astype(jnp.int32)
    pos_iota = jnp.arange(m_pad, dtype=jnp.int32)[None, :]
    rlen_sparse = jnp.where(pos_iota < n_dense[:, None], 0, rlen_s)
    rowmax_bins = jnp.stack(
        [jnp.max(rlen_sparse[:, s:e], axis=1) for s, e in plan.bins],
        axis=1).astype(jnp.int32)                        # (batch, nbins)
    slab = None
    if plan.d_pad:
        def one_slab(rid, cid, val, nnz_b, rank_b, nd):
            pos = jnp.take(rank_b, jnp.clip(rid, 0, m_pad - 1))
            ok = (jnp.arange(rid.shape[0]) < nnz_b) & (pos < nd)
            return jnp.zeros((plan.d_pad + 1, m_pad), val.dtype).at[
                jnp.where(ok, pos, plan.d_pad), cid
            ].add(jnp.where(ok, val, 0))[:plan.d_pad]

        slab = jax.vmap(one_slab)(row_ids, col_ids, values, nnz, rank,
                                  n_dense)
    return rank, start_s, rlen_sparse, rowmax_bins, csr.col_ids, \
        csr.values, slab


def _kernel(*refs, bins, d_pad: int, has_scale: bool):
    if has_scale:
        scale_ref, refs = refs[0], refs[1:]
    else:
        scale_ref = None
    if d_pad:
        (rowmax_ref, rank_ref, start_ref, rlen_ref, cid_ref, val_ref,
         slab_ref, b_ref, c_ref) = refs
    else:
        (rowmax_ref, rank_ref, start_ref, rlen_ref, cid_ref, val_ref,
         b_ref, c_ref) = refs
        slab_ref = None
    start = start_ref[0]                     # (m_pad,) int32, sorted order
    rlen = rlen_ref[0]                       # (m_pad,) int32, hubs zeroed
    cid = cid_ref[0]                         # (nnz_pad,) flat, CSR order
    val = val_ref[0]
    bb = b_ref[0]                            # (m_pad, n_block)
    nnz_pad = cid.shape[0]

    # sparse remainder: the CSR scatter loop, statically unrolled over the
    # degree-sorted work bins — each bin pays only ITS OWN max degree
    parts = []
    for j, (s, e) in enumerate(bins):
        st = start[s:e]
        rl = rlen[s:e]

        def body(k, acc, st=st, rl=rl):
            idx = jnp.minimum(st + k, nnz_pad - 1)
            live = (k < rl)[:, None]
            c = jnp.take(cid, idx, axis=0).astype(jnp.int32)
            rows = jnp.take(bb, c, axis=0).astype(jnp.float32)
            e_ = jnp.take(val, idx, axis=0).astype(jnp.float32)[:, None]
            return acc + jnp.where(live, rows * e_, 0.0)

        parts.append(jax.lax.fori_loop(
            0, rowmax_ref[0, j], body,
            jnp.zeros((e - s, bb.shape[1]), jnp.float32)))
    acc = jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]

    if d_pad:
        # hub rows: one dense GEMM tile on the MXU replaces up to dmin..m_pad
        # scatter-loop trips per row
        dense = jax.lax.dot_general(
            slab_ref[0].astype(jnp.float32), bb.astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        head = acc[:d_pad] + dense
        acc = head if d_pad == acc.shape[0] else \
            jnp.concatenate([head, acc[d_pad:]], axis=0)

    # fused epilogue: inverse permutation — out[r] = acc_sorted[rank[r]] —
    # so the caller sees original row order
    acc = jnp.take(acc, rank_ref[0], axis=0)
    if has_scale:
        acc = acc * scale_ref[0]
    c_ref[0] = acc.astype(c_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("plan", "narrow", "interpret"))
def batched_spmm_hybrid(
    row_ids: jax.Array,   # (batch, nnz_pad) int32
    col_ids: jax.Array,   # (batch, nnz_pad) int32
    values: jax.Array,    # (batch, nnz_pad) f32/bf16
    nnz: jax.Array,       # (batch,) int32
    b: jax.Array,         # (batch, m_pad, n_b)
    *,
    plan: HybridPlan,
    narrow: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    batch, nnz_pad = col_ids.shape
    m_pad, n_b = b.shape[-2], b.shape[-1]
    base = plan.spmm
    assert base.batch == batch and base.m_pad == m_pad and base.n_b == n_b, \
        plan
    rank, start_s, rlen_sparse, rowmax_bins, cid_f, val_f, slab = \
        hybrid_operands(row_ids, col_ids, values, nnz, m_pad, plan)
    if narrow:
        cid_f = narrow_col_ids(cid_f, m_pad)

    n_block, p = base.n_block, base.p
    if n_b % n_block:
        b = jnp.pad(b, ((0, 0), (0, 0), (0, p * n_block - n_b)))

    in_specs = [
        pl.BlockSpec((1, plan.nbins), lambda i, j: (i, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, m_pad), lambda i, j: (i, 0)),   # rank
        pl.BlockSpec((1, m_pad), lambda i, j: (i, 0)),   # start (sorted)
        pl.BlockSpec((1, m_pad), lambda i, j: (i, 0)),   # rlen (sparse-only)
        pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)),
        pl.BlockSpec((1, nnz_pad), lambda i, j: (i, 0)),
    ]
    operands = [rowmax_bins, rank, start_s, rlen_sparse, cid_f, val_f]
    if plan.d_pad:
        in_specs.append(
            pl.BlockSpec((1, plan.d_pad, m_pad), lambda i, j: (i, 0, 0)))
        operands.append(slab)
    in_specs.append(pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)))
    operands.append(b)

    out = pl.pallas_call(
        functools.partial(_kernel, bins=plan.bins, d_pad=plan.d_pad,
                          has_scale=False),
        grid=(batch, p),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, p * n_block), b.dtype),
        interpret=interpret,
    )(*operands)
    return out[..., :n_b]


def batched_spmm_hybrid_xla(a: BatchedCOO, b: jax.Array, m_pad: int, *,
                            plan: HybridPlan) -> jax.Array:
    """Pure-XLA sibling (``impl="hybrid"``): the same degree split without a
    Pallas launch.

    Hub rows (``deg >= plan.dmin``) go through a dense ``(d_pad, m_pad)``
    slab GEMM; the remainder goes through ELL whose static width is
    ``dmin - 1`` — sound because every non-hub row has ``deg < dmin`` by
    classification, so ``coo_to_ell`` can never silently drop a slot. Hub
    non-zeros are excluded from the ELL build via the ``rid >= m_pad``
    sentinel rewrite (the documented drop rule of ``coo_to_ell``).
    """
    deg = row_degrees(a, m_pad)
    is_hub = deg >= plan.dmin
    rid_sp = jax.vmap(
        lambda rid, hub: jnp.where(
            jnp.take(hub, jnp.clip(rid, 0, m_pad - 1)), m_pad, rid)
    )(a.row_ids, is_hub)
    k_sparse = max(1, plan.dmin - 1)
    ell = coo_to_ell(dataclasses.replace(a, row_ids=rid_sp), m_pad, k_sparse)
    out = ref.batched_spmm_ell_ref(ell, b)
    if not plan.d_pad:
        # degenerate guard: nnz_pad < dmin ⟹ no row can classify dense —
        # skip the slab entirely rather than emit an empty GEMM
        return out
    # hubs first (stable ⟹ original row order within the hub group), so the
    # slab row for hub h is its rank among hubs
    order = jnp.argsort(jnp.where(is_hub, 0, 1).astype(jnp.int32), axis=1,
                        stable=True)
    inv = jnp.argsort(order, axis=1)
    n_dense = jnp.minimum(jnp.sum(is_hub, axis=1), plan.d_pad)

    def one(rid, cid, val, nnz_b, inv_b, nd, rows_idx, bb):
        pos = jnp.take(inv_b, jnp.clip(rid, 0, m_pad - 1))
        ok = (jnp.arange(rid.shape[0]) < nnz_b) & (pos < nd)
        slab = jnp.zeros((plan.d_pad + 1, m_pad), val.dtype).at[
            jnp.where(ok, pos, plan.d_pad), cid
        ].add(jnp.where(ok, val, 0))[:plan.d_pad]
        hub = jnp.einsum("dm,mn->dn", slab, bb,
                         preferred_element_type=jnp.float32)
        valid = jnp.arange(plan.d_pad) < nd
        return jnp.zeros((m_pad + 1, bb.shape[-1]), jnp.float32).at[
            jnp.where(valid, rows_idx, m_pad)
        ].add(jnp.where(valid[:, None], hub, 0.0))[:m_pad]

    hub_out = jax.vmap(one)(a.row_ids, a.col_ids, a.values, a.nnz, inv,
                            n_dense, order[:, :plan.d_pad], b)
    return (out.astype(jnp.float32) + hub_out).astype(b.dtype)
