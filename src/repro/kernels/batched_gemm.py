"""Batched dense GEMM — the cuBLAS ``gemmBatched()`` analogue the paper
benchmarks against (§V-A), as an MXU-tiled Pallas kernel.

One grid step computes one (matrix × column panel) product with the full K
dimension resident in VMEM (the matrices are small — that is the paper's whole
premise), so there is no K-loop and no revisit traffic. On TPU this baseline
is *stronger* relative to SpMM than on the P100 because dense 128×128 tiles
are exactly what the MXU wants; the benchmarks report this honestly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.batching import BatchPlan
from repro.kernels import resolve_interpret


def _kernel(a_ref, b_ref, c_ref):
    c_ref[0] = jax.lax.dot_general(
        a_ref[0], b_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("plan", "interpret"))
def batched_gemm(
    a: jax.Array,         # (batch, m_pad, k)
    b: jax.Array,         # (batch, k, n)
    *,
    plan: BatchPlan,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = resolve_interpret(interpret)
    batch, m_pad, k = a.shape
    n = b.shape[-1]
    n_block, p = plan.n_block, plan.p
    if n % n_block:
        b = jnp.pad(b, ((0, 0), (0, 0), (0, p * n_block - n)))

    out = pl.pallas_call(
        _kernel,
        grid=(batch, p),
        in_specs=[
            pl.BlockSpec((1, m_pad, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, k, n_block), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, p * n_block), b.dtype),
        interpret=interpret,
    )(a, b)
    return out[..., :n]
