"""Pallas TPU kernels (validated with interpret=True on CPU) + jnp oracles.

``default_interpret()`` is the single resolver for the kernels' execution
posture: every kernel entry point takes ``interpret=None`` and resolves it
here, so compiled execution on a real TPU backend does not require threading
``interpret=False`` through every call site.
"""
from __future__ import annotations

import os

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """Process-default for the kernels' ``interpret`` flag.

    Precedence:

    1. ``REPRO_INTERPRET`` env var, when set ("1"/"true"/... forces interpret
       mode, "0"/"false"/... forces compiled Mosaic lowering);
    2. otherwise auto: ``False`` on a real TPU backend (compiled execution),
       ``True`` everywhere else (CPU/GPU, where the Pallas TPU kernels only
       run under the Python interpreter).

    Resolution happens at trace time: a jitted call that already traced with
    one posture does not re-read the env var.
    """
    env = os.environ.get("REPRO_INTERPRET")
    if env is not None:
        v = env.strip().lower()
        if v in _TRUTHY:
            return True
        if v in _FALSY:
            return False
        raise ValueError(
            f"REPRO_INTERPRET={env!r}: expected one of {_TRUTHY + _FALSY}")
    import jax

    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` → :func:`default_interpret`; an explicit bool passes through."""
    return default_interpret() if interpret is None else bool(interpret)
