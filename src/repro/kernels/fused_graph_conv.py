"""Fused graph-conv megakernel with skew-aware nnz packing (DESIGN.md §7).

One Pallas grid step computes, for one (matrix × output-column panel), the
ENTIRE Fig. 7 layer ``Y = Σ_ch A_ch · (X·W_ch + b_ch)`` plus an optional
ReLU/residual epilogue:

- the feature transform ``X·W_ch + b_ch`` runs on the MXU and its product
  ``U_ch`` never leaves VMEM — the unfused path's per-channel
  ``(batch, m_pad, n_out)`` MatMul/Add intermediates, which each round-trip
  through HBM, disappear;
- ``U_ch`` is immediately consumed by the one-hot-scatter SpMM of
  ``batched_spmm_coo.py`` (atomics → MXU contraction, DESIGN.md §2);
- the channel sum accumulates in a single f32 VMEM accumulator, written to
  HBM exactly once per panel.

Device-op structure per layer: 4·channels ops (MatMul, Add, Batched SpMM,
channel-sum per edge channel) → ONE ``pallas_call`` — the paper's
O(channel·batchsize) → O(channel) launch reduction taken the rest of the way
to O(1), in the spirit of GE-SpMM/Accel-GCN's fused aggregation stage.

**Skew-aware nnz packing**: the per-channel non-zero loop is bounded by each
graph's REAL chunk count (``ceil(nnz[s, ch] / CHUNK)``, read from SMEM) rather
than the batch-max ``nnz_pad`` — on a skewed batch the padded slots the COO
kernel multiplies by 0.0 are simply never visited. The static, auditable side
of the same decision lives in ``BatchPlan.sample_chunks``
(``core/batching.plan_fused_graph_conv``).

The custom VJP recomputes ``U_ch`` (cheap: one einsum) instead of storing it,
runs dU = A_chᵀ·dZ as ONE channel-stacked batched SpMM, and reduces
dW/db/dX with dense contractions — so training through the fused layer keeps
the same batched-op structure as the forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.batching import (
    CHUNK,
    BatchPlan,
    HybridPlan,
    plan_fused_graph_conv,
    plan_hybrid,
)
from repro.kernels import resolve_interpret

EPILOGUES = ("none", "relu")


def _kernel(chunks_ref, rid_ref, cid_ref, val_ref, x_ref, w_ref, b_ref,
            *rest, channels: int, total_chunks: int, epilogue: str,
            has_residual: bool, d_pad: int = 0):
    rest = list(rest)
    if d_pad:           # hybrid dispatch (DESIGN.md §12): inverse-perm + slab
        rank_ref, slab_ref = rest[0], rest[1]
        rest = rest[2:]
    if has_residual:
        res_ref, c_ref = rest
    else:
        (c_ref,) = rest
    m_pad = c_ref.shape[1]
    xx = x_ref[0].astype(jnp.float32)                     # (m_pad, n_in)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (CHUNK, m_pad), 1)
    acc = jnp.zeros(c_ref.shape[1:], jnp.float32)
    if d_pad:
        dacc = jnp.zeros((d_pad, c_ref.shape[2]), jnp.float32)

    for ch in range(channels):    # static unroll; channels is small (bond types)
        # feature transform on the MXU — U_ch never leaves VMEM
        u = jax.lax.dot_general(
            xx, w_ref[ch].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) + b_ref[ch].astype(jnp.float32)[None, :]

        if d_pad:
            # hub rows: this channel's pre-gathered dense tiles contract
            # against U_ch on the MXU — no scatter loop for the heavy rows
            dacc = dacc + jax.lax.dot_general(
                slab_ref[0, ch].astype(jnp.float32), u,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

        def body(i, a, u=u, ch=ch):
            sl = pl.dslice(i * CHUNK, CHUNK)
            # ids may be narrowed int16 storage (DESIGN.md §10); widen to
            # int32 for the take / iota compare
            rid = rid_ref[0, ch, sl].astype(jnp.int32)    # (CHUNK,)
            cid = cid_ref[0, ch, sl].astype(jnp.int32)
            val = val_ref[0, ch, sl].astype(jnp.float32)
            g = jnp.take(u, cid, axis=0) * val[:, None]
            p1 = (rid[:, None] == row_iota).astype(jnp.float32)
            # scatter-add as MXU contraction (DESIGN.md §2)
            return a + jax.lax.dot_general(
                p1, g, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

        # skew-aware bound: this graph's real chunk count, not the batch max
        n_ch = jnp.minimum(chunks_ref[0, ch], total_chunks)
        acc = jax.lax.fori_loop(0, n_ch, body, acc)

    if d_pad:
        # accumulator is in SORTED row order (hub rows first); merge the MXU
        # tiles, then the inverse permutation is fused into the epilogue so
        # outputs leave in the original row order (DESIGN.md §12)
        head = acc[:d_pad] + dacc
        acc = head if d_pad == m_pad else jnp.concatenate([head, acc[d_pad:]])
        acc = jnp.take(acc, rank_ref[0].astype(jnp.int32), axis=0)

    if has_residual:
        acc = acc + res_ref[0].astype(jnp.float32)
    if epilogue == "relu":
        acc = jnp.maximum(acc, 0.0)
    c_ref[0] = acc.astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("plan", "epilogue", "interpret"))
def fused_forward(
    row_ids: jax.Array,     # (batch, channels, nnz_pad) int32
    col_ids: jax.Array,     # (batch, channels, nnz_pad) int32
    values: jax.Array,      # (batch, channels, nnz_pad)
    chunks: jax.Array,      # (batch, channels) int32 — real CHUNK counts
    x: jax.Array,           # (batch, m_pad, n_in)
    w: jax.Array,           # (channels, n_in, n_out)
    bias: jax.Array,        # (channels, n_out)
    residual: jax.Array | None = None,   # (batch, m_pad, n_out)
    rank: jax.Array | None = None,       # (batch, m_pad) int32 — hybrid inverse perm
    slab: jax.Array | None = None,       # (batch, channels, d_pad, m_pad) hub tiles
    *,
    plan: BatchPlan,
    epilogue: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Raw fused forward (no VJP) — shared by the local custom-VJP wrapper and
    the mesh-sharded per-shard dispatch (``distributed/spmm.py``).

    ``rank``/``slab`` (set together by :func:`fused_hybrid_forward`) switch on
    the hybrid dispatch: per-channel hub tiles are contracted on the MXU and
    the accumulator — built in degree-sorted row order — is inverse-permuted
    before the residual/ReLU epilogue."""
    interpret = resolve_interpret(interpret)
    assert (rank is None) == (slab is None), "rank/slab must be set together"
    d_pad = 0 if slab is None else slab.shape[2]
    if epilogue not in EPILOGUES:
        raise ValueError(f"epilogue={epilogue!r}; expected one of {EPILOGUES}")
    batch, channels, nnz_pad = row_ids.shape
    m_pad, n_in = x.shape[1], x.shape[2]
    n_out = w.shape[-1]
    assert plan.batch == batch and plan.m_pad == m_pad and plan.n_b == n_out, \
        (plan, row_ids.shape, x.shape, w.shape)

    if nnz_pad % CHUNK:
        pad = CHUNK - nnz_pad % CHUNK
        # padded rid points past the one-hot range so the slots are inert even
        # structurally; padded values are 0.0 anyway
        row_ids = jnp.pad(row_ids, ((0, 0), (0, 0), (0, pad)),
                          constant_values=m_pad)
        col_ids = jnp.pad(col_ids, ((0, 0), (0, 0), (0, pad)))
        values = jnp.pad(values, ((0, 0), (0, 0), (0, pad)))
        nnz_pad += pad
    total_chunks = nnz_pad // CHUNK

    n_block, p = plan.n_block, plan.p
    if n_out % n_block:
        padc = p * n_block - n_out
        w = jnp.pad(w, ((0, 0), (0, 0), (0, padc)))
        bias = jnp.pad(bias, ((0, 0), (0, padc)))
        if residual is not None:
            residual = jnp.pad(residual, ((0, 0), (0, 0), (0, padc)))

    in_specs = [
        pl.BlockSpec((1, channels), lambda i, j: (i, 0),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, channels, nnz_pad), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, channels, nnz_pad), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, channels, nnz_pad), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, m_pad, n_in), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((channels, n_in, n_block), lambda i, j: (0, 0, j)),
        pl.BlockSpec((channels, n_block), lambda i, j: (0, j)),
    ]
    operands = [chunks.astype(jnp.int32), row_ids, col_ids, values, x, w, bias]
    if d_pad:
        in_specs.append(pl.BlockSpec((1, m_pad), lambda i, j: (i, 0)))
        in_specs.append(pl.BlockSpec((1, channels, d_pad, m_pad),
                                     lambda i, j: (i, 0, 0, 0)))
        operands += [rank.astype(jnp.int32), slab]
    if residual is not None:
        in_specs.append(pl.BlockSpec((1, m_pad, n_block),
                                     lambda i, j: (i, 0, j)))
        operands.append(residual)

    out = pl.pallas_call(
        functools.partial(
            _kernel, channels=channels, total_chunks=total_chunks,
            epilogue=epilogue, has_residual=residual is not None,
            d_pad=d_pad),
        grid=(batch, p),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m_pad, n_block), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, p * n_block), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[..., :n_out]


def runtime_chunks(nnz: jax.Array) -> jax.Array:
    """Trace-safe skew-aware chunk counts: ``ceil(nnz / CHUNK)`` per
    (sample × channel), from the BatchedCOO ``nnz`` metadata."""
    return ((nnz + CHUNK - 1) // CHUNK).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("plan", "hplan", "epilogue", "interpret"))
def fused_hybrid_forward(
    row_ids: jax.Array,     # (batch, channels, nnz_pad) int32
    col_ids: jax.Array,     # (batch, channels, nnz_pad) int32
    values: jax.Array,      # (batch, channels, nnz_pad)
    nnz: jax.Array,         # (batch, channels) int32 — true nnz per channel
    x: jax.Array,           # (batch, m_pad, n_in)
    w: jax.Array,           # (channels, n_in, n_out)
    bias: jax.Array,        # (channels, n_out)
    residual: jax.Array | None = None,
    *,
    plan: BatchPlan,
    hplan: HybridPlan,
    epilogue: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """The degree-binned hybrid dispatch folded into the fused megakernel
    (DESIGN.md §12): fully traced prep, one ``pallas_call``.

    Hub-ness is a property of the OUTPUT row across the whole layer, so rows
    are classified by their degree summed over edge channels. Hub rows' edges
    leave the one-hot scatter stream entirely — gathered into per-channel
    ``(d_pad, m_pad)`` dense tiles contracted on the MXU — and the surviving
    sparse slots are compacted to the front of each channel so the skew-aware
    chunk loop shrinks by exactly the work the MXU absorbed. Sparse slots
    target the degree-SORTED row position; the kernel merges the MXU head and
    inverse-permutes the accumulator before the epilogue, so outputs (and
    therefore cotangents) stay in original row order and the backward runs on
    the ORIGINAL arrays (``fused_bwd`` unchanged — exact by bilinearity).

    The layer-level padding semantics is the §IV-C VALUE invariant (padded
    slots carry value 0.0 and may sit ANYWHERE the visited chunks cover, not
    just past the ``nnz`` prefix — the channel streams are slot-permuted
    views), so slots are classified live by ``value != 0``, exactly the
    property that makes them inert in the plain kernel. Re-targeted hub and
    dead slots map to the ``m_pad`` row-id sentinel, structurally inert in
    the one-hot.
    """
    interpret = resolve_interpret(interpret)
    batch, channels, nnz_pad = row_ids.shape
    m_pad = x.shape[1]
    assert hplan.spmm.m_pad == m_pad, (hplan, x.shape)
    if hplan.d_pad == 0:
        # degenerate split (layer nnz budget below dmin): no row can be a
        # hub, so the MXU tile group would be empty — plain fused kernel
        return fused_forward(row_ids, col_ids, values, runtime_chunks(nnz),
                             x, w, bias, residual, plan=plan,
                             epilogue=epilogue, interpret=interpret)

    f32 = jnp.float32
    live = values != 0                                       # §IV-C: by value
    rid_c = jnp.clip(row_ids.astype(jnp.int32), 0, m_pad - 1)
    cid_c = jnp.clip(col_ids.astype(jnp.int32), 0, m_pad - 1)

    def sample_deg(rids_s, live_s):
        tgt = jnp.where(live_s, rids_s, m_pad).reshape(-1)
        return jnp.zeros((m_pad + 1,), jnp.int32).at[tgt].add(1)[:m_pad]

    deg = jax.vmap(sample_deg)(rid_c, live)                  # (batch, m_pad)
    perm = jnp.argsort(-deg, axis=1, stable=True)
    rank = jnp.argsort(perm, axis=1).astype(jnp.int32)       # inverse perm
    n_dense = jnp.minimum(
        jnp.sum((deg >= hplan.dmin).astype(jnp.int32), axis=1),
        hplan.d_pad).astype(jnp.int32)                       # (batch,)

    # sorted row position of every slot; hub slots are the ones landing in
    # the first n_dense sorted rows. Routing ignores liveness: dead slots
    # carry value 0.0, so wherever they land they contribute nothing.
    pos = jax.vmap(lambda r, i: r[i])(
        rank, rid_c.reshape(batch, -1)).reshape(rid_c.shape)
    is_hub = pos < n_dense[:, None, None]

    rid_m = jnp.where(is_hub, m_pad, pos)
    # compact live sparse slots to the front so runtime chunk counts shrink;
    # the tail (hub slots, dead slots) stays inert — by sentinel or by value
    live_sp = live & ~is_hub
    order = jnp.argsort(jnp.where(live_sp, 0, 1).astype(jnp.int32),
                        axis=2, stable=True)
    rid_s = jnp.take_along_axis(rid_m, order, axis=2)
    cid_s = jnp.take_along_axis(col_ids, order, axis=2)
    val_s = jnp.take_along_axis(values, order, axis=2)
    nnz_sparse = jnp.sum(live_sp.astype(jnp.int32), axis=2)

    def one_slab(pos_sc, hub_sc, cid_sc, val_sc):
        d = jnp.where(hub_sc, pos_sc, hplan.d_pad)
        return jnp.zeros((hplan.d_pad + 1, m_pad), f32).at[d, cid_sc].add(
            jnp.where(hub_sc, val_sc.astype(f32), 0.0))[:hplan.d_pad]

    slab = jax.vmap(jax.vmap(one_slab))(
        pos, is_hub, cid_c, values).astype(values.dtype)

    return fused_forward(rid_s, cid_s, val_s, runtime_chunks(nnz_sparse),
                         x, w, bias, residual, rank, slab, plan=plan,
                         epilogue=epilogue, interpret=interpret)


def fused_bwd(rids, cids, values, x, w, bias, y, dy, *,
              epilogue: str, interpret: bool, has_residual: bool,
              bwd_impl: str):
    """Backward through the fused layer, shared by the local custom VJP and
    the mesh-sharded per-shard backward.

    dZ = dY masked by the epilogue; dU_ch = A_chᵀ·dZ runs as ONE
    channel-stacked batched SpMM (indices swapped — free in COO, §IV-D);
    dValues is the batched gather-dot against the recomputed U_ch; dX/dW/db
    are dense contractions of dU. Returns (dvalues, dx, dw, db, dresidual).
    Like the unfused VJP, dValues is taken over every slot (padded slots
    carry value 0.0, so the linearization point is identical).
    """
    from repro.kernels.ops import _forward, dvalues

    batch, channels, nnz_pad = rids.shape
    m_pad = x.shape[1]
    n_out = w.shape[-1]
    f32 = jnp.float32
    dy = dy.astype(f32)
    dz = dy * (y > 0) if epilogue == "relu" else dy
    dres = dz if has_residual else None

    # channel-major stacking: one (channels·batch) batched call, not a loop
    def flat(t):
        return t.transpose(1, 0, 2).reshape(channels * batch, -1)

    rids_f, cids_f, vals_f = flat(rids), flat(cids), flat(values)
    dz_f = jnp.broadcast_to(
        dz[None], (channels, batch, m_pad, n_out)
    ).reshape(channels * batch, m_pad, n_out)
    nnz_f = jnp.full((channels * batch,), nnz_pad, jnp.int32)

    du_f = _forward(cids_f, rids_f, nnz_f, vals_f, dz_f,
                    impl=bwd_impl, k_pad=None, interpret=interpret)
    u = jnp.einsum("bmn,cnf->cbmf", x.astype(f32), w.astype(f32)) \
        + bias.astype(f32)[:, None, None, :]
    dvals_f = dvalues(rids_f, cids_f, dz_f,
                      u.reshape(channels * batch, m_pad, n_out))
    dvals = dvals_f.reshape(channels, batch, nnz_pad).transpose(1, 0, 2)
    du = du_f.astype(f32).reshape(channels, batch, m_pad, n_out)
    dx = jnp.einsum("cbmf,cnf->bmn", du, w.astype(f32))
    dw = jnp.einsum("bmn,cbmf->cnf", x.astype(f32), du)
    db = jnp.sum(du, axis=(1, 2))
    return (dvals.astype(values.dtype), dx.astype(x.dtype),
            dw.astype(w.dtype), db.astype(bias.dtype), dres)


def fused_graph_conv(
    row_ids: jax.Array,     # (batch, channels, nnz_pad) int32
    col_ids: jax.Array,     # (batch, channels, nnz_pad) int32
    values: jax.Array,      # (batch, channels, nnz_pad)
    nnz: jax.Array,         # (batch, channels) int32 — true nnz per channel
    x: jax.Array,           # (batch, m_pad, n_in)
    w: jax.Array,           # (channels, n_in, n_out)
    bias: jax.Array,        # (channels, n_out)
    *,
    plan: BatchPlan | None = None,
    epilogue: str = "none",
    residual: jax.Array | None = None,
    interpret: bool | None = None,
    impl: str = "fused",
) -> jax.Array:
    """Y = epilogue(Σ_ch A_ch·(X·W_ch + b_ch) [+ residual]) in ONE device op.

    Differentiable in ``values``, ``x``, ``w``, ``bias`` and ``residual``.
    ``plan=None`` builds the blocking plan from the call's static shapes
    (``core/batching.plan_fused_graph_conv``); pass a plan with
    ``sample_chunks`` when host-side nnz metadata is available so the
    packing decision is recorded statically too.
    """
    interpret = resolve_interpret(interpret)
    batch, channels, nnz_pad = row_ids.shape
    if plan is None:
        plan = plan_fused_graph_conv(
            batch=batch, m_pad=x.shape[1], n_in=x.shape[2], n_out=w.shape[-1],
            channels=channels, nnz_pad=nnz_pad, itemsize=x.dtype.itemsize)
    if plan.case == 3:
        raise ValueError(
            f"m_pad={plan.m_pad} is planner case 3 (> LARGE_M): the fused "
            "megakernel does not batch matrices this large — use the unfused "
            "graph_conv_batched fallback")
    chunks = runtime_chunks(nnz)
    from repro.autotune.cost_model import precision_of
    from repro.kernels.ops import bwd_impl_for
    bwd_impl = bwd_impl_for(impl) if not interpret else "ref"
    has_res = residual is not None
    rids, cids = row_ids, col_ids
    hybrid = precision_of(impl)[0] == "fused_hybrid"
    if hybrid:
        # hub-ness is judged on the layer's whole edge budget (all channels)
        hplan = plan_hybrid(batch=batch, m_pad=plan.m_pad, n_b=w.shape[-1],
                            nnz_pad=channels * nnz_pad,
                            itemsize=x.dtype.itemsize)

    @jax.custom_vjp
    def f(values, x, w, bias, residual):
        if hybrid:
            return fused_hybrid_forward(
                rids, cids, values, nnz, x, w, bias, residual, plan=plan,
                hplan=hplan, epilogue=epilogue, interpret=interpret)
        return fused_forward(rids, cids, values, chunks, x, w, bias, residual,
                             plan=plan, epilogue=epilogue, interpret=interpret)

    def fwd(values, x, w, bias, residual):
        y = f(values, x, w, bias, residual)
        return y, (values, x, w, bias, y)

    def bwd(res_, dy):
        values, xx, ww, bb, y = res_
        dvals, dx, dw, db, dres = fused_bwd(
            rids, cids, values, xx, ww, bb, y, dy, epilogue=epilogue,
            interpret=interpret, has_residual=has_res, bwd_impl=bwd_impl)
        return dvals, dx, dw, db, (dres if has_res else None)

    f.defvjp(fwd, bwd)
    return f(values, x, w, bias, residual)
