"""Pure-jnp oracles for every kernel in this package.

These are the ground truth for the Pallas kernels' allclose sweeps, *and* they
double as the "non-batched" baseline implementations from the paper:

- ``spmm_coo_single``  == TensorFlow's SparseTensorDenseMatMul (paper Fig. 2),
  one matrix at a time, which the paper benchmarks as "SpMM (TF)".
- ``batched_spmm_*_ref`` are the batched semantics (vmap of the single-sample
  op over the padded batch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import BatchedCOO, BatchedCSR, BatchedELL


# ---------------------------------------------------------------------------
# Single-sample (non-batched baseline, paper Fig. 2)
# ---------------------------------------------------------------------------

def spmm_coo_single(
    row_ids: jax.Array,
    col_ids: jax.Array,
    values: jax.Array,
    b: jax.Array,
    m_out: int,
) -> jax.Array:
    """C[rid] += val * B[cid] — SparseTensorDenseMatMul semantics. Padded
    entries (value 0.0) are harmless. Accumulates in f32 regardless of the
    storage dtype (DESIGN.md §10) and casts to ``b.dtype`` on the way out,
    matching the Pallas kernels' f32 VMEM accumulators."""
    gathered = values[:, None].astype(jnp.float32) * b[col_ids].astype(
        jnp.float32
    )
    return (
        jnp.zeros((m_out, b.shape[-1]), jnp.float32).at[row_ids].add(gathered)
    ).astype(b.dtype)


# ---------------------------------------------------------------------------
# Batched references
# ---------------------------------------------------------------------------

def batched_spmm_coo_ref(a: BatchedCOO, b: jax.Array, m_out: int) -> jax.Array:
    """a: BatchedCOO, b: (batch, m_pad, n_b) → (batch, m_out, n_b)."""
    return jax.vmap(lambda r, c, v, bb: spmm_coo_single(r, c, v, bb, m_out))(
        a.row_ids, a.col_ids, a.values, b
    )


def batched_spmm_ell_ref(a: BatchedELL, b: jax.Array) -> jax.Array:
    """a: BatchedELL (batch, m_pad, k), b: (batch, m_pad, n_b).

    C[i] = Σ_k values[i,k] * B[col_ids[i,k]] — atomic-free row-split, the
    SWA-CSR analogue."""

    def one(cid, val, bb):
        rows = bb[cid]                      # (m_pad, k, n_b) gather
        return jnp.einsum(
            "mk,mkn->mn", val, rows, preferred_element_type=jnp.float32
        ).astype(bb.dtype)

    return jax.vmap(one)(a.col_ids, a.values, b)


def batched_spmm_csr_ref(a: BatchedCSR, b: jax.Array) -> jax.Array:
    """CSR row-split semantics via position-in-row masking."""

    def one(rpt, cid, val, bb):
        m_pad = rpt.shape[0] - 1
        nnz_pad = cid.shape[0]
        slot = jnp.arange(nnz_pad)
        # row of each slot = searchsorted over rpt
        rid = jnp.searchsorted(rpt, slot, side="right") - 1
        rid = jnp.clip(rid, 0, m_pad - 1)
        valid = slot < rpt[-1]
        contrib = jnp.where(
            valid[:, None],
            val[:, None].astype(jnp.float32) * bb[cid].astype(jnp.float32),
            0.0,
        )
        return jnp.zeros((m_pad, bb.shape[-1]), jnp.float32).at[rid].add(
            contrib
        ).astype(bb.dtype)

    return jax.vmap(one)(a.rpt, a.col_ids, a.values, b)


def batched_gemm_ref(a_dense: jax.Array, b: jax.Array) -> jax.Array:
    """cuBLAS gemmBatched analogue: (batch, m, k) @ (batch, k, n)."""
    return jax.lax.batch_matmul(
        a_dense.astype(b.dtype), b, precision=jax.lax.Precision.HIGHEST
    )


def grouped_matmul_ref(
    x: jax.Array, group_ids: jax.Array, w: jax.Array
) -> jax.Array:
    """out[i] = x[i] @ w[group_ids[i]] — ragged grouped GEMM oracle (MoE)."""
    return jnp.einsum("td,tdf->tf", x, w[group_ids])
