"""Pure-jnp oracles for every kernel in this package.

These are the ground truth for the Pallas kernels' allclose sweeps, *and* they
double as the "non-batched" baseline implementations from the paper:

- ``spmm_coo_single``  == TensorFlow's SparseTensorDenseMatMul (paper Fig. 2),
  one matrix at a time, which the paper benchmarks as "SpMM (TF)".
- ``batched_spmm_*_ref`` are the batched semantics (vmap of the single-sample
  op over the padded batch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import BatchedCOO, BatchedCSR, BatchedELL


# ---------------------------------------------------------------------------
# Single-sample (non-batched baseline, paper Fig. 2)
# ---------------------------------------------------------------------------

def spmm_coo_single(
    row_ids: jax.Array,
    col_ids: jax.Array,
    values: jax.Array,
    b: jax.Array,
    m_out: int,
) -> jax.Array:
    """C[rid] += val * B[cid] — SparseTensorDenseMatMul semantics. Padded
    entries (value 0.0) are harmless. Accumulates in f32 regardless of the
    storage dtype (DESIGN.md §10) and casts to ``b.dtype`` on the way out,
    matching the Pallas kernels' f32 VMEM accumulators."""
    gathered = values[:, None].astype(jnp.float32) * b[col_ids].astype(
        jnp.float32
    )
    return (
        jnp.zeros((m_out, b.shape[-1]), jnp.float32).at[row_ids].add(gathered)
    ).astype(b.dtype)


# ---------------------------------------------------------------------------
# Batched references
# ---------------------------------------------------------------------------

def batched_spmm_coo_ref(a: BatchedCOO, b: jax.Array, m_out: int) -> jax.Array:
    """a: BatchedCOO, b: (batch, m_pad, n_b) → (batch, m_out, n_b)."""
    return jax.vmap(lambda r, c, v, bb: spmm_coo_single(r, c, v, bb, m_out))(
        a.row_ids, a.col_ids, a.values, b
    )


def batched_spmm_ell_ref(a: BatchedELL, b: jax.Array) -> jax.Array:
    """a: BatchedELL (batch, m_pad, k), b: (batch, m_pad, n_b).

    C[i] = Σ_k values[i,k] * B[col_ids[i,k]] — atomic-free row-split, the
    SWA-CSR analogue."""

    def one(cid, val, bb):
        rows = bb[cid]                      # (m_pad, k, n_b) gather
        return jnp.einsum(
            "mk,mkn->mn", val, rows, preferred_element_type=jnp.float32
        ).astype(bb.dtype)

    return jax.vmap(one)(a.col_ids, a.values, b)


def batched_spmm_csr_ref(a: BatchedCSR, b: jax.Array) -> jax.Array:
    """CSR row-split semantics via position-in-row masking."""

    def one(rpt, cid, val, bb):
        m_pad = rpt.shape[0] - 1
        nnz_pad = cid.shape[0]
        slot = jnp.arange(nnz_pad)
        # row of each slot = searchsorted over rpt
        rid = jnp.searchsorted(rpt, slot, side="right") - 1
        rid = jnp.clip(rid, 0, m_pad - 1)
        valid = slot < rpt[-1]
        contrib = jnp.where(
            valid[:, None],
            val[:, None].astype(jnp.float32) * bb[cid].astype(jnp.float32),
            0.0,
        )
        return jnp.zeros((m_pad, bb.shape[-1]), jnp.float32).at[rid].add(
            contrib
        ).astype(bb.dtype)

    return jax.vmap(one)(a.rpt, a.col_ids, a.values, b)


# ---------------------------------------------------------------------------
# Generalized message passing (g-SpMM): C[r] = reduce_{(r,c) ∈ E} op(B[c], e)
# ---------------------------------------------------------------------------

# Finite stand-in for -inf in the max-reduce accumulators: -inf would make
# the `where` fix-up of empty rows produce inf-inf NaNs under autodiff.
NEG_INF = -3.0e38


def gspmm_combine(u: jax.Array, e: jax.Array | None, op: str) -> jax.Array:
    """The per-edge combine ``op(u, e)``: ``u`` is the gathered B row(s),
    ``e`` the edge value — a scalar broadcast over features or a d_e == n_b
    feature vector. ``copy_lhs`` ignores ``e`` entirely."""
    if op == "copy_lhs":
        return u
    ef = e.astype(jnp.float32)
    if ef.ndim < u.ndim:
        ef = ef[..., None]
    if op == "mul":
        return u * ef
    if op == "add":
        return u + ef
    raise ValueError(f"unknown g-SpMM op {op!r}")


def gspmm_coo_single(
    row_ids: jax.Array,
    col_ids: jax.Array,
    values: jax.Array,    # (nnz_pad,) scalar or (nnz_pad, d_e) vector edges
    b: jax.Array,
    m_out: int,
    nnz: jax.Array,
    *,
    op: str = "mul",
    reduce: str = "sum",
) -> jax.Array:
    """Single-sample g-SpMM, the differentiable ground truth.

    Unlike :func:`spmm_coo_single`, padding is masked EXPLICITLY from
    ``nnz``: the §IV-C padding invariant (value 0.0 contributes nothing)
    only neutralizes the ``(mul, sum)`` corner — an ``add``/``copy_lhs``
    combine or a ``max``/``mean`` reduce would see phantom edges at row 0.
    Zero-degree rows take the identity 0.0 for every reduce (``max`` runs on
    a finite -inf stand-in then rewrites empty rows; ``mean`` guards the
    0/0 with a degree clamp)."""
    nnz_pad = row_ids.shape[0]
    valid = jnp.arange(nnz_pad) < nnz
    u = b[col_ids].astype(jnp.float32)                 # (nnz_pad, n_b)
    msg = gspmm_combine(u, values, op)
    if reduce in ("sum", "mean"):
        msg = jnp.where(valid[:, None], msg, 0.0)
        out = jnp.zeros((m_out, b.shape[-1]), jnp.float32).at[row_ids].add(msg)
        if reduce == "mean":
            deg = jnp.zeros((m_out,), jnp.float32).at[row_ids].add(
                valid.astype(jnp.float32))
            out = out / jnp.maximum(deg, 1.0)[:, None]
        return out.astype(b.dtype)
    if reduce != "max":
        raise ValueError(f"unknown g-SpMM reduce {reduce!r}")
    # max: park invalid slots on an overflow row so their NEG_INF sentinel
    # never competes, then rewrite empty rows to the 0.0 identity
    msg = jnp.where(valid[:, None], msg, NEG_INF)
    rid_eff = jnp.where(valid, row_ids, m_out)
    out = jnp.full((m_out + 1, b.shape[-1]), NEG_INF, jnp.float32)
    out = out.at[rid_eff].max(msg)[:m_out]
    deg = jnp.zeros((m_out + 1,), jnp.float32).at[rid_eff].add(
        valid.astype(jnp.float32))[:m_out]
    return jnp.where(deg[:, None] > 0, out, 0.0).astype(b.dtype)


def batched_gspmm_ref(a: BatchedCOO, b: jax.Array, m_out: int, *,
                      op: str = "mul", reduce: str = "sum") -> jax.Array:
    """Batched pure-jnp g-SpMM oracle: vmap of :func:`gspmm_coo_single`.
    Differentiable in ``a.values`` and ``b`` — the autodiff grads of THIS
    function are the ground truth the custom-VJP backwards are tested
    against (tests/oracle.py)."""
    return jax.vmap(
        lambda r, c, v, bb, n: gspmm_coo_single(r, c, v, bb, m_out, n,
                                                op=op, reduce=reduce)
    )(a.row_ids, a.col_ids, a.values, b, a.nnz)


def batched_gspmm_ell_ref(a: BatchedELL, rlen: jax.Array, b: jax.Array, *,
                          op: str = "mul", reduce: str = "sum") -> jax.Array:
    """XLA row-split g-SpMM over the ELL layout: the Pallas ELL kernel's
    semantics (masked slot loop, per-row live bound ``rlen``) as one gather
    + masked reduce over the slot axis."""

    def one(cid, val, rl, bb):
        m_pad, k_pad = cid.shape
        u = bb[cid].astype(jnp.float32)               # (m_pad, k_pad, n_b)
        msg = gspmm_combine(u, val, op)
        live = (jnp.arange(k_pad)[None, :] < rl[:, None])[..., None]
        if reduce in ("sum", "mean"):
            out = jnp.sum(jnp.where(live, msg, 0.0), axis=1)
            if reduce == "mean":
                out = out / jnp.maximum(rl, 1).astype(jnp.float32)[:, None]
        else:
            out = jnp.max(jnp.where(live, msg, NEG_INF), axis=1)
            out = jnp.where((rl > 0)[:, None], out, 0.0)
        return out.astype(bb.dtype)

    return jax.vmap(one)(a.col_ids, a.values, rlen, b)


def batched_gspmm_csr_ref(a: BatchedCSR, b: jax.Array, *,
                          op: str = "mul", reduce: str = "sum") -> jax.Array:
    """XLA CSR g-SpMM: searchsorted row recovery + masked segment reduce —
    the segment-sum reference of :func:`batched_spmm_csr_ref` generalized to
    the op × reduce matrix."""

    def one(rpt, cid, val, bb):
        m_pad = rpt.shape[0] - 1
        nnz_pad = cid.shape[0]
        slot = jnp.arange(nnz_pad)
        rid = jnp.clip(jnp.searchsorted(rpt, slot, side="right") - 1,
                       0, m_pad - 1)
        valid = slot < rpt[-1]
        u = bb[cid].astype(jnp.float32)
        msg = gspmm_combine(u, val, op)
        deg = (rpt[1:] - rpt[:-1]).astype(jnp.float32)
        if reduce in ("sum", "mean"):
            msg = jnp.where(valid[:, None], msg, 0.0)
            out = jnp.zeros((m_pad, bb.shape[-1]), jnp.float32).at[rid].add(
                msg)
            if reduce == "mean":
                out = out / jnp.maximum(deg, 1.0)[:, None]
        else:
            msg = jnp.where(valid[:, None], msg, NEG_INF)
            out = jnp.full((m_pad, bb.shape[-1]), NEG_INF,
                           jnp.float32).at[rid].max(msg)
            out = jnp.where(deg[:, None] > 0, out, 0.0)
        return out.astype(bb.dtype)

    return jax.vmap(one)(a.rpt, a.col_ids, a.values, b)


def batched_gemm_ref(a_dense: jax.Array, b: jax.Array) -> jax.Array:
    """cuBLAS gemmBatched analogue: (batch, m, k) @ (batch, k, n)."""
    return jax.lax.batch_matmul(
        a_dense.astype(b.dtype), b, precision=jax.lax.Precision.HIGHEST
    )


def grouped_matmul_ref(
    x: jax.Array, group_ids: jax.Array, w: jax.Array
) -> jax.Array:
    """out[i] = x[i] @ w[group_ids[i]] — ragged grouped GEMM oracle (MoE)."""
    return jnp.einsum("td,tdf->tf", x, w[group_ids])
