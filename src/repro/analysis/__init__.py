from repro.analysis.roofline import HW, RooflineReport, analyze  # noqa: F401
