"""Trip-count-aware cost analysis of a compiled (SPMD-partitioned) HLO module.

``compiled.cost_analysis()`` counts every while-loop body ONCE — for
scan-over-layers / scan-over-microbatches programs (ours) that undercounts
FLOPs, bytes and collective traffic by factors of 16–500. This analyzer
re-derives the three roofline inputs directly from ``compiled.as_text()``:

- FLOPs: every ``dot`` op (2·batch·M·N·K from operand shapes + dnums),
  including dots inside fusion computations;
- bytes: operand + result sizes at instruction boundaries (fusion-internal
  ops excluded — they live in registers/VMEM, mirroring XLA's own
  "bytes accessed" convention);
- collective wire bytes: ring-algorithm factors over parsed replica groups;

…then multiplies each computation's cost by the trip count of every while
loop that calls it (parsed from the loop-condition ``compare(iv, constant)``),
recursively, so nested scans (microbatch × layers × attention blocks) are
counted exactly. The module is already partitioned, so every number is
per-device.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id"}
# ops that physically touch only their RESULT-sized region (a slice reads the
# slice, not the whole operand; an in-place update writes the update region)
_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _shape_dims(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            out.append((dtype,
                        tuple(int(d) for d in dims.split(",") if d)))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult


@dataclasses.dataclass
class _Instr:
    name: str
    result_txt: str
    opcode: str
    rest: str


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, list[_Instr]] = {}
        # per-computation symbol tables (names repeat across computations)
        self.shapes: dict[str, dict[str, str]] = {}
        self._parse(text)
        self._cost_cache: dict[str, Cost] = {}
        self.entry = self._find_entry(text)

    def _shape(self, comp: str, name: str) -> str:
        return self.shapes.get(comp, {}).get(name, "")

    def _find_entry(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(line)
                if m:
                    return m.group(1)
        return next(iter(self.comps))

    def _parse(self, text: str) -> None:
        cur = None
        for line in text.splitlines():
            if not line.strip():
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.rstrip().endswith("{"):
                cur = hdr.group(1)
                self.comps[cur] = []
                self.shapes[cur] = {}
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, result_txt, opcode, rest = m.groups()
                self.comps[cur].append(
                    _Instr(name, result_txt, opcode, rest))
                self.shapes[cur][name] = result_txt

    # -- trip counts -----------------------------------------------------

    @staticmethod
    def trip_count(while_rest: str, cond_comp_cost=None) -> float:
        """XLA records `backend_config={"known_trip_count":{"n":"N"}}` on the
        while op after loop analysis — use it directly."""
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"', while_rest)
        if m:
            return float(m.group(1))
        return 1.0

    # -- flops -----------------------------------------------------------

    def _dot_flops(self, comp: str, ins: _Instr) -> float:
        # operands: first two %refs in the call args
        args = ins.rest.split("),")[0]
        ops = _OPERAND_RE.findall(args)
        if len(ops) < 2:
            return 0.0
        lhs = self._shape(comp, ops[0])
        dims = _shape_dims(lhs)
        if not dims:
            return 0.0
        lhs_dims = dims[0][1]
        m = re.search(r"rhs_contracting_dims=\{([\d,]*)\}", ins.rest)
        rhs_contract = ([int(x) for x in m.group(1).split(",") if x]
                        if m else [])
        rhs = self._shape(comp, ops[1])
        rdims = _shape_dims(rhs)
        rhs_dims = rdims[0][1] if rdims else ()
        m = re.search(r"rhs_batch_dims=\{([\d,]*)\}", ins.rest)
        rhs_batch = ([int(x) for x in m.group(1).split(",") if x]
                     if m else [])
        n_free = 1
        for i, d in enumerate(rhs_dims):
            if i not in rhs_contract and i not in rhs_batch:
                n_free *= d
        lhs_prod = 1
        for d in lhs_dims:
            lhs_prod *= d
        return 2.0 * lhs_prod * n_free

    def _fusion_operand_bytes(self, comp: str, called: str,
                              opnames: list) -> int:
        """Operand bytes for a fusion call, slice-aware: when a fusion
        parameter's only consumer is a slice/gather, the fusion reads only
        the sliced region (the dominant pattern in scan bodies, where stacked
        layer params are dynamic-sliced per step)."""
        params = {}
        for ins in self.comps.get(called, []):
            if ins.opcode == "parameter":
                m = re.match(r"(\d+)", ins.rest)
                if m:
                    params[int(m.group(1))] = ins.name
        total = 0
        for i, op in enumerate(opnames):
            full = _shape_bytes(self._shape(comp, op))
            pname = params.get(i)
            if pname is None:
                total += full
                continue
            consumers = [c for c in self.comps.get(called, [])
                         if re.search(rf"%{re.escape(pname)}\b", c.rest)]
            if consumers and all(c.opcode in _SLICE_OPS for c in consumers):
                total += sum(_shape_bytes(c.result_txt) for c in consumers)
            elif consumers and all(
                    c.opcode == "dynamic-update-slice"
                    and (_OPERAND_RE.findall(c.rest) or [None])[0] == pname
                    for c in consumers):
                # param is the DUS *destination*: updated in place; the write
                # is the update region, charged via the update operand below
                total += 0
            else:
                total += full
        return total

    def _fusion_result_bytes(self, called: str, result_txt: str) -> int:
        """Result bytes for a fusion call: when the root is a
        dynamic-update-slice (scan residual stacking), only the update region
        is written."""
        instrs = self.comps.get(called, [])
        by_name = {i.name: i for i in instrs}
        root = instrs[-1] if instrs else None
        # follow bitcast/copy roots to the real producer
        seen = 0
        while root is not None and root.opcode in ("bitcast", "copy") \
                and seen < 4:
            ops = _OPERAND_RE.findall(root.rest)
            root = by_name.get(ops[0]) if ops else None
            seen += 1
        if root is not None and root.opcode == "dynamic-update-slice":
            ops = _OPERAND_RE.findall(root.rest)
            if len(ops) > 1:
                upd = by_name.get(ops[1])
                if upd is not None:
                    return _shape_bytes(upd.result_txt)
                # update may itself be a fusion param
                return min(_shape_bytes(result_txt),
                           _shape_bytes(self.shapes.get(called, {}).get(
                               ops[1], result_txt)))
        return _shape_bytes(result_txt)

    # -- per-computation cost ---------------------------------------------

    def comp_cost(self, comp: str, *, inside_fusion: bool = False) -> Cost:
        key = f"{comp}|{inside_fusion}"
        if key in self._cost_cache:
            return self._cost_cache[key]
        total = Cost()
        self._cost_cache[key] = total  # guards recursion
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            if op == "dot":
                total.flops += self._dot_flops(comp, ins)
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                if mb:
                    trips = self.trip_count(ins.rest)
                    total.add(self.comp_cost(mb.group(1)), trips)
                continue
            if op in ("fusion", "call", "async-start"):
                m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.rest)
                if m:
                    inner = self.comp_cost(m.group(1), inside_fusion=True)
                    # fusion internals contribute flops & collectives but not
                    # HBM bytes (boundary counted below)
                    total.flops += inner.flops
                    total.coll_bytes += inner.coll_bytes
                    for k, v in inner.coll_by_kind.items():
                        total.coll_by_kind[k] = \
                            total.coll_by_kind.get(k, 0.0) + v
                    for k, v in inner.coll_counts.items():
                        total.coll_counts[k] = \
                            total.coll_counts.get(k, 0) + v
            if op == "conditional":
                for mm in re.finditer(
                        r"(?:branch_computations=\{([^}]*)\}|"
                        r"(?:true|false)_computation=%?([\w.\-]+))", ins.rest):
                    names = (mm.group(1) or mm.group(2) or "")
                    for nm in _OPERAND_RE.findall(names) or \
                            [x.strip() for x in names.split(",") if x.strip()]:
                        total.add(self.comp_cost(nm), 1.0)
                    break
            base = op.replace("-start", "") if op.endswith("-start") else op
            if base in ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                rbytes = _shape_bytes(ins.result_txt)
                if base in ("all-reduce", "reduce-scatter") or rbytes == 0:
                    # result of -start can be tuple incl. operand aliases;
                    # use operand shapes
                    opnames = _OPERAND_RE.findall(ins.rest.split(")")[0])
                    obytes = sum(_shape_bytes(self._shape(comp, o))
                                 for o in opnames)
                    rbytes = obytes or rbytes
                n = self._group_size(ins.rest)
                if base == "all-gather":
                    wire = rbytes * (n - 1) / max(n, 1)
                elif base == "reduce-scatter":
                    wire = rbytes * (n - 1) / max(n, 1)
                elif base == "all-reduce":
                    wire = rbytes * 2 * (n - 1) / max(n, 1)
                elif base == "all-to-all":
                    wire = rbytes * (n - 1) / max(n, 1)
                else:
                    wire = rbytes
                total.coll_bytes += wire
                total.coll_by_kind[base] = \
                    total.coll_by_kind.get(base, 0.0) + wire
                total.coll_counts[base] = total.coll_counts.get(base, 0) + 1
            # HBM bytes at instruction boundary
            if not inside_fusion and op not in _SKIP_BYTES and \
                    op != "while":
                opnames = _OPERAND_RE.findall(
                    ins.rest.split(", calls=")[0].split(", to_apply=")[0]
                    .split(", metadata=")[0])[:8]
                if op in _SLICE_OPS:
                    b = 2 * _shape_bytes(ins.result_txt)
                elif op == "dynamic-update-slice":
                    upd = (_shape_bytes(self._shape(comp, opnames[1]))
                           if len(opnames) > 1 else 0)
                    b = 2 * upd
                elif op == "fusion":
                    m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                    if m:
                        b = self._fusion_result_bytes(
                            m.group(1), ins.result_txt)
                        b += self._fusion_operand_bytes(
                            comp, m.group(1), opnames)
                    else:
                        b = _shape_bytes(ins.result_txt)
                        b += sum(_shape_bytes(self._shape(comp, o))
                                 for o in opnames)
                else:
                    b = _shape_bytes(ins.result_txt)
                    b += sum(_shape_bytes(self._shape(comp, o))
                             for o in opnames)
                total.bytes += b
        self._cost_cache[key] = total
        return total

    @staticmethod
    def _group_size(rest: str) -> int:
        m = re.search(r"replica_groups=\{\{([^}]*)\}", rest)
        if m:
            return max(len(m.group(1).split(",")), 1)
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
        if m:
            return max(int(m.group(2)), 1)
        return 1

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry)


def analyze_text(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
