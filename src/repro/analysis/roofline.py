"""Three-term roofline analysis from a compiled (AOT) artifact.

    compute    = HLO_FLOPs_per_device            / peak_FLOP/s
    memory     = HLO_bytes_per_device            / HBM_bw
    collective = collective_wire_bytes_per_device / link_bw

``compiled.cost_analysis()`` runs on the post-SPMD, per-device module, so its
"flops"/"bytes accessed" are already per-chip — dividing the fleet totals by
`chips` (the formula in the brief) lands on the same quantity.

Collective bytes are NOT in cost_analysis: we parse the optimized HLO text
and sum the *wire* bytes of every collective, using standard ring-algorithm
factors over the parsed replica-group size n:

    all-gather          result_bytes × (n-1)/n      (per device leaves)
    reduce-scatter      result_bytes × (n-1)        (operand passes through)
    all-reduce          result_bytes × 2(n-1)/n     (RS + AG)
    all-to-all          result_bytes × (n-1)/n
    collective-permute  result_bytes × 1
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (per chip), from the brief.
@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12     # bf16
    hbm_bw: float = 819e9          # B/s
    ici_bw: float = 50e9           # B/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [num_groups, group_size]
        return max(int(m.group(2)), 1)
    return 1


def collective_bytes(hlo_text: str) -> tuple[float, dict]:
    """Sum wire bytes over every collective op in the optimized HLO."""
    total = 0.0
    per_kind: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        kind = None
        for c in _COLLECTIVES:
            # match `= dtype[...] all-reduce(` and `-start(` variants
            if f" {c}(" in stripped or f" {c}-start(" in stripped:
                kind = c
                break
        if kind is None:
            continue
        lhs = stripped.split("=", 1)[0] if "=" in stripped else ""
        rhs = stripped.split("=", 1)[1] if "=" in stripped else stripped
        # result shapes sit between '=' and the op name
        result_txt = rhs.split(kind)[0]
        rbytes = _shape_bytes(result_txt)
        if rbytes == 0:
            rbytes = _shape_bytes(lhs)
        n = _group_size(stripped)
        if kind == "all-gather":
            wire = rbytes * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            wire = rbytes * max(n - 1, 0)
        elif kind == "all-reduce":
            wire = rbytes * 2 * (n - 1) / max(n, 1)
        elif kind == "all-to-all":
            wire = rbytes * (n - 1) / max(n, 1)
        else:  # collective-permute
            wire = rbytes
        total += wire
        per_kind[kind] = per_kind.get(kind, 0.0) + wire
        counts[kind] = counts.get(kind, 0) + 1
    return total, {"bytes_by_kind": per_kind, "counts": counts}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    cell: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_flops_ratio: float
    peak_fraction: float
    memory_per_device: dict
    collective_detail: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze(compiled, *, arch: str, cell: str, mesh_name: str, chips: int,
            model_flops_total: float, hw: HW = HW()) -> RooflineReport:
    # raw XLA numbers (undercount while-loop bodies — kept for reference)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    # trip-count-aware analysis of the partitioned module (the real numbers)
    from repro.analysis.hlo_cost import analyze_text

    hlo = compiled.as_text()
    tc = analyze_text(hlo)
    flops, bytes_acc, coll = tc.flops, tc.bytes, tc.coll_bytes
    detail = {"bytes_by_kind": tc.coll_by_kind, "counts": tc.coll_counts,
              "raw_cost_analysis": {"flops": raw_flops,
                                    "bytes_accessed": raw_bytes}}

    t_c = flops / hw.peak_flops
    t_m = bytes_acc / hw.hbm_bw
    t_x = coll / hw.ici_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    model_flops_dev = model_flops_total / chips
    useful = model_flops_dev / flops if flops else 0.0
    # fraction of the compute roofline the whole step achieves if it runs at
    # the max of the three terms (the perf score we hillclimb)
    t_step = max(terms.values())
    peak_fraction = (model_flops_dev / hw.peak_flops) / t_step if t_step else 0

    try:
        mem = {k: int(v) for k, v in compiled.memory_analysis().__dict__.items()
               if isinstance(v, (int, float))}
    except Exception:
        ma = compiled.memory_analysis()
        mem = {a: int(getattr(ma, a)) for a in dir(ma)
               if a.endswith("size_in_bytes") and not a.startswith("_")}

    return RooflineReport(
        arch=arch, cell=cell, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=bytes_acc,
        collective_bytes_per_device=coll,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops_total,
        useful_flops_ratio=useful,
        peak_fraction=peak_fraction,
        memory_per_device=mem,
        collective_detail=detail,
    )
