"""Message-passing layer API — the g-SpMM primitive as a layer building
block (DESIGN.md §11), beside ``repro.core.graph_conv``.

Graph convolution fixes the inner op to ``C[r] += val · B[c]`` (weighted-sum
aggregation). Message passing generalizes it to

    ``C[r] = reduce_{edges (r, c)} op(B[c], e)``

with a static ``(op, reduce)`` pair and edge values ``e`` that may be
scalars or per-edge feature vectors — the DGL g-SpMM shape
(arXiv:1909.01315) on the existing batched stack. The batched execution
story is unchanged: ONE device op per call for the whole mini-batch, kernels
shared with plain batched SpMM, ``impl="auto"`` resolved per workload by
``repro.autotune`` (the candidate ladder restricted to the g-SpMM-capable
subset), mesh sharding via ``repro.distributed.spmm``.

The model-zoo layers built on this primitive live in ``repro.models.gnn``:

- ``gat_layer``  — multi-head attention: per-edge logits →
  :func:`repro.kernels.segment_softmax.segment_softmax` → one vector-edge
  ``(mul, sum)`` g-SpMM;
- ``rgcn_layer`` — relation-batched weights via ``grouped_matmul`` + one
  ``(copy_lhs, mean)`` g-SpMM over the relation-flattened batch.
"""
from __future__ import annotations

import jax

from repro.core.formats import BatchedCOO
from repro.kernels.ops import batched_gspmm, resolve_gspmm_impl


def resolve_message_passing_impl(
    adj: BatchedCOO,
    x: jax.Array,
    *,
    op: str = "mul",
    reduce: str = "sum",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
    mesh_axis: str = "data",
):
    """Resolve ``impl`` against one message-passing call's workload.

    Returns a :class:`repro.autotune.Decision`. With ``mesh=``, resolution
    runs against the per-shard workload — the shapes each device actually
    executes (DESIGN.md §6)."""
    if mesh is not None:
        from repro.distributed.spmm import resolve_sharded_gspmm_impl

        return resolve_sharded_gspmm_impl(
            adj, x, mesh, op=op, reduce=reduce, axis=mesh_axis, impl=impl,
            k_pad=k_pad, interpret=interpret)
    return resolve_gspmm_impl(adj, x, op=op, reduce=reduce, impl=impl,
                              k_pad=k_pad, interpret=interpret)


def message_passing(
    adj: BatchedCOO,
    x: jax.Array,                # (batch, m_pad, n_b) node features
    *,
    op: str = "mul",
    reduce: str = "sum",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
    mesh_axis: str = "data",
) -> jax.Array:
    """One batched message-passing step: per sample,
    ``out[r] = reduce_{edges (r, c)} op(x[c], e)`` with ``e = adj.values``
    (scalar per edge, or a ``(batch, nnz_pad, d_e)`` feature vector with
    ``d_e`` equal to the feature width).

    Differentiable in ``adj.values`` and ``x``; zero-degree rows emit the
    0.0 identity with zero gradient for every reduce. ``(mul, sum)`` with
    scalar edges is exactly ``batched_spmm`` and delegates to it (full
    registry, precision variants)."""
    return batched_gspmm(adj, x, op=op, reduce=reduce, impl=impl,
                         k_pad=k_pad, interpret=interpret, mesh=mesh,
                         mesh_axis=mesh_axis)
