"""Batched sparse-matrix containers (paper §II-B, adapted for TPU).

The paper works with three storages: CSR, COO and TensorFlow's SparseTensor
(COO with an (nnz, 2) index array). For a *batch* of small graphs we pad every
matrix in the batch to the batch maxima (``m_pad`` rows, ``nnz_pad`` non-zeros,
``k_pad`` nnz/row for ELL) so the whole batch is a dense, stackable pytree —
this is the TPU analogue of the paper's "launch max(m_A)*subWarp*batch threads
and let the redundant ones terminate immediately" policy (§IV-C): padded slots
carry value 0.0 and index 0, so they contribute nothing.

All containers are registered pytrees; they flow through jit/vmap/pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# Largest row/column index an int16 column-id array can address. The COO
# kernels pad row_ids with the sentinel value ``m_pad`` (one past the last
# row), so the narrowing guard requires m_pad itself — not just m_pad - 1 —
# to fit.
INT16_MAX = 32767


def narrow_col_ids(ids: jax.Array, m_pad: int) -> jax.Array:
    """Narrow an int32 index array to int16 storage (half the index traffic
    of the reduced-precision kernel variants — DESIGN.md §10).

    ``m_pad`` is the exclusive index bound AND the padding sentinel the COO
    kernels append, so the guard is on ``m_pad`` itself. The bound is a
    static shape, so overflow raises host-side — under jit too — instead of
    silently wrapping negative on device.
    """
    if m_pad > INT16_MAX:
        raise ValueError(
            f"m_pad={m_pad} does not fit int16 column indices (max "
            f"{INT16_MAX} including the m_pad padding sentinel): use a "
            "full-precision impl for this geometry")
    return ids.astype(jnp.int16)


def quantize_values_i8(values: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-matrix symmetric int8 quantization of a batched values array.

    Returns ``(codes, scale)``: ``codes`` int8 with the input's shape,
    ``scale`` (batch,) float32 such that ``codes * scale ≈ values``. The
    scale is ``maxabs / 127`` per matrix (1.0 for all-zero matrices so
    dequantization stays well-defined); padded slots are 0.0 and quantize
    to code 0, preserving the §IV-C padding invariant. Because SpMM is
    linear in the values, the scale can be applied to the f32 accumulator
    *after* the kernel — the quantized product is exactly
    ``scale · SpMM(codes, B)``, so the only error is the rounding of the
    codes themselves.
    """
    v = values.astype(jnp.float32)
    axes = tuple(range(1, v.ndim))
    maxabs = jnp.max(jnp.abs(v), axis=axes)
    scale = jnp.where(maxabs > 0, maxabs / 127.0, 1.0).astype(jnp.float32)
    codes = jnp.round(
        v / scale.reshape((-1,) + (1,) * (v.ndim - 1))).astype(jnp.int8)
    return codes, scale


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BatchedCOO:
    """SparseTensor/COO analogue: flat non-zero triples, padded to nnz_pad.

    row_ids, col_ids : (batch, nnz_pad) int32  — padding points at row/col 0
    values           : (batch, nnz_pad) float  — padding is 0.0. g-SpMM edge
                       features may add a trailing axis: (batch, nnz_pad, d_e)
    nnz              : (batch,) int32          — true nnz per matrix
    n_rows           : (batch,) int32          — true m_A per matrix
    """

    row_ids: jax.Array
    col_ids: jax.Array
    values: jax.Array
    nnz: jax.Array
    n_rows: jax.Array

    @property
    def batch(self) -> int:
        return self.values.shape[0]

    @property
    def nnz_pad(self) -> int:
        return self.values.shape[1]

    def with_values(self, values: jax.Array) -> "BatchedCOO":
        return dataclasses.replace(self, values=values)

    def transpose(self, m_pad: int) -> "BatchedCOO":
        """Aᵀ for the backward pass (paper §IV-D: batched SpMM is also used in
        backprop). For COO a transpose is just swapping the index arrays."""
        del m_pad
        return dataclasses.replace(self, row_ids=self.col_ids, col_ids=self.row_ids)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BatchedCSR:
    """CSR analogue (paper Fig. 1/4): row pointers over padded rows.

    rpt     : (batch, m_pad + 1) int32
    col_ids : (batch, nnz_pad) int32
    values  : (batch, nnz_pad) float
    n_rows  : (batch,) int32
    """

    rpt: jax.Array
    col_ids: jax.Array
    values: jax.Array
    n_rows: jax.Array

    @property
    def batch(self) -> int:
        return self.values.shape[0]

    @property
    def m_pad(self) -> int:
        return self.rpt.shape[1] - 1

    @property
    def nnz_pad(self) -> int:
        return self.values.shape[1]

    @property
    def nnz(self) -> jax.Array:
        """(batch,) int32 — true nnz per matrix (the CSR invariant: rpt's
        last entry counts exactly the valid slots)."""
        return self.rpt[:, -1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BatchedELL:
    """Row-padded ELL: the TPU-native layout for the atomic-free row-split
    kernel (the SWA-CSR analogue — see DESIGN.md §2).

    col_ids : (batch, m_pad, k_pad) int32  — padding points at column 0
    values  : (batch, m_pad, k_pad) float  — padding is 0.0 (g-SpMM vector
              edges append a trailing (…, d_e) axis)
    n_rows  : (batch,) int32
    """

    col_ids: jax.Array
    values: jax.Array
    n_rows: jax.Array

    @property
    def batch(self) -> int:
        return self.values.shape[0]

    @property
    def m_pad(self) -> int:
        return self.values.shape[1]

    @property
    def k_pad(self) -> int:
        return self.values.shape[2]


# ---------------------------------------------------------------------------
# Host-side constructors (numpy in, device pytree out)
# ---------------------------------------------------------------------------

def coo_from_lists(
    triples: Sequence[tuple[np.ndarray, np.ndarray, np.ndarray]],
    n_rows: Sequence[int],
    *,
    m_pad: int | None = None,
    nnz_pad: int | None = None,
    dtype=jnp.float32,
) -> BatchedCOO:
    """Build a BatchedCOO from per-sample (rows, cols, vals) numpy triples."""
    batch = len(triples)
    max_nnz = max((len(t[0]) for t in triples), default=1)
    nnz_pad = nnz_pad or max(1, _round_up(max_nnz, 8))
    rid = np.zeros((batch, nnz_pad), np.int32)
    cid = np.zeros((batch, nnz_pad), np.int32)
    val = np.zeros((batch, nnz_pad), np.float32)
    nnz = np.zeros((batch,), np.int32)
    for b, (r, c, v) in enumerate(triples):
        k = len(r)
        rid[b, :k], cid[b, :k], val[b, :k] = r, c, v
        nnz[b] = k
    del m_pad
    return BatchedCOO(
        row_ids=jnp.asarray(rid),
        col_ids=jnp.asarray(cid),
        values=jnp.asarray(val, dtype),
        nnz=jnp.asarray(nnz),
        n_rows=jnp.asarray(np.asarray(n_rows, np.int32)),
    )


def coo_to_csr(coo: BatchedCOO, m_pad: int) -> BatchedCSR:
    """Device-side stable conversion COO → CSR (sorts by row id)."""

    def one(rid, cid, val, nnz):
        nnz_pad = rid.shape[0]
        # Send padding to row m_pad so it sorts to the tail; padded values are
        # already 0.0 so the tail is harmless.
        slot = jnp.arange(nnz_pad)
        valid = slot < nnz
        rid_eff = jnp.where(valid, rid, m_pad)
        order = jnp.argsort(rid_eff, stable=True)
        rid_s, cid_s, val_s = rid_eff[order], cid[order], val[order]
        counts = (
            jnp.zeros((m_pad + 1,), jnp.int32)
            .at[jnp.minimum(rid_s, m_pad)]
            .add(valid[order].astype(jnp.int32))
        )
        rpt = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts[:m_pad])]
        )
        return rpt, cid_s, val_s

    rpt, cid, val = jax.vmap(one)(coo.row_ids, coo.col_ids, coo.values, coo.nnz)
    return BatchedCSR(rpt=rpt, col_ids=cid, values=val, n_rows=coo.n_rows)


def csr_transpose(csr: BatchedCSR, n_cols: int | None = None) -> BatchedCSR:
    """Device-side Aᵀ in CSR for the backward pass (paper §IV-D: Batched SpMM
    is also applied to backprop). COO transposes by swapping index arrays;
    CSR has to re-sort: expand ``rpt`` back to per-slot row ids
    (``searchsorted``), stable-sort by column id, and rebuild the row
    pointers over ``n_cols`` (defaults to square: ``m_pad``). Padded slots
    sort to the tail with value 0.0, exactly like ``coo_to_csr``."""
    n_cols = n_cols or csr.m_pad

    def expand(rpt):
        # per-slot row ids back from the pointers; padded slots clip to the
        # last row but coo_to_csr re-masks them from nnz anyway
        m_pad = rpt.shape[0] - 1
        slot = jnp.arange(csr.nnz_pad)
        return jnp.clip(jnp.searchsorted(rpt, slot, side="right") - 1,
                        0, m_pad - 1)

    coo_t = BatchedCOO(row_ids=csr.col_ids, col_ids=jax.vmap(expand)(csr.rpt),
                       values=csr.values, nnz=csr.nnz, n_rows=csr.n_rows)
    # the sort / padding-to-tail / rpt-rebuild invariant has ONE owner
    return coo_to_csr(coo_t, n_cols)


def row_degrees(coo: BatchedCOO, m_pad: int) -> jax.Array:
    """(batch, m_pad) int32 — the true per-row non-zero count of each sample
    (only valid slots counted; padding rows are 0). This is the g-SpMM
    validity statistic: a ``mean`` reduce divides by it, a ``max`` reduce
    replaces rows where it is 0 with the identity element, and the ELL
    kernel's masked slot loop reads it as the per-row live-slot bound."""

    def one(rid, nnz):
        valid = (jnp.arange(rid.shape[0]) < nnz).astype(jnp.int32)
        return jnp.zeros((m_pad,), jnp.int32).at[
            jnp.clip(rid, 0, m_pad - 1)].add(valid)

    return jax.vmap(one)(coo.row_ids, coo.nnz)


def max_row_degree(coo: BatchedCOO, m_pad: int) -> jax.Array:
    """(batch,) int32 — the true max nnz in any single row of each sample
    (only valid slots counted). This is the statistic ``k_pad`` must cover
    for an ELL conversion to be lossless."""
    return jnp.max(row_degrees(coo, m_pad), axis=1)


def validate_ell_k_pad(coo: BatchedCOO, m_pad: int, k_pad: int,
                       *, on_traced: str = "skip") -> None:
    """Guard against silent ELL nnz drops: raise when any row holds more than
    ``k_pad`` non-zeros (``coo_to_ell`` would zero the overflow out and the
    product would be silently wrong).

    Concrete (eager) inputs raise ``ValueError`` host-side immediately.
    Traced inputs cannot branch on data, so ``on_traced`` selects the
    posture: ``"skip"`` (no runtime cost — the jitted hot path) or
    ``"debug"`` (a ``jax.debug.callback`` assert that raises host-side at
    run time; best-effort on async backends)."""
    if isinstance(coo.row_ids, jax.core.Tracer) or \
            isinstance(coo.nnz, jax.core.Tracer):
        if on_traced == "debug":
            def _assert(deg):
                worst = int(np.max(deg, initial=0))
                if worst > k_pad:
                    raise ValueError(
                        f"coo_to_ell overflow: a row holds {worst} non-zeros "
                        f"but k_pad={k_pad}; the ELL conversion would "
                        "silently drop the excess")
            jax.debug.callback(_assert, max_row_degree(coo, m_pad))
        return
    rid = np.asarray(coo.row_ids)
    nnz = np.asarray(coo.nnz)
    worst = 0
    for b in range(rid.shape[0]):
        k = int(nnz[b])
        if k:
            worst = max(worst, int(np.bincount(rid[b, :k]).max()))
    if worst > k_pad:
        raise ValueError(
            f"k_pad={k_pad} is smaller than the batch's true max row degree "
            f"{worst}: the ELL conversion would silently zero out "
            f"{worst - k_pad} non-zero(s) in the worst row. Size k_pad from "
            "the planner's batch maximum (repro.core.formats.max_row_degree) "
            "or pick a CSR/COO impl, which have no per-row bound.")


def coo_to_ell(coo: BatchedCOO, m_pad: int, k_pad: int,
               *, check: bool = False) -> BatchedELL:
    """Device-side COO → ELL. Slot index within a row is computed with a
    stable sort + per-row running count; rows with > k_pad nnz OVERFLOW —
    their excess non-zeros are dropped (zeroed), so callers must size
    ``k_pad`` from the batch's true max row degree. ``check=True`` guards
    the conversion: concrete inputs raise host-side, traced inputs install
    a runtime debug-assert (see :func:`validate_ell_k_pad`)."""
    if check:
        validate_ell_k_pad(coo, m_pad, k_pad, on_traced="debug")

    def one(rid, cid, val, nnz):
        nnz_pad = rid.shape[0]
        slot = jnp.arange(nnz_pad)
        valid = slot < nnz
        rid_eff = jnp.where(valid, rid, m_pad)
        order = jnp.argsort(rid_eff, stable=True)
        rid_s, cid_s, val_s, valid_s = (
            rid_eff[order],
            cid[order],
            val[order],
            valid[order],
        )
        # position within row = index - first index of this row
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), rid_s[1:] != rid_s[:-1]]
        )
        seg_start = jnp.where(is_start, slot, 0)
        seg_start = jax.lax.associative_scan(jnp.maximum, seg_start)
        k_in_row = slot - seg_start
        ok = valid_s & (rid_s < m_pad) & (k_in_row < k_pad)
        flat = jnp.where(ok, rid_s * k_pad + k_in_row, m_pad * k_pad)
        col_out = (
            jnp.zeros((m_pad * k_pad + 1,), jnp.int32)
            .at[flat]
            .set(jnp.where(ok, cid_s, 0))[:-1]
            .reshape(m_pad, k_pad)
        )
        # values may carry a trailing edge-feature axis (g-SpMM vector
        # edges): the scatter runs over the flat slot axis either way
        tail = val.shape[1:]
        ok_b = ok.reshape((-1,) + (1,) * len(tail))
        val_out = (
            jnp.zeros((m_pad * k_pad + 1,) + tail, val.dtype)
            .at[flat]
            .set(jnp.where(ok_b, val_s, 0))[:-1]
            .reshape((m_pad, k_pad) + tail)
        )
        return col_out, val_out

    cid, val = jax.vmap(one)(coo.row_ids, coo.col_ids, coo.values, coo.nnz)
    return BatchedELL(col_ids=cid, values=val, n_rows=coo.n_rows)


def coo_to_dense(coo: BatchedCOO, m_pad: int, n_cols: int | None = None) -> jax.Array:
    """Densify the batch of adjacency matrices (the cuBLAS-gemmBatched-baseline
    path, paper §V-A)."""
    n_cols = n_cols or m_pad

    def one(rid, cid, val, nnz):
        valid = jnp.arange(rid.shape[0]) < nnz
        v = jnp.where(valid, val, 0)
        return jnp.zeros((m_pad, n_cols), val.dtype).at[rid, cid].add(v)

    return jax.vmap(one)(coo.row_ids, coo.col_ids, coo.values, coo.nnz)


def random_batch(
    rng: np.random.Generator,
    *,
    batch: int,
    dim: int | tuple[int, int],
    nnz_per_row: int | tuple[int, int],
    self_loops: bool = True,
    dtype=jnp.float32,
) -> tuple[BatchedCOO, int]:
    """Randomly generated square sparse matrices following the paper's §V-A
    generator (dim and nnz/row parameterized; mixed batches supported via
    (lo, hi) ranges as in Fig. 10). Returns (BatchedCOO, m_pad)."""
    dims = (dim, dim) if isinstance(dim, int) else dim
    ks = (nnz_per_row,) * 2 if isinstance(nnz_per_row, int) else nnz_per_row
    triples, n_rows = [], []
    for _ in range(batch):
        m = int(rng.integers(dims[0], dims[1] + 1))
        k = int(rng.integers(ks[0], ks[1] + 1))
        rows, cols = [], []
        for r in range(m):
            cs = rng.choice(m, size=min(k, m), replace=False).tolist()
            rows.extend([r] * len(cs))
            cols.extend(cs)
            # a_uu = 1 (paper §II-A) — only when rng.choice did not already
            # sample the diagonal, else the duplicate COO entries would sum
            # to 2.0 on densify
            if self_loops and r not in cs:
                rows.append(r)
                cols.append(r)
        rows = np.asarray(rows, np.int32)
        cols = np.asarray(cols, np.int32)
        vals = np.ones(len(rows), np.float32)
        triples.append((rows, cols, vals))
        n_rows.append(m)
    m_pad = _round_up(max(n_rows), 8)
    return coo_from_lists(triples, n_rows, dtype=dtype), m_pad


def powerlaw_degrees(
    rng: np.random.Generator,
    n: int,
    avg_deg: float,
    alpha: float = 1.2,
) -> np.ndarray:
    """(n,) int64 truncated-power-law degree sequence: ``deg_r ∝ (r+1)^-alpha``
    rescaled to mean ≈ ``avg_deg``, capped at ``n``, then SHUFFLED so hubs
    land on random ids. This is the one Zipf recipe shared by the
    small-graph skewed batches (:func:`random_powerlaw_batch`) and the
    giant-graph "reddit-like" node-classification generator
    (``repro.data.graphs``): hub nodes hold a large fraction of the edges —
    the load-imbalance regime the hybrid dispatch absorbs (DESIGN.md §12)
    and the hot-node feature cache exploits (DESIGN.md §14)."""
    w = (np.arange(n, dtype=np.float64) + 1.0) ** -alpha
    deg = np.minimum(
        np.maximum(np.rint(w * (avg_deg * n / w.sum())), 0.0), n
    ).astype(np.int64)
    rng.shuffle(deg)
    return deg


def random_powerlaw_batch(
    rng: np.random.Generator,
    *,
    batch: int,
    dim: int | tuple[int, int],
    avg_deg: float,
    alpha: float = 1.2,
    self_loops: bool = True,
    dtype=jnp.float32,
) -> tuple[BatchedCOO, int]:
    """Degree-SKEWED square sparse matrices: per-row degrees follow a
    truncated power law (Zipf-like, ``deg_r ∝ (r+1)^-alpha`` over a random
    row order — :func:`powerlaw_degrees`), rescaled so the mean degree is ≈
    ``avg_deg`` and capped at ``dim``. The head rows are hubs holding a
    large fraction of the nnz — the load-imbalance regime a flat row-split
    serializes on and the hybrid dispatch's MXU tiles absorb (DESIGN.md
    §12). Returns (BatchedCOO, m_pad).
    """
    dims = (dim, dim) if isinstance(dim, int) else dim
    triples, n_rows = [], []
    for _ in range(batch):
        m = int(rng.integers(dims[0], dims[1] + 1))
        deg = powerlaw_degrees(rng, m, avg_deg, alpha)
        rows, cols = [], []
        for r in range(m):
            cs = rng.choice(m, size=int(deg[r]), replace=False).tolist()
            rows.extend([r] * len(cs))
            cols.extend(cs)
            if self_loops and r not in cs:
                rows.append(r)
                cols.append(r)
        rows = np.asarray(rows, np.int32)
        cols = np.asarray(cols, np.int32)
        vals = np.ones(len(rows), np.float32)
        triples.append((rows, cols, vals))
        n_rows.append(m)
    m_pad = _round_up(max(n_rows), 8)
    return coo_from_lists(triples, n_rows, dtype=dtype), m_pad
