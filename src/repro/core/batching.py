"""Batching planner — the paper's §IV-B/§IV-C resource-assignment logic,
re-derived for the TPU memory hierarchy.

Paper (P100/CUDA)                         | Here (TPU v5e/Pallas)
------------------------------------------+----------------------------------
shared memory per block: 32-64 KB         | VMEM per core: ~16 MiB usable
case 1: m_A*n_B*4 <= smem -> whole output | case 1: working set <= VMEM_TILE_BUDGET
        resident in shared memory         |         -> one grid step per matrix
case 2: column cache-blocking into p subs | case 2: split n_B into p column
        (Fig. 5-(b)/(d))                  |         panels (multiples of 128 lanes)
case 3: m_A > 8192 -> don't batch, use a  | case 3: m_pad > LARGE_M -> fall back
        large-matrix kernel               |         to the non-batched path
one thread block per (matrix x panel)     | one grid step per (matrix x panel)
subWarp = next_pow2(n_B) capped at 32     | the 128-wide lane axis covers n_B
                                          | columns; sublanes cover rows/slots

The planner is *static*: it sees only shapes (batch, m_pad, k_pad/nnz_pad,
n_B, dtype bytes) and emits a BatchPlan that the kernels, the reference path
and the benchmarks all share — so "batched vs non-batched" comparisons use
identical blocking decisions.
"""
from __future__ import annotations

import dataclasses

# TPU constants: ~16 MiB VMEM per TensorCore (v5e), with a conservative
# per-step budget because Pallas double-buffers every block for pipelining.
VMEM_BYTES = 16 * 1024 * 1024
VMEM_TILE_BUDGET = 4 * 1024 * 1024  # per-grid-step working set target
LANES = 128                         # vector lane width (last dim tiling)
SUBLANES = 8                        # second-to-last dim tiling (f32)
LARGE_M = 8192                      # paper's case-3 threshold, kept verbatim


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Static blocking decision for one batched SpMM/GEMM call."""

    batch: int
    m_pad: int          # padded rows per matrix (multiple of SUBLANES)
    n_b: int            # dense operand columns
    n_block: int        # column panel width (multiple of LANES, or n_b if small)
    p: int              # number of column panels = ceil(n_b / n_block)
    case: int           # 1, 2 or 3 (paper Fig. 5)
    bytes_per_step: int # VMEM working-set estimate per grid step

    @property
    def grid(self) -> tuple[int, int]:
        return (self.batch, self.p)


def plan_batched_spmm(
    *,
    batch: int,
    m_pad: int,
    n_b: int,
    slots: int,
    itemsize: int = 4,
) -> BatchPlan:
    """Size the column panels the way the paper sizes cache blocks.

    ``slots`` is k_pad (ELL) or nnz_pad (COO) — it contributes the index/value
    working set. The per-step working set is:

        out panel   m_pad * n_block * itemsize
        B panel     m_pad * n_block * itemsize   (same rows, same panel)
        indices     ~ 2 * slots_bytes
    """
    m_pad = _round_up(max(m_pad, 1), SUBLANES)
    if m_pad > LARGE_M:
        # Paper case 3: too large to benefit from batching; callers take the
        # per-sample large-matrix path.
        return BatchPlan(batch, m_pad, n_b, n_b, 1, 3, 0)

    idx_bytes = 2 * slots * 8  # int32 ids + values, per matrix
    n_block = _round_up(n_b, LANES) if n_b >= LANES else n_b
    while n_block > LANES:
        step = 2 * m_pad * n_block * itemsize + idx_bytes
        if step <= VMEM_TILE_BUDGET:
            break
        # halve along 128-lane multiples, mirroring the paper's "divide the
        # output along the column" (Fig. 5-(b)/(d))
        n_block = _round_up(n_block // 2, LANES)
    step = 2 * m_pad * n_block * itemsize + idx_bytes
    p = -(-n_b // n_block)
    case = 1 if p == 1 else 2
    return BatchPlan(batch, m_pad, n_b, n_block, p, case, step)


def plan_batched_gemm(
    *, batch: int, m: int, n: int, k: int, itemsize: int = 4
) -> BatchPlan:
    """Panel plan for the densified (gemmBatched-analogue) path."""
    m_pad = _round_up(max(m, 1), SUBLANES)
    k_pad = _round_up(max(k, 1), SUBLANES)
    n_block = _round_up(n, LANES) if n >= LANES else n
    while n_block > LANES:
        step = (m_pad * n_block + k_pad * n_block + m_pad * k_pad) * itemsize
        if step <= VMEM_TILE_BUDGET:
            break
        n_block = _round_up(n_block // 2, LANES)
    step = (m_pad * n_block + k_pad * n_block + m_pad * k_pad) * itemsize
    p = -(-n // n_block)
    return BatchPlan(batch, m_pad, n, n_block, p, 1 if p == 1 else 2, step)
