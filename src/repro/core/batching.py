"""Batching planner — the paper's §IV-B/§IV-C resource-assignment logic,
re-derived for the TPU memory hierarchy.

Paper (P100/CUDA)                         | Here (TPU v5e/Pallas)
------------------------------------------+----------------------------------
shared memory per block: 32-64 KB         | VMEM per core: ~16 MiB usable
case 1: m_A*n_B*4 <= smem -> whole output | case 1: working set <= VMEM_TILE_BUDGET
        resident in shared memory         |         -> one grid step per matrix
case 2: column cache-blocking into p subs | case 2: split n_B into p column
        (Fig. 5-(b)/(d))                  |         panels (multiples of 128 lanes)
case 3: m_A > 8192 -> don't batch, use a  | case 3: m_pad > LARGE_M -> fall back
        large-matrix kernel               |         to the non-batched path
one thread block per (matrix x panel)     | one grid step per (matrix x panel)
subWarp = next_pow2(n_B) capped at 32     | the 128-wide lane axis covers n_B
                                          | columns; sublanes cover rows/slots

The planner is *static*: it sees only shapes (batch, m_pad, k_pad/nnz_pad,
n_B, dtype bytes) and emits a BatchPlan that the kernels, the reference path
and the benchmarks all share — so "batched vs non-batched" comparisons use
identical blocking decisions.
"""
from __future__ import annotations

import dataclasses
import math

# TPU constants: ~16 MiB VMEM per TensorCore (v5e), with a conservative
# per-step budget because Pallas double-buffers every block for pipelining.
VMEM_BYTES = 16 * 1024 * 1024
VMEM_TILE_BUDGET = 4 * 1024 * 1024  # per-grid-step working set target
LANES = 128                         # vector lane width (last dim tiling)
SUBLANES = 8                        # second-to-last dim tiling (f32)
LARGE_M = 8192                      # paper's case-3 threshold, kept verbatim
CHUNK = 128                         # COO non-zero chunk (one MXU sublane tile)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Static blocking decision for one batched SpMM/GEMM call.

    ``sample_chunks`` is the skew-aware nnz packing decision for the fused
    graph-conv path: per-sample CHUNK counts (``Σ_ch ceil(nnz_ch / CHUNK)``
    — what the per-channel loop executes — batch-major), known only when
    the planner saw host-side nnz metadata. ``None`` means "bound every
    sample by the batch max" —
    the pre-skew-aware behavior. The kernels themselves always take the
    runtime per-(sample × channel) chunk-count array (trace-safe, derived
    from ``BatchedCOO.nnz``); this field is the *static, auditable* record
    of the same decision for the cost model, benchmarks and EXPERIMENTS.md.
    """

    batch: int
    m_pad: int          # padded rows per matrix (multiple of SUBLANES)
    n_b: int            # dense operand columns
    n_block: int        # column panel width (multiple of LANES, or n_b if small)
    p: int              # number of column panels = ceil(n_b / n_block)
    case: int           # 1, 2 or 3 (paper Fig. 5)
    bytes_per_step: int # VMEM working-set estimate per grid step
    sample_chunks: tuple[int, ...] | None = None  # skew-aware per-sample chunks

    @property
    def grid(self) -> tuple[int, int]:
        return (self.batch, self.p)

    @property
    def max_chunks(self) -> int:
        """Batch-max CHUNK count a skew-oblivious loop would run per sample
        (``sample_chunks`` known only)."""
        return max(self.sample_chunks) if self.sample_chunks else 0


def plan_batched_spmm(
    *,
    batch: int,
    m_pad: int,
    n_b: int,
    slots: int,
    itemsize: int = 4,
) -> BatchPlan:
    """Size the column panels the way the paper sizes cache blocks.

    ``slots`` is k_pad (ELL) or nnz_pad (COO) — it contributes the index/value
    working set. The per-step working set is:

        out panel   m_pad * n_block * itemsize
        B panel     m_pad * n_block * itemsize   (same rows, same panel)
        indices     ~ 2 * slots_bytes
    """
    m_pad = _round_up(max(m_pad, 1), SUBLANES)
    if m_pad > LARGE_M:
        # Paper case 3: too large to benefit from batching; callers take the
        # per-sample large-matrix path.
        return BatchPlan(batch, m_pad, n_b, n_b, 1, 3, 0)

    idx_bytes = 2 * slots * 8  # int32 ids + values, per matrix
    n_block = _round_up(n_b, LANES) if n_b >= LANES else n_b
    while n_block > LANES:
        step = 2 * m_pad * n_block * itemsize + idx_bytes
        if step <= VMEM_TILE_BUDGET:
            break
        # halve along 128-lane multiples, mirroring the paper's "divide the
        # output along the column" (Fig. 5-(b)/(d))
        n_block = _round_up(n_block // 2, LANES)
    step = 2 * m_pad * n_block * itemsize + idx_bytes
    p = -(-n_b // n_block)
    case = 1 if p == 1 else 2
    return BatchPlan(batch, m_pad, n_b, n_block, p, case, step)


# Hybrid dispatch defaults (DESIGN.md §12): a row whose density
# ``deg / m_pad`` reaches TAU is routed to the dense MXU slab; everything
# below stays in the rpt-bounded CSR remainder, whose per-row trip count is
# then bounded by ``dmin - 1`` *by construction*. NBINS_TARGET bins the
# sorted row axis into similar-work groups so adjacent program ids get
# near-equal fori_loop trip counts.
HYBRID_TAU = 0.25
HYBRID_NBINS = 8


@dataclasses.dataclass(frozen=True)
class HybridPlan:
    """Static decision record for the degree-binned hybrid SpMM path.

    ``spmm`` carries the shared column-panel blocking (same grid as the CSR
    kernel). ``dmin`` is the hub threshold in non-zeros per row
    (``ceil(tau * m_pad)``; a row with ``deg >= dmin`` — density exactly AT
    the threshold included — is a hub). ``d_pad`` is the static height of
    the dense hub slab: since every hub holds at least ``dmin`` non-zeros,
    at most ``nnz_pad // dmin`` rows can ever classify dense, so the slab is
    provably tall enough and ``d_pad == 0`` means *no* MXU tile group is
    emitted at all (the degenerate-input guard: all-empty batches and
    ``nnz_pad < dmin`` never reach the dense dot). ``bins`` are static
    ``(start, stop)`` slices of the degree-sorted row axis, every edge a
    SUBLANES multiple so per-bin accumulators tile cleanly.
    """

    spmm: BatchPlan
    tau: float
    dmin: int           # hub threshold, in nnz per row (>= comparison)
    d_pad: int          # static dense-slab height (0 => no dense tile group)
    bins: tuple[tuple[int, int], ...]  # sorted-row-axis work bins

    @property
    def nbins(self) -> int:
        return len(self.bins)


def plan_hybrid(
    *,
    batch: int,
    m_pad: int,
    n_b: int,
    nnz_pad: int,
    itemsize: int = 4,
    tau: float = HYBRID_TAU,
    nbins: int = HYBRID_NBINS,
) -> HybridPlan:
    """Plan the degree-binned hybrid split (DESIGN.md §12).

    Static-only, like every planner here: the *threshold* and *capacity*
    are shape-derived; which rows actually classify dense is runtime data
    (``hybrid_operands`` in kernels/batched_spmm_hybrid.py).
    """
    if not 0.0 < tau <= 1.0:
        raise ValueError(f"tau must be in (0, 1], got {tau}")
    base = plan_batched_spmm(batch=batch, m_pad=m_pad, n_b=n_b,
                             slots=nnz_pad, itemsize=itemsize)
    m_pad = base.m_pad
    dmin = max(1, math.ceil(tau * m_pad))
    # slab capacity: each hub row holds >= dmin nnz, so nnz_pad // dmin
    # bounds the hub count. nnz_pad < dmin => no row can be a hub => d_pad=0
    # and the kernel never materialises a dense operand (satellite guard).
    if nnz_pad < dmin:
        d_pad = 0
    else:
        d_pad = min(m_pad, _round_up(max(1, nnz_pad // dmin), SUBLANES))
    rows_per_bin = max(SUBLANES,
                       _round_up(max(1, m_pad // max(1, nbins)), SUBLANES))
    bins = tuple((s, min(s + rows_per_bin, m_pad))
                 for s in range(0, m_pad, rows_per_bin))
    return HybridPlan(base, tau, dmin, d_pad, bins)


def chunk_counts(nnz_per_sample) -> tuple[int, ...]:
    """Skew-aware packing: the CHUNK count the fused kernel actually runs per
    sample, from host-side nnz metadata. Accepts per-sample totals (a
    sequence of ints → ``ceil(nnz / CHUNK)`` each) or per-(sample × channel)
    counts (a sequence of sequences → ``Σ_ch ceil(nnz_ch / CHUNK)``, which is
    what the per-channel loop executes — ceils do NOT commute with the
    channel sum). A zero-nnz sample runs zero chunks (the kernel writes its
    zero output unconditionally)."""

    def one(n):
        try:
            return sum(-(-int(c) // CHUNK) for c in n)
        except TypeError:
            return -(-int(n) // CHUNK)

    return tuple(one(n) for n in nnz_per_sample)


def plan_fused_graph_conv(
    *,
    batch: int,
    m_pad: int,
    n_in: int,
    n_out: int,
    channels: int,
    nnz_pad: int,
    itemsize: int = 4,
    nnz_per_sample=None,
) -> BatchPlan:
    """Blocking plan for the fused graph-conv megakernel (DESIGN.md §7).

    One grid step computes, for one (matrix × output-column panel), the whole
    layer: ``channels`` MXU products ``X·W_ch + b_ch`` immediately consumed by
    the one-hot-scatter SpMM, accumulated into a single VMEM-resident panel.
    The per-step working set is therefore:

        X panel      m_pad * n_in * itemsize
        W panel      channels * n_in * n_block * itemsize
        bias panel   channels * n_block * itemsize
        indices      channels * nnz_chunks * CHUNK * (8 + itemsize)
        acc/out      2 * m_pad * n_block * 4       (f32 accumulator + store)

    ``nnz_per_sample`` (host-side: per-sample totals, or per-(sample ×
    channel) rows for the exact sum-of-ceils — see :func:`chunk_counts`)
    makes the plan skew-aware: ``sample_chunks`` records each graph's real
    chunk count so the kernel's nnz loop — and the cost model — stop paying
    for the batch-max ``nnz_pad`` on skewed batches.
    """
    m_pad = _round_up(max(m_pad, 1), SUBLANES)
    sample_chunks = (chunk_counts(nnz_per_sample)
                     if nnz_per_sample is not None else None)
    if m_pad > LARGE_M:
        # paper case 3: matrices this large do not batch — callers fall back
        # to the unfused per-sample path, same as plan_batched_spmm.
        return BatchPlan(batch, m_pad, n_out, n_out, 1, 3, 0, sample_chunks)

    chunks_pad = max(1, -(-nnz_pad // CHUNK))
    idx_bytes = channels * chunks_pad * CHUNK * (8 + itemsize)
    x_bytes = m_pad * n_in * itemsize
    n_block = _round_up(n_out, LANES) if n_out >= LANES else n_out

    def step_bytes(nb: int) -> int:
        return (x_bytes + channels * n_in * nb * itemsize
                + channels * nb * itemsize + idx_bytes
                + 2 * m_pad * nb * 4)

    while n_block > LANES and step_bytes(n_block) > VMEM_TILE_BUDGET:
        # halve along 128-lane multiples — the paper's "divide the output
        # along the column" (Fig. 5-(b)/(d)) applied to the fused epilogue
        n_block = _round_up(n_block // 2, LANES)
    p = -(-n_out // n_block)
    case = 1 if p == 1 else 2
    return BatchPlan(batch, m_pad, n_out, n_block, p, case,
                     step_bytes(n_block), sample_chunks)


def tier_ladder(
    *,
    m_max: int,
    nnz_max: int,
    levels: int = 3,
    m_min: int = 2 * SUBLANES,
    nnz_min: int = 64,
) -> tuple[tuple[int, int], ...]:
    """Geometry ladder for the serving scheduler's bucketing policy
    (DESIGN.md §8): ``levels`` (m_pad, nnz_pad) rungs halving down from the
    dataset maxima, each rounded to the same hardware multiples the
    :class:`BatchPlan` constructors use (``SUBLANES`` rows; nnz slots to 8,
    matching ``coo_from_lists``). The top rung always covers
    (``m_max``, ``nnz_max``) so every admissible request has a bucket; lower
    rungs stop small molecules paying worst-case padding.
    """
    if levels < 1:
        raise ValueError(f"levels must be >= 1, got {levels}")
    m_top = _round_up(max(m_max, 1), SUBLANES)
    nnz_top = _round_up(max(nnz_max, 1), 8)
    ms, nnzs = [], []
    m, nz = m_top, nnz_top
    for _ in range(levels):
        ms.append(max(_round_up(m, SUBLANES), _round_up(m_min, SUBLANES)))
        nnzs.append(max(_round_up(nz, 8), _round_up(nnz_min, 8)))
        m //= 2
        nz //= 2
    rungs = sorted(set(zip(ms, nnzs)))
    return tuple(rungs)


def plan_batched_gemm(
    *, batch: int, m: int, n: int, k: int, itemsize: int = 4
) -> BatchPlan:
    """Panel plan for the densified (gemmBatched-analogue) path."""
    m_pad = _round_up(max(m, 1), SUBLANES)
    k_pad = _round_up(max(k, 1), SUBLANES)
    n_block = _round_up(n, LANES) if n >= LANES else n
    while n_block > LANES:
        step = (m_pad * n_block + k_pad * n_block + m_pad * k_pad) * itemsize
        if step <= VMEM_TILE_BUDGET:
            break
        n_block = _round_up(n_block // 2, LANES)
    step = (m_pad * n_block + k_pad * n_block + m_pad * k_pad) * itemsize
    p = -(-n // n_block)
    return BatchPlan(batch, m_pad, n, n_block, p, 1 if p == 1 else 2, step)
