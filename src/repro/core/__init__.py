"""The paper's primary contribution: batched SpMM for GCNs."""
from repro.core.formats import (  # noqa: F401
    INT16_MAX,
    BatchedCOO,
    BatchedCSR,
    BatchedELL,
    coo_from_lists,
    coo_to_csr,
    coo_to_dense,
    coo_to_ell,
    csr_transpose,
    max_row_degree,
    narrow_col_ids,
    quantize_values_i8,
    random_batch,
    validate_ell_k_pad,
)
from repro.core.csc import (  # noqa: F401
    Block,
    CSCGraph,
    csc_from_edges,
    csc_to_coo,
    coo_to_csc,
    make_block,
)
from repro.core.batching import (  # noqa: F401
    BatchPlan,
    chunk_counts,
    plan_batched_gemm,
    plan_batched_spmm,
    plan_fused_graph_conv,
)
from repro.core.spmm import (  # noqa: F401
    GSPMM_OPS,
    GSPMM_REDUCES,
    IMPLS,
    batched_gspmm,
    batched_spmm,
    dense_batched_matmul,
    resolve_gspmm_impl,
    resolve_impl,
)
from repro.core.message_passing import (  # noqa: F401
    message_passing,
    resolve_message_passing_impl,
)
