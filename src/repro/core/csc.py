"""CSC sampling structure + bipartite Blocks for the giant-graph tier
(DESIGN.md §14).

Everything before this tier batches many *small* graphs — the source paper's
regime. One Reddit/ogbn-scale graph (millions of nodes) cannot be padded into
a :class:`~repro.core.formats.BatchedCOO` wholesale; the production pattern
(DGL graphbolt's ``csc_sampling_graph``/``minibatch_sampler`` split, GE-SpMM's
row-split CSR) is:

1. hold the FULL graph host-side in a static CSC structure (:class:`CSCGraph`:
   one ``indptr`` column pointer per destination node, in-neighbor ``indices``
   grouped per column — sampling reads exactly one contiguous slice per seed);
2. sample fanout-bounded neighborhoods into bipartite **Blocks**
   (``repro.sampling``), each emitted directly in the existing padded
   batched-COO format so every kernel, autotuner branch and telemetry hook
   downstream runs on them *unchanged*.

**Block convention.** A block is a (dst-nodes × src-nodes) bipartite
adjacency with *compacted* local ids. We embed it in the square
``(m_pad, m_pad)`` shape the batched kernels expect by ordering the src node
set with the dst nodes as its PREFIX (``src_ids[:n_dst]`` are the dst nodes —
DGL's ``include_dst_in_src`` invariant): rows ``0..n_dst-1`` carry edges,
rows ``n_dst..m_pad-1`` are structural padding (value 0.0, index 0 — the
paper's §IV-C invariant), and ``BatchedCOO.n_rows == n_dst`` stays the true
row count exactly as for a small-graph batch. ``C = A_block @ H_src`` then
computes the next layer's dst features in its first ``n_dst`` rows, which are
by construction the *src prefix of the next block* — layer chaining is a
static slice.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.formats import BatchedCOO, coo_from_lists


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class CSCGraph:
    """Static host-side CSC over ONE giant graph (NumPy, never traced).

    indptr  : (n_nodes + 1,) int64 — per-DESTINATION column pointers
    indices : (n_edges,)    int32/int64 — in-neighbor (source) node ids,
              grouped per destination: node ``v``'s in-neighbors are
              ``indices[indptr[v]:indptr[v+1]]``

    CSC-by-destination is the sampling-native layout: fanout sampling reads
    one contiguous ``indices`` slice per seed (GE-SpMM row-split locality,
    graphbolt's ``csc_sampling_graph``). The structure is immutable and
    shared read-only across sampler workers.
    """

    indptr: np.ndarray
    indices: np.ndarray

    def __post_init__(self):
        if self.indptr.ndim != 1 or self.indices.ndim != 1:
            raise ValueError("CSCGraph arrays must be 1-D")
        if int(self.indptr[0]) != 0 or int(self.indptr[-1]) != len(self.indices):
            raise ValueError(
                f"indptr must run 0..n_edges={len(self.indices)}, got "
                f"[{int(self.indptr[0])}..{int(self.indptr[-1])}]")

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    def in_degrees(self) -> np.ndarray:
        """(n_nodes,) int64 — per-destination in-degree (the hot-node cache's
        static admission statistic: Zipf-hot hubs have the top in-degrees)."""
        return np.diff(self.indptr)

    def in_neighbors(self, v: int) -> np.ndarray:
        """The contiguous in-neighbor slice of one destination node."""
        return self.indices[self.indptr[v]:self.indptr[v + 1]]


def csc_from_edges(src: np.ndarray, dst: np.ndarray,
                   n_nodes: int) -> CSCGraph:
    """Build a :class:`CSCGraph` from flat (src → dst) edge arrays.

    Counting sort by destination (stable: parallel edges and the relative
    source order within a destination are preserved), O(E + N) — no
    comparison sort, so a 10M-edge graph builds in one pass.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    if src.shape != dst.shape:
        raise ValueError(f"src/dst shape mismatch: {src.shape} vs {dst.shape}")
    if len(dst) and (int(dst.min()) < 0 or int(dst.max()) >= n_nodes
                     or int(src.min()) < 0 or int(src.max()) >= n_nodes):
        raise ValueError(f"edge endpoints out of range [0, {n_nodes})")
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    order = np.argsort(dst, kind="stable")
    indices = np.ascontiguousarray(src[order].astype(np.int32, copy=False))
    return CSCGraph(indptr=indptr, indices=indices)


def coo_to_csc(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSCGraph:
    """COO edge list → CSC (alias of :func:`csc_from_edges`, named for the
    round-trip pair)."""
    return csc_from_edges(src, dst, n_nodes)


def csc_to_coo(csc: CSCGraph) -> tuple[np.ndarray, np.ndarray]:
    """CSC → flat (src, dst) COO edge arrays, destination-major (the same
    order ``coo_to_csc`` stores, so ``coo_to_csc(*csc_to_coo(g), n)`` is
    bitwise ``g``)."""
    dst = np.repeat(np.arange(csc.n_nodes, dtype=np.int64),
                    csc.in_degrees())
    return csc.indices.copy(), dst


@dataclasses.dataclass(frozen=True)
class Block:
    """One sampled bipartite (dst × src) adjacency, kernel-ready.

    adj     : BatchedCOO, batch=1, square over ``m_pad`` padded rows. Rows
              are LOCAL dst ids (< n_dst), cols LOCAL src ids (< n_src);
              ``adj.n_rows == [n_dst]`` and padded slots follow the §IV-C
              zero-value/zero-index invariant, so ``batched_spmm`` /
              ``batched_gspmm`` and every registry impl run unchanged.
    src_ids : (n_src,) int64 GLOBAL node ids of the src set, dst-prefixed:
              ``src_ids[:n_dst]`` are the dst nodes in seed order.
    m_pad   : the padded square dimension the adjacency was emitted at
              (a bucket rung — see ``repro.sampling.bucketing``).
    max_deg : true max sampled in-degree of any dst row — the host-side skew
              evidence ``autotune.Workload.max_deg`` prices (DESIGN.md §12):
              a hubby block ranks the CSR/hybrid classes first.
    """

    adj: BatchedCOO
    src_ids: np.ndarray
    n_dst: int
    n_src: int
    m_pad: int
    max_deg: int

    @property
    def nnz_pad(self) -> int:
        return self.adj.nnz_pad

    @property
    def nnz(self) -> int:
        return int(np.asarray(self.adj.nnz)[0])

    def dst_ids(self) -> np.ndarray:
        """(n_dst,) global ids of the dst nodes (the src prefix)."""
        return self.src_ids[:self.n_dst]


def make_block(
    rows: np.ndarray,
    cols: np.ndarray,
    src_ids: np.ndarray,
    n_dst: int,
    *,
    m_pad: int | None = None,
    nnz_pad: int | None = None,
    normalize: str = "mean",
) -> Block:
    """Emit one sampled bipartite adjacency as a kernel-ready :class:`Block`.

    ``rows``/``cols`` are LOCAL (dst, src) edge endpoints; ``src_ids`` the
    dst-prefixed global id map. ``normalize="mean"`` sets each edge value to
    ``1 / sampled_in_degree(dst)`` (the neighbor-sampled mean aggregator —
    fanout sampling changes degrees per minibatch, so normalization must use
    the SAMPLED degree, not the full graph's); ``"none"`` keeps 1.0.
    ``m_pad``/``nnz_pad`` pad to a bucket rung (defaults: minimal hardware
    multiples).
    """
    if normalize not in ("mean", "none"):
        raise ValueError(f"unknown normalize {normalize!r}: "
                         "expected 'mean' or 'none'")
    rows = np.asarray(rows, np.int32)
    cols = np.asarray(cols, np.int32)
    n_src = len(src_ids)
    deg = np.bincount(rows, minlength=max(n_dst, 1)) if len(rows) else \
        np.zeros(max(n_dst, 1), np.int64)
    max_deg = int(deg.max()) if len(deg) else 0
    if normalize == "mean" and len(rows):
        vals = (1.0 / np.maximum(deg[rows], 1)).astype(np.float32)
    else:
        vals = np.ones(len(rows), np.float32)
    m_pad = m_pad or _round_up(max(n_src, 1), 8)
    if n_src > m_pad:
        raise ValueError(f"n_src={n_src} exceeds m_pad={m_pad}")
    if nnz_pad is not None and len(rows) > nnz_pad:
        raise ValueError(f"nnz={len(rows)} exceeds nnz_pad={nnz_pad}")
    adj = coo_from_lists([(rows, cols, vals)], [n_dst], nnz_pad=nnz_pad)
    return Block(adj=adj, src_ids=np.asarray(src_ids, np.int64),
                 n_dst=int(n_dst), n_src=int(n_src), m_pad=int(m_pad),
                 max_deg=max_deg)
