"""Graph convolution layer — paper Fig. 6 (non-batched) and Fig. 7 (batched).

Semantics (paper §II-A, eq. (2)): Y = Σ_ch A_ch · (X · W_ch + bias_ch), summed
over edge channels (bond types in ChemGCN). The two execution strategies are
numerically identical; the batched one restructures the computation so MatMul,
Add and SpMM each run as ONE device op per channel instead of one per
(sample × channel) — the paper's O(channel·batchsize) → O(channel) kernel
launch reduction.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.formats import BatchedCOO
from repro.core.spmm import batched_spmm
from repro.kernels.ref import spmm_coo_single


def init_graph_conv(key, n_in: int, n_out: int, channels: int):
    k1, _ = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(n_in)
    return {
        "w": jax.random.uniform(k1, (channels, n_in, n_out), jnp.float32,
                                -scale, scale),
        "b": jnp.zeros((channels, n_out), jnp.float32),
    }


def graph_conv_batched(
    params,
    adj: Sequence[BatchedCOO],   # one BatchedCOO per channel, batch-leading
    x: jax.Array,                # (batch, m_pad, n_in)
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool = True,
    mesh=None,
) -> jax.Array:
    """Paper Fig. 7: per channel, one MatMul over the whole mini-batch
    (the reshape to (m_X·batchsize, n_X) is implicit in the batched einsum),
    one Add, one Batched SpMM; then the element-wise channel sum.

    ``mesh=`` shards each channel's Batched SpMM over the mesh's ``"data"``
    axis (DESIGN.md §6); the surrounding MatMul/Add/sum stay ordinary XLA ops
    that GSPMD partitions around the sharded SpMM.
    """
    y = None
    for ch, a_ch in enumerate(adj):
        u = jnp.einsum("bmn,nf->bmf", x, params["w"][ch])      # MATMUL (one op)
        u = u + params["b"][ch]                                 # ADD (one op)
        c = batched_spmm(a_ch, u, impl=impl, k_pad=k_pad,
                         interpret=interpret, mesh=mesh)        # BATCHEDSPMM
        y = c if y is None else y + c                           # ELEMENTWISEADD
    return y


def graph_conv_nonbatched(
    params,
    adj: Sequence[BatchedCOO],
    x: jax.Array,
) -> jax.Array:
    """Paper Fig. 6: the per-(sample × channel) loop, kept sequential with a
    scan over the batch so it reproduces the launch-per-sample structure that
    the paper measures as the baseline."""
    channels = len(adj)
    rids = jnp.stack([a.row_ids for a in adj], 1)   # (batch, ch, nnz_pad)
    cids = jnp.stack([a.col_ids for a in adj], 1)
    vals = jnp.stack([a.values for a in adj], 1)

    def per_sample(_, args):
        rid, cid, val, xb = args                     # one mini-batch sample
        m_pad = xb.shape[0]
        y = jnp.zeros((m_pad, params["w"].shape[-1]), xb.dtype)
        for ch in range(channels):
            u = xb @ params["w"][ch]                 # MATMUL (per sample)
            u = u + params["b"][ch]                  # ADD (per sample)
            y = y + spmm_coo_single(rid[ch], cid[ch], val[ch], u, m_pad)
        return None, y

    _, y = jax.lax.scan(per_sample, None, (rids, cids, vals, x))
    return y
