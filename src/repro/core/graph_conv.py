"""Graph convolution layer — paper Fig. 6 (non-batched) and Fig. 7 (batched).

Semantics (paper §II-A, eq. (2)): Y = Σ_ch A_ch · (X · W_ch + bias_ch), summed
over edge channels (bond types in ChemGCN). The execution strategies are
numerically identical and differ only in op structure:

- ``graph_conv_nonbatched``  Fig. 6: one op per (sample × channel) — the
  paper's O(channel·batchsize) launch baseline;
- ``graph_conv_batched``     Fig. 7 and beyond. The SpMM impl resolves per
  LAYER workload (``repro.autotune.select_graph_conv_impl``):

  * ``impl="fused"`` — ONE device op for the whole layer: the Pallas
    megakernel (``kernels/fused_graph_conv.py``, DESIGN.md §7) computes
    X·W_ch + b_ch on the MXU, consumes it in-VMEM with the one-hot-scatter
    SpMM, and accumulates the channel sum — no per-channel HBM
    intermediates, nnz loop bounded by each graph's REAL non-zeros;
  * any SpMM impl — the stacked fallback: the per-channel einsum and ALL
    channels' SpMMs are stacked into one ``(channels·batch)`` batched call
    (4·channels ops → 3 ops), then channel-summed.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.formats import BatchedCOO, narrow_col_ids
from repro.core.spmm import batched_spmm
from repro.kernels import resolve_interpret
from repro.kernels.ref import spmm_coo_single


def init_graph_conv(key, n_in: int, n_out: int, channels: int):
    k1, _ = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(n_in)
    return {
        "w": jax.random.uniform(k1, (channels, n_in, n_out), jnp.float32,
                                -scale, scale),
        "b": jnp.zeros((channels, n_out), jnp.float32),
    }


def stack_channels(adj: Sequence[BatchedCOO]):
    """Stack per-channel BatchedCOOs into channel-axis arrays for the fused
    kernel: (batch, channels, nnz_max) row/col/values + (batch, channels)
    true nnz. Channels with a smaller nnz_pad are zero-padded (value 0.0,
    index 0 — the §IV-C invariant)."""
    nnz_max = max(a.nnz_pad for a in adj)

    def pad(x):
        return jnp.pad(x, ((0, 0), (0, nnz_max - x.shape[1])))

    rids = jnp.stack([pad(a.row_ids) for a in adj], axis=1)
    cids = jnp.stack([pad(a.col_ids) for a in adj], axis=1)
    vals = jnp.stack([pad(a.values) for a in adj], axis=1)
    nnz = jnp.stack([a.nnz for a in adj], axis=1)
    return rids, cids, vals, nnz


def flatten_channels(adj: Sequence[BatchedCOO]) -> BatchedCOO:
    """Concatenate the channel axis into the batch axis: one BatchedCOO of
    ``channels·batch`` samples (channel-major), for the stacked fallback's
    single ``(channels·batch)`` SpMM call."""
    rids, cids, vals, nnz = stack_channels(adj)
    batch, channels, nnz_pad = rids.shape

    def flat(t):
        return t.transpose(1, 0, 2).reshape(channels * batch, nnz_pad)

    n_rows = jnp.tile(adj[0].n_rows, channels)
    return BatchedCOO(row_ids=flat(rids), col_ids=flat(cids),
                      values=flat(vals),
                      nnz=nnz.transpose(1, 0).reshape(-1), n_rows=n_rows)


def resolve_graph_conv_impl(
    adj: Sequence[BatchedCOO],
    x: jax.Array,
    n_out: int,
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
    mesh_axis: str = "data",
    precision: str = "f32",
):
    """Resolve ``impl`` against the LAYER workload of one graph-conv call.

    Returns a :class:`repro.autotune.Decision`; candidates include the fused
    megakernel next to every SpMM impl (each priced as the stacked fallback
    layer), and — under a reduced ``precision`` policy — their bf16/i8
    variants (DESIGN.md §10). With ``mesh=``, resolution runs against the
    per-shard workload — the shapes each device actually executes
    (DESIGN.md §6).
    """
    from repro import autotune

    interpret = resolve_interpret(interpret)
    batch, m_pad, n_in = x.shape
    dtype = autotune.precision_of(impl)[1] if impl != "auto" else precision
    w = autotune.Workload(
        batch=batch, m_pad=m_pad, nnz_pad=max(a.nnz_pad for a in adj),
        k_pad=k_pad, n_b=n_out, itemsize=x.dtype.itemsize,
        channels=len(adj), n_in=n_in, dtype=dtype)
    if mesh is not None:
        from repro.distributed.spmm import shard_count

        w = w.shard(shard_count(mesh, mesh_axis))
    if impl != "auto":
        return autotune.forced_decision(w, impl)
    return autotune.select_graph_conv_impl(
        w, allow_pallas=not interpret, cache=autotune.default_cache())


def graph_conv_batched(
    params,
    adj: Sequence[BatchedCOO],   # one BatchedCOO per channel, batch-leading
    x: jax.Array,                # (batch, m_pad, n_in)
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
    epilogue: str = "none",
    precision: str = "f32",
) -> jax.Array:
    """Paper Fig. 7 and beyond: the whole mini-batch's layer in O(1) ops.

    ``impl="auto"`` resolves per layer workload (fused megakernel vs stacked
    SpMM classes); ``impl="fused"`` pins the megakernel; any SpMM impl pins
    the stacked fallback with that kernel. ``epilogue`` ("none"|"relu") is
    applied inside the fused kernel when it runs, as an XLA op otherwise —
    identical numerics either way.

    ``precision`` ("f32"|"bf16"|"i8") is the layer's dtype policy under
    ``impl="auto"`` (DESIGN.md §10); pinning a variant impl (e.g.
    ``"fused_bf16"``) applies its policy directly. The bf16 megakernel
    variant casts values/X/W/bias to bfloat16 and narrows the index storage
    to int16 before dispatch; the f32 accumulator lives in the kernel and
    the output is cast back to X's dtype.

    ``mesh=`` shards the batch axis over the mesh's ``"data"`` axis
    (DESIGN.md §6): the fused megakernel dispatches per shard via
    ``distributed.spmm.sharded_fused_graph_conv``; the fallback's stacked
    SpMM runs through ``sharded_batched_spmm`` with the dense ops GSPMD
    partitions around it.
    """
    from repro.autotune.cost_model import precision_of

    interpret = resolve_interpret(interpret)
    channels = len(adj)
    n_out = params["w"].shape[-1]
    concrete = impl
    if impl == "auto":
        concrete = resolve_graph_conv_impl(
            adj, x, n_out, impl="auto", k_pad=k_pad, interpret=interpret,
            mesh=mesh, precision=precision).impl

    base, policy = precision_of(concrete)
    if base.startswith("fused"):
        rids, cids, vals, nnz = stack_channels(adj)
        xx, ww, bb = x, params["w"], params["b"]
        if policy == "bf16":
            m_pad = x.shape[1]
            rids = narrow_col_ids(rids, m_pad)
            cids = narrow_col_ids(cids, m_pad)
            vals = vals.astype(jnp.bfloat16)
            xx = xx.astype(jnp.bfloat16)
            ww = ww.astype(jnp.bfloat16)
            bb = bb.astype(jnp.bfloat16)
        if mesh is not None:
            from repro.distributed.spmm import sharded_fused_graph_conv

            y = sharded_fused_graph_conv(
                rids, cids, vals, nnz, xx, ww, bb,
                mesh=mesh, epilogue=epilogue, interpret=interpret,
                impl=concrete)
        else:
            from repro.kernels.fused_graph_conv import fused_graph_conv

            y = fused_graph_conv(rids, cids, vals, nnz, xx, ww, bb,
                                 epilogue=epilogue, interpret=interpret,
                                 impl=concrete)
        return y.astype(x.dtype) if policy != "f32" else y

    # Stacked fallback: ONE feature-transform einsum over all channels, ONE
    # (channels·batch) Batched SpMM, one channel-sum — 4·channels ops → 3.
    # On a mesh with impl="auto", keep "auto" so the sharded path re-resolves
    # against the per-shard stacked workload it actually runs (DESIGN.md §6);
    # otherwise pin the layer-resolved (or caller-pinned) impl. A variant
    # SpMM impl (e.g. "ell_bf16") applies its storage policy inside
    # batched_spmm.
    spmm_impl = "auto" if impl == "auto" and mesh is not None else concrete
    batch, m_pad = x.shape[0], x.shape[1]
    u = jnp.einsum("bmn,cnf->cbmf", x, params["w"]) \
        + params["b"][:, None, None, :]                 # MATMUL+ADD (one op)
    a_flat = flatten_channels(adj)
    c = batched_spmm(a_flat, u.reshape(channels * batch, m_pad, n_out),
                     impl=spmm_impl, k_pad=k_pad, interpret=interpret,
                     mesh=mesh, precision=precision)     # BATCHEDSPMM (one op)
    y = jnp.sum(c.reshape(channels, batch, m_pad, n_out), axis=0)  # SUM
    return jnp.maximum(y, 0.0) if epilogue == "relu" else y


def graph_conv_nonbatched(
    params,
    adj: Sequence[BatchedCOO],
    x: jax.Array,
) -> jax.Array:
    """Paper Fig. 6: the per-(sample × channel) loop, kept sequential with a
    scan over the batch so it reproduces the launch-per-sample structure that
    the paper measures as the baseline."""
    channels = len(adj)
    rids, cids, vals, _ = stack_channels(adj)       # (batch, ch, nnz_max)

    def per_sample(_, args):
        rid, cid, val, xb = args                     # one mini-batch sample
        m_pad = xb.shape[0]
        y = jnp.zeros((m_pad, params["w"].shape[-1]), xb.dtype)
        for ch in range(channels):
            u = xb @ params["w"][ch]                 # MATMUL (per sample)
            u = u + params["b"][ch]                  # ADD (per sample)
            y = y + spmm_coo_single(rid[ch], cid[ch], val[ch], u, m_pad)
        return None, y

    _, y = jax.lax.scan(per_sample, None, (rids, cids, vals, x))
    return y
