"""ChemGCN — the paper's target application (§IV-D, §V-B).

Architecture per the paper: a stack of graph-convolution layers, batch
normalization after each layer, ReLU, a masked sum readout over nodes, and a
dense prediction head. Two task heads match the evaluation datasets:

- Tox21: 12 independent binary toxicity tasks (sigmoid + BCE);
- Reaction100: 100-way reaction classification (softmax + CE).

The model is pure-functional (init/apply), with ``batched=True`` selecting the
Fig. 7 execution and ``batched=False`` the Fig. 6 baseline — identical
numerics, different op structure.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.formats import BatchedCOO
from repro.core.graph_conv import (
    graph_conv_batched,
    graph_conv_nonbatched,
    init_graph_conv,
)


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    n_features: int = 62          # input atom-feature width
    channels: int = 4             # bond-type adjacency channels
    conv_widths: tuple[int, ...] = (64, 64)   # Tox21: two layers of 64
    n_tasks: int = 12             # Tox21: 12 binary tasks
    task: str = "multitask_binary"  # or "multiclass"
    layer: str = "gcn"            # conv layer kind (DESIGN.md §11):
                                  # "gcn"  — channel-summed graph conv
                                  #          (paper eq. (2));
                                  # "gat"  — multi-head attention over the
                                  #          first adjacency channel's
                                  #          connectivity (models/gnn.py);
                                  # "rgcn" — adjacency channels as relations
                                  #          with per-relation weights
    heads: int = 4                # attention heads (layer="gat" only; every
                                  # conv width must divide by it)
    impl: str = "auto"            # layer implementation (repro.core.spmm.IMPLS
                                  # incl. the "fused" megakernel; "auto" =
                                  # adaptive dispatch, DESIGN.md §5/§7)
    k_pad: int = 8                # max nnz/row for the ELL path
    batched: bool = True          # Fig. 7 (True) vs Fig. 6 (False)
    precision: str = "f32"        # layer dtype policy under impl="auto"
                                  # ("f32"|"bf16"|"i8", DESIGN.md §10);
                                  # training keeps f32, serving may opt
                                  # into bf16 via GraphServeEngine
    interpret: bool | None = None  # None → repro.kernels.default_interpret()
                                   # ($REPRO_INTERPRET, auto-False on TPU)
    bn_mode: str = "batch"        # "batch": stats over the whole wave (the
                                  # paper's TF training graph); "sample":
                                  # per-graph stats over its own real nodes —
                                  # wave-composition-INVARIANT, required for
                                  # continuous-batching serving where the set
                                  # of co-batched requests is a scheduling
                                  # accident (DESIGN.md §8)

    @staticmethod
    def tox21(**kw) -> "GCNConfig":
        return GCNConfig(conv_widths=(64, 64), n_tasks=12,
                         task="multitask_binary", **kw)

    @staticmethod
    def reaction100(**kw) -> "GCNConfig":
        # three conv layers, width 512 (paper §V-B)
        return GCNConfig(conv_widths=(512, 512, 512), n_tasks=100,
                         task="multiclass", **kw)


def _init_conv(key, cfg: GCNConfig, n_in: int, n_out: int):
    """One conv layer's params for ``cfg.layer`` (DESIGN.md §11)."""
    if cfg.layer == "gcn":
        return init_graph_conv(key, n_in, n_out, cfg.channels)
    from repro.models.gnn import init_gat_layer, init_rgcn_layer

    if cfg.layer == "gat":
        return init_gat_layer(key, n_in, n_out, cfg.heads)
    if cfg.layer == "rgcn":
        return init_rgcn_layer(key, n_in, n_out, cfg.channels)
    raise ValueError(f"unknown layer kind {cfg.layer!r}: expected 'gcn', "
                     "'gat' or 'rgcn'")


def init_gcn(key, cfg: GCNConfig):
    keys = jax.random.split(key, len(cfg.conv_widths) + 1)
    params = {"convs": [], "bns": []}
    n_in = cfg.n_features
    for i, w in enumerate(cfg.conv_widths):
        params["convs"].append(_init_conv(keys[i], cfg, n_in, w))
        params["bns"].append({
            "scale": jnp.ones((w,), jnp.float32),
            "bias": jnp.zeros((w,), jnp.float32),
        })
        n_in = w
    scale = 1.0 / jnp.sqrt(n_in)
    params["head"] = {
        "w": jax.random.uniform(keys[-1], (n_in, cfg.n_tasks), jnp.float32,
                                -scale, scale),
        "b": jnp.zeros((cfg.n_tasks,), jnp.float32),
    }
    return params


def resolve_conv_impls(cfg: GCNConfig, batch: int, m_pad: int, nnz_pad: int,
                       *, itemsize: int = 4, mesh=None):
    """The resolved layer impl for EVERY conv layer of the stack, one
    :class:`repro.autotune.Decision` per ``cfg.conv_widths`` entry.

    ``apply_gcn`` re-resolves ``impl="auto"`` per layer (each layer's
    workload differs in n_in/n_out), so a guard or audit that looks only at
    the first layer can miss a deeper layer landing in a different kernel
    class — consumers that gate on "could an ELL impl run?" must OR over
    this whole tuple. ``itemsize`` must match the features the runtime will
    actually carry (the Workload key embeds it, and the tuning cache is
    keyed per itemsize) — default 4 for the f32 GCN stack. Pure shape work:
    safe to call host-side per geometry.

    ``cfg.layer`` selects the workload shape (DESIGN.md §11): ``"gcn"``
    resolves the graph-conv LAYER workload (fused megakernel vs stacked
    SpMM); ``"gat"`` resolves the attention aggregation's vector-edge
    ``(mul, sum)`` g-SpMM over the head-flattened batch; ``"rgcn"`` the
    ``(copy_lhs, mean)`` g-SpMM over the relation-flattened batch — both
    over the g-SpMM-capable candidate subset."""
    from repro import autotune
    from repro.kernels import resolve_interpret

    interpret = resolve_interpret(cfg.interpret)
    decisions = []
    n_in = cfg.n_features
    dtype = (autotune.precision_of(cfg.impl)[1] if cfg.impl != "auto"
             else cfg.precision)
    for n_out in cfg.conv_widths:
        if cfg.layer == "gat":
            d_head = n_out // cfg.heads
            w = autotune.Workload(
                batch=batch * cfg.heads, m_pad=m_pad, nnz_pad=nnz_pad,
                k_pad=cfg.k_pad, n_b=d_head, itemsize=itemsize,
                dtype=dtype, d_e=d_head)
        elif cfg.layer == "rgcn":
            w = autotune.Workload(
                batch=batch * cfg.channels, m_pad=m_pad, nnz_pad=nnz_pad,
                k_pad=cfg.k_pad, n_b=n_out, itemsize=itemsize,
                dtype=dtype, op="copy_lhs", reduce="mean")
        else:
            w = autotune.Workload(
                batch=batch, m_pad=m_pad, nnz_pad=nnz_pad, k_pad=cfg.k_pad,
                n_b=n_out, itemsize=itemsize, channels=cfg.channels,
                n_in=n_in, dtype=dtype)
        if mesh is not None:
            from repro.distributed.spmm import shard_count

            w = w.shard(shard_count(mesh, "data"))
        if cfg.impl != "auto":
            decisions.append(autotune.forced_decision(w, cfg.impl))
        elif cfg.layer == "gcn":
            decisions.append(autotune.select_graph_conv_impl(
                w, allow_pallas=not interpret,
                cache=autotune.default_cache()))
        else:
            decisions.append(autotune.select_impl(
                w, allow_pallas=not interpret,
                cache=autotune.default_cache()))
        n_in = n_out
    return tuple(decisions)


def _batch_norm(p, x, mask, mode: str = "batch"):
    """Masked batch-norm: padded nodes excluded from the statistics (the
    paper's TF graph normalizes over real nodes only).

    ``mode="batch"`` reduces over (batch, nodes) — training semantics, but the
    output of one graph then depends on which OTHER graphs share its wave.
    ``mode="sample"`` reduces over each graph's own nodes only, so a request's
    logits are identical whether it is scored alone or inside any wave — the
    invariant the continuous-batching scheduler relies on (DESIGN.md §8).
    """
    if mode not in ("batch", "sample"):
        # a typo silently falling into "batch" would void the scheduler's
        # wave-composition-invariance guarantee — fail at trace time instead
        raise ValueError(f"unknown bn_mode {mode!r}: expected 'batch' or "
                         "'sample'")
    if mode == "sample":
        denom = jnp.maximum(jnp.sum(mask, axis=(1, 2), keepdims=True), 1.0)
        mean = jnp.sum(x * mask, axis=1, keepdims=True) / denom
        var = jnp.sum(((x - mean) * mask) ** 2, axis=1, keepdims=True) / denom
    else:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        mean = jnp.sum(x * mask, axis=(0, 1)) / denom
        var = jnp.sum(((x - mean) * mask) ** 2, axis=(0, 1)) / denom
    xn = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return xn * p["scale"] + p["bias"]


def apply_gcn(
    params,
    cfg: GCNConfig,
    adj: Sequence[BatchedCOO],
    x: jax.Array,                # (batch, m_pad, n_features)
    n_nodes: jax.Array,          # (batch,) true node counts
    *,
    mesh=None,                   # shard every SpMM's batch axis (DESIGN.md §6)
) -> jax.Array:
    mask = (
        jnp.arange(x.shape[1])[None, :, None] < n_nodes[:, None, None]
    ).astype(x.dtype)
    if cfg.layer != "gcn" and not cfg.batched:
        # GAT/R-GCN only exist on the batched g-SpMM stack — there is no
        # Fig. 6 per-sample baseline for them
        raise ValueError(f"layer={cfg.layer!r} requires batched=True")
    h = x
    for conv_p, bn_p in zip(params["convs"], params["bns"]):
        if cfg.layer == "gat":
            from repro.models.gnn import gat_layer

            h = gat_layer(conv_p, adj[0], h, impl=cfg.impl, k_pad=cfg.k_pad,
                          interpret=cfg.interpret, mesh=mesh)
        elif cfg.layer == "rgcn":
            from repro.models.gnn import rgcn_layer

            h = rgcn_layer(conv_p, adj, h, impl=cfg.impl, k_pad=cfg.k_pad,
                           interpret=cfg.interpret, mesh=mesh)
        elif cfg.batched:
            h = graph_conv_batched(conv_p, adj, h, impl=cfg.impl,
                                   k_pad=cfg.k_pad, interpret=cfg.interpret,
                                   mesh=mesh, precision=cfg.precision)
        else:
            h = graph_conv_nonbatched(conv_p, adj, h)
        h = _batch_norm(bn_p, h * mask, mask, cfg.bn_mode)
        h = jax.nn.relu(h) * mask
    readout = jnp.sum(h, axis=1)                          # masked sum readout
    return readout @ params["head"]["w"] + params["head"]["b"]


def apply_gcn_blocks(
    params,
    cfg: GCNConfig,
    adjs: Sequence[BatchedCOO],  # one per conv layer, input-side first
    x: jax.Array,                # (m_pads[0], n_features) input-layer src rows
    *,
    m_pads: tuple[int, ...],     # static per-layer square dims (bucket rungs)
    impls: tuple[str, ...] | None = None,  # static per-layer resolved impls
) -> jax.Array:
    """Forward over one sampled minibatch's layered blocks (DESIGN.md §14).

    ``adjs[i]`` is layer ``i``'s bipartite block in the square
    ``(m_pads[i], m_pads[i])`` embedding (``core.csc.Block.adj``): its first
    ``n_dst_i`` output rows are — by the dst-prefix convention — exactly
    layer ``i+1``'s src prefix, so chaining is a static slice/pad to
    ``m_pads[i+1]`` plus a mask from the traced ``adj.n_rows``. All shapes
    here are static (the loader's bucket rungs): one compile per distinct
    ``(m_pads, impls, nnz_pads)``, bounded by the ladder product.

    ``impls`` carries the trainer's per-layer block-aware autotune decision
    (``Workload(block=..., max_deg=...)``) — ``None`` falls back to
    ``cfg.impl`` for every layer. Returns per-node logits
    ``(m_pads[-1], n_tasks)``; rows past the seed count are padding.
    """
    if len(adjs) != len(params["convs"]):
        raise ValueError(f"{len(adjs)} blocks for "
                         f"{len(params['convs'])} conv layers")
    if cfg.layer != "gcn":
        raise ValueError("sampled-block forward currently supports "
                         f"layer='gcn' only, got {cfg.layer!r}")
    if impls is None:
        impls = (cfg.impl,) * len(adjs)
    h = x[None]                               # (1, m_pads[0], n_features)
    for i, (conv_p, bn_p) in enumerate(zip(params["convs"], params["bns"])):
        adj = adjs[i]
        # real dst rows of THIS layer (traced — part of the block's pytree)
        mask = (
            jnp.arange(h.shape[1])[None, :, None] < adj.n_rows[0]
        ).astype(h.dtype)
        h = graph_conv_batched(conv_p, [adj], h, impl=impls[i],
                               k_pad=cfg.k_pad, interpret=cfg.interpret,
                               precision=cfg.precision)
        h = _batch_norm(bn_p, h * mask, mask, cfg.bn_mode)
        h = jax.nn.relu(h) * mask
        if i + 1 < len(adjs):
            # dst rows ARE the next block's src prefix (same local ids)
            m_next = m_pads[i + 1]
            if m_next <= h.shape[1]:
                h = h[:, :m_next]
            else:
                h = jnp.pad(h, ((0, 0), (0, m_next - h.shape[1]), (0, 0)))
    # node-level head: no readout — one logit row per dst node
    return h[0] @ params["head"]["w"] + params["head"]["b"]


def gcn_node_loss(params, cfg: GCNConfig, adjs, x, labels, *,
                  m_pads: tuple[int, ...],
                  impls: tuple[str, ...] | None = None):
    """Node-classification loss over the seed rows of a sampled minibatch:
    softmax CE on the first ``len(labels)`` rows of the block forward (the
    seed prefix of the last block — padding rows never touch the loss)."""
    logits = apply_gcn_blocks(params, cfg, adjs, x, m_pads=m_pads,
                              impls=impls)[:labels.shape[0]]
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc


def gcn_loss(params, cfg: GCNConfig, adj, x, n_nodes, labels, *, mesh=None):
    logits = apply_gcn(params, cfg, adj, x, n_nodes, mesh=mesh)
    if cfg.task == "multitask_binary":
        # labels: (batch, n_tasks) in {0, 1}
        z = logits
        loss = jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
        loss = jnp.mean(loss)
        pred = (z > 0).astype(jnp.float32)
        acc = jnp.mean((pred == labels).astype(jnp.float32))
    else:
        # labels: (batch,) int class ids
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc
