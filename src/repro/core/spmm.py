"""User-facing batched SpMM API (re-export; the implementation lives in
``repro.kernels.ops`` next to the kernels it dispatches to).

``resolve_impl`` exposes the adaptive ``impl="auto"`` decision (DESIGN.md §5)
so callers and benchmarks can inspect *why* a kernel was chosen.

``sharded_batched_spmm`` / ``resolve_sharded_impl`` are the mesh-sharded
variants (DESIGN.md §6): the batch axis split over a ``("data",)`` mesh axis,
with ``impl="auto"`` resolved against the per-shard workload. They are
imported lazily so ``repro.core`` stays importable without touching the
distributed stack.
"""
from repro.kernels.ops import (
    GSPMM_OPS,
    GSPMM_REDUCES,
    IMPLS,
    batched_gspmm,
    batched_spmm,
    dense_batched_matmul,
    resolve_gspmm_impl,
    resolve_impl,
)

__all__ = ["GSPMM_OPS", "GSPMM_REDUCES", "IMPLS", "batched_gspmm",
           "batched_spmm", "dense_batched_matmul", "resolve_gspmm_impl",
           "resolve_impl", "sharded_batched_spmm", "sharded_batched_gspmm",
           "resolve_sharded_impl"]


def __getattr__(name):
    if name in ("sharded_batched_spmm", "sharded_batched_gspmm",
                "resolve_sharded_impl"):
        from repro.distributed import spmm as _dspmm

        return getattr(_dspmm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
