"""User-facing batched SpMM API (re-export; the implementation lives in
``repro.kernels.ops`` next to the kernels it dispatches to)."""
from repro.kernels.ops import IMPLS, batched_spmm, dense_batched_matmul

__all__ = ["IMPLS", "batched_spmm", "dense_batched_matmul"]
