"""User-facing batched SpMM API (re-export; the implementation lives in
``repro.kernels.ops`` next to the kernels it dispatches to).

``resolve_impl`` exposes the adaptive ``impl="auto"`` decision (DESIGN.md §5)
so callers and benchmarks can inspect *why* a kernel was chosen.
"""
from repro.kernels.ops import (
    IMPLS,
    batched_spmm,
    dense_batched_matmul,
    resolve_impl,
)

__all__ = ["IMPLS", "batched_spmm", "dense_batched_matmul", "resolve_impl"]
