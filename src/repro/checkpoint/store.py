"""Fault-tolerant checkpointing.

Durability properties (the things that actually matter at 1000+ nodes):
- **atomic**: write to ``<dir>.tmp-<pid>`` then ``os.rename`` — a checkpoint
  directory either exists completely or not at all; a host killed mid-write
  never corrupts the latest restorable state;
- **self-verifying**: every array file carries a sha256 digest in the
  manifest; ``load_pytree`` verifies before restoring, so a truncated file
  fails loudly at restore time, not as NaNs 1,000 steps later;
- **keep-k GC** with the newest checkpoints retained;
- **resume-from-latest**: the trainer calls ``manager.latest_step()`` on
  startup — restart-after-SIGKILL is a tested path (tests/test_trainer.py).

Format: one ``.npz`` per checkpoint + a JSON manifest holding the treedef and
digests. Multi-host note: on a real cluster each host writes its addressable
shards under ``shard-<process_index>`` and host 0 writes the manifest; on this
single-process runtime that collapses to one shard.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_pytree(tree, directory: str) -> None:
    tmp = f"{directory}.tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    npz = os.path.join(tmp, "shard-0.npz")
    np.savez(npz, **arrays)
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "digests": {"shard-0.npz": _digest(npz)},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def load_pytree(tree_like, directory: str):
    """Restore into the structure of `tree_like` (shapes/arrays pytree)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    npz_path = os.path.join(directory, "shard-0.npz")
    if _digest(npz_path) != manifest["digests"]["shard-0.npz"]:
        raise IOError(f"checkpoint {directory} failed integrity check")
    data = np.load(npz_path)
    leaves, treedef = jax.tree.flatten(tree_like)
    if manifest["n_leaves"] != len(leaves):
        raise IOError(
            f"checkpoint {directory} has {manifest['n_leaves']} leaves, "
            f"expected {len(leaves)} (config mismatch?)")
    restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, restored)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree) -> None:
        save_pytree(tree, self._dir(step))
        self._gc()

    def restore(self, step: int, tree_like):
        return load_pytree(tree_like, self._dir(step))

    def _gc(self) -> None:
        for s in self.steps()[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
