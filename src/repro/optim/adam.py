"""AdamW, pure-pytree, mesh-agnostic.

State layout is chosen for ZeRO-1-style partitioning: m and v mirror the
parameter pytree exactly, so whatever PartitionSpec tree applies to the params
applies to the optimizer state — plus the trainer may *further* shard m/v over
the data axis (see repro.distributed.sharding.zero1_specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0       # 0 disables


def adam_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads: Any, max_norm: float):
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        t = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return lr


def adam_update(cfg: AdamConfig, params: Any, grads: Any, state: dict,
                lr_schedule=None):
    if cfg.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(step) if lr_schedule else cfg.lr
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        update = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    # preserve extra state entries (e.g. error-feedback residuals)
    return new_p, {**state, "m": new_m, "v": new_v, "step": step}
