from repro.optim.adam import (  # noqa: F401
    AdamConfig,
    adam_init,
    adam_update,
    clip_by_global_norm,
    cosine_schedule,
)
