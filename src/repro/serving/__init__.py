from repro.serving.engine import (  # noqa: F401
    GraphRequest,
    GraphServeEngine,
    GraphWaveReport,
    Request,
    ServeEngine,
)
