"""Batched serving engine (wave-scheduled, slot-masked).

The Batched-SpMM philosophy applied to serving: a batch of small independent
jobs becomes ONE compiled decode step per token, never one dispatch per
request. Requests are served in waves of ``batch`` slots:

- prompts in a wave are left-padded to a common length and prefilled in
  lockstep through the shared decode step (one compiled program total — the
  decode cell of the dry-run);
- finished sequences are masked (their sampled tokens discarded) so one long
  request cannot stall completed ones' results — and the wave ends as soon as
  EVERY slot is done, at which point the next wave refills all slots;
- sampling is greedy or temperature-categorical.

A production multi-host engine would add per-slot position vectors for true
continuous batching; the step function and caches already support restarting
a slot, so that is a scheduler change, not a model change.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch: int = 4,
                 max_len: int = 128, temperature: float = 0.0, seed: int = 0):
        self.params, self.cfg = params, cfg
        self.batch, self.max_len = batch, max_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, cfg, t, c, pos))

    def _sample(self, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature)

    def _run_wave(self, wave: list[Request]) -> None:
        n = len(wave)
        maxp = max(len(r.prompt) for r in wave)
        toks = np.zeros((self.batch, maxp), np.int32)
        for s, r in enumerate(wave):
            toks[s, maxp - len(r.prompt):] = r.prompt    # left padding
        caches = lm.init_decode_state(self.cfg, self.batch, self.max_len)
        # lockstep prefill through the decode step (positions shared)
        last = None
        for i in range(maxp):
            last, caches = self._decode(
                self.params, jnp.asarray(toks[:, i:i + 1]), caches,
                jnp.asarray(i, jnp.int32))
        pos = maxp
        cur = np.asarray(self._sample(last[:, 0, :]))
        active = np.array([True] * n + [False] * (self.batch - n))
        for s, r in enumerate(wave):
            r.out.append(int(cur[s]))
        while active.any() and pos < self.max_len - 1:
            logits, caches = self._decode(
                self.params, jnp.asarray(cur.reshape(-1, 1)), caches,
                jnp.asarray(pos, jnp.int32))
            cur = np.asarray(self._sample(logits[:, 0, :]))
            pos += 1
            for s, r in enumerate(wave):
                if not active[s]:
                    continue
                r.out.append(int(cur[s]))
                if len(r.out) >= r.max_new_tokens:
                    r.done = True
                    active[s] = False
        for r in wave:
            r.done = True

    def run(self, requests: list[Request]) -> list[Request]:
        queue = list(requests)
        while queue:
            wave, queue = queue[:self.batch], queue[self.batch:]
            self._run_wave(wave)
        return requests
