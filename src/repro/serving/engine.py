"""Batched serving engines (wave-scheduled, slot-masked).

Two engines share the wave philosophy:

- ``ServeEngine``      — LM decode waves (one compiled decode step per token);
- ``GraphServeEngine`` — ChemGCN inference waves: a queue of single-molecule
  scoring requests becomes ONE batched forward pass per wave, every graph
  convolution running as one Batched SpMM with ``impl="auto"`` (adaptive
  dispatch, DESIGN.md §5) instead of one dispatch per molecule — the paper's
  launch-amortization argument applied to online inference.

The Batched-SpMM philosophy applied to serving: a batch of small independent
jobs becomes ONE compiled decode step per token, never one dispatch per
request. Requests are served in waves of ``batch`` slots:

- prompts in a wave are left-padded to a common length and prefilled in
  lockstep through the shared decode step (one compiled program total — the
  decode cell of the dry-run);
- finished sequences are masked (their sampled tokens discarded) so one long
  request cannot stall completed ones' results — and the wave ends as soon as
  EVERY slot is done, at which point the next wave refills all slots;
- sampling is greedy or temperature-categorical.

A production multi-host engine would add per-slot position vectors for true
continuous batching; the step function and caches already support restarting
a slot, so that is a scheduler change, not a model change.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.formats import coo_from_lists
from repro.core.gcn import GCNConfig, apply_gcn
from repro.models import lm


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False          # served to completion (max_new_tokens reached)
    truncated: bool = False     # cut off by the engine's max_len window


def _serve_in_waves(engine, requests: list) -> list:
    """Shared wave scheduler: slice the queue into ``engine.batch``-slot
    waves, run each through ``engine._run_wave``."""
    queue = list(requests)
    while queue:
        wave, queue = queue[:engine.batch], queue[engine.batch:]
        engine._run_wave(wave)
    return requests


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch: int = 4,
                 max_len: int = 128, temperature: float = 0.0, seed: int = 0):
        self.params, self.cfg = params, cfg
        self.batch, self.max_len = batch, max_len
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, cfg, t, c, pos))

    def _sample(self, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature)

    def _run_wave(self, wave: list[Request]) -> None:
        n = len(wave)
        maxp = max(len(r.prompt) for r in wave)
        toks = np.zeros((self.batch, maxp), np.int32)
        for s, r in enumerate(wave):
            toks[s, maxp - len(r.prompt):] = r.prompt    # left padding
        caches = lm.init_decode_state(self.cfg, self.batch, self.max_len)
        # lockstep prefill through the decode step (positions shared)
        last = None
        for i in range(maxp):
            last, caches = self._decode(
                self.params, jnp.asarray(toks[:, i:i + 1]), caches,
                jnp.asarray(i, jnp.int32))
        pos = maxp
        cur = np.asarray(self._sample(last[:, 0, :]))
        active = np.array([True] * n + [False] * (self.batch - n))
        for s, r in enumerate(wave):
            if r.max_new_tokens <= 0:       # zero-budget: served, no tokens
                r.done = True
                active[s] = False
                continue
            r.out.append(int(cur[s]))
            if r.max_new_tokens <= 1:
                r.done = True
                active[s] = False
        while active.any() and pos < self.max_len - 1:
            logits, caches = self._decode(
                self.params, jnp.asarray(cur.reshape(-1, 1)), caches,
                jnp.asarray(pos, jnp.int32))
            cur = np.asarray(self._sample(logits[:, 0, :]))
            pos += 1
            for s, r in enumerate(wave):
                if not active[s]:
                    continue
                r.out.append(int(cur[s]))
                if len(r.out) >= r.max_new_tokens:
                    r.done = True
                    active[s] = False
        # slots still active here hit the max_len window, not their token
        # budget: record the truncation honestly instead of claiming done.
        for s, r in enumerate(wave):
            if active[s]:
                r.truncated = True
                active[s] = False

    def run(self, requests: list[Request]) -> list[Request]:
        return _serve_in_waves(self, requests)


@dataclasses.dataclass
class GraphRequest:
    """One molecule to score: per-channel COO triples + node features.

    ``failed``/``error`` record a per-request rejection (oversize for the
    wave geometry, no admissible bucket, …) — a failed request never kills
    its wave; the other slots are served normally.
    """

    rows: list[np.ndarray]          # one (e,) int array per channel
    cols: list[np.ndarray]
    features: np.ndarray            # (n_nodes, n_features)
    n_nodes: int
    logits: np.ndarray | None = None
    done: bool = False
    failed: bool = False
    error: str | None = None

    @property
    def max_nnz(self) -> int:
        """Largest per-channel edge count — with ``n_nodes`` the request's
        geometry, which the scheduler buckets on (DESIGN.md §8)."""
        return max((len(r) for r in self.rows), default=0)


@dataclasses.dataclass(frozen=True)
class GraphWaveReport:
    """What one executed wave actually carried vs. what its geometry paid
    for — the per-wave record behind the scheduler's padding-waste metric."""

    slots: int                      # wave batch slots (engine.batch)
    n_requests: int                 # real requests placed in the wave
    n_failed: int                   # of those, rejected by validation
    real_nodes: int                 # Σ n_nodes over served requests
    real_nnz: int                   # Σ over served requests and channels
    node_capacity: int              # slots * m_pad
    nnz_capacity: int               # slots * channels * nnz_pad


class GraphServeEngine:
    """Wave-scheduled batched GCN inference.

    Requests are padded to fixed wave geometry (``batch`` slots, ``m_pad``
    node rows) so every wave hits the SAME jitted program — one compilation
    total, and per (conv layer × wave) either ONE fused megakernel op
    (``impl="fused"``/auto-selected, DESIGN.md §7) or one stacked
    (channels·batch) Batched SpMM. Empty slots carry zero-nnz adjacencies
    and contribute nothing (the padding invariant of §IV-C) — under the
    fused kernel's skew-aware packing they cost zero nnz chunks too, so a
    part-full final wave does not pay for its empty slots. The layer impl
    per workload shape is chosen by ``cfg.impl`` — ``"auto"`` resolves via
    repro.autotune at trace time; :meth:`layer_decision` exposes the choice.
    """

    def __init__(self, params, cfg: GCNConfig, *, batch: int = 32,
                 m_pad: int = 56, nnz_pad: int = 256, mesh=None,
                 precision: str | None = None):
        if precision is not None:
            # Serving's dtype-policy override (DESIGN.md §10): training keeps
            # the config's f32, an engine may opt its waves into bf16 without
            # touching the shared GCNConfig.
            cfg = dataclasses.replace(cfg, precision=precision)
        self.params, self.cfg = params, cfg
        self.batch, self.m_pad, self.nnz_pad = batch, m_pad, nnz_pad
        self.mesh = mesh
        if mesh is not None:
            params = jax.device_put(params, jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()))
            self.params = params
        self._apply = jax.jit(
            lambda adj_arrays, x, n_nodes: apply_gcn(
                params, cfg, self._rebuild(adj_arrays), x, n_nodes,
                mesh=mesh))
        # Degree guard posture (see _validate): only an ELL-class layer impl
        # silently drops > k_pad nnz/row, so resolve what this engine's
        # geometry will actually run — EVERY conv layer, since each layer
        # re-resolves "auto" against its own n_in/n_out workload.
        impls = {cfg.impl}
        if cfg.impl == "auto" and cfg.k_pad is not None:
            from repro.core.gcn import resolve_conv_impls

            impls = {d.impl for d in resolve_conv_impls(
                cfg, batch, m_pad, nnz_pad, mesh=mesh)}
        from repro.autotune import precision_of

        self._ell_degree_guard = (
            self.cfg.k_pad is not None
            and any(i != "auto"
                    and precision_of(i)[0] in ("ell", "pallas_ell")
                    for i in impls))

    @staticmethod
    def _rebuild(adj_arrays):
        from repro.core.formats import BatchedCOO
        return [BatchedCOO(*a) for a in adj_arrays]

    def layer_decision(self):
        """The adaptive layer decision for this engine's (fixed) wave
        geometry — fused megakernel vs stacked SpMM for ``layer="gcn"``, the
        g-SpMM workload for ``"gat"``/``"rgcn"`` (DESIGN.md §11) — for the
        first conv layer. Audit/ops visibility; the jitted apply resolves
        identically."""
        from repro.core.formats import BatchedCOO
        from repro.core.graph_conv import resolve_graph_conv_impl

        if self.cfg.layer != "gcn":
            from repro.core.gcn import resolve_conv_impls

            return resolve_conv_impls(self.cfg, self.batch, self.m_pad,
                                      self.nnz_pad, mesh=self.mesh)[0]
        z2 = jnp.zeros((self.batch, self.nnz_pad), jnp.int32)
        adj = [BatchedCOO(z2, z2, z2.astype(jnp.float32),
                          jnp.zeros((self.batch,), jnp.int32),
                          jnp.full((self.batch,), self.m_pad, jnp.int32))
               for _ in range(self.cfg.channels)]
        x = jnp.zeros((self.batch, self.m_pad, self.cfg.n_features),
                      jnp.float32)
        return resolve_graph_conv_impl(
            adj, x, self.cfg.conv_widths[0], impl=self.cfg.impl,
            k_pad=self.cfg.k_pad, interpret=self.cfg.interpret,
            mesh=self.mesh, precision=self.cfg.precision)

    def _validate(self, s: int, r: GraphRequest) -> str | None:
        """Reason this request cannot ride this engine's wave geometry, or
        None when it fits. Never raises: an oversize request is a per-slot
        failure, not a wave-killer — the scheduler routes it to a bigger
        bucket or rejects it cleanly (DESIGN.md §8)."""
        if r.n_nodes > self.m_pad:
            return (f"request {s}: n_nodes={r.n_nodes} exceeds wave "
                    f"m_pad={self.m_pad}; needs a bigger geometry tier")
        # channel-count defects first: zip would silently truncate, letting
        # an unvalidated channel reach the degree guard / wave assembly
        if len(r.rows) != self.cfg.channels or len(r.cols) != self.cfg.channels:
            return (f"request {s}: {len(r.rows)} row / {len(r.cols)} col "
                    f"channels, engine expects {self.cfg.channels}")
        for ch, (rows, cols) in enumerate(zip(r.rows, r.cols)):
            if len(rows) > self.nnz_pad:
                return (f"request {s}, channel {ch}: {len(rows)} edges "
                        f"exceed wave nnz_pad={self.nnz_pad}")
            if len(rows) != len(cols):
                return (f"request {s}, channel {ch}: {len(rows)} row ids vs "
                        f"{len(cols)} col ids")
            if len(rows):
                rr, cc = np.asarray(rows), np.asarray(cols)
                # malformed ids must soft-fail like every other defect —
                # never raise (a negative id would blow up np.bincount
                # below, and a huge one would corrupt the wave's scatter)
                if (int(rr.min()) < 0 or int(cc.min()) < 0
                        or int(rr.max()) >= r.n_nodes
                        or int(cc.max()) >= r.n_nodes):
                    return (f"request {s}, channel {ch}: edge ids outside "
                            f"[0, n_nodes={r.n_nodes})")
        if self._ell_degree_guard:
            # ELL silent-drop guard (ISSUE 5) at the concrete boundary: the
            # jitted apply cannot data-branch, so a request whose row degree
            # exceeds cfg.k_pad would get edges silently zeroed by
            # coo_to_ell — soft-fail it instead. Active only when this
            # engine's layer impl actually resolves to the ELL class.
            for ch, rows in enumerate(r.rows):
                if len(rows):
                    deg = int(np.bincount(np.asarray(rows, np.int64)).max())
                    if deg > self.cfg.k_pad:
                        return (f"request {s}, channel {ch}: max row degree "
                                f"{deg} exceeds cfg.k_pad={self.cfg.k_pad} "
                                "(an ELL impl would silently drop edges)")
        return None

    def run_wave(self, wave: list[GraphRequest]) -> GraphWaveReport:
        """Execute ONE wave (≤ ``batch`` requests) through the shared jitted
        program and return the wave's fill/padding accounting. This is the
        per-wave executor the continuous-batching ``repro.scheduler`` drives;
        ``run()`` keeps the legacy fixed-slicing loop on top of it.

        The whole wave runs inside a ``serve/wave`` span (DESIGN.md §13)
        tagged with the wave geometry; any kernel-dispatch spans fired at
        trace time (telemetry on, first wave per geometry) nest inside it."""
        from repro.observability import TRACER

        n = len(wave)
        if n > self.batch:
            raise ValueError(f"wave of {n} requests > {self.batch} slots")
        with TRACER.span("serve/wave", cat="serve", args={
                "n_requests": n, "slots": self.batch, "m_pad": self.m_pad,
                "nnz_pad": self.nnz_pad, "channels": self.cfg.channels,
                "layer": self.cfg.layer, "impl": self.cfg.impl}):
            return self._run_wave_inner(wave)

    def _run_wave_inner(self, wave: list[GraphRequest]) -> GraphWaveReport:
        n = len(wave)
        channels = self.cfg.channels
        n_feat = self.cfg.n_features
        x = np.zeros((self.batch, self.m_pad, n_feat), np.float32)
        n_nodes = np.zeros((self.batch,), np.int32)
        triples_by_ch = [[] for _ in range(channels)]
        served: list[tuple[int, GraphRequest]] = []
        n_failed = real_nodes = real_nnz = 0
        for s in range(self.batch):
            r = wave[s] if s < n else None
            if r is not None:
                err = self._validate(s, r)
                if err is None:
                    served.append((s, r))
                    x[s, :r.n_nodes] = r.features
                    n_nodes[s] = r.n_nodes
                    real_nodes += r.n_nodes
                    for ch in range(channels):
                        rows = np.asarray(r.rows[ch], np.int32)
                        cols = np.asarray(r.cols[ch], np.int32)
                        real_nnz += len(rows)
                        triples_by_ch[ch].append(
                            (rows, cols, np.ones(len(rows), np.float32)))
                    continue
                r.failed, r.error, r.done = True, err, False
                n_failed += 1
            # empty or failed slot: zero-nnz adjacency
            for ch in range(channels):
                z = np.zeros(0, np.int32)
                triples_by_ch[ch].append((z, z, np.zeros(0, np.float32)))
        adj = [coo_from_lists(t, n_rows=list(n_nodes),
                              nnz_pad=self.nnz_pad)
               for t in triples_by_ch]
        adj_arrays = [(a.row_ids, a.col_ids, a.values, a.nnz, a.n_rows)
                      for a in adj]
        x, n_nodes = jnp.asarray(x), jnp.asarray(n_nodes)
        if self.mesh is not None:
            # one wave spans every device: batch-shard the wave operands so
            # each shard_map'd SpMM (and the dense ops GSPMD partitions
            # around it) runs on its slice of the slots
            from repro.distributed import sharding as shrules

            def place(leaf):
                return jax.device_put(leaf, jax.sharding.NamedSharding(
                    self.mesh, shrules.batch_specs(leaf, self.mesh)))

            adj_arrays, x, n_nodes = jax.tree.map(
                place, (adj_arrays, x, n_nodes))
        logits = np.asarray(self._apply(adj_arrays, x, n_nodes))
        for s, r in served:
            r.logits = logits[s]
            r.done = True
        return GraphWaveReport(
            slots=self.batch, n_requests=n, n_failed=n_failed,
            real_nodes=real_nodes, real_nnz=real_nnz,
            node_capacity=self.batch * self.m_pad,
            nnz_capacity=self.batch * channels * self.nnz_pad)

    # _serve_in_waves drives waves through the same public executor
    _run_wave = run_wave

    def compiled_programs(self) -> int | None:
        """Entries in this engine's jit cache — 1 is the one-program-per-
        geometry invariant the scheduler's program cache relies on. The
        count comes from JAX's private ``_cache_size`` introspection helper;
        None when that helper is unavailable (this method is the ONE place
        that dependency lives)."""
        try:
            return self._apply._cache_size()
        except AttributeError:
            return None

    def run(self, requests: list[GraphRequest]) -> list[GraphRequest]:
        return _serve_in_waves(self, requests)
