"""Assigned-architecture model zoo (pure-functional JAX, scan-over-layers)."""
