"""Assigned-architecture model zoo (pure-functional JAX, scan-over-layers).

``repro.models.gnn`` holds the g-SpMM-backed graph layers (GAT, R-GCN) built
on :mod:`repro.core.message_passing` (DESIGN.md §11).
"""
from repro.models.gnn import (  # noqa: F401
    gat_layer,
    init_gat_layer,
    init_rgcn_layer,
    rgcn_layer,
)
