"""Attention-free sequence mixers: RWKV-6 "Finch" and Mamba2 (for Zamba2).

Both expose the same contract as attention: ``(params, cfg, x, state) →
(out, new_state)`` where ``state`` is the O(1) decode state (this is what
makes the `long_500k` cell runnable for these families — no KV cache).

Training/prefill processes the sequence with `lax.scan` over time by default;
`mamba2_apply` also has a *chunked* path (`chunk > 0`) that rewrites the
scalar-decay recurrence as block matmuls (intra-chunk attention-like matmul +
inter-chunk state carry) — MXU-friendly, numerically stable because decay
factors within a chunk are ≤ 1. The chunked path is a perf-pass option
benchmarked in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import tuning
from repro.configs.base import ModelConfig
from repro.models.layers import init_rms_norm, rms_norm

# ---------------------------------------------------------------------------
# RWKV-6 (Finch): data-dependent decay linear recurrence
# ---------------------------------------------------------------------------

def init_rwkv(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 10)
    init = jax.nn.initializers.normal(0.02)
    lora = 64
    return {
        # time-mix lerp coefficients (mu) for r, k, v, g, w
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),
        "wr": init(ks[1], (d, d), dtype),
        "wk": init(ks[2], (d, d), dtype),
        "wv": init(ks[3], (d, d), dtype),
        "wg": init(ks[4], (d, d), dtype),
        "wo": init(ks[5], (d, d), dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(xw A) B))  (Finch)
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wA": init(ks[6], (d, lora), dtype),
        "wB": init(ks[7], (lora, d), dtype),
        "u": jax.random.uniform(ks[8], (h, hd), jnp.float32) - 0.5,  # bonus
        "ln_x": init_rms_norm(d),
        # channel mix
        "cm_mu": jax.random.uniform(ks[9], (2, d), jnp.float32),
        "cm_k": init(jax.random.fold_in(key, 1), (d, cfg.d_ff), dtype),
        "cm_v": init(jax.random.fold_in(key, 2), (cfg.d_ff, d), dtype),
        "cm_r": init(jax.random.fold_in(key, 3), (d, d), dtype),
        # pre-norms (RWKV blocks carry their own norms + residuals)
        "ln1": init_rms_norm(d),
        "ln2": init_rms_norm(d),
    }


def rwkv_state_init(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    return {
        "prev_x_tm": jnp.zeros((batch, d), jnp.float32),   # token shift (time)
        "prev_x_cm": jnp.zeros((batch, d), jnp.float32),   # token shift (chan)
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }


def rwkv_apply(p, cfg: ModelConfig, x: jax.Array, state: dict):
    """x: (B, T, D). Runs time-mix + channel-mix (one full RWKV block)."""
    b, t, d = x.shape
    h = cfg.n_heads
    hd = d // h

    # ---- time mix ----
    x_res = x
    x = rms_norm(p["ln1"], x)
    x_prev = jnp.concatenate(
        [state["prev_x_tm"][:, None].astype(x.dtype), x[:, :-1]], axis=1)
    xx = x_prev - x
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + xx * mu[i] for i in range(5))
    r = (xr @ p["wr"]).reshape(b, t, h, hd)
    k = (xk @ p["wk"]).reshape(b, t, h, hd)
    v = (xv @ p["wv"]).reshape(b, t, h, hd)
    g = xg @ p["wg"]
    logw = -jnp.exp(
        p["w0"] + (jnp.tanh(xw @ p["wA"]) @ p["wB"]).astype(jnp.float32))
    w = jnp.exp(logw).reshape(b, t, h, hd)                 # decay ∈ (0, 1)
    u = p["u"]

    def step(s, inp):
        rt, kt, vt, wt = inp                                # (B, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]            # (B, H, hd, hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    rs, ks_, vs, ws = (a.transpose(1, 0, 2, 3).astype(jnp.float32)
                       for a in (r, k, v, w))
    wkv_state, out = jax.lax.scan(step, state["wkv"], (rs, ks_, vs, ws))
    out = out.transpose(1, 0, 2, 3).reshape(b, t, d)
    out = rms_norm(p["ln_x"], out.astype(x.dtype))
    out = out * jax.nn.silu(g)
    y_res = x_res + (out @ p["wo"]).astype(x.dtype)

    # ---- channel mix ----
    y = rms_norm(p["ln2"], y_res)
    y_prev = jnp.concatenate(
        [state["prev_x_cm"][:, None].astype(y.dtype), y[:, :-1]], axis=1)
    yy = y_prev - y
    cmu = p["cm_mu"].astype(y.dtype)
    yk = y + yy * cmu[0]
    yr = y + yy * cmu[1]
    kk = jnp.square(jax.nn.relu(yk @ p["cm_k"]))
    out_cm = jax.nn.sigmoid(yr @ p["cm_r"]) * (kk @ p["cm_v"])
    z = y_res + out_cm.astype(y.dtype)

    new_state = {
        "prev_x_tm": x[:, -1].astype(jnp.float32),
        "prev_x_cm": y[:, -1].astype(jnp.float32),
        "wkv": wkv_state,
    }
    return z, new_state


# ---------------------------------------------------------------------------
# Mamba2 (SSD) — scalar-per-head decay selective state space
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_inner = 2 * d
    nheads = cfg.ssm_heads or max(1, d_inner // 128)
    state = cfg.ssm_state or 64
    ks = jax.random.split(key, 5)
    init = jax.nn.initializers.normal(0.02)
    return {
        # z,x / B,C / dt projections kept separate so every output dim is
        # mesh-divisible (a fused 2·d_inner+2·state+heads dim is not)
        "in_proj_zx": init(ks[3], (d, 2 * d_inner), dtype),
        "in_proj_bc": init(ks[4], (d, 2 * state), dtype),
        "in_proj_dt": init(ks[0], (d, nheads), dtype),
        "conv_w": init(ks[1], (4, d_inner + 2 * state), dtype),   # depthwise
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "norm": init_rms_norm(d_inner),
        "out_proj": init(ks[2], (d_inner, d), dtype),
    }


def mamba_state_init(cfg: ModelConfig, batch: int) -> dict:
    d_inner = 2 * cfg.d_model
    nheads = cfg.ssm_heads or max(1, d_inner // 128)
    state = cfg.ssm_state or 64
    hd = d_inner // nheads
    return {
        "conv": jnp.zeros((batch, 3, d_inner + 2 * state), jnp.float32),
        "ssm": jnp.zeros((batch, nheads, hd, state), jnp.float32),
    }


def mamba_apply(p, cfg: ModelConfig, x: jax.Array, state: dict, *,
                chunk: int = 0):
    """x: (B, T, D) → (out, new_state). `chunk>0` selects the SSD blocked path."""
    b, t, d = x.shape
    if chunk == 0:
        c = tuning.flags().mamba_chunk
        if c and t > 1 and t % c == 0:
            chunk = c
    d_inner = 2 * d
    nheads = cfg.ssm_heads or max(1, d_inner // 128)
    nstate = cfg.ssm_state or 64
    hd = d_inner // nheads

    zx = x @ p["in_proj_zx"]
    z, xs_raw = jnp.split(zx, [d_inner], axis=-1)
    bc = x @ p["in_proj_bc"]
    dt = x @ p["in_proj_dt"]
    xbc = jnp.concatenate([xs_raw, bc], axis=-1)
    # depthwise causal conv over (x, B, C), kernel 4, carrying conv state
    xbc_hist = jnp.concatenate(
        [state["conv"].astype(xbc.dtype), xbc], axis=1)      # (B, T+3, ·)
    conv_w = p["conv_w"]
    xbc_conv = sum(
        xbc_hist[:, i:i + t] * conv_w[i] for i in range(4))
    xbc_conv = jax.nn.silu(xbc_conv)
    xs, bmat, cmat = jnp.split(xbc_conv, [d_inner, d_inner + nstate], axis=-1)
    xs = xs.reshape(b, t, nheads, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, T, H)
    a = -jnp.exp(p["a_log"])                                  # (H,) negative
    decay = jnp.exp(dt * a)                                   # (B, T, H) ∈ (0,1)
    bx = (dt[..., None] * xs.astype(jnp.float32))             # (B,T,H,hd) scaled

    if chunk:
        y = _ssd_chunked(xs, bmat, cmat, decay, bx, state["ssm"], chunk)
        yout, new_ssm = y
    else:
        def step(s, inp):
            bxt, bt_, ct, dect = inp
            s = dect[..., None, None] * s \
                + bxt[..., None] * bt_[:, None, None, :]
            yt = jnp.einsum("bhds,bs->bhd", s, ct)
            return s, yt

        seq = (bx.transpose(1, 0, 2, 3),
               bmat.transpose(1, 0, 2).astype(jnp.float32),
               cmat.transpose(1, 0, 2).astype(jnp.float32),
               decay.transpose(1, 0, 2))
        new_ssm, ys = jax.lax.scan(step, state["ssm"], seq)
        yout = ys.transpose(1, 0, 2, 3)                       # (B, T, H, hd)

    yout = yout + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    yout = yout.reshape(b, t, d_inner).astype(x.dtype)
    yout = rms_norm(p["norm"], yout) * jax.nn.silu(z)
    out = yout @ p["out_proj"]
    new_state = {
        "conv": xbc_hist[:, -3:].astype(jnp.float32),
        "ssm": new_ssm,
    }
    return out.astype(x.dtype), new_state


def _ssd_chunked(xs, bmat, cmat, decay, bx, s0, chunk):
    """SSD blocked evaluation: intra-chunk 'attention' matmul + inter-chunk
    carried state. decay is scalar per (B, T, H) ⇒ the pairwise factor
    exp(L_i − L_j) ≤ 1 for i ≥ j, so the blocked form is stable."""
    b, t, h, hd = xs.shape
    n = t // chunk
    assert t % chunk == 0, (t, chunk)
    ns = bmat.shape[-1]
    logd = jnp.log(jnp.maximum(decay, 1e-38))                 # (B, T, H)
    bx_c = bx.reshape(b, n, chunk, h, hd)
    bm_c = bmat.reshape(b, n, chunk, ns).astype(jnp.float32)
    cm_c = cmat.reshape(b, n, chunk, ns).astype(jnp.float32)
    ld_c = logd.reshape(b, n, chunk, h)
    lcum = jnp.cumsum(ld_c, axis=2)                           # inclusive
    ltot = lcum[:, :, -1]                                     # (B, N, H)

    # intra-chunk: y_i += Σ_{j≤i} exp(lcum_i - lcum_j) (c_i·b_j) bx_j
    scores = jnp.einsum("bncs,bnks->bnck", cm_c, bm_c)        # (B,N,C,C)
    rel = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]     # (B,N,C,C,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    att = jnp.where(causal[None, None, :, :, None],
                    jnp.exp(rel), 0.0) * scores[..., None]
    y_intra = jnp.einsum("bnckh,bnkhd->bnchd", att, bx_c)

    # inter-chunk: carry state across chunks with a scan over N
    chunk_kv = jnp.einsum("bnkh,bnks,bnkhd->bnhds",
                          jnp.exp(ltot[:, :, None, :] - lcum), bm_c, bx_c)

    def carry(s, inp):
        kv, lt, cm, lc = inp                                  # per chunk
        # y_cross_i = c_i · (exp(lcum_i) * s)
        y = jnp.einsum("bch,bcs,bhds->bchd", jnp.exp(lc), cm, s)
        s = jnp.exp(lt)[:, :, None, None] * s + kv
        return s, y

    s_fin, y_cross = jax.lax.scan(
        carry, s0,
        (chunk_kv.transpose(1, 0, 2, 3, 4), ltot.transpose(1, 0, 2),
         cm_c.transpose(1, 0, 2, 3), lcum.transpose(1, 0, 2, 3)))
    y = y_intra + y_cross.transpose(1, 0, 2, 3, 4)
    return y.reshape(b, t, h, hd), s_fin
