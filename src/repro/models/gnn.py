"""GNN layer zoo on top of the g-SpMM message-passing primitive
(DESIGN.md §11).

Both layers keep the paper's batched execution discipline — a handful of
batched device ops per layer for the WHOLE mini-batch, never a per-sample or
per-head loop:

- ``gat_layer``  (Graph Attention, arXiv:1710.10903): the per-head feature
  transform is one einsum; per-edge attention logits are two gathers over
  node-level projections; the softmax over each destination row's incoming
  edges is :func:`repro.kernels.segment_softmax.segment_softmax`; and the
  attention-weighted aggregation of EVERY head is ONE vector-edge
  ``(mul, sum)`` g-SpMM with the head axis flattened into the batch axis —
  the attention weights are the edge-feature vectors.
- ``rgcn_layer`` (Relational GCN, arXiv:1703.06103): the per-relation weight
  transforms run as ONE ragged :func:`repro.kernels.grouped_matmul` over
  relation-major tokens (the MoE idiom of DESIGN.md §4 — relations are the
  groups), and the degree-normalized neighborhood aggregation of every
  relation is ONE ``(copy_lhs, mean)`` g-SpMM over the relation-flattened
  batch.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.formats import BatchedCOO
from repro.core.graph_conv import flatten_channels
from repro.core.message_passing import message_passing
from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.segment_softmax import segment_softmax


def init_gat_layer(key, n_in: int, n_out: int, heads: int):
    """Multi-head GAT parameters: per-head transform ``w`` to ``n_out //
    heads`` features, split attention vectors ``a_src``/``a_dst`` (the
    concatenation trick: a·[h_i ‖ h_j] = a_src·h_j + a_dst·h_i), and an
    output bias over the concatenated heads."""
    if n_out % heads:
        raise ValueError(f"n_out={n_out} not divisible by heads={heads}")
    d_head = n_out // heads
    k1, k2, k3 = jax.random.split(key, 3)
    scale = 1.0 / jnp.sqrt(n_in)
    return {
        "w": jax.random.uniform(k1, (heads, n_in, d_head), jnp.float32,
                                -scale, scale),
        "a_src": jax.random.uniform(k2, (heads, d_head), jnp.float32,
                                    -scale, scale),
        "a_dst": jax.random.uniform(k3, (heads, d_head), jnp.float32,
                                    -scale, scale),
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def gat_layer(
    params,
    adj: BatchedCOO,             # connectivity; edge values are ignored
    x: jax.Array,                # (batch, m_pad, n_in)
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
    negative_slope: float = 0.2,
) -> jax.Array:
    """One multi-head graph-attention layer → ``(batch, m_pad, n_out)`` with
    the heads' outputs concatenated.

    ``alpha = segment_softmax(LeakyReLU(a_src·h[cid] + a_dst·h[rid]))`` per
    head over each destination row's incoming edges, then the aggregation
    ``out[r] = Σ_edges alpha · h[cid]`` for ALL heads runs as ONE
    ``(mul, sum)`` g-SpMM: heads flatten into the batch axis (head-major)
    and the per-edge ``alpha`` broadcasts across the head width as a
    vector edge feature. Zero-degree rows get all-zero attention rows from
    ``segment_softmax`` and therefore the 0.0 identity output with finite
    (zero) gradients — no NaN from the empty softmax.
    """
    heads, _, d_head = params["w"].shape
    batch, m_pad, _ = x.shape
    nnz_pad = adj.row_ids.shape[1]

    h = jnp.einsum("bmn,hnf->hbmf", x, params["w"])    # (heads, b, m, d_head)
    # node-level halves of the edge logit, then two gathers per edge
    s_src = jnp.einsum("hbmf,hf->hbm", h, params["a_src"])
    s_dst = jnp.einsum("hbmf,hf->hbm", h, params["a_dst"])
    gather = jax.vmap(jax.vmap(lambda s, ids: s[ids]))  # over (heads, batch)
    logits = (gather(s_src, jnp.broadcast_to(adj.col_ids, (heads, batch,
                                                           nnz_pad)))
              + gather(s_dst, jnp.broadcast_to(adj.row_ids, (heads, batch,
                                                             nnz_pad))))
    logits = jax.nn.leaky_relu(logits, negative_slope)
    # per-row softmax, independent per head: (batch, nnz_pad, heads)
    alpha = segment_softmax(logits.transpose(1, 2, 0), adj.row_ids,
                            nnz=adj.nnz, m_pad=m_pad)

    # ONE aggregation for all heads: flatten heads into the batch axis
    # (head-major, like graph_conv's flatten_channels) and carry alpha as a
    # vector edge feature broadcast over the head width
    def flat(t):
        return jnp.broadcast_to(t, (heads,) + t.shape).reshape(
            (heads * batch,) + t.shape[1:])

    e_vec = jnp.repeat(
        alpha.transpose(2, 0, 1).reshape(heads * batch, nnz_pad)[..., None],
        d_head, axis=-1)
    a_flat = BatchedCOO(row_ids=flat(adj.row_ids), col_ids=flat(adj.col_ids),
                        values=e_vec, nnz=flat(adj.nnz),
                        n_rows=flat(adj.n_rows))
    out = message_passing(a_flat, h.reshape(heads * batch, m_pad, d_head),
                          op="mul", reduce="sum", impl=impl, k_pad=k_pad,
                          interpret=interpret, mesh=mesh)
    out = out.reshape(heads, batch, m_pad, d_head)
    return (out.transpose(1, 2, 0, 3).reshape(batch, m_pad, heads * d_head)
            + params["b"])


def init_rgcn_layer(key, n_in: int, n_out: int, relations: int):
    """R-GCN parameters: one weight per relation (stacked for the grouped
    matmul), a self-loop weight, and a bias."""
    k1, k2 = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(n_in)
    return {
        "w_rel": jax.random.uniform(k1, (relations, n_in, n_out), jnp.float32,
                                    -scale, scale),
        "w_self": jax.random.uniform(k2, (n_in, n_out), jnp.float32,
                                     -scale, scale),
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def rgcn_layer(
    params,
    adj: Sequence[BatchedCOO],   # one BatchedCOO per relation
    x: jax.Array,                # (batch, m_pad, n_in)
    *,
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    mesh=None,
) -> jax.Array:
    """One R-GCN layer: ``out[i] = Σ_r mean_{j ∈ N_r(i)} (x[j] · W_r)
    + x[i] · W_self + b``.

    The per-relation transforms are ONE ragged grouped matmul over
    relation-major tokens (every graph's node block repeated per relation —
    equal group sizes, the capacity-style dispatch of DESIGN.md §4), and the
    per-relation mean aggregation is ONE ``(copy_lhs, mean)`` g-SpMM over
    the relation-flattened batch (``graph_conv.flatten_channels`` — the mean
    normalizer 1/|N_r(i)| is exactly the g-SpMM mean-reduce identity, with
    zero-degree rows contributing the 0.0 identity).
    """
    relations = len(adj)
    batch, m_pad, n_in = x.shape
    n_out = params["w_rel"].shape[-1]
    tokens = m_pad * batch

    # relation-major tokens: [all nodes under W_0 | all nodes under W_1 | …]
    xt = jnp.broadcast_to(x.reshape(1, tokens, n_in),
                          (relations, tokens, n_in)).reshape(-1, n_in)
    h = grouped_matmul(xt, params["w_rel"],
                       jnp.full((relations,), tokens, jnp.int32),
                       interpret=interpret)
    h = h.reshape(relations * batch, m_pad, n_out)

    a_flat = flatten_channels(adj)
    agg = message_passing(a_flat, h, op="copy_lhs", reduce="mean",
                          impl=impl, k_pad=k_pad, interpret=interpret,
                          mesh=mesh)
    y = jnp.sum(agg.reshape(relations, batch, m_pad, n_out), axis=0)
    return y + x @ params["w_self"] + params["b"]
