"""Unified LM over the architecture zoo.

One functional model covering all 10 assigned architectures:

- decoder-only transformers (dense / MoE / mixed block patterns) — scanned
  over homogeneous blocks so HLO size is O(1) in depth;
- hybrid (zamba2): groups of `attn_every` mamba sublayers + ONE weight-shared
  attention block applied per group (weights shared, KV caches per group);
- attention-free (rwkv6): token-shift linear recurrence blocks;
- encoder-decoder (whisper): encoder scan + decoder scan with cross-attention;
- stub frontends (llava vision tiles, whisper audio frames): precomputed
  embeddings from the input pipeline, scattered into the sequence.

Entry points:
  init_params(key, cfg)
  loss_fn(params, cfg, batch)                  → (loss, metrics)
  prefill(params, cfg, batch)                  → (last_logits, caches)
  decode_step(params, cfg, tokens, caches, pos)→ (logits, caches)
  init_decode_state(cfg, batch, cache_len)     → caches pytree
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import tuning
from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.layers import (
    attention_apply,
    ffn_apply,
    init_attention,
    init_ffn,
    init_moe,
    init_rms_norm,
    moe_apply,
    rms_norm,
)

VISION_DIM = 1024   # stub CLIP-like patch embedding width
AUDIO_DIM = 80      # stub mel-frame width


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_sublayer(key, kind: str, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    if kind in ("attn_dense", "attn_moe"):
        p = {
            "ln1": init_rms_norm(cfg.d_model),
            "attn": init_attention(ks[0], cfg, dtype),
            "ln2": init_rms_norm(cfg.d_model),
        }
        if kind == "attn_dense":
            p["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff, dtype)
        else:
            p["moe"] = init_moe(ks[1], cfg, dtype)
        return p
    if kind == "mamba":
        return {"ln": init_rms_norm(cfg.d_model),
                "mamba": ssm.init_mamba(ks[0], cfg, dtype)}
    if kind == "rwkv":
        return {"rwkv": ssm.init_rwkv(ks[0], cfg, dtype)}
    raise ValueError(kind)


def _stack_init(key, n: int, init_one):
    """Initialize n sublayer pytrees and stack leaves on axis 0."""
    keys = jax.random.split(key, n)
    trees = [init_one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ModelConfig):
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 8)
    init = jax.nn.initializers.normal(0.02)
    params = {
        "embed": init(ks[0], (cfg.vocab, cfg.d_model), dtype),
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init(ks[1], (cfg.d_model, cfg.vocab), dtype)

    if cfg.attn_every:                      # zamba2-style hybrid
        n_groups = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers % cfg.attn_every

        def group_init(k):
            return _stack_init(
                k, cfg.attn_every,
                lambda kk: _init_sublayer(kk, "mamba", cfg, dtype))

        params["groups"] = _stack_init(ks[2], n_groups, group_init)
        if tail:
            params["tail"] = _stack_init(
                ks[3], tail, lambda kk: _init_sublayer(kk, "mamba", cfg, dtype))
        params["shared_attn"] = _init_sublayer(ks[4], "attn_dense", cfg, dtype)
    else:
        pattern = cfg.block_pattern

        def block_init(k):
            kks = jax.random.split(k, len(pattern))
            return {f"{i}_{kind}": _init_sublayer(kks[i], kind, cfg, dtype)
                    for i, kind in enumerate(pattern)}

        params["blocks"] = _stack_init(ks[2], cfg.n_blocks, block_init)

    if cfg.encoder_layers:                  # whisper encoder + cross-attn
        def enc_init(k):
            return _init_sublayer(k, "attn_dense", cfg, dtype)

        params["encoder"] = {
            "frame_proj": init(ks[5], (AUDIO_DIM, cfg.d_model), dtype),
            "blocks": _stack_init(ks[6], cfg.encoder_layers, enc_init),
            "final_norm": init_rms_norm(cfg.d_model),
        }

        def cross_init(k):
            return {"ln": init_rms_norm(cfg.d_model),
                    "attn": init_attention(k, cfg, dtype)}

        params["cross"] = _stack_init(ks[7], cfg.n_blocks, cross_init)

    if cfg.frontend == "vision_tiles":
        params["patch_proj"] = init(
            jax.random.fold_in(key, 99), (VISION_DIM, cfg.d_model), dtype)
    return params


# ---------------------------------------------------------------------------
# Sublayer application
# ---------------------------------------------------------------------------

def _apply_sublayer(kind, p, cfg, x, *, positions, cache, cache_pos, xa=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_dense", "attn_moe"):
        a, cache = attention_apply(
            p["attn"], cfg, rms_norm(p["ln1"], x, cfg.norm_eps),
            positions=positions, kv_cache=cache, cache_pos=cache_pos)
        x = x + a
        h = rms_norm(p["ln2"], x, cfg.norm_eps)
        if kind == "attn_dense":
            x = x + ffn_apply(p["ffn"], h)
        else:
            mo, aux = moe_apply(p["moe"], cfg, h)
            x = x + mo
        return x, cache, aux
    if kind == "mamba":
        if cache is None:   # training/prefill from t=0: zero initial state
            cache = ssm.mamba_state_init(cfg, x.shape[0])
        m, cache = ssm.mamba_apply(
            p["mamba"], cfg, rms_norm(p["ln"], x, cfg.norm_eps), cache)
        return x + m, cache, aux
    if kind == "rwkv":
        if cache is None:
            cache = ssm.rwkv_state_init(cfg, x.shape[0])
        x, cache = ssm.rwkv_apply(p["rwkv"], cfg, x, cache)
        return x, cache, aux
    raise ValueError(kind)


def _cross_attend(p, cfg, x, enc_out=None, enc_kv=None):
    """Decoder cross-attention: from encoder activations (train/prefill) or a
    precomputed per-layer K/V cache (decode)."""
    h = rms_norm(p["ln"], x, cfg.norm_eps)
    if enc_kv is not None:
        a, _ = attention_apply(
            p["attn"], cfg, h, positions=jnp.zeros(x.shape[:2], jnp.int32),
            causal=False, kv_cache=enc_kv, cache_mode="read_all")
    else:
        a, _ = attention_apply(
            p["attn"], cfg, h, positions=jnp.zeros(x.shape[:2], jnp.int32),
            causal=False, xa=enc_out)
    return x + a


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def _attn_cache(cfg, batch, cache_len, dtype):
    length = min(cache_len, cfg.window) if cfg.window else cache_len
    # 128-aligned so the sequence axis is mesh-divisible (S-sharded decode)
    length = -(-length // 128) * 128
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      enc_len: int = 0):
    """Zero decode caches sized for `cache_len` past tokens (+1 slot room)."""
    dtype = _dtype(cfg)
    cache_len = cache_len + 8
    if cfg.attn_every:
        n_groups = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers % cfg.attn_every

        def rep(n, f):
            return jax.tree.map(lambda x: jnp.broadcast_to(
                x, (n,) + x.shape).copy(),
                f())

        state = {
            "groups_mamba": rep(n_groups, lambda: jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x, (cfg.attn_every,) + x.shape).copy(),
                ssm.mamba_state_init(cfg, batch))),
            "groups_attn": rep(n_groups,
                               lambda: _attn_cache(cfg, batch, cache_len,
                                                   dtype)),
        }
        if tail:
            state["tail_mamba"] = rep(tail, lambda: ssm.mamba_state_init(
                cfg, batch))
        return state
    caches = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind in ("attn_dense", "attn_moe"):
            one = lambda: _attn_cache(cfg, batch, cache_len, dtype)
        elif kind == "mamba":
            one = lambda: ssm.mamba_state_init(cfg, batch)
        elif kind == "rwkv":
            one = lambda: ssm.rwkv_state_init(cfg, batch)
        caches[f"{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_blocks,) + x.shape).copy(),
            one())
    if cfg.encoder_layers:
        enc_len = -(-max(enc_len, 8) // 8) * 8
        caches["cross_kv"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_blocks,) + x.shape).copy(),
            _attn_cache(cfg, batch, enc_len, dtype))
    return caches


# ---------------------------------------------------------------------------
# Backbone
# ---------------------------------------------------------------------------

def _maybe_checkpoint(body, remat: bool):
    if not remat:
        return body
    pol = tuning.flags().remat_policy
    if pol == "none":
        return body
    if pol == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def _run_blocks(params, cfg: ModelConfig, h, *, positions, caches, cache_pos,
                enc_out=None, remat=False):
    """Scan over blocks. Returns (h, new_caches, aux)."""
    decode = caches is not None

    if cfg.attn_every:
        shared = params["shared_attn"]

        def group_body(carry, inp):
            h, aux = carry
            gp = inp["p"]
            g_mamba = inp.get("mamba")
            g_attn = inp.get("attn")
            new_m = []
            for j in range(cfg.attn_every):
                sub_p = jax.tree.map(lambda x: x[j], gp)
                sub_c = jax.tree.map(lambda x: x[j], g_mamba) if decode else None
                h, c, _ = _apply_sublayer(
                    "mamba", sub_p, cfg, h, positions=positions,
                    cache=sub_c, cache_pos=cache_pos)
                new_m.append(c)
            h, new_attn, a2 = _apply_sublayer(
                "attn_dense", shared, cfg, h, positions=positions,
                cache=g_attn, cache_pos=cache_pos)
            aux = aux + a2
            out = {}
            if decode:
                out["mamba"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
                out["attn"] = new_attn
            return (h, aux), out

        xs = {"p": params["groups"]}
        if decode:
            xs["mamba"] = caches["groups_mamba"]
            xs["attn"] = caches["groups_attn"]
        body = _maybe_checkpoint(group_body, remat)
        (h, aux), outs = jax.lax.scan(body, (h, 0.0), xs)
        new_caches = None
        if decode:
            new_caches = dict(caches)
            new_caches["groups_mamba"] = outs["mamba"]
            new_caches["groups_attn"] = outs["attn"]
        if "tail" in params:
            tail_n = jax.tree.leaves(params["tail"])[0].shape[0]
            new_tail = []
            for j in range(tail_n):
                sub_p = jax.tree.map(lambda x: x[j], params["tail"])
                sub_c = (jax.tree.map(lambda x: x[j], caches["tail_mamba"])
                         if decode else None)
                h, c, _ = _apply_sublayer(
                    "mamba", sub_p, cfg, h, positions=positions,
                    cache=sub_c, cache_pos=cache_pos)
                new_tail.append(c)
            if decode:
                new_caches["tail_mamba"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *new_tail)
        return h, new_caches, aux

    pattern = cfg.block_pattern

    def block_body(carry, inp):
        h, aux = carry
        new_c = {}
        for i, kind in enumerate(pattern):
            sub_p = inp["p"][f"{i}_{kind}"]
            sub_c = inp.get(f"c{i}")
            h, c, a = _apply_sublayer(
                kind, sub_p, cfg, h, positions=positions, cache=sub_c,
                cache_pos=cache_pos)
            if kind in ("mamba", "rwkv") and not decode and c is not None:
                c = None          # training: recurrent states not threaded out
            if decode:
                new_c[f"c{i}"] = c
            aux = aux + a
        if enc_out is not None:
            h = _cross_attend(inp["xp"], cfg, h, enc_out=enc_out)
        elif decode and "cross" in inp:
            h = _cross_attend(inp["xp"], cfg, h, enc_kv=inp["cross"])
        return (h, aux), new_c

    xs = {"p": params["blocks"]}
    if cfg.encoder_layers:
        xs["xp"] = params["cross"]
    if decode:
        for i in range(len(pattern)):
            xs[f"c{i}"] = caches[f"{i}"]
        if cfg.encoder_layers:
            xs["cross"] = caches["cross_kv"]
    body = _maybe_checkpoint(block_body, remat)
    (h, aux), outs = jax.lax.scan(body, (h, 0.0), xs)
    new_caches = None
    if decode:
        new_caches = {f"{i}": outs[f"c{i}"] for i in range(len(pattern))}
        if cfg.encoder_layers:
            new_caches["cross_kv"] = caches["cross_kv"]
    return h, new_caches, aux


# ---------------------------------------------------------------------------
# Embedding / heads / frontends
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.frontend == "vision_tiles" and "patch_embeds" in batch:
        # stub vision tower: precomputed per-tile patch embeddings are
        # projected and scattered into the prompt prefix (anyres tiling).
        pe = (batch["patch_embeds"].astype(h.dtype) @ params["patch_proj"])
        n = pe.shape[1]
        h = jnp.concatenate([pe, h[:, n:]], axis=1)
    return h


def _logits(params, cfg: ModelConfig, h) -> jax.Array:
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return h @ head


def _run_encoder(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub mel frames (B, S, AUDIO_DIM). The conv
    frontend is stubbed as a linear projection per the brief."""
    enc = params["encoder"]
    h = frames.astype(_dtype(cfg)) @ enc["frame_proj"]
    positions = jnp.broadcast_to(
        jnp.arange(h.shape[1]), h.shape[:2]).astype(jnp.int32)

    def body(carry, p):
        h, = carry
        h, _, _ = _apply_sublayer("attn_dense", p, cfg, h,
                                  positions=positions, cache=None,
                                  cache_pos=None)
        return (h,), None

    (h,), _ = jax.lax.scan(body, (h,), enc["blocks"])
    return rms_norm(enc["final_norm"], h, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, batch: dict, *, remat: bool = False):
    """Full-sequence forward (training / prefill). Returns (logits, aux)."""
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(params, cfg, batch["frames"])
    h = _embed(params, cfg, batch)
    positions = jnp.broadcast_to(
        jnp.arange(h.shape[1]), h.shape[:2]).astype(jnp.int32)
    h, _, aux = _run_blocks(params, cfg, h, positions=positions, caches=None,
                            cache_pos=None, enc_out=enc_out, remat=remat)
    return _logits(params, cfg, h), aux


def loss_fn(params, cfg: ModelConfig, batch: dict, *, remat: bool = False,
            aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    logits, aux = forward(params, cfg, batch, remat=remat)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = batch["tokens"][:, 1:]
    mask = jnp.ones_like(targets, jnp.float32)
    if "loss_mask" in batch:
        mask = batch["loss_mask"][:, 1:].astype(jnp.float32)
    elif cfg.frontend == "vision_tiles" and "patch_embeds" in batch:
        n = batch["patch_embeds"].shape[1]
        mask = jnp.broadcast_to(
            (jnp.arange(targets.shape[1])[None, :] >= n), targets.shape
        ).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    total = loss + aux_weight * aux / max(cfg.n_layers, 1)
    return total, {"nll": loss, "aux": aux,
                   "tokens": denom}


def prefill(params, cfg: ModelConfig, batch: dict):
    """Process a full prompt, returning (last_logits, decode caches).

    Used by the serving path; for the dry-run's prefill cells this is the
    lowered computation.
    """
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(params, cfg, batch["frames"])
    h = _embed(params, cfg, batch)
    b, t = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t), (b, t)).astype(jnp.int32)
    # run blocks WITHOUT caches (chunked attention), then build caches from a
    # second cheap projection pass is wasteful; instead run with prefill-style
    # cache capture: for simplicity and O(seq) memory we re-run projections.
    h_out, _, _ = _run_blocks(params, cfg, h, positions=positions,
                              caches=None, cache_pos=None, enc_out=enc_out)
    return _logits(params, cfg, h_out[:, -1:]), enc_out


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, caches,
                pos: jax.Array):
    """One decode step: tokens (B, 1), absolute position `pos` (scalar)."""
    batch = {"tokens": tokens}
    h = _embed(params, cfg, batch)
    positions = jnp.full(h.shape[:2], pos, jnp.int32)
    h, caches, _ = _run_blocks(params, cfg, h, positions=positions,
                               caches=caches, cache_pos=pos)
    return _logits(params, cfg, h), caches
