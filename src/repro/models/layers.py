"""Shared neural-net layers for the architecture zoo.

Everything is pure-functional: ``init_*`` builds param pytrees, ``*_apply``
consumes them. Attention is *chunked* (online-softmax over KV blocks, a pure
JAX flash-attention) so 32k-prefill cells lower with O(block²) live memory
instead of O(seq²); sliding-window and cross-attention reuse the same body.

MoE expert compute is the paper's technique surfacing at LM scale: token
dispatch produces a batch of small per-expert GEMMs executed as ONE batched
einsum (``ecd,edf->ecf``) — exactly the batched-small-matmul structure of
Batched SpMM, with the same pad-to-capacity policy as `core.batching`
(DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro import tuning

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rms_norm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


def init_layer_norm(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
            + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, hd); positions: (..., T) int32."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., T, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    init = jax.nn.initializers.normal(0.02)
    p = {
        "wq": init(ks[0], (d, h * hd), dtype),
        "wk": init(ks[1], (d, kv * hd), dtype),
        "wv": init(ks[2], (d, kv * hd), dtype),
        "wo": init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, T, KV, hd) → (B, T, KV·groups, hd)."""
    return jnp.repeat(k, groups, axis=2)


def chunked_attention(
    q: jax.Array,          # (B, Tq, H, hd)
    k: jax.Array,          # (B, Tk, H, hd)   (already GQA-expanded)
    v: jax.Array,
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,   # absolute position of q[0]
    window: int = 0,
    q_block: int = 0,
    kv_block: int = 0,
) -> jax.Array:
    """Online-softmax blocked attention (pure-JAX flash attention).

    Memory is O(q_block × kv_block) per step instead of O(Tq × Tk); a 32k
    prefill lowers with MBs of live score memory rather than TBs.
    """
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    scale = hd ** -0.5
    q_block = min(q_block or tuning.flags().q_block, tq)
    kv_block = min(kv_block or tuning.flags().kv_block, tk)
    nq = -(-tq // q_block)
    nk = -(-tk // kv_block)
    # pad to block multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * q_block - tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_block - tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_block - tk), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, q_block, h, hd)
    kp = kp.reshape(b, nk, kv_block, h, hd)
    vp = vp.reshape(b, nk, kv_block, h, hd)

    q_pos = q_offset + jnp.arange(nq * q_block).reshape(nq, q_block)
    k_pos = jnp.arange(nk * kv_block).reshape(nk, kv_block)
    k_valid = (jnp.arange(nk * kv_block) < tk).reshape(nk, kv_block)

    def q_step(_, qi):
        qb, qpos = qi                                  # (B, qb, H, hd)

        def kv_step(carry, ki):
            acc, m, l = carry
            kb, vb, kpos, kval = ki
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = kval[None, None, None, :]
            if causal:
                mask = mask & (kpos[None, None, None, :]
                               <= qpos[None, None, :, None])
            if window:
                mask = mask & (kpos[None, None, None, :]
                               > qpos[None, None, :, None] - window)
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask, p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isneginf(m), m_safe, m) - m_safe)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, h, q_block, hd), jnp.float32)
        m0 = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4),
             k_pos, k_valid))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out.transpose(0, 2, 1, 3)         # (B, qb, H, hd)

    # scan over q blocks; qp axes → (nq, B, qb, H, hd)
    _, out = jax.lax.scan(
        q_step, None, (qp.transpose(1, 0, 2, 3, 4), q_pos))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_block, h, hd)
    return out[:, :tq].astype(q.dtype)


def packed_causal_attention(
    q: jax.Array,          # (B, T, H, hd)
    k: jax.Array,
    v: jax.Array,
    *,
    window: int = 0,
    block: int = 0,
) -> jax.Array:
    """Triangle-packed blocked attention (§Perf iteration, beyond-paper).

    The plain chunked scan visits all nq×nk block pairs; for causal masks
    ~half are fully masked, and for sliding windows all but a diagonal band.
    Here the (iq, ik) pair list is STATIC (numpy tril + window band filter),
    so skipped blocks cost nothing — compute AND panel traffic drop ~2× for
    causal, ~S/window× for windowed prefill. Online-softmax state (acc, m, l)
    is carried per q-block and updated at dynamic index iq; the merge is
    order-independent, so one flat scan over the pair list suffices.
    """
    import numpy as np

    b, t, h, hd = q.shape
    block = block or max(tuning.flags().q_block, tuning.flags().kv_block)
    block = min(block, t)
    nb = -(-t // block)
    pad = nb * block - t
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # (nb, B, blk, H, hd) — leading block axis for dynamic gathering
    qp = qp.reshape(b, nb, block, h, hd).transpose(1, 0, 2, 3, 4)
    kp = kp.reshape(b, nb, block, h, hd).transpose(1, 0, 2, 3, 4)
    vp = vp.reshape(b, nb, block, h, hd).transpose(1, 0, 2, 3, 4)

    iqs, iks = np.tril_indices(nb)
    if window:
        # keep only block pairs intersecting the window band
        keep = (iks + 1) * block - 1 > iqs * block - window
        iqs, iks = iqs[keep], iks[keep]
    scale = hd ** -0.5

    def step(carry, pair):
        acc, m, l = carry
        iq, ik = pair
        qb = jnp.take(qp, iq, axis=0)               # (B, blk, H, hd)
        kb = jnp.take(kp, ik, axis=0)
        vb = jnp.take(vp, ik, axis=0)
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        qpos = iq * block + jax.lax.broadcasted_iota(
            jnp.int32, (block, block), 0)
        kpos = ik * block + jax.lax.broadcasted_iota(
            jnp.int32, (block, block), 1)
        mask = (kpos <= qpos) & (kpos < t)
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_blk = jnp.take(m, iq, axis=0)             # (B, H, blk)
        l_blk = jnp.take(l, iq, axis=0)
        a_blk = jnp.take(acc, iq, axis=0)           # (B, H, blk, hd)
        m_new = jnp.maximum(m_blk, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.where(mask[None, None],
                      jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.exp(jnp.where(jnp.isneginf(m_blk), m_safe, m_blk) - m_safe)
        l_new = l_blk * corr + jnp.sum(p, axis=-1)
        a_new = a_blk * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb, preferred_element_type=jnp.float32)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, iq, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, iq, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, iq, 0)
        return (acc, m, l), None

    acc0 = jnp.zeros((nb, b, h, block, hd), jnp.float32)
    m0 = jnp.full((nb, b, h, block), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((nb, b, h, block), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(
        step, (acc0, m0, l0),
        (jnp.asarray(iqs, jnp.int32), jnp.asarray(iks, jnp.int32)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]      # (nb, B, H, blk, hd)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, nb * block, h, hd)
    return out[:, :t].astype(q.dtype)


def attention_apply(
    p,
    cfg: ModelConfig,
    x: jax.Array,                  # (B, T, D)
    *,
    positions: jax.Array,          # (B, T)
    causal: bool = True,
    kv_cache: dict | None = None,  # decode: {"k","v"} (B, S, KV, hd) ring/linear
    cache_pos: jax.Array | None = None,  # () int32 current absolute position
    xa: jax.Array | None = None,   # cross-attention source (B, Ta, D)
    cache_mode: str = "write",     # "write" (self decode) | "read_all" (cross)
):
    """Self/cross attention with GQA, optional qk-norm, RoPE, window and an
    optional decode-time KV cache. Returns (out, new_kv_cache)."""
    b, t, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, t, h, hd)
    if kv_cache is not None and cache_mode == "read_all":
        # cross-attention over a precomputed, static cache: no projection of
        # the source, no cache update, every slot valid.
        k, v = kv_cache["k"], kv_cache["v"]
        if cfg.qk_norm:
            q = rms_norm(p["q_norm"], q)
        groups = h // k.shape[2]
        k = _repeat_kv(k, groups)
        v = _repeat_kv(v, groups)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=jnp.float32) * hd ** -0.5
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v).astype(x.dtype)
        return (out.reshape(b, t, h * hd) @ p["wo"]).astype(x.dtype), kv_cache
    src = xa if xa is not None else x
    k = (src @ p["wk"]).reshape(b, src.shape[1], kv, hd)
    v = (src @ p["wv"]).reshape(b, src.shape[1], kv, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    if xa is None:                                     # RoPE on self-attn only
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = kv_cache
    if kv_cache is not None and xa is None:
        # decode: write this step's K/V at cache_pos (ring buffer if window)
        s_cache = kv_cache["k"].shape[1]
        slot = cache_pos % s_cache if cfg.window else cache_pos
        ck = jax.lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, slot, 0, 0))
        if tuning.flags().constrain_decode:
            # sequence-parallel KV: pin the cache (and its update) to
            # S-sharding over "model"; scores are then shard-local and only
            # the (B,H,1) softmax stats + (B,H,1,hd) output cross shards.
            dp = ("pod", "data")
            ck = tuning.constrain(ck, dp, "model", None, None)
            cv = tuning.constrain(cv, dp, "model", None, None)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv

    groups = h // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)

    if kv_cache is not None and xa is None:
        # single-token decode: direct (non-chunked) attention over the cache
        s_cache = k.shape[1]
        scale = hd ** -0.5
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=jnp.float32) * scale
        if tuning.flags().constrain_decode:
            # sequence-parallel decode attention: scores stay sharded on the
            # cache's S axis; only the softmax max/sum stats ((B,H,1) - bytes)
            # and the (B,H,1,hd) output reduction cross shards.
            s = tuning.constrain(s, ("pod", "data"), None, None, "model")
        slots = jnp.arange(s_cache)
        if cfg.window:
            # ring buffer (possibly larger than the window for 128-alignment):
            # slot s currently holds absolute position
            #   p_s = cache_pos - ((cache_pos - s) mod s_cache);
            # valid iff it exists (p_s ≥ 0) and is inside the window.
            age = jnp.mod(cache_pos - slots, s_cache)
            exists = (slots <= cache_pos) | (cache_pos >= s_cache)
            valid = exists & (age < cfg.window)
        else:
            valid = slots <= cache_pos
        s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1)
        if tuning.flags().constrain_decode:
            w = tuning.constrain(w, ("pod", "data"), None, None, "model")
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v).astype(x.dtype)
    elif tuning.flags().attention_impl == "pallas":
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(q, k, v, causal=causal and xa is None,
                              window=cfg.window).astype(x.dtype)
    elif (tuning.flags().attention_impl == "xla_packed"
          and causal and xa is None and k.shape[1] == q.shape[1]):
        out = packed_causal_attention(q, k, v, window=cfg.window)
    else:
        out = chunked_attention(q, k, v, causal=causal and xa is None,
                                window=cfg.window)
    out = out.reshape(b, t, h * hd) @ p["wo"]
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# FFN: SwiGLU and MoE
# ---------------------------------------------------------------------------

def init_ffn(key, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    init = jax.nn.initializers.normal(0.02)
    return {
        "w_gate": init(ks[0], (d, d_ff), dtype),
        "w_up": init(ks[1], (d, d_ff), dtype),
        "w_down": init(ks[2], (d_ff, d), dtype),
    }


def ffn_apply(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def init_moe(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 5)
    init = jax.nn.initializers.normal(0.02)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": init(ks[0], (d, e), jnp.float32),
        "w_gate": init(ks[1], (e, d, f), dtype),
        "w_up": init(ks[2], (e, d, f), dtype),
        "w_down": init(ks[3], (e, f, d), dtype),
    }
    if cfg.shared_expert:
        p["shared"] = init_ffn(ks[4], d, f, dtype)
    return p


def _moe_grouped(p, cfg: ModelConfig, x, capacity_factor):
    """Grouped local dispatch: each sequence is its own dispatch group with
    its own capacity — no cross-batch scatter, so the dispatch stays local to
    the data shard (what real EP systems do: dispatch group == DP shard).
    The batch dim rides through the expert GEMM as a leading batch axis."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(capacity_factor * t * k / e), 8)
    cap = -(-cap // 8) * 8

    logits = x.astype(jnp.float32) @ p["router"]          # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, k)             # (B, T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(eids[..., 0], e, dtype=jnp.float32),
                  axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    flat_e = eids.reshape(b, t * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)     # (B, T·k, E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1).astype(jnp.int32) - 1
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)
    xk = jnp.repeat(x, k, axis=1)                             # (B, T·k, D)

    def scatter_one(xe, fe, sl):
        return jnp.zeros((e, cap + 1, d), x.dtype).at[fe, sl].add(xe)

    # vmap over the batch dim so the scatter carries an explicit batch
    # dimension — GSPMD then partitions it over "data" instead of gathering
    # operands across the mesh (the §Perf fix for the 8.7 TB dispatch gather).
    buf = jax.vmap(scatter_one)(xk, flat_e, slot)
    buf = buf[:, :, :cap]
    dp = ("pod", "data")
    ms = tuning.axis_size("model")
    ep = bool(ms) and e % ms == 0        # expert-parallel vs TP-in-expert
    if ep:
        # EP: experts across "model"; activations follow the expert axis.
        buf = tuning.constrain(buf, dp, "model", None, None)
    else:
        buf = tuning.constrain(buf, dp, None, None, None)
    hidden = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    # TP-in-expert (E < mesh axis, e.g. mixtral E=8 on 16): the hidden d_ff
    # axis carries the "model" sharding instead — matches the F-sharded
    # expert weights, so no gather of either operand is ever needed.
    hidden = (tuning.constrain(hidden, dp, "model", None, None) if ep
              else tuning.constrain(hidden, dp, None, None, "model"))
    out_buf = jnp.einsum("becf,efd->becd", hidden, p["w_down"])
    out_buf = (tuning.constrain(out_buf, dp, "model", None, None) if ep
               else tuning.constrain(out_buf, dp, None, None, None))
    out_buf = jnp.pad(out_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))
    gathered = jax.vmap(lambda ob, fe, sl: ob[fe, sl])(
        out_buf, flat_e, slot)                                # (B, T·k, D)
    gathered = gathered * (gate_vals.reshape(b, t * k, 1).astype(x.dtype)
                           * keep[..., None].astype(x.dtype))
    out = gathered.reshape(b, t, k, d).sum(axis=2)
    if cfg.shared_expert:
        out = out + ffn_apply(p["shared"], x)
    return out, aux


def moe_apply(p, cfg: ModelConfig, x: jax.Array, *, capacity_factor=None):
    """Top-k MoE with capacity dispatch + ONE batched expert GEMM.

    The dispatch buffer is (E, C, D) — a batch of E small (C × D) matrices —
    and expert compute is a single einsum over the expert axis: the LM-scale
    incarnation of the paper's Batched SpMM/GEMM (one op for the whole batch
    of small matmuls instead of E sequential kernels). Returns (out, aux_loss).
    """
    if capacity_factor is None:
        capacity_factor = tuning.flags().capacity_factor
    if tuning.flags().moe_dispatch == "grouped":
        return _moe_grouped(p, cfg, x, capacity_factor)
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * t
    xf = x.reshape(n, d)
    logits = (xf.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, k)           # (n, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch/GShard style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(eids[:, 0], e, dtype=jnp.float32)), axis=0)
    aux = e * jnp.sum(me * ce)

    cap = max(int(capacity_factor * n * k / e), 8)
    cap = -(-cap // 8) * 8
    flat_e = eids.reshape(-1)                            # (n·k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.float32)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1).astype(jnp.int32) - 1
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                     # overflow → slot `cap`
    # dispatch: (E, C+1, D) scatter, slot `cap` is the drop bucket
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    xk = jnp.repeat(xf, k, axis=0)
    buf = buf.at[flat_e, slot].add(xk)
    buf = buf[:, :cap]
    sharded = tuning.flags().moe_dispatch == "sharded_scatter"
    if sharded:
        # expert-parallel pin: dispatch buffer, expert activations and the
        # return buffer all shard the EXPERT axis over "model", so the three
        # expert GEMMs run 1/16-sized per device with an all-to-all at the
        # dispatch boundary instead of replicated expert compute.
        buf = tuning.constrain(buf, "model", None, None)
    # one batched GEMM over all experts (the paper's single-kernel batch)
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if sharded:
        hidden = tuning.constrain(hidden, "model", None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", hidden, p["w_down"])
    if sharded:
        out_buf = tuning.constrain(out_buf, "model", None, None)
    out_buf = jnp.pad(out_buf, ((0, 0), (0, 1), (0, 0)))
    # combine
    gathered = out_buf[flat_e, slot]                     # (n·k, d)
    if sharded:
        gathered = tuning.constrain(gathered, ("pod", "data"), None)
    gathered = gathered * (gate_vals.reshape(-1, 1).astype(x.dtype)
                           * keep[:, None].astype(x.dtype))
    out = gathered.reshape(n, k, d).sum(axis=1)
    if cfg.shared_expert:
        out = out + ffn_apply(p["shared"], xf)
    return out.reshape(b, t, d), aux
