"""Giant-graph sampling tier: CSC neighbor sampling, geometry bucketing,
hot-node feature caching, and the assembled minibatch loader (DESIGN.md §14).
"""
from repro.sampling.bucketing import (  # noqa: F401
    block_caps,
    block_ladders,
    bucket_for,
)
from repro.sampling.feature_cache import (  # noqa: F401
    FeatureStore,
    HotNodeCache,
    Prefetcher,
    static_hot_ids,
)
from repro.sampling.item_sampler import ItemSampler  # noqa: F401
from repro.sampling.loader import (  # noqa: F401
    SampledBatch,
    SampledNodeLoader,
)
from repro.sampling.neighbor import neighbor_sample, sample_layer  # noqa: F401
