"""Gather-on-demand feature fetch with a hot-node cache and double-buffered
prefetch (DESIGN.md §14).

At giant-graph scale the node feature matrix lives host-side (or slower);
each minibatch gathers only its block's source rows. Real graphs are
Zipf-hot — hub nodes appear in almost every sampled neighborhood — so a
small cache over the hottest rows absorbs most of the gather traffic. Two
admission policies:

* ``"static"`` — pin the top-in-degree rows once (the CSC's ``in_degrees``
  is the admission statistic). Zero bookkeeping per fetch; the right default
  when hubs are structural (powerlaw graphs).
* ``"lru"`` — classic recency eviction, for drifting access patterns.

Hit-rate and fetch-byte accounting are first-class metrics through the
PR 9 observability registry (``featcache_*`` — same substrate as the kernel
spans and trainer gauges, one ``snapshot()`` covers all of them), and the
bench gate asserts cache-on fetch-bytes ≤ cache-off.

``Prefetcher`` overlaps the NEXT minibatch's sample+gather with the current
step (one-deep double buffer — a ``Queue(maxsize=1)`` worker thread; depth
1 is enough because sampling is the producer and the jitted step the
consumer, and deeper queues only add host memory pressure).
"""
from __future__ import annotations

import collections
import queue
import threading
from typing import Callable, Iterable, Iterator

import numpy as np

from repro.observability import default_registry


class FeatureStore:
    """Backing feature matrix with gather accounting.

    Wraps the full (n_nodes, feat_dim) host array and counts every byte a
    ``gather()`` touches — the denominator of the cache's traffic-saved
    story. Pass ``registry=None`` to use the process default.
    """

    def __init__(self, features: np.ndarray, *, registry=None):
        if features.ndim != 2:
            raise ValueError(f"features must be 2-D, got {features.shape}")
        self.features = features
        reg = registry if registry is not None else default_registry()
        self._fetch_bytes = reg.counter(
            "featcache_fetch_bytes_total",
            "bytes gathered from the backing feature store")
        self._fetch_rows = reg.counter(
            "featcache_fetch_rows_total",
            "rows gathered from the backing feature store")
        self.row_bytes = int(features.shape[1] * features.dtype.itemsize)

    @property
    def n_nodes(self) -> int:
        return self.features.shape[0]

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]

    def gather(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids)
        self._fetch_rows.inc(len(ids))
        self._fetch_bytes.inc(len(ids) * self.row_bytes)
        return self.features[ids]


class HotNodeCache:
    """Hot-row cache in front of a :class:`FeatureStore`.

    ``gather(ids)`` returns the same array a raw store gather would — cache
    hits are served from the cache's copy, misses fall through to the store
    (and, under ``"lru"``, are admitted). Hit/miss counters and a hit-rate
    gauge are registered per policy label so cache-on/off A-B runs separate
    cleanly in one snapshot.
    """

    def __init__(
        self,
        store: FeatureStore,
        capacity: int,
        *,
        policy: str = "static",
        hot_ids: np.ndarray | None = None,
        registry=None,
    ):
        if policy not in ("static", "lru"):
            raise ValueError(f"unknown cache policy {policy!r}: "
                             "expected 'static' or 'lru'")
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if policy == "static" and hot_ids is None:
            raise ValueError("static policy needs hot_ids (e.g. the top "
                             "in-degree nodes from CSCGraph.in_degrees())")
        self.store = store
        self.capacity = int(capacity)
        self.policy = policy
        reg = registry if registry is not None else default_registry()
        self._hits = reg.counter("featcache_hit_total",
                                 "feature-cache row hits")
        self._misses = reg.counter("featcache_miss_total",
                                   "feature-cache row misses")
        self._hit_rate = reg.gauge("featcache_hit_rate",
                                   "cumulative feature-cache hit rate")
        if policy == "static":
            hot_ids = np.asarray(hot_ids, np.int64)[:capacity]
            # one up-front bulk gather fills the cache; NOT counted against
            # the store's per-minibatch fetch counters (it is a fixed,
            # amortized cost, and counting it would let a tiny run look
            # worse with the cache than without)
            self._rows = {int(i): store.features[int(i)] for i in hot_ids}
        else:
            self._rows = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._rows)

    def hit_rate(self) -> float:
        h = self._hits.value(policy=self.policy)
        m = self._misses.value(policy=self.policy)
        return h / (h + m) if (h + m) else 0.0

    def gather(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids)
        rows = self._rows
        hit_mask = np.fromiter((int(i) in rows for i in ids), bool,
                               count=len(ids))
        miss_ids = ids[~hit_mask]
        out = np.empty((len(ids), self.store.feat_dim),
                       self.store.features.dtype)
        if len(miss_ids):
            out[~hit_mask] = self.store.gather(miss_ids)
        for k in np.flatnonzero(hit_mask):
            out[k] = rows[int(ids[k])]
        if self.policy == "lru":
            # membership is re-checked against the LIVE dict, not hit_mask:
            # an admission earlier in this batch may already have evicted a
            # row that was a hit when the mask was computed (its data is
            # safely in `out`), and a repeated miss id is only admitted once
            for k, i in enumerate(ids):
                i = int(i)
                if i in rows:
                    rows.move_to_end(i)
                else:
                    rows[i] = out[k]
                    if len(rows) > self.capacity:
                        rows.popitem(last=False)
        n_hit = int(hit_mask.sum())
        self._hits.inc(n_hit, policy=self.policy)
        self._misses.inc(len(ids) - n_hit, policy=self.policy)
        self._hit_rate.set(self.hit_rate(), policy=self.policy)
        return out


def static_hot_ids(in_degrees: np.ndarray, capacity: int) -> np.ndarray:
    """Top-``capacity`` node ids by in-degree (descending, stable) — the
    static cache's admission set."""
    order = np.argsort(-np.asarray(in_degrees), kind="stable")
    return order[:capacity].astype(np.int64)


class Prefetcher:
    """One-deep double buffer over any minibatch iterator.

    A worker thread drains ``it`` into a ``Queue(maxsize=1)``: while the
    trainer steps on minibatch ``t``, the worker is already sampling and
    gathering minibatch ``t+1``. Exceptions propagate to the consumer at the
    item where they occurred; iteration ends cleanly on exhaustion.
    """

    _DONE = object()

    def __init__(self, it: Iterable, *, registry=None):
        self._q: queue.Queue = queue.Queue(maxsize=1)
        reg = registry if registry is not None else default_registry()
        self._depth = reg.gauge("featcache_prefetch_depth",
                                "minibatches resident in the prefetch buffer")
        self._thread = threading.Thread(
            target=self._run, args=(iter(it),), daemon=True)
        self._thread.start()

    def _run(self, it: Iterator) -> None:
        try:
            for item in it:
                self._q.put(item)
        except BaseException as e:  # propagate to the consumer
            self._q.put((self._DONE, e))
        else:
            self._q.put((self._DONE, None))

    def __iter__(self):
        while True:
            self._depth.set(self._q.qsize())
            item = self._q.get()
            if isinstance(item, tuple) and len(item) == 2 \
                    and item[0] is self._DONE:
                self._thread.join()
                if item[1] is not None:
                    raise item[1]
                return
            yield item
