"""Geometry bucketing for sampled blocks (DESIGN.md §14).

Sampled blocks have data-dependent shapes — every minibatch draws a
different ``(n_src, nnz)`` per layer — and JAX recompiles per shape. We
reuse the serving scheduler's ladder policy (``core.batching.tier_ladder``,
DESIGN.md §8): each layer gets a small static set of ``(m_pad, nnz_pad)``
rungs derived from its worst-case caps, every sampled block is padded UP to
the smallest covering rung, and the per-layer compile count is bounded by
``len(ladder)`` for the whole run.

The caps are closed-form from the sampling parameters alone (no data pass):
walking seed-side inward, layer ``i``'s destination count is at most
``batch · ∏_{l>i} (fanout_l + 1)`` (each dst contributes itself — the dst
prefix — plus at most ``fanout`` sampled sources), its source count one more
fanout factor, and its nnz at most ``dst_cap · fanout_i``.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.batching import tier_ladder


def block_caps(
    batch_size: int,
    fanouts: Sequence[int],
    *,
    n_nodes: int | None = None,
) -> list[tuple[int, int]]:
    """Per-layer worst-case ``(m_cap, nnz_cap)``, input-side first (matching
    ``neighbor_sample``'s block order). ``n_nodes`` optionally clamps the
    node caps — a small graph can't produce more sources than it has nodes.
    """
    fanouts = list(fanouts)
    caps = []
    dst_cap = batch_size
    for fanout in reversed(fanouts):      # seed-side inward
        src_cap = dst_cap * (fanout + 1)  # dst prefix + sampled sources
        if n_nodes is not None:
            dst_cap = min(dst_cap, n_nodes)
            src_cap = min(src_cap, n_nodes)
        caps.append((src_cap, dst_cap * fanout))
        dst_cap = src_cap
    return list(reversed(caps))


def block_ladders(
    batch_size: int,
    fanouts: Sequence[int],
    *,
    n_nodes: int | None = None,
    levels: int = 3,
) -> list[tuple[tuple[int, int], ...]]:
    """One ``tier_ladder`` per layer (input-side first): the static rung sets
    the loader pads every sampled block into. Total compile count per layer
    is at most ``levels`` regardless of epoch length."""
    return [
        tier_ladder(m_max=m_cap, nnz_max=nnz_cap, levels=levels)
        for m_cap, nnz_cap in block_caps(batch_size, fanouts,
                                         n_nodes=n_nodes)
    ]


def bucket_for(
    ladder: Sequence[tuple[int, int]],
    n_src: int,
    nnz: int,
) -> tuple[int, int]:
    """Smallest rung covering ``(n_src, nnz)`` on BOTH axes. The top rung
    covers every admissible block by construction; exceeding it is a caller
    bug (the caps were computed from different sampling parameters)."""
    for m_pad, nnz_pad in ladder:         # ladder is sorted ascending
        if n_src <= m_pad and nnz <= nnz_pad:
            return (m_pad, nnz_pad)
    raise ValueError(
        f"block (n_src={n_src}, nnz={nnz}) exceeds the top ladder rung "
        f"{tuple(ladder[-1])} — ladder built for different sampling params?")
