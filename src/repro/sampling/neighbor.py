"""Fanout-bounded neighbor sampling over a :class:`~repro.core.csc.CSCGraph`
(DESIGN.md §14).

Modeled on DGL graphbolt's ``csc_sampling_graph`` / ``minibatch_sampler``
split: the static CSC structure owns the graph, this module owns the
per-minibatch randomness. ``neighbor_sample`` walks the layer stack from the
seed (output) side inward: for each layer it samples at most ``fanout``
in-neighbors per current destination node, compacts the touched node ids
into local 0-based ids with the destinations as the PREFIX of the source
set (the ``include_dst_in_src`` invariant ``core.csc.Block`` documents), and
emits the bipartite adjacency as a kernel-ready padded ``BatchedCOO``.

Determinism: the entire multi-layer sample is a pure function of
``(csc, seeds, fanouts, seed)`` — same seed, same blocks, bitwise. A
checkpoint-resumed trainer re-derives any minibatch's blocks from its
``(loader seed, epoch, batch index)`` coordinates alone.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.csc import Block, CSCGraph, make_block


def _compact(seeds: np.ndarray, flat_src: np.ndarray):
    """Local-id compaction with the dst set as prefix: returns
    ``(src_ids, cols_local)`` where ``src_ids[:len(seeds)] == seeds`` and
    every entry of ``flat_src`` maps to its position in ``src_ids``
    (first-appearance order — deterministic, no hash-order dependence)."""
    cat = np.concatenate([seeds, flat_src]) if len(flat_src) else seeds
    _, first = np.unique(cat, return_index=True)
    src_ids = cat[np.sort(first)]          # unique, in first-appearance order
    sorter = np.argsort(src_ids)
    if len(flat_src):
        cols = sorter[np.searchsorted(src_ids, flat_src, sorter=sorter)]
    else:
        cols = np.zeros((0,), np.int64)
    return src_ids.astype(np.int64), cols.astype(np.int32)


def sample_layer(
    csc: CSCGraph,
    seeds: np.ndarray,
    fanout: int,
    rng: np.random.Generator,
):
    """One layer's raw sample: for each seed (destination), up to ``fanout``
    of its in-neighbors, without replacement (all of them when the true
    in-degree is below the fanout — never padded back up).

    Returns ``(rows, cols, src_ids)``: LOCAL dst row ids, LOCAL src col ids,
    and the dst-prefixed global id map.
    """
    if fanout < 1:
        raise ValueError(f"fanout must be >= 1, got {fanout}")
    picked = []
    indptr, indices = csc.indptr, csc.indices
    for g in seeds:
        lo, hi = int(indptr[g]), int(indptr[g + 1])
        deg = hi - lo
        if deg <= fanout:
            picked.append(indices[lo:hi])
        else:
            picked.append(indices[lo + rng.choice(deg, size=fanout,
                                                  replace=False)])
    counts = np.fromiter((len(p) for p in picked), np.int64,
                         count=len(picked))
    rows = np.repeat(np.arange(len(seeds), dtype=np.int32), counts)
    flat_src = (np.concatenate(picked) if len(picked) and counts.sum()
                else np.zeros((0,), np.int64))
    src_ids, cols = _compact(np.asarray(seeds, np.int64),
                             flat_src.astype(np.int64))
    return rows, cols, src_ids


def neighbor_sample(
    csc: CSCGraph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    *,
    seed: int | tuple = 0,
    normalize: str = "mean",
    shapes: Sequence[tuple[int, int] | None] | None = None,
) -> list[Block]:
    """Sample one minibatch's layered blocks (graphbolt's minibatch shape).

    ``fanouts[i]`` bounds layer ``i``'s per-destination sample — layer 0 is
    the INPUT-side layer (applied first in the forward pass), layer ``L-1``
    the seed-side layer, matching the returned block order: ``blocks[-1]``
    has ``dst == seeds`` and ``blocks[i].dst_ids() == blocks[i+1].src_ids``
    (the chaining invariant the block forward pass slices on). Sampling
    itself walks seed-side inward, so each layer's destinations are the
    previous (outer) layer's source set.

    ``shapes`` optionally pins each block's padded ``(m_pad, nnz_pad)`` to a
    bucket rung (``repro.sampling.bucketing``) so every layer compiles a
    bounded set of programs; ``None`` entries pad minimally.

    ``seed`` may be an int or an int tuple (e.g. ``(loader_seed, epoch,
    batch_index)``) — anything ``np.random.default_rng`` accepts as a seed
    sequence — making every minibatch's randomness addressable.
    """
    seeds = np.asarray(seeds, np.int64)
    if len(seeds) == 0:
        raise ValueError("neighbor_sample needs at least one seed node")
    if len(np.unique(seeds)) != len(seeds):
        raise ValueError("seed nodes must be unique (they become the "
                         "compacted dst prefix)")
    if shapes is not None and len(shapes) != len(fanouts):
        raise ValueError(f"shapes has {len(shapes)} entries for "
                         f"{len(fanouts)} layers")
    rng = np.random.default_rng(seed)
    raw = []                                # seed-side first
    cur = seeds
    for fanout in reversed(list(fanouts)):
        rows, cols, src_ids = sample_layer(csc, cur, fanout, rng)
        raw.append((rows, cols, src_ids, len(cur)))
        cur = src_ids
    blocks = []
    for i, (rows, cols, src_ids, n_dst) in enumerate(reversed(raw)):
        shape = shapes[i] if shapes is not None else None
        m_pad, nnz_pad = shape if shape is not None else (None, None)
        blocks.append(make_block(rows, cols, src_ids, n_dst,
                                 m_pad=m_pad, nnz_pad=nnz_pad,
                                 normalize=normalize))
    return blocks
