"""Seed-node minibatching with epoch-addressable shuffling (DESIGN.md §14).

The graphbolt split: the ``ItemSampler`` owns WHICH seed nodes form each
minibatch, ``neighbor_sample`` owns the neighborhood draw around them. Both
derive their randomness from ``(seed, epoch[, batch])`` coordinates rather
than a sequentially-consumed stream, so a checkpoint-restored run can
reconstruct any epoch's exact batch order without replaying prior epochs —
the same contract ``data.graphs.batches`` follows.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


class ItemSampler:
    """Deterministic seed-node batcher over a fixed id set.

    ``epoch(e)`` yields ``(batch_index, seed_ids)`` pairs; the permutation is
    a pure function of ``(seed, e)``, so epochs are independently
    addressable (resume-safe) and distinct (no repeated order across epochs).
    """

    def __init__(
        self,
        item_ids: np.ndarray,
        batch_size: int,
        *,
        seed: int = 0,
        shuffle: bool = True,
        drop_remainder: bool = True,
    ):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.item_ids = np.asarray(item_ids, np.int64)
        if len(np.unique(self.item_ids)) != len(self.item_ids):
            raise ValueError("item_ids must be unique (seed nodes become "
                             "the compacted dst prefix)")
        self.batch_size = int(batch_size)
        self.seed = int(seed)
        self.shuffle = bool(shuffle)
        self.drop_remainder = bool(drop_remainder)

    def batches_per_epoch(self) -> int:
        n = len(self.item_ids)
        return n // self.batch_size if self.drop_remainder else \
            -(-n // self.batch_size)

    def epoch(self, epoch: int) -> Iterator[tuple[int, np.ndarray]]:
        ids = self.item_ids
        if self.shuffle:
            perm = np.random.default_rng((self.seed, epoch)).permutation(
                len(ids))
            ids = ids[perm]
        n_full = len(ids) // self.batch_size
        for b in range(n_full):
            yield b, ids[b * self.batch_size:(b + 1) * self.batch_size]
        rem = len(ids) - n_full * self.batch_size
        if rem and not self.drop_remainder:
            yield n_full, ids[n_full * self.batch_size:]
