"""Sampled-minibatch loader: ItemSampler × neighbor_sample × feature gather
(DESIGN.md §14).

``SampledNodeLoader`` is the assembled giant-graph input pipeline the
trainer consumes: per epoch it shuffles the seed set (``ItemSampler``,
``(seed, epoch)``-addressable), samples each minibatch's layered blocks
(``neighbor_sample``, ``(seed, epoch, batch)``-addressable), pads every
block to its layer's bucket rung (``bucketing.block_ladders`` — bounded
compile count), and gathers the input-layer source features through the
optional hot-node cache. Wrap ``epoch(e)`` in a
:class:`~repro.sampling.feature_cache.Prefetcher` to overlap the next
minibatch's sample+gather with the current jitted step.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from repro.core.csc import Block, CSCGraph
from repro.core.formats import coo_from_lists
from repro.sampling.bucketing import block_ladders, bucket_for
from repro.sampling.feature_cache import FeatureStore, HotNodeCache
from repro.sampling.item_sampler import ItemSampler
from repro.sampling.neighbor import neighbor_sample


@dataclasses.dataclass(frozen=True)
class SampledBatch:
    """One training minibatch: layered blocks (input-side first), the
    input-layer source features padded to ``blocks[0].m_pad`` rows, and the
    per-seed labels aligned with ``blocks[-1]``'s dst prefix."""

    blocks: list
    x: np.ndarray          # (blocks[0].m_pad, feat_dim) — rows >= n_src zero
    labels: np.ndarray     # (batch_size,) seed-node labels
    seeds: np.ndarray      # (batch_size,) global seed ids
    epoch: int
    batch_index: int

    def shape_key(self) -> tuple:
        """Static geometry of this minibatch — distinct keys = distinct
        compiled programs. Tests bound ``len({...shape_key()...})`` by the
        ladder product."""
        return tuple((b.m_pad, b.nnz_pad) for b in self.blocks)


class SampledNodeLoader:
    """Deterministic sampled-minibatch stream over one :class:`CSCGraph`."""

    def __init__(
        self,
        csc: CSCGraph,
        features: np.ndarray,
        labels: np.ndarray,
        seed_ids: np.ndarray,
        *,
        fanouts: Sequence[int],
        batch_size: int,
        seed: int = 0,
        levels: int = 3,
        cache: HotNodeCache | None = None,
        store: FeatureStore | None = None,
        registry=None,
    ):
        if len(labels) != csc.n_nodes or len(features) != csc.n_nodes:
            raise ValueError(
                f"features ({len(features)}) / labels ({len(labels)}) must "
                f"cover all {csc.n_nodes} nodes")
        self.csc = csc
        self.labels = np.asarray(labels)
        self.fanouts = list(fanouts)
        self.seed = int(seed)
        self.sampler = ItemSampler(seed_ids, batch_size, seed=seed)
        self.ladders = block_ladders(batch_size, self.fanouts,
                                     n_nodes=csc.n_nodes, levels=levels)
        if cache is not None:
            self.store = cache.store
            self.fetch = cache.gather
        else:
            self.store = store if store is not None else \
                FeatureStore(features, registry=registry)
            self.fetch = self.store.gather

    def batches_per_epoch(self) -> int:
        return self.sampler.batches_per_epoch()

    def sample_batch(self, epoch: int, batch_index: int,
                     seeds: np.ndarray) -> SampledBatch:
        """Build one minibatch — pure in ``(loader seed, epoch, batch_index,
        seeds)``, so any step is reconstructible post-restore."""
        blocks = neighbor_sample(
            self.csc, seeds, self.fanouts,
            seed=(self.seed, epoch, batch_index))
        blocks = [
            self._rebucket(b, self.ladders[i]) for i, b in enumerate(blocks)
        ]
        b0 = blocks[0]
        x = np.zeros((b0.m_pad, self.store.feat_dim),
                     self.store.features.dtype)
        x[:b0.n_src] = self.fetch(b0.src_ids)
        return SampledBatch(blocks=blocks, x=x,
                            labels=self.labels[seeds],
                            seeds=np.asarray(seeds, np.int64),
                            epoch=epoch, batch_index=batch_index)

    def _rebucket(self, block: Block, ladder) -> Block:
        """Pad a block UP to its layer's smallest covering rung. Edge triples
        (incl. the sampled-degree normalization) are carried over verbatim —
        only the structural zero padding grows."""
        m_pad, nnz_pad = bucket_for(ladder, block.n_src, block.nnz)
        if m_pad == block.m_pad and nnz_pad == block.nnz_pad:
            return block
        nnz = block.nnz
        adj = coo_from_lists(
            [(np.asarray(block.adj.row_ids[0][:nnz]),
              np.asarray(block.adj.col_ids[0][:nnz]),
              np.asarray(block.adj.values[0][:nnz]))],
            [block.n_dst], nnz_pad=nnz_pad)
        return dataclasses.replace(block, adj=adj, m_pad=m_pad)

    def epoch(self, epoch: int) -> Iterator[SampledBatch]:
        for batch_index, seeds in self.sampler.epoch(epoch):
            yield self.sample_batch(epoch, batch_index, seeds)
