"""Adaptive dispatch + autotune for Batched SpMM (DESIGN.md §5).

Makes ``impl="auto"`` a first-class value in ``repro.core.spmm.batched_spmm``:

- :mod:`repro.autotune.cost_model` — shape-keyed analytic ranking of the
  implementations (roofline terms + dispatch overheads over the planner's
  case analysis);
- :mod:`repro.autotune.selector` — the Decision object and precedence rules
  (case-3 force → tuning-cache winner → model winner);
- :mod:`repro.autotune.cache` — persistent JSON cache of on-device
  measurements ($REPRO_TUNE_CACHE), refining the model per workload key.
"""
from repro.autotune.cache import (  # noqa: F401
    ENV_VAR,
    TuningCache,
    autotune,
    default_cache,
    measure_workload,
)
from repro.autotune.cost_model import (  # noqa: F401
    GSPMM_IMPLS,
    PRECISION_IMPLS,
    Workload,
    estimate,
    estimate_layer,
    precision_of,
    rank,
    rank_layer,
    spmm_plan,
    supports_gspmm,
)
from repro.autotune.selector import (  # noqa: F401
    KINDS,
    Decision,
    forced_decision,
    resolve_auto,
    select_graph_conv_impl,
    select_impl,
)

__all__ = [
    "ENV_VAR", "TuningCache", "autotune", "default_cache", "measure_workload",
    "GSPMM_IMPLS", "PRECISION_IMPLS", "Workload", "estimate",
    "estimate_layer", "precision_of", "rank", "rank_layer", "spmm_plan",
    "supports_gspmm", "KINDS", "Decision", "forced_decision", "resolve_auto",
    "select_graph_conv_impl", "select_impl",
]
