"""Analytic per-implementation cost model for Batched SpMM (DESIGN.md §5).

The paper's §IV-B/§IV-C resource-assignment policy decides *how* a batch is
blocked (``repro.core.batching.BatchPlan``); this module extends that case
analysis into a *which-kernel* decision by estimating wall time for each of
the six implementations in ``repro.kernels.ops.IMPLS`` on a shape-keyed
workload. The estimate is a two-term roofline (compute vs HBM traffic — the
same hardware constants as ``repro.analysis.roofline.HW``) plus the dispatch /
grid-step overheads that batching exists to amortize:

    t(impl) = max(flops / unit_peak, bytes / hbm_bw) + overheads

The model sees only static shapes — ``(batch, m_pad, nnz_pad, k_pad, n_b,
itemsize)`` — so selection is trace-safe: ``nnz_pad`` (the padded non-zero
slot count) stands in for density, exactly like the planner's ``slots``
argument. Padded slots cost real bandwidth on TPU (they are multiplied by
0.0, not skipped), so charging them is faithful, not pessimistic.

Per-impl traffic/compute accounting (see each kernel's module docstring for
the execution structure being modeled):

- ``ref``      scatter-add: gathers one B row per non-zero, then a
               segment-sum into the output; the scatter is charged a
               read-modify-write penalty on the output.
- ``ell``      XLA row-split: one B gather per ELL slot column, no scatter
               (each output row is owned by one reduction).
- ``pallas_ell``  same arithmetic, but panel-blocked: inputs are re-read once
               per column panel and the output block stays VMEM-resident.
- ``csr``      XLA CSR segment-sum: the ref traffic plus the rpt arrays —
               the scatter stays, only the layout changes.
- ``pallas_csr``  CSR row-split (DESIGN.md §9): the ELL arithmetic with the
               inner loop bounded by the true max row degree (statically:
               ``k_pad`` when known, else the uniform ``nnz_pad / m_pad``
               estimate) and HBM traffic on the FLAT nnz arrays —
               ``nnz_pad`` slots, not ``m_pad · k_pad`` — which is what
               makes CSR win skewed-degree batches (GE-SpMM's case).
               Format conversions (COO→ELL, COO→CSR, densify) are charged
               to no impl: every non-COO path converts inside ``_forward``,
               so the ranking compares like with like; the real conversion
               cost is measured by ``benchmarks/bench_formats.py``.
- ``pallas_coo``  the one-hot MXU scatter: each CHUNK of non-zeros costs a
               (CHUNK × m_pad)ᵀ × (CHUNK × n_block) contraction.
- ``dense`` / ``pallas_gemm``  densify (write + read m_pad² per matrix) then
               a batched GEMM at MXU tile efficiency.
- ``loop``     the non-batched baseline: ``batch`` sequential steps, each
               paying the per-step dispatch latency the paper's Fig. 2
               measures — modeled, like measured, as strictly dominated for
               real batch sizes.
"""
from __future__ import annotations

import dataclasses
import functools

# Reduced-precision kernel variants (DESIGN.md §10): variant impl →
# (base impl, storage policy). The base impl defines the execution structure
# (and therefore the roofline branch); the policy defines the bytes each
# value/index element costs on the wire. "bf16" stores values, features and
# column indices (int16) at 2 bytes; "i8" stores values as int8 quantization
# codes (1 byte) + int16 indices while B and the output stay at the caller's
# f32. Every variant accumulates in f32 inside the kernel.
PRECISION_IMPLS = {
    "ell_bf16": ("ell", "bf16"),
    "csr_bf16": ("csr", "bf16"),
    "pallas_ell_bf16": ("pallas_ell", "bf16"),
    "pallas_csr_bf16": ("pallas_csr", "bf16"),
    "pallas_coo_bf16": ("pallas_coo", "bf16"),
    "pallas_ell_i8": ("pallas_ell", "i8"),
    "pallas_csr_i8": ("pallas_csr", "i8"),
    "fused_bf16": ("fused", "bf16"),
    "pallas_hybrid_bf16": ("pallas_hybrid", "bf16"),
}


def precision_of(impl: str) -> tuple[str, str]:
    """(base impl, storage policy) for any registry impl — ("csr", "bf16")
    for a variant, (impl, "f32") for the full-precision impls."""
    return PRECISION_IMPLS.get(impl, (impl, "f32"))


# Impls that implement the full g-SpMM matrix (op × reduce × edge-feature
# width — DESIGN.md §11). The GEMM class (dense/pallas_gemm) IS the
# (mul, sum) product and cannot express other reduces; the precision
# variants stay (mul, sum)-only for now, so a g-SpMM workload (reduce !=
# "sum" or d_e set) restricts the candidate ladder to this set at f32.
GSPMM_IMPLS = ("ref", "loop", "ell", "pallas_ell", "csr", "pallas_csr",
               "pallas_coo")


def supports_gspmm(impl: str) -> bool:
    """Whether ``impl`` can run a non-(mul, sum) or vector-edge workload."""
    base, policy = precision_of(impl)
    return base in GSPMM_IMPLS and policy == "f32"


def _traffic(policy: str, itemsize: int) -> tuple[int, int, int, int]:
    """(value, index, feature, output) bytes-per-element under a storage
    policy. f32 keeps the legacy accounting (4-byte indices, caller
    itemsize elsewhere) so full-precision estimates are unchanged."""
    if policy == "bf16":
        return 2, 2, 2, 2
    if policy == "i8":
        return 1, 2, itemsize, itemsize
    return itemsize, 4, itemsize, itemsize


# These imports sit BELOW the variant registry on purpose: repro.core's
# package __init__ pulls in kernels/ops.py, which imports PRECISION_IMPLS /
# precision_of from this module at import time. Keeping the registry above
# the repro.core import makes that re-entrant import find the names bound
# even while this module is still initializing.
from repro.analysis.roofline import HW  # noqa: E402
from repro.core.batching import (  # noqa: E402
    CHUNK,
    BatchPlan,
    plan_batched_gemm,
    plan_batched_spmm,
    plan_fused_graph_conv,
    plan_hybrid,
)

# Overhead constants (seconds). These are *relative* knobs, not measurements:
# the model only needs ordering, and the ordering is validated against the
# ref oracle in tests/test_autotune.py and refined on-device by
# repro.autotune.cache when a tuning cache is enabled.
OP_OVERHEAD = 2e-6       # one fused XLA op inside a jitted program
SCAN_STEP_OVERHEAD = 2e-6  # one sequential scan iteration (the 'loop' path)
GRID_STEP_OVERHEAD = 0.2e-6  # one Pallas grid step
SCATTER_PENALTY = 3.0    # read-modify-write amplification of scatter-adds
_COO_CHUNK = CHUNK       # the COO/fused kernels' non-zero chunk (batching.py)


def _mxu_eff(m: int, n: int) -> float:
    """Fraction of the 128x128 MXU tile a (m, n) product actually fills."""
    return max(min(1.0, m / 128.0) * min(1.0, n / 128.0), 1e-3)


@dataclasses.dataclass(frozen=True)
class Workload:
    """Static shape key for one batched SpMM call (hashable, trace-safe).

    ``nnz_pad`` is the COO slot count per matrix (the density proxy: the
    planner and the kernels both pay for padded slots), ``k_pad`` the ELL
    slots per row or None when no ELL conversion is available.

    A *graph-conv layer* workload additionally carries ``channels`` (edge
    channels summed by the layer) and ``n_in`` (the X feature width feeding
    the fused MatMul); both None means "plain SpMM call" and keeps the key
    format unchanged. ``nnz_avg`` is the skew knob: the mean real non-zeros
    per (sample × channel) when host metadata knows it — the fused kernel's
    per-sample chunk loop pays for the MEAN, every other impl pays for the
    padded max.

    A *g-SpMM* workload (DESIGN.md §11) additionally carries ``op``
    (``"mul"``/``"add"``/``"copy_lhs"``), ``reduce`` (``"sum"``/``"max"``/
    ``"mean"``) and ``d_e`` (the per-edge feature-vector width, None for
    scalar edges): the defaults mean "plain SpMM" and keep the key format
    unchanged; non-defaults restrict the candidate ladder to
    :data:`GSPMM_IMPLS` and charge the extra value traffic.
    """

    batch: int
    m_pad: int
    nnz_pad: int
    k_pad: int | None
    n_b: int
    itemsize: int = 4
    channels: int | None = None
    n_in: int | None = None
    nnz_avg: int | None = None
    dtype: str = "f32"      # precision policy: "f32" | "bf16" | "i8"
    d_e: int | None = None  # edge-feature width (g-SpMM vector edges)
    reduce: str = "sum"     # g-SpMM reduce kind: "sum" | "max" | "mean"
    op: str = "mul"         # g-SpMM combine op: "mul" | "add" | "copy_lhs"
    # the SKEW knob for the row-split classes: per-matrix (batch-max) max
    # row degree from host metadata. The CSR kernel's slot loop runs this
    # many trips — the serialization bound it actually pays — and the
    # hybrid split amortizes only when it exceeds the hub threshold. None
    # keeps every legacy estimate and cache key unchanged.
    max_deg: int | None = None
    # the sampled-BLOCK knob (DESIGN.md §14): padded dst-row count of a
    # bipartite block embedded in the (m_pad, m_pad) square — only the
    # first `block` rows are real outputs. Output traffic scales to it for
    # every impl, and the row-split (CSR/hybrid) classes additionally bound
    # their per-row work by it (rows past n_dst have rlen 0 — predicated
    # off), while dense still densifies the full square and ELL still runs
    # every padded row's k_pad slots. That asymmetry is exactly why
    # CSR-class kernels win sampled blocks. None (a non-block workload)
    # keeps every legacy estimate and cache key unchanged.
    block: int | None = None

    def key(self) -> str:
        """Stable string key for the persistent tuning cache (DESIGN.md §5).
        The dtype / g-SpMM (op, reduce, edge-feature) suffixes appear only
        for non-default values so every pre-existing cache entry keeps its
        key."""
        k = self.k_pad if self.k_pad is not None else 0
        base = (f"b{self.batch}_m{self.m_pad}_nnz{self.nnz_pad}"
                f"_k{k}_n{self.n_b}_i{self.itemsize}")
        if self.channels is not None:
            base += f"_c{self.channels}_nin{self.n_in or 0}"
        if self.dtype != "f32":
            base += f"_d{self.dtype}"
        if self.d_e is not None:
            base += f"_e{self.d_e}"
        if self.reduce != "sum":
            base += f"_r{self.reduce}"
        if self.op != "mul":
            base += f"_o{self.op}"
        if self.max_deg is not None:
            base += f"_md{self.max_deg}"
        if self.block is not None:
            base += f"_blk{self.block}"
        return base

    @property
    def is_gspmm(self) -> bool:
        """True when this workload needs a g-SpMM-capable impl."""
        return (self.op != "mul" or self.reduce != "sum"
                or self.d_e is not None)

    def shard(self, n_shards: int) -> "Workload":
        """The per-shard view of this workload on an ``n_shards``-way mesh:
        batch ``ceil(batch / n_shards)`` (the batch axis is padded to a
        multiple before sharding), every other dimension unchanged. This is
        the workload each device actually runs under
        ``repro.distributed.spmm.sharded_batched_spmm``, and therefore the
        one ``impl="auto"`` must be resolved against (DESIGN.md §6)."""
        return dataclasses.replace(self, batch=-(-self.batch // n_shards))


def spmm_plan(w: Workload, impl: str | None = None) -> BatchPlan:
    """The planner decision this workload falls under, with the SAME slot
    accounting as kernels/ops.py: ``k_pad`` slots for the ELL kernel,
    ``nnz_pad`` (COO) slots for everything else. ``impl=None`` means
    "best available" (ELL accounting when k_pad is known). The case-3
    boundary depends only on m_pad, so it is identical either way.
    Precision variants plan as their base impl; the bf16 policy blocks at
    2-byte elements (the features are cast too), the i8 policy keeps the
    caller itemsize (B and the output stay f32)."""
    base, policy = (None, "f32") if impl is None else precision_of(impl)
    if base in (None, "ell", "pallas_ell") and w.k_pad is not None:
        slots = w.k_pad
    else:
        slots = w.nnz_pad
    itemsize = 2 if policy == "bf16" else w.itemsize
    return plan_batched_spmm(batch=w.batch, m_pad=w.m_pad, n_b=w.n_b,
                             slots=slots, itemsize=itemsize)


def _roofline(flops: float, bytes_: float, unit_peak: float,
              hw: HW) -> float:
    return max(flops / unit_peak, bytes_ / hw.hbm_bw)


def estimate(w: Workload, impl: str, hw: HW = HW()) -> float:
    """Estimated seconds for one batched call of ``impl`` on workload ``w``.

    Precision variants reuse their base impl's roofline branch with the
    policy's bytes-per-element (``_traffic``): same execution structure,
    cheaper wire traffic. The pricing follows the IMPL's policy, not
    ``w.dtype`` — on a bf16-policy workload the full-precision candidates
    still pay full-precision bytes, which is exactly why a variant can
    out-rank its base."""
    base, policy = precision_of(impl)
    f32_path = policy == "f32"
    vb, ib, fb, ob = _traffic(policy, w.itemsize)
    vpu_peak = hw.peak_flops / 16.0           # vector (non-MXU) arithmetic
    # sampled blocks (DESIGN.md §14): only the first `block` rows are real
    # outputs; non-block workloads keep rows_out == m_pad (legacy estimates
    # bitwise unchanged)
    rows_out = w.block if w.block is not None else w.m_pad
    out_bytes = w.batch * rows_out * w.n_b * ob
    b_bytes = w.batch * w.m_pad * w.n_b * fb
    # g-SpMM extras (DESIGN.md §11), zero for plain SpMM so every legacy
    # estimate is unchanged: vector edges read (d_e - 1) extra value
    # elements per slot; a max/mean reduce pays one post-kernel fix-up pass
    # over the output (degree rewrite / scale).
    d_x = (w.d_e - 1) if w.d_e else 0
    gfix = out_bytes if w.reduce != "sum" else 0.0

    if base in ("ref", "loop"):
        gather = w.batch * w.nnz_pad * w.n_b * fb
        idx = w.batch * w.nnz_pad * (8 if f32_path else 2 * ib)
        flops = 2.0 * w.batch * w.nnz_pad * w.n_b
        bytes_ = (gather + idx + SCATTER_PENALTY * out_bytes
                  + w.batch * w.nnz_pad * d_x * vb + gfix)
        t = _roofline(flops, bytes_, vpu_peak, hw) + OP_OVERHEAD
        if base == "loop":
            # sequential per-sample execution: no cross-sample overlap, one
            # step latency per sample — the Fig. 2 structure.
            t = w.batch * (t / w.batch + SCAN_STEP_OVERHEAD)
        return t

    if base in ("ell", "pallas_ell"):
        if w.k_pad is None:
            return float("inf")
        slots = w.batch * w.m_pad * w.k_pad
        flops = 2.0 * slots * w.n_b
        if base == "ell":
            bytes_ = slots * (w.n_b * fb + (8 if f32_path else ib + vb)) \
                + out_bytes + slots * d_x * vb + gfix
            return _roofline(flops, bytes_, vpu_peak, hw) + OP_OVERHEAD
        plan = spmm_plan(w, impl)
        if plan.case == 3:
            return float("inf")   # kernels/ops.py falls back before Pallas
        # per (matrix × panel) grid step: B panel + ELL arrays read from HBM,
        # output panel written once; gathers happen VMEM-side.
        per_step = (w.m_pad * plan.n_block * fb
                    + w.m_pad * w.k_pad
                    * ((w.itemsize + 4) if f32_path else (vb + ib)))
        bytes_ = (w.batch * plan.p * per_step + out_bytes
                  + slots * d_x * vb + gfix)
        steps = w.batch * plan.p
        return (_roofline(flops, bytes_, vpu_peak, hw)
                + steps * GRID_STEP_OVERHEAD + OP_OVERHEAD)

    if base in ("csr", "pallas_csr"):
        # The kernel's dynamic per-matrix row bound IS the max row degree —
        # one hub row serializes the whole matrix's slot loop. Price the
        # host-measured ``max_deg`` when known (the serialization bound the
        # kernel actually pays on skewed batches); fall back to ``k_pad``
        # (the same quantity, when an ELL bound was sized) and only then to
        # the uniform-degree estimate.
        row_bound = w.max_deg if w.max_deg is not None else (
            w.k_pad if w.k_pad is not None else max(
                1, -(-w.nnz_pad // w.m_pad)))
        if base == "csr":
            # segment-sum reference: ref's gather/scatter traffic + rpt
            gather = w.batch * w.nnz_pad * w.n_b * fb
            idx = w.batch * (w.nnz_pad * (8 if f32_path else 2 * ib)
                             + w.m_pad * 4)
            flops = 2.0 * w.batch * w.nnz_pad * w.n_b
            bytes_ = (gather + idx + SCATTER_PENALTY * out_bytes
                      + w.batch * w.nnz_pad * d_x * vb + gfix)
            return _roofline(flops, bytes_, vpu_peak, hw) + OP_OVERHEAD
        plan = spmm_plan(w, impl)
        if plan.case == 3:
            return float("inf")   # kernels/ops.py falls back before Pallas
        # row-split work is per REAL output row: block rows past n_dst have
        # rlen 0 and are predicated off
        flops = 2.0 * w.batch * rows_out * row_bound * w.n_b
        # per (matrix × panel) grid step: B panel + FLAT cid/val arrays +
        # start/rlen row pointers (always int32); output panel written once.
        per_step = (w.m_pad * plan.n_block * fb
                    + w.nnz_pad * ((4 + w.itemsize) if f32_path else (ib + vb))
                    + 2 * w.m_pad * 4)
        bytes_ = (w.batch * plan.p * per_step + out_bytes
                  + w.batch * w.nnz_pad * d_x * vb + gfix)
        steps = w.batch * plan.p
        return (_roofline(flops, bytes_, vpu_peak, hw)
                + steps * GRID_STEP_OVERHEAD + OP_OVERHEAD)

    if base in ("hybrid", "pallas_hybrid"):
        # Degree-binned hybrid split (DESIGN.md §12): hub rows (deg >= dmin)
        # run as ONE MXU dense tile, the remainder runs the CSR slot loop
        # whose trip count is bounded by dmin - 1 BY CONSTRUCTION — skew
        # cannot serialize it. The price of that bound is the one-time
        # permutation (sort/rank/pointer gathers) and the slab densify,
        # charged below so ``auto`` picks hybrid only when binning amortizes
        # (i.e. when the measured ``max_deg`` actually exceeds dmin).
        plan = spmm_plan(w, impl)
        if base == "pallas_hybrid" and plan.case == 3:
            return float("inf")   # kernels/ops.py falls back before Pallas
        hp = plan_hybrid(batch=w.batch, m_pad=w.m_pad, n_b=w.n_b,
                         nnz_pad=w.nnz_pad,
                         itemsize=2 if policy == "bf16" else w.itemsize)
        # one-time costs both siblings pay: slab build+read, degree/argsort/
        # rank/pointer-permute passes
        slab_bytes = 2.0 * w.batch * hp.d_pad * w.m_pad * vb
        perm_bytes = 6.0 * w.batch * w.m_pad * 4
        n_prep = 6   # degrees, argsort, rank, pointer permutes, slab, bins
        if base == "hybrid":
            # pure-XLA sibling: the remainder is an ELL gather over a STATIC
            # k = dmin - 1 slot budget (sound because non-hub rows have
            # deg < dmin) — per-slot n_b-float gathers like the segment-sum
            # classes — plus the hub einsum on the MXU
            k_sp = min(w.m_pad, max(1, hp.dmin - 1))
            slots = w.batch * w.m_pad * k_sp
            flops_s = 2.0 * slots * w.n_b
            bytes_ = (slots * (w.n_b * fb + 8)
                      + SCATTER_PENALTY * out_bytes + slab_bytes + perm_bytes)
            t = _roofline(flops_s, bytes_, vpu_peak, hw)
            if hp.d_pad:
                flops_d = 2.0 * w.batch * hp.d_pad * w.m_pad * w.n_b
                t += flops_d / (hw.peak_flops * _mxu_eff(hp.d_pad, w.n_b))
            return t + (1 + n_prep) * OP_OVERHEAD
        if w.max_deg is not None:
            # measured skew: hubs above dmin leave the slot loop, so the
            # serialization bound drops to min(max_deg, dmin - 1)
            row_bound = min(w.max_deg, max(1, hp.dmin - 1))
        else:
            # no skew evidence — price the SAME bound as the CSR class, so
            # hybrid's strictly-positive extras (slab, permutation, MXU
            # tiles) keep it from winning on uniform-looking workloads
            row_bound = (w.k_pad if w.k_pad is not None
                         else max(1, -(-w.nnz_pad // w.m_pad)))
        flops_s = 2.0 * w.batch * rows_out * row_bound * w.n_b
        # CSR-remainder traffic + the permuted row pointers and rank vector
        per_step = (w.m_pad * plan.n_block * fb
                    + w.nnz_pad * ((4 + w.itemsize) if f32_path else (ib + vb))
                    + 4 * w.m_pad * 4)
        bytes_ = (w.batch * plan.p * per_step + out_bytes
                  + slab_bytes + perm_bytes)
        t = _roofline(flops_s, bytes_, vpu_peak, hw)
        if hp.d_pad:
            flops_d = 2.0 * w.batch * hp.d_pad * w.m_pad * w.n_b
            t += flops_d / (hw.peak_flops * _mxu_eff(hp.d_pad, plan.n_block))
        steps = w.batch * plan.p
        return (t + steps * GRID_STEP_OVERHEAD
                + (1 + n_prep) * OP_OVERHEAD)

    if base == "pallas_coo":
        plan = spmm_plan(w, impl)
        if plan.case == 3:
            return float("inf")
        chunks = -(-w.nnz_pad // _COO_CHUNK)
        # one-hot scatter: a CHUNK×m_pad ᵀ× CHUNK×n_block MXU contraction per
        # (chunk × matrix × panel)
        flops = (2.0 * w.batch * plan.p * chunks * _COO_CHUNK
                 * w.m_pad * plan.n_block)
        per_step = (w.m_pad * plan.n_block * fb
                    + chunks * _COO_CHUNK
                    * ((8 + w.itemsize) if f32_path else (2 * ib + vb)))
        bytes_ = (w.batch * plan.p * per_step + out_bytes
                  + w.batch * w.nnz_pad * d_x * vb + gfix)
        steps = w.batch * plan.p
        eff = _mxu_eff(w.m_pad, plan.n_block)
        return (_roofline(flops, bytes_, hw.peak_flops * eff, hw)
                + steps * GRID_STEP_OVERHEAD + OP_OVERHEAD)

    if base in ("fused", "fused_hybrid"):
        # Fused graph-conv megakernel (DESIGN.md §7): per (matrix × panel)
        # grid step, `channels` MXU feature transforms + one-hot-scatter
        # SpMMs accumulate into one VMEM panel; intermediates never touch
        # HBM and the nnz loop is skew-aware (mean chunks, not padded max).
        if w.channels is None or w.n_in is None:
            return float("inf")   # not a layer workload — fused can't run
        plan = plan_fused_graph_conv(
            batch=w.batch, m_pad=w.m_pad, n_in=w.n_in, n_out=w.n_b,
            channels=w.channels, nnz_pad=w.nnz_pad,
            itemsize=2 if policy == "bf16" else w.itemsize)
        if plan.case == 3:
            return float("inf")
        nnz_eff = w.nnz_avg if w.nnz_avg is not None else w.nnz_pad
        extra = 0.0
        if base == "fused_hybrid":
            # hybrid fold-in (DESIGN.md §12): hub rows leave the one-hot
            # chunk loop for a per-channel dense slab dot; the split pays
            # the one-time permutation + slab densify. Only a measured
            # ``max_deg`` past the hub threshold shrinks the chunk count,
            # so without skew metadata fused_hybrid prices >= fused and
            # ``auto`` keeps the plain megakernel.
            hp = plan_hybrid(batch=w.batch, m_pad=w.m_pad, n_b=w.n_b,
                             nnz_pad=w.channels * w.nnz_pad,
                             itemsize=2 if policy == "bf16" else w.itemsize)
            md = w.max_deg if w.max_deg is not None else 0
            if md >= hp.dmin:
                nnz_eff = max(0, nnz_eff - (-(-md // w.channels)))
            flops_d = (2.0 * w.batch * plan.p * w.channels * hp.d_pad
                       * w.m_pad * plan.n_block)
            slab_bytes = 2.0 * w.batch * w.channels * hp.d_pad * w.m_pad * vb
            perm_bytes = 6.0 * w.batch * w.m_pad * 4
            extra = (flops_d / (hw.peak_flops
                                * _mxu_eff(max(hp.d_pad, 1), plan.n_block))
                     + (slab_bytes + perm_bytes) / hw.hbm_bw
                     + 5 * OP_OVERHEAD)
        chunks = max(1, -(-nnz_eff // _COO_CHUNK))
        steps = w.batch * plan.p
        flops = (2.0 * steps * w.channels * w.m_pad * plan.n_block
                 * (w.n_in + chunks * _COO_CHUNK))
        per_step = (w.m_pad * w.n_in * fb                           # X panel
                    + w.channels * w.n_in * plan.n_block * fb       # W
                    + w.channels * chunks * _COO_CHUNK
                    * ((8 + w.itemsize) if f32_path else (2 * ib + vb)))
        bytes_ = steps * per_step + out_bytes       # output written ONCE
        eff = _mxu_eff(w.m_pad, plan.n_block)
        return (_roofline(flops, bytes_, hw.peak_flops * eff, hw)
                + steps * GRID_STEP_OVERHEAD + OP_OVERHEAD + extra)

    if impl in ("dense", "pallas_gemm"):
        densify = 2.0 * w.batch * w.m_pad * w.m_pad * w.itemsize  # write+read
        flops = 2.0 * w.batch * w.m_pad * w.m_pad * w.n_b
        bytes_ = densify + b_bytes + out_bytes
        eff = _mxu_eff(w.m_pad, w.n_b)
        t = _roofline(flops, bytes_, hw.peak_flops * eff, hw) + 2 * OP_OVERHEAD
        if impl == "pallas_gemm":
            plan = plan_batched_gemm(batch=w.batch, m=w.m_pad, n=w.n_b,
                                     k=w.m_pad, itemsize=w.itemsize)
            t += w.batch * plan.p * GRID_STEP_OVERHEAD
        return t

    raise ValueError(f"unknown impl {impl!r}")


def _candidates(dtype: str, allow_pallas: bool) -> list[str]:
    """The SpMM candidate ladder for a precision policy. ``dtype="f32"``
    reproduces the legacy candidate set exactly; reduced policies ADD their
    variants next to the full-precision impls (the model decides whether the
    byte savings beat f32, it is never forced)."""
    cands = ["ref", "ell", "csr", "hybrid", "dense", "loop"]
    if dtype in ("bf16", "i8"):
        cands += ["ell_bf16", "csr_bf16"]
    if allow_pallas:
        cands += ["pallas_ell", "pallas_csr", "pallas_coo", "pallas_hybrid",
                  "pallas_gemm"]
        if dtype in ("bf16", "i8"):
            cands += ["pallas_ell_bf16", "pallas_csr_bf16", "pallas_coo_bf16",
                      "pallas_hybrid_bf16"]
        if dtype == "i8":
            cands += ["pallas_ell_i8", "pallas_csr_i8"]
    return cands


@functools.lru_cache(maxsize=4096)
def rank(w: Workload, *, allow_pallas: bool = True,
         hw: HW = HW()) -> tuple[tuple[str, float], ...]:
    """All runnable impls for ``w``, cheapest-first, as (impl, est-seconds).

    ``allow_pallas=False`` (the CPU/interpret posture — Pallas interpret mode
    is a Python emulator, never a performance path) restricts candidates to
    the XLA-lowered impls. ``w.dtype`` widens the ladder with the matching
    reduced-precision variants (DESIGN.md §10).
    """
    cands = _candidates(w.dtype, allow_pallas)
    if w.is_gspmm:
        # op × reduce × edge-feature workloads only admit the g-SpMM-capable
        # impls (DESIGN.md §11): the GEMM class IS the (mul, sum) product,
        # and the precision variants are (mul, sum)-only.
        cands = [c for c in cands if supports_gspmm(c)]
    scored = [(i, estimate(w, i, hw)) for i in cands]
    scored = [(i, t) for i, t in scored if t != float("inf")]
    return tuple(sorted(scored, key=lambda it: it[1]))


def estimate_layer(w: Workload, impl: str, hw: HW = HW()) -> float:
    """Estimated seconds for one WHOLE graph-conv layer (Fig. 7) on a
    channels-aware workload: ``Y = Σ_ch A_ch·(X·W_ch + b_ch)``.

    - ``impl="fused"``: the megakernel — one device op, no HBM intermediates
      (priced by :func:`estimate`).
    - any SpMM impl: the stacked fallback path — ONE (channels·batch) batched
      SpMM call plus the dense feature-transform (MXU matmul, U written to
      and re-read from HBM) and the channel sum, as separate XLA ops.
    """
    if w.channels is None or w.n_in is None:
        raise ValueError(f"not a layer workload (channels/n_in unset): {w}")
    if precision_of(impl)[0].startswith("fused"):
        return estimate(w, impl, hw)
    stacked = dataclasses.replace(w, batch=w.batch * w.channels,
                                  channels=None, n_in=None, nnz_avg=None)
    t_spmm = estimate(stacked, impl, hw)
    if t_spmm == float("inf"):
        return t_spmm
    ch, b = w.channels, w.batch
    u_bytes = ch * b * w.m_pad * w.n_b * w.itemsize     # the HBM intermediate
    x_bytes = b * w.m_pad * (w.n_in or 0) * w.itemsize
    out_bytes = b * w.m_pad * w.n_b * w.itemsize
    # MatMul+Add: read X (once; XLA keeps it hot across channels is optimistic
    # — charge one read per layer), write U once per channel.
    mm_flops = 2.0 * ch * b * w.m_pad * (w.n_in or 0) * w.n_b
    t_mm = _roofline(mm_flops, x_bytes + u_bytes,
                     hw.peak_flops * _mxu_eff(w.m_pad, w.n_b), hw)
    # channel sum: read the `ch` SpMM outputs, write Y.
    t_sum = _roofline(ch * b * w.m_pad * w.n_b,
                      (ch + 1) * out_bytes, hw.peak_flops / 16.0, hw)
    # op count: ch fused MatMul+Add ops + 1 stacked SpMM (inside t_spmm) +
    # 1 channel-sum op.
    return t_spmm + t_mm + t_sum + (ch + 1) * OP_OVERHEAD


@functools.lru_cache(maxsize=4096)
def rank_layer(w: Workload, *, allow_pallas: bool = True,
               hw: HW = HW()) -> tuple[tuple[str, float], ...]:
    """All runnable impls for a graph-conv LAYER workload, cheapest-first.

    Candidates are the SpMM impls of :func:`rank` (each priced as the stacked
    fallback layer) plus ``"fused"`` when Pallas is allowed — the megakernel
    is Pallas-only, so the CPU/interpret posture never selects it. Reduced
    policies add ``fused_bf16`` alongside the SpMM variants.
    """
    candidates = _candidates(w.dtype, allow_pallas)
    if allow_pallas:
        candidates += ["fused", "fused_hybrid"]
        if w.dtype in ("bf16", "i8"):
            candidates += ["fused_bf16"]
    scored = [(i, estimate_layer(w, i, hw)) for i in candidates]
    scored = [(i, t) for i, t in scored if t != float("inf")]
    return tuple(sorted(scored, key=lambda it: it[1]))
