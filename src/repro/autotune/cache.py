"""Persistent on-device tuning cache for ``impl="auto"`` (DESIGN.md §5).

The analytic cost model ranks implementations from shapes alone; this module
*refines* that ranking by measurement, the way ``repro.tuning`` records every
dry-run flag set: each record is keyed by the workload's stable shape key and
stores the per-impl median seconds actually observed, so every §Perf data
point in EXPERIMENTS.md is reproducible from the cache file.

The cache is a flat JSON document::

    {"version": 1,
     "records": {"b100_m64_nnz256_k8_n128_i4": {
         "best": "ell",
         "times": {"ell": 1.1e-4, "ref": 2.0e-4, "dense": 3.2e-4},
         "interpret": true}}}

Writes are atomic (tmp + rename). The default location comes from the
``REPRO_TUNE_CACHE`` environment variable; unset means no persistent cache
(selection stays purely analytic).
"""
from __future__ import annotations

import functools
import json
import os
import tempfile

from repro.autotune.cost_model import Workload, precision_of, rank, rank_layer

ENV_VAR = "REPRO_TUNE_CACHE"
_VERSION = 1


def _merge_records(disk: dict, mine: dict) -> dict:
    """Union of two record maps (see :meth:`TuningCache.save`): disk-only
    keys survive, shared keys merge their ``times`` at per-impl min with
    ``best`` recomputed; ``interpret`` follows the merged best's side."""
    merged = dict(disk)
    for key, rec in mine.items():
        other = merged.get(key)
        if other is None:
            merged[key] = rec
            continue
        times = dict(other.get("times", {}))
        for impl, t in rec.get("times", {}).items():
            times[impl] = min(t, times[impl]) if impl in times else t
        best = min(times, key=times.get) if times else rec.get("best")
        interpret = (rec if best in rec.get("times", {})
                     and rec["times"].get(best) == times.get(best)
                     else other).get("interpret")
        merged[key] = {"best": best, "times": times, "interpret": interpret}
    return merged


class TuningCache:
    """Workload-key → measured per-impl seconds, persisted as JSON."""

    def __init__(self, path: str | None):
        self.path = path
        self.records: dict[str, dict] = {}
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    doc = json.load(f)
                if doc.get("version") == _VERSION:
                    self.records = doc.get("records", {})
            except (json.JSONDecodeError, OSError):
                self.records = {}

    def best(self, key: str) -> str | None:
        rec = self.records.get(key)
        return rec.get("best") if rec else None

    def times(self, key: str) -> dict[str, float]:
        rec = self.records.get(key)
        return dict(rec.get("times", {})) if rec else {}

    def put(self, key: str, times: dict[str, float], *,
            interpret: bool) -> str:
        best = min(times, key=times.get)
        self.records[key] = {"best": best, "times": times,
                             "interpret": interpret}
        self.save()
        return best

    def save(self) -> None:
        """Merge-on-save then atomic replace.

        Atomic-replace alone is last-write-wins: two processes sharing one
        cache path (CI dtype-matrix lanes, a sampler worker next to the
        trainer) would silently drop each other's measurements. Before
        writing we re-read the file and union its records into ours —
        disk-only keys are adopted; for keys both sides measured, the
        per-impl ``times`` merge at min (each measurement is a median of a
        noisy timer, the lower one is the better estimate of the same
        quantity) and ``best`` is recomputed from the merged map. The merged
        view also updates ``self.records`` so a subsequent ``best()`` in
        this process sees what it just persisted."""
        if not self.path:
            return
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    doc = json.load(f)
                if doc.get("version") == _VERSION:
                    self.records = _merge_records(doc.get("records", {}),
                                                  self.records)
            except (json.JSONDecodeError, OSError):
                pass    # a torn/corrupt file loses the merge, not the save
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"version": _VERSION, "records": self.records},
                          f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)


@functools.lru_cache(maxsize=8)
def _cache_for(path: str) -> TuningCache:
    return TuningCache(path)


def default_cache() -> TuningCache | None:
    """Process-default cache, from $REPRO_TUNE_CACHE (None when unset).

    Memoized per path: every ``impl="auto"`` resolution consults this, so
    the JSON file is parsed once per process, not once per call. External
    edits to the file during the process's lifetime are not re-read;
    ``autotune``'s own puts update the memoized instance AND the file.
    """
    path = os.environ.get(ENV_VAR)
    return _cache_for(path) if path else None


def measure_workload(
    w: Workload,
    impls: tuple[str, ...] | None = None,
    *,
    interpret: bool = True,
    warmup: int = 1,
    iters: int = 5,
    seed: int = 0,
) -> dict[str, float]:
    """Time each candidate impl on synthetic inputs matching ``w`` EXACTLY.

    The inputs are constructed directly at the workload's static shapes —
    (batch, nnz_pad) COO arrays, (batch, m_pad, n_b) dense operand, dtype
    from ``itemsize`` (2 → bfloat16, else float32) — so the measured record
    is keyed by precisely the shapes it ran, never an approximation.

    A LAYER workload (``w.channels``/``n_in`` set — the graph-conv keys of
    ``select_graph_conv_impl``) is measured as the layer it keys: one whole
    ``graph_conv_batched`` call per candidate (fused megakernel or stacked
    fallback, matmul + SpMM + channel sum included), never a bare SpMM —
    otherwise the record would override the layer model with a timing of a
    different computation. Imports are local to avoid a cycle with
    ``kernels/ops.py`` (which imports this package for ``impl="auto"``).
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.formats import BatchedCOO
    from repro.kernels.ops import batched_spmm

    layer = w.channels is not None and w.n_in is not None
    ell_lossy = (w.k_pad is not None and w.nnz_pad > w.m_pad * w.k_pad)
    if impls is None:
        ranked = (rank_layer if layer else rank)(
            w, allow_pallas=not interpret)
        impls = tuple(i for i, _ in ranked)
        if ell_lossy:
            # ELL cannot represent this workload losslessly (more slots
            # than m_pad·k_pad cells) — timing its candidates would measure
            # a silently truncated product and poison the cache record.
            # Class membership via precision_of so bf16/i8 ELL variants are
            # filtered too.
            impls = tuple(i for i in impls
                          if precision_of(i)[0] not in ("ell", "pallas_ell"))
    elif ell_lossy and any(precision_of(i)[0] in ("ell", "pallas_ell")
                           for i in impls):
        # an EXPLICITLY requested unmeasurable impl must fail loudly, not
        # silently vanish from the record
        raise ValueError(
            f"workload {w.key()}: nnz_pad={w.nnz_pad} > m_pad*k_pad="
            f"{w.m_pad * w.k_pad} — the requested ELL impl(s) cannot "
            "represent it losslessly, so their timings would be bogus")

    rng = np.random.default_rng(seed)
    dtype = jnp.bfloat16 if w.itemsize == 2 else jnp.float32

    def make_coo():
        if w.k_pad is not None and w.nnz_pad <= w.m_pad * w.k_pad:
            # Bound every row to ≤ k_pad non-zeros so the ELL candidates
            # measure the SAME computation as the rest — fully random row
            # ids can exceed k_pad and coo_to_ell would silently drop the
            # overflow, timing a smaller product under this workload's key.
            base = (np.arange(w.nnz_pad, dtype=np.int64) // w.k_pad) % w.m_pad
            rid = np.stack([
                rng.permutation(w.m_pad).astype(np.int32)[base]
                for _ in range(w.batch)])
        else:
            rid = rng.integers(0, w.m_pad,
                               (w.batch, w.nnz_pad)).astype(np.int32)
        cid = rng.integers(0, w.m_pad, (w.batch, w.nnz_pad)).astype(np.int32)
        return BatchedCOO(
            row_ids=jnp.asarray(rid), col_ids=jnp.asarray(cid),
            values=jnp.asarray(rng.normal(size=(w.batch, w.nnz_pad)), dtype),
            nnz=jnp.full((w.batch,), w.nnz_pad, jnp.int32),
            n_rows=jnp.full((w.batch,), w.m_pad, jnp.int32))

    if layer:
        from repro.core.graph_conv import graph_conv_batched

        adj = [make_coo() for _ in range(w.channels)]
        x = jnp.asarray(rng.normal(size=(w.batch, w.m_pad, w.n_in)), dtype)
        params = {
            "w": jnp.asarray(
                rng.normal(size=(w.channels, w.n_in, w.n_b)), dtype),
            "b": jnp.zeros((w.channels, w.n_b), dtype),
        }

        def make_fn(impl):
            return jax.jit(functools.partial(
                graph_conv_batched, impl=impl, k_pad=w.k_pad,
                interpret=interpret)), (params, adj, x)
    else:
        coo, b = make_coo(), jnp.asarray(
            rng.normal(size=(w.batch, w.m_pad, w.n_b)), dtype)

        def make_fn(impl):
            return jax.jit(functools.partial(
                batched_spmm, impl=impl, k_pad=w.k_pad,
                interpret=interpret)), (coo, b)

    times: dict[str, float] = {}
    for impl in impls:
        fn, args = make_fn(impl)
        try:
            for _ in range(warmup):
                jax.block_until_ready(fn(*args))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                ts.append(time.perf_counter() - t0)
            times[impl] = float(np.median(ts))
        except Exception:  # noqa: BLE001 — an impl a backend can't run is
            continue       # simply absent from the record
    return times


def autotune(
    w: Workload,
    *,
    cache: TuningCache,
    impls: tuple[str, ...] | None = None,
    interpret: bool = True,
    refresh: bool = False,
) -> str:
    """Measured-best impl for ``w``, memoized in ``cache``."""
    key = w.key()
    if not refresh:
        best = cache.best(key)
        if best is not None:
            return best
    times = measure_workload(w, impls, interpret=interpret)
    if not times:
        raise RuntimeError(f"no candidate impl ran for workload {key}")
    return cache.put(key, times, interpret=interpret)
