"""Shape-keyed implementation selection for ``impl="auto"`` (DESIGN.md §5).

``select_impl`` turns a :class:`~repro.autotune.cost_model.Workload` into a
:class:`Decision`: the concrete impl string ``kernels/ops.py`` should run,
plus everything needed to audit the choice (the planner case, the model's
full ranking, and whether a measured tuning-cache entry overrode the model).

Decision precedence:

1. planner case 3 (``m_pad > LARGE_M``) — forced to the per-sample ``ref``
   fallback, mirroring the guard inside ``kernels/ops.py``;
2. a measured winner from the persistent tuning cache, when one exists for
   this workload key and names a runnable candidate;
3. the analytic cost model's cheapest candidate.

The regimes the model separates (asserted by tests/test_autotune.py):

- *small-dense* (small m_pad, high nnz density) → the GEMM class: densify is
  cheap at m_pad², the MXU does the rest — the paper's §V-A observation that
  gemmBatched wins on small dense matrices;
- *large-m fallback* (m_pad > 8192, planner case 3) → ``ref``;
- *column-paneled sparse* (case 2: wide n_b split into panels, low density)
  → the ELL row-split class, the paper's headline batched kernel.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.roofline import HW
from repro.autotune.cost_model import (
    PRECISION_IMPLS,
    Workload,
    precision_of,
    rank,
    rank_layer,
    spmm_plan,
)
from repro.core.batching import BatchPlan, plan_fused_graph_conv


def _layer_plan(w: Workload, impl: str) -> BatchPlan:
    """The blocking plan a layer impl runs: the fused megakernel's own plan
    for the fused class (variants block at their policy's element size), the
    stacked (channels·batch) SpMM plan otherwise."""
    base, policy = precision_of(impl)
    if base.startswith("fused"):
        return plan_fused_graph_conv(
            batch=w.batch, m_pad=w.m_pad, n_in=w.n_in or 0, n_out=w.n_b,
            channels=w.channels or 1, nnz_pad=w.nnz_pad,
            itemsize=2 if policy == "bf16" else w.itemsize)
    return spmm_plan(dataclasses.replace(
        w, batch=w.batch * (w.channels or 1), channels=None, n_in=None,
        nnz_avg=None), impl)

# impl string → kernel class, for tests and reporting: the class is the
# decision the paper's policy makes; pallas-vs-XLA within a class is a
# backend posture (allow_pallas), not a policy change, and a precision
# variant keeps its base impl's class (DESIGN.md §10 — precision is a
# storage policy, not an execution structure). "fused" is its own class:
# the graph-conv layer megakernel (DESIGN.md §7).
KINDS = {
    "ref": "scatter", "loop": "scatter",
    "ell": "ell", "pallas_ell": "ell",
    "csr": "csr", "pallas_csr": "csr",
    "pallas_coo": "coo",
    "hybrid": "hybrid", "pallas_hybrid": "hybrid",
    "dense": "gemm", "pallas_gemm": "gemm",
    "fused": "fused", "fused_hybrid": "fused",
}
KINDS.update({v: KINDS[base] for v, (base, _) in PRECISION_IMPLS.items()})


@dataclasses.dataclass(frozen=True)
class Decision:
    """An auditable ``impl="auto"`` resolution."""

    impl: str                       # concrete impl for kernels/ops.py
    kind: str                       # kernel class (KINDS[impl])
    case: int                       # planner case 1/2/3 for this workload
    plan: BatchPlan                 # the blocking decision behind `case`
    scores: tuple[tuple[str, float], ...]  # model ranking, cheapest first
    source: str                     # "model" | "cache" | "forced"
    reason: str                     # one-line human-readable justification
    workload: Workload | None = None  # the shape key this decision resolved
                                      # (telemetry/regret audit provenance)


def forced_decision(w: Workload, impl: str, *, note: str = "") -> Decision:
    """The Decision for a caller-pinned concrete ``impl``: no ranking, but
    the same auditable plan/case fields as a model decision. Shared by the
    local (``kernels/ops.py``) and mesh-sharded (``distributed/spmm.py``)
    resolution paths so the forced-path semantics cannot diverge. A LAYER
    workload (``channels``/``n_in`` set) reports the plan the layer impl
    actually runs — the fused megakernel's own plan, or the stacked
    (channels·batch) SpMM plan — not a bare per-channel SpMM plan."""
    if w.channels is not None and w.n_in is not None:
        plan = _layer_plan(w, impl)
    else:
        plan = spmm_plan(w, impl)
    return Decision(
        impl=impl, kind=KINDS.get(impl, impl), case=plan.case, plan=plan,
        scores=(), source="forced", workload=w,
        reason=f"caller pinned impl={impl!r}{note}")


def select_impl(
    w: Workload,
    *,
    allow_pallas: bool = True,
    cache=None,
    hw: HW = HW(),
) -> Decision:
    """Resolve ``impl="auto"`` for one workload. Pure in shapes: safe to call
    at trace time (and cached upstream via ``cost_model.rank``)."""
    scores = rank(w, allow_pallas=allow_pallas, hw=hw)
    if spmm_plan(w).case == 3:          # case 3 depends only on m_pad
        plan = spmm_plan(w, "ref")
        return Decision(
            impl="ref", kind="scatter", case=3, plan=plan, scores=scores,
            source="forced", workload=w,
            reason=(f"m_pad={w.m_pad} > LARGE_M: paper case 3 — batching "
                    "does not pay, per-sample scatter-add fallback"),
        )
    allowed = {i for i, _ in scores}
    if cache is not None:
        measured = cache.best(w.key())
        if measured in allowed:
            plan = spmm_plan(w, measured)   # the plan this impl will run
            return Decision(
                impl=measured, kind=KINDS[measured], case=plan.case,
                plan=plan, scores=scores, source="cache", workload=w,
                reason=f"measured winner for key {w.key()} (tuning cache)",
            )
    impl, est = scores[0]
    plan = spmm_plan(w, impl)
    runner_up = f"; runner-up {scores[1][0]} @ {scores[1][1]:.2e}s" \
        if len(scores) > 1 else ""
    return Decision(
        impl=impl, kind=KINDS[impl], case=plan.case, plan=plan,
        scores=scores, source="model", workload=w,
        reason=f"cost model: {impl} @ {est:.2e}s (case {plan.case}, "
               f"p={plan.p}){runner_up}",
    )


def select_graph_conv_impl(
    w: Workload,
    *,
    allow_pallas: bool = True,
    cache=None,
    hw: HW = HW(),
) -> Decision:
    """Resolve ``impl="auto"`` for one graph-conv LAYER workload
    (``w.channels``/``w.n_in`` set): the candidates are every SpMM impl
    priced as the stacked fallback layer plus the fused megakernel
    (``cost_model.rank_layer``). Same precedence as :func:`select_impl`:
    case-3 force → measured tuning-cache winner → model winner."""
    if w.channels is None or w.n_in is None:
        raise ValueError(f"not a layer workload (channels/n_in unset): {w}")
    scores = rank_layer(w, allow_pallas=allow_pallas, hw=hw)
    if spmm_plan(w).case == 3:          # case 3 depends only on m_pad
        plan = spmm_plan(w, "ref")
        return Decision(
            impl="ref", kind="scatter", case=3, plan=plan, scores=scores,
            source="forced", workload=w,
            reason=(f"m_pad={w.m_pad} > LARGE_M: paper case 3 — neither "
                    "batching nor fusion pays, per-sample scatter-add "
                    "fallback"),
        )
    allowed = {i for i, _ in scores}
    if cache is not None:
        measured = cache.best(w.key())
        if measured in allowed:
            plan = _layer_plan(w, measured)
            return Decision(
                impl=measured, kind=KINDS[measured], case=plan.case,
                plan=plan, scores=scores, source="cache", workload=w,
                reason=f"measured winner for key {w.key()} (tuning cache)",
            )
    impl, est = scores[0]
    plan = _layer_plan(w, impl)
    runner_up = f"; runner-up {scores[1][0]} @ {scores[1][1]:.2e}s" \
        if len(scores) > 1 else ""
    return Decision(
        impl=impl, kind=KINDS[impl], case=plan.case, plan=plan,
        scores=scores, source="model", workload=w,
        reason=f"layer cost model: {impl} @ {est:.2e}s "
               f"(channels={w.channels}, case {plan.case}){runner_up}",
    )


def resolve_auto(
    *,
    batch: int,
    m_pad: int,
    nnz_pad: int,
    k_pad: int | None,
    n_b: int,
    itemsize: int,
    interpret: bool = True,
    cache=None,
    dtype: str = "f32",
) -> Decision:
    """Entry point used by ``kernels/ops.py``: build the Workload from the
    static shapes of one ``batched_spmm`` call and select.

    ``interpret=True`` (the CPU posture) disables Pallas candidates — in
    interpret mode they are Python emulation, correct but never fastest.
    ``dtype`` is the caller's precision policy: ``"bf16"``/``"i8"`` admit
    the matching reduced-precision variants to the ranking.
    """
    if cache is None:
        from repro.autotune.cache import default_cache
        cache = default_cache()
    w = Workload(batch=batch, m_pad=m_pad, nnz_pad=nnz_pad, k_pad=k_pad,
                 n_b=n_b, itemsize=itemsize, dtype=dtype)
    return select_impl(w, allow_pallas=not interpret, cache=cache)
