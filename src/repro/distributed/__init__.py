"""Distribution: sharding rules, pjit step builders, compression, collectives,
and the mesh-sharded Batched SpMM (``repro.distributed.spmm``, DESIGN.md §6)."""
