"""Distribution: sharding rules, pjit step builders, compression, collectives."""
