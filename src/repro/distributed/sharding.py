"""Mesh-parametric sharding rules (DP / TP / EP / ZeRO-1).

Rules are *name- and shape-based* over the param pytree, aligned to the LAST
dimensions of each leaf so stacked-scan leading axes (n_blocks, groups, …)
are transparently replicated. Divisibility against the actual mesh axis size
is always checked, with graceful fallback (e.g. whisper's 51,865 vocab is not
16-divisible → its embedding shards on d_model instead). This is what makes
elastic restart work: the same rules re-evaluate against any mesh shape.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, trailing-dims rule) — rule entries are per trailing dim, each
# a tuple of candidate axis names tried in order (first divisible wins), or
# None for replicated. Earlier rules win.
_PARAM_RULES: list[tuple[str, tuple]] = [
    # MoE expert banks: expert-parallel over "model" on the expert axis when
    # E divides the axis; otherwise tensor-parallel on the d_ff axis
    # (mixtral's E=8 < 16 ⇒ TP-in-expert; llama4's E=128 ⇒ EP).
    (r"moe/(w_gate|w_up)$", "MOE_IN"),
    (r"moe/w_down$", "MOE_OUT"),
    # embeddings / output head: shard the vocab-ish big axis.
    (r"embed$", (("model",), ("model",))),         # try vocab, else d_model
    (r"lm_head$", (None, ("model",))),
    (r"router$", (None, ("model",))),
    # column-parallel (output-dim) projections.
    (r"(wq|wk|wv|wr|wg|w_gate|w_up|cm_k|cm_r|in_proj_zx|in_proj_bc|frame_proj|patch_proj|"
     r"wA)$", (None, ("model",))),
    # row-parallel (input-dim) projections.
    (r"(wo|w_down|cm_v|out_proj|wB)$", (("model",), None)),
    # depthwise conv, norms, biases, scalars: replicated.
]


def _spec_for(path: str, shape: tuple[int, ...], axis_sizes: dict) -> P:
    for pattern, rule in _PARAM_RULES:
        if re.search(pattern, path):
            if rule in ("MOE_IN", "MOE_OUT"):
                if len(shape) < 3:
                    continue
                e, lead = shape[-3], [None] * (len(shape) - 3)
                ms = axis_sizes["model"]
                if e % ms == 0:
                    return P(*lead, "model", None, None)
                ff_dim = -1 if rule == "MOE_IN" else -2
                if shape[ff_dim] % ms == 0:
                    tail = [None, None, None]
                    tail[3 + ff_dim] = "model"
                    return P(*lead, *tail)
                return P(*lead, None, None, None)
            k = len(rule)
            if len(shape) < k:
                continue
            tail = []
            for dim_size, cand in zip(shape[-k:], rule):
                picked = None
                if cand:
                    for ax in cand if isinstance(cand, tuple) else (cand,):
                        if dim_size % axis_sizes[ax] == 0:
                            picked = ax
                            break
                tail.append(picked)
            # "embed" special case: vocab OR d_model over model, never both
            if path.endswith("embed") and tail[0] == "model":
                tail[1] = None
            lead = [None] * (len(shape) - k)
            return P(*lead, *tail)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_specs(params_shape: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree for a param pytree (shapes or arrays)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        return _spec_for(_path_str(path), leaf.shape, axis_sizes)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def zero1_specs(p_specs: Any, params_shape: Any, mesh: Mesh,
                axis: str = "data") -> Any:
    """ZeRO-1: additionally shard optimizer moments over the data axis on the
    first dimension that is still replicated and divisible."""
    size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def one(spec: P, leaf) -> P:
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for p_ in parts:
            for a in (p_ if isinstance(p_, tuple) else (p_,)):
                used.add(a)
        if axis in used:        # already data-sharded (e.g. FSDP params)
            return P(*parts)
        for i, (dim, cur) in enumerate(zip(leaf.shape, parts)):
            if cur is None and dim % size == 0 and dim >= size:
                parts[i] = axis
                return P(*parts)
        return spec

    return jax.tree.map(one, p_specs, params_shape)


def batch_specs(batch_shape: Any, mesh: Mesh) -> Any:
    """Data inputs: batch axis over ("pod","data") where divisible."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([dict(zip(mesh.axis_names,
                                    mesh.devices.shape))[a] for a in dp]))

    def one(leaf):
        if not leaf.shape:
            return P()
        if leaf.shape[0] % dp_size == 0 and leaf.shape[0] >= dp_size:
            return P(dp, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(one, batch_shape)


_CACHE_RULES: list[tuple[str, tuple]] = [
    # attention KV cache (B, S, KV, hd): batch→data, SEQUENCE→model
    # (sequence-parallel decode: scores shard-local, only softmax stats and
    # the (B,H,1,hd) output cross shards — see EXPERIMENTS.md §Perf)
    (r"(k|v)$", (("pod", "data"), ("model",), None, None)),
    # mamba ssm state (B, H, hd, state): hd→model (heads often not divisible)
    (r"ssm$", (("pod", "data"), ("model",), ("model",), None)),
    # mamba conv state (B, 3, d_conv): channels→model
    (r"conv$", (("pod", "data"), None, ("model",))),
    # rwkv wkv state (B, H, hd, hd)
    (r"wkv$", (("pod", "data"), ("model",), None, None)),
    (r"prev_x_(tm|cm)$", (("pod", "data"), None)),
]


def cache_specs(cache_shape: Any, mesh: Mesh) -> Any:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def pick(dim_size, cand):
        if cand is None:
            return None
        if isinstance(cand, tuple) and all(a in ("pod", "data") for a in cand):
            dp = tuple(a for a in cand if a in mesh.axis_names)
            if dp and dim_size % int(np.prod([axis_sizes[a] for a in dp])) == 0:
                return dp
            return None
        for ax in cand:
            if ax in axis_sizes and dim_size % axis_sizes[ax] == 0:
                return ax
        return None

    def one(path, leaf):
        ps = _path_str(path)
        for pattern, rule in _CACHE_RULES:
            if re.search(pattern, ps) and len(leaf.shape) >= len(rule):
                k = len(rule)
                tail = [pick(d, c) for d, c in zip(leaf.shape[-k:], rule)]
                # at most ONE "model" placement per leaf
                seen_model = False
                for i, t in enumerate(tail):
                    if t == "model":
                        if seen_model:
                            tail[i] = None
                        seen_model = True
                lead = [None] * (len(leaf.shape) - k)
                return P(*lead, *tail)
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
