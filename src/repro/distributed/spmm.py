"""Mesh-sharded Batched SpMM — the batch axis across a device mesh.

The paper's core claim is that batching many small SpMMs into ONE kernel
launch is what saturates one device (§IV); this module is the next rung:
split the *batch* axis of a :class:`~repro.core.formats.BatchedCOO` (and its
dense operand) over a ``("data",)`` mesh axis with ``shard_map`` and run the
existing single-device batched kernels on each shard (DESIGN.md §6).

Design points:

- **Per-shard autotuning.** ``impl="auto"`` is resolved against the
  *per-shard* workload (``batch_padded // n_shards`` samples), not the global
  one — the adaptive dispatcher's cost model (DESIGN.md §5) sees the shapes
  the kernel will actually run at, so a global batch that would pick the GEMM
  class may correctly pick the scatter class once split 8 ways.
  :func:`resolve_sharded_impl` exposes that decision for audit.
- **Padding invariant (§IV-C).** A batch not divisible by the shard count is
  padded with zero-nnz samples (value 0.0, indices 0) — exactly the padded
  slots the kernels already tolerate — and the output is sliced back.
- **No forward all-gather.** ``out_specs=P(axis)`` keeps the output
  batch-sharded; consumers that keep reducing along non-batch axes never pay
  a gather. The custom-VJP backward runs inside the same ``shard_map``, so
  dValues and dB come out batch-sharded too.

``shard_map`` requires every float leaf to be rank ≥ 1 per shard — all
BatchedCOO leaves are batch-leading arrays, so the specs are uniform
``P(axis)`` on dim 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.formats import BatchedCOO

__all__ = [
    "pad_batch",
    "resolve_sharded_impl",
    "shard_count",
    "sharded_batched_spmm",
]


def shard_count(mesh: Mesh, axis: str = "data") -> int:
    """Number of shards the batch axis is split into on ``mesh``."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis not in sizes:
        raise ValueError(
            f"mesh has axes {mesh.axis_names}, no {axis!r} axis to shard the "
            "batch over")
    return sizes[axis]


def pad_batch(a: BatchedCOO, b: jax.Array, n_shards: int
              ) -> tuple[BatchedCOO, jax.Array, int]:
    """Pad the batch axis to a multiple of ``n_shards`` with zero-nnz samples
    (the §IV-C padding invariant: indices 0, values 0.0, nnz 0 contribute
    nothing). Returns (a, b, pad) with ``pad`` rows to slice off outputs."""
    batch = b.shape[0]
    pad = (-batch) % n_shards
    if pad == 0:
        return a, b, 0

    def padb(x):
        return jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)

    a = BatchedCOO(
        row_ids=padb(a.row_ids), col_ids=padb(a.col_ids),
        values=padb(a.values), nnz=padb(a.nnz),
        # padded samples keep the real m_pad so per-shard geometry is uniform
        n_rows=jnp.concatenate(
            [a.n_rows, jnp.full((pad,), b.shape[1], a.n_rows.dtype)]),
    )
    return a, padb(b), pad


def resolve_sharded_impl(
    a: BatchedCOO,
    b: jax.Array,
    mesh: Mesh,
    *,
    axis: str = "data",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool = True,
):
    """Resolve ``impl`` against the PER-SHARD workload shapes.

    Returns an :class:`repro.autotune.Decision` whose ``plan``/``scores``
    describe one shard's call — batch ``ceil(batch / n_shards)``, everything
    else unchanged — which is the workload each device actually runs.
    """
    from repro import autotune

    n = shard_count(mesh, axis)
    batch, m_pad, n_b = b.shape
    w = autotune.Workload(batch=batch, m_pad=m_pad,
                          nnz_pad=a.row_ids.shape[1], k_pad=k_pad,
                          n_b=n_b, itemsize=b.dtype.itemsize).shard(n)
    if impl != "auto":
        return autotune.forced_decision(w, impl, note=f" ({n}-way sharded)")
    return autotune.select_impl(w, allow_pallas=not interpret,
                                cache=autotune.default_cache())


def sharded_batched_spmm(
    a: BatchedCOO,
    b: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "data",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """C[s] = A[s] @ B[s] with the batch axis sharded over ``mesh[axis]``.

    Semantically identical to :func:`repro.kernels.ops.batched_spmm` (the
    per-shard kernels are the same code); differentiable in ``a.values`` and
    ``b`` with batch-sharded cotangents. ``impl="auto"`` resolves against the
    per-shard workload. Output stays batch-sharded (no forward all-gather).
    """
    from repro.kernels.ops import _forward, batched_spmm, bwd_impl_for, dvalues

    n = shard_count(mesh, axis)
    if n == 1:
        return batched_spmm(a, b, impl=impl, k_pad=k_pad, interpret=interpret)

    batch = b.shape[0]
    a, b, pad = pad_batch(a, b, n)
    concrete = resolve_sharded_impl(
        a, b, mesh, axis=axis, impl=impl, k_pad=k_pad,
        interpret=interpret).impl

    spec = P(axis)      # dim-0 (batch) sharding for every operand
    row_ids, col_ids, nnz = a.row_ids, a.col_ids, a.nnz

    # The custom VJP lives OUTSIDE the shard_map and each side runs its own
    # shard_map over explicit operands: AD never differentiates *through* a
    # shard_map (no transpose, no scalar-residual issues), and the backward
    # is itself a batch-sharded batched SpMM + gather-dot, so dValues/dB come
    # out batch-sharded exactly like the forward output.
    def _fwd_local(rids, cids, nz, values, b_local):
        return _forward(rids, cids, nz, values, b_local,
                        impl=concrete, k_pad=k_pad, interpret=interpret)

    fwd_sharded = shard_map(
        _fwd_local, mesh=mesh, in_specs=(spec,) * 5, out_specs=spec,
        check_rep=False)

    def _bwd_local(rids, cids, nz, values, b_local, dc):
        db = _forward(cids, rids, nz, values, dc,
                      impl=bwd_impl_for(concrete), k_pad=None,
                      interpret=interpret)
        dval = dvalues(rids, cids, dc, b_local)
        return dval.astype(values.dtype), db.astype(b_local.dtype)

    bwd_sharded = shard_map(
        _bwd_local, mesh=mesh, in_specs=(spec,) * 6, out_specs=(spec, spec),
        check_rep=False)

    @jax.custom_vjp
    def f(values, bb):
        return fwd_sharded(row_ids, col_ids, nnz, values, bb)

    def fwd(values, bb):
        return f(values, bb), (values, bb)

    def bwd(res, dc):
        values, bb = res
        return bwd_sharded(row_ids, col_ids, nnz, values, bb, dc)

    f.defvjp(fwd, bwd)
    out = f(a.values, b)
    return out[:batch] if pad else out
