"""Mesh-sharded Batched SpMM — the batch axis across a device mesh.

The paper's core claim is that batching many small SpMMs into ONE kernel
launch is what saturates one device (§IV); this module is the next rung:
split the *batch* axis of a :class:`~repro.core.formats.BatchedCOO` (and its
dense operand) over a ``("data",)`` mesh axis with ``shard_map`` and run the
existing single-device batched kernels on each shard (DESIGN.md §6).

Design points:

- **Per-shard autotuning.** ``impl="auto"`` is resolved against the
  *per-shard* workload (``batch_padded // n_shards`` samples), not the global
  one — the adaptive dispatcher's cost model (DESIGN.md §5) sees the shapes
  the kernel will actually run at, so a global batch that would pick the GEMM
  class may correctly pick the scatter class once split 8 ways.
  :func:`resolve_sharded_impl` exposes that decision for audit.
- **Padding invariant (§IV-C).** A batch not divisible by the shard count is
  padded with zero-nnz samples (value 0.0, indices 0) — exactly the padded
  slots the kernels already tolerate — and the output is sliced back.
- **No forward all-gather.** ``out_specs=P(axis)`` keeps the output
  batch-sharded; consumers that keep reducing along non-batch axes never pay
  a gather. The custom-VJP backward runs inside the same ``shard_map``, so
  dValues and dB come out batch-sharded too.

``shard_map`` requires every float leaf to be rank ≥ 1 per shard — all
BatchedCOO leaves are batch-leading arrays, so the specs are uniform
``P(axis)`` on dim 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.formats import BatchedCOO
from repro.kernels import resolve_interpret
from repro.observability import trace as obs_trace

__all__ = [
    "pad_batch",
    "resolve_sharded_gspmm_impl",
    "resolve_sharded_impl",
    "shard_count",
    "sharded_batched_gspmm",
    "sharded_batched_spmm",
    "sharded_fused_graph_conv",
]


def shard_count(mesh: Mesh, axis: str = "data") -> int:
    """Number of shards the batch axis is split into on ``mesh``."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis not in sizes:
        raise ValueError(
            f"mesh has axes {mesh.axis_names}, no {axis!r} axis to shard the "
            "batch over")
    return sizes[axis]


def pad_batch(a: BatchedCOO, b: jax.Array, n_shards: int
              ) -> tuple[BatchedCOO, jax.Array, int]:
    """Pad the batch axis to a multiple of ``n_shards`` with zero-nnz samples
    (the §IV-C padding invariant: indices 0, values 0.0, nnz 0 contribute
    nothing). Returns (a, b, pad) with ``pad`` rows to slice off outputs."""
    batch = b.shape[0]
    pad = (-batch) % n_shards
    if pad == 0:
        return a, b, 0

    def padb(x):
        return jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)

    a = BatchedCOO(
        row_ids=padb(a.row_ids), col_ids=padb(a.col_ids),
        values=padb(a.values), nnz=padb(a.nnz),
        # padded samples keep the real m_pad so per-shard geometry is uniform
        n_rows=jnp.concatenate(
            [a.n_rows, jnp.full((pad,), b.shape[1], a.n_rows.dtype)]),
    )
    return a, padb(b), pad


def resolve_sharded_impl(
    a: BatchedCOO,
    b: jax.Array,
    mesh: Mesh,
    *,
    axis: str = "data",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    precision: str = "f32",
):
    """Resolve ``impl`` against the PER-SHARD workload shapes.

    Returns an :class:`repro.autotune.Decision` whose ``plan``/``scores``
    describe one shard's call — batch ``ceil(batch / n_shards)``, everything
    else unchanged — which is the workload each device actually runs.
    ``precision`` admits the reduced-precision variants under ``impl="auto"``
    exactly like the local path (DESIGN.md §10).
    """
    from repro import autotune

    interpret = resolve_interpret(interpret)
    n = shard_count(mesh, axis)
    batch, m_pad, n_b = b.shape
    dtype = autotune.precision_of(impl)[1] if impl != "auto" else precision
    w = autotune.Workload(batch=batch, m_pad=m_pad,
                          nnz_pad=a.row_ids.shape[1], k_pad=k_pad,
                          n_b=n_b, itemsize=b.dtype.itemsize,
                          dtype=dtype).shard(n)
    if impl != "auto":
        return autotune.forced_decision(w, impl, note=f" ({n}-way sharded)")
    return autotune.select_impl(w, allow_pallas=not interpret,
                                cache=autotune.default_cache())


def sharded_batched_spmm(
    a: BatchedCOO,
    b: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "data",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
    precision: str = "f32",
) -> jax.Array:
    """C[s] = A[s] @ B[s] with the batch axis sharded over ``mesh[axis]``.

    Semantically identical to :func:`repro.kernels.ops.batched_spmm` (the
    per-shard kernels are the same code); differentiable in ``a.values`` and
    ``b`` with batch-sharded cotangents. ``impl="auto"`` resolves against the
    per-shard workload (``precision`` admits reduced-precision variants to
    that ranking). Output stays batch-sharded (no forward all-gather).
    """
    from repro.kernels.ops import _forward, backward_db, batched_spmm, dvalues

    interpret = resolve_interpret(interpret)
    n = shard_count(mesh, axis)
    if n == 1:
        return batched_spmm(a, b, impl=impl, k_pad=k_pad, interpret=interpret,
                            precision=precision)

    batch = b.shape[0]
    a, b, pad = pad_batch(a, b, n)
    decision = resolve_sharded_impl(
        a, b, mesh, axis=axis, impl=impl, k_pad=k_pad,
        interpret=interpret, precision=precision)
    concrete = decision.impl

    spec = P(axis)      # dim-0 (batch) sharding for every operand
    row_ids, col_ids, nnz = a.row_ids, a.col_ids, a.nnz

    # The custom VJP lives OUTSIDE the shard_map and each side runs its own
    # shard_map over explicit operands: AD never differentiates *through* a
    # shard_map (no transpose, no scalar-residual issues), and the backward
    # is itself a batch-sharded batched SpMM + gather-dot, so dValues/dB come
    # out batch-sharded exactly like the forward output.
    def _fwd_local(rids, cids, nz, values, b_local):
        return _forward(rids, cids, nz, values, b_local,
                        impl=concrete, k_pad=k_pad, interpret=interpret)

    fwd_sharded = shard_map(
        _fwd_local, mesh=mesh, in_specs=(spec,) * 5, out_specs=spec,
        check_rep=False)

    def _bwd_local(rids, cids, nz, values, b_local, dc):
        # dB = Aᵀ·dC per shard: COO index swap, or csr_transpose for the
        # CSR class (kernels/ops.backward_db — same routing as the local VJP)
        db = backward_db(rids, cids, nz, values, dc,
                         impl=concrete, interpret=interpret)
        dval = dvalues(rids, cids, dc, b_local)
        return dval.astype(values.dtype), db.astype(b_local.dtype)

    bwd_sharded = shard_map(
        _bwd_local, mesh=mesh, in_specs=(spec,) * 6, out_specs=(spec, spec),
        check_rep=False)

    @jax.custom_vjp
    def f(values, bb):
        return fwd_sharded(row_ids, col_ids, nnz, values, bb)

    def fwd(values, bb):
        return f(values, bb), (values, bb)

    def bwd(res, dc):
        values, bb = res
        return bwd_sharded(row_ids, col_ids, nnz, values, bb, dc)

    f.defvjp(fwd, bwd)
    if obs_trace.enabled():
        # distributed-layer span (DESIGN.md §13): the per-SHARD workload key
        # is the decision's provenance — the same key the regret auditor and
        # tuning cache use for this dispatch's shapes
        w = decision.workload
        with obs_trace.TRACER.span(
                f"sharded_spmm/{concrete}", cat="kernel",
                args={"impl": concrete, "source": decision.source,
                      "n_shards": n, "padded": bool(pad),
                      "key": None if w is None else w.key()}):
            out = f(a.values, b)
    else:
        out = f(a.values, b)
    return out[:batch] if pad else out


def resolve_sharded_gspmm_impl(
    a: BatchedCOO,
    b: jax.Array,
    mesh: Mesh,
    *,
    op: str = "mul",
    reduce: str = "sum",
    axis: str = "data",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
):
    """Resolve a g-SpMM ``impl`` against the PER-SHARD workload shapes — the
    :func:`resolve_sharded_impl` analogue with the ``(op, reduce, d_e)``
    workload axes set, so the ranked ladder is restricted to the
    g-SpMM-capable subset (DESIGN.md §11)."""
    from repro import autotune

    interpret = resolve_interpret(interpret)
    n = shard_count(mesh, axis)
    batch, m_pad, n_b = b.shape
    d_e = a.values.shape[2] if a.values.ndim == 3 else None
    w = autotune.Workload(batch=batch, m_pad=m_pad,
                          nnz_pad=a.row_ids.shape[1], k_pad=k_pad,
                          n_b=n_b, itemsize=b.dtype.itemsize,
                          d_e=d_e, reduce=reduce, op=op).shard(n)
    if impl != "auto":
        return autotune.forced_decision(w, impl, note=f" ({n}-way sharded)")
    return autotune.select_impl(w, allow_pallas=not interpret,
                                cache=autotune.default_cache())


def sharded_batched_gspmm(
    a: BatchedCOO,
    b: jax.Array,
    *,
    op: str = "mul",
    reduce: str = "sum",
    mesh: Mesh,
    axis: str = "data",
    impl: str = "auto",
    k_pad: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """g-SpMM (``C[r] = reduce op(B[c], e)``, DESIGN.md §11) with the batch
    axis sharded over ``mesh[axis]``.

    Same structure as :func:`sharded_batched_spmm`: zero-nnz batch padding
    (harmless for every ``(op, reduce)`` corner — a padded sample has
    ``nnz = 0``, so all its slots are masked and every row takes the 0.0
    identity), per-shard ``impl="auto"`` resolution, custom VJP outside the
    shard_map with ``kernels.ops.gspmm_backward`` running per shard. The
    ``(mul, sum)`` scalar-edge corner delegates to
    :func:`sharded_batched_spmm` exactly like the local entry point.
    """
    from repro.autotune.cost_model import GSPMM_IMPLS, supports_gspmm
    from repro.kernels.ops import _forward, batched_gspmm, gspmm_backward

    interpret = resolve_interpret(interpret)
    if (op, reduce) == ("mul", "sum") and a.values.ndim == 2:
        return sharded_batched_spmm(a, b, mesh=mesh, axis=axis, impl=impl,
                                    k_pad=k_pad, interpret=interpret)
    n = shard_count(mesh, axis)
    if n == 1:
        return batched_gspmm(a, b, op=op, reduce=reduce, impl=impl,
                             k_pad=k_pad, interpret=interpret)

    batch = b.shape[0]
    a, b, pad = pad_batch(a, b, n)
    concrete = resolve_sharded_gspmm_impl(
        a, b, mesh, op=op, reduce=reduce, axis=axis, impl=impl,
        k_pad=k_pad, interpret=interpret).impl
    if not supports_gspmm(concrete):
        raise ValueError(
            f"impl {concrete!r} cannot run g-SpMM (op={op!r}, "
            f"reduce={reduce!r}); the capable set is {GSPMM_IMPLS} at f32")

    spec = P(axis)      # dim-0 (batch) sharding for every operand
    row_ids, col_ids, nnz = a.row_ids, a.col_ids, a.nnz

    def _fwd_local(rids, cids, nz, values, b_local):
        return _forward(rids, cids, nz, values, b_local, impl=concrete,
                        k_pad=k_pad, interpret=interpret, op=op,
                        reduce=reduce)

    fwd_sharded = shard_map(
        _fwd_local, mesh=mesh, in_specs=(spec,) * 5, out_specs=spec,
        check_rep=False)

    def _bwd_local(rids, cids, nz, values, b_local, c_local, dc):
        return gspmm_backward(rids, cids, nz, values, b_local, c_local, dc,
                              op=op, reduce=reduce, impl=concrete,
                              interpret=interpret)

    bwd_sharded = shard_map(
        _bwd_local, mesh=mesh, in_specs=(spec,) * 7,
        out_specs=(spec, spec), check_rep=False)

    @jax.custom_vjp
    def f(values, bb):
        return fwd_sharded(row_ids, col_ids, nnz, values, bb)

    def fwd(values, bb):
        c = f(values, bb)
        # only the max backward consumes the forward output (argmax routing)
        return c, (values, bb, c if reduce == "max" else None)

    def bwd(res, dc):
        values, bb, c = res
        cf = c if c is not None else jnp.zeros_like(dc)
        return bwd_sharded(row_ids, col_ids, nnz, values, bb, cf, dc)

    f.defvjp(fwd, bwd)
    out = f(a.values, b)
    return out[:batch] if pad else out


def sharded_fused_graph_conv(
    row_ids: jax.Array,     # (batch, channels, nnz_pad) int32
    col_ids: jax.Array,
    values: jax.Array,
    nnz: jax.Array,         # (batch, channels) int32
    x: jax.Array,           # (batch, m_pad, n_in)
    w: jax.Array,           # (channels, n_in, n_out) — replicated
    bias: jax.Array,        # (channels, n_out) — replicated
    *,
    mesh: Mesh,
    axis: str = "data",
    epilogue: str = "none",
    interpret: bool | None = None,
    impl: str = "fused",
) -> jax.Array:
    """The fused graph-conv megakernel (DESIGN.md §7) with the batch axis
    sharded over ``mesh[axis]``: each shard runs ONE fused ``pallas_call``
    for its slice of the batch — per-shard fused dispatch.

    Same structure as :func:`sharded_batched_spmm`: zero-nnz batch padding,
    custom VJP outside the shard_map, batch-sharded dValues/dX. The layer
    parameters ``w``/``bias`` enter replicated, so their gradients are
    psum-reduced over the batch shards inside the backward shard_map and
    come out replicated — exactly the all-reduce GSPMD would insert for the
    unfused path's dense MatMul.
    """
    from repro.autotune.cost_model import precision_of
    from repro.core.batching import plan_fused_graph_conv, plan_hybrid
    from repro.kernels.fused_graph_conv import (
        fused_bwd,
        fused_forward,
        fused_graph_conv,
        fused_hybrid_forward,
        runtime_chunks,
    )
    from repro.kernels.ops import bwd_impl_for

    interpret = resolve_interpret(interpret)
    n = shard_count(mesh, axis)
    if n == 1:
        return fused_graph_conv(row_ids, col_ids, values, nnz, x, w, bias,
                                epilogue=epilogue, interpret=interpret,
                                impl=impl)

    batch, channels, nnz_pad = row_ids.shape
    m_pad, n_in = x.shape[1], x.shape[2]
    n_out = w.shape[-1]
    pad = (-batch) % n
    if pad:
        # §IV-C padding invariant: zero-nnz samples contribute nothing and
        # their runtime chunk count is 0, so the skew-aware loop never runs
        def padb(t):
            return jnp.concatenate(
                [t, jnp.zeros((pad,) + t.shape[1:], t.dtype)], axis=0)

        row_ids, col_ids, values, nnz, x = map(
            padb, (row_ids, col_ids, values, nnz, x))
    plan = plan_fused_graph_conv(
        batch=(batch + pad) // n, m_pad=m_pad, n_in=n_in, n_out=n_out,
        channels=channels, nnz_pad=nnz_pad, itemsize=x.dtype.itemsize)
    if plan.case == 3:
        raise ValueError(
            f"m_pad={plan.m_pad} is planner case 3 (> LARGE_M): use the "
            "unfused graph_conv_batched fallback")
    hybrid = precision_of(impl)[0] == "fused_hybrid"
    # 4th sharded forward operand: the hybrid prep re-derives chunk counts
    # AFTER hub extraction, so it needs the raw per-channel nnz; the plain
    # megakernel takes precomputed chunk counts
    meta = nnz.astype(jnp.int32) if hybrid else runtime_chunks(nnz)
    if hybrid:
        # per-shard plan: the shapes each device actually runs (DESIGN.md §6)
        hplan = plan_hybrid(batch=(batch + pad) // n, m_pad=m_pad,
                            n_b=n_out, nnz_pad=channels * nnz_pad,
                            itemsize=x.dtype.itemsize)
    bwd_impl = bwd_impl_for(impl) if not interpret else "ref"

    spec, repl = P(axis), P()
    rids, cids = row_ids, col_ids

    def _fwd_local(rids_l, cids_l, vals_l, meta_l, x_l, w_l, b_l):
        if hybrid:
            return fused_hybrid_forward(
                rids_l, cids_l, vals_l, meta_l, x_l, w_l, b_l, None,
                plan=plan, hplan=hplan, epilogue=epilogue,
                interpret=interpret)
        return fused_forward(rids_l, cids_l, vals_l, meta_l, x_l, w_l, b_l,
                             None, plan=plan, epilogue=epilogue,
                             interpret=interpret)

    fwd_sharded = shard_map(
        _fwd_local, mesh=mesh, in_specs=(spec,) * 5 + (repl, repl),
        out_specs=spec, check_rep=False)

    def _bwd_local(rids_l, cids_l, vals_l, x_l, w_l, b_l, y_l, dy_l):
        dvals, dx, dw, db, _ = fused_bwd(
            rids_l, cids_l, vals_l, x_l, w_l, b_l, y_l, dy_l,
            epilogue=epilogue, interpret=interpret, has_residual=False,
            bwd_impl=bwd_impl)
        # replicated params: all-reduce their grads over the batch shards
        return dvals, dx, jax.lax.psum(dw, axis), jax.lax.psum(db, axis)

    bwd_sharded = shard_map(
        _bwd_local, mesh=mesh,
        in_specs=(spec,) * 4 + (repl, repl) + (spec, spec),
        out_specs=(spec, spec, repl, repl), check_rep=False)

    @jax.custom_vjp
    def f(vals, xx, ww, bb):
        return fwd_sharded(rids, cids, vals, meta, xx, ww, bb)

    def fwd(vals, xx, ww, bb):
        y = f(vals, xx, ww, bb)
        return y, (vals, xx, ww, bb, y)

    def bwd(res, dy):
        vals, xx, ww, bb, y = res
        return bwd_sharded(rids, cids, vals, xx, ww, bb, y, dy)

    f.defvjp(fwd, bwd)
    out = f(values, x, w, bias)
    return out[:batch] if pad else out
