"""pjit step builders shared by the trainer, the server and the dry-run.

``build_train_step`` produces a *full* optimizer step: microbatched gradient
accumulation (a lax.scan over microbatches — XLA's latency-hiding scheduler
overlaps the per-microbatch gradient all-reduce with the next microbatch's
backward compute), global-norm clipping, AdamW with ZeRO-1-sharded moments,
and optional int8 error-feedback gradient compression before the update.

All functions return (step_fn, in_shardings, out_shardings) so the dry-run
can ``jax.jit(...).lower(...).compile()`` against abstract inputs and the
trainer can call the same artifact with real arrays.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import tuning
from repro.configs.base import ModelConfig
from repro.distributed import sharding
from repro.distributed.compression import ef_int8_compress_decompress
from repro.models import lm
from repro.optim import AdamConfig, adam_init, adam_update


def shaped_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: lm.init_params(jax.random.key(0), cfg))


def shaped_opt_state(params_shape):
    return jax.eval_shape(adam_init, params_shape)


def build_train_step(cfg: ModelConfig, mesh, opt: AdamConfig, *,
                     microbatches: int = 1, remat: bool = True,
                     compress_grads: bool = False, zero1: bool = True,
                     donate: bool = True):
    p_shape = shaped_params(cfg)
    p_specs = sharding.param_specs(p_shape, mesh)
    if tuning.flags().fsdp:
        # ZeRO-3/FSDP: params shard the data axis too; XLA all-gathers shards
        # at use and the latency-hiding scheduler overlaps the gathers with
        # the previous layer's compute (scan-over-blocks structure).
        p_specs = sharding.zero1_specs(p_specs, p_shape, mesh)
    m_specs = (sharding.zero1_specs(p_specs, p_shape, mesh)
               if zero1 else p_specs)
    o_specs = {"m": m_specs, "v": m_specs,
               "step": jax.sharding.PartitionSpec()}
    if compress_grads:
        o_specs["ef_err"] = m_specs

    def train_step(params, opt_state, batch):
        def micro_loss(p, mb):
            loss, metrics = lm.loss_fn(p, cfg, mb, remat=remat)
            return loss, metrics

        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def constrain(mb):
                # Pin each microbatch to the plain data-parallel layout. On a
                # mixed (data × model) mesh, GSPMD otherwise picks an ad-hoc
                # layout for the scanned operand ("involuntary full
                # rematerialization") whose numerics drift ~1e-4 per forward
                # from the single-device program — enough to break loss-parity
                # across mesh shapes after a few optimizer steps.
                specs = sharding.batch_specs(mb, mesh)
                return jax.tree.map(
                    lambda x, s: jax.lax.with_sharding_constraint(
                        x, jax.sharding.NamedSharding(mesh, s)), mb, specs)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    micro_loss, has_aux=True)(params, constrain(mb))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
        else:
            (loss, _), grads = jax.value_and_grad(
                micro_loss, has_aux=True)(params, batch)

        if compress_grads:
            grads, new_err = ef_int8_compress_decompress(
                grads, opt_state["ef_err"])
            opt_state = {**opt_state, "ef_err": new_err}

        params, opt_state = adam_update(opt, params, grads, opt_state)
        return params, opt_state, {"loss": loss}

    jit_kw = dict(donate_argnums=(0, 1)) if donate else {}

    def jitted(batch_tree_shape):
        b_specs = sharding.batch_specs(batch_tree_shape, mesh)
        return jax.jit(
            train_step,
            in_shardings=sharding.named(mesh, (p_specs, o_specs, b_specs)),
            out_shardings=sharding.named(
                mesh, (p_specs, o_specs,
                       {"loss": jax.sharding.PartitionSpec()})),
            **jit_kw,
        )

    return jitted, p_specs, o_specs


def build_prefill(cfg: ModelConfig, mesh):
    p_shape = shaped_params(cfg)
    p_specs = sharding.param_specs(p_shape, mesh)

    def prefill_step(params, batch):
        logits, _ = lm.prefill(params, cfg, batch)
        return logits

    def jitted(batch_tree_shape):
        b_specs = sharding.batch_specs(batch_tree_shape, mesh)
        return jax.jit(
            prefill_step,
            in_shardings=sharding.named(mesh, (p_specs, b_specs)),
            out_shardings=sharding.named(
                mesh, sharding.batch_specs(
                    jax.ShapeDtypeStruct(
                        (batch_first_dim(batch_tree_shape), 1, cfg.vocab),
                        jnp.float32), mesh)),
        )

    return jitted, p_specs


def batch_first_dim(batch_tree_shape) -> int:
    return jax.tree.leaves(batch_tree_shape)[0].shape[0]


def build_decode_step(cfg: ModelConfig, mesh):
    p_shape = shaped_params(cfg)
    p_specs = sharding.param_specs(p_shape, mesh)

    def decode(params, tokens, caches, pos):
        return lm.decode_step(params, cfg, tokens, caches, pos)

    def jitted(tokens_shape, caches_shape):
        c_specs = sharding.cache_specs(caches_shape, mesh)
        t_specs = sharding.batch_specs(tokens_shape, mesh)
        logits_spec = sharding.batch_specs(
            jax.ShapeDtypeStruct(
                (tokens_shape.shape[0], 1, cfg.vocab), jnp.float32), mesh)
        return jax.jit(
            decode,
            in_shardings=sharding.named(
                mesh, (p_specs, t_specs, c_specs,
                       jax.sharding.PartitionSpec())),
            out_shardings=sharding.named(mesh, (logits_spec, c_specs)),
            donate_argnums=(2,),
        )

    return jitted, p_specs
