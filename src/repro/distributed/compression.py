"""Gradient compression: int8 error-feedback (EF-SGD style).

Each gradient leaf is quantized to int8 with a per-leaf scale before the
cross-replica reduction; the quantization residual is carried in the
optimizer state and added back the next step, which keeps convergence
(Karimireddy et al., 2019). Under GSPMD the reduction itself is emitted by
XLA; the wire format a multi-pod runtime would ship per hop is the int8
payload + one f32 scale per leaf (8 B), a ~4× cross-pod bandwidth saving —
EXPERIMENTS.md reports the collective-bytes delta from the lowered HLO.

Off by default; enabled with ``--compress-grads`` and covered by a
convergence test (tests/test_distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant(g, err):
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_int8_compress_decompress(grads, ef_err):
    """Returns (decompressed grads, new EF residuals)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_err)
    out = [_quant(g, e) for g, e in zip(flat_g, flat_e)]
    deq = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
    return deq, new_err
