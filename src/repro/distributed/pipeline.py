"""Pipeline parallelism (GPipe schedule) via shard_map + collective_permute.

Layer blocks are stacked on axis 0 (the scan axis), so pipeline-stage
assignment is just sharding that axis over a "pipe" mesh axis: stage s owns
blocks [s·L/P, (s+1)·L/P). The schedule is the classic synchronous pipeline:
T = microbatches + P − 1 ticks; on tick t, stage s processes microbatch
t − s (when valid) and forwards its activation to stage s+1 with
``jax.lax.ppermute`` (whose VJP is the reverse permute, so backward
pipelines automatically under ``jax.grad``). The bubble fraction is
(P−1)/T — reported by ``pipeline_bubble_fraction``.

Embedding and LM head are replicated; only stage 0 embeds and only stage
P−1 computes logits/loss (their gradients are psum'd across stages).
Supported: homogeneous block-pattern architectures (all dense/MoE LMs here);
zamba's grouped hybrid and whisper's enc-dec would need per-stage
heterogeneous programs — out of scope, noted in DESIGN.md.

Tested end-to-end (loss parity vs the non-pipelined step) on a 4-stage CPU
mesh in tests/test_distributed.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.layers import rms_norm


def pipeline_bubble_fraction(microbatches: int, stages: int) -> float:
    return (stages - 1) / (microbatches + stages - 1)


def build_pp_loss(cfg: ModelConfig, mesh, *, microbatches: int,
                  pipe_axis: str = "pipe"):
    """Returns loss_fn(params, batch) running the block stack as a pipeline
    over `pipe_axis`. batch["tokens"]: (microbatches·b, T)."""
    assert not cfg.attn_every and not cfg.encoder_layers, \
        "pipeline path supports homogeneous block-pattern archs"
    stages = dict(zip(mesh.axis_names, mesh.devices.shape))[pipe_axis]
    assert cfg.n_blocks % stages == 0, (cfg.n_blocks, stages)

    def stage_blocks(blocks_local, h, positions):
        from repro.models.lm import _apply_sublayer

        def body(carry, blk):
            h, aux = carry
            for i, kind in enumerate(cfg.block_pattern):
                h, _, a = _apply_sublayer(
                    kind, blk[f"{i}_{kind}"], cfg, h, positions=positions,
                    cache=None, cache_pos=None)
                aux = aux + a
            return (h, aux), None

        (h, aux), _ = jax.lax.scan(body, (h, 0.0), blocks_local)
        return h, aux

    def loss_fn(params, batch):
        tokens = batch["tokens"]                       # (mb·b, T)
        n, t = tokens.shape
        b = n // microbatches
        mbs = tokens.reshape(microbatches, b, t)

        blocks_spec = jax.tree.map(lambda _: P(pipe_axis), params["blocks"])
        other_spec = jax.tree.map(lambda _: P(), {
            k: v for k, v in params.items() if k != "blocks"})

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=({"blocks": blocks_spec, **other_spec}, P()),
            out_specs=P(),
            check_rep=False)
        def run(params, mbs):
            stage = jax.lax.axis_index(pipe_axis)
            blocks_local = jax.tree.map(lambda x: x, params["blocks"])
            positions = jnp.broadcast_to(jnp.arange(t), (b, t)).astype(
                jnp.int32)
            ticks = microbatches + stages - 1
            d = cfg.d_model

            def tick(carry, ti):
                act_in, loss_sum, tok_sum = carry
                # stage 0 ingests microbatch `ti` (garbage after the ramp;
                # masked out on the loss side)
                mb_idx = jnp.clip(ti, 0, microbatches - 1)
                toks = jax.lax.dynamic_index_in_dim(
                    mbs, mb_idx, 0, keepdims=False)
                fresh = jnp.take(params["embed"], toks, axis=0)
                h = jnp.where(jnp.equal(stage, 0), fresh, act_in)
                h, _ = stage_blocks(blocks_local, h, positions)
                # last stage: loss for microbatch ti-(P-1) when valid
                out_idx = ti - (stages - 1)
                valid = (out_idx >= 0) & (out_idx < microbatches) & \
                    jnp.equal(stage, stages - 1)
                otoks = jax.lax.dynamic_index_in_dim(
                    mbs, jnp.clip(out_idx, 0, microbatches - 1), 0,
                    keepdims=False)
                hf = rms_norm(params["final_norm"], h, cfg.norm_eps)
                head = (params["embed"].T if cfg.tie_embeddings
                        else params["lm_head"])
                logits = (hf @ head)[:, :-1].astype(jnp.float32)
                targets = otoks[:, 1:]
                logz = jax.scipy.special.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(
                    logits, targets[..., None], axis=-1)[..., 0]
                # rank-1 (not scalar) accumulators: a rank-0 float residual
                # inside shard_map trips jax's scalar-residual _SpecError when
                # the loss is differentiated (shard_map transpose gives
                # residuals a mesh-axis spec that rank 0 cannot carry).
                nll = jnp.sum(logz - gold).reshape(1)
                loss_sum = loss_sum + jnp.where(valid, nll, 0.0)
                tok_sum = tok_sum + jnp.where(
                    valid, jnp.float32(targets.size).reshape(1), 0.0)
                # forward activation to the next stage
                act_out = jax.lax.ppermute(
                    h, pipe_axis,
                    [(i, i + 1) for i in range(stages - 1)])
                return (act_out, loss_sum, tok_sum), None

            act0 = jnp.zeros((b, t, d), jnp.dtype(cfg.dtype))
            (_, loss_sum, tok_sum), _ = jax.lax.scan(
                tick, (act0, jnp.zeros((1,), jnp.float32),
                       jnp.zeros((1,), jnp.float32)),
                jnp.arange(ticks))
            # only the last stage accumulated loss; share it with everyone
            loss_sum = jax.lax.psum(loss_sum, pipe_axis)
            tok_sum = jax.lax.psum(tok_sum, pipe_axis)
            return loss_sum / jnp.maximum(tok_sum, 1.0)

        return run(params, mbs)[0]

    return loss_fn
