"""Per-kernel allclose sweeps: every Pallas kernel (interpret=True) against
the pure-jnp oracle in kernels/ref.py, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    batching,
    coo_to_csr,
    coo_to_dense,
    coo_to_ell,
    random_batch,
)
from repro.core.spmm import IMPLS, batched_spmm
from repro.kernels import ref
from repro.kernels.batched_gemm import batched_gemm
from repro.kernels.batched_spmm_coo import batched_spmm_coo
from repro.kernels.batched_spmm_csr import batched_spmm_csr
from repro.kernels.batched_spmm_ell import batched_spmm_ell
from repro.kernels.ops import bwd_impl_for


def _case(seed, batch, dim, nnz, n_b, dtype):
    rng = np.random.default_rng(seed)
    coo, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz,
                              dtype=dtype)
    b = jnp.asarray(rng.normal(size=(batch, m_pad, n_b)), dtype)
    dense = coo_to_dense(coo, m_pad)
    want = jax.lax.batch_matmul(dense.astype(jnp.float32),
                                b.astype(jnp.float32))
    return coo, m_pad, b, want


TOLS = {jnp.float32: 1e-5, jnp.bfloat16: 8e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("batch,dim,nnz,n_b", [
    (4, 32, 1, 8),        # tiny
    (8, (20, 50), (1, 5), 64),   # paper's GCN regime (mixed sizes, Fig. 10)
    (4, 64, 5, 128),      # one full lane tile
    (2, 128, 3, 300),     # non-multiple-of-128 columns (padding path)
    (3, (8, 40), (1, 8), 520),   # forces cache blocking (p > 1)
])
def test_spmm_ell_vs_oracle(batch, dim, nnz, n_b, dtype):
    coo, m_pad, b, want = _case(0, batch, dim, nnz, n_b, dtype)
    k_pad = 16
    ell = coo_to_ell(coo, m_pad, k_pad)
    plan = batching.plan_batched_spmm(batch=batch, m_pad=m_pad, n_b=n_b,
                                      slots=k_pad, itemsize=b.dtype.itemsize)
    got = batched_spmm_ell(ell.col_ids, ell.values, b, plan=plan)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               atol=TOLS[dtype] * max(1, nnz if isinstance(nnz, int) else nnz[1]),
                               rtol=TOLS[dtype])
    # oracle self-check: ELL ref == COO ref
    got_ref = ref.batched_spmm_ell_ref(ell, b)
    np.testing.assert_allclose(np.asarray(got_ref, np.float32), want,
                               atol=TOLS[dtype] * 8, rtol=TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("batch,dim,nnz,n_b", [
    (4, 32, 1, 8),
    (8, (20, 50), (1, 5), 64),
    (2, 128, 3, 300),
    (3, (8, 40), (1, 8), 520),
])
def test_spmm_coo_vs_oracle(batch, dim, nnz, n_b, dtype):
    coo, m_pad, b, want = _case(1, batch, dim, nnz, n_b, dtype)
    plan = batching.plan_batched_spmm(batch=batch, m_pad=m_pad, n_b=n_b,
                                      slots=coo.nnz_pad,
                                      itemsize=b.dtype.itemsize)
    got = batched_spmm_coo(coo.row_ids, coo.col_ids, coo.values, b, plan=plan)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               atol=TOLS[dtype] * 8, rtol=TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("batch,m,k,n", [
    (4, 16, 16, 16), (2, 64, 32, 128), (3, 40, 24, 260), (1, 128, 128, 512),
])
def test_batched_gemm_vs_oracle(batch, m, k, n, dtype):
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(batch, m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(batch, k, n)), dtype)
    plan = batching.plan_batched_gemm(batch=batch, m=m, n=n, k=k,
                                      itemsize=b.dtype.itemsize)
    got = batched_gemm(a, b, plan=plan)
    want = ref.batched_gemm_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               atol=TOLS[dtype] * k, rtol=TOLS[dtype] * 4)


def test_all_impls_agree():
    coo, m_pad, b, want = _case(3, 6, (10, 60), (1, 5), 96, jnp.float32)
    outs = {}
    for impl in ("ref", "loop", "dense", "pallas_gemm", "pallas_coo",
                 "pallas_ell", "ell", "csr", "pallas_csr", "hybrid",
                 "pallas_hybrid"):
        outs[impl] = np.asarray(
            batched_spmm(coo, b, impl=impl, k_pad=16))
    for impl, got in outs.items():
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-5,
                                   err_msg=impl)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("batch,dim,nnz,n_b", [
    (4, 32, 1, 8),        # tiny
    (8, (20, 50), (1, 5), 64),   # paper's GCN regime (mixed sizes, Fig. 10)
    (4, 64, 5, 128),      # one full lane tile
    (2, 128, 3, 300),     # non-multiple-of-128 columns (padding path)
    (3, (8, 40), (1, 8), 520),   # forces cache blocking (p > 1)
])
def test_spmm_csr_vs_oracle(batch, dim, nnz, n_b, dtype):
    """The CSR row-split Pallas kernel (DESIGN.md §9) against the dense
    oracle — same sweep as the ELL kernel's."""
    coo, m_pad, b, want = _case(5, batch, dim, nnz, n_b, dtype)
    csr = coo_to_csr(coo, m_pad)
    plan = batching.plan_batched_spmm(batch=batch, m_pad=m_pad, n_b=n_b,
                                      slots=csr.nnz_pad,
                                      itemsize=b.dtype.itemsize)
    got = batched_spmm_csr(csr.rpt, csr.col_ids, csr.values, b, plan=plan)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               atol=TOLS[dtype] * 8, rtol=TOLS[dtype])
    # oracle self-check: CSR segment-sum ref == dense oracle
    got_ref = ref.batched_spmm_csr_ref(csr, b)
    np.testing.assert_allclose(np.asarray(got_ref, np.float32), want,
                               atol=TOLS[dtype] * 8, rtol=TOLS[dtype])


def test_vjp_matches_ref():
    coo, m_pad, b, _ = _case(4, 4, (10, 30), (1, 4), 32, jnp.float32)

    def make_loss(impl):
        def loss(values, bb):
            import dataclasses
            c = batched_spmm(dataclasses.replace(coo, values=values), bb,
                             impl=impl, k_pad=8)
            return jnp.sum(jnp.tanh(c))
        return loss

    g_ref = jax.grad(make_loss("ref"), argnums=(0, 1))(coo.values, b)
    for impl in ("pallas_ell", "pallas_coo", "dense"):
        g = jax.grad(make_loss(impl), argnums=(0, 1))(coo.values, b)
        np.testing.assert_allclose(np.asarray(g[0]), np.asarray(g_ref[0]),
                                   atol=1e-4, err_msg=f"{impl} dvalues")
        np.testing.assert_allclose(np.asarray(g[1]), np.asarray(g_ref[1]),
                                   atol=1e-4, err_msg=f"{impl} db")


# ---------------------------------------------------------------------------
# The impl matrix (ISSUE 5 satellite, generalized by ISSUE 6): EVERY
# registered concrete impl — full-precision AND reduced-precision variants —
# must match the ref oracle, forward and grads, on uniform, skewed and
# zero-nnz batches at its policy's tolerance. The shared harness lives in
# tests/oracle.py; "auto" resolves to one of these; the fused layer class
# runs through the same harness in test_fused_graph_conv.py.
# ---------------------------------------------------------------------------

from oracle import (  # noqa: E402
    CONCRETE_SPMM_IMPLS,
    check_spmm_forward,
    check_spmm_grads,
)


@pytest.mark.parametrize("impl", CONCRETE_SPMM_IMPLS)
def test_impl_matrix_forward_matches_ref(impl):
    check_spmm_forward(impl)


@pytest.mark.parametrize("impl", CONCRETE_SPMM_IMPLS)
def test_impl_matrix_grads_match_ref(impl):
    check_spmm_grads(impl)


@pytest.mark.parametrize("impl", ["dense", "pallas_gemm"])
def test_dense_fallback_promotes_mixed_dtypes(impl):
    """Regression (ISSUE 6 satellite): the dense fallback used to
    ``a_dense.astype(b.dtype)`` — a SILENT downcast that rounded f32
    adjacency values to bf16 whenever B arrived in bf16, and returned the
    product at bf16. Mixed dtypes must resolve through the promotion policy
    (resolve_compute_dtype): both operands promoted to f32 compute, output
    at the promoted dtype. Values are chosen so bf16 rounding is visible
    and B is exactly representable at bf16, so the pre-fix path fails both
    the dtype and the allclose assertion."""
    import dataclasses

    rng = np.random.default_rng(21)
    coo, m_pad = random_batch(rng, batch=2, dim=12, nnz_per_row=2)
    vals = np.asarray(coo.values)
    coo = dataclasses.replace(coo, values=jnp.asarray(
        np.where(vals != 0, vals + 1e-3, 0.0), jnp.float32))
    b = jnp.asarray(rng.integers(-4, 5, (2, m_pad, 8)), jnp.bfloat16)
    out = batched_spmm(coo, b, impl=impl)
    assert out.dtype == jnp.float32, "mixed f32×bf16 must promote, not demote"
    want = batched_spmm(coo, b.astype(jnp.float32), impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6,
                               rtol=1e-6)


def test_bwd_impl_mapping_pinned():
    """bwd_impl_for's mapping, pinned for EVERY entry in IMPLS — the
    backward class is part of each impl's contract (CSR keeps CSR via
    csr_transpose; ELL-class falls back to the scatter classes; reduced-
    precision variants keep a class-consistent backward that accumulates in
    f32; a typo'd or future impl falls back to ref)."""
    want = {
        "auto": "ref",          # resolved before the VJP; ref if it leaks
        "ref": "ref",
        "ell": "ref",           # Aᵀ loses the per-row ELL bound
        "pallas_ell": "pallas_coo",
        "csr": "csr",           # csr_transpose: exact device-side Aᵀ
        "pallas_csr": "pallas_csr",
        "pallas_coo": "pallas_coo",
        "dense": "dense",
        "pallas_gemm": "pallas_coo",
        "loop": "loop",
        "fused": "pallas_coo",  # dU = Aᵀ·dZ is a plain batched SpMM
        "fused_hybrid": "pallas_coo",   # same: bwd runs on the ORIGINAL COO
        # hybrid backward: the epilogue's inverse permutation lives INSIDE
        # the custom_vjp boundary, so cotangents arrive in original row
        # order and the backward is the plain CSR class — no re-sort
        "hybrid": "csr",
        "pallas_hybrid": "pallas_csr",
        # bf16 variants keep the class (and policy) through the backward
        "ell_bf16": "ref",
        "csr_bf16": "csr_bf16",
        "pallas_ell_bf16": "pallas_coo_bf16",
        "pallas_csr_bf16": "pallas_csr_bf16",
        "pallas_hybrid_bf16": "pallas_csr_bf16",
        "pallas_coo_bf16": "pallas_coo_bf16",
        "fused_bf16": "pallas_coo_bf16",
        # i8 backward is full-precision straight-through: the residuals hold
        # the ORIGINAL f32 values, so the grads run the f32 base class
        "pallas_ell_i8": "pallas_coo",
        "pallas_csr_i8": "pallas_csr",
    }
    assert set(want) == set(IMPLS)
    for impl in IMPLS:
        assert bwd_impl_for(impl) == want[impl], impl


def test_planner_cases():
    # paper Fig. 5 case analysis with TPU constants
    p1 = batching.plan_batched_spmm(batch=10, m_pad=64, n_b=64, slots=8)
    assert p1.case == 1 and p1.p == 1
    p2 = batching.plan_batched_spmm(batch=10, m_pad=2048, n_b=4096, slots=8)
    assert p2.case == 2 and p2.p > 1
    assert p2.n_block % batching.LANES == 0
    assert 2 * p2.m_pad * p2.n_block * 4 <= batching.VMEM_TILE_BUDGET * 1.01
    p3 = batching.plan_batched_spmm(batch=2, m_pad=10000, n_b=64, slots=8)
    assert p3.case == 3   # paper: m_A > 8192 → don't batch


# ---------------------------------------------------------------------------
# Flash attention kernel (interpret mode) vs jnp oracle
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal, window):
    b, tq, h, hd = q.shape
    groups = h // k.shape[2]
    k = jnp.repeat(k, groups, axis=2).astype(jnp.float32)
    v = jnp.repeat(v, groups, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k) * hd ** -0.5
    qpos = jnp.arange(tq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((tq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,kv,hd,causal,window,qb,kvb", [
    (2, 64, 4, 4, 32, True, 0, 32, 32),      # MHA causal
    (1, 128, 8, 2, 16, True, 0, 64, 32),     # GQA (index-map kv selection)
    (2, 96, 4, 1, 32, True, 0, 32, 32),      # MQA + non-multiple seq
    (1, 128, 4, 4, 32, True, 48, 32, 32),    # sliding window
    (2, 64, 4, 2, 32, False, 0, 64, 64),     # bidirectional (encoder)
])
def test_flash_attention_vs_oracle(b, t, h, kv, hd, causal, window, qb, kvb,
                                   dtype):
    from repro.kernels.flash_attention import flash_attention

    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, kv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, kv, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=qb, kv_block=kvb)
    want = _naive_attention(q, k, v, causal, window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_matches_chunked():
    """Both attention impls (XLA-chunked baseline, Pallas flash) agree —
    the §Perf substitution changes traffic, not numerics."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.layers import chunked_attention

    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(2, 80, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 80, 4, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 80, 4, 32)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    c = chunked_attention(q, k, v, causal=True, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


# ---------------------------------------------------------------------------
# Grouped ragged matmul (MoE expert GEMM) vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,e,tm,seed", [
    (256, 32, 64, 4, 64, 0),       # aligned boundaries
    (200, 16, 48, 3, 64, 1),       # ragged + padding rows
    (128, 32, 200, 8, 32, 2),      # many groups, non-128 N
    (64, 8, 16, 2, 64, 3),         # one tile, boundary inside the tile
])
def test_grouped_matmul_vs_oracle(m, k, n, e, tm, seed, dtype):
    from repro.kernels.grouped_matmul import grouped_matmul, sort_by_group
    from repro.kernels.ref import grouped_matmul_ref

    rng = np.random.default_rng(seed)
    # random ragged sizes summing to m
    cuts = np.sort(rng.choice(np.arange(1, m), size=e - 1, replace=False))
    sizes = np.diff(np.concatenate([[0], cuts, [m]])).astype(np.int32)
    eids = jnp.asarray(np.repeat(np.arange(e), sizes), jnp.int32)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(e, k, n)), dtype)
    got = grouped_matmul(x, w, jnp.asarray(sizes), tm=tm, tn=128,
                         max_groups_per_tile=e)
    want = grouped_matmul_ref(x.astype(jnp.float32), eids,
                              w.astype(jnp.float32))
    tol = 1e-4 * k if dtype == jnp.float32 else 0.15 * np.sqrt(k)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=tol, rtol=1e-2)


def test_sort_by_group_roundtrip():
    from repro.kernels.grouped_matmul import sort_by_group

    eids = jnp.asarray([2, 0, 1, 0, 2, 1, 1], jnp.int32)
    order, sizes = sort_by_group(eids, 3)
    np.testing.assert_array_equal(np.asarray(sizes), [2, 3, 2])
    assert (np.diff(np.asarray(eids)[np.asarray(order)]) >= 0).all()
