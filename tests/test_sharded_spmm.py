"""Mesh-sharded Batched SpMM regression tests (DESIGN.md §6).

The mesh tests run in an 8-device subprocess (XLA locks the host device
count at first init — same pattern as tests/test_distributed.py); the
pure-shape tests (per-shard workload resolution, padding) run in-process.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, n_dev: int = 8, timeout: int = 600):
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}",
           "JAX_PLATFORMS": "cpu"}
    return subprocess.run([sys.executable, "-c", script, SRC],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


_HEADER = r"""
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.core.formats import random_batch
from repro.distributed.spmm import resolve_sharded_impl, sharded_batched_spmm
from repro.kernels.ops import batched_spmm
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
"""


def test_sharded_matches_single_device_fwd_and_grad():
    """Forward and both gradients match the single-device batched_spmm
    bit-for-bit on an 8-way mesh, for impl="ref" and impl="auto"."""
    script = _HEADER + r"""
a, m_pad = random_batch(rng, batch=16, dim=24, nnz_per_row=3)
b = jnp.asarray(rng.standard_normal((16, m_pad, 32)), jnp.float32)
for impl in ("ref", "auto"):
    ref = batched_spmm(a, b, impl=impl, k_pad=8)
    got = sharded_batched_spmm(a, b, mesh=mesh, impl=impl, k_pad=8)
    assert float(jnp.max(jnp.abs(ref - got))) == 0.0, impl

    def loss(f):
        return lambda v, bb: jnp.sum(f(a.with_values(v), bb) ** 2)

    f_ref = lambda aa, bb: batched_spmm(aa, bb, impl=impl, k_pad=8)
    f_sh = lambda aa, bb: sharded_batched_spmm(aa, bb, mesh=mesh, impl=impl,
                                               k_pad=8)
    gr = jax.grad(loss(f_ref), argnums=(0, 1))(a.values, b)
    gs = jax.grad(loss(f_sh), argnums=(0, 1))(a.values, b)
    assert float(jnp.max(jnp.abs(gr[0] - gs[0]))) == 0.0, impl   # dValues
    assert float(jnp.max(jnp.abs(gr[1] - gs[1]))) == 0.0, impl   # dB
    # under jit XLA may re-fuse the gather-dot: tight allclose, not bitwise
    gj = jax.jit(jax.grad(loss(f_sh), argnums=(0, 1)))(a.values, b)
    np.testing.assert_allclose(gr[0], gj[0], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(gr[1], gj[1], rtol=2e-5, atol=2e-5)
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


def test_sharded_batch_not_divisible_by_devices():
    """batch=13 on 8 devices: padded with zero-nnz samples (§IV-C padding
    invariant), output sliced back, fwd + grads still match."""
    script = _HEADER + r"""
a, m_pad = random_batch(rng, batch=13, dim=20, nnz_per_row=3)
b = jnp.asarray(rng.standard_normal((13, m_pad, 16)), jnp.float32)
for impl in ("ref", "auto"):
    ref = batched_spmm(a, b, impl=impl, k_pad=8)
    got = sharded_batched_spmm(a, b, mesh=mesh, impl=impl, k_pad=8)
    assert got.shape == ref.shape
    assert float(jnp.max(jnp.abs(ref - got))) == 0.0, impl

    def loss(f):
        return lambda v, bb: jnp.sum(f(a.with_values(v), bb) ** 2)

    f_ref = lambda aa, bb: batched_spmm(aa, bb, impl=impl, k_pad=8)
    f_sh = lambda aa, bb: sharded_batched_spmm(aa, bb, mesh=mesh, impl=impl,
                                               k_pad=8)
    gr = jax.grad(loss(f_ref), argnums=(0, 1))(a.values, b)
    gs = jax.grad(loss(f_sh), argnums=(0, 1))(a.values, b)
    assert gs[0].shape == gr[0].shape and gs[1].shape == gr[1].shape
    assert float(jnp.max(jnp.abs(gr[0] - gs[0]))) == 0.0, impl
    assert float(jnp.max(jnp.abs(gr[1] - gs[1]))) == 0.0, impl
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


def test_forward_output_stays_batch_sharded():
    """No forward all-gather: the jitted sharded output carries a
    batch-sharded NamedSharding over the data axis."""
    script = _HEADER + r"""
a, m_pad = random_batch(rng, batch=16, dim=24, nnz_per_row=3)
b = jnp.asarray(rng.standard_normal((16, m_pad, 32)), jnp.float32)
out = jax.jit(lambda v, bb: sharded_batched_spmm(
    a.with_values(v), bb, mesh=mesh))(a.values, b)
spec = out.sharding.spec
assert tuple(spec)[:1] == ("data",), spec
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


def test_graph_serve_engine_mesh_wave_matches_single_device():
    """GraphServeEngine(mesh=...): one wave spans all devices and the logits
    match the single-device engine."""
    script = _HEADER + r"""
from repro.core.gcn import GCNConfig, init_gcn
from repro.serving.engine import GraphRequest, GraphServeEngine
cfg = GCNConfig(n_features=8, channels=2, conv_widths=(16,), n_tasks=4)
params = init_gcn(jax.random.key(0), cfg)
def make():
    reqs = []
    r2 = np.random.default_rng(7)
    for i in range(10):
        m = int(r2.integers(5, 12)); e = int(r2.integers(4, 10))
        reqs.append(GraphRequest(
            rows=[r2.integers(0, m, e).astype(np.int32)
                  for _ in range(cfg.channels)],
            cols=[r2.integers(0, m, e).astype(np.int32)
                  for _ in range(cfg.channels)],
            features=r2.standard_normal((m, cfg.n_features)).astype(
                np.float32),
            n_nodes=m))
    return reqs
single = GraphServeEngine(params, cfg, batch=16, m_pad=16, nnz_pad=16)
meshed = GraphServeEngine(params, cfg, batch=16, m_pad=16, nnz_pad=16,
                          mesh=mesh)
r1, r2_ = single.run(make()), meshed.run(make())
assert all(r.done for r in r2_)
d = max(float(np.max(np.abs(a.logits - b.logits))) for a, b in zip(r1, r2_))
assert d < 1e-5, d
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


def test_scheduler_mesh_waves_match_single_device():
    """The continuous-batching Scheduler with mesh= spans every wave across
    the 8-device mesh (per-tier engines inherit the mesh) and its logits
    match the single-device scheduler's."""
    script = _HEADER + r"""
from repro.core.gcn import GCNConfig, init_gcn
from repro.data.graphs import GraphDatasetSpec, generate
from repro.scheduler import Scheduler, TierPolicy, VirtualClock
from repro.serving.engine import GraphRequest
spec = GraphDatasetSpec.tox21_like(n_samples=12, n_features=8, channels=2,
                                   size_dist="skewed", seed=3)
data = generate(spec)
cfg = GCNConfig(n_features=8, channels=2, conv_widths=(16,), n_tasks=4)
params = init_gcn(jax.random.key(0), cfg)
policy = TierPolicy.from_requests(
    [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
    levels=2, batch=8)
def make():
    return [GraphRequest(rows=s.rows, cols=s.cols, features=s.features,
                         n_nodes=s.n_nodes) for s in data]
single = Scheduler(params, cfg, tiers=policy, clock=VirtualClock())
meshed = Scheduler(params, cfg, tiers=policy, clock=VirtualClock(),
                   mesh=mesh)
r1, r2 = single.serve(make()), meshed.serve(make())
assert all(r.done for r in r2)
assert meshed.metrics.compile_count == single.metrics.compile_count
d = max(float(np.max(np.abs(a.logits - b.logits))) for a, b in zip(r1, r2))
assert d < 1e-5, d
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


def test_gcn_trainer_mesh_gradients_match_single_device():
    """GCNTrainer(mesh=...): the data-parallel step's loss and gradients
    match the single-device step (the grad all-reduce is GSPMD's, inserted
    from the sharded-batch/replicated-params layout)."""
    script = _HEADER + r"""
from repro.core.gcn import GCNConfig, gcn_loss, init_gcn
cfg = GCNConfig(n_features=8, channels=2, conv_widths=(16,), n_tasks=4)
a0, m_pad = random_batch(rng, batch=16, dim=12, nnz_per_row=2)
adj = [a0] * cfg.channels
x = jnp.asarray(rng.standard_normal((16, m_pad, cfg.n_features)), jnp.float32)
n_nodes = jnp.asarray(a0.n_rows)
labels = jnp.asarray(
    rng.integers(0, 2, (16, cfg.n_tasks)).astype(np.float32))
params = init_gcn(jax.random.key(0), cfg)
vg = lambda mk: jax.jit(jax.value_and_grad(
    lambda p: gcn_loss(p, cfg, adj, x, n_nodes, labels, mesh=mk)[0]))
(l1, g1), (l2, g2) = vg(None)(params), vg(mesh)(params)
assert abs(float(l1) - float(l2)) < 1e-5, (l1, l2)
for ga, gb in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
    assert float(jnp.max(jnp.abs(ga - gb))) < 1e-5
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


def test_sharded_fused_graph_conv_matches_local():
    """Per-shard fused megakernel dispatch (DESIGN.md §7): fwd + all four
    grads match the local fused layer on an 8-way mesh, including a batch
    that is not divisible by the device count (zero-nnz padding)."""
    script = _HEADER + r"""
from repro.core.graph_conv import init_graph_conv, stack_channels
from repro.distributed.spmm import sharded_fused_graph_conv
from repro.kernels.fused_graph_conv import fused_graph_conv
for batch in (16, 13):
    adj = []
    for _ in range(3):
        a, m_pad = random_batch(rng, batch=batch, dim=(8, 24),
                                nnz_per_row=(1, 3))
        adj.append(a)
    m_pad = 24
    x = jnp.asarray(rng.standard_normal((batch, m_pad, 10)), jnp.float32)
    params = init_graph_conv(jax.random.key(0), 10, 16, 3)
    rids, cids, vals, nnz = stack_channels(adj)
    args = (vals, x, params["w"], params["b"])

    def loc(v, xx, ww, bb):
        return fused_graph_conv(rids, cids, v, nnz, xx, ww, bb)

    def sh(v, xx, ww, bb):
        return sharded_fused_graph_conv(rids, cids, v, nnz, xx, ww, bb,
                                        mesh=mesh)

    ref, got = loc(*args), sh(*args)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    gl = jax.grad(lambda *a: jnp.sum(jnp.tanh(loc(*a))),
                  argnums=(0, 1, 2, 3))(*args)
    gs = jax.grad(lambda *a: jnp.sum(jnp.tanh(sh(*a))),
                  argnums=(0, 1, 2, 3))(*args)
    for name, a1, a2 in zip(("dvals", "dx", "dw", "db"), gl, gs):
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                                   rtol=2e-5, atol=2e-5, err_msg=name)
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


# ---- in-process, shape-only checks -----------------------------------------

def test_workload_shard_view():
    from repro.autotune import Workload

    w = Workload(batch=13, m_pad=56, nnz_pad=256, k_pad=4, n_b=64)
    assert w.shard(8).batch == 2          # ceil(13 / 8)
    assert w.shard(1) == w
    assert w.shard(8).m_pad == w.m_pad and w.shard(8).n_b == w.n_b


def test_pad_batch_zero_nnz_and_slice():
    import numpy as np

    import jax.numpy as jnp
    from repro.core.formats import random_batch
    from repro.distributed.spmm import pad_batch

    rng = np.random.default_rng(0)
    a, m_pad = random_batch(rng, batch=5, dim=8, nnz_per_row=2)
    b = jnp.ones((5, m_pad, 4), jnp.float32)
    a2, b2, pad = pad_batch(a, b, 4)
    assert pad == 3 and b2.shape[0] == 8 and a2.values.shape[0] == 8
    assert float(jnp.sum(a2.values[5:])) == 0.0
    assert int(jnp.sum(a2.nnz[5:])) == 0
    a3, b3, pad3 = pad_batch(a, b, 5)
    assert pad3 == 0 and b3 is b
