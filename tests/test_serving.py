"""Serving engine: wave batching, per-request lengths, determinism."""
import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serving import ServeEngine
from repro.serving.engine import Request


def _engine(temperature=0.0):
    cfg = configs.get("llama3-8b").reduced()
    params = lm.init_params(jax.random.key(0), cfg)
    return ServeEngine(params, cfg, batch=3, max_len=48,
                       temperature=temperature), cfg


def test_serves_all_requests_exact_lengths():
    engine, _ = _engine()
    reqs = [Request(prompt=[1 + i, 5], max_new_tokens=3 + i)
            for i in range(7)]          # 3 waves of ≤3 slots
    engine.run(reqs)
    assert all(r.done for r in reqs)
    for i, r in enumerate(reqs):
        assert len(r.out) == 3 + i, (i, r.out)


def test_greedy_decode_is_deterministic_and_batch_invariant():
    engine, cfg = _engine()
    r1 = Request(prompt=[3, 7, 11], max_new_tokens=6)
    engine.run([r1])
    # same request again inside a full wave with different neighbours
    r2 = Request(prompt=[3, 7, 11], max_new_tokens=6)
    others = [Request(prompt=[9, 2, 4], max_new_tokens=6) for _ in range(2)]
    engine.run([r2] + others)
    assert r1.out == r2.out, (r1.out, r2.out)


def test_greedy_matches_forward_argmax():
    """First sampled token == argmax of the full-sequence forward logits."""
    import jax.numpy as jnp

    engine, cfg = _engine()
    prompt = [2, 9, 14]
    r = Request(prompt=list(prompt), max_new_tokens=1)
    engine.run([r])
    logits, _ = lm.forward(engine.params, cfg,
                           {"tokens": jnp.asarray([prompt])})
    want = int(np.argmax(np.asarray(logits[0, -1], np.float32)))
    assert r.out[0] == want
