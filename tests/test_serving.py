"""Serving engine: wave batching, per-request lengths, determinism."""
import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serving import ServeEngine
from repro.serving.engine import Request


def _engine(temperature=0.0):
    cfg = configs.get("llama3-8b").reduced()
    params = lm.init_params(jax.random.key(0), cfg)
    return ServeEngine(params, cfg, batch=3, max_len=48,
                       temperature=temperature), cfg


def test_serves_all_requests_exact_lengths():
    engine, _ = _engine()
    reqs = [Request(prompt=[1 + i, 5], max_new_tokens=3 + i)
            for i in range(7)]          # 3 waves of ≤3 slots
    engine.run(reqs)
    assert all(r.done for r in reqs)
    for i, r in enumerate(reqs):
        assert len(r.out) == 3 + i, (i, r.out)


def test_greedy_decode_is_deterministic_and_batch_invariant():
    engine, cfg = _engine()
    r1 = Request(prompt=[3, 7, 11], max_new_tokens=6)
    engine.run([r1])
    # same request again inside a full wave with different neighbours
    r2 = Request(prompt=[3, 7, 11], max_new_tokens=6)
    others = [Request(prompt=[9, 2, 4], max_new_tokens=6) for _ in range(2)]
    engine.run([r2] + others)
    assert r1.out == r2.out, (r1.out, r2.out)


def test_truncated_requests_reported_not_done():
    """A request whose budget cannot fit in the engine's max_len window is
    reported truncated, not silently marked done."""
    engine, _ = _engine()            # max_len=48
    long = Request(prompt=[1, 2], max_new_tokens=500)
    short = Request(prompt=[3, 4], max_new_tokens=4)
    engine.run([long, short])
    assert short.done and not short.truncated
    assert len(short.out) == 4
    assert long.truncated and not long.done
    # generation ran to the window edge, then stopped honestly
    assert 4 < len(long.out) < 500
    assert len(long.out) <= engine.max_len


def test_zero_budget_request_gets_no_tokens():
    engine, _ = _engine()
    zero = Request(prompt=[1, 2], max_new_tokens=0)
    other = Request(prompt=[3, 4], max_new_tokens=3)
    engine.run([zero, other])
    assert zero.done and not zero.truncated and zero.out == []
    assert other.done and len(other.out) == 3


def test_greedy_matches_forward_argmax():
    """First sampled token == argmax of the full-sequence forward logits."""
    import jax.numpy as jnp

    engine, cfg = _engine()
    prompt = [2, 9, 14]
    r = Request(prompt=list(prompt), max_new_tokens=1)
    engine.run([r])
    logits, _ = lm.forward(engine.params, cfg,
                           {"tokens": jnp.asarray([prompt])})
    want = int(np.argmax(np.asarray(logits[0, -1], np.float32)))
    assert r.out[0] == want
