"""Shared tolerance-aware oracle harness (DESIGN.md §10).

One parametrized matrix covers EVERY concrete impl in the registry —
full-precision and reduced-precision variants alike — against the
``impl="ref"`` f32 oracle, forward AND both grads, on the three acceptance
regimes (uniform, skewed, zero-nnz). The per-policy tolerance table encodes
the accumulation contract: every kernel accumulates in f32, so the error
budget is the *storage* rounding of the policy (bf16 mantissa, i8
quantization step), not a compounding accumulation error.

Not ``test_``-prefixed on purpose: this is a library the test modules
(test_kernels.py, test_fused_graph_conv.py) parametrize over, importable
because pytest puts ``tests/`` on sys.path via conftest.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune import GSPMM_IMPLS, precision_of
from repro.core import coo_from_lists, max_row_degree, random_batch
from repro.core.graph_conv import graph_conv_batched, init_graph_conv
from repro.core.spmm import (
    GSPMM_OPS,
    GSPMM_REDUCES,
    IMPLS,
    batched_gspmm,
    batched_spmm,
)
from repro.kernels import ref

CASES = ("uniform", "skewed", "zero_nnz")

# storage policy → (atol, rtol) against the f32 ref oracle. f32 impls are
# bit-compatible reorderings of the same f32 math (tiny atol covers the
# reduction-order slack); bf16 pays one 8-bit-mantissa rounding per stored
# value/feature; i8 pays half a quantization step (maxabs/254) per value,
# amplified by the row degree of the test batches.
TOLS = {
    "f32": (1e-4, 1e-5),
    "bf16": (8e-2, 2e-2),
    "i8": (0.25, 2e-2),
}

# Every concrete SpMM-shaped impl: not the resolver ("auto"), not the
# layer-op class ("fused"/"fused_bf16"/"fused_hybrid" — exercised by
# layer_cases below).
CONCRETE_SPMM_IMPLS = tuple(
    i for i in IMPLS if i != "auto"
    and not precision_of(i)[0].startswith("fused"))

LAYER_IMPLS = tuple(
    i for i in IMPLS if precision_of(i)[0].startswith("fused"))


def spmm_cases():
    """(name, coo, m_pad, b, k_pad) for the three acceptance regimes.

    Values are drawn from N(0, 1) — NOT the unit adjacency values of the
    dataset generator — so the i8 quantizer has a real dynamic range to
    compress (unit values would make quantization exact and the i8 leg of
    the matrix vacuous).
    """
    rng = np.random.default_rng(11)
    cases = []
    # uniform: every row the same degree
    coo, m_pad = random_batch(rng, batch=4, dim=24, nnz_per_row=3)
    coo = dataclasses.replace(
        coo, values=jnp.asarray(
            np.where(np.asarray(coo.values) != 0.0,
                     rng.normal(size=coo.values.shape), 0.0), jnp.float32))
    cases.append(("uniform", coo, m_pad))
    # skewed: one heavy sample among light ones, plus an all-zero sample
    heavy_r = np.repeat(np.arange(4, dtype=np.int32), 8)        # degree 8
    heavy_c = np.asarray(rng.integers(0, 24, heavy_r.size), np.int32)
    light_r = np.asarray([0, 5], np.int32)
    light_c = np.asarray([1, 2], np.int32)
    empty = (np.zeros(0, np.int32), np.zeros(0, np.int32),
             np.zeros(0, np.float32))
    coo = coo_from_lists(
        [(heavy_r, heavy_c,
          rng.normal(size=heavy_r.size).astype(np.float32)),
         (light_r, light_c, rng.normal(size=2).astype(np.float32)), empty],
        [24, 24, 24])
    cases.append(("skewed", coo, 24))
    # zero-nnz: every sample empty (padding-wave shape)
    coo = coo_from_lists([empty, empty], [16, 16])
    cases.append(("zero_nnz", coo, 16))
    out = []
    for name, coo, m_pad in cases:
        b = jnp.asarray(
            np.random.default_rng(12).normal(size=(coo.batch, m_pad, 48)),
            jnp.float32)
        k_pad = max(1, int(np.asarray(max_row_degree(coo, m_pad)).max()))
        out.append((name, coo, m_pad, b, k_pad))
    return out


def tols_for(impl: str) -> tuple[float, float]:
    return TOLS[precision_of(impl)[1]]


def check_spmm_forward(impl: str) -> None:
    """Forward sweep: ``impl`` vs the f32 ref oracle on every case, at the
    impl's policy tolerance. The output dtype contract is also asserted:
    every impl returns in B's dtype regardless of internal storage."""
    atol, rtol = tols_for(impl)
    for name, coo, m_pad, b, k_pad in spmm_cases():
        want = np.asarray(batched_spmm(coo, b, impl="ref"))
        got_j = batched_spmm(coo, b, impl=impl, k_pad=k_pad)
        assert got_j.dtype == b.dtype, f"{impl} output dtype on {name}"
        np.testing.assert_allclose(np.asarray(got_j), want, atol=atol,
                                   rtol=rtol, err_msg=f"{impl} on {name}")


def check_spmm_grads(impl: str) -> None:
    """Both grads (d/dvalues, d/dB) of a tanh-sum loss vs the ref oracle.
    Reduced-precision variants accumulate their backward in f32 too, so the
    same per-policy tolerance applies."""
    atol, rtol = tols_for(impl)
    for name, coo, m_pad, b, k_pad in spmm_cases():
        def loss(values, bb, impl=impl, coo=coo, k_pad=k_pad):
            c = batched_spmm(dataclasses.replace(coo, values=values), bb,
                             impl=impl, k_pad=k_pad)
            return jnp.sum(jnp.tanh(c))

        def loss_ref(values, bb, coo=coo):
            c = batched_spmm(dataclasses.replace(coo, values=values), bb,
                             impl="ref")
            return jnp.sum(jnp.tanh(c))

        g = jax.grad(loss, argnums=(0, 1))(coo.values, b)
        g_ref = jax.grad(loss_ref, argnums=(0, 1))(coo.values, b)
        np.testing.assert_allclose(
            np.asarray(g[0]), np.asarray(g_ref[0]), atol=atol, rtol=rtol,
            err_msg=f"{impl} dvalues on {name}")
        np.testing.assert_allclose(
            np.asarray(g[1]), np.asarray(g_ref[1]), atol=atol, rtol=rtol,
            err_msg=f"{impl} db on {name}")


# ---------------------------------------------------------------------------
# Layer-class impls (the fused megakernel and its variants): the same three
# regimes expressed as graph-conv layer inputs.
# ---------------------------------------------------------------------------

def layer_cases(channels: int = 2, n_in: int = 12, n_out: int = 24):
    """(name, params, adj, x) per acceptance regime for the fused class."""
    out = []
    for name, coo, m_pad, _, _ in spmm_cases():
        rng = np.random.default_rng(13)
        adj = [coo]
        for ch in range(1, channels):
            perm = rng.permutation(coo.values.shape[1])
            adj.append(dataclasses.replace(
                coo, values=coo.values[:, perm], row_ids=coo.row_ids[:, perm],
                col_ids=coo.col_ids[:, perm]))
        x = jnp.asarray(rng.normal(size=(coo.batch, m_pad, n_in)),
                        jnp.float32)
        params = init_graph_conv(jax.random.key(13), n_in, n_out, channels)
        out.append((name, params, adj, x))
    return out


def check_layer_forward(impl: str) -> None:
    atol, rtol = tols_for(impl)
    for name, params, adj, x in layer_cases():
        want = np.asarray(graph_conv_batched(params, adj, x, impl="ref"))
        got_j = graph_conv_batched(params, adj, x, impl=impl)
        assert got_j.dtype == x.dtype, f"{impl} output dtype on {name}"
        np.testing.assert_allclose(np.asarray(got_j), want, atol=atol,
                                   rtol=rtol, err_msg=f"{impl} on {name}")


# ---------------------------------------------------------------------------
# g-SpMM: the full (op × reduce × edge-kind) message-passing matrix
# (DESIGN.md §11) on the same three acceptance regimes. The autodiff grads
# of the pure-jnp ``ref.batched_gspmm_ref`` are the ground truth for every
# corner — including max-reduce tie-splitting and the zero-degree identity.
# ---------------------------------------------------------------------------

GSPMM_EDGE_KINDS = ("scalar", "vector")

GSPMM_MATRIX = tuple(
    (op, red) for op in GSPMM_OPS for red in GSPMM_REDUCES)


def gspmm_cases(edges: str = "scalar", n_b: int = 48):
    """:func:`spmm_cases` geometry, optionally with ``(batch, nnz_pad,
    n_b)`` per-edge feature vectors instead of scalar values. Padded slots
    keep the 0.0 values the dataset formats guarantee (§IV-C) — the
    ``(mul, sum, scalar)`` corner delegates to plain batched SpMM, which
    RELIES on that invariant instead of masking."""
    out = []
    for name, coo, m_pad, b, k_pad in spmm_cases():
        if edges == "vector":
            rng = np.random.default_rng(14)
            vv = rng.normal(
                size=coo.values.shape + (n_b,)).astype(np.float32)
            vv = np.where(gspmm_valid_mask(coo)[..., None], vv, 0.0)
            coo = dataclasses.replace(coo, values=jnp.asarray(vv))
        out.append((name, coo, m_pad, b, k_pad))
    return out


def gspmm_valid_mask(coo) -> np.ndarray:
    """(batch, nnz_pad) bool — True at real edges, False at padding."""
    return (np.arange(coo.row_ids.shape[1])[None, :]
            < np.asarray(coo.nnz)[:, None])


def check_gspmm_forward(impl: str, op: str, reduce: str, edges: str) -> None:
    """One (impl, op, reduce, edge-kind) corner, forward, vs the pure-jnp
    oracle on every acceptance regime. All g-SpMM impls are f32."""
    atol, rtol = TOLS["f32"]
    for name, coo, m_pad, b, k_pad in gspmm_cases(edges):
        want = np.asarray(ref.batched_gspmm_ref(coo, b, m_pad, op=op,
                                                reduce=reduce))
        got = batched_gspmm(coo, b, op=op, reduce=reduce, impl=impl,
                            k_pad=k_pad)
        assert got.dtype == b.dtype, \
            f"{impl} ({op},{reduce},{edges}) dtype on {name}"
        np.testing.assert_allclose(
            np.asarray(got), want, atol=atol, rtol=rtol,
            err_msg=f"{impl} ({op},{reduce},{edges}) on {name}")


def check_gspmm_grads(impl: str, op: str, reduce: str, edges: str) -> None:
    """Both grads of a tanh-sum loss vs JAX autodiff of the pure-jnp oracle.

    dValues is compared at VALID slots only: the delegated ``(mul, sum,
    scalar)`` corner inherits batched_spmm's legacy VJP, which reports
    unmasked cotangents at padded slots — harmless (padded values are
    pinned 0.0 and never trained) but not bitwise-zero there."""
    atol, rtol = TOLS["f32"]
    for name, coo, m_pad, b, k_pad in gspmm_cases(edges):
        def loss(values, bb, coo=coo, k_pad=k_pad):
            c = batched_gspmm(dataclasses.replace(coo, values=values), bb,
                              op=op, reduce=reduce, impl=impl, k_pad=k_pad)
            return jnp.sum(jnp.tanh(c))

        def loss_ref(values, bb, coo=coo, m_pad=m_pad):
            c = ref.batched_gspmm_ref(
                dataclasses.replace(coo, values=values), bb, m_pad,
                op=op, reduce=reduce)
            return jnp.sum(jnp.tanh(c))

        g = jax.grad(loss, argnums=(0, 1))(coo.values, b)
        g_ref = jax.grad(loss_ref, argnums=(0, 1))(coo.values, b)
        vm = gspmm_valid_mask(coo).astype(np.float32)
        if np.asarray(g[0]).ndim == 3:
            vm = vm[..., None]
        np.testing.assert_allclose(
            np.asarray(g[0]) * vm, np.asarray(g_ref[0]) * vm, atol=atol,
            rtol=rtol, err_msg=f"{impl} ({op},{reduce},{edges}) dval {name}")
        np.testing.assert_allclose(
            np.asarray(g[1]), np.asarray(g_ref[1]), atol=atol, rtol=rtol,
            err_msg=f"{impl} ({op},{reduce},{edges}) db on {name}")


def check_layer_grads(impl: str) -> None:
    # dW/dX contract over the whole (batch · m_pad) extent, so the storage
    # rounding of a reduced policy is amplified by the reduction width —
    # unlike the per-element SpMM grads. 3x the per-policy budget covers the
    # sqrt(batch·m_pad) growth of the test geometries.
    atol, rtol = (t * 3 for t in tols_for(impl))
    for name, params, adj, x in layer_cases():
        def loss(vals_list, xx, ww, bb, impl=impl, adj=adj):
            aa = [a.with_values(v) for a, v in zip(adj, vals_list)]
            y = graph_conv_batched({"w": ww, "b": bb}, aa, xx, impl=impl)
            return jnp.sum(jnp.tanh(y))

        args = ([a.values for a in adj], x, params["w"], params["b"])
        g = jax.grad(loss, argnums=(0, 1, 2, 3))(*args)
        g_ref = jax.grad(
            lambda *a: loss(*a, impl="ref"), argnums=(0, 1, 2, 3))(*args)
        for leaf, (gg, gr) in enumerate(zip(jax.tree.leaves(g),
                                            jax.tree.leaves(g_ref))):
            np.testing.assert_allclose(
                np.asarray(gg), np.asarray(gr), atol=atol, rtol=rtol,
                err_msg=f"{impl} grad leaf {leaf} on {name}")
