"""Distribution tests in an 8-device subprocess (the main test process keeps
1 CPU device; XLA locks the device count at first init)."""
import os
import subprocess
import sys

import numpy as np

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, n_dev: int = 8, timeout: int = 600):
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}",
           "JAX_PLATFORMS": "cpu"}
    return subprocess.run([sys.executable, "-c", script, SRC],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


_HEADER = r"""
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch.mesh import make_mesh
from repro.distributed.steps import build_train_step
from repro.distributed.compression import ef_init
from repro.models import lm
from repro.optim import AdamConfig, adam_init
cfg = configs.get("llama3-8b").reduced()
def data(k, b=8, t=16):
    return {"tokens": jax.random.randint(jax.random.key(k), (b, t), 0,
                                         cfg.vocab)}
def make_state():
    params = lm.init_params(jax.random.key(0), cfg)
    return params, adam_init(params)
"""


def test_dp_tp_matches_single_device():
    """Loss trajectory on a 2×4 (data×model) mesh == single-device: sharding
    must not change numerics."""
    script = _HEADER + r"""
losses = {}
for shape in [(1, 1), (2, 4)]:
    mesh = make_mesh(shape, ("data", "model"))
    builder, _, _ = build_train_step(cfg, mesh, AdamConfig(lr=1e-2),
                                     microbatches=2, remat=False,
                                     zero1=True, donate=False)
    params, opt = make_state()
    batch = data(1)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          batch)
    with mesh:
        step = builder(shapes)
        ls = []
        for i in range(6):
            # repeat one batch: loss decrease is then deterministic
            params, opt, m = step(params, opt, data(0))
            ls.append(float(m["loss"]))
    losses[shape] = ls
print("L11", losses[(1, 1)])
print("L24", losses[(2, 4)])
diff = max(abs(a - b) for a, b in zip(losses[(1, 1)], losses[(2, 4)]))
print("MAXDIFF", diff)
assert diff < 2e-2, diff
assert losses[(1, 1)][-1] < losses[(1, 1)][0] - 0.5
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


def test_zero1_shards_optimizer_state():
    script = _HEADER + r"""
from repro.distributed import sharding
mesh = make_mesh((4, 2), ("data", "model"))
p_shape = jax.eval_shape(lambda: lm.init_params(jax.random.key(0), cfg))
p_specs = sharding.param_specs(p_shape, mesh)
z_specs = sharding.zero1_specs(p_specs, p_shape, mesh)
n_extra = sum(
    1 for a, b in zip(jax.tree.leaves(p_specs), jax.tree.leaves(z_specs))
    if a != b)
assert n_extra > 0, "ZeRO-1 sharded nothing"
# every zero1 spec stays valid (divisible)
sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
for spec, leaf in zip(jax.tree.leaves(z_specs), jax.tree.leaves(p_shape)):
    for dim, part in zip(leaf.shape, tuple(spec) + (None,) * 8):
        if part is not None:
            axes = part if isinstance(part, tuple) else (part,)
            k = int(np.prod([sizes[a] for a in axes]))
            assert dim % k == 0, (spec, leaf.shape)
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


def test_gradient_compression_converges():
    """int8 EF compression: training still converges and parameters stay
    close to the uncompressed run."""
    script = _HEADER + r"""
mesh = make_mesh((2, 1), ("data", "model"))
results = {}
for compress in (False, True):
    builder, _, _ = build_train_step(cfg, mesh, AdamConfig(lr=1e-2),
                                     microbatches=1, remat=False,
                                     compress_grads=compress, donate=False)
    params, opt = make_state()
    if compress:
        opt["ef_err"] = ef_init(params)
    batch = data(0)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          batch)
    with mesh:
        step = builder(shapes)
        for i in range(6):
            params, opt, m = step(params, opt, data(i))
        results[compress] = float(m["loss"])
print("LOSSES", results)
assert results[True] < 6.0          # still learning (init ~ ln(256)=5.5)
assert abs(results[True] - results[False]) < 0.3
print("PASS")
"""
    r = _run(script)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr


def test_elastic_restart_smaller_mesh(tmp_path):
    """Checkpoint on an 8-device mesh, restore + continue on 4 devices: the
    sharding rules are mesh-parametric, so re-lowering just works."""
    common = _HEADER + r"""
from repro.checkpoint import save_pytree, load_pytree
import os
ckdir = sys.argv[2]
shape = tuple(int(x) for x in sys.argv[3].split(","))
mesh = make_mesh(shape, ("data", "model"))
builder, _, _ = build_train_step(cfg, mesh, AdamConfig(lr=1e-2),
                                 microbatches=1, remat=False, donate=False)
params, opt = make_state()
if os.path.exists(os.path.join(ckdir, "manifest.json")):
    params, opt = load_pytree((params, opt), ckdir)
    print("RESTORED")
batch = data(0)
shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
with mesh:
    step = builder(shapes)
    for i in range(3):
        params, opt, m = step(params, opt, data(i))
save_pytree((params, opt), ckdir)
print("LOSS", float(m["loss"]))
print("PASS")
"""
    env = {**os.environ, "JAX_PLATFORMS": "cpu"}
    ck = str(tmp_path / "ck")

    def run(n_dev, shape):
        e = {**env,
             "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_dev}"}
        return subprocess.run(
            [sys.executable, "-c", common, SRC, ck, shape],
            capture_output=True, text=True, env=e, timeout=600)

    r1 = run(8, "2,4")
    assert "PASS" in r1.stdout, r1.stdout + r1.stderr
    r2 = run(4, "2,2")     # shrink the fleet; resume from the 8-dev ckpt
    assert "RESTORED" in r2.stdout and "PASS" in r2.stdout, \
        r2.stdout + r2.stderr
    loss2 = float(r2.stdout.split("LOSS")[1].split()[0])
    assert loss2 < 5.7      # continued training, not re-init


def test_pipeline_parallel_matches_single_device():
    """GPipe pipeline over 4 stages: loss (and its gradient) match the
    non-pipelined reference loss on identical params/batch."""
    script = _HEADER + r"""
import dataclasses
from repro.distributed.pipeline import build_pp_loss, pipeline_bubble_fraction
cfg0 = configs.get("llama3-8b").reduced()
cfg2 = dataclasses.replace(cfg0, n_layers=4, dtype="float32")
mesh = make_mesh((4,), ("pipe",))
params = lm.init_params(jax.random.key(0), cfg2)
mb, b, t = 4, 2, 16
tokens = jax.random.randint(jax.random.key(1), (mb * b, t), 0, cfg2.vocab)
pp_loss = build_pp_loss(cfg2, mesh, microbatches=mb)
with mesh:
    lp = float(jax.jit(pp_loss)(params, {"tokens": tokens}))
# reference: plain loss over the same tokens (aux-free: dense arch)
lr = float(lm.loss_fn(params, cfg2, {"tokens": tokens})[0])
print("PP", lp, "REF", lr)
assert abs(lp - lr) < 2e-3, (lp, lr)
# gradients flow through the pipeline (ppermute VJP)
with mesh:
    g = jax.jit(jax.grad(lambda p: pp_loss(p, {"tokens": tokens})))(params)
gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
assert gn > 0
assert pipeline_bubble_fraction(4, 4) == 3 / 7
print("PASS")
"""
    r = _run(script, n_dev=4)
    assert "PASS" in r.stdout, r.stdout + "\n" + r.stderr
