"""Fault tolerance: atomic checkpoints, integrity, resume-after-kill."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_save_load_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": [jnp.ones((3, 3)), jnp.zeros(())]}
    save_pytree(tree, str(tmp_path / "ck"))
    back = load_pytree(tree, str(tmp_path / "ck"))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_integrity_check_fails_on_corruption(tmp_path):
    tree = {"a": jnp.arange(100.0)}
    save_pytree(tree, str(tmp_path / "ck"))
    npz = tmp_path / "ck" / "shard-0.npz"
    data = npz.read_bytes()
    npz.write_bytes(data[:-20] + b"x" * 20)
    with pytest.raises(IOError, match="integrity"):
        load_pytree(tree, str(tmp_path / "ck"))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones(4)}
    for s in (10, 20, 30, 40):
        mgr.save(s, tree)
    assert mgr.steps() == [30, 40]
    assert mgr.latest_step() == 40


_RESUME_SCRIPT = r"""
import os, sys, json
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from repro import configs
from repro.training import Trainer, TrainerConfig
from repro.optim import AdamConfig
from repro.launch.mesh import make_mesh
from repro.launch import specs

cfg = configs.get("llama3-8b").reduced()
mesh = make_mesh((1, 1), ("data", "model"))
total = int(sys.argv[3])
tcfg = TrainerConfig(total_steps=total, checkpoint_every=5, log_every=5,
                     checkpoint_dir=sys.argv[2], zero1=False)
tr = Trainer(cfg, mesh, AdamConfig(lr=1e-3), tcfg)

def data():
    k = jax.random.key(0)
    while True:
        k, sub = jax.random.split(k)
        yield {"tokens": jax.random.randint(sub, (2, 16), 0, cfg.vocab)}

params, _ = tr.fit(data())
print("FINAL_STEP", tr.manager.latest_step())
"""


def test_gcn_resume_after_interruption(tmp_path):
    """Regression (ISSUE 5): ``GCNTrainer.fit`` used to call ``init_state()``
    unconditionally — checkpoints written by ``manager.save`` were never
    restored and the step counter restarted at 0, silently overwriting the
    saved trajectory. Save → kill (fresh trainer == fresh process: only the
    checkpoint dir survives) → resume."""
    from repro.core.gcn import GCNConfig
    from repro.data.graphs import GraphDatasetSpec, batches, generate
    from repro.training import GCNTrainer, TrainerConfig

    spec = GraphDatasetSpec.tox21_like(n_samples=8)
    ck = str(tmp_path / "gcn_ck")
    cfg = GCNConfig.tox21()
    tcfg = TrainerConfig(checkpoint_dir=ck, checkpoint_every=1)
    batches_a = list(batches(generate(spec), spec, 4, seed=0))   # 2 steps
    t1 = GCNTrainer(cfg, tcfg=tcfg)
    p1, _, _ = t1.fit(batches_a, epochs=1)
    assert t1.manager.latest_step() == 2

    # restore_or_init resumes the saved params AND the step counter
    t2 = GCNTrainer(cfg, tcfg=tcfg)
    p2, _, start = t2.restore_or_init()
    assert start == 2
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    # resume over DIFFERENT data with the same step budget: every batch is
    # already trained, so fit fast-forwards and returns the restored params
    # untouched. Pre-fix this re-inits, trains the new data from step 0 and
    # overwrites the saved checkpoints — the params would differ.
    spec_b = GraphDatasetSpec.tox21_like(n_samples=8, seed=1)
    batches_b = list(batches(generate(spec_b), spec_b, 4, seed=1))
    t3 = GCNTrainer(cfg, tcfg=tcfg)
    p3, _, _ = t3.fit(batches_b, epochs=1)
    assert t3.manager.latest_step() == 2
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    # a longer budget continues training past the restored step
    t4 = GCNTrainer(cfg, tcfg=tcfg)
    t4.fit(batches_b, epochs=2)          # 4 batches: skip 2, train 2
    assert t4.manager.latest_step() == 4
    assert t4.restore_or_init()[2] == 4


def test_gcn_trainer_rejects_undersized_k_pad(tmp_path):
    """ELL silent-drop guard at the trainer's concrete boundary (ISSUE 5):
    a cfg.k_pad smaller than the data's true max row degree must fail fast
    instead of letting a jitted ELL path silently zero edges."""
    from repro.core.gcn import GCNConfig
    from repro.data.graphs import GraphDatasetSpec, batches, generate
    from repro.training import GCNTrainer, TrainerConfig

    spec = GraphDatasetSpec.tox21_like(n_samples=8)
    bs = list(batches(generate(spec), spec, 4, seed=0))
    # pinned ELL impl: generated molecules reach degree > 1, so k_pad=1
    # WOULD silently corrupt — the guard must fire before the jitted step
    cfg = GCNConfig.tox21(k_pad=1, impl="ell")
    trainer = GCNTrainer(cfg, tcfg=TrainerConfig(
        checkpoint_dir=str(tmp_path), checkpoint_every=1000))
    with pytest.raises(ValueError, match="max row degree"):
        trainer.fit(bs, epochs=1)


def test_resume_after_interruption(tmp_path):
    """Train 10 steps (checkpoint at 5, 10); then a second process resumes
    from step 10 and continues to 15 — restart-after-kill path."""
    env = {**os.environ, "JAX_PLATFORMS": "cpu"}
    ckdir = str(tmp_path / "ck")

    def run(total):
        return subprocess.run(
            [sys.executable, "-c", _RESUME_SCRIPT, SRC, ckdir, str(total)],
            capture_output=True, text=True, env=env, timeout=300)

    r1 = run(10)
    assert "FINAL_STEP 10" in r1.stdout, r1.stdout + r1.stderr
    r2 = run(15)
    assert "FINAL_STEP 15" in r2.stdout, r2.stdout + r2.stderr
    # metrics log shows a contiguous, resumed history
    steps = [json.loads(line)["step"]
             for line in open(os.path.join(ckdir, "metrics.jsonl"))]
    assert 10 in steps and 15 in steps
    # resumed run must not restart from 0: 5 only appears once
    assert steps.count(5) == 1
