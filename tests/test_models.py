"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import specs
from repro.models import lm


@pytest.fixture(scope="module")
def rng_key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step(arch, rng_key):
    """One forward/backward on a reduced same-family config: shapes + finite."""
    cfg = configs.get(arch).reduced()
    params = lm.init_params(rng_key, cfg)
    batch = specs.make_train_batch(cfg, 2, 32, concrete=True)
    batch["tokens"] = jax.random.randint(
        jax.random.key(1), batch["tokens"].shape, 0, cfg.vocab)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    assert sum(float(jnp.sum(jnp.abs(g))) for g in leaves) > 0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_shapes(arch, rng_key):
    cfg = configs.get(arch).reduced()
    params = lm.init_params(rng_key, cfg)
    batch = specs.make_train_batch(cfg, 2, 32, concrete=True)
    logits, _ = lm.forward(params, cfg, batch)
    t = batch["tokens"].shape[1]
    assert logits.shape == (2, t, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_decode_step(arch, rng_key):
    cfg = configs.get(arch).reduced()
    params = lm.init_params(rng_key, cfg)
    tokens, caches, pos = specs.make_decode_inputs(cfg, 2, 32, concrete=True)
    logits, new_caches = lm.decode_step(params, cfg, tokens, caches, pos)
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure is preserved (required for jit donation)
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("arch", [
    "llama3-8b",        # dense full attention
    "qwen3-14b",        # qk-norm path
    "mixtral-8x22b",    # MoE + sliding window (ring cache)
    "rwkv6-1.6b",       # linear recurrence state
    "zamba2-7b",        # hybrid grouped scan + shared attn
])
def test_decode_matches_forward(arch, rng_key):
    """Step-by-step decode with caches reproduces the full-sequence forward
    logits — the strongest correctness check for cache handling.

    MoE note: capacity_factor is raised so no token is capacity-dropped —
    drops are batch-competition effects that legitimately differ between
    full-sequence forward and one-token decode."""
    from repro import tuning

    cfg = configs.get(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = lm.init_params(rng_key, cfg)
    t = 12
    tokens = jax.random.randint(jax.random.key(7), (2, t), 0, cfg.vocab)
    with tuning.use_flags(capacity_factor=16.0):
        want, _ = lm.forward(params, cfg, {"tokens": tokens})

        caches = lm.init_decode_state(cfg, 2, t)
        step = jax.jit(
            lambda p, tok, c, pos: lm.decode_step(p, cfg, tok, c, pos))
        got = []
        for i in range(t):
            logits, caches = step(params, tokens[:, i:i + 1], caches,
                                  jnp.asarray(i, jnp.int32))
            got.append(np.asarray(logits[:, 0], np.float32))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("mode", ["scatter", "grouped"])
def test_moe_dispatch_modes_agree(mode):
    """Both MoE dispatch strategies compute identical outputs when capacity
    is ample (§Perf iteration: grouped local dispatch)."""
    from repro import tuning
    from repro.models.layers import init_moe, moe_apply

    cfg = dataclasses.replace(configs.get("mixtral-8x22b").reduced(),
                              dtype="float32")
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (3, 8, cfg.d_model))
    with tuning.use_flags(capacity_factor=16.0):
        want, aux_w = moe_apply(p, cfg, x)
        with tuning.use_flags(moe_dispatch=mode):
            got, aux_g = moe_apply(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    np.testing.assert_allclose(float(aux_g), float(aux_w), atol=1e-5)


def test_window_attention_masks_old_tokens():
    """Sliding-window ring cache: token beyond the window has no influence."""
    cfg = configs.get("mixtral-8x22b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32", window=4)
    params = lm.init_params(jax.random.key(0), cfg)
    t = 10
    toks_a = jax.random.randint(jax.random.key(1), (1, t), 0, cfg.vocab)
    toks_b = toks_a.at[:, 0].set((toks_a[:, 0] + 1) % cfg.vocab)

    def decode_all(tokens):
        caches = lm.init_decode_state(cfg, 1, t)
        out = None
        for i in range(t):
            out, caches = lm.decode_step(params, cfg, tokens[:, i:i + 1],
                                         caches, jnp.asarray(i, jnp.int32))
        return np.asarray(out, np.float32)

    # changing token 0 must NOT change the logits at position t-1 > window
    np.testing.assert_allclose(decode_all(toks_a), decode_all(toks_b),
                               atol=1e-5)


def test_long_500k_applicability():
    from repro.configs.base import SHAPE_CELLS
    cell = SHAPE_CELLS["long_500k"]
    runnable = {a for a in configs.ARCHS
                if specs.cell_supported(configs.get(a), cell)[0]}
    assert runnable == {"mixtral-8x22b", "rwkv6-1.6b", "zamba2-7b"}


def test_param_counts_match_published():
    expect = {
        "mixtral-8x22b": 141e9, "llama4-maverick-400b-a17b": 400e9,
        "stablelm-12b": 12e9, "qwen3-14b": 14e9, "llama3-8b": 8e9,
        "yi-34b": 34e9, "rwkv6-1.6b": 1.6e9, "llava-next-34b": 34e9,
        "zamba2-7b": 7e9, "whisper-small": 0.24e9,
    }
    for arch, n in expect.items():
        got = configs.get(arch).param_count()
        assert 0.75 * n <= got <= 1.3 * n, (arch, got, n)


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_mamba_chunked_ssd_matches_scan(chunk):
    """Blocked SSD evaluation (intra-chunk matmuls + carried state) is
    numerically identical to the sequential selective scan."""
    import jax.numpy as jnp
    from repro.models import ssm

    cfg = dataclasses.replace(configs.get("zamba2-7b").reduced(),
                              dtype="float32")
    p = ssm.init_mamba(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model)) * 0.5
    st = ssm.mamba_state_init(cfg, 2)
    y_seq, s_seq = ssm.mamba_apply(p, cfg, x, st, chunk=0)
    y_chk, s_chk = ssm.mamba_apply(p, cfg, x, st, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chk["ssm"]),
                               np.asarray(s_seq["ssm"]), atol=1e-5)
