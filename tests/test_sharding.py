"""Sharding rules: validity (divisibility, no axis reuse) for every arch on
the production mesh geometry — no devices needed, rules only read axis sizes."""
import types

import numpy as np
import jax
import pytest

from repro import configs
from repro.distributed import sharding
from repro.distributed.steps import shaped_params

MESH16 = types.SimpleNamespace(axis_names=("data", "model"),
                               devices=np.empty((16, 16)))
MESH_POD = types.SimpleNamespace(axis_names=("pod", "data", "model"),
                                 devices=np.empty((2, 16, 16)))
SIZES = {"pod": 2, "data": 16, "model": 16}


def _check_specs(p_shape, specs):
    for (path, leaf), spec in zip(
            jax.tree_util.tree_flatten_with_path(p_shape)[0],
            jax.tree.leaves(specs)):
        parts = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        used = []
        for dim, part in zip(leaf.shape, parts):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            k = int(np.prod([SIZES[a] for a in axes]))
            assert dim % k == 0, (path, leaf.shape, spec)
            used.extend(axes)
        assert len(used) == len(set(used)), f"axis reused: {path} {spec}"


@pytest.mark.parametrize("arch", configs.ARCHS)
@pytest.mark.parametrize("mesh", [MESH16, MESH_POD])
def test_param_specs_valid(arch, mesh):
    p_shape = shaped_params(configs.get(arch))
    specs = sharding.param_specs(p_shape, mesh)
    _check_specs(p_shape, specs)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_zero1_and_fsdp_specs_valid(arch):
    p_shape = shaped_params(configs.get(arch))
    specs = sharding.param_specs(p_shape, MESH16)
    fsdp = sharding.zero1_specs(specs, p_shape, MESH16)
    _check_specs(p_shape, fsdp)
    # stacking zero1 on fsdp must not reuse "data" (the wave-5 regression)
    z = sharding.zero1_specs(fsdp, p_shape, MESH16)
    _check_specs(p_shape, z)


def test_moe_expert_rule_e_else_f():
    """mixtral (E=8 < 16) → TP-in-expert on d_ff; llama4 (E=128) → EP."""
    mix = shaped_params(configs.get("mixtral-8x22b"))
    specs = sharding.param_specs(mix, MESH16)
    wg = specs["blocks"]["0_attn_moe"]["moe"]["w_gate"]
    assert tuple(wg) == (None, None, None, "model"), wg     # F-sharded
    ll = shaped_params(configs.get("llama4-maverick-400b-a17b"))
    specs = sharding.param_specs(ll, MESH16)
    wg = specs["blocks"]["1_attn_moe"]["moe"]["w_gate"]
    assert tuple(wg) == (None, "model", None, None), wg     # E-sharded


def test_whisper_vocab_fallback():
    """51,865 vocab is not 16-divisible → embed shards d_model instead."""
    p = shaped_params(configs.get("whisper-small"))
    specs = sharding.param_specs(p, MESH16)
    emb = tuple(specs["embed"])
    assert emb[0] is None and emb[1] == "model", emb


def test_no_replicated_big_leaves():
    """No parameter leaf > 64 MB may end up fully replicated (memory fit)."""
    for arch in configs.ARCHS:
        p_shape = shaped_params(configs.get(arch))
        specs = sharding.param_specs(p_shape, MESH16)
        for (path, leaf), spec in zip(
                jax.tree_util.tree_flatten_with_path(p_shape)[0],
                jax.tree.leaves(specs)):
            if all(x is None for x in tuple(spec)):
                nbytes = int(np.prod(leaf.shape)) * 2
                assert nbytes < 64 * 2**20, (arch, path, leaf.shape)


def test_cache_specs_sequence_parallel():
    import jax.numpy as jnp
    from repro.models import lm

    cfg = configs.get("llama3-8b")
    caches = jax.eval_shape(lambda: lm.init_decode_state(cfg, 128, 32768))
    specs = sharding.cache_specs(caches, MESH16)
    k_spec = tuple(specs["0"]["k"])
    # (n_blocks, B, S, KV, hd): batch→data, S→model
    assert k_spec[1] == ("data",) or k_spec[1] == "data", k_spec
    assert k_spec[2] == "model", k_spec
