"""Tuning flags + best-effort sharding constraints."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import tuning


def test_flags_context_nesting():
    assert tuning.flags().moe_dispatch == "grouped"      # optimized default
    with tuning.use_flags(moe_dispatch="scatter", q_block=64):
        assert tuning.flags().moe_dispatch == "scatter"
        assert tuning.flags().q_block == 64
        with tuning.use_flags(q_block=32):
            assert tuning.flags().q_block == 32
            assert tuning.flags().moe_dispatch == "scatter"
        assert tuning.flags().q_block == 64
    assert tuning.flags().q_block == 1024


def test_parse_tune_args():
    out = tuning.parse_tune_args(
        ["q_block=256", "fsdp=true", "capacity_factor=2.0",
         "moe_dispatch=scatter"])
    assert out == {"q_block": 256, "fsdp": True, "capacity_factor": 2.0,
                   "moe_dispatch": "scatter"}
    with pytest.raises(KeyError):
        tuning.parse_tune_args(["nope=1"])


def test_constrain_is_identity_without_mesh():
    x = jnp.ones((8, 16))
    y = tuning.constrain(x, "data", "model")
    assert y is x


def test_constrain_skips_nondivisible():
    mesh = jax.make_mesh((1,), ("model",))
    with tuning.use_mesh_hint(mesh):
        assert tuning.axis_size("model") == 1
        x = jnp.ones((7, 16))
        y = tuning.constrain(x, "model", None)   # 7 % 1 == 0 → applies
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        # unknown axis names are dropped silently
        z = tuning.constrain(x, ("pod", "data"), None)
        np.testing.assert_array_equal(np.asarray(z), np.asarray(x))
