"""Fused graph-conv megakernel (DESIGN.md §7): oracle equivalence vs the
``impl="ref"`` layer across channel counts, skewed-nnz batches, gradients
through values/X/W/b, the epilogue, skew-aware packing, and the autotuner
registration of ``impl="fused"``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batching, random_batch
from repro.core.batching import CHUNK, chunk_counts, plan_fused_graph_conv
from repro.core.formats import coo_from_lists
from repro.core.graph_conv import (
    graph_conv_batched,
    graph_conv_nonbatched,
    init_graph_conv,
    resolve_graph_conv_impl,
    stack_channels,
)
from repro.kernels.fused_graph_conv import fused_graph_conv, runtime_chunks


def _layer_case(seed, batch, dim, nnz, channels, n_in, n_out):
    rng = np.random.default_rng(seed)
    adj, m_pads = [], []
    for _ in range(channels):
        coo, mp = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)
        adj.append(coo)
        m_pads.append(mp)
    m_pad = max(m_pads)
    x = jnp.asarray(rng.normal(size=(batch, m_pad, n_in)), jnp.float32)
    params = init_graph_conv(jax.random.key(seed), n_in, n_out, channels)
    return params, adj, x


def _skewed_case(seed, channels=3, n_in=24, n_out=48):
    """One giant graph in a batch of tiny ones — the padded-nnz waste case."""
    rng = np.random.default_rng(seed)
    n_nodes = [40, 6, 8, 5]
    adj = []
    for _ in range(channels):
        triples = []
        for n in n_nodes:
            k = (n * 8) if n > 20 else 2        # heavy skew: 320 vs 2 nnz
            r = rng.integers(0, n, k).astype(np.int32)
            c = rng.integers(0, n, k).astype(np.int32)
            triples.append((r, c, rng.normal(size=k).astype(np.float32)))
        adj.append(coo_from_lists(triples, n_nodes))
    m_pad = -(-max(n_nodes) // 8) * 8
    x = jnp.asarray(rng.normal(size=(len(n_nodes), m_pad, n_in)), jnp.float32)
    params = init_graph_conv(jax.random.key(seed), n_in, n_out, channels)
    return params, adj, x


# ---------------------------------------------------------------------------
# Forward: fused == ref oracle across channel counts and shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("channels", [1, 2, 4])
@pytest.mark.parametrize("batch,dim,nnz,n_in,n_out", [
    (4, 24, 2, 16, 32),             # tiny
    (6, (10, 50), (1, 4), 62, 64),  # ChemGCN regime (mixed sizes)
    (2, 48, 3, 30, 260),            # non-multiple-of-128 n_out (panel path)
])
def test_fused_matches_ref(channels, batch, dim, nnz, n_in, n_out):
    params, adj, x = _layer_case(0, batch, dim, nnz, channels, n_in, n_out)
    want = graph_conv_batched(params, adj, x, impl="ref")
    got = graph_conv_batched(params, adj, x, impl="fused")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5 * max(n_in, 16), rtol=1e-5)


def test_fused_matches_ref_on_skewed_batch():
    params, adj, x = _skewed_case(1)
    want = graph_conv_batched(params, adj, x, impl="ref")
    got = graph_conv_batched(params, adj, x, impl="fused")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-5)
    # the skew-aware loop bound really differs per sample
    _, _, _, nnz = stack_channels(adj)
    chunks = np.asarray(runtime_chunks(nnz))
    assert chunks.min() < chunks.max()          # skew is visible to the kernel


def test_fused_zero_nnz_samples_are_inert():
    """§IV-C padding invariant under skew-aware packing: a zero-nnz sample
    runs ZERO chunks and still writes its (zero) output."""
    params, adj, x = _layer_case(2, 4, 16, 2, 2, 8, 16)
    adj = [dataclasses.replace(
        a, values=a.values.at[0].set(0.0), nnz=a.nnz.at[0].set(0))
        for a in adj]
    _, _, _, nnz = stack_channels(adj)
    assert int(runtime_chunks(nnz)[0].sum()) == 0
    want = graph_conv_batched(params, adj, x, impl="ref")
    got = graph_conv_batched(params, adj, x, impl="fused")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# Gradients: jax.grad through values / X / W / b matches the ref layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", ["uniform", "skewed"])
def test_fused_grads_match_ref(case):
    if case == "uniform":
        params, adj, x = _layer_case(3, 4, (10, 30), (1, 4), 2, 12, 24)
    else:
        params, adj, x = _skewed_case(3)
    rids, cids, vals, nnz = stack_channels(adj)

    def loss_fused(vals, x, w, b):
        y = fused_graph_conv(rids, cids, vals, nnz, x, w, b)
        return jnp.sum(jnp.tanh(y))

    def loss_ref(vals, x, w, b):
        adj2 = [dataclasses.replace(a, values=vals[:, ch])
                for ch, a in enumerate(adj)]
        y = graph_conv_batched({"w": w, "b": b}, adj2, x, impl="ref")
        return jnp.sum(jnp.tanh(y))

    args = (vals, x, params["w"], params["b"])
    g_fused = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(*args)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(*args)
    for name, gf, gr in zip(("dvalues", "dx", "dw", "db"), g_fused, g_ref):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gr),
                                   atol=1e-4, rtol=1e-4, err_msg=name)


def test_fused_epilogue_and_residual():
    params, adj, x = _layer_case(4, 3, 20, 2, 2, 10, 20)
    rids, cids, vals, nnz = stack_channels(adj)
    res = jnp.asarray(np.random.default_rng(4).normal(
        size=(x.shape[0], x.shape[1], 20)), jnp.float32)
    base = graph_conv_batched(params, adj, x, impl="ref")
    got = fused_graph_conv(rids, cids, vals, nnz, x, params["w"], params["b"],
                           epilogue="relu", residual=res)
    np.testing.assert_allclose(np.asarray(got),
                               np.maximum(np.asarray(base + res), 0.0),
                               atol=1e-5)
    # residual is differentiable too: d(relu(y+r))/dr == relu mask
    g = jax.grad(lambda r: jnp.sum(fused_graph_conv(
        rids, cids, vals, nnz, x, params["w"], params["b"],
        epilogue="relu", residual=r)))(res)
    np.testing.assert_allclose(np.asarray(g),
                               (np.asarray(base + res) > 0).astype(np.float32),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Shared oracle harness (tests/oracle.py, ISSUE 6): the fused layer class —
# f32 megakernel AND its bf16 variant — over the same uniform/skewed/zero-nnz
# matrix as every SpMM impl, at per-policy tolerance.
# ---------------------------------------------------------------------------

from oracle import LAYER_IMPLS, check_layer_forward, check_layer_grads  # noqa: E402


@pytest.mark.parametrize("impl", LAYER_IMPLS)
def test_layer_matrix_forward_matches_ref(impl):
    check_layer_forward(impl)


@pytest.mark.parametrize("impl", LAYER_IMPLS)
def test_layer_matrix_grads_match_ref(impl):
    check_layer_grads(impl)


def test_fused_bf16_registered_and_ranked():
    """fused_bf16 is a first-class layer candidate: admitted by rank_layer
    under a reduced dtype policy, absent at f32, and resolvable end-to-end
    through graph_conv_batched(impl='auto', precision='bf16')."""
    from repro.autotune import KINDS, Workload, rank_layer

    assert KINDS["fused_bf16"] == KINDS["fused"] == "fused"
    w = Workload(batch=100, m_pad=56, nnz_pad=512, k_pad=8, n_b=64,
                 channels=4, n_in=62, nnz_avg=128, dtype="bf16")
    cands = [i for i, _ in rank_layer(w, allow_pallas=True)]
    assert "fused_bf16" in cands
    wf = dataclasses.replace(w, dtype="f32")
    assert "fused_bf16" not in [i for i, _ in rank_layer(wf,
                                                         allow_pallas=True)]


# ---------------------------------------------------------------------------
# Skew-aware packing plan
# ---------------------------------------------------------------------------

def test_plan_fused_sample_chunks():
    nnz = [5, 300, 129, 0]
    plan = plan_fused_graph_conv(batch=4, m_pad=64, n_in=32, n_out=64,
                                 channels=2, nnz_pad=512,
                                 nnz_per_sample=nnz)
    assert plan.sample_chunks == chunk_counts(nnz) == (1, 3, 2, 0)
    assert plan.max_chunks == 3
    # skew-oblivious bound would be ceil(512/128) = 4 chunks for EVERY sample
    assert all(c <= -(-512 // CHUNK) for c in plan.sample_chunks)
    # per-(sample × channel) rows: the SUM of ceils the channel loop runs
    # (ceils do not commute with the channel sum: [1,1,1,1] → 4, not 1)
    assert chunk_counts([[1, 1, 1, 1], [300, 0, 5, 0]]) == (4, 4)
    per_ch = np.array([[5, 0], [200, 100], [129, 0], [0, 0]])
    assert chunk_counts(per_ch) == (1, 3, 2, 0)
    # runtime (trace-safe) counts agree with the static audit
    rt = np.asarray(runtime_chunks(jnp.asarray(per_ch))).sum(1)
    assert tuple(rt) == chunk_counts(per_ch)


def test_plan_fused_panels_wide_output():
    plan = plan_fused_graph_conv(batch=8, m_pad=2048, n_in=64, n_out=4096,
                                 channels=4, nnz_pad=8192)
    assert plan.case == 2 and plan.p > 1
    assert plan.n_block % batching.LANES == 0
    assert plan.bytes_per_step <= batching.VMEM_TILE_BUDGET * 1.01
    # the X panel + indices are fixed costs the column split cannot shrink:
    # with a huge n_in the plan bottoms out at one-lane-tile panels
    floor = plan_fused_graph_conv(batch=8, m_pad=2048, n_in=2048, n_out=4096,
                                  channels=4, nnz_pad=8192)
    assert floor.n_block == batching.LANES


def test_fused_rejects_case3():
    plan = plan_fused_graph_conv(batch=2, m_pad=10000, n_in=8, n_out=8,
                                 channels=1, nnz_pad=64)
    assert plan.case == 3
    z = jnp.zeros((2, 1, 64), jnp.int32)
    with pytest.raises(ValueError, match="case 3"):
        fused_graph_conv(z, z, z.astype(jnp.float32),
                         jnp.zeros((2, 1), jnp.int32),
                         jnp.zeros((2, 10000, 8), jnp.float32),
                         jnp.zeros((1, 8, 8), jnp.float32),
                         jnp.zeros((1, 8), jnp.float32))


# ---------------------------------------------------------------------------
# Autotuner registration: impl="fused" is selectable
# ---------------------------------------------------------------------------

def test_autotuner_selects_fused_for_gcn_layer():
    from repro.autotune import Workload, rank_layer, select_graph_conv_impl

    # tox21-like layer: nnz_pad is the batch max, the MEAN nnz (skew knob)
    # is what the fused kernel's per-sample loop actually pays
    w = Workload(batch=100, m_pad=56, nnz_pad=512, k_pad=8, n_b=64,
                 channels=4, n_in=62, nnz_avg=128)
    d = select_graph_conv_impl(w, allow_pallas=True)
    assert d.impl == "fused" and d.kind == "fused" and d.source == "model"
    assert ("fused" in {i for i, _ in d.scores})
    # CPU/interpret posture: the Pallas megakernel is never a candidate
    d_cpu = select_graph_conv_impl(w, allow_pallas=False)
    assert d_cpu.impl != "fused"
    assert all(i != "fused" for i, _ in rank_layer(w, allow_pallas=False))


def test_autotuner_fused_skew_awareness_lowers_cost():
    from repro.autotune import Workload, estimate_layer

    dense_w = Workload(batch=64, m_pad=56, nnz_pad=1024, k_pad=8, n_b=64,
                       channels=4, n_in=62)
    skewed = dataclasses.replace(dense_w, nnz_avg=128)   # mean ≪ padded max
    assert estimate_layer(skewed, "fused") < estimate_layer(dense_w, "fused")


def test_fused_workload_key_distinct_and_backcompat():
    from repro.autotune import Workload

    w = Workload(batch=4, m_pad=16, nnz_pad=64, k_pad=4, n_b=8)
    assert w.key() == "b4_m16_nnz64_k4_n8_i4"     # unchanged for plain SpMM
    wl = dataclasses.replace(w, channels=4, n_in=62)
    assert wl.key() != w.key() and "_c4_" in wl.key()


def test_forced_layer_decision_reports_layer_plan():
    """A pinned layer impl must audit the plan the layer actually runs —
    the fused kernel's own plan, not a bare per-channel SpMM plan."""
    from repro.autotune import Workload, forced_decision

    w = Workload(batch=32, m_pad=56, nnz_pad=512, k_pad=8, n_b=64,
                 channels=4, n_in=62)
    d = forced_decision(w, "fused")
    want = plan_fused_graph_conv(batch=32, m_pad=56, n_in=62, n_out=64,
                                 channels=4, nnz_pad=512)
    assert d.plan == want
    # stacked fallback impls audit the (channels·batch) stacked plan
    d_ref = forced_decision(w, "ref")
    assert d_ref.plan.batch == 32 * 4


def test_layer_workload_autotune_measures_the_layer(tmp_path):
    """A channels-aware Workload in the tuning cache must be measured as the
    LAYER it keys (graph_conv_batched per candidate), and the measured
    winner must then drive select_graph_conv_impl."""
    from repro.autotune import (TuningCache, Workload, autotune,
                                select_graph_conv_impl)

    cache = TuningCache(str(tmp_path / "tune.json"))
    w = Workload(batch=4, m_pad=16, nnz_pad=64, k_pad=4, n_b=8,
                 channels=2, n_in=6)
    best = autotune(w, cache=cache, impls=("ref", "dense"), interpret=True)
    assert best in ("ref", "dense")
    assert set(cache.times(w.key())) == {"ref", "dense"}
    d = select_graph_conv_impl(w, allow_pallas=False, cache=cache)
    assert d.source == "cache" and d.impl == best


def test_batched_spmm_rejects_fused():
    from repro.core.spmm import IMPLS, batched_spmm

    assert "fused" in IMPLS
    rng = np.random.default_rng(0)
    coo, m_pad = random_batch(rng, batch=2, dim=8, nnz_per_row=1)
    b = jnp.zeros((2, m_pad, 4), jnp.float32)
    with pytest.raises(ValueError, match="graph_conv"):
        batched_spmm(coo, b, impl="fused")


def test_graph_conv_auto_resolves_layer_workload():
    params, adj, x = _layer_case(5, 4, 20, 2, 2, 12, 16)
    d = resolve_graph_conv_impl(adj, x, 16, interpret=True)
    assert d.impl != "fused"          # interpret posture → no Pallas
    d_tpu = resolve_graph_conv_impl(adj, x, 16, interpret=False)
    assert d_tpu.impl in ("fused", "pallas_coo", "pallas_ell", "pallas_gemm",
                          "ref", "ell", "dense", "loop")
    want = graph_conv_batched(params, adj, x, impl="ref")
    got = graph_conv_batched(params, adj, x, impl="auto")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# Stacked fallback (one (channels·batch) SpMM) and the whole-model path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "dense", "pallas_coo"])
def test_stacked_fallback_matches_nonbatched(impl):
    params, adj, x = _layer_case(6, 5, (8, 30), (1, 3), 3, 14, 28)
    want = graph_conv_nonbatched(params, adj, x)
    got = graph_conv_batched(params, adj, x, impl=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-5, err_msg=impl)


def test_stacked_fallback_mixed_channel_nnz_pad():
    """Channels with different nnz_pad stack fine (zero-padded to the max)."""
    rng = np.random.default_rng(7)
    n_nodes = [10, 12]
    t1 = [(np.array([0, 1], np.int32), np.array([1, 0], np.int32),
           np.ones(2, np.float32)) for _ in n_nodes]
    t2 = [(np.arange(9, dtype=np.int32), np.arange(9, dtype=np.int32),
           np.ones(9, np.float32)) for _ in n_nodes]
    adj = [coo_from_lists(t1, n_nodes), coo_from_lists(t2, n_nodes)]
    assert adj[0].nnz_pad != adj[1].nnz_pad
    m_pad = 16
    x = jnp.asarray(rng.normal(size=(2, m_pad, 6)), jnp.float32)
    params = init_graph_conv(jax.random.key(7), 6, 12, 2)
    want = graph_conv_nonbatched(params, adj, x)
    for impl in ("ref", "fused"):
        got = graph_conv_batched(params, adj, x, impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, err_msg=impl)


def test_gcn_trains_with_fused_impl(tmp_path):
    """End-to-end: GCNTrainer with cfg.impl='fused' — the megakernel's VJP
    carries a real training step."""
    from repro.core.gcn import GCNConfig
    from repro.data.graphs import GraphDatasetSpec, batches, generate
    from repro.training import GCNTrainer, TrainerConfig

    spec = GraphDatasetSpec.tox21_like(n_samples=16)
    data = generate(spec)
    cfg = GCNConfig.tox21(impl="fused")
    trainer = GCNTrainer(cfg, tcfg=TrainerConfig(
        checkpoint_dir=str(tmp_path), checkpoint_every=1000))
    batch = next(iter(batches(data, spec, 8)))
    d = trainer.layer_decision(batch)
    assert d.impl == "fused" and d.source == "forced"
    params, _, metrics = trainer.fit(
        lambda e: batches(data, spec, 8, seed=e), epochs=1)
    assert np.isfinite(metrics["loss"])

    # identical logits as the ref-impl model with identical params
    from repro.core.gcn import apply_gcn
    b = next(iter(batches(data, spec, 8)))
    lf = apply_gcn(params, cfg, b["adj"], b["x"], b["n_nodes"])
    lr = apply_gcn(params, dataclasses.replace(cfg, impl="ref"),
                   b["adj"], b["x"], b["n_nodes"])
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), atol=1e-4)


def test_graph_serve_engine_reports_layer_decision():
    from repro.core.gcn import GCNConfig, init_gcn
    from repro.serving import GraphServeEngine

    cfg = GCNConfig.tox21(impl="fused")
    params = init_gcn(jax.random.key(0), cfg)
    eng = GraphServeEngine(params, cfg, batch=4, m_pad=16, nnz_pad=64)
    d = eng.layer_decision()
    assert d.impl == "fused" and d.source == "forced"


# ---------------------------------------------------------------------------
# default_interpret resolver (REPRO_INTERPRET)
# ---------------------------------------------------------------------------

def test_default_interpret_resolver(monkeypatch):
    from repro.kernels import default_interpret, resolve_interpret

    monkeypatch.delenv("REPRO_INTERPRET", raising=False)
    assert default_interpret() is True          # CPU backend → interpret
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    assert default_interpret() is False
    assert resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_INTERPRET", "true")
    assert default_interpret() is True
    assert resolve_interpret(False) is False    # explicit beats env
    monkeypatch.setenv("REPRO_INTERPRET", "maybe")
    with pytest.raises(ValueError, match="REPRO_INTERPRET"):
        default_interpret()
