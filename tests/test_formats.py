"""Format conversions + hypothesis property tests on SpMM invariants.

``hypothesis`` is an optional dev dependency (see pyproject.toml extras);
the property tests are defined only when it is installed, so tier-1
collection never fails on it and the deterministic tests always run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.core import (
    coo_from_lists,
    coo_to_csr,
    coo_to_dense,
    coo_to_ell,
    random_batch,
)
from repro.core.spmm import batched_spmm
from repro.kernels import ref


def _random_coo(seed, batch, dim, nnz):
    rng = np.random.default_rng(seed)
    return random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)


def test_csr_roundtrip():
    coo, m_pad = _random_coo(0, 5, (5, 30), (1, 4))
    csr = coo_to_csr(coo, m_pad)
    # rpt is monotone, ends at true nnz
    rpt = np.asarray(csr.rpt)
    assert (np.diff(rpt, axis=1) >= 0).all()
    np.testing.assert_array_equal(rpt[:, -1], np.asarray(coo.nnz))
    b = jnp.asarray(np.random.default_rng(1).normal(size=(5, m_pad, 16)),
                    jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.batched_spmm_csr_ref(csr, b)),
        np.asarray(ref.batched_spmm_coo_ref(coo, b, m_pad)), atol=1e-5)


def test_ell_matches_dense():
    coo, m_pad = _random_coo(2, 4, (5, 20), (1, 3))
    ell = coo_to_ell(coo, m_pad, k_pad=8)
    dense_from_ell = np.zeros((4, m_pad, m_pad), np.float32)
    cid = np.asarray(ell.col_ids)
    val = np.asarray(ell.values)
    for b in range(4):
        for r in range(m_pad):
            for k in range(8):
                dense_from_ell[b, r, cid[b, r, k]] += val[b, r, k]
    np.testing.assert_allclose(dense_from_ell,
                               np.asarray(coo_to_dense(coo, m_pad)), atol=0)


# ---------------------------------------------------------------------------
# Property tests (hypothesis) — decorators need hypothesis at definition
# time, so the whole block is conditional on the optional dep.
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @st.composite
    def coo_batches(draw):
        batch = draw(st.integers(1, 5))
        dim_hi = draw(st.integers(4, 40))
        nnz_hi = draw(st.integers(1, 6))
        seed = draw(st.integers(0, 2**16))
        n_b = draw(st.sampled_from([1, 4, 16, 40, 130]))
        coo, m_pad = _random_coo(seed, batch, (3, dim_hi), (1, nnz_hi))
        b = jnp.asarray(
            np.random.default_rng(seed + 1).normal(size=(batch, m_pad, n_b)),
            jnp.float32)
        return coo, m_pad, b

    @settings(max_examples=20, deadline=None)
    @given(coo_batches())
    def test_property_impls_equal_dense(case):
        """∀ batches: every impl == densify+matmul oracle."""
        coo, m_pad, b = case
        want = np.asarray(
            jax.lax.batch_matmul(coo_to_dense(coo, m_pad), b))
        for impl in ("ref", "pallas_coo", "pallas_ell"):
            got = np.asarray(batched_spmm(coo, b, impl=impl, k_pad=8))
            np.testing.assert_allclose(got, want, atol=1e-4, err_msg=impl)

    @settings(max_examples=15, deadline=None)
    @given(coo_batches(), st.floats(-3, 3), st.floats(-3, 3))
    def test_property_linearity(case, alpha, beta):
        """SpMM is linear in B: A(αB₁+βB₂) = αAB₁ + βAB₂."""
        coo, m_pad, b = case
        b2 = b[:, ::-1, :]
        lhs = batched_spmm(coo, alpha * b + beta * b2, impl="ref")
        rhs = (alpha * batched_spmm(coo, b, impl="ref")
               + beta * batched_spmm(coo, b2, impl="ref"))
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   atol=1e-3, rtol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(coo_batches(), st.integers(1, 64))
    def test_property_padding_invariance(case, extra):
        """Adding zero-valued padding slots never changes the product (the
        paper's §IV-C 'redundant threads terminate immediately' invariant)."""
        coo, m_pad, b = case
        pad = lambda x: jnp.pad(x, ((0, 0), (0, extra)))  # noqa: E731
        coo2 = dataclasses.replace(
            coo, row_ids=pad(coo.row_ids), col_ids=pad(coo.col_ids),
            values=pad(coo.values))
        got = batched_spmm(coo2, b, impl="ref")
        want = batched_spmm(coo, b, impl="ref")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)

    @settings(max_examples=10, deadline=None)
    @given(coo_batches())
    def test_property_batch_independence(case):
        """Batching never mixes samples: batched result row b == single-sample
        result for sample b (the core correctness claim of Batched SpMM)."""
        coo, m_pad, b = case
        full = np.asarray(batched_spmm(coo, b, impl="ref"))
        for s in range(min(coo.batch, 3)):
            single = ref.spmm_coo_single(
                coo.row_ids[s], coo.col_ids[s], coo.values[s], b[s], m_pad)
            np.testing.assert_allclose(full[s], np.asarray(single),
                                       atol=1e-5)
