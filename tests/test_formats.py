"""Format conversions + hypothesis property tests on SpMM invariants.

``hypothesis`` is an optional dev dependency (see pyproject.toml extras);
the property tests are defined only when it is installed, so tier-1
collection never fails on it and the deterministic tests always run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

import pytest

from repro.core import (
    INT16_MAX,
    coo_from_lists,
    coo_to_csr,
    coo_to_dense,
    coo_to_ell,
    csr_transpose,
    max_row_degree,
    narrow_col_ids,
    random_batch,
    validate_ell_k_pad,
)
from repro.core.spmm import batched_spmm
from repro.kernels import ref


def _random_coo(seed, batch, dim, nnz):
    rng = np.random.default_rng(seed)
    return random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)


def test_random_batch_self_loops_never_duplicate():
    """Regression (ISSUE 5): the §V-A generator used to append a (r, r)
    self-loop even when rng.choice already sampled the diagonal, so the two
    unit-valued COO entries summed to 2.0 on densify. Dense adjacencies must
    be strictly 0/1. Dense dims with high nnz make the collision near-certain
    pre-fix."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        coo, m_pad = random_batch(rng, batch=6, dim=(4, 12),
                                  nnz_per_row=(2, 6), self_loops=True)
        dense = np.asarray(coo_to_dense(coo, m_pad))
        assert set(np.unique(dense)) <= {0.0, 1.0}, seed
        # and the diagonal is complete over the real rows (a_uu = 1, §II-A)
        n_rows = np.asarray(coo.n_rows)
        for b in range(coo.batch):
            diag = np.diagonal(dense[b])[: n_rows[b]]
            np.testing.assert_array_equal(diag, 1.0)


def test_coo_to_ell_overflow_raises():
    """Regression (ISSUE 5): coo_to_ell silently zeroed any nnz beyond k_pad
    in a row. The checked path and the ops-level ELL guard must both raise
    host-side on concrete inputs."""
    r = np.asarray([0, 0, 0, 0, 1], np.int32)     # row 0 holds 4 nnz
    c = np.asarray([1, 2, 3, 4, 0], np.int32)
    coo = coo_from_lists([(r, c, np.ones(5, np.float32))], [8])
    assert int(np.asarray(max_row_degree(coo, 8)).max()) == 4
    with pytest.raises(ValueError, match="max row degree"):
        coo_to_ell(coo, 8, 2, check=True)
    with pytest.raises(ValueError, match="max row degree"):
        validate_ell_k_pad(coo, 8, 3)
    b = jnp.ones((1, 8, 4), jnp.float32)
    for impl in ("ell", "pallas_ell"):
        with pytest.raises(ValueError, match="max row degree"):
            batched_spmm(coo, b, impl=impl, k_pad=2)
    # a correctly sized k_pad passes and is lossless
    ell = coo_to_ell(coo, 8, 4, check=True)
    assert float(np.asarray(ell.values).sum()) == 5.0
    got = np.asarray(batched_spmm(coo, b, impl="ell", k_pad=4))
    want = np.asarray(batched_spmm(coo, b, impl="ref"))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_csr_transpose_matches_dense_transpose():
    coo, m_pad = _random_coo(7, 5, (6, 28), (1, 4))
    csr_t = csr_transpose(coo_to_csr(coo, m_pad), m_pad)
    # rpt invariants survive the transpose
    rpt = np.asarray(csr_t.rpt)
    assert (np.diff(rpt, axis=1) >= 0).all()
    np.testing.assert_array_equal(rpt[:, -1], np.asarray(coo.nnz))
    b = jnp.asarray(np.random.default_rng(8).normal(size=(5, m_pad, 12)),
                    jnp.float32)
    got = np.asarray(ref.batched_spmm_csr_ref(csr_t, b))
    want = np.asarray(jax.lax.batch_matmul(
        coo_to_dense(coo, m_pad).transpose(0, 2, 1), b))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_csr_roundtrip():
    coo, m_pad = _random_coo(0, 5, (5, 30), (1, 4))
    csr = coo_to_csr(coo, m_pad)
    # rpt is monotone, ends at true nnz
    rpt = np.asarray(csr.rpt)
    assert (np.diff(rpt, axis=1) >= 0).all()
    np.testing.assert_array_equal(rpt[:, -1], np.asarray(coo.nnz))
    b = jnp.asarray(np.random.default_rng(1).normal(size=(5, m_pad, 16)),
                    jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ref.batched_spmm_csr_ref(csr, b)),
        np.asarray(ref.batched_spmm_coo_ref(coo, b, m_pad)), atol=1e-5)


def test_ell_matches_dense():
    coo, m_pad = _random_coo(2, 4, (5, 20), (1, 3))
    ell = coo_to_ell(coo, m_pad, k_pad=8)
    dense_from_ell = np.zeros((4, m_pad, m_pad), np.float32)
    cid = np.asarray(ell.col_ids)
    val = np.asarray(ell.values)
    for b in range(4):
        for r in range(m_pad):
            for k in range(8):
                dense_from_ell[b, r, cid[b, r, k]] += val[b, r, k]
    np.testing.assert_allclose(dense_from_ell,
                               np.asarray(coo_to_dense(coo, m_pad)), atol=0)


# ---------------------------------------------------------------------------
# Deterministic checkers behind the property tests (ISSUE 6 satellite).
# Plain functions so the invariant logic runs in tier-1 even without
# hypothesis; the @given wrappers below fuzz them when it is installed.
# ---------------------------------------------------------------------------

def _check_format_roundtrip_preserves_values(coo, m_pad):
    """coo↔ell↔csr: every conversion carries the SAME value multiset per
    sample — the product is identical because the values are, not merely
    close."""
    deg = int(np.asarray(max_row_degree(coo, m_pad)).max())
    k_pad = max(1, deg)
    ell = coo_to_ell(coo, m_pad, k_pad)
    csr = coo_to_csr(coo, m_pad)
    for s in range(coo.batch):
        def nz(x):
            flat = np.asarray(x).ravel()
            return np.sort(flat[flat != 0.0])

        want = nz(coo.values[s])
        np.testing.assert_array_equal(nz(ell.values[s]), want)
        np.testing.assert_array_equal(nz(csr.values[s]), want)


def _check_csr_transpose_involution(coo, m_pad):
    """csr_transpose(csr_transpose(A)) == A, compared as dense matrices
    (the row ordering inside a CSR row may legally permute)."""
    csr = coo_to_csr(coo, m_pad)
    back = csr_transpose(csr_transpose(csr, m_pad), m_pad)
    eye = jnp.eye(m_pad, dtype=jnp.float32)[None].repeat(coo.batch, axis=0)
    d0 = np.asarray(ref.batched_spmm_csr_ref(csr, eye))
    d1 = np.asarray(ref.batched_spmm_csr_ref(back, eye))
    np.testing.assert_allclose(d1, d0, atol=1e-6)


def _check_ell_guard_agrees_with_conversion(coo, m_pad, k_pad):
    """validate_ell_k_pad passes ⟺ coo_to_ell at that k_pad drops nothing:
    the guard must never admit a batch the conversion would silently
    truncate (and never reject a lossless one)."""
    total = float(np.asarray(coo.values).sum())
    try:
        validate_ell_k_pad(coo, m_pad, k_pad)
        admitted = True
    except ValueError:
        admitted = False
    ell_total = float(np.asarray(coo_to_ell(coo, m_pad, k_pad).values).sum())
    lossless = ell_total == total
    assert admitted == lossless, (
        f"guard admitted={admitted} but conversion lossless={lossless} "
        f"(k_pad={k_pad}, sum {ell_total} vs {total})")


def test_format_roundtrip_preserves_values_deterministic():
    coo, m_pad = _random_coo(3, 4, (5, 24), (1, 4))
    _check_format_roundtrip_preserves_values(coo, m_pad)


def test_csr_transpose_involution_deterministic():
    coo, m_pad = _random_coo(4, 3, (5, 20), (1, 4))
    _check_csr_transpose_involution(coo, m_pad)


def test_ell_guard_agrees_with_conversion_deterministic():
    r = np.asarray([0, 0, 0, 1], np.int32)
    c = np.asarray([1, 2, 3, 0], np.int32)
    coo = coo_from_lists([(r, c, np.ones(4, np.float32))], [8])
    for k_pad in (1, 2, 3, 4):
        _check_ell_guard_agrees_with_conversion(coo, 8, k_pad)


def test_int16_narrowing_boundary():
    """int16 column-index storage (DESIGN.md §10): indices at m_pad-1
    survive the narrowing exactly up to the int16 ceiling (m_pad=32767 is
    also the COO pad sentinel, so it must fit); one past it raises
    host-side instead of wrapping negative on device."""
    ids = jnp.asarray([[0, INT16_MAX - 1, INT16_MAX]], jnp.int32)
    narrow = narrow_col_ids(ids, INT16_MAX)
    assert narrow.dtype == jnp.int16
    np.testing.assert_array_equal(np.asarray(narrow, np.int64),
                                  np.asarray(ids, np.int64))
    with pytest.raises(ValueError, match="int16"):
        narrow_col_ids(ids, INT16_MAX + 1)


# ---------------------------------------------------------------------------
# Property tests (hypothesis) — decorators need hypothesis at definition
# time, so the whole block is conditional on the optional dep.
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @st.composite
    def coo_batches(draw):
        batch = draw(st.integers(1, 5))
        dim_hi = draw(st.integers(4, 40))
        nnz_hi = draw(st.integers(1, 6))
        seed = draw(st.integers(0, 2**16))
        n_b = draw(st.sampled_from([1, 4, 16, 40, 130]))
        coo, m_pad = _random_coo(seed, batch, (3, dim_hi), (1, nnz_hi))
        b = jnp.asarray(
            np.random.default_rng(seed + 1).normal(size=(batch, m_pad, n_b)),
            jnp.float32)
        return coo, m_pad, b

    @settings(max_examples=20, deadline=None)
    @given(coo_batches())
    def test_property_impls_equal_dense(case):
        """∀ batches: every impl == densify+matmul oracle."""
        coo, m_pad, b = case
        want = np.asarray(
            jax.lax.batch_matmul(coo_to_dense(coo, m_pad), b))
        for impl in ("ref", "pallas_coo", "pallas_ell"):
            got = np.asarray(batched_spmm(coo, b, impl=impl, k_pad=8))
            np.testing.assert_allclose(got, want, atol=1e-4, err_msg=impl)

    @settings(max_examples=15, deadline=None)
    @given(coo_batches(), st.floats(-3, 3), st.floats(-3, 3))
    def test_property_linearity(case, alpha, beta):
        """SpMM is linear in B: A(αB₁+βB₂) = αAB₁ + βAB₂."""
        coo, m_pad, b = case
        b2 = b[:, ::-1, :]
        lhs = batched_spmm(coo, alpha * b + beta * b2, impl="ref")
        rhs = (alpha * batched_spmm(coo, b, impl="ref")
               + beta * batched_spmm(coo, b2, impl="ref"))
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   atol=1e-3, rtol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(coo_batches(), st.integers(1, 64))
    def test_property_padding_invariance(case, extra):
        """Adding zero-valued padding slots never changes the product (the
        paper's §IV-C 'redundant threads terminate immediately' invariant)."""
        coo, m_pad, b = case
        pad = lambda x: jnp.pad(x, ((0, 0), (0, extra)))  # noqa: E731
        coo2 = dataclasses.replace(
            coo, row_ids=pad(coo.row_ids), col_ids=pad(coo.col_ids),
            values=pad(coo.values))
        got = batched_spmm(coo2, b, impl="ref")
        want = batched_spmm(coo, b, impl="ref")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)

    @settings(max_examples=20, deadline=None)
    @given(coo_batches())
    def test_property_format_roundtrip_preserves_values(case):
        """∀ batches: coo↔ell↔csr conversions preserve the per-sample
        value multiset exactly (ISSUE 6 satellite)."""
        coo, m_pad, _ = case
        _check_format_roundtrip_preserves_values(coo, m_pad)

    @settings(max_examples=15, deadline=None)
    @given(coo_batches())
    def test_property_csr_transpose_involution(case):
        """∀ batches: csr_transpose(csr_transpose(A)) == A."""
        coo, m_pad, _ = case
        _check_csr_transpose_involution(coo, m_pad)

    @settings(max_examples=15, deadline=None)
    @given(coo_batches(), st.integers(1, 8))
    def test_property_ell_guard_never_passes_lossy_batch(case, k_pad):
        """∀ batches, k_pad: validate_ell_k_pad admits exactly the batches
        coo_to_ell(k_pad) converts losslessly — the guard can never let a
        silently-truncating conversion through."""
        coo, m_pad, _ = case
        _check_ell_guard_agrees_with_conversion(coo, m_pad, k_pad)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, INT16_MAX + 1), st.data())
    def test_property_int16_narrowing_boundary(m_pad, data):
        """∀ m_pad ≤ INT16_MAX: narrowing is exact for ids in [0, m_pad)
        and at the pad sentinel m_pad itself; m_pad > INT16_MAX raises
        host-side (never wraps negative on device)."""
        ids_list = data.draw(st.lists(
            st.integers(0, m_pad), min_size=1, max_size=8))
        ids_list.append(m_pad - 1)          # always hit the boundary id
        ids = jnp.asarray([ids_list], jnp.int32)
        if m_pad > INT16_MAX:
            with pytest.raises(ValueError, match="int16"):
                narrow_col_ids(ids, m_pad)
            return
        narrow = narrow_col_ids(ids, m_pad)
        assert narrow.dtype == jnp.int16
        np.testing.assert_array_equal(np.asarray(narrow, np.int64),
                                      np.asarray(ids, np.int64))

    @settings(max_examples=10, deadline=None)
    @given(coo_batches())
    def test_property_batch_independence(case):
        """Batching never mixes samples: batched result row b == single-sample
        result for sample b (the core correctness claim of Batched SpMM)."""
        coo, m_pad, b = case
        full = np.asarray(batched_spmm(coo, b, impl="ref"))
        for s in range(min(coo.batch, 3)):
            single = ref.spmm_coo_single(
                coo.row_ids[s], coo.col_ids[s], coo.values[s], b[s], m_pad)
            np.testing.assert_allclose(full[s], np.asarray(single),
                                       atol=1e-5)
