"""Roofline analyzer: trip-count-aware HLO cost parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import HloModule, analyze_text


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    def body(c, _):
        return c @ c, None

    for n in (1, 10, 37):
        def f(x, n=n):
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y

        c = _compiled(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
        cost = analyze_text(c.as_text())
        expect = n * 2 * 128 ** 3
        assert abs(cost.flops - expect) / expect < 1e-6, (n, cost.flops)


def test_nested_scan_flops():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=5)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    cost = analyze_text(c.as_text())
    expect = 3 * 5 * 2 * 64 ** 3
    assert abs(cost.flops - expect) / expect < 1e-6


def test_dot_general_batched_flops():
    def f(a, b):
        return jax.lax.dot_general(
            a, b, (((2,), (1,)), ((0,), (0,))))   # batched matmul

    a = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 24), jnp.float32)
    cost = analyze_text(_compiled(f, a, b).as_text())
    expect = 2 * 4 * 32 * 16 * 24
    assert abs(cost.flops - expect) / expect < 1e-6


def test_collective_bytes_parsed():
    """An explicitly-sharded psum program must show all-reduce wire bytes."""
    import subprocess
    import sys
    import os
    script = r"""
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.analysis.hlo_cost import analyze_text
mesh = jax.make_mesh((4,), ("x",))
def f(a):
    return jax.lax.with_sharding_constraint(
        a.sum(axis=0, keepdims=True), NamedSharding(mesh, P()))
c = jax.jit(f, in_shardings=NamedSharding(mesh, P("x")),
            out_shardings=NamedSharding(mesh, P())).lower(
    jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile()
cost = analyze_text(c.as_text())
assert cost.coll_bytes > 0, c.as_text()[:4000]
assert "all-reduce" in cost.coll_by_kind or "all-gather" in cost.coll_by_kind
print("PASS")
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "JAX_PLATFORMS": "cpu"}
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script, src],
                       capture_output=True, text=True, env=env, timeout=300)
    assert "PASS" in r.stdout, r.stdout + r.stderr


def test_bytes_counts_boundaries_not_fused_internals():
    def f(x):
        return jnp.tanh(x) * 2 + 1     # one fused elementwise chain

    c = _compiled(f, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    cost = analyze_text(c.as_text())
    nbytes = 1024 * 1024 * 4
    # in + out (+ small slack for copies); must NOT count 3 intermediates
    assert cost.bytes <= 4 * nbytes, cost.bytes
    assert cost.bytes >= 1.5 * nbytes


def test_slice_aware_scan_residuals():
    """A scan that saves per-step residuals must charge the slice, not the
    whole stacked buffer, per step."""
    def body(c, _):
        y = c @ c
        return y, y     # stacks (n, 256, 256) residuals

    def f(x):
        y, res = jax.lax.scan(body, x, None, length=100)
        return y, res

    c = _compiled(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    cost = analyze_text(c.as_text())
    step = 256 * 256 * 4
    # stacked buffer is 100 steps; whole-buffer-per-step would be ~100×100
    # slices; correct accounting is O(100) slices + matmul traffic
    assert cost.bytes < 100 * step * 20, cost.bytes
