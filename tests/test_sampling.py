"""Giant-graph sampled tier (DESIGN.md §14): CSC structure, neighbor
sampling, bucketing, the feature cache and the end-to-end sampled trainer.

``hypothesis`` is an optional dev dependency (see pyproject.toml extras);
the property tests are defined only when it is installed, so tier-1
collection never fails on it and the deterministic tests always run.
"""
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.core.csc import CSCGraph, csc_from_edges, csc_to_coo
from repro.data.graphs import reddit_like
from repro.observability.metrics import MetricsRegistry
from repro.sampling import (
    FeatureStore,
    HotNodeCache,
    ItemSampler,
    Prefetcher,
    SampledNodeLoader,
    block_ladders,
    bucket_for,
    neighbor_sample,
    static_hot_ids,
)


def _random_graph(seed, n_nodes, n_edges):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int64)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int64)
    return csc_from_edges(src, dst, n_nodes), src, dst


# ---------------------------------------------------------------------------
# CSC structure
# ---------------------------------------------------------------------------

def test_csc_round_trip_bitwise():
    """edges → CSC → edges → CSC is bitwise stable (csc_to_coo emits the
    canonical dst-major order, which csc_from_edges' counting sort
    preserves)."""
    csc, src, dst = _random_graph(0, 23, 150)
    src2, dst2 = csc_to_coo(csc)
    csc2 = csc_from_edges(src2, dst2, csc.n_nodes)
    np.testing.assert_array_equal(csc.indptr, csc2.indptr)
    np.testing.assert_array_equal(csc.indices, csc2.indices)
    # same multiset of edges as the original (order-insensitive)
    want = sorted(zip(dst.tolist(), src.tolist()))
    got = sorted(zip(dst2.tolist(), src2.tolist()))
    assert want == got


def test_csc_degrees_and_neighbors():
    #   0 ← 1, 0 ← 2, 1 ← 2, 2 ← 2 (self-loop)
    src = np.array([1, 2, 2, 2])
    dst = np.array([0, 0, 1, 2])
    csc = csc_from_edges(src, dst, 3)
    np.testing.assert_array_equal(csc.in_degrees(), [2, 1, 1])
    np.testing.assert_array_equal(np.sort(csc.in_neighbors(0)), [1, 2])
    np.testing.assert_array_equal(csc.in_neighbors(2), [2])


def test_csc_rejects_out_of_range_endpoints():
    with pytest.raises(ValueError):
        csc_from_edges(np.array([0, 5]), np.array([0, 1]), 3)
    with pytest.raises(ValueError):
        csc_from_edges(np.array([0, 1]), np.array([0, -1]), 3)


# ---------------------------------------------------------------------------
# Neighbor sampling: determinism, fanout bounds, compaction, chaining
# ---------------------------------------------------------------------------

def _block_edges(block):
    """(local rows, local cols) of the real (non-padding) entries."""
    nnz = block.nnz
    return (np.asarray(block.adj.row_ids[0][:nnz]),
            np.asarray(block.adj.col_ids[0][:nnz]))


def _assert_valid_blocks(csc, seeds, fanouts, blocks):
    assert len(blocks) == len(fanouts)
    np.testing.assert_array_equal(blocks[-1].dst_ids(), seeds)
    for i, (block, fanout) in enumerate(zip(blocks, fanouts)):
        rows, cols = _block_edges(block)
        # compacted ids: unique, dst set is the prefix of the src set
        assert len(np.unique(block.src_ids)) == len(block.src_ids)
        np.testing.assert_array_equal(block.src_ids[:block.n_dst],
                                      block.dst_ids())
        # fanout bound, per destination AND via the padded-format max_deg
        if len(rows):
            assert np.bincount(rows).max() <= fanout
        assert block.max_deg <= fanout
        # every sampled edge exists in the global graph
        for r, c in zip(rows[:64], cols[:64]):
            g_dst = int(block.src_ids[r])
            g_src = int(block.src_ids[c])
            assert g_src in csc.in_neighbors(g_dst), (i, g_src, g_dst)
        # chaining invariant the layered forward slices on
        if i + 1 < len(blocks):
            np.testing.assert_array_equal(block.dst_ids(),
                                          blocks[i + 1].src_ids)


def test_neighbor_sample_invariants():
    csc, _, _ = _random_graph(1, 60, 500)
    seeds = np.array([3, 17, 41, 8])
    fanouts = [4, 2]
    blocks = neighbor_sample(csc, seeds, fanouts, seed=(0, 0, 0))
    _assert_valid_blocks(csc, seeds, fanouts, blocks)


def test_neighbor_sample_deterministic():
    """Bitwise-equal blocks from the same (csc, seeds, fanouts, seed) —
    the addressability the checkpoint-resume path re-derives batches from."""
    csc, _, _ = _random_graph(2, 80, 700)
    seeds = np.arange(0, 80, 7)
    a = neighbor_sample(csc, seeds, [5, 3], seed=(9, 2, 4))
    b = neighbor_sample(csc, seeds, [5, 3], seed=(9, 2, 4))
    c = neighbor_sample(csc, seeds, [5, 3], seed=(9, 2, 5))
    for ba, bb in zip(a, b):
        np.testing.assert_array_equal(ba.src_ids, bb.src_ids)
        np.testing.assert_array_equal(np.asarray(ba.adj.row_ids),
                                      np.asarray(bb.adj.row_ids))
        np.testing.assert_array_equal(np.asarray(ba.adj.col_ids),
                                      np.asarray(bb.adj.col_ids))
        np.testing.assert_array_equal(np.asarray(ba.adj.values),
                                      np.asarray(bb.adj.values))
    # a different batch coordinate draws a different sample
    assert any(
        len(ba.src_ids) != len(bc.src_ids)
        or not np.array_equal(ba.src_ids, bc.src_ids)
        for ba, bc in zip(a, c))


def test_neighbor_sample_rejects_duplicate_seeds():
    csc, _, _ = _random_graph(3, 10, 40)
    with pytest.raises(ValueError, match="unique"):
        neighbor_sample(csc, np.array([1, 1, 2]), [2])


def test_neighbor_sample_mean_normalization():
    """With normalize="mean" each destination's incoming values sum to 1
    (its sampled-degree average) — zero-degree destinations contribute
    nothing."""
    csc, _, _ = _random_graph(4, 40, 300)
    seeds = np.arange(10)
    (block,) = neighbor_sample(csc, seeds, [3], seed=0)
    rows, _ = _block_edges(block)
    vals = np.asarray(block.adj.values[0][:block.nnz])
    sums = np.zeros(block.n_dst)
    np.add.at(sums, rows, vals)
    deg = np.bincount(rows, minlength=block.n_dst)
    np.testing.assert_allclose(sums[deg > 0], 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# ItemSampler + bucketing
# ---------------------------------------------------------------------------

def test_item_sampler_epoch_addressable():
    ids = np.arange(100, 164)
    s = ItemSampler(ids, 16, seed=3)
    e0a = [b for _, b in s.epoch(0)]
    e0b = [b for _, b in s.epoch(0)]
    e1 = [b for _, b in s.epoch(1)]
    for a, b in zip(e0a, e0b):
        np.testing.assert_array_equal(a, b)     # replayable
    assert not all(np.array_equal(a, b) for a, b in zip(e0a, e1))
    # full coverage when batch_size divides the set
    assert set(np.concatenate(e0a).tolist()) == set(ids.tolist())
    assert s.batches_per_epoch() == 4


def test_bucket_for_picks_smallest_covering_rung():
    ladders = block_ladders(64, [4, 2], levels=3)
    assert len(ladders) == 2
    for ladder in ladders:
        assert len(ladder) <= 3
        # rungs ascend; smallest covering rung is returned
        m0, z0 = ladder[0]
        assert bucket_for(ladder, m0, z0) == (m0, z0)
        assert bucket_for(ladder, 1, 1) == (m0, z0)
        m_top, z_top = ladder[-1]
        assert bucket_for(ladder, m_top, z_top) == (m_top, z_top)
        with pytest.raises(ValueError, match="top ladder rung"):
            bucket_for(ladder, m_top + 1, z_top)


def test_block_caps_clamped_by_graph_size():
    small = block_ladders(512, [10, 5], n_nodes=100, levels=2)
    for ladder in small:
        assert all(m <= 104 for m, _ in ladder)  # round_up(100, 8)


# ---------------------------------------------------------------------------
# Feature store / hot-node cache / prefetcher
# ---------------------------------------------------------------------------

def test_feature_store_counts_traffic():
    feats = np.random.default_rng(0).normal(size=(32, 8)).astype(np.float32)
    store = FeatureStore(feats, registry=MetricsRegistry())
    got = store.gather(np.array([3, 1, 3]))
    np.testing.assert_array_equal(got, feats[[3, 1, 3]])
    assert store._fetch_rows.total() == 3
    assert store._fetch_bytes.total() == 3 * 8 * 4


def test_hot_node_cache_static_reduces_traffic():
    feats = np.random.default_rng(1).normal(size=(64, 4)).astype(np.float32)
    deg = np.arange(64)          # node 63 hottest
    reg = MetricsRegistry()      # fresh registry: counters isolated per test
    store = FeatureStore(feats, registry=reg)
    cache = HotNodeCache(store, 8, policy="static",
                         hot_ids=static_hot_ids(deg, 8), registry=reg)
    ids = np.array([63, 62, 0, 1, 63])        # 3 hot hits, 2 cold misses
    np.testing.assert_array_equal(cache.gather(ids), feats[ids])
    assert cache.hit_rate() == pytest.approx(3 / 5)
    # only the misses touched the backing store (static fill is amortized)
    assert store._fetch_rows.total() == 2


def test_hot_node_cache_lru_evicts():
    feats = np.arange(20, dtype=np.float32).reshape(10, 2)
    reg = MetricsRegistry()
    store = FeatureStore(feats, registry=reg)
    cache = HotNodeCache(store, 2, policy="lru", registry=reg)
    cache.gather(np.array([0]))               # miss, cached {0}
    cache.gather(np.array([1]))               # miss, cached {0, 1}
    cache.gather(np.array([0]))               # hit, 0 most-recent
    cache.gather(np.array([2]))               # miss, evicts 1
    got = cache.gather(np.array([1, 0]))      # 1 miss, 0 hit
    np.testing.assert_array_equal(got, feats[[1, 0]])
    assert store._fetch_rows.total() == 4     # misses: 0, 1, 2, 1
    assert cache.hit_rate() == pytest.approx(2 / 6)


def test_static_hot_ids_ranks_by_degree():
    np.testing.assert_array_equal(
        static_hot_ids(np.array([5, 1, 9, 9, 0]), 3), [2, 3, 0])


def test_prefetcher_preserves_order_and_propagates_errors():
    def gen():
        yield from range(5)
        raise RuntimeError("boom")

    pf = Prefetcher(gen())
    it = iter(pf)
    assert [next(it) for _ in range(5)] == list(range(5))
    with pytest.raises(RuntimeError, match="boom"):
        next(it)


# ---------------------------------------------------------------------------
# Loader: bounded compile count, feature alignment
# ---------------------------------------------------------------------------

def test_loader_shape_keys_bounded_by_ladder():
    """An epoch of data-dependent sample shapes maps to at most
    ∏ len(ladder_i) distinct static geometries — the compile-count bound the
    sampled trainer inherits (acceptance: ISSUE 10)."""
    data = reddit_like(600, n_classes=4, n_features=8, seed=1)
    loader = SampledNodeLoader(
        data.csc, data.features, data.labels, data.train_ids,
        fanouts=[5, 3], batch_size=64, levels=3)
    keys = set()
    bound = 1
    for ladder in loader.ladders:
        bound *= len(ladder)
    for batch in loader.epoch(0):
        keys.add(batch.shape_key())
        for block in batch.blocks:
            # rebucketing preserved the real payload inside the padding
            assert block.n_src <= block.m_pad
            assert block.nnz <= block.nnz_pad
    assert 1 <= len(keys) <= bound
    # features are aligned with the input block's compacted src ids
    batch = loader.sample_batch(0, 0, data.train_ids[:64])
    b0 = batch.blocks[0]
    np.testing.assert_array_equal(batch.x[:b0.n_src],
                                  data.features[b0.src_ids])
    assert not batch.x[b0.n_src:].any()
    np.testing.assert_array_equal(batch.labels,
                                  data.labels[batch.seeds])


def test_loader_batches_replayable():
    data = reddit_like(400, n_classes=4, n_features=8, seed=2)
    loader = SampledNodeLoader(
        data.csc, data.features, data.labels, data.train_ids,
        fanouts=[4, 2], batch_size=32)
    a = loader.sample_batch(3, 1, data.train_ids[:32])
    b = loader.sample_batch(3, 1, data.train_ids[:32])
    np.testing.assert_array_equal(a.x, b.x)
    for ba, bb in zip(a.blocks, b.blocks):
        np.testing.assert_array_equal(ba.src_ids, bb.src_ids)
        np.testing.assert_array_equal(np.asarray(ba.adj.row_ids),
                                      np.asarray(bb.adj.row_ids))


# ---------------------------------------------------------------------------
# reddit_like generator
# ---------------------------------------------------------------------------

def test_reddit_like_structure():
    data = reddit_like(500, n_classes=4, n_features=8, seed=0)
    assert data.csc.n_nodes == 500
    assert data.labels.shape == (500,) and data.labels.max() < 4
    assert data.features.shape == (500, 8)
    # every node has a self-loop (no isolated destinations)
    assert data.csc.in_degrees().min() >= 1
    # train/val split is disjoint and covers the node set
    assert not set(data.train_ids) & set(data.val_ids)
    assert len(data.train_ids) + len(data.val_ids) == 500
    # homophily: same-class edges dominate (excluding self-loops)
    src, dst = csc_to_coo(data.csc)
    off = src != dst
    same = (data.labels[src[off]] == data.labels[dst[off]]).mean()
    assert same > 0.5


# ---------------------------------------------------------------------------
# Block-aware autotuning (acceptance: CSR-class wins skewed blocks)
# ---------------------------------------------------------------------------

def test_workload_block_axis_key_and_selection():
    from repro.autotune import Workload, select_impl

    w = Workload(batch=1, m_pad=1600, nnz_pad=3200, k_pad=None, n_b=64,
                 max_deg=16, block=360)
    assert w.key().endswith("_blk360")
    legacy = Workload(batch=1, m_pad=1600, nnz_pad=3200, k_pad=None, n_b=64,
                      max_deg=16)
    assert "_blk" not in legacy.key()
    # a skewed sampled block (few output rows, bounded row degree, wide
    # src padding) must route to the row-split CSR class on the TPU model —
    # dense/ELL still pay the full m_pad² / m_pad·k_pad geometry
    d = select_impl(w, allow_pallas=True)
    assert d.impl in ("pallas_csr", "pallas_hybrid"), d


# ---------------------------------------------------------------------------
# End-to-end: fit_sampled learns and compiles a bounded program set
# ---------------------------------------------------------------------------

def test_fit_sampled_learns_node_classification():
    from repro.core.gcn import GCNConfig
    from repro.optim import AdamConfig
    from repro.training.trainer import GCNTrainer, TrainerConfig

    data = reddit_like(1500, n_classes=4, n_features=16, seed=0)
    loader = SampledNodeLoader(
        data.csc, data.features, data.labels, data.train_ids,
        fanouts=[5, 3], batch_size=128)
    cfg = GCNConfig(n_features=16, channels=1, conv_widths=(16, 16),
                    n_tasks=4, task="multiclass", k_pad=None)
    with tempfile.TemporaryDirectory() as ckpt:
        trainer = GCNTrainer(
            cfg, AdamConfig(lr=5e-3),
            TrainerConfig(checkpoint_dir=ckpt, checkpoint_every=10_000,
                          log_every=50))
        _, _, metrics = trainer.fit_sampled(loader, epochs=3,
                                            prefetch=True)
    assert metrics["acc"] > 0.5          # chance = 0.25
    bound = 1
    for ladder in loader.ladders:
        bound *= len(ladder)
    assert 1 <= metrics["programs"] <= bound


# ---------------------------------------------------------------------------
# Property tests (hypothesis) — decorators need hypothesis at definition
# time, so the whole block is conditional on the optional dep.
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @st.composite
    def graphs(draw):
        n_nodes = draw(st.integers(4, 50))
        n_edges = draw(st.integers(1, 6 * n_nodes))
        seed = draw(st.integers(0, 2**16))
        return _random_graph(seed, n_nodes, n_edges)[0]

    @settings(max_examples=25, deadline=None)
    @given(graphs())
    def test_property_csc_round_trip(csc):
        """∀ graphs: CSC → COO → CSC is bitwise stable."""
        src, dst = csc_to_coo(csc)
        csc2 = csc_from_edges(src, dst, csc.n_nodes)
        np.testing.assert_array_equal(csc.indptr, csc2.indptr)
        np.testing.assert_array_equal(csc.indices, csc2.indices)

    @settings(max_examples=15, deadline=None)
    @given(graphs(), st.integers(1, 6), st.integers(1, 4),
           st.integers(0, 2**16))
    def test_property_sample_invariants(csc, fanout0, fanout1, seed):
        """∀ (graph, fanouts, seed): determinism + fanout bounds +
        compacted-id validity + the chaining invariant."""
        rng = np.random.default_rng(seed)
        n_seeds = min(4, csc.n_nodes)
        seeds = rng.choice(csc.n_nodes, n_seeds, replace=False)
        fanouts = [fanout0, fanout1]
        blocks = neighbor_sample(csc, seeds, fanouts, seed=seed)
        _assert_valid_blocks(csc, seeds, fanouts, blocks)
        again = neighbor_sample(csc, seeds, fanouts, seed=seed)
        for a, b in zip(blocks, again):
            np.testing.assert_array_equal(a.src_ids, b.src_ids)
            np.testing.assert_array_equal(np.asarray(a.adj.col_ids),
                                          np.asarray(b.adj.col_ids))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 64), st.integers(1, 12), st.integers(1, 12),
           st.integers(1, 400))
    def test_property_ladder_covers_caps(batch, f0, f1, n_nodes):
        """∀ sampling params: every admissible (n_src, nnz) — up to the
        closed-form caps — lands on some rung (bucket_for never raises)."""
        from repro.sampling.bucketing import block_caps

        caps = block_caps(batch, [f0, f1], n_nodes=n_nodes)
        ladders = block_ladders(batch, [f0, f1], n_nodes=n_nodes)
        for (m_cap, nnz_cap), ladder in zip(caps, ladders):
            assert bucket_for(ladder, m_cap, nnz_cap) == tuple(ladder[-1])
            assert bucket_for(ladder, 1, 0)
