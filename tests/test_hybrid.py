"""Degree-binned hybrid SpMM regression tests (DESIGN.md §12).

Covers the hybrid dispatch contract end to end:

- ``plan_hybrid`` static properties (bins tile m_pad SUBLANES-aligned, the
  degenerate ``d_pad = 0`` guard, tau validation);
- degenerate-input guards: all-empty-row batches and rows whose density sits
  EXACTLY at the hub threshold (``deg == dmin`` classifies dense — the ``>=``
  comparison is load-bearing);
- the row-permutation round trip: permute → SpMM → inverse-permute is
  bitwise-stable on outputs and matches the unpermuted gradients for EVERY
  concrete impl in the registry, including zero-nnz and single-long-row
  matrices;
- the cost model's skew pricing: the CSR branch is monotone in the measured
  ``max_deg`` (the serialization bound the kernel actually pays), the hybrid
  branch amortizes only when ``max_deg`` clears ``dmin``, and the workload
  key grows a ``_md`` suffix only when the knob is set (cache back-compat);
- the fused fold-in: ``fused_hybrid`` with residual + ReLU epilogue, and
  mesh-sharded parity.
"""
import dataclasses
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from oracle import CONCRETE_SPMM_IMPLS, tols_for
from repro.autotune.cost_model import Workload, estimate, rank
from repro.autotune.selector import KINDS
from repro.core.batching import HYBRID_TAU, SUBLANES, plan_hybrid
from repro.core.formats import (
    coo_from_lists,
    random_powerlaw_batch,
    row_degrees,
)
from repro.core.spmm import batched_spmm
from repro.kernels.batched_spmm_hybrid import hybrid_operands


# ---------------------------------------------------------------------------
# plan_hybrid statics
# ---------------------------------------------------------------------------

def test_plan_hybrid_static_properties():
    hp = plan_hybrid(batch=4, m_pad=64, n_b=32, nnz_pad=512)
    assert hp.dmin == math.ceil(HYBRID_TAU * 64)
    assert hp.d_pad % SUBLANES == 0 and 0 < hp.d_pad <= 64
    # bins tile [0, m_pad) exactly, SUBLANES-aligned, in order
    assert hp.bins[0][0] == 0 and hp.bins[-1][1] == 64
    for (_, e), (s2, _) in zip(hp.bins, hp.bins[1:]):
        assert e == s2
    for s, e in hp.bins:
        assert s % SUBLANES == 0 and s < e


def test_plan_hybrid_degenerate_dpad_zero():
    """nnz_pad below dmin: NO row can reach hub density, so the planner must
    not size a dense tile group at all (satellite: never emit an empty MXU
    tile group)."""
    hp = plan_hybrid(batch=2, m_pad=64, n_b=32, nnz_pad=8)
    assert hp.dmin == 16 and hp.d_pad == 0


def test_plan_hybrid_tau_validation():
    for tau in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError):
            plan_hybrid(batch=1, m_pad=8, n_b=8, nnz_pad=8, tau=tau)


# ---------------------------------------------------------------------------
# degenerate-input guards (fails-pre-fix regressions)
# ---------------------------------------------------------------------------

def _empty_sample():
    return (np.zeros(0, np.int32), np.zeros(0, np.int32),
            np.zeros(0, np.float32))


def test_hybrid_dpad_zero_path_matches_ref():
    """m_pad = 64 with an 8-slot budget → dmin = 16 > nnz_pad: the d_pad = 0
    plan must route cleanly through both hybrid siblings (no zero-size MXU
    tile group in the kernel)."""
    rng = np.random.default_rng(3)
    tri = [(np.asarray([0, 1, 2], np.int32), np.asarray([5, 6, 7], np.int32),
            rng.normal(size=3).astype(np.float32)) for _ in range(2)]
    coo = coo_from_lists(tri, [64, 64])
    assert coo.nnz_pad < plan_hybrid(batch=2, m_pad=64, n_b=16,
                                     nnz_pad=coo.nnz_pad).dmin
    b = jnp.asarray(rng.normal(size=(2, 64, 16)), jnp.float32)
    want = np.asarray(batched_spmm(coo, b, impl="ref"))
    for impl in ("hybrid", "pallas_hybrid"):
        got = np.asarray(batched_spmm(coo, b, impl=impl, k_pad=4))
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5,
                                   err_msg=impl)


def test_hybrid_all_empty_rows_matches_ref():
    """Every row empty: degrees are all zero, n_dense must be 0 and both
    siblings must return exact zeros (no garbage from the slab scatter)."""
    coo = coo_from_lists([_empty_sample()] * 3, [24, 24, 24])
    b = jnp.asarray(np.random.default_rng(4).normal(size=(3, 24, 16)),
                    jnp.float32)
    for impl in ("hybrid", "pallas_hybrid"):
        got = np.asarray(batched_spmm(coo, b, impl=impl, k_pad=1))
        assert not got.any(), impl


def test_hybrid_exact_threshold_row_classifies_dense():
    """A row whose degree sits EXACTLY at dmin is a hub (>= comparison):
    its sparse trip count must be zeroed and its nnz must land in the slab.
    Pre-fix (a strict > comparison) the row stays in the slot loop and the
    tile group sized for it is empty."""
    m_pad = 32
    hp = plan_hybrid(batch=1, m_pad=m_pad, n_b=16, nnz_pad=16)
    dmin = hp.dmin                      # 8 at tau = 0.25
    rows = np.concatenate([np.zeros(dmin, np.int32),
                           np.asarray([3, 9], np.int32)])
    cols = np.concatenate([np.arange(dmin, dtype=np.int32),
                           np.asarray([1, 2], np.int32)])
    vals = np.ones(rows.size, np.float32)
    coo = coo_from_lists([(rows, cols, vals)], [m_pad], nnz_pad=16)
    (rank_, start_s, rlen_sparse, rowmax_bins, cid_f, val_f,
     slab) = hybrid_operands(coo.row_ids, coo.col_ids, coo.values, coo.nnz,
                             m_pad, hp)
    deg = np.asarray(row_degrees(coo, m_pad))[0]
    assert deg[0] == dmin
    # row 0 sorts to position 0; as a hub its sparse trip count is zero...
    assert int(np.asarray(rlen_sparse)[0, 0]) == 0
    # ...and ALL of its nnz live in slab row 0 (unit values → sum == dmin)
    assert float(np.asarray(slab)[0, 0].sum()) == float(dmin)
    # the light rows keep their slots in the sparse remainder
    assert int(np.asarray(rlen_sparse)[0].sum()) == 2
    # and the forward stays exact
    b = jnp.asarray(np.random.default_rng(5).normal(size=(1, m_pad, 16)),
                    jnp.float32)
    want = np.asarray(batched_spmm(coo, b, impl="ref"))
    for impl in ("hybrid", "pallas_hybrid"):
        np.testing.assert_allclose(
            np.asarray(batched_spmm(coo, b, impl=impl, k_pad=dmin)), want,
            atol=1e-5, rtol=1e-5, err_msg=impl)


# ---------------------------------------------------------------------------
# row-permutation round trip (every concrete impl)
# ---------------------------------------------------------------------------

def _mixed_batch():
    """Uniform, skewed-with-hub, zero-nnz and single-long-row samples in ONE
    batch — the corners the permutation must survive."""
    rng = np.random.default_rng(6)
    m = 16
    uni_r = np.repeat(np.arange(m, dtype=np.int32), 2)
    uni_c = np.asarray(rng.integers(0, m, uni_r.size), np.int32)
    skew_r = np.concatenate([np.full(8, 2, np.int32),
                             np.asarray([0, 5, 11], np.int32)])
    skew_c = np.asarray(rng.integers(0, m, skew_r.size), np.int32)
    long_r = np.full(m, 7, np.int32)        # ONE row holding every nnz
    long_c = np.arange(m, dtype=np.int32)
    tri = [
        (uni_r, uni_c, rng.normal(size=uni_r.size).astype(np.float32)),
        (skew_r, skew_c, rng.normal(size=skew_r.size).astype(np.float32)),
        _empty_sample(),
        (long_r, long_c, rng.normal(size=m).astype(np.float32)),
    ]
    coo = coo_from_lists(tri, [m] * 4)
    b = jnp.asarray(rng.normal(size=(4, m, 24)), jnp.float32)
    return coo, m, b


@pytest.mark.parametrize("impl", CONCRETE_SPMM_IMPLS)
def test_row_permutation_round_trip(impl):
    """Relabel rows by a random per-sample permutation, run the impl, and
    inverse-permute the output: BITWISE equal to the unpermuted output (the
    per-row accumulation sequence is label-independent), and the gradients
    match the unpermuted ones at the impl's policy tolerance."""
    coo, m_pad, b = _mixed_batch()
    k_pad = m_pad                       # single-long-row needs the full bound
    rng = np.random.default_rng(7)
    pi = np.stack([rng.permutation(m_pad) for _ in range(coo.batch)])
    pi_j = jnp.asarray(pi, jnp.int32)
    coo_p = dataclasses.replace(
        coo, row_ids=jnp.take_along_axis(pi_j, coo.row_ids, axis=1))

    out = np.asarray(batched_spmm(coo, b, impl=impl, k_pad=k_pad))
    out_p = np.asarray(batched_spmm(coo_p, b, impl=impl, k_pad=k_pad))
    recover = np.take_along_axis(out_p, pi[:, :, None], axis=1)
    np.testing.assert_array_equal(recover, out, err_msg=impl)

    # gradients: same loss expressed through the permuted layout must give
    # the same dValues/dB as the unpermuted call
    t = jnp.asarray(np.random.default_rng(8).normal(size=out.shape),
                    jnp.float32)

    def loss(values, bb, a, weight):
        c = batched_spmm(dataclasses.replace(a, values=values), bb,
                         impl=impl, k_pad=k_pad)
        return jnp.sum(c.astype(jnp.float32) * weight)

    t_p = jnp.take_along_axis(
        t, jnp.argsort(pi_j, axis=1)[:, :, None], axis=1)
    g = jax.grad(loss, argnums=(0, 1))(coo.values, b, coo, t)
    g_p = jax.grad(loss, argnums=(0, 1))(coo_p.values, b, coo_p, t_p)
    atol, rtol = tols_for(impl)
    np.testing.assert_allclose(np.asarray(g_p[0]), np.asarray(g[0]),
                               atol=atol, rtol=rtol,
                               err_msg=f"{impl} dvalues")
    np.testing.assert_allclose(np.asarray(g_p[1]), np.asarray(g[1]),
                               atol=atol, rtol=rtol, err_msg=f"{impl} db")


# ---------------------------------------------------------------------------
# cost model: max_deg pricing + amortization rule
# ---------------------------------------------------------------------------

_SKEW_W = Workload(batch=100, m_pad=256, nnz_pad=2048, k_pad=None, n_b=256)


def test_workload_key_max_deg_suffix():
    w = Workload(batch=4, m_pad=64, nnz_pad=256, k_pad=8, n_b=32)
    assert "_md" not in w.key()         # legacy cache keys unchanged
    assert dataclasses.replace(w, max_deg=48).key() == w.key() + "_md48"


def test_csr_cost_prices_max_degree():
    """Satellite regression: the CSR kernel's slot loop serializes on the
    per-matrix MAX row degree, so its estimate must be strictly increasing
    in ``max_deg`` (pre-fix it only priced flat-nnz traffic and was flat)."""
    es = [estimate(dataclasses.replace(_SKEW_W, max_deg=md), "pallas_csr")
          for md in (2, 64, 128, 248)]
    assert es == sorted(es) and len(set(es)) == len(es), es
    # unset max_deg keeps the legacy flat estimate, well below the skew price
    assert 0.0 < estimate(_SKEW_W, "pallas_csr") < es[-1]


def test_hybrid_amortizes_only_under_skew():
    """The amortization rule (DESIGN.md §12): hybrid's permutation + slab
    overhead only pays when the measured max degree clears dmin — uniform
    degrees keep the CSR class ahead, hub degrees flip the order."""
    lo = dataclasses.replace(_SKEW_W, max_deg=4)
    hi = dataclasses.replace(_SKEW_W, max_deg=248)
    assert estimate(lo, "pallas_csr") < estimate(lo, "pallas_hybrid")
    assert estimate(hi, "pallas_hybrid") < estimate(hi, "pallas_csr")
    # the hybrid bound is dmin-1 BY CONSTRUCTION: its estimate is flat in
    # max_deg once above dmin, while csr keeps climbing
    mid = dataclasses.replace(_SKEW_W, max_deg=128)
    assert estimate(mid, "pallas_hybrid") == estimate(hi, "pallas_hybrid")
    # without skew evidence the hybrid class must never win the ranking
    assert KINDS[rank(_SKEW_W, allow_pallas=True)[0][0]] != "hybrid"


def test_hybrid_kinds_registered():
    assert KINDS["hybrid"] == KINDS["pallas_hybrid"] == "hybrid"
    assert KINDS["pallas_hybrid_bf16"] == "hybrid"
    assert KINDS["fused_hybrid"] == "fused"
    ranked = [i for i, _ in rank(_SKEW_W, allow_pallas=True)]
    assert "pallas_hybrid" in ranked and "hybrid" in ranked
    assert "pallas_hybrid" not in [
        i for i, _ in rank(_SKEW_W, allow_pallas=False)]


# ---------------------------------------------------------------------------
# powerlaw generator (the bench's skewed-degree geometry family)
# ---------------------------------------------------------------------------

def test_powerlaw_batch_is_skewed():
    rng = np.random.default_rng(9)
    coo, m_pad = random_powerlaw_batch(rng, batch=4, dim=64, avg_deg=4)
    deg = np.asarray(row_degrees(coo, m_pad))
    valid = deg[np.asarray(coo.n_rows)[:, None]
                > np.arange(m_pad)[None, :]]
    assert deg.max() >= 4 * max(1.0, valid.mean())   # hubs well above mean
    hp = plan_hybrid(batch=4, m_pad=m_pad, n_b=64, nnz_pad=coo.nnz_pad)
    assert deg.max() >= hp.dmin         # the hybrid split actually engages


# ---------------------------------------------------------------------------
# fused fold-in: epilogue corners + mesh-sharded parity
# ---------------------------------------------------------------------------

def test_fused_hybrid_residual_relu_matches_fused():
    """The inverse permutation must land BEFORE the residual/ReLU epilogue —
    a permuted residual add would silently mix rows."""
    from repro.core.graph_conv import init_graph_conv, stack_channels
    from repro.kernels.fused_graph_conv import fused_graph_conv

    coo, m_pad, _ = _mixed_batch()
    rng = np.random.default_rng(10)
    rids, cids, vals, nnz = stack_channels([coo, coo])
    x = jnp.asarray(rng.normal(size=(coo.batch, m_pad, 12)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(coo.batch, m_pad, 20)), jnp.float32)
    params = init_graph_conv(jax.random.key(10), 12, 20, 2)
    outs = {}
    for impl in ("fused", "fused_hybrid"):
        outs[impl] = np.asarray(fused_graph_conv(
            rids, cids, vals, nnz, x, params["w"], params["b"],
            epilogue="relu", residual=res, impl=impl))
    np.testing.assert_allclose(outs["fused_hybrid"], outs["fused"],
                               atol=1e-5, rtol=1e-5)


def test_fused_hybrid_sharded_parity():
    """Mesh-sharded fused_hybrid == local fused_hybrid, forward and dX, on a
    2-device host mesh (subprocess: XLA locks the device count at init)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = r"""
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core.formats import random_powerlaw_batch
from repro.core.graph_conv import graph_conv_batched, init_graph_conv
rng = np.random.default_rng(1)
coo, m_pad = random_powerlaw_batch(rng, batch=5, dim=24, avg_deg=4)
adj = [coo, coo]
x = jnp.asarray(rng.normal(size=(5, m_pad, 8)), jnp.float32)
params = init_graph_conv(jax.random.PRNGKey(1), 8, 16, 2)
mesh = jax.make_mesh((2,), ("data",))
def run(mesh=None):
    def loss(xx):
        return jnp.sum(jnp.sin(graph_conv_batched(
            params, adj, xx, impl="fused_hybrid", epilogue="relu",
            mesh=mesh)))
    return loss(x), jax.grad(loss)(x)
y0, g0 = run()
y1, g1 = run(mesh)
assert float(jnp.abs(y1 - y0).max()) == 0.0, "fwd"
assert float(jnp.abs(g1 - g0).max()) == 0.0, "grad"
print("OK")
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
           "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", script, src],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
