"""g-SpMM message-passing regression tests (DESIGN.md §11).

The (op × reduce × edge-kind) generalized-SpMM matrix against the pure-jnp
oracle across every g-SpMM-capable impl; segment_softmax; the GAT / R-GCN
layers against per-head/per-relation dense references; workload resolution
and the ELL-guard class gating; and mesh-sharded parity (subprocess, same
pattern as tests/test_sharded_spmm.py)."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from oracle import (
    GSPMM_EDGE_KINDS,
    GSPMM_MATRIX,
    check_gspmm_forward,
    check_gspmm_grads,
    gspmm_cases,
)
from repro.autotune import GSPMM_IMPLS, Workload, supports_gspmm
from repro.core import coo_from_lists, random_batch
from repro.core.spmm import GSPMM_OPS, GSPMM_REDUCES, batched_gspmm
from repro.kernels.segment_softmax import segment_softmax


# ---------------------------------------------------------------------------
# the full matrix: every capable impl × every (op, reduce) × both edge kinds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op,reduce", GSPMM_MATRIX)
@pytest.mark.parametrize("impl", GSPMM_IMPLS)
def test_gspmm_forward_vs_oracle(impl, op, reduce):
    for edges in GSPMM_EDGE_KINDS:
        check_gspmm_forward(impl, op, reduce, edges)


@pytest.mark.parametrize("op,reduce", GSPMM_MATRIX)
@pytest.mark.parametrize("impl", GSPMM_IMPLS)
def test_gspmm_grads_vs_oracle(impl, op, reduce):
    for edges in GSPMM_EDGE_KINDS:
        check_gspmm_grads(impl, op, reduce, edges)


@pytest.mark.parametrize("reduce", ["max", "mean"])
@pytest.mark.parametrize("impl", GSPMM_IMPLS)
def test_gspmm_zero_nnz_identity(impl, reduce):
    """Regression (ISSUE 7): a zero-nnz sample must emit the 0.0 identity —
    not the NEG_INF max sentinel, not a 0/0 NaN from the mean normalizer —
    for EVERY concrete impl, with finite (zero) gradients."""
    empty = (np.zeros(0, np.int32), np.zeros(0, np.int32),
             np.zeros(0, np.float32))
    coo = coo_from_lists([empty, empty], [16, 16])
    b = jnp.asarray(np.random.default_rng(3).normal(size=(2, 16, 8)),
                    jnp.float32)
    out = batched_gspmm(coo, b, op="mul", reduce=reduce, impl=impl, k_pad=4)
    np.testing.assert_array_equal(np.asarray(out), 0.0)

    g = jax.grad(lambda v, bb: jnp.sum(batched_gspmm(
        coo.with_values(v) if hasattr(coo, "with_values")
        else dataclasses.replace(coo, values=v),
        bb, op="mul", reduce=reduce, impl=impl, k_pad=4) ** 2),
        argnums=(0, 1))(coo.values, b)
    for leaf in g:
        arr = np.asarray(leaf)
        assert np.isfinite(arr).all()
        np.testing.assert_array_equal(arr, 0.0)


def test_gspmm_validates_op_reduce_and_impl():
    rng = np.random.default_rng(0)
    a, m_pad = random_batch(rng, batch=2, dim=8, nnz_per_row=2)
    b = jnp.ones((2, m_pad, 4), jnp.float32)
    with pytest.raises(ValueError, match="unknown g-SpMM op"):
        batched_gspmm(a, b, op="div")
    with pytest.raises(ValueError, match="unknown g-SpMM reduce"):
        batched_gspmm(a, b, reduce="min")
    # a reduced-precision variant cannot carry a non-default corner …
    with pytest.raises(ValueError, match="cannot run g-SpMM"):
        batched_gspmm(a, b, op="add", impl="csr_bf16")
    # … but the (mul, sum, scalar) corner IS plain batched SpMM and
    # delegates to the full registry, precision variants included
    out = batched_gspmm(a, b, op="mul", reduce="sum", impl="csr_bf16")
    assert out.shape == (2, m_pad, 4)


def test_gspmm_matrix_covers_all_corners():
    assert set(GSPMM_MATRIX) == {
        (op, red) for op in GSPMM_OPS for red in GSPMM_REDUCES}
    assert len(GSPMM_MATRIX) == 9


# ---------------------------------------------------------------------------
# segment_softmax
# ---------------------------------------------------------------------------

def _softmax_case():
    rng = np.random.default_rng(7)
    coo, m_pad = random_batch(rng, batch=3, dim=(8, 16), nnz_per_row=(1, 4))
    scores = jnp.asarray(rng.normal(size=coo.row_ids.shape), jnp.float32)
    return coo, m_pad, scores


def _softmax_ref(scores, row_ids, nnz, m_pad):
    """Pure-jnp per-row softmax (one-hot matmul formulation) — independent
    of the kernel's NEG_INF/clip machinery, fully autodiffable."""
    valid = jnp.arange(scores.shape[1])[None, :] < nnz[:, None]
    onehot = jax.nn.one_hot(row_ids, m_pad, dtype=jnp.float32)
    onehot = onehot * valid[..., None]
    e = jnp.exp(scores) * valid                    # small scores: no overflow
    denom = jnp.einsum("bnm,bn->bm", onehot, e)
    gath = jnp.einsum("bnm,bm->bn", onehot, denom)
    # invalid slots gather a 0 denominator; substitute 1.0 (not a tiny
    # epsilon — its square underflows f32 in the quotient backward → 0/0)
    return e / jnp.where(gath > 0, gath, 1.0)


def test_segment_softmax_rows_sum_to_one():
    coo, m_pad, scores = _softmax_case()
    alpha = segment_softmax(scores, coo.row_ids, nnz=coo.nnz, m_pad=m_pad)
    want = _softmax_ref(scores, coo.row_ids, coo.nnz, m_pad)
    np.testing.assert_allclose(np.asarray(alpha), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    # per-destination-row mass: exactly 1 for rows with edges, 0 otherwise
    onehot = jax.nn.one_hot(coo.row_ids, m_pad, dtype=jnp.float32)
    valid = (jnp.arange(scores.shape[1])[None, :]
             < coo.nnz[:, None]).astype(jnp.float32)
    mass = jnp.einsum("bnm,bn->bm", onehot * valid[..., None], alpha)
    deg = jnp.einsum("bnm,bn->bm", onehot, valid)
    np.testing.assert_allclose(np.asarray(mass),
                               np.asarray((deg > 0).astype(jnp.float32)),
                               atol=1e-5)


def test_segment_softmax_grads_match_autodiff_ref():
    coo, m_pad, scores = _softmax_case()
    g = jax.grad(lambda s: jnp.sum(jnp.tanh(segment_softmax(
        s, coo.row_ids, nnz=coo.nnz, m_pad=m_pad))))(scores)
    g_ref = jax.grad(lambda s: jnp.sum(jnp.tanh(
        _softmax_ref(s, coo.row_ids, coo.nnz, m_pad))))(scores)
    valid = np.asarray(
        jnp.arange(scores.shape[1])[None, :] < coo.nnz[:, None], np.float32)
    np.testing.assert_allclose(np.asarray(g) * valid,
                               np.asarray(g_ref) * valid,
                               atol=1e-5, rtol=1e-5)
    assert np.isfinite(np.asarray(g)).all()


def test_segment_softmax_zero_nnz_finite():
    """All-empty batch: zero attention, zero (finite) gradient — the
    zero-degree rows of a GAT wave must not NaN the step."""
    empty = (np.zeros(0, np.int32), np.zeros(0, np.int32),
             np.zeros(0, np.float32))
    coo = coo_from_lists([empty, empty], [8, 8])
    scores = jnp.asarray(np.random.default_rng(1).normal(
        size=coo.row_ids.shape), jnp.float32)
    alpha = segment_softmax(scores, coo.row_ids, nnz=coo.nnz, m_pad=8)
    np.testing.assert_array_equal(np.asarray(alpha), 0.0)
    g = jax.grad(lambda s: jnp.sum(segment_softmax(
        s, coo.row_ids, nnz=coo.nnz, m_pad=8) ** 2))(scores)
    np.testing.assert_array_equal(np.asarray(g), 0.0)


# ---------------------------------------------------------------------------
# GAT / R-GCN layers vs dense per-head / per-relation references
# ---------------------------------------------------------------------------

def _layer_geometry():
    rng = np.random.default_rng(5)
    coo, m_pad = random_batch(rng, batch=3, dim=(10, 16), nnz_per_row=(1, 4))
    x = jnp.asarray(rng.normal(size=(3, m_pad, 10)), jnp.float32)
    return coo, m_pad, x


def test_gat_layer_vs_dense_reference():
    from repro.models.gnn import gat_layer, init_gat_layer

    coo, m_pad, x = _layer_geometry()
    heads, n_out = 2, 8
    d_head = n_out // heads
    p = init_gat_layer(jax.random.PRNGKey(2), x.shape[-1], n_out, heads)
    out = gat_layer(p, coo, x, impl="ref")

    ref_out = np.zeros((x.shape[0], m_pad, n_out), np.float32)
    for b in range(x.shape[0]):
        nz = int(coo.nnz[b])
        rid = np.asarray(coo.row_ids[b][:nz])
        cid = np.asarray(coo.col_ids[b][:nz])
        for h_i in range(heads):
            hb = np.asarray(x[b]) @ np.asarray(p["w"][h_i])
            logit = (hb @ np.asarray(p["a_src"][h_i]))[cid] \
                + (hb @ np.asarray(p["a_dst"][h_i]))[rid]
            logit = np.where(logit >= 0, logit, 0.2 * logit)
            for r in range(m_pad):
                sel = rid == r
                if not sel.any():
                    continue
                e = np.exp(logit[sel] - logit[sel].max())
                alpha = e / e.sum()
                ref_out[b, r, h_i * d_head:(h_i + 1) * d_head] = (
                    alpha[:, None] * hb[cid[sel]]).sum(0)
    np.testing.assert_allclose(np.asarray(out), ref_out + np.asarray(p["b"]),
                               atol=1e-4, rtol=1e-4)

    g = jax.grad(lambda pp: jnp.sum(gat_layer(pp, coo, x, impl="ref") ** 2))(p)
    assert all(bool(jnp.isfinite(v).all())
               for v in jax.tree_util.tree_leaves(g))


def test_rgcn_layer_vs_dense_reference():
    from repro.models.gnn import init_rgcn_layer, rgcn_layer

    coo, m_pad, x = _layer_geometry()
    rng = np.random.default_rng(21)
    adjs = [coo, random_batch(rng, batch=3, dim=m_pad, nnz_per_row=2)[0]]
    n_out = 8
    p = init_rgcn_layer(jax.random.PRNGKey(3), x.shape[-1], n_out, len(adjs))
    out = rgcn_layer(p, adjs, x, impl="ref")

    ref_out = np.zeros((x.shape[0], m_pad, n_out), np.float32)
    for b in range(x.shape[0]):
        for r_i, a in enumerate(adjs):
            nz = int(a.nnz[b])
            rid = np.asarray(a.row_ids[b][:nz])
            cid = np.asarray(a.col_ids[b][:nz])
            hb = np.asarray(x[b]) @ np.asarray(p["w_rel"][r_i])
            for row in range(m_pad):
                sel = rid == row
                if sel.any():
                    ref_out[b, row] += hb[cid[sel]].mean(0)
    want = (ref_out + np.asarray(x) @ np.asarray(p["w_self"])
            + np.asarray(p["b"]))
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-4, rtol=1e-4)

    g = jax.grad(
        lambda pp: jnp.sum(rgcn_layer(pp, adjs, x, impl="ref") ** 2))(p)
    assert all(bool(jnp.isfinite(v).all())
               for v in jax.tree_util.tree_leaves(g))


def test_grouped_matmul_vjp_vs_dense():
    """grouped_matmul's custom VJP (pallas_call has no autodiff rule) vs
    autodiff of the per-row dense gather formulation — both operands."""
    from repro.kernels.grouped_matmul import grouped_matmul

    x = jax.random.normal(jax.random.PRNGKey(0), (20, 6))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 6, 5))
    sizes = jnp.asarray([7, 9, 4], jnp.int32)
    rg = np.repeat([0, 1, 2], [7, 9, 4])

    def f(x, w):
        return jnp.sum(jnp.sin(grouped_matmul(x, w, sizes, tm=8)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(jnp.einsum("mk,mkn->mn", x, w[rg])))

    g = jax.grad(f, argnums=(0, 1))(x, w)
    g_ref = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(g_ref[0]),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(g_ref[1]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# workload resolution + config plumbing
# ---------------------------------------------------------------------------

def test_workload_gspmm_keys_and_capability():
    base = dict(batch=4, m_pad=64, nnz_pad=32, k_pad=8, n_b=48, itemsize=4)
    assert not Workload(**base).is_gspmm
    assert Workload(**base, reduce="max").is_gspmm
    assert Workload(**base, op="copy_lhs").is_gspmm
    assert Workload(**base, d_e=48).is_gspmm
    key = Workload(**base, d_e=48, reduce="max", op="copy_lhs").key()
    assert key.endswith("_e48_rmax_ocopy_lhs")
    assert "_r" not in Workload(**base).key()

    for impl in GSPMM_IMPLS:
        assert supports_gspmm(impl)
    for impl in ("dense", "pallas_gemm", "csr_bf16", "pallas_ell_i8",
                 "fused", "auto"):
        assert not supports_gspmm(impl)


def test_resolve_gspmm_impl_stays_in_capable_set():
    from repro.core.spmm import resolve_gspmm_impl

    rng = np.random.default_rng(0)
    a, m_pad = random_batch(rng, batch=4, dim=24, nnz_per_row=3)
    b = jnp.ones((4, m_pad, 16), jnp.float32)
    for op, reduce in GSPMM_MATRIX:
        d = resolve_gspmm_impl(a, b, op=op, reduce=reduce, k_pad=8)
        if (op, reduce) == ("mul", "sum"):
            # scalar edges: that corner IS plain batched SpMM — the full
            # registry (dense, precision variants) stays in play
            continue
        assert d.impl in GSPMM_IMPLS, (op, reduce, d.impl)
        assert all(i in GSPMM_IMPLS for i, _ in d.scores)


def test_message_passing_matches_batched_gspmm():
    from repro.core.message_passing import (
        message_passing,
        resolve_message_passing_impl,
    )

    rng = np.random.default_rng(9)
    a, m_pad = random_batch(rng, batch=2, dim=12, nnz_per_row=2)
    x = jnp.asarray(rng.normal(size=(2, m_pad, 6)), jnp.float32)
    want = batched_gspmm(a, x, op="copy_lhs", reduce="max", impl="csr")
    got = message_passing(a, x, op="copy_lhs", reduce="max", impl="csr")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    d = resolve_message_passing_impl(a, x, op="copy_lhs", reduce="max")
    assert d.impl in GSPMM_IMPLS


def test_resolve_conv_impls_layer_kinds():
    from repro.core.gcn import GCNConfig, resolve_conv_impls

    geom = dict(batch=8, m_pad=64, nnz_pad=256)
    for layer in ("gcn", "gat", "rgcn"):
        cfg = GCNConfig.tox21(layer=layer, interpret=True)
        ds = resolve_conv_impls(cfg, **geom)
        assert len(ds) == len(cfg.conv_widths)
        forced = resolve_conv_impls(
            GCNConfig.tox21(layer=layer, impl="csr", interpret=True), **geom)
        assert all(d.impl == "csr" and d.source == "forced" for d in forced)
    gat = resolve_conv_impls(
        GCNConfig.tox21(layer="gat", interpret=True), **geom)
    assert all(d.impl in GSPMM_IMPLS for d in gat)


def test_gcn_config_rejects_bad_layer_kinds():
    from repro.core.gcn import GCNConfig, apply_gcn, init_gcn

    with pytest.raises(ValueError, match="unknown layer kind"):
        init_gcn(jax.random.PRNGKey(0), GCNConfig.tox21(layer="sage"))
    cfg = GCNConfig.tox21(layer="gat", batched=False, interpret=True)
    params = init_gcn(jax.random.PRNGKey(0), GCNConfig.tox21(layer="gat"))
    rng = np.random.default_rng(0)
    adj, m_pad = random_batch(rng, batch=2, dim=8, nnz_per_row=2)
    x = jnp.ones((2, m_pad, cfg.n_features), jnp.float32)
    with pytest.raises(ValueError, match="requires batched=True"):
        apply_gcn(params, cfg, [adj] * cfg.channels, x,
                  jnp.asarray([4, 4], jnp.int32))


def test_ell_guard_ors_over_every_layer_decision(monkeypatch):
    """The engine's ELL degree guard must trip when ANY conv layer's
    decision lands in the ELL class — including reduced-precision ELL
    variants — not just the first layer's (regression: ISSUE 7 satellite)."""
    import repro.core.gcn as gcn_mod
    from repro.core.gcn import GCNConfig, init_gcn
    from repro.serving.engine import GraphServeEngine

    cfg = GCNConfig.tox21(interpret=True)
    params = init_gcn(jax.random.PRNGKey(0), cfg)

    def fake_resolver(mixed_impls):
        def resolve(cfg, batch, m_pad, nnz_pad, *, itemsize=4, mesh=None):
            from repro.autotune import Workload, forced_decision

            w = Workload(batch=batch, m_pad=m_pad, nnz_pad=nnz_pad,
                         k_pad=cfg.k_pad, n_b=64, itemsize=itemsize)
            return tuple(forced_decision(w, i) for i in mixed_impls)
        return resolve

    # deep layer resolves to a reduced-precision ELL variant → guard on
    monkeypatch.setattr(gcn_mod, "resolve_conv_impls",
                        fake_resolver(["csr", "ell_bf16"]))
    eng = GraphServeEngine(params, cfg, batch=4)
    assert eng._ell_degree_guard
    # no layer in the ELL class → guard off
    monkeypatch.setattr(gcn_mod, "resolve_conv_impls",
                        fake_resolver(["csr", "pallas_coo"]))
    eng = GraphServeEngine(params, cfg, batch=4)
    assert not eng._ell_degree_guard
    # forced concrete ELL impl bypasses the resolver entirely → guard on
    eng = GraphServeEngine(
        params, dataclasses.replace(cfg, impl="pallas_ell_bf16"), batch=4)
    assert eng._ell_degree_guard


def test_ops_docstring_lists_every_impl():
    """The impl table in kernels/ops.py is GENERATED from IMPLS (ISSUE 7
    satellite: the hand-written list had drifted) — every registry entry
    must appear in the rendered module docstring."""
    from repro.core.spmm import IMPLS
    from repro.kernels import ops

    for impl in IMPLS:
        assert f"'{impl}'" in ops.__doc__, impl


# ---------------------------------------------------------------------------
# end-to-end: GAT trains via GCNTrainer and serves via the engine/scheduler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layer", ["gat", "rgcn"])
def test_layer_trains_and_serves(layer, tmp_path):
    from repro.core.gcn import GCNConfig
    from repro.data.graphs import GraphDatasetSpec, batches, generate
    from repro.serving import GraphRequest, GraphServeEngine
    from repro.training import GCNTrainer, TrainerConfig

    spec = GraphDatasetSpec.tox21_like(n_samples=16)
    data = generate(spec)
    cfg = GCNConfig.tox21(layer=layer, interpret=True)
    trainer = GCNTrainer(cfg, tcfg=TrainerConfig(
        checkpoint_dir=str(tmp_path), checkpoint_every=1000))
    params, _, metrics = trainer.fit(
        lambda e: batches(data, spec, 8, seed=e), epochs=1)
    assert np.isfinite(metrics["loss"])

    reqs = [GraphRequest(rows=s.rows, cols=s.cols, features=s.features,
                         n_nodes=s.n_nodes) for s in data[:3]]
    out = GraphServeEngine(params, cfg, batch=4).run(reqs)
    assert all(r.done and r.logits.shape == (cfg.n_tasks,) for r in out)


def test_gat_serves_via_scheduler_auto_per_tier():
    """A GAT model rides the continuous-batching scheduler: every request
    completes, and each geometry tier's program records an ``impl="auto"``
    decision resolved against THAT tier's g-SpMM workload."""
    from repro.core.gcn import GCNConfig, init_gcn
    from repro.data.graphs import GraphDatasetSpec, generate
    from repro.scheduler import Scheduler, TierPolicy, VirtualClock
    from repro.serving import GraphRequest

    spec = GraphDatasetSpec.tox21_like(
        n_samples=12, n_features=8, channels=2, size_dist="skewed", seed=1)
    data = generate(spec)
    cfg = GCNConfig(n_features=8, channels=2, conv_widths=(8,), n_tasks=3,
                    layer="gat", heads=2, interpret=True)
    params = init_gcn(jax.random.PRNGKey(0), cfg)
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=2, batch=4)
    sched = Scheduler(params, cfg, tiers=policy, clock=VirtualClock())
    out = sched.serve([GraphRequest(rows=s.rows, cols=s.cols,
                                    features=s.features, n_nodes=s.n_nodes)
                       for s in data])
    assert all(r.done and not r.failed for r in out)
    assert all(r.logits.shape == (cfg.n_tasks,) for r in out)
    decisions = sched.programs.decisions()
    assert decisions
    assert all(d.impl in GSPMM_IMPLS for d in decisions.values())


# ---------------------------------------------------------------------------
# mesh-sharded parity (8-device subprocess, as in test_sharded_spmm.py)
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_sharded_gspmm_matches_local():
    script = r"""
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.core.formats import random_batch
from repro.distributed.spmm import sharded_batched_gspmm
from repro.kernels.ops import batched_gspmm
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
a, m_pad = random_batch(rng, batch=12, dim=24, nnz_per_row=3)  # 12 % 8 != 0
b = jnp.asarray(rng.standard_normal((12, m_pad, 16)), jnp.float32)
for op, red in (("mul", "max"), ("copy_lhs", "mean"), ("add", "sum")):
    ref = batched_gspmm(a, b, op=op, reduce=red, impl="csr", k_pad=8)
    got = sharded_batched_gspmm(a, b, op=op, reduce=red, mesh=mesh,
                                impl="csr", k_pad=8)
    assert float(jnp.max(jnp.abs(ref - got))) < 1e-5, (op, red)

    def loss(f):
        return lambda v, bb: jnp.sum(jnp.tanh(f(a.with_values(v), bb)))

    f_ref = lambda aa, bb: batched_gspmm(aa, bb, op=op, reduce=red,
                                         impl="csr", k_pad=8)
    f_sh = lambda aa, bb: sharded_batched_gspmm(aa, bb, op=op, reduce=red,
                                                mesh=mesh, impl="csr",
                                                k_pad=8)
    gr = jax.grad(loss(f_ref), argnums=(0, 1))(a.values, b)
    gs = jax.grad(loss(f_sh), argnums=(0, 1))(a.values, b)
    assert float(jnp.max(jnp.abs(gr[0] - gs[0]))) < 1e-5, (op, red)
    assert float(jnp.max(jnp.abs(gr[1] - gs[1]))) < 1e-5, (op, red)
print("SHARDED-GSPMM-OK")
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "JAX_PLATFORMS": "cpu"}
    r = subprocess.run([sys.executable, "-c", script, SRC],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SHARDED-GSPMM-OK" in r.stdout
