"""Continuous-batching scheduler: buckets, dispatch, parity, metrics.

The load-bearing assertions (ISSUE acceptance criteria):

- scheduler outputs are BITWISE-identical to per-request GraphServeEngine
  scoring at the same wave geometry (bn_mode="sample" numerics);
- the program cache compiles exactly one program per geometry tier used;
- on a mixed-size stream with Poisson arrivals, bucketed continuous
  batching beats the fixed-wave baseline on padding waste AND p99 latency
  (deterministic service model — no wall-clock flakiness);
- oversize requests are failed cleanly, never killing a wave.
"""
import collections

import jax
import numpy as np
import pytest

from repro.core.batching import tier_ladder
from repro.core.gcn import GCNConfig, init_gcn
from repro.data.graphs import GraphDatasetSpec, generate
from repro.scheduler import (
    AdmissionQueue,
    ContinuousDispatcher,
    GeometryTier,
    Scheduler,
    SchedulerConfig,
    TierPolicy,
    VirtualClock,
    Wait,
    WavePlan,
)
from repro.serving import GraphRequest, GraphServeEngine


# ---------------------------------------------------------------------------
# pure policy pieces (no jax)
# ---------------------------------------------------------------------------

def test_tier_ladder_rounds_and_covers_max():
    rungs = tier_ladder(m_max=50, nnz_max=300, levels=3)
    assert all(m % 8 == 0 and z % 8 == 0 for m, z in rungs)
    m_top, z_top = rungs[-1]
    assert m_top >= 50 and z_top >= 300
    assert rungs == tuple(sorted(rungs))
    assert 1 <= len(rungs) <= 3


def test_tier_policy_smallest_fit_and_oversize():
    pol = TierPolicy(m_pads=(16, 32, 56), nnz_pads=(64, 128, 256), batch=4)
    assert pol.tier_for(10, 30).m_pad == 16
    assert pol.tier_for(10, 100).m_pad == 32      # nnz pushes a tier up
    assert pol.tier_for(40, 30).m_pad == 56
    assert pol.tier_for(57, 30) is None           # no bucket: clean reject
    assert pol.tier_for(10, 300) is None


def test_tier_policy_rejects_non_monotone_ladder():
    with pytest.raises(ValueError, match="non-monotone"):
        TierPolicy(m_pads=(16, 32), nnz_pads=(128, 64), batch=4)


def test_tier_policy_from_requests_never_nnz_bounces():
    """from_requests: any request fitting a rung's m_pad also fits its
    nnz_pad (nnz derived from the data, not an uncorrelated ladder)."""
    rng = np.random.default_rng(0)
    geoms = [(int(n), int(2.5 * n + rng.integers(0, 10)))
             for n in rng.integers(8, 51, 200)]
    pol = TierPolicy.from_requests(geoms, levels=3, batch=8)
    for n, z in geoms:
        t = pol.tier_for(n, z)
        assert t is not None
        # the chosen tier is decided by the node ladder alone
        t_by_m = next(x for x in pol.tiers if n <= x.m_pad)
        assert t == t_by_m, (n, z, t, t_by_m)


def test_admission_queue_orders_by_arrival_then_fifo():
    q = AdmissionQueue()
    r = lambda: GraphRequest(rows=[np.zeros(0, np.int32)],
                             cols=[np.zeros(0, np.int32)],
                             features=np.zeros((1, 4), np.float32), n_nodes=1)
    q.submit(r(), arrival=2.0)
    a = q.submit(r(), arrival=1.0)
    b = q.submit(r(), arrival=1.0)
    assert q.next_arrival() == 1.0
    due = q.due(1.5)
    assert [p.seq for p in due] == [a.seq, b.seq]
    assert len(q) == 1 and q.next_arrival() == 2.0
    assert q.due(2.5)[0].arrival == 2.0 and len(q) == 0


def _pending(tier, arrival, seq, deadline=None):
    from repro.scheduler.queue import PendingRequest

    p = PendingRequest(seq=seq, request=None, arrival=arrival,
                       deadline=deadline)
    p.tier = tier
    return p


def _buckets(policy, *entries):
    b = {t: collections.deque() for t in policy.tiers}
    for tier, arrival, seq in entries:
        b[tier].append(_pending(tier, arrival, seq))
    return b


def test_dispatcher_full_bucket_dispatches_immediately():
    pol = TierPolicy(m_pads=(16, 56), nnz_pads=(64, 256), batch=2)
    small, big = pol.tiers
    d = ContinuousDispatcher(flush_after=10.0)
    b = _buckets(pol, (small, 0.0, 0), (small, 0.0, 1))
    plan = d.next_wave(b, now=0.0)
    assert isinstance(plan, WavePlan)
    assert plan.tier == small and plan.count == 2


def test_dispatcher_pool_readiness_tops_up_larger_wave():
    """A burst split across buckets launches ONE full wave at the largest
    tier present, smaller requests riding its spare slots."""
    pol = TierPolicy(m_pads=(16, 56), nnz_pads=(64, 256), batch=4)
    small, big = pol.tiers
    d = ContinuousDispatcher(flush_after=10.0)
    b = _buckets(pol, (small, 0.0, 0), (small, 0.0, 1), (small, 0.0, 2),
                 (big, 0.0, 3))
    plan = d.next_wave(b, now=0.0)
    assert isinstance(plan, WavePlan) and plan.tier == big
    assert dict(plan.takes) == {big: 1, small: 3}
    # without top-up neither bucket is ready
    d2 = ContinuousDispatcher(flush_after=10.0, topup=False)
    assert isinstance(d2.next_wave(b, now=0.0), Wait)


def test_dispatcher_flush_after_waits_then_flushes():
    pol = TierPolicy(m_pads=(16,), nnz_pads=(64,), batch=4)
    (tier,) = pol.tiers
    d = ContinuousDispatcher(flush_after=1.0)
    b = _buckets(pol, (tier, 0.0, 0))
    w = d.next_wave(b, now=0.5)
    assert isinstance(w, Wait) and w.until == pytest.approx(1.0)
    plan = d.next_wave(b, now=w.until)     # the wait target itself is ready
    assert isinstance(plan, WavePlan) and plan.count == 1


def test_dispatcher_draining_flushes_everything():
    pol = TierPolicy(m_pads=(16, 56), nnz_pads=(64, 256), batch=4)
    small, big = pol.tiers
    d = ContinuousDispatcher(flush_after=100.0)
    b = _buckets(pol, (small, 0.0, 0))
    assert isinstance(d.next_wave(b, now=0.0), Wait)
    plan = d.next_wave(b, now=0.0, draining=True)
    assert isinstance(plan, WavePlan) and plan.tier == small


def test_dispatcher_deadline_slack_forces_early_flush():
    pol = TierPolicy(m_pads=(16,), nnz_pads=(64,), batch=4)
    (tier,) = pol.tiers
    d = ContinuousDispatcher(flush_after=1.0)
    b = {tier: collections.deque([_pending(tier, 0.0, 0, deadline=1.2)])}
    # slack 1.2 > flush_after at t=0 → wait, but only until slack == 1.0
    w = d.next_wave(b, now=0.0)
    assert isinstance(w, Wait) and w.until == pytest.approx(0.2)
    assert isinstance(d.next_wave(b, now=0.2), WavePlan)


def test_dispatcher_younger_requests_tight_deadline_pulls_flush():
    """The bucket's TIGHTEST deadline drives the flush, even when it sits
    behind a deadline-less older request at the head of the queue."""
    pol = TierPolicy(m_pads=(16,), nnz_pads=(64,), batch=4)
    (tier,) = pol.tiers
    d = ContinuousDispatcher(flush_after=1.0)
    b = {tier: collections.deque([
        _pending(tier, 0.0, 0),                   # no deadline, oldest
        _pending(tier, 0.1, 1, deadline=0.5),     # younger, tight SLO
    ])}
    w = d.next_wave(b, now=0.0)
    # flush at deadline - flush_after → already due at t=0 would be -0.5,
    # clamped by readiness: now >= flush_at → dispatch immediately
    assert isinstance(w, WavePlan) and w.count == 2


# ---------------------------------------------------------------------------
# end-to-end (small GCN)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_setup():
    spec = GraphDatasetSpec.tox21_like(
        n_samples=24, n_features=8, channels=2, size_dist="skewed", seed=1)
    data = generate(spec)
    cfg = GCNConfig(n_features=8, channels=2, conv_widths=(8,), n_tasks=3)
    params = init_gcn(jax.random.key(0), cfg)
    return spec, data, cfg, params


def _reqs(data):
    return [GraphRequest(rows=s.rows, cols=s.cols, features=s.features,
                         n_nodes=s.n_nodes) for s in data]


def test_scheduler_serves_all_and_compiles_once_per_tier(small_setup):
    spec, data, cfg, params = small_setup
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=3, batch=4)
    sched = Scheduler(params, cfg, tiers=policy, clock=VirtualClock())
    out = sched.serve(_reqs(data))
    assert all(r.done and not r.failed for r in out)
    assert all(r.logits.shape == (cfg.n_tasks,) for r in out)
    used = {w.tier_key for w in sched.metrics.waves}
    assert sched.metrics.compile_count == len(used) <= len(policy.tiers)
    # the one-compilation-per-tier invariant, straight from the jit caches
    assert set(sched.programs.jit_cache_sizes().values()) == {1}
    # every tier program records its autotune layer decision
    assert all(d.impl for d in sched.programs.decisions().values())


def test_scheduler_bitwise_matches_per_request_engine(small_setup):
    """Acceptance: scheduler outputs == per-request GraphServeEngine scoring,
    bitwise, at the wave geometry each request actually rode."""
    import dataclasses

    spec, data, cfg, params = small_setup
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=3, batch=4)
    sched = Scheduler(params, cfg, tiers=policy, clock=VirtualClock())
    sched.serve(_reqs(data))
    cfg_sample = dataclasses.replace(cfg, bn_mode="sample")
    engines = {}
    for p in sched.completed:
        tier = p.served_tier
        if tier not in engines:
            engines[tier] = GraphServeEngine(
                params, cfg_sample, batch=tier.batch, m_pad=tier.m_pad,
                nnz_pad=tier.nnz_pad)
        s = data[p.seq]
        solo = GraphRequest(rows=s.rows, cols=s.cols, features=s.features,
                            n_nodes=s.n_nodes)
        engines[tier].run([solo])
        np.testing.assert_array_equal(solo.logits, p.request.logits)


def test_degree_overflow_request_fails_cleanly_not_the_wave(small_setup):
    """ELL silent-drop guard at the serving boundary (ISSUE 5): a request
    whose max row degree exceeds cfg.k_pad soft-fails (an ELL impl would
    silently zero its edges under jit) and the rest of the wave survives."""
    import dataclasses

    spec, data, cfg, params = small_setup
    cfg = dataclasses.replace(cfg, impl="ell")   # pin the ELL-class layer
    assert cfg.k_pad is not None
    deg = cfg.k_pad + 2
    hot = GraphRequest(        # one node with `deg` out-edges per channel
        rows=[np.zeros(deg, np.int32)] * cfg.channels,
        cols=[np.arange(deg, dtype=np.int32)] * cfg.channels,
        features=np.zeros((deg + 1, cfg.n_features), np.float32),
        n_nodes=deg + 1)
    normal = _reqs(data[:3])
    engine = GraphServeEngine(params, cfg, batch=4)
    out = engine.run([hot] + normal)
    assert hot.failed and not hot.done
    assert "max row degree" in hot.error
    assert all(r.done and not r.failed for r in normal)


def test_malformed_edge_ids_fail_cleanly_not_the_wave(small_setup):
    """_validate's never-raises contract extends to malformed requests: a
    negative or out-of-range edge id soft-fails the request (it would blow
    up the degree guard's bincount or corrupt the wave's scatter) and the
    rest of the wave survives."""
    spec, data, cfg, params = small_setup
    bad = GraphRequest(
        rows=[np.asarray([-1, 0], np.int32)] * cfg.channels,
        cols=[np.asarray([0, 1], np.int32)] * cfg.channels,
        features=np.zeros((4, cfg.n_features), np.float32), n_nodes=4)
    normal = _reqs(data[:3])
    out = GraphServeEngine(params, cfg, batch=4).run([bad] + normal)
    assert bad.failed and not bad.done
    assert "edge ids outside" in bad.error
    assert all(r.done and not r.failed for r in normal)


def test_oversize_request_fails_cleanly_not_the_wave(small_setup):
    spec, data, cfg, params = small_setup
    big_nodes = 200
    oversize = GraphRequest(
        rows=[np.zeros(2, np.int32)] * cfg.channels,
        cols=[np.zeros(2, np.int32)] * cfg.channels,
        features=np.zeros((big_nodes, cfg.n_features), np.float32),
        n_nodes=big_nodes)
    normal = _reqs(data[:3])
    policy = TierPolicy(m_pads=(56,), nnz_pads=(128,), batch=4)
    sched = Scheduler(params, cfg, tiers=policy, clock=VirtualClock())
    sched.serve([oversize] + normal)
    assert oversize.failed and not oversize.done
    assert "no geometry tier fits" in oversize.error
    assert all(r.done and not r.failed for r in normal)
    assert sched.metrics.rejected == 1 and sched.metrics.served == 3


def test_engine_validate_marks_failed_wave_survives(small_setup):
    """Engine-level soft failure: an oversize request inside a wave is
    marked failed; the other slots still get logits."""
    spec, data, cfg, params = small_setup
    eng = GraphServeEngine(params, cfg, batch=4, m_pad=16, nnz_pad=64)
    small = [s for s in data if s.n_nodes <= 16][:2]
    assert small, "need small samples"
    good = _reqs(small)
    bad = GraphRequest(
        rows=[np.zeros(1, np.int32)] * cfg.channels,
        cols=[np.zeros(1, np.int32)] * cfg.channels,
        features=np.zeros((30, cfg.n_features), np.float32), n_nodes=30)
    report = eng.run_wave(good + [bad])
    assert bad.failed and "exceeds wave m_pad" in bad.error
    assert all(r.done and r.logits is not None for r in good)
    assert report.n_failed == 1 and report.n_requests == 3


def test_scheduler_routes_to_bigger_bucket_on_nnz(small_setup):
    """A small-node but edge-dense request lands in a bigger bucket rather
    than failing (the nnz dimension of tier_for)."""
    spec, data, cfg, params = small_setup
    dense = GraphRequest(
        rows=[np.zeros(100, np.int32)] * cfg.channels,
        cols=[np.zeros(100, np.int32)] * cfg.channels,
        features=np.ones((10, cfg.n_features), np.float32), n_nodes=10)
    policy = TierPolicy(m_pads=(16, 56), nnz_pads=(64, 128), batch=2)
    sched = Scheduler(params, cfg, tiers=policy, clock=VirtualClock())
    sched.serve([dense])
    assert dense.done and not dense.failed
    assert sched.completed[0].tier.m_pad == 56     # routed up by nnz


def test_virtual_clock_arrivals_respected(small_setup):
    spec, data, cfg, params = small_setup
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=2, batch=4)
    sched = Scheduler(
        params, cfg, tiers=policy, clock=VirtualClock(),
        service_model=lambda tier, n: 0.001,
        config=SchedulerConfig(batch=4, flush_after=0.5))
    reqs = _reqs(data[:6])
    arrivals = [0.0, 0.0, 1.0, 1.0, 5.0, 5.0]
    sched.serve(reqs, arrivals=arrivals)
    for p in sched.completed:
        assert p.dispatch >= p.arrival
        assert p.wait <= 0.5 + 1e-9 or p.dispatch == pytest.approx(p.arrival)
    # flush_after honored: nobody waits (much) past the straggler guard
    assert max(p.wait for p in sched.completed) <= 0.5 + 1e-9


def test_fixed_wave_matches_legacy_engine_run(small_setup):
    """Scheduler.fixed_wave reproduces the legacy fixed-slicing semantics:
    same wave partitioning, same logits as GraphServeEngine.run."""
    import dataclasses

    spec, data, cfg, params = small_setup
    cfg_sample = dataclasses.replace(cfg, bn_mode="sample")
    legacy = GraphServeEngine(params, cfg_sample, batch=4, m_pad=56,
                              nnz_pad=128)
    legacy_reqs = _reqs(data[:10])
    legacy.run(legacy_reqs)
    sched = Scheduler.fixed_wave(params, cfg, batch=4, m_pad=56, nnz_pad=128,
                                 clock=VirtualClock())
    sched_reqs = _reqs(data[:10])
    sched.serve(sched_reqs)
    assert sched.metrics.compile_count == 1
    assert len(sched.metrics.waves) == 3           # 4+4+2, FIFO slicing
    for a, b in zip(legacy_reqs, sched_reqs):
        np.testing.assert_array_equal(a.logits, b.logits)


def test_deadline_miss_accounting(small_setup):
    spec, data, cfg, params = small_setup
    policy = TierPolicy(m_pads=(56,), nnz_pads=(128,), batch=4)
    sched = Scheduler(
        params, cfg, tiers=policy, clock=VirtualClock(),
        service_model=lambda tier, n: 1.0,          # service alone busts SLO
        config=SchedulerConfig(batch=4, flush_after=0.1))
    reqs = _reqs(data[:2])
    sched.serve(reqs, deadlines=[0.5, 2.5])
    assert all(r.done for r in reqs)
    assert sched.metrics.deadline_misses == 1


# ---------------------------------------------------------------------------
# acceptance: bucketed vs fixed on a mixed Poisson stream
# ---------------------------------------------------------------------------

def test_bucketed_beats_fixed_wave_on_waste_and_p99(small_setup):
    """Deterministic service model (cost ∝ wave node capacity): bucketed
    continuous batching wins padding waste AND p99 latency, with compile
    count == number of geometry tiers used."""
    spec, data, cfg, params = small_setup
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=3, batch=4)
    top = policy.tiers[-1]

    def svc(tier, n):                   # deterministic: ∝ node capacity
        return 1e-3 * tier.m_pad / top.m_pad

    batch = 4
    wave_s = 1e-3
    mean_gap = 3.0 * wave_s / batch
    rng = np.random.default_rng(3)
    arrivals = np.cumsum(rng.exponential(mean_gap, len(data)))

    fixed = Scheduler.fixed_wave(
        params, cfg, batch=batch, m_pad=top.m_pad, nnz_pad=top.nnz_pad,
        clock=VirtualClock(), service_model=svc)
    fr = _reqs(data)
    fixed.serve(fr, arrivals=list(arrivals))

    bucketed = Scheduler(
        params, cfg, tiers=policy, clock=VirtualClock(), service_model=svc,
        config=SchedulerConfig(batch=batch, flush_after=batch * mean_gap))
    br = _reqs(data)
    bucketed.serve(br, arrivals=list(arrivals))

    assert all(r.done for r in fr) and all(r.done for r in br)
    fm, bm = fixed.metrics.summary(), bucketed.metrics.summary()
    assert bm["padding_waste_nodes"] < fm["padding_waste_nodes"], (fm, bm)
    assert bm["latency_p99_s"] < fm["latency_p99_s"], (fm, bm)
    used = {w.tier_key for w in bucketed.metrics.waves}
    assert bm["compile_count"] == len(used)


# ---------------------------------------------------------------------------
# dataset → scheduler end-to-end smoke (satellite)
# ---------------------------------------------------------------------------

def test_unknown_bn_mode_fails_at_trace_time():
    """A bn_mode typo must raise, not silently fall back to wave-dependent
    "batch" statistics (which would void the scheduler's invariance)."""
    from repro.core.gcn import _batch_norm

    p = {"scale": np.ones(4, np.float32), "bias": np.zeros(4, np.float32)}
    x = np.zeros((2, 3, 4), np.float32)
    mask = np.ones((2, 3, 1), np.float32)
    with pytest.raises(ValueError, match="unknown bn_mode"):
        _batch_norm(p, x, mask, "per-sample")


def test_dataset_stream_to_scheduler_end_to_end():
    spec = GraphDatasetSpec.tox21_like(
        n_samples=12, n_features=8, channels=2, size_dist="skewed", seed=7)
    data = generate(spec)
    cfg = GCNConfig(n_features=8, channels=2, conv_widths=(8,),
                    n_tasks=spec.n_tasks)
    params = init_gcn(jax.random.key(1), cfg)
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=2, batch=4)
    sched = Scheduler(params, cfg, tiers=policy, clock=VirtualClock(),
                      config=SchedulerConfig(batch=4, flush_after=0.05))
    reqs = _reqs(data)
    sched.warmup(reqs)
    out = sched.serve(reqs)
    assert all(r.done and r.logits.shape == (spec.n_tasks,) for r in out)
    s = sched.metrics.summary()
    assert s["served"] == len(data) and s["rejected"] == 0
    assert s["compile_count"] <= len(policy.tiers)
    assert 0.0 < s["fill_rate"] <= 1.0


# ---------------------------------------------------------------------------
# RealClock (ISSUE 6 satellite): the wall-time clock through the same event
# loop, with time.monotonic/time.sleep stubbed so nothing actually sleeps.
# ---------------------------------------------------------------------------

class _FakeTime:
    """Deterministic stand-in for the ``time`` module inside scheduler.py:
    monotonic()/perf_counter() read a controlled counter, sleep() advances
    it (recording every sleep), so RealClock's real code paths run without
    wall-clock flakiness."""

    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def monotonic(self):
        return self.t

    def perf_counter(self):
        return self.t

    def sleep(self, dt):
        assert dt >= 0
        self.sleeps.append(dt)
        self.t += dt


@pytest.fixture()
def fake_time(monkeypatch):
    from repro.scheduler import scheduler as sched_mod

    ft = _FakeTime()
    monkeypatch.setattr(sched_mod, "time", ft)
    return ft


def test_real_clock_serve_drains(small_setup, fake_time):
    """serve() under the default RealClock drains the whole stream: every
    request completes, future arrivals are waited for by really sleeping
    (the stub records the sleeps), and waves dispatch at >= arrival."""
    spec, data, cfg, params = small_setup
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=2, batch=4)
    sched = Scheduler(params, cfg, tiers=policy,
                      service_model=lambda tier, n: 0.0,
                      config=SchedulerConfig(batch=4, flush_after=0.05))
    from repro.scheduler.scheduler import RealClock

    assert isinstance(sched.clock, RealClock)      # the default clock
    reqs = _reqs(data[:8])
    arrivals = [0.0, 0.0, 0.0, 0.0, 0.5, 0.5, 0.5, 0.5]
    out = sched.serve(reqs, arrivals=arrivals)
    assert all(r.done and not r.failed for r in out)
    assert sched.metrics.served == 8
    # the second burst arrives in the future: RealClock must actually sleep
    # to it, not spin or drop it
    assert fake_time.sleeps and fake_time.t >= 0.5
    for p in sched.completed:
        assert p.dispatch >= p.arrival


def test_real_clock_deadline_expiry_wall_time(small_setup, fake_time):
    """Deadline misses under RealClock are measured against WALL time: a
    wave whose service outlasts the request's deadline records a miss even
    though the virtual service model never advances this clock."""
    import dataclasses as dc

    spec, data, cfg, params = small_setup
    policy = TierPolicy(m_pads=(56,), nnz_pads=(128,), batch=4)

    class _SlowEngine(GraphServeEngine):
        def run_wave(self, wave):
            fake_time.t += 1.0              # the wave burns 1s of wall time
            return super().run_wave(wave)

    cfg_sample = dc.replace(cfg, bn_mode="sample")
    sched = Scheduler(
        params, cfg, tiers=policy,
        config=SchedulerConfig(batch=4, flush_after=0.1),
        engine_factory=lambda tier: _SlowEngine(
            params, cfg_sample, batch=tier.batch, m_pad=tier.m_pad,
            nnz_pad=tier.nnz_pad))
    reqs = _reqs(data[:2])
    sched.serve(reqs, deadlines=[0.5, 2.5])        # one busts, one survives
    assert all(r.done for r in reqs)
    assert sched.metrics.deadline_misses == 1
    for p in sched.completed:
        assert p.finish >= 1.0                      # wall time really moved


def test_real_clock_matches_virtual_wave_composition(small_setup, fake_time):
    """The SAME arrival trace produces the SAME wave composition under
    RealClock (stubbed wall time) and VirtualClock: the clock abstraction
    changes how time passes, never which requests ride together."""
    spec, data, cfg, params = small_setup
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=2, batch=4)
    arrivals = [0.0, 0.0, 0.1, 0.1, 0.4, 0.4, 0.4, 1.0]

    def run(clock):
        sched = Scheduler(
            params, cfg, tiers=policy, clock=clock,
            service_model=lambda tier, n: 0.0,
            config=SchedulerConfig(batch=4, flush_after=0.05))
        sched.serve(_reqs(data[:8]), arrivals=list(arrivals))
        return [(w.tier_key, w.report.n_requests)
                for w in sched.metrics.waves]

    real = run(None)                                # None → RealClock
    fake_time.t = 0.0
    virtual = run(VirtualClock())
    assert real == virtual and sum(n for _, n in real) == 8
