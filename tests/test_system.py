"""End-to-end behaviour tests for the paper's system (ChemGCN + Batched SpMM)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.formats import BatchedCOO
from repro.core.gcn import GCNConfig, apply_gcn, gcn_loss, init_gcn
from repro.data.graphs import GraphDatasetSpec, batches, generate
from repro.optim import AdamConfig, adam_init, adam_update


@pytest.fixture(scope="module")
def tox21_like():
    spec = GraphDatasetSpec.tox21_like(n_samples=160)
    return spec, generate(spec)


def _train(cfg, spec, data, steps_epochs=4, lr=3e-3, batch=32):
    params = init_gcn(jax.random.key(0), cfg)
    opt = AdamConfig(lr=lr)
    state = adam_init(params)

    @jax.jit
    def step(params, state, adj_arrays, x, n_nodes, labels):
        adj = [BatchedCOO(*a) for a in adj_arrays]
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gcn_loss(p, cfg, adj, x, n_nodes, labels),
            has_aux=True)(params)
        params, state = adam_update(opt, params, grads, state)
        return params, state, loss, acc

    losses = []
    for epoch in range(steps_epochs):
        for b in batches(data, spec, batch, seed=epoch):
            adj_arrays = [(a.row_ids, a.col_ids, a.values, a.nnz, a.n_rows)
                          for a in b["adj"]]
            params, state, loss, acc = step(
                params, state, adj_arrays, b["x"], b["n_nodes"], b["labels"])
        losses.append(float(loss))
    return params, losses, float(acc)


def test_chemgcn_trains(tox21_like):
    """Training on teacher-labeled molecular graphs: loss decreases, accuracy
    beats chance — proves the whole substrate (data → conv → loss → Adam)."""
    spec, data = tox21_like
    _, losses, acc = _train(GCNConfig.tox21(impl="ref"), spec, data)
    assert losses[-1] < 0.6 * losses[0], losses
    assert acc > 0.7


def test_batched_equals_nonbatched_full_model(tox21_like):
    """Paper's central numerics claim: the Fig. 7 batched restructuring does
    not change the model output vs the Fig. 6 per-sample loop."""
    spec, data = tox21_like
    cfg = GCNConfig.tox21(impl="ref")
    params = init_gcn(jax.random.key(1), cfg)
    b = next(batches(data, spec, 16))
    y_batched = apply_gcn(params, cfg, b["adj"], b["x"], b["n_nodes"])
    y_loop = apply_gcn(params, dataclasses.replace(cfg, batched=False),
                       b["adj"], b["x"], b["n_nodes"])
    np.testing.assert_allclose(np.asarray(y_batched), np.asarray(y_loop),
                               atol=2e-4, rtol=1e-4)


def test_pallas_impl_trains_identically(tox21_like):
    """Swapping the SpMM kernel (ref → Pallas ELL) must not change training:
    same losses step for step (within float tolerance)."""
    spec, data = tox21_like
    _, losses_ref, _ = _train(GCNConfig.tox21(impl="ref"), spec, data,
                              steps_epochs=2)
    _, losses_ell, _ = _train(GCNConfig.tox21(impl="pallas_ell"), spec, data,
                              steps_epochs=2)
    np.testing.assert_allclose(losses_ref, losses_ell, rtol=2e-3)


def test_reaction100_multiclass_head():
    spec = GraphDatasetSpec.reaction100_like(n_samples=96)
    data = generate(spec)
    cfg = GCNConfig(conv_widths=(64, 64, 64), n_tasks=100, task="multiclass",
                    n_features=spec.n_features)
    _, losses, acc = _train(cfg, spec, data, steps_epochs=6, batch=24)
    assert losses[-1] < losses[0]
    assert acc > 0.10   # 100-way chance = 1%
