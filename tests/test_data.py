"""Data pipelines: determinism, resume, shard disjointness, learnability."""
import numpy as np

from repro.data.graphs import GraphDatasetSpec, batches, generate
from repro.data.tokens import TokenStreamSpec, make_batch, token_stream


def test_token_batches_deterministic_and_resumable():
    spec = TokenStreamSpec(vocab=128, batch=4, seq_len=32, seed=3)
    a = make_batch(spec, step=7)
    b = make_batch(spec, step=7)
    np.testing.assert_array_equal(a, b)
    # streaming from step 7 yields exactly batch 7 (restart == resume)
    it = token_stream(spec, start_step=7)
    np.testing.assert_array_equal(np.asarray(next(it)["tokens"]), a)


def test_token_shards_disjoint():
    s0 = TokenStreamSpec(vocab=128, batch=4, seq_len=32, shard=0,
                         num_shards=2)
    s1 = TokenStreamSpec(vocab=128, batch=4, seq_len=32, shard=1,
                         num_shards=2)
    assert not np.array_equal(make_batch(s0, 0), make_batch(s1, 0))


def test_token_stream_has_structure():
    """Bigram structure: successor entropy must be far below uniform."""
    spec = TokenStreamSpec(vocab=64, batch=16, seq_len=128, noise=0.0)
    toks = make_batch(spec, 0)
    # every (prev → next) transition must come from ≤ branch successors
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    assert max(len(v) for v in succ.values()) <= spec.branch


def test_graph_batches_fixed_shapes():
    spec = GraphDatasetSpec.tox21_like(n_samples=64)
    data = generate(spec)
    shapes = set()
    for b in batches(data, spec, 16):
        shapes.add((b["x"].shape, b["adj"][0].row_ids.shape))
    assert len(shapes) == 1, shapes   # single compiled step per epoch
