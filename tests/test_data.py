"""Data pipelines: determinism, resume, shard disjointness, learnability."""
import numpy as np

from repro.data.graphs import GraphDatasetSpec, batches, generate
from repro.data.tokens import TokenStreamSpec, make_batch, token_stream


def test_token_batches_deterministic_and_resumable():
    spec = TokenStreamSpec(vocab=128, batch=4, seq_len=32, seed=3)
    a = make_batch(spec, step=7)
    b = make_batch(spec, step=7)
    np.testing.assert_array_equal(a, b)
    # streaming from step 7 yields exactly batch 7 (restart == resume)
    it = token_stream(spec, start_step=7)
    np.testing.assert_array_equal(np.asarray(next(it)["tokens"]), a)


def test_token_shards_disjoint():
    s0 = TokenStreamSpec(vocab=128, batch=4, seq_len=32, shard=0,
                         num_shards=2)
    s1 = TokenStreamSpec(vocab=128, batch=4, seq_len=32, shard=1,
                         num_shards=2)
    assert not np.array_equal(make_batch(s0, 0), make_batch(s1, 0))


def test_token_stream_has_structure():
    """Bigram structure: successor entropy must be far below uniform."""
    spec = TokenStreamSpec(vocab=64, batch=16, seq_len=128, noise=0.0)
    toks = make_batch(spec, 0)
    # every (prev → next) transition must come from ≤ branch successors
    succ = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    assert max(len(v) for v in succ.values()) <= spec.branch


def test_graph_batches_fixed_shapes():
    spec = GraphDatasetSpec.tox21_like(n_samples=64)
    data = generate(spec)
    shapes = set()
    for b in batches(data, spec, 16):
        shapes.add((b["x"].shape, b["adj"][0].row_ids.shape))
    assert len(shapes) == 1, shapes   # single compiled step per epoch


def test_graph_batches_same_seed_streams_identical():
    """Two same-seed batch iterators over the same dataset yield identical
    batches — the determinism the serving/benchmark replays rely on."""
    spec = GraphDatasetSpec.tox21_like(n_samples=48)
    data = generate(spec)
    for a, b in zip(batches(data, spec, 16, seed=5, epochs=2),
                    batches(data, spec, 16, seed=5, epochs=2)):
        np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
        np.testing.assert_array_equal(np.asarray(a["labels"]),
                                      np.asarray(b["labels"]))
        for ca, cb in zip(a["adj"], b["adj"]):
            np.testing.assert_array_equal(np.asarray(ca.row_ids),
                                          np.asarray(cb.row_ids))
            np.testing.assert_array_equal(np.asarray(ca.values),
                                          np.asarray(cb.values))
            np.testing.assert_array_equal(np.asarray(ca.nnz),
                                          np.asarray(cb.nnz))
    # different shuffle seed actually reorders
    first_a = next(iter(batches(data, spec, 16, seed=5)))
    first_c = next(iter(batches(data, spec, 16, seed=6)))
    assert not np.array_equal(np.asarray(first_a["n_nodes"]),
                              np.asarray(first_c["n_nodes"]))


def test_graph_batches_epoch_addressable_resume():
    """Regression (ISSUE 10): each epoch's shuffle must be a pure function
    of ``(seed, epoch)``, NOT a sequentially-consumed RNG — so
    ``start_epoch=e`` reproduces the tail of a longer stream bitwise without
    replaying the epochs before it (the fit() fast-forward contract).
    Pre-fix the shuffles chained through one Generator and any mid-stream
    entry point produced a different order."""
    spec = GraphDatasetSpec.tox21_like(n_samples=48)
    data = generate(spec)
    full = list(batches(data, spec, 16, seed=7, epochs=3))
    tail = list(batches(data, spec, 16, seed=7, epochs=1, start_epoch=2))
    per_epoch = len(full) // 3
    assert len(tail) == per_epoch
    for a, b in zip(full[2 * per_epoch:], tail):
        np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
        np.testing.assert_array_equal(np.asarray(a["labels"]),
                                      np.asarray(b["labels"]))
    # distinct epochs still reshuffle (epoch enters the seed sequence)
    assert not np.array_equal(np.asarray(full[0]["n_nodes"]),
                              np.asarray(full[per_epoch]["n_nodes"]))


def test_graph_generate_same_seed_identical_and_skewed_sizes():
    """generate() is a pure function of the spec, and size_dist="skewed"
    concentrates node counts well below max_nodes (paper Table I: Avg dim
    ≪ Max dim) while respecting the bounds."""
    spec = GraphDatasetSpec.tox21_like(n_samples=64, size_dist="skewed",
                                       seed=9)
    a, b = generate(spec), generate(spec)
    assert [s.n_nodes for s in a] == [s.n_nodes for s in b]
    for sa, sb in zip(a, b):
        np.testing.assert_array_equal(sa.features, sb.features)
        for ra, rb in zip(sa.rows, sb.rows):
            np.testing.assert_array_equal(ra, rb)
    sizes = np.array([s.n_nodes for s in a])
    assert sizes.min() >= spec.min_nodes and sizes.max() <= spec.max_nodes
    assert np.median(sizes) < (spec.min_nodes + spec.max_nodes) / 2
