import os
import sys

# Tests run on ONE CPU device (the dry-run overrides this in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
