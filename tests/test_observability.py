"""Unified telemetry layer (DESIGN.md §13): span tracer, metrics registry,
regret auditor, ServeMetrics-on-registry, strict-JSON exporters.

The load-bearing assertions (ISSUE acceptance criteria):

- a telemetry-enabled serve run produces a Chrome trace with NESTED
  scheduler → wave → kernel spans that passes the trace sanity gate;
- the regret auditor FLAGS a deliberately mis-cached decision (a poisoned
  tuning-cache ``best``) and names the would-have-won alternative;
- disabled-mode kernel hooks cost < 5% of one XLA-impl dispatch;
- ``write_bench_json`` never emits a bare ``NaN`` literal;
- ``ServeMetrics.summary()`` keys and the histogram bucket boundaries are
  pinned (downstream dashboards key on both).
"""
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_batch
from repro.core.spmm import batched_spmm
from repro.observability import (
    DEFAULT_TIME_BUCKETS,
    MetricsRegistry,
    RegretAuditor,
    TRACER,
    Tracer,
    sanitize_json,
    span,
    telemetry,
)
from repro.observability import trace as obs_trace


def _small_batch(batch=2, dim=16, nnz_per_row=2, n_b=8, seed=0):
    rng = np.random.default_rng(seed)
    a, m_pad = random_batch(rng, batch=batch, dim=dim,
                            nnz_per_row=nnz_per_row)
    b = jnp.asarray(rng.standard_normal((batch, m_pad, n_b)), jnp.float32)
    return a, b


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_records_complete_event_with_args():
    tr = Tracer()
    with tr.span("outer", cat="t", args={"k": 1}):
        time.sleep(0.001)
    (ev,) = tr.events()
    assert ev.name == "outer" and ev.ph == "X" and ev.cat == "t"
    assert ev.dur >= 1000          # ≥ 1ms in µs
    assert ev.args == {"k": 1}


def test_nested_spans_contain_by_timestamp():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    inner, outer = tr.events()     # inner closes (appends) first
    assert inner.name == "inner" and outer.name == "outer"
    assert outer.ts <= inner.ts
    assert inner.ts + inner.dur <= outer.ts + outer.dur


def test_ring_buffer_bounds_and_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 4 and tr.dropped == 6
    assert [e.name for e in evs] == ["e6", "e7", "e8", "e9"]


def test_module_span_disabled_is_shared_null_context():
    obs_trace.set_enabled(False)
    assert span("x") is obs_trace._NULL
    assert span("y") is span("z")       # no allocation per call
    n0 = len(TRACER.events())
    with span("nothing"):
        pass
    assert len(TRACER.events()) == n0


def test_telemetry_context_scopes_enabled():
    obs_trace.set_enabled(False)
    with telemetry():
        assert obs_trace.enabled()
        with telemetry(False):
            assert not obs_trace.enabled()
        assert obs_trace.enabled()
    assert not obs_trace.enabled()


def test_export_chrome_is_strict_json_and_sanitizes_args(tmp_path):
    tr = Tracer()
    with tr.span("s", args={"bad": float("nan"), "ok": 2.0}):
        pass
    tr.instant("mark")
    tr.counter("depth", 3)
    path = tr.export_chrome(tmp_path / "t.json")

    def boom(tok):
        raise AssertionError(f"non-strict literal {tok}")

    doc = json.loads(path.read_text(), parse_constant=boom)
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} == {"X", "i", "C"}
    s = next(e for e in evs if e["ph"] == "X")
    assert s["args"] == {"bad": None, "ok": 2.0}
    assert all({"name", "ph", "ts", "pid", "tid"} <= set(e) for e in evs)


def test_sanitize_json_maps_all_non_finite():
    out = sanitize_json({"a": float("inf"), "b": [float("-inf"),
                                                 float("nan"), 1.5]})
    assert out == {"a": None, "b": [None, None, 1.5]}


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_labels_and_negative_rejected():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    c.inc(impl="csr")
    c.inc(2, impl="ell")
    assert c.value(impl="csr") == 1 and c.value(impl="ell") == 2
    assert c.value(impl="none") == 0 and c.total() == 3
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)


def test_gauge_nan_until_set():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    assert math.isnan(g.value())
    g.set(4)
    assert g.value() == 4.0


def test_registry_kind_mismatch_raises_and_same_name_shares():
    reg = MetricsRegistry()
    c = reg.counter("n")
    assert reg.counter("n") is c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("n")


def test_default_bucket_boundaries_pinned():
    # downstream dashboards key on these exact le bounds — changing them is
    # a schema change, not a tweak
    assert DEFAULT_TIME_BUCKETS == (
        1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0)


def test_histogram_bucket_boundaries_are_le_inclusive():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 10.0))
    for v in (0.5, 1.0, 1.0001, 10.0, 11.0):
        h.observe(v)
    (row,) = list(h.rows())
    assert [b["le"] for b in row["buckets"]] == [1.0, 10.0, float("inf")]
    assert [b["count"] for b in row["buckets"]] == [2, 2, 1]   # le-inclusive
    assert row["count"] == 5 and row["min"] == 0.5 and row["max"] == 11.0


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError, match="ascending"):
        MetricsRegistry().histogram("bad", buckets=(2.0, 1.0))


def test_histogram_exact_percentile_with_keep_samples():
    reg = MetricsRegistry()
    h = reg.histogram("lat", keep_samples=True)
    for v in range(1, 101):
        h.observe(float(v))
    assert h.percentile(50) == pytest.approx(50.5)
    assert h.percentile(99) == pytest.approx(
        float(np.percentile(np.arange(1.0, 101.0), 99)))


def test_histogram_single_sample_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat", keep_samples=True)
    h.observe(0.25)
    assert h.percentile(50) == 0.25 and h.percentile(99) == 0.25
    assert math.isnan(h.percentile(50, tier="other"))   # empty series


def test_export_jsonl_strict_with_nan_gauge(tmp_path):
    reg = MetricsRegistry()
    reg.gauge("g").set(float("nan"))
    reg.counter("c").inc()
    path = reg.export_jsonl(tmp_path / "m.jsonl", extra={"run": "t"})

    def boom(tok):
        raise AssertionError(f"non-strict literal {tok}")

    lines = [json.loads(ln, parse_constant=boom)
             for ln in path.read_text().splitlines()]
    assert lines[0] == {"type": "meta", "run": "t"}
    by_name = {ln.get("metric"): ln for ln in lines[1:]}
    assert by_name["g"]["value"] is None        # NaN → null
    assert by_name["c"]["value"] == 1


# ---------------------------------------------------------------------------
# kernel-dispatch spans + overhead guard
# ---------------------------------------------------------------------------

def test_kernel_dispatch_span_carries_geometry_and_prediction():
    a, b = _small_batch(seed=0)
    TRACER.clear()
    with telemetry():
        batched_spmm(a, b, impl="csr")
    evs = [e for e in TRACER.events() if e.name.startswith("spmm/")]
    assert evs, "no kernel span recorded under telemetry"
    args = evs[0].args
    assert args["impl"] == "csr" and args["source"] == "forced"
    assert args["batch"] == 2 and args["n_b"] == 8
    assert args["predicted_s"] is None or args["predicted_s"] > 0
    assert args["key"]            # the Workload key ties span → cache/audit
    TRACER.clear()


def test_kernel_span_feeds_regret_auditor():
    from repro.observability import default_auditor

    a, b = _small_batch(seed=1)
    aud = default_auditor()
    n0 = len(aud.entries)
    with telemetry():
        batched_spmm(a, b, impl="auto")
    new = aud.entries[n0:]
    assert new and all(e.source == "span" for e in new)
    assert all(e.regret_ratio == 1.0 for e in new)
    TRACER.clear()


def test_disabled_telemetry_overhead_under_5pct_of_xla_dispatch():
    """The ISSUE overhead gate: with telemetry OFF, the per-dispatch hook
    cost (one predicate + null context) must be < 5% of one jitted XLA-impl
    batched_spmm dispatch. Comparing hook-cost against the dispatch median
    (not two nearly-equal end-to-end timings) keeps this robust to CI
    timing noise."""
    obs_trace.set_enabled(False)
    a, b = _small_batch(batch=4, dim=32, nnz_per_row=2, n_b=16, seed=2)
    f = jax.jit(lambda bb: batched_spmm(a, bb, impl="csr"))
    jax.block_until_ready(f(b))
    ts = []
    for _ in range(30):
        t0 = time.perf_counter()
        jax.block_until_ready(f(b))
        ts.append(time.perf_counter() - t0)
    dispatch_s = float(np.median(ts))

    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        with span("x"):
            pass
        obs_trace.enabled()
    hook_s = (time.perf_counter() - t0) / n
    assert hook_s < 0.05 * dispatch_s, (
        f"disabled-mode hook {hook_s:.2e}s >= 5% of dispatch "
        f"{dispatch_s:.2e}s")


# ---------------------------------------------------------------------------
# regret auditor
# ---------------------------------------------------------------------------

def test_auditor_flags_deliberately_poisoned_cache(tmp_path):
    """Poison a tuning-cache record so its pinned ``best`` is a measured
    LOSER; the auditor must replay the cache-driven decision, flag it, and
    name the measured winner as would_have_won — the ISSUE acceptance."""
    from repro.autotune import TuningCache, Workload, select_impl

    cache = TuningCache(str(tmp_path / "tune.json"))
    w = Workload(batch=4, m_pad=16, nnz_pad=64, k_pad=4, n_b=8)
    times = {"ref": 5e-4, "csr": 1e-4, "dense": 2e-4}
    cache.put(w.key(), times, interpret=True)
    cache.records[w.key()]["best"] = "ref"      # the poison: pin a loser
    d = select_impl(w, allow_pallas=False, cache=cache)
    assert d.impl == "ref" and d.source == "cache"   # poison took effect

    aud = RegretAuditor()
    (entry,) = aud.audit_cache(cache, [w], allow_pallas=False)
    assert entry.flagged and entry.chosen == "ref" and entry.best == "csr"
    assert entry.regret_ratio == pytest.approx(5.0)
    rep = aud.report()
    assert rep["n_flagged"] == 1
    assert rep["flagged"][0]["would_have_won"] == "csr"
    assert rep["flagged"][0]["source"] == "cache"
    json.dumps(sanitize_json(rep), allow_nan=False)   # strict-JSON-able
    assert "FLAG" in aud.format_report()


def test_auditor_clean_cache_not_flagged(tmp_path):
    from repro.autotune import TuningCache, Workload

    cache = TuningCache(str(tmp_path / "tune.json"))
    w = Workload(batch=4, m_pad=16, nnz_pad=64, k_pad=4, n_b=8)
    cache.put(w.key(), {"ref": 5e-4, "csr": 1e-4}, interpret=True)
    aud = RegretAuditor()
    (entry,) = aud.audit_cache(cache, [w], allow_pallas=False)
    assert not entry.flagged and entry.regret_ratio == pytest.approx(1.0)


def test_auditor_per_impl_ratios_geomean():
    from repro.autotune import Workload

    aud = RegretAuditor()
    w = Workload(batch=4, m_pad=16, nnz_pad=64, k_pad=4, n_b=8)
    # measured = 2x predicted twice → geomean exactly 2.0
    for _ in range(2):
        p = aud.entries  # noqa: F841
        from repro.autotune.cost_model import estimate

        pred = estimate(w, "ref", aud.hw)
        aud.record(w.key(), "ref", predicted_s=pred, measured_s=2 * pred)
    r = aud.per_impl_ratios()
    assert r["ref"]["n"] == 2
    assert r["ref"]["geomean_measured_over_predicted"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# ServeMetrics on the registry
# ---------------------------------------------------------------------------

SUMMARY_KEYS = {
    "served", "rejected", "deadline_misses", "waves", "compile_count",
    "throughput_rps", "latency_p50_s", "latency_p99_s", "mean_wait_s",
    "padding_waste_nodes", "padding_waste_nnz", "fill_rate",
}


def _report(**kw):
    from repro.serving.engine import GraphWaveReport

    base = dict(slots=4, n_requests=2, n_failed=0, real_nodes=20,
                real_nnz=40, node_capacity=64, nnz_capacity=512)
    base.update(kw)
    return GraphWaveReport(**base)


def test_servemetrics_empty_run_summary_keys_pinned():
    from repro.scheduler.metrics import ServeMetrics

    s = ServeMetrics().summary()
    assert set(s) == SUMMARY_KEYS       # the BENCH_serve.json schema
    assert s["served"] == 0 and s["waves"] == 0
    for k in ("throughput_rps", "latency_p50_s", "latency_p99_s",
              "mean_wait_s", "padding_waste_nodes", "fill_rate"):
        assert math.isnan(s[k]), k


def test_servemetrics_all_rejected():
    from repro.scheduler.metrics import ServeMetrics

    m = ServeMetrics()
    m.record_rejection(arrival=0.0)
    m.record_request(arrival=1.0, dispatch=2.0, finish=3.0, failed=True)
    assert m.served == 0 and m.rejected == 2
    assert math.isnan(m.throughput) and math.isnan(m.p50)


def test_servemetrics_single_sample_percentiles():
    from repro.scheduler.metrics import ServeMetrics

    m = ServeMetrics()
    m.record_request(arrival=0.0, dispatch=0.5, finish=2.0)
    assert m.p50 == pytest.approx(2.0) and m.p99 == pytest.approx(2.0)


def test_servemetrics_single_request_throughput_not_nan():
    """Regression: ONE request finishing at its own arrival timestamp
    (zero-width clock span) used to make throughput NaN; it must fall back
    to the wave's service time."""
    from repro.scheduler.metrics import ServeMetrics

    m = ServeMetrics()
    m.record_wave("t0", dispatch=0.0, service_time=0.25, report=_report())
    m.record_request(arrival=0.0, dispatch=0.0, finish=0.0)
    assert m.throughput == pytest.approx(1 / 0.25)
    assert not math.isnan(m.summary()["throughput_rps"])


def test_servemetrics_deadline_and_waste_accounting():
    from repro.scheduler.metrics import ServeMetrics

    m = ServeMetrics()
    m.record_wave("t0", dispatch=1.0, service_time=0.5, report=_report())
    m.record_request(arrival=0.0, dispatch=1.0, finish=1.5, deadline=1.2)
    m.record_request(arrival=0.5, dispatch=1.0, finish=1.5, deadline=2.0)
    assert m.served == 2 and m.deadline_misses == 1
    assert m.padding_waste_nodes == pytest.approx(1 - 20 / 64)
    assert m.padding_waste_nnz == pytest.approx(1 - 40 / 512)
    assert m.fill_rate == pytest.approx(2 / 4)
    assert m.throughput == pytest.approx(2 / 1.5)


def test_servemetrics_snapshot_carries_serve_series():
    from repro.scheduler.metrics import ServeMetrics

    m = ServeMetrics()
    m.record_wave("t0", dispatch=0.0, service_time=0.1, report=_report())
    m.record_request(arrival=0.0, dispatch=0.0, finish=0.1)
    names = {r["metric"] for r in m.registry.snapshot()}
    assert {"serve_requests_total", "serve_latency_seconds",
            "serve_wave_service_seconds", "serve_waves_total"} <= names


def test_shared_registry_with_instance_labels():
    from repro.scheduler.metrics import ServeMetrics

    reg = MetricsRegistry()
    a = ServeMetrics(registry=reg, labels={"instance": "a"})
    b = ServeMetrics(registry=reg, labels={"instance": "b"})
    a.record_request(arrival=0.0, dispatch=0.0, finish=1.0)
    assert a.served == 1 and b.served == 0      # series stay separate


# ---------------------------------------------------------------------------
# end-to-end: telemetry-enabled serve run → nested trace + regret report
# ---------------------------------------------------------------------------

def test_serve_run_produces_nested_trace_and_regret_report(tmp_path):
    from benchmarks.check_trace_json import check_file
    from repro.core.gcn import GCNConfig, init_gcn
    from repro.data.graphs import GraphDatasetSpec, generate
    from repro.observability import default_auditor
    from repro.scheduler import Scheduler, TierPolicy, VirtualClock
    from repro.serving import GraphRequest

    spec = GraphDatasetSpec.tox21_like(
        n_samples=6, n_features=8, channels=2, seed=3)
    data = generate(spec)
    cfg = GCNConfig(n_features=8, channels=2, conv_widths=(8,), n_tasks=3)
    params = init_gcn(jax.random.key(0), cfg)
    policy = TierPolicy.from_requests(
        [(s.n_nodes, max(len(r) for r in s.rows)) for s in data],
        levels=1, batch=4)
    reqs = [GraphRequest(rows=s.rows, cols=s.cols, features=s.features,
                         n_nodes=s.n_nodes) for s in data]

    TRACER.clear()
    aud = default_auditor()
    n0 = len(aud.entries)
    with telemetry():       # kernel spans on; no warmup → trace-time spans
        sched = Scheduler(params, cfg, tiers=policy, clock=VirtualClock())
        out = sched.serve(reqs)
    assert all(r.done and not r.failed for r in out)

    evs = TRACER.events()
    sched_spans = [e for e in evs if e.name == "sched/wave"]
    wave_spans = [e for e in evs if e.name == "serve/wave"]
    kern_spans = [e for e in evs if e.name.startswith(("spmm/", "gspmm/"))]
    assert sched_spans and wave_spans and kern_spans

    def contains(outer, inner):
        return (outer.ts <= inner.ts
                and inner.ts + inner.dur <= outer.ts + outer.dur)

    # nesting: every engine wave sits inside a scheduler wave; at least one
    # kernel span (fired at trace time, first wave per geometry) sits
    # inside an engine wave
    assert all(any(contains(s, w) for s in sched_spans) for w in wave_spans)
    assert any(any(contains(w, k) for w in wave_spans) for k in kern_spans)
    # lifecycle events on the scheduler's clock track
    names = {e.name for e in evs}
    assert {"request/arrival", "request/admit", "request", "queue_depth"} \
        <= names

    # the exported trace passes the CI gate
    path = TRACER.export_chrome(tmp_path / "serve_trace.json")
    assert check_file(path) == []

    # the regret report saw this run's kernel spans (predicted-vs-measured
    # per impl) and rolls up strict-JSON-able
    rep = default_auditor().report()
    assert len(aud.entries) > n0
    assert rep["per_impl"], "no per-impl calibration ratios accumulated"
    json.dumps(sanitize_json(rep), allow_nan=False)
    TRACER.clear()


def test_trainer_metrics_hooks(tmp_path):
    from repro.core.gcn import GCNConfig
    from repro.data.graphs import GraphDatasetSpec, batches, generate
    from repro.training import GCNTrainer, TrainerConfig

    spec = GraphDatasetSpec.tox21_like(
        n_samples=8, n_features=8, channels=2, seed=4)
    data = generate(spec)
    cfg = GCNConfig(n_features=8, channels=2, conv_widths=(8,), n_tasks=12)
    reg = MetricsRegistry()
    trainer = GCNTrainer(
        cfg, tcfg=TrainerConfig(checkpoint_dir=str(tmp_path),
                                checkpoint_every=1000, log_every=1),
        registry=reg)
    TRACER.clear()
    _, _, metrics = trainer.fit(
        lambda e: batches(data, spec, 4, seed=e), epochs=1)
    labels = {"layer": cfg.layer, "impl": cfg.impl}
    assert reg.get("train_steps_total").value(**labels) == 2    # 8/4 graphs
    assert reg.get("train_step_seconds").count(**labels) == 2
    assert np.isfinite(reg.get("train_loss").value(**labels))
    assert reg.get("train_grad_norm").value(**labels) > 0
    assert metrics["grad_norm"] > 0
    assert any(e.name == "train/step" for e in TRACER.events())
    TRACER.clear()


def test_trainer_telemetry_opt_out(tmp_path):
    from repro.core.gcn import GCNConfig
    from repro.data.graphs import GraphDatasetSpec, batches, generate
    from repro.training import GCNTrainer, TrainerConfig

    spec = GraphDatasetSpec.tox21_like(
        n_samples=4, n_features=8, channels=2, seed=5)
    data = generate(spec)
    cfg = GCNConfig(n_features=8, channels=2, conv_widths=(8,), n_tasks=12)
    reg = MetricsRegistry()
    trainer = GCNTrainer(
        cfg, tcfg=TrainerConfig(checkpoint_dir=str(tmp_path),
                                checkpoint_every=1000),
        registry=reg, telemetry=False)
    TRACER.clear()
    trainer.fit(lambda e: batches(data, spec, 4, seed=e), epochs=1)
    assert reg.get("train_steps_total").total() == 0
    assert not any(e.name == "train/step" for e in TRACER.events())


# ---------------------------------------------------------------------------
# bench-JSON strictness satellites
# ---------------------------------------------------------------------------

def test_write_bench_json_serializes_nan_as_null(tmp_path):
    from benchmarks import common
    from benchmarks.check_bench_json import check_file

    start = common.results_snapshot()
    common.RESULTS.append({"name": "t/nan", "us_per_call": float("nan"),
                           "derived": ""})
    path = common.write_bench_json(
        "obs_test", start=start, path=tmp_path / "BENCH_obs_test.json",
        extra={"inf": float("inf")})
    common.RESULTS.pop()

    def boom(tok):
        raise AssertionError(f"bare {tok} literal in bench JSON")

    doc = json.loads(path.read_text(), parse_constant=boom)
    assert doc["rows"][0]["us_per_call"] is None
    assert doc["inf"] is None
    assert check_file(path) == []       # schema-clean too


def test_check_bench_json_rejects_nan_literal(tmp_path):
    from benchmarks.check_bench_json import check_file

    p = tmp_path / "BENCH_bad.json"
    p.write_text('{"suite": "bad", "backend": "cpu", "rows": '
                 '[{"name": "x", "us_per_call": NaN, "derived": ""}]}')
    errs = check_file(p)
    assert errs and "NaN" in errs[0]


def test_check_trace_json_gates(tmp_path):
    from benchmarks.check_trace_json import check_file

    empty = tmp_path / "empty.json"
    empty.write_text('{"traceEvents": []}')
    assert any("EMPTY" in e for e in check_file(empty))

    nan = tmp_path / "nan.json"
    nan.write_text('{"traceEvents": [{"name": "x", "ph": "X", "ts": NaN, '
                   '"pid": 1, "tid": 1, "dur": 1}]}')
    assert any("non-finite" in e for e in check_file(nan))

    bad_ph = tmp_path / "ph.json"
    bad_ph.write_text('{"traceEvents": [{"name": "x", "ph": "Q", "ts": 1, '
                      '"pid": 1, "tid": 1}]}')
    assert any("unknown" in e for e in check_file(bad_ph))
