"""Adaptive dispatch (impl="auto") — selector regimes, oracle equivalence,
tuning cache persistence (DESIGN.md §5)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autotune import (
    Decision,
    TuningCache,
    Workload,
    autotune,
    measure_workload,
    rank,
    select_impl,
)
from repro.core import coo_to_dense, random_batch
from repro.core.spmm import IMPLS, batched_spmm, resolve_impl


# ---------------------------------------------------------------------------
# Selector: the paper's three regimes must pick three different impl classes
# ---------------------------------------------------------------------------

SMALL_DENSE = Workload(batch=100, m_pad=56, nnz_pad=512, k_pad=16, n_b=64)
LARGE_M = Workload(batch=4, m_pad=10000, nnz_pad=40000, k_pad=4, n_b=64)
COL_PANELED = Workload(batch=100, m_pad=2048, nnz_pad=8192, k_pad=4, n_b=512)


def test_selector_small_dense_picks_gemm_class():
    """Small dense-ish matrices: densify + batched GEMM (the paper's §V-A
    gemmBatched observation)."""
    d = select_impl(SMALL_DENSE)
    assert d.kind == "gemm", d
    assert d.case == 1
    assert d.impl in ("dense", "pallas_gemm")


def test_selector_large_m_forces_case3_fallback():
    """m_pad > LARGE_M: planner case 3, per-sample fallback, no batching."""
    d = select_impl(LARGE_M)
    assert d.case == 3
    assert d.impl == "ref"
    assert d.source == "forced"


def test_selector_column_paneled_picks_ell_class():
    """Case 2 (n_b split into column panels), sparse rows: the row-split ELL
    kernel — the paper's headline batched SpMM."""
    d = select_impl(COL_PANELED)
    assert d.kind == "ell", d
    assert d.case == 2
    assert d.plan.p > 1


def test_three_regimes_are_three_different_classes():
    kinds = {select_impl(w).kind for w in (SMALL_DENSE, LARGE_M, COL_PANELED)}
    assert len(kinds) == 3, kinds


def test_allow_pallas_switches_backend_not_class():
    """interpret=True (CPU) must not pick Pallas impls, but the kernel CLASS
    decision is backend-independent."""
    for w in (SMALL_DENSE, COL_PANELED):
        d_tpu = select_impl(w, allow_pallas=True)
        d_cpu = select_impl(w, allow_pallas=False)
        assert d_tpu.kind == d_cpu.kind
        assert not d_cpu.impl.startswith("pallas")


def test_selector_skewed_degree_picks_csr_class():
    """avg degree (nnz_pad/m_pad) well below k_pad: the CSR row-split —
    flat nnz traffic, rpt-bounded loop — beats ELL's padded m_pad·k_pad
    slots (GE-SpMM's skewed-degree case, DESIGN.md §9)."""
    w = Workload(batch=100, m_pad=2048, nnz_pad=8192, k_pad=8, n_b=512)
    d = select_impl(w)
    assert d.kind == "csr" and d.impl == "pallas_csr", d
    # the XLA csr fallback is a segment-sum — same scatter traffic as ref
    # plus the rpt arrays — so the CPU posture legitimately keeps the
    # scatter class; only the Pallas row-split kernel monetizes the layout
    d_cpu = select_impl(w, allow_pallas=False)
    assert d_cpu.kind in ("csr", "scatter")


def test_csr_runnable_without_k_pad():
    """CSR has no per-row bound, so unlike the ELL class it stays a
    candidate when k_pad is unknown."""
    w = Workload(batch=100, m_pad=2048, nnz_pad=8192, k_pad=None, n_b=512)
    impls = {i for i, _ in rank(w)}
    assert {"csr"} <= impls
    assert not impls & {"ell", "pallas_ell"}


def test_no_k_pad_excludes_ell_class():
    w = Workload(batch=100, m_pad=2048, nnz_pad=8192, k_pad=None, n_b=512)
    d = select_impl(w)
    assert d.kind != "ell"
    assert all(i not in ("ell", "pallas_ell") for i, _ in d.scores)


def test_rank_is_complete_and_sorted():
    scored = rank(SMALL_DENSE, allow_pallas=True)
    ts = [t for _, t in scored]
    assert ts == sorted(ts)
    assert {i for i, _ in scored} <= set(IMPLS)
    assert "loop" in {i for i, _ in scored}   # baseline is ranked, never inf


# ---------------------------------------------------------------------------
# impl="auto" end-to-end: numerics match the ref oracle in every regime
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch,dim,nnz,n_b", [
    (8, 20, 2, 16),      # small sparse (quickstart-like)
    (6, 40, 8, 64),      # small dense-ish
    (4, 60, 2, 200),     # wider n_b
])
def test_auto_matches_dense_oracle(batch, dim, nnz, n_b):
    rng = np.random.default_rng(batch + dim)
    coo, m_pad = random_batch(rng, batch=batch, dim=dim, nnz_per_row=nnz)
    b = jnp.asarray(rng.normal(size=(batch, m_pad, n_b)), jnp.float32)
    want = np.asarray(jnp.einsum("bij,bjk->bik", coo_to_dense(coo, m_pad), b))
    got = np.asarray(batched_spmm(coo, b, impl="auto", k_pad=nnz + 2))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_auto_is_default_and_jit_safe():
    rng = np.random.default_rng(0)
    coo, m_pad = random_batch(rng, batch=4, dim=16, nnz_per_row=2)
    b = jnp.asarray(rng.normal(size=(4, m_pad, 8)), jnp.float32)
    fn = jax.jit(functools.partial(batched_spmm, k_pad=4))   # impl defaults
    got = np.asarray(fn(coo, b))
    want = np.asarray(batched_spmm(coo, b, impl="ref"))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_auto_differentiable():
    rng = np.random.default_rng(3)
    coo, m_pad = random_batch(rng, batch=3, dim=12, nnz_per_row=2)
    b = jnp.asarray(rng.normal(size=(3, m_pad, 8)), jnp.float32)

    def loss(values, bb, impl):
        return jnp.sum(batched_spmm(coo.with_values(values), bb,
                                    impl=impl, k_pad=4) ** 2)

    g_auto = jax.grad(loss, argnums=(0, 1))(coo.values, b, "auto")
    g_ref = jax.grad(loss, argnums=(0, 1))(coo.values, b, "ref")
    for ga, gr in zip(g_auto, g_ref):
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gr),
                                   atol=1e-4, rtol=1e-4)


def test_resolve_impl_exposes_decision():
    rng = np.random.default_rng(1)
    coo, m_pad = random_batch(rng, batch=4, dim=16, nnz_per_row=2)
    b = jnp.asarray(rng.normal(size=(4, m_pad, 8)), jnp.float32)
    d = resolve_impl(coo, b, k_pad=4)
    assert isinstance(d, Decision)
    assert d.impl in IMPLS and d.impl != "auto"
    assert d.reason
    pinned = resolve_impl(coo, b, impl="dense", k_pad=4)
    assert pinned.impl == "dense" and pinned.source == "forced"


# ---------------------------------------------------------------------------
# Planner case boundaries drive the expected impl class
# ---------------------------------------------------------------------------

def test_case_boundaries():
    # case 1: one panel, tiny working set
    w1 = Workload(batch=10, m_pad=64, nnz_pad=256, k_pad=8, n_b=64)
    d1 = select_impl(w1)
    assert d1.case == 1 and d1.plan.p == 1
    # case 2: same rows, wide n_b → panels. Avg degree (nnz_pad/m_pad = 4)
    # is half of k_pad=8, so the row-split class that wins is CSR — flat
    # nnz traffic — not ELL, which pays the padded m_pad·k_pad slots
    # (GE-SpMM's skewed-degree case, DESIGN.md §9).
    w2 = Workload(batch=10, m_pad=2048, nnz_pad=8192, k_pad=8, n_b=4096)
    d2 = select_impl(w2)
    assert d2.case == 2 and d2.plan.p > 1
    assert d2.kind == "csr"
    # case 3: over the LARGE_M threshold
    w3 = Workload(batch=2, m_pad=8200, nnz_pad=16400, k_pad=8, n_b=64)
    d3 = select_impl(w3)
    assert d3.case == 3 and d3.impl == "ref"


# ---------------------------------------------------------------------------
# Tuning cache: persistence + measured override
# ---------------------------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "tune.json")
    cache = TuningCache(path)
    w = Workload(batch=4, m_pad=16, nnz_pad=64, k_pad=4, n_b=8)
    best = cache.put(w.key(), {"ref": 2e-4, "ell": 1e-4, "dense": 3e-4},
                     interpret=True)
    assert best == "ell"
    reloaded = TuningCache(path)
    assert reloaded.best(w.key()) == "ell"
    assert reloaded.times(w.key())["dense"] == pytest.approx(3e-4)


def test_cache_merge_on_save_unions_writers(tmp_path):
    """Regression (ISSUE 10): two caches sharing one path used to be
    last-write-wins — the second save silently dropped the first writer's
    records. save() now re-reads the file and unions: disk-only keys
    survive, shared keys merge their times at per-impl min with ``best``
    recomputed."""
    path = str(tmp_path / "tune.json")
    a = TuningCache(path)
    b = TuningCache(path)       # opened before a writes anything
    a.put("k_a", {"ref": 2e-4, "ell": 3e-4}, interpret=True)
    b.put("k_b", {"dense": 1e-4}, interpret=True)   # pre-fix: clobbered k_a
    merged = TuningCache(path)
    assert set(merged.records) == {"k_a", "k_b"}
    assert merged.best("k_a") == "ref" and merged.best("k_b") == "dense"
    # shared key: per-impl min, best recomputed from the merged map
    c = TuningCache(path)
    c.records.pop("k_b")        # this writer never measured k_b
    c.put("k_a", {"ref": 5e-4, "dense": 0.5e-4}, interpret=True)
    final = TuningCache(path)
    assert set(final.records) == {"k_a", "k_b"}     # k_b still survives
    assert final.times("k_a") == pytest.approx(
        {"ref": 2e-4, "ell": 3e-4, "dense": 0.5e-4})
    assert final.best("k_a") == "dense"
    # the merged view is also what the saving process sees afterwards
    assert c.best("k_a") == "dense"


def test_cache_overrides_model_selection(tmp_path):
    cache = TuningCache(str(tmp_path / "tune.json"))
    w = SMALL_DENSE
    assert select_impl(w, cache=cache).source == "model"
    cache.put(w.key(), {"ref": 1e-6, "dense": 9e-4}, interpret=True)
    d = select_impl(w, cache=cache)
    assert d.source == "cache" and d.impl == "ref"


def test_cache_ignores_unrunnable_measured_winner(tmp_path):
    cache = TuningCache(str(tmp_path / "tune.json"))
    w = SMALL_DENSE
    cache.put(w.key(), {"pallas_gemm": 1e-6}, interpret=True)
    # pallas not allowed on CPU → measured winner not runnable → model wins
    d = select_impl(w, allow_pallas=False, cache=cache)
    assert d.source == "model"


def test_autotune_measures_and_caches(tmp_path):
    cache = TuningCache(str(tmp_path / "tune.json"))
    w = Workload(batch=4, m_pad=16, nnz_pad=64, k_pad=4, n_b=8)
    best = autotune(w, cache=cache, impls=("ref", "ell"), interpret=True)
    assert best in ("ref", "ell")
    times = cache.times(w.key())
    assert set(times) == {"ref", "ell"}
    assert all(t > 0 for t in times.values())
    # memoized: second call returns without measuring (same record object)
    assert autotune(w, cache=cache) == best


def test_measured_cache_selects_csr_for_fig8_geometry(tmp_path, monkeypatch):
    """Acceptance (ISSUE 5): ``impl="auto"`` can select a CSR impl for a
    Fig. 8 geometry via the MEASURED tuning cache, end-to-end through the
    ``$REPRO_TUNE_CACHE`` default-cache path — and the selected impl matches
    the oracle."""
    from repro.autotune import cache as cache_mod

    rng = np.random.default_rng(0)
    coo, m_pad = random_batch(rng, batch=20, dim=20, nnz_per_row=2)  # fig8
    b = jnp.asarray(rng.normal(size=(20, m_pad, 64)), jnp.float32)
    w = Workload(batch=20, m_pad=m_pad, nnz_pad=coo.nnz_pad, k_pad=4, n_b=64)

    path = str(tmp_path / "tune.json")
    cache = TuningCache(path)
    # the user-side refresh workflow: measure the CSR class on this exact
    # workload key and persist the record
    times = measure_workload(w, ("csr",), interpret=True, warmup=1, iters=2)
    assert set(times) == {"csr"} and times["csr"] > 0
    cache.put(w.key(), times, interpret=True)

    monkeypatch.setenv(cache_mod.ENV_VAR, path)
    cache_mod._cache_for.cache_clear()    # the default cache memoizes by path
    try:
        d = resolve_impl(coo, b, impl="auto", k_pad=4)
        assert d.impl == "csr" and d.source == "cache", d
        got = np.asarray(batched_spmm(coo, b, impl=d.impl, k_pad=4))
        want = np.asarray(batched_spmm(coo, b, impl="ref"))
        np.testing.assert_allclose(got, want, atol=1e-4)
    finally:
        cache_mod._cache_for.cache_clear()


def test_measure_workload_returns_sane_times():
    w = Workload(batch=2, m_pad=16, nnz_pad=32, k_pad=4, n_b=8)
    times = measure_workload(w, ("ref", "dense"), interpret=True,
                             warmup=1, iters=2)
    assert set(times) == {"ref", "dense"}
    assert all(0 < t < 60 for t in times.values())


# ---------------------------------------------------------------------------
# Consumers route through impl="auto" by default
# ---------------------------------------------------------------------------

def test_gcn_config_defaults_to_auto():
    from repro.core.gcn import GCNConfig

    assert GCNConfig().impl == "auto"
    assert GCNConfig.tox21().impl == "auto"


def test_trainer_and_serving_consume_auto(tmp_path):
    """GCNTrainer trains and GraphServeEngine serves with the default
    (adaptive) impl — the whole consumer path exercises the dispatcher."""
    from repro.core.gcn import GCNConfig
    from repro.data.graphs import GraphDatasetSpec, batches, generate
    from repro.serving import GraphRequest, GraphServeEngine
    from repro.training import GCNTrainer, TrainerConfig

    spec = GraphDatasetSpec.tox21_like(n_samples=32)
    data = generate(spec)
    cfg = GCNConfig.tox21()
    trainer = GCNTrainer(cfg, tcfg=TrainerConfig(
        checkpoint_dir=str(tmp_path), checkpoint_every=1000))
    params, _, metrics = trainer.fit(
        lambda e: batches(data, spec, 16, seed=e), epochs=1)
    assert np.isfinite(metrics["loss"])

    reqs = [GraphRequest(rows=s.rows, cols=s.cols, features=s.features,
                         n_nodes=s.n_nodes) for s in data[:3]]
    out = GraphServeEngine(params, cfg, batch=4).run(reqs)
    assert all(r.done and r.logits.shape == (cfg.n_tasks,) for r in out)
